//! Fig 5 reproduction: the assembly of one convolution layer on the
//! baseline core (v0) next to the fully extended core (v4), with
//! per-instruction cycle counts measured by the simulator — showing the
//! `mul/add/addi/addi` inner loop collapsing to `fusedmac` and the
//! `blt` + counter increment eliminated by the hardware loop.
//!
//! Run: `make artifacts && cargo run --release --example asm_diff [-- model [layer]]`

use std::path::Path;

use marvel::coordinator::experiments::fig5_asm_diff;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .cloned()
        .unwrap_or_else(|| "lenet5".to_string());
    let layer = args.get(1).and_then(|s| s.parse().ok());
    print!("{}", fig5_asm_diff::render(artifacts, &model, layer)?);
    Ok(())
}
