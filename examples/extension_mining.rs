//! The "model-class aware" discovery loop, end to end:
//!
//! 1. profile a model's generated code on the baseline v0 core,
//! 2. let `extgen` propose ISA extensions from the profile (pattern mining,
//!    immediate-width allocation, opcode assignment, area pricing, nML),
//! 3. *close the loop*: build the extended core the proposals describe and
//!    re-measure, confirming the predicted savings direction — the paper's
//!    §II.C methodology made executable.
//!
//! Run: `make artifacts && cargo run --release --example extension_mining [-- model]`

use std::path::Path;

use marvel::compiler::{compile, execute_compiled};
use marvel::coordinator::experiments::fig3_patterns;
use marvel::extgen;
use marvel::models;
use marvel::runtime;
use marvel::sim::{NopHook, V0, V4};
use marvel::util::tables::fmt_si;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let model = std::env::args()
        .nth(1)
        .filter(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "lenet5".to_string());

    // 1. profile on v0
    println!("profiling {model} on v0...");
    let counts = fig3_patterns::profile_model(artifacts, &model)?;
    println!(
        "  {} retired instrs, {} cycles; patterns: {} mul+add, {} addi+addi, {} fusedmac-groups\n",
        fmt_si(counts.total),
        fmt_si(counts.cycles),
        fmt_si(counts.mul_add),
        fmt_si(counts.addi_addi),
        fmt_si(counts.fusedmac),
    );

    // 2. propose extensions
    let proposals = extgen::propose(&counts, 0.005);
    let mut predicted: f64 = 0.0;
    for p in &proposals {
        println!(
            "proposal: {:<9} saves {:>5.1}% of cycles  ({} sites, {:+} LUT, {:+} DSP)",
            p.name,
            p.savings_frac * 100.0,
            fmt_si(p.occurrences),
            p.cost.lut,
            p.cost.dsp
        );
        println!("{}", p.nml.lines().map(|l| format!("    {l}"))
            .collect::<Vec<_>>().join("\n"));
        predicted += p.savings_frac;
    }

    // 3. close the loop: build v4 (all proposals) and re-measure
    let spec = models::load(artifacts, &model)?;
    let io = runtime::load_golden_io(artifacts, &model)?;
    let c0 = compile(&spec, V0)?;
    let c4 = compile(&spec, V4)?;
    let (_, s0) =
        execute_compiled(&c0, &spec, &io.inputs[0], 1 << 36, &mut NopHook)?;
    let (_, s4) =
        execute_compiled(&c4, &spec, &io.inputs[0], 1 << 36, &mut NopHook)?;
    let measured = 1.0 - s4.cycles as f64 / s0.cycles as f64;
    println!(
        "\npredicted savings (upper bound, overlapping patterns): {:.1}%",
        predicted * 100.0
    );
    println!(
        "measured  savings after building the extended core:     {:.1}%  \
         ({} -> {} cycles, {:.2}x)",
        measured * 100.0,
        fmt_si(s0.cycles),
        fmt_si(s4.cycles),
        s0.cycles as f64 / s4.cycles as f64
    );
    anyhow::ensure!(measured > 0.0, "extended core must be faster");
    Ok(())
}
