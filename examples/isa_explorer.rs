//! ISA explorer: prints the extended instruction set the way the paper
//! documents it — the Table 3 opcode map, the custom encodings (Tables 4–7)
//! with live encode/decode round-trips, and the zol register model —
//! then disassembles a few encodings as a self-check.
//!
//! Run: `cargo run --release --example isa_explorer`

use marvel::isa::decode::decode;
use marvel::isa::encode::encode;
use marvel::isa::{opcodes, Instr};
use marvel::util::tables::Table;

fn show(i: Instr) {
    let w = encode(&i);
    let back = decode(w).expect("round-trip");
    assert_eq!(back, i);
    println!(
        "  {:032b}  {:#010x}  {}",
        w,
        w,
        marvel::isa::disasm::disasm(&i)
    );
}

fn main() {
    println!("== Table 3 — custom opcode assignments ==");
    let mut t = Table::new(&["extension", "opcode (binary)", "RISC-V slot"]);
    t.row(vec!["fusedmac".into(), format!("{:07b}", opcodes::CUSTOM0_FUSEDMAC),
               "custom-0".into()]);
    t.row(vec!["add2i".into(), format!("{:07b}", opcodes::CUSTOM1_ADD2I),
               "custom-1".into()]);
    t.row(vec!["mac".into(), format!("{:07b}", opcodes::CUSTOM2_MAC),
               "custom-2".into()]);
    t.row(vec!["zol (1/2)".into(), format!("{:07b}", opcodes::ZOL1),
               "reserved".into()]);
    t.row(vec!["zol (2/2)".into(), format!("{:07b}", opcodes::ZOL2),
               "row 10 / col 111".into()]);
    println!("{}", t.render());

    println!("== Table 4 — mac (fixed x20 += x21*x22) ==");
    show(Instr::Mac);

    println!("\n== Table 5 — add2i rs1+=i1; rs2+=i2 (5+10-bit split) ==");
    show(Instr::Add2i { rs1: 10, rs2: 11, i1: 1, i2: 1 });
    show(Instr::Add2i { rs1: 17, rs2: 8, i1: 31, i2: 1023 });

    println!("\n== Table 6 — fusedmac (mac + add2i in one cycle) ==");
    show(Instr::FusedMac { rs1: 10, rs2: 11, i1: 1, i2: 1 });

    println!("\n== Table 7 — zero-overhead loop instructions ==");
    show(Instr::Dlpi { count: 6, body_len: 6 });
    show(Instr::Dlp { rs1: 5, body_len: 42 });
    show(Instr::Zlp { rs1: 5, body_len: 42 });
    show(Instr::SetZc { rs1: 5 });
    show(Instr::SetZs { rs1: 6 });
    show(Instr::SetZe { rs1: 7 });
    println!(
        "\nzol registers: ZC (count), ZS (start), ZE (end); \
         hardware loops back from ZE to ZS at zero cycle cost."
    );

    println!("\n== baseline RV32IM (the trv32p3 ISA) — samples ==");
    use marvel::isa::{AluImmOp, AluOp, BranchOp, LoadOp, StoreOp};
    show(Instr::OpImm { op: AluImmOp::Addi, rd: 10, rs1: 10, imm: 1 });
    show(Instr::Op { op: AluOp::Mul, rd: 23, rs1: 21, rs2: 22 });
    show(Instr::Load { op: LoadOp::Lb, rd: 21, rs1: 10, offset: 0 });
    show(Instr::Store { op: StoreOp::Sb, rs2: 20, rs1: 12, offset: 0 });
    show(Instr::Branch { op: BranchOp::Blt, rs1: 5, rs2: 30, offset: -36 });
    println!("\nisa_explorer OK (all encodings round-tripped)");
}
