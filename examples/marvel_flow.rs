//! The end-to-end driver (DESIGN.md §5, "§III headline"): run the complete
//! MARVEL flow on the real trained LeNet-5* artifact — the workload the
//! paper's bare-metal deployment story is built around — and on every other
//! exported model.
//!
//! For each model this:
//!   1. loads the AOT-exported spec + weights (`artifacts/models/`),
//!   2. compiles it for all five processor variants (v0..v4),
//!   3. runs the golden inputs on the cycle-accurate simulator,
//!   4. verifies outputs against the exporter's reference logits and —
//!      with `--pjrt` — against the AOT HLO artifact executed via the PJRT
//!      CPU client (all three layers of the stack composing),
//!   5. reports cycles / speedup / energy (eq. 1) / memory.
//!
//! Run: `make artifacts && cargo run --release --example marvel_flow [-- --pjrt]`

use std::path::Path;

use marvel::coordinator::{run_flow, FlowOptions};
use marvel::util::tables::{fmt_si, Table};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let models =
        marvel::coordinator::experiments::available_models(artifacts);
    anyhow::ensure!(
        !models.is_empty(),
        "no artifacts found — run `make artifacts` first"
    );

    let opts = FlowOptions { n_inputs: 2, use_pjrt, ..FlowOptions::default() };
    let mut headline = Table::new(&[
        "model", "v0 cycles", "v4 cycles", "speedup", "v0 mJ", "v4 mJ",
        "energy x", "verified",
    ])
    .with_title("MARVEL end-to-end flow — headline results (cf. paper abstract)");

    for name in &models {
        let f = run_flow(artifacts, name, &opts)?;
        let v0 = f.metrics.first().unwrap();
        let v4 = f.metrics.last().unwrap();
        headline.row(vec![
            f.model.clone(),
            fmt_si(v0.cycles),
            fmt_si(v4.cycles),
            format!("{:.2}x", v4.speedup),
            format!("{:.3}", v0.energy.energy_mj),
            format!("{:.3}", v4.energy.energy_mj),
            format!(
                "{:.2}x",
                v0.energy.energy_mj / v4.energy.energy_mj.max(1e-12)
            ),
            match (f.verified_golden, f.verified_pjrt) {
                (true, Some(true)) => "golden+pjrt".into(),
                (true, None) => "golden".into(),
                _ => "FAILED".into(),
            },
        ]);
        anyhow::ensure!(f.verified_golden, "{name}: golden verification failed");
        if let Some(false) = f.verified_pjrt {
            anyhow::bail!("{name}: PJRT verification failed");
        }
    }
    println!("{}", headline.render());
    println!("(area overhead of v4: see `marvel hw` / Table 8 — 38.17% LUTs, 2.28% power)");
    Ok(())
}
