//! Quickstart: the MARVEL public API in ~40 lines.
//!
//! Builds a small in-process CNN spec (no artifacts needed), compiles it for
//! the baseline v0 and the fully-extended v4 core, runs both on the
//! cycle-accurate simulator, and checks them against the native reference
//! executor.
//!
//! Run: `cargo run --release --example quickstart`

use marvel::compiler::{compile, execute_compiled};
use marvel::hw::energy_mj;
use marvel::models::synth::{lenet_shaped, Builder};
use marvel::refexec;
use marvel::sim::{NopHook, V0, V4};
use marvel::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A model spec — normally loaded from the AOT artifacts
    //    (`marvel::models::load`); here a LeNet-5*-shaped synthetic one.
    let spec = lenet_shaped(42);
    let mut rng = Rng::new(7);
    let input = Builder::random_input(&spec, &mut rng);

    // 2. The ground truth from the native reference executor.
    let want = refexec::run(&spec, &input)?;

    // 3. Compile + simulate on baseline and extended cores.
    for variant in [V0, V4] {
        let compiled = compile(&spec, variant)?;
        let (logits, stats) =
            execute_compiled(&compiled, &spec, &input, 1 << 32, &mut NopHook)?;
        assert_eq!(logits, want, "ISS output must match the reference");
        let e = energy_mj(&variant, stats.cycles);
        println!(
            "{}: {:>9} instrs {:>9} cycles  {:>7.3} ms  {:>7.3} mJ  \
             (fused: {} mac, {} add2i, {} fusedmac; {} zol loops)",
            variant.name,
            stats.instrs,
            stats.cycles,
            e.time_ms,
            e.energy_mj,
            compiled.rewrite_stats.mac,
            compiled.rewrite_stats.add2i,
            compiled.rewrite_stats.fusedmac,
            compiled.flatten_stats.zol_loops,
        );
    }
    println!("quickstart OK — logits {want:?}");
    Ok(())
}
