"""AOT driver: build + train + quantize + export + lower everything.

This is the compile path of the three-layer architecture.  ``make
artifacts`` runs it exactly once; after that the rust binary is
self-contained.  Outputs under --out-dir (default ../artifacts):

    models/<name>.json,.bin   specs + weights (rust compiler input)
    data/<name>_{x,y}.bin     golden inputs + ref-model logits
    hlo/<name>.hlo.txt        L2 pallas model lowered to HLO *text*
    train/lenet_train_log.json  LeNet-5* training loss curve
    manifest.json             index of everything above

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
(the version the rust `xla` crate binds) rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, export, model, quantize, specs, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the baked weights MUST survive the text
    # round-trip (the default elides them as "{...}" and the rust-side
    # parser silently zero-fills).
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(spec: dict, weights: dict) -> str:
    """Lower the pallas-backed model fn to HLO text."""
    import jax.numpy as jnp
    fn = model.build_model_fn(spec, weights, backend="pallas")
    x_spec = jax.ShapeDtypeStruct(tuple(spec["input_shape"]), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(x_spec))


def build_all(out_dir: str, profile: str, names: list[str],
              train_steps: int, calib_n: int, golden_n: int,
              skip_hlo: bool) -> dict:
    manifest = {"profile": profile, "models": {}}
    os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "train"), exist_ok=True)

    for name in names:
        t0 = time.time()
        if name == "lenet5":
            params, log = train.train_lenet(steps=train_steps)
            train.save_log(log, os.path.join(out_dir, "train",
                                             "lenet_train_log.json"))
            trained = train.quantize_trained(params)
            spec, weights = specs.build(name, profile=profile,
                                        trained=trained)
        else:
            spec, weights = specs.build(name, profile=profile)

        xs_cal, _ = datagen.dataset_for(spec, calib_n, seed=100)
        quantize.calibrate(spec, weights, xs_cal)

        doc = export.export_model(spec, weights, out_dir)
        xs, labels = datagen.dataset_for(spec, golden_n, seed=200)
        ys = export.export_golden_io(spec, weights, xs, out_dir)

        entry = {
            "json": f"models/{name}.json",
            "weights": f"models/{name}.bin",
            "golden_x": f"data/{name}_x.bin",
            "golden_y": f"data/{name}_y.bin",
            "layers": len(spec["layers"]),
            "params": int(sum(np.asarray(w).size for w in weights.values())),
        }
        if name == "lenet5":
            # int-model accuracy on held-out digits (EXPERIMENTS.md)
            xs_te, ys_te = datagen.digits(256, seed=43)
            logits = model.run_batch_np(spec, weights, xs_te, backend="ref")
            acc = float((logits.argmax(1) == ys_te).mean())
            entry["int8_test_acc"] = acc
        if not skip_hlo:
            hlo = lower_model(spec, weights)
            hp = os.path.join(out_dir, "hlo", f"{name}.hlo.txt")
            with open(hp, "w") as f:
                f.write(hlo)
            entry["hlo"] = f"hlo/{name}.hlo.txt"
            entry["hlo_bytes"] = len(hlo)
        entry["build_seconds"] = round(time.time() - t0, 2)
        manifest["models"][name] = entry
        print(f"[aot] {name}: {entry}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", choices=["quick", "full"], default="quick")
    ap.add_argument("--models", nargs="*", default=specs.MODEL_NAMES)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--calib-n", type=int, default=4)
    ap.add_argument("--golden-n", type=int, default=4)
    ap.add_argument("--skip-hlo", action="store_true",
                    help="skip HLO lowering (spec/golden export only)")
    args = ap.parse_args()
    build_all(args.out_dir, args.profile, args.models, args.train_steps,
              args.calib_n, args.golden_n, args.skip_hlo)


if __name__ == "__main__":
    main()
