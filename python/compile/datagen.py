"""Synthetic datasets (substitutes for the paper's data — DESIGN.md §2).

The paper fine-tunes on StanfordCars/COCO ("Car" vs "Not Car", 64×64×3) and
uses MNIST-style digits for LeNet-5*.  Neither dataset is available offline,
and cycle counts do not depend on pixel values — so we generate procedural
lookalikes that exercise the identical code paths:

* ``digits`` — 28×28 grayscale renderings of ten 7-segment-style glyphs with
  random jitter, thickness and noise (a learnable 10-class problem: train.py
  reaches high accuracy on it, giving the end-to-end flow a real trained
  model).
* ``cars`` — H×W×3 procedural scenes: class 1 ("car") draws a body rectangle,
  cabin and two dark wheels on a gradient background; class 0 ("not car")
  draws random blobs.

All images are emitted as int8-range int32 CHW arrays (value-128 centering).
"""

import numpy as np

# 7-segment encodings for digits 0-9: segments (a,b,c,d,e,f,g)
_SEGS = {
    0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
    5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abfgcd",
}


def _draw_segment(img: np.ndarray, seg: str, x0: int, y0: int, w: int,
                  h: int, t: int):
    """Rasterize one 7-seg segment into img (modifies in place)."""
    if seg == "a":
        img[y0:y0 + t, x0:x0 + w] = 1.0
    elif seg == "b":
        img[y0:y0 + h // 2 + t // 2, x0 + w - t:x0 + w] = 1.0
    elif seg == "c":
        img[y0 + h // 2 - t // 2:y0 + h, x0 + w - t:x0 + w] = 1.0
    elif seg == "d":
        img[y0 + h - t:y0 + h, x0:x0 + w] = 1.0
    elif seg == "e":
        img[y0 + h // 2 - t // 2:y0 + h, x0:x0 + t] = 1.0
    elif seg == "f":
        img[y0:y0 + h // 2 + t // 2, x0:x0 + t] = 1.0
    elif seg == "g":
        mid = y0 + h // 2
        img[mid - t // 2:mid - t // 2 + t, x0:x0 + w] = 1.0


def digits(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """n samples of (1, 28, 28) int32 digit images + labels (n,) int32."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 1, 28, 28), dtype=np.int32)
    ys = rng.integers(0, 10, size=n).astype(np.int32)
    for i in range(n):
        img = np.zeros((28, 28), dtype=np.float64)
        w = int(rng.integers(10, 15))
        h = int(rng.integers(16, 22))
        x0 = int(rng.integers(2, 28 - w - 1))
        y0 = int(rng.integers(2, 28 - h - 1))
        t = int(rng.integers(2, 4))
        for seg in _SEGS[int(ys[i])]:
            _draw_segment(img, seg, x0, y0, w, h, t)
        img = img * rng.uniform(0.7, 1.0)
        img += rng.normal(0, 0.06, size=img.shape)
        img = np.clip(img, 0.0, 1.0)
        xs[i, 0] = (img * 255.0 - 128.0).round().astype(np.int32)
    return xs, ys


def _disk(img: np.ndarray, cy: float, cx: float, r: float, val):
    h, w = img.shape[:2]
    yy, xx = np.mgrid[0:h, 0:w]
    mask = (yy - cy) ** 2 + (xx - cx) ** 2 <= r * r
    img[mask] = val


def cars(n: int, hw: int = 64, seed: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """n samples of (3, hw, hw) int32 car/not-car images + labels (n,)."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 3, hw, hw), dtype=np.int32)
    ys = rng.integers(0, 2, size=n).astype(np.int32)
    for i in range(n):
        img = np.zeros((hw, hw, 3), dtype=np.float64)
        # gradient sky/road background
        grad = np.linspace(0.65, 0.25, hw)[:, None]
        img[..., 0] = grad * rng.uniform(0.8, 1.0)
        img[..., 1] = grad * rng.uniform(0.8, 1.0)
        img[..., 2] = grad * rng.uniform(0.9, 1.1)
        if ys[i] == 1:
            # car: body + cabin + two wheels
            bw = int(rng.integers(hw // 2, hw - 8))
            bh = int(rng.integers(hw // 6, hw // 3))
            x0 = int(rng.integers(2, hw - bw - 2))
            y0 = int(rng.integers(hw // 2, hw - bh - hw // 8))
            color = rng.uniform(0.3, 1.0, size=3)
            img[y0:y0 + bh, x0:x0 + bw] = color
            cw = int(bw * 0.5)
            ch = int(bh * 0.8)
            img[y0 - ch:y0, x0 + bw // 4:x0 + bw // 4 + cw] = color * 0.9
            r = max(2.0, bh * 0.45)
            _disk(img, y0 + bh, x0 + bw * 0.22, r, 0.05)
            _disk(img, y0 + bh, x0 + bw * 0.78, r, 0.05)
        else:
            # not-car: random blobs
            for _ in range(int(rng.integers(2, 6))):
                _disk(img, rng.uniform(0, hw), rng.uniform(0, hw),
                      rng.uniform(3, hw / 4), rng.uniform(0, 1, size=3))
        img += rng.normal(0, 0.02, size=img.shape)
        img = np.clip(img, 0.0, 1.0)
        xs[i] = np.transpose((img * 255.0 - 128.0).round(), (2, 0, 1))
    return xs.astype(np.int32), ys


def dataset_for(spec: dict, n: int, seed: int = 3):
    """Calibration/eval inputs matching a spec's input shape."""
    c, h, w = spec["input_shape"]
    if c == 1:
        xs, ys = digits(n, seed=seed)
        assert xs.shape[2:] == (h, w)
        return xs, ys
    return cars(n, hw=h, seed=seed)
