"""Artifact exporter: spec JSON + weight blob + golden I/O binaries.

Formats consumed by the rust side (rust/src/compiler/spec.rs,
rust/src/coordinator):

``models/<name>.json``  — the spec dict with a ``tensors`` table:
    tensors: [{name, dtype: "i8"|"i32", shape, offset, size}], offsets into
    ``models/<name>.bin``.  i8 tensors are stored one byte per element
    (two's complement), i32 little-endian 4 bytes.
``data/<name>_x.bin`` / ``data/<name>_y.bin`` — N golden inputs (int8 bytes,
    CHW row-major) and the ref-model logits (int32 LE), with a small JSON
    sidecar ``data/<name>_io.json`` describing counts/shapes.
"""

import json
import os

import numpy as np

from . import model as model_mod


def export_model(spec: dict, weights: dict, out_dir: str) -> dict:
    """Write models/<name>.{json,bin}. Returns the JSON dict."""
    os.makedirs(os.path.join(out_dir, "models"), exist_ok=True)
    name = spec["name"]
    dtypes = spec.get("tensor_dtypes", {})
    tensors = []
    blob = bytearray()
    for tname in sorted(weights.keys(), key=lambda s: int(s[1:])):
        arr = np.asarray(weights[tname], dtype=np.int32)
        dtype = dtypes.get(tname, "i8")
        offset = len(blob)
        if dtype == "i8":
            assert arr.min() >= -128 and arr.max() <= 127, \
                f"{name}/{tname}: values out of int8 range"
            blob += arr.astype(np.int8).tobytes()
        else:
            blob += arr.astype("<i4").tobytes()
        tensors.append({
            "name": tname, "dtype": dtype, "shape": list(arr.shape),
            "offset": offset, "size": int(arr.size),
        })
    doc = {k: v for k, v in spec.items() if k != "tensor_dtypes"}
    doc["tensors"] = tensors
    doc["weights_file"] = f"{name}.bin"
    with open(os.path.join(out_dir, "models", f"{name}.json"), "w") as f:
        json.dump(doc, f, indent=1)
    with open(os.path.join(out_dir, "models", f"{name}.bin"), "wb") as f:
        f.write(bytes(blob))
    return doc


def export_golden_io(spec: dict, weights: dict, xs: np.ndarray,
                     out_dir: str) -> np.ndarray:
    """Run the ref model on xs, write golden inputs/outputs. Returns logits."""
    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    name = spec["name"]
    ys = model_mod.run_batch_np(spec, weights, xs, backend="ref")
    with open(os.path.join(out_dir, "data", f"{name}_x.bin"), "wb") as f:
        f.write(xs.astype(np.int8).tobytes())
    with open(os.path.join(out_dir, "data", f"{name}_y.bin"), "wb") as f:
        f.write(ys.astype("<i4").tobytes())
    with open(os.path.join(out_dir, "data", f"{name}_io.json"), "w") as f:
        json.dump({
            "n": int(xs.shape[0]),
            "input_shape": list(xs.shape[1:]),
            "output_len": int(ys.shape[1]),
        }, f, indent=1)
    return ys
