"""L1: Pallas kernels for MARVEL's quantized CNN operators + jnp oracles."""

from .conv2d import conv2d, conv2d_f32
from .dwconv2d import dwconv2d
from .dense import dense, dense_f32
from .pool import maxpool, avgpool_global, avgpool2d
from .eltwise import add, requantize

__all__ = [
    "conv2d", "conv2d_f32", "dwconv2d", "dense", "dense_f32",
    "maxpool", "avgpool_global", "avgpool2d", "add", "requantize",
]
