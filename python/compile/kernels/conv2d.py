"""L1 Pallas kernel: quantized 2-D convolution.

The paper's compute hot-spot is the int8 conv inner loop (the very loop whose
``mul+add`` / ``addi+addi`` / ``blt`` patterns MARVEL fuses on the RISC-V
side).  Here the same operator is expressed as a Pallas kernel so it lowers
into the AOT HLO artifact that the rust runtime executes as the golden model.

TPU mapping of the paper's insight (DESIGN.md §Hardware-Adaptation): the grid
tiles the output-channel axis; each program holds one OC slice of the weights
and the whole padded input block in VMEM and performs the (ic, ky, kx)
reduction as dense contractions that map onto the MXU — the scalar
``mac``/``fusedmac`` chain of the RISC-V core becomes a systolic-array
contraction, and loop control (``zol``) is absorbed by the Pallas grid.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is exactly what the
rust PJRT CPU client needs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import requant


def _conv2d_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, shift, relu,
                   kh, kw, oh, ow):
    """One grid step: one output channel over the full (OH, OW) plane.

    x_ref: (IC, IHp, IWp) already zero-padded input block.
    w_ref: (1, IC, KH, KW) weight block for this output channel.
    b_ref: (1,) bias. o_ref: (1, OH, OW).
    """
    x = x_ref[...]
    w = w_ref[...][0]
    ic = x.shape[0]
    acc = jnp.full((oh, ow), b_ref[0], dtype=jnp.int32)
    # Static (ky, kx) unroll; each tap is a strided slice + channel
    # contraction.  In interpret mode this is an einsum; on a real TPU the
    # contraction feeds the MXU.
    for ky in range(kh):
        for kx in range(kw):
            xs = jax.lax.slice(
                x,
                (0, ky, kx),
                (ic, ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )  # (IC, OH, OW)
            acc = acc + jnp.einsum(
                "i,ihw->hw", w[:, ky, kx], xs,
                preferred_element_type=jnp.int32)
    o_ref[0] = requant(acc, shift, relu)


def conv2d(x, w, b, *, stride: int, pad: int, shift: int, relu: bool):
    """Quantized conv2d via Pallas.

    x: (IC, IH, IW) int32 (int8-range values), w: (OC, IC, KH, KW) int32,
    b: (OC,) int32.  Returns (OC, OH, OW) int32.
    """
    ic, ih, iw = x.shape
    oc, wic, kh, kw = w.shape
    assert wic == ic, f"channel mismatch: x has {ic}, w has {wic}"
    oh = (ih + 2 * pad - kh) // stride + 1
    ow = (iw + 2 * pad - kw) // stride + 1
    assert oh >= 1 and ow >= 1, "empty output"
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    ihp, iwp = ih + 2 * pad, iw + 2 * pad

    kernel = functools.partial(
        _conv2d_kernel, stride=stride, shift=shift, relu=relu,
        kh=kh, kw=kw, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(oc,),
        in_specs=[
            pl.BlockSpec((ic, ihp, iwp), lambda o: (0, 0, 0)),
            pl.BlockSpec((1, ic, kh, kw), lambda o: (o, 0, 0, 0)),
            pl.BlockSpec((1,), lambda o: (o,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow), lambda o: (o, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oc, oh, ow), jnp.int32),
        interpret=True,
    )(xp, w, b)


def _conv2d_kernel_f32(x_ref, w_ref, b_ref, o_ref, *, stride, kh, kw, oh, ow):
    """Float variant of the conv kernel (dtype-sweep testing)."""
    x = x_ref[...]
    w = w_ref[...][0]
    ic = x.shape[0]
    acc = jnp.full((oh, ow), b_ref[0], dtype=jnp.float32)
    for ky in range(kh):
        for kx in range(kw):
            xs = jax.lax.slice(
                x,
                (0, ky, kx),
                (ic, ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )
            acc = acc + jnp.einsum("i,ihw->hw", w[:, ky, kx], xs)
    o_ref[0] = acc


def conv2d_f32(x, w, b, *, stride: int, pad: int):
    """Float conv2d via Pallas (no requant)."""
    ic, ih, iw = x.shape
    oc, _, kh, kw = w.shape
    oh = (ih + 2 * pad - kh) // stride + 1
    ow = (iw + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    ihp, iwp = ih + 2 * pad, iw + 2 * pad
    kernel = functools.partial(
        _conv2d_kernel_f32, stride=stride, kh=kh, kw=kw, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(oc,),
        in_specs=[
            pl.BlockSpec((ic, ihp, iwp), lambda o: (0, 0, 0)),
            pl.BlockSpec((1, ic, kh, kw), lambda o: (o, 0, 0, 0)),
            pl.BlockSpec((1,), lambda o: (o,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow), lambda o: (o, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oc, oh, ow), jnp.float32),
        interpret=True,
    )(xp, w, b)
