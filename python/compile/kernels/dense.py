"""L1 Pallas kernel: quantized fully-connected layer.

Classifier heads (and VGG's big FC layers) reduce to a single int8
matrix-vector product.  On the RISC-V side this is the purest mac/zol
workload; on TPU the (O, I) × (I,) contraction is a single MXU pass, so the
kernel keeps the whole weight block in VMEM and emits one dot.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import requant


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, shift, relu):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.matmul(w, x, preferred_element_type=jnp.int32) + b_ref[...]
    o_ref[...] = requant(acc, shift, relu)


def dense(x, w, b, *, shift: int, relu: bool):
    """Quantized dense via Pallas. x: (I,), w: (O, I), b: (O,) -> (O,)."""
    o, i = w.shape
    assert x.shape == (i,), f"shape mismatch: x {x.shape} vs w {w.shape}"
    kernel = functools.partial(_dense_kernel, shift=shift, relu=relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((o,), jnp.int32),
        interpret=True,
    )(x, w, b)


def _dense_kernel_f32(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jnp.matmul(w_ref[...], x_ref[...]) + b_ref[...]


def dense_f32(x, w, b):
    """Float dense via Pallas (dtype-sweep testing)."""
    o, i = w.shape
    return pl.pallas_call(
        _dense_kernel_f32,
        out_shape=jax.ShapeDtypeStruct((o,), jnp.float32),
        interpret=True,
    )(x, w, b)
