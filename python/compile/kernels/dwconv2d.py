"""L1 Pallas kernel: quantized depthwise 2-D convolution.

The MobileNet model class spends most of its non-pointwise time here; the
RISC-V profile of this operator is the same mac/add2i/fusedmac pattern mix
with a shallower reduction (no input-channel loop), which is why the paper's
extensions transfer across the CNN class.  Grid tiles the channel axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import requant


def _dwconv2d_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, shift, relu,
                     kh, kw, oh, ow):
    """One grid step: one channel. x_ref: (1, IHp, IWp), w_ref: (1, KH, KW)."""
    x = x_ref[...][0]
    w = w_ref[...][0]
    acc = jnp.full((oh, ow), b_ref[0], dtype=jnp.int32)
    for ky in range(kh):
        for kx in range(kw):
            xs = jax.lax.slice(
                x,
                (ky, kx),
                (ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (stride, stride),
            )  # (OH, OW)
            acc = acc + w[ky, kx] * xs
    o_ref[0] = requant(acc, shift, relu)


def dwconv2d(x, w, b, *, stride: int, pad: int, shift: int, relu: bool):
    """Quantized depthwise conv via Pallas.

    x: (C, IH, IW) int32, w: (C, KH, KW) int32, b: (C,) int32.
    Returns (C, OH, OW) int32.
    """
    c, ih, iw = x.shape
    wc, kh, kw = w.shape
    assert wc == c, f"channel mismatch: x has {c}, w has {wc}"
    oh = (ih + 2 * pad - kh) // stride + 1
    ow = (iw + 2 * pad - kw) // stride + 1
    assert oh >= 1 and ow >= 1, "empty output"
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    ihp, iwp = ih + 2 * pad, iw + 2 * pad

    kernel = functools.partial(
        _dwconv2d_kernel, stride=stride, shift=shift, relu=relu,
        kh=kh, kw=kw, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, ihp, iwp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kh, kw), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=True,
    )(xp, w, b)
