"""L1 Pallas kernels: elementwise residual add and standalone requantize."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import INT8_MAX, INT8_MIN, requant


def _add_kernel(a_ref, b_ref, o_ref, *, relu):
    out = jnp.clip(a_ref[...] + b_ref[...], INT8_MIN, INT8_MAX)
    if relu:
        out = jnp.maximum(out, 0)
    o_ref[...] = out


def add(a, b, *, relu: bool):
    """Saturating int8 residual add via Pallas. a, b: same shape."""
    assert a.shape == b.shape, f"shape mismatch {a.shape} vs {b.shape}"
    kernel = functools.partial(_add_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int32),
        interpret=True,
    )(a, b)


def _requant_kernel(x_ref, o_ref, *, shift, relu):
    o_ref[...] = requant(x_ref[...], shift, relu)


def requantize(x, *, shift: int, relu: bool):
    """Standalone shift-requantize via Pallas (int32 acc -> int8 range)."""
    kernel = functools.partial(_requant_kernel, shift=shift, relu=relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=True,
    )(x)
