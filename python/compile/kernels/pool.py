"""L1 Pallas kernels: max pooling and global average pooling.

Pooling is memory-bound on every target; the Pallas versions tile the channel
axis so each program reduces one (H, W) plane resident in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import requant


def _maxpool_kernel(x_ref, o_ref, *, k, stride, oh, ow):
    x = x_ref[...][0]
    acc = jnp.full((oh, ow), -(2**31), dtype=jnp.int32)
    for ky in range(k):
        for kx in range(k):
            xs = jax.lax.slice(
                x,
                (ky, kx),
                (ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (stride, stride),
            )
            acc = jnp.maximum(acc, xs)
    o_ref[0] = acc


def maxpool(x, *, k: int, stride: int):
    """Max pooling via Pallas. x: (C, H, W) -> (C, OH, OW). VALID padding."""
    c, ih, iw = x.shape
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1
    assert oh >= 1 and ow >= 1, "empty output"
    kernel = functools.partial(_maxpool_kernel, k=k, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, ih, iw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=True,
    )(x)


def _avgpool2d_kernel(x_ref, o_ref, *, k, stride, shift, oh, ow):
    x = x_ref[...][0]
    acc = jnp.zeros((oh, ow), dtype=jnp.int32)
    for ky in range(k):
        for kx in range(k):
            xs = jax.lax.slice(
                x,
                (ky, kx),
                (ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (stride, stride),
            )
            acc = acc + xs
    o_ref[0] = requant(acc, shift, False)


def avgpool2d(x, *, k: int, stride: int):
    """Average pooling via Pallas (VALID). Divide by k*k as a round-shift.

    k must be a power of two so the division is exact power-of-two requant
    (DenseNet transitions use k=2).  x: (C, H, W) -> (C, OH, OW).
    """
    c, ih, iw = x.shape
    shift = (k * k - 1).bit_length()
    assert (1 << shift) == k * k, f"avgpool2d k={k}: k*k must be a power of two"
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1
    assert oh >= 1 and ow >= 1, "empty output"
    kernel = functools.partial(
        _avgpool2d_kernel, k=k, stride=stride, shift=shift, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, ih, iw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, oh, ow), jnp.int32),
        interpret=True,
    )(x)


def _avgpool_global_kernel(x_ref, o_ref, *, shift):
    acc = jnp.sum(x_ref[...][0].astype(jnp.int32))
    o_ref[0, 0, 0] = requant(acc, shift, False)


def avgpool_global(x, *, shift: int):
    """Global average pooling via Pallas.

    shift = log2(H*W); x: (C, H, W) -> (C, 1, 1).
    """
    c, ih, iw = x.shape
    assert (1 << shift) == ih * iw, \
        f"avgpool shift {shift} must equal log2({ih}*{iw})"
    kernel = functools.partial(_avgpool_global_kernel, shift=shift)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, ih, iw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, 1), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1, 1), jnp.int32),
        interpret=True,
    )(x)
