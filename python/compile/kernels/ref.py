"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground-truth definitions of MARVEL's quantized operators.  They
deliberately use an *independent* lowering path (``lax.conv_general_dilated``
/ ``jnp.matmul`` / reduce_window) from the Pallas kernels, so agreement
between the two is a meaningful correctness signal rather than shared-code
tautology.

All activation tensors are int32 arrays holding int8-range values (see
``compile.quant``).  Layouts: activations CHW, conv weights (OC, IC, KH, KW),
depthwise weights (C, KH, KW), dense weights (O, I).
"""

import jax.numpy as jnp
from jax import lax

from ..quant import requant, saturating_add


def conv2d_ref(x, w, b, *, stride: int, pad: int, shift: int, relu: bool):
    """Quantized 2-D convolution oracle.

    x: (IC, IH, IW) int32, w: (OC, IC, KH, KW) int32, b: (OC,) int32.
    Returns (OC, OH, OW) int32 in int8 range.
    """
    xb = x[None].astype(jnp.int32)  # NCHW with N=1
    acc = lax.conv_general_dilated(
        xb,
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.int32,
    )[0]
    acc = acc + b[:, None, None]
    return requant(acc, shift, relu)


def dwconv2d_ref(x, w, b, *, stride: int, pad: int, shift: int, relu: bool):
    """Quantized depthwise conv oracle.

    x: (C, IH, IW), w: (C, KH, KW), b: (C,).
    """
    c = x.shape[0]
    xb = x[None].astype(jnp.int32)
    # feature_group_count=C with OIHW weights of shape (C, 1, KH, KW)
    acc = lax.conv_general_dilated(
        xb,
        w[:, None].astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
        preferred_element_type=jnp.int32,
    )[0]
    acc = acc + b[:, None, None]
    return requant(acc, shift, relu)


def dense_ref(x, w, b, *, shift: int, relu: bool):
    """Quantized fully-connected oracle. x: (I,), w: (O, I), b: (O,)."""
    acc = jnp.matmul(w.astype(jnp.int32), x.astype(jnp.int32),
                     preferred_element_type=jnp.int32) + b
    return requant(acc, shift, relu)


def maxpool_ref(x, *, k: int, stride: int):
    """Max pooling oracle (no requant — int8 in, int8 out). x: (C, H, W)."""
    return lax.reduce_window(
        x,
        jnp.int32(-(2**31)),
        lax.max,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding="VALID",
    )


def avgpool2d_ref(x, *, k: int, stride: int):
    """Average pooling oracle (VALID): window sum then round-shift by
    log2(k*k).  x: (C, H, W)."""
    shift = (k * k - 1).bit_length()
    assert (1 << shift) == k * k
    acc = lax.reduce_window(
        x.astype(jnp.int32),
        jnp.int32(0),
        lax.add,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding="VALID",
    )
    return requant(acc, shift, False)


def avgpool_global_ref(x, *, shift: int):
    """Global average pooling oracle: sum over H×W then round-shift.

    ``shift`` must equal log2(H*W) (enforced by the exporter); the rounding
    matches ``quant.round_shift`` so the RV32 code is a plain add+srai.
    x: (C, H, W) -> (C, 1, 1).
    """
    acc = jnp.sum(x.astype(jnp.int32), axis=(1, 2), keepdims=True)
    return requant(acc, shift, False)


def add_ref(a, b, *, relu: bool):
    """Residual elementwise saturating add oracle."""
    out = saturating_add(a, b)
    if relu:
        out = jnp.maximum(out, 0)
    return out


def concat_ref(xs):
    """Channel concatenation oracle. xs: list of (Ci, H, W)."""
    return jnp.concatenate(xs, axis=0)


def conv2d_ref_f32(x, w, b, *, stride: int, pad: int):
    """Float conv reference (used by the float-dtype kernel sweeps)."""
    acc = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return acc + b[:, None, None]
