"""L1 structural performance analysis: VMEM footprint + MXU utilization
estimates for the Pallas kernels (DESIGN.md §8, EXPERIMENTS §Perf).

interpret=True gives CPU-numpy timings that say nothing about TPU behaviour,
so the Pallas optimization loop is *structural*: per conv layer, compute the
VMEM bytes each grid step holds (its BlockSpec blocks) and the utilization
of the MXU reduction axis (contraction length vs. the 128-lane systolic
dimension).  The analyzer walks a model spec, checks every layer against the
16 MB VMEM budget, and reports the achieved-utilization distribution.
"""

VMEM_BYTES = 16 * 1024 * 1024
MXU_LANES = 128


def conv_block_stats(in_shape, k, oc, dtype_bytes: int = 4) -> dict:
    """VMEM/MXU stats for one conv2d grid step (one output-channel plane).

    BlockSpecs (kernels/conv2d.py): x block (IC, IHp, IWp), w block
    (1, IC, KH, KW), bias (1,), out block (1, OH, OW).
    """
    ic, ihp, iwp = in_shape
    x_bytes = ic * ihp * iwp * dtype_bytes
    w_bytes = ic * k * k * dtype_bytes
    out_bytes = ihp * iwp * dtype_bytes  # upper bound (OH*OW <= IHp*IWp)
    vmem = x_bytes + w_bytes + out_bytes
    # the (ic, ky, kx) reduction feeds the MXU contraction axis
    red = ic * k * k
    # utilization of the 128-lane dimension after padding to a multiple
    lanes = -(-red // MXU_LANES) * MXU_LANES
    util = red / lanes
    return {
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_BYTES,
        "reduction": red,
        "mxu_util": util,
    }


def analyze_spec(spec: dict) -> dict:
    """Aggregate L1 stats across a model's conv/dw layers."""
    per_layer = []
    for li, layer in enumerate(spec["layers"]):
        if layer["op"] == "conv2d":
            ic, ih, iw = layer["in_shape"]
            pad = layer["pad"]
            k = _k_of(layer)
            st = conv_block_stats(
                (ic, ih + 2 * pad, iw + 2 * pad), k, layer["out_shape"][0])
            st["layer"] = li
            per_layer.append(st)
    if not per_layer:
        return {"layers": [], "peak_vmem": 0, "mean_mxu_util": 1.0,
                "all_fit_vmem": True}
    return {
        "layers": per_layer,
        "peak_vmem": max(s["vmem_bytes"] for s in per_layer),
        "mean_mxu_util": (sum(s["mxu_util"] for s in per_layer)
                          / len(per_layer)),
        "all_fit_vmem": all(s["vmem_ok"] for s in per_layer),
    }


def _k_of(layer) -> int:
    """Kernel size from recorded shapes: (IH + 2p - K)/s + 1 = OH."""
    ih = layer["in_shape"][1]
    oh = layer["out_shape"][1]
    return ih + 2 * layer["pad"] - layer["stride"] * (oh - 1)


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    import os
    results = {}
    mdir = os.path.join(args.artifacts, "models")
    for f in sorted(os.listdir(mdir)):
        if not f.endswith(".json"):
            continue
        spec = json.load(open(os.path.join(mdir, f)))
        r = analyze_spec(spec)
        results[spec["name"]] = {
            "peak_vmem_kb": round(r["peak_vmem"] / 1024, 1),
            "mean_mxu_util": round(r["mean_mxu_util"], 3),
            "all_fit_vmem": r["all_fit_vmem"],
            "conv_layers": len(r["layers"]),
        }
        print(f"{spec['name']:14s} peak VMEM "
              f"{results[spec['name']]['peak_vmem_kb']:>9.1f} kB  "
              f"mean MXU util {results[spec['name']]['mean_mxu_util']:.3f}  "
              f"fits: {r['all_fit_vmem']}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(results, fh, indent=1)


if __name__ == "__main__":
    main()
