"""L2: the JAX model — a quantized-CNN interpreter over model specs.

``run_spec`` executes a spec layer-by-layer, chaining the L1 Pallas kernels
(``backend="pallas"``, the path that is AOT-lowered into the HLO artifact) or
the independent jnp oracles (``backend="ref"``, used for calibration and as
the cross-check).  Build-time only; the rust coordinator executes the lowered
HLO via PJRT.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref


def _as_i32(x):
    return jnp.asarray(x, dtype=jnp.int32)


def run_spec(spec: dict, weights: dict, x, backend: str = "pallas"):
    """Run one inference. x: int32 (C,H,W) in int8 range -> logits (classes,).

    All conv/dw/dense layers must have calibrated (non-None) shifts.
    """
    k = kernels if backend == "pallas" else None
    outs: list = []
    x = _as_i32(x)

    def inp(layer):
        srcs = [x if i == -1 else outs[i] for i in layer["inputs"]]
        return srcs

    for li, layer in enumerate(spec["layers"]):
        op = layer["op"]
        srcs = inp(layer)
        if op in ("conv2d", "dwconv2d", "dense") and layer["shift"] is None:
            raise ValueError(
                f"layer {li} ({op}) has uncalibrated shift; run "
                "quantize.calibrate() first")
        if op == "conv2d":
            f = kernels.conv2d if backend == "pallas" else ref.conv2d_ref
            out = f(srcs[0], _as_i32(weights[layer["w"]]),
                    _as_i32(weights[layer["b"]]),
                    stride=layer["stride"], pad=layer["pad"],
                    shift=layer["shift"], relu=layer["relu"])
        elif op == "dwconv2d":
            f = kernels.dwconv2d if backend == "pallas" else ref.dwconv2d_ref
            out = f(srcs[0], _as_i32(weights[layer["w"]]),
                    _as_i32(weights[layer["b"]]),
                    stride=layer["stride"], pad=layer["pad"],
                    shift=layer["shift"], relu=layer["relu"])
        elif op == "dense":
            f = kernels.dense if backend == "pallas" else ref.dense_ref
            out = f(srcs[0].reshape(-1), _as_i32(weights[layer["w"]]),
                    _as_i32(weights[layer["b"]]),
                    shift=layer["shift"], relu=layer["relu"])
        elif op == "maxpool":
            f = kernels.maxpool if backend == "pallas" else ref.maxpool_ref
            out = f(srcs[0], k=layer["k"], stride=layer["stride"])
        elif op == "avgpool2d":
            f = kernels.avgpool2d if backend == "pallas" else ref.avgpool2d_ref
            out = f(srcs[0], k=layer["k"], stride=layer["stride"])
        elif op == "avgpool_global":
            f = (kernels.avgpool_global if backend == "pallas"
                 else ref.avgpool_global_ref)
            out = f(srcs[0], shift=layer["shift"])
        elif op == "add":
            f = kernels.add if backend == "pallas" else ref.add_ref
            out = f(srcs[0], srcs[1], relu=layer["relu"])
        elif op == "concat":
            # Pure data movement; jnp.concatenate on both backends.
            out = ref.concat_ref(srcs)
        else:
            raise ValueError(f"unknown op {op!r}")
        outs.append(out)
    return outs[-1]


def build_model_fn(spec: dict, weights: dict, backend: str = "pallas"):
    """Return a jit-able ``fn(x) -> (logits,)`` with weights closed over.

    The 1-tuple return matches the ``return_tuple=True`` AOT lowering
    convention (rust side unwraps with ``to_tuple1``).
    """
    w = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in weights.items()}

    def fn(x):
        return (run_spec(spec, w, x, backend=backend),)

    return fn


def run_batch_np(spec: dict, weights: dict, xs: np.ndarray,
                 backend: str = "ref") -> np.ndarray:
    """Run a batch of inputs (N, C, H, W) -> (N, classes) as numpy."""
    fn = jax.jit(build_model_fn(spec, weights, backend=backend))
    out = [np.asarray(fn(jnp.asarray(x, jnp.int32))[0]) for x in xs]
    return np.stack(out)
