"""The single definition of MARVEL's quantized arithmetic (Python side).

Quantized tensors are carried as ``int32`` arrays holding int8-range values
([-128, 127]).  This is bit-exact to int8 semantics (all accumulations fit in
int32 by a wide margin) while avoiding PJRT/Literal dtype friction on the
rust side of the AOT bridge.

The requantization scheme is symmetric power-of-two: an int32 accumulator is
rounded (half-up, i.e. ``+ 2^(s-1)`` before an *arithmetic* right shift by
``s``) and clamped to the int8 range.  On RV32 this is exactly

    add  acc, acc, rnd      # rnd = 1 << (s-1), hoisted out of the loop
    srai acc, acc, s
    <clamp via blt/bge>

so the golden model and the generated RISC-V code agree bit-for-bit.  The
mirror implementation lives in ``rust/src/quant/mod.rs``; pytest checks this
file's semantics, and the rust property tests check that module against the
ISS — the AOT integration test ties the two together.
"""

import jax.numpy as jnp
import numpy as np

INT8_MIN = -128
INT8_MAX = 127


def round_shift(acc, shift: int):
    """Round-half-up arithmetic right shift of an int32 accumulator."""
    if shift == 0:
        return acc
    if shift < 0:
        raise ValueError(f"negative requant shift: {shift}")
    return (acc + (1 << (shift - 1))) >> shift


def requant(acc, shift: int, relu: bool):
    """Requantize an int32 accumulator to int8 range (kept in int32).

    Clamp order matches the generated RV32 code: shift, then clamp to
    [0 if relu else -128, 127].
    """
    acc = round_shift(acc, shift)
    lo = 0 if relu else INT8_MIN
    return jnp.clip(acc, lo, INT8_MAX)


def requant_np(acc: np.ndarray, shift: int, relu: bool) -> np.ndarray:
    """NumPy twin of :func:`requant` (used by dataset/golden generation)."""
    acc = acc.astype(np.int64)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    lo = 0 if relu else INT8_MIN
    return np.clip(acc, lo, INT8_MAX).astype(np.int32)


def saturating_add(a, b):
    """Elementwise int8 saturating add (residual connections)."""
    return jnp.clip(a + b, INT8_MIN, INT8_MAX)


def quantize_weights_np(w: np.ndarray) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor weight quantization float -> int8 (as int32).

    Returns (q, scale) with ``w ≈ q * scale`` and q in [-127, 127].
    """
    amax = float(np.max(np.abs(w))) if w.size else 0.0
    if amax == 0.0:
        return np.zeros_like(w, dtype=np.int32), 1.0
    scale = amax / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int32)
    return q, scale
