"""Post-training quantization: per-layer requant shift calibration.

The paper applies TFLite int8 post-training quantization (§II.A.3).  Our
scheme (DESIGN.md §2) is symmetric power-of-two: each conv/dw/dense layer
requantizes its int32 accumulator with an arithmetic right shift.  This
module picks the smallest shift per layer such that the calibration batch
never saturates the int8 range (beyond the final clamp), processing layers
in topological order so each layer calibrates against real upstream
activations.
"""

import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .quant import round_shift


def _min_shift_for(amax: int) -> int:
    """Smallest s with round_shift(amax, s) <= 127."""
    s = 0
    while round_shift(int(amax), s) > 127:
        s += 1
    return s


def calibrate(spec: dict, weights: dict, xs: np.ndarray) -> dict:
    """Fill in every None shift in ``spec`` (mutates and returns it).

    xs: calibration batch (N, C, H, W) int in int8 range.
    """
    w32 = {k: jnp.asarray(v, jnp.int32) for k, v in weights.items()}
    # Per-sample activation lists; calibrate layer-by-layer across the batch.
    acts = [[jnp.asarray(x, jnp.int32) for x in xs]]  # acts[0] = inputs

    def srcs(layer, si):
        return [acts[0][si] if i == -1 else acts[i + 1][si]
                for i in layer["inputs"]]

    for li, layer in enumerate(spec["layers"]):
        op = layer["op"]
        outs = []
        if op in ("conv2d", "dwconv2d", "dense"):
            # Raw (un-requantized) accumulators across the batch -> amax ->
            # smallest non-saturating shift; then requant with it to produce
            # this layer's calibrated activations for downstream layers.
            amax = 0
            raw_outs = []
            for si in range(len(xs)):
                s0 = srcs(layer, si)
                raw = _raw_acc(layer, op, s0, w32)
                amax = max(amax, int(jnp.max(jnp.abs(raw))))
                raw_outs.append(raw)
            shift = _min_shift_for(amax)
            layer["shift"] = shift
            lo = 0 if layer["relu"] else -128
            for raw in raw_outs:
                out = jnp.clip(round_shift(raw, shift) if shift else raw,
                               lo, 127)
                outs.append(out)
        elif op == "maxpool":
            for si in range(len(xs)):
                outs.append(ref.maxpool_ref(srcs(layer, si)[0],
                                            k=layer["k"],
                                            stride=layer["stride"]))
        elif op == "avgpool2d":
            for si in range(len(xs)):
                outs.append(ref.avgpool2d_ref(srcs(layer, si)[0],
                                              k=layer["k"],
                                              stride=layer["stride"]))
        elif op == "avgpool_global":
            for si in range(len(xs)):
                outs.append(ref.avgpool_global_ref(srcs(layer, si)[0],
                                                   shift=layer["shift"]))
        elif op == "add":
            for si in range(len(xs)):
                a, b = srcs(layer, si)
                outs.append(ref.add_ref(a, b, relu=layer["relu"]))
        elif op == "concat":
            for si in range(len(xs)):
                outs.append(ref.concat_ref(srcs(layer, si)))
        else:
            raise ValueError(f"unknown op {op!r}")
        acts.append(outs)
    return spec


def _raw_acc(layer, op, s0, w32):
    """Un-requantized int32 accumulator for a compute layer."""
    from jax import lax
    if op == "conv2d":
        x, w, b = s0[0], w32[layer["w"]], w32[layer["b"]]
        acc = lax.conv_general_dilated(
            x[None].astype(jnp.int32), w.astype(jnp.int32),
            window_strides=(layer["stride"], layer["stride"]),
            padding=[(layer["pad"], layer["pad"])] * 2,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            preferred_element_type=jnp.int32)[0]
        return acc + b[:, None, None]
    if op == "dwconv2d":
        x, w, b = s0[0], w32[layer["w"]], w32[layer["b"]]
        c = x.shape[0]
        acc = lax.conv_general_dilated(
            x[None].astype(jnp.int32), w[:, None].astype(jnp.int32),
            window_strides=(layer["stride"], layer["stride"]),
            padding=[(layer["pad"], layer["pad"])] * 2,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=c,
            preferred_element_type=jnp.int32)[0]
        return acc + b[:, None, None]
    # dense
    x, w, b = s0[0].reshape(-1), w32[layer["w"]], w32[layer["b"]]
    return jnp.matmul(w.astype(jnp.int32), x.astype(jnp.int32),
                      preferred_element_type=jnp.int32) + b
