"""Model zoo: spec builders for the six CNNs the paper evaluates.

A *spec* is the hardware-agnostic model description that flows through the
whole system — the analogue of the paper's TVM Relay module.  It is a plain
dict (JSON-serializable, see export.py) plus a dict of int32 numpy weight
tensors.  The rust compiler (`rust/src/compiler/spec.rs`) consumes the same
JSON.

Layer dicts
-----------
Every layer has ``op``, ``inputs`` (list of producer layer indices; ``-1``
is the model input) and ``out_shape``.  Per-op fields:

=============  =================================================once=========
conv2d         w, b, stride, pad, shift, relu, in_shape [IC,IH,IW]
dwconv2d       w, b, stride, pad, shift, relu, in_shape [C,IH,IW]
dense          w, b, shift, relu, in_len (input flattened CHW row-major)
maxpool        k, stride, in_shape
avgpool2d      k, stride, shift (= log2 k²), in_shape
avgpool_global shift (= log2 H·W), in_shape
add            relu  (two inputs, same shape, saturating int8 add)
concat         (N inputs, channel axis)
=============  ==============================================================

``shift`` values for conv/dw/dense start as ``None`` placeholders and are
filled by calibration (quantize.py).

Scaling (DESIGN.md §6): the paper runs 64×64×3 inputs on full-width models;
the quick profile shrinks widths/inputs so the ISS benches run in seconds
while preserving layer types and loop structure.
"""

import numpy as np


INT8 = "i8"
INT32 = "i32"


def _scale_ch(c: int, alpha: float, div: int = 4) -> int:
    """Scale a channel count by alpha, keeping it a positive multiple of div."""
    return max(div, int(c * alpha) // div * div)


class SpecBuilder:
    """Accumulates layers + weight tensors for one model."""

    def __init__(self, name: str, input_shape, seed: int):
        self.name = name
        self.input_shape = list(input_shape)
        self.layers = []
        self.weights = {}  # name -> np int32 array (int8/int32-range values)
        self.rng = np.random.default_rng(seed)
        self._tid = 0

    # -- shape tracking ----------------------------------------------------
    def shape_of(self, idx: int):
        if idx == -1:
            return list(self.input_shape)
        return list(self.layers[idx]["out_shape"])

    def _tensor(self, arr: np.ndarray, dtype: str) -> str:
        name = f"t{self._tid}"
        self._tid += 1
        self.weights[name] = arr.astype(np.int32)
        self.weights[name + "/dtype"] = dtype  # sidecar, stripped at export
        return name

    def _rand_w(self, shape) -> np.ndarray:
        """Random int8 weights with conv-ish distribution."""
        w = self.rng.normal(0.0, 40.0, size=shape)
        return np.clip(np.round(w), -127, 127).astype(np.int32)

    def _rand_b(self, n: int) -> np.ndarray:
        return self.rng.integers(-64, 64, size=(n,)).astype(np.int32)

    # -- layer emitters ----------------------------------------------------
    def conv2d(self, inp: int, oc: int, k: int, stride: int = 1, pad: int = 0,
               relu: bool = True, w: np.ndarray | None = None,
               b: np.ndarray | None = None) -> int:
        ic, ih, iw = self.shape_of(inp)
        oh = (ih + 2 * pad - k) // stride + 1
        ow = (iw + 2 * pad - k) // stride + 1
        assert oh >= 1 and ow >= 1, \
            f"{self.name}: conv2d output empty ({ih}x{iw} k{k} s{stride} p{pad})"
        w = self._rand_w((oc, ic, k, k)) if w is None else w.astype(np.int32)
        b = self._rand_b(oc) if b is None else b.astype(np.int32)
        self.layers.append({
            "op": "conv2d", "inputs": [inp],
            "w": self._tensor(w, INT8), "b": self._tensor(b, INT32),
            "stride": stride, "pad": pad, "shift": None, "relu": relu,
            "in_shape": [ic, ih, iw], "out_shape": [oc, oh, ow],
        })
        return len(self.layers) - 1

    def dwconv2d(self, inp: int, k: int, stride: int = 1, pad: int = 1,
                 relu: bool = True) -> int:
        c, ih, iw = self.shape_of(inp)
        oh = (ih + 2 * pad - k) // stride + 1
        ow = (iw + 2 * pad - k) // stride + 1
        assert oh >= 1 and ow >= 1, f"{self.name}: dwconv output empty"
        self.layers.append({
            "op": "dwconv2d", "inputs": [inp],
            "w": self._tensor(self._rand_w((c, k, k)), INT8),
            "b": self._tensor(self._rand_b(c), INT32),
            "stride": stride, "pad": pad, "shift": None, "relu": relu,
            "in_shape": [c, ih, iw], "out_shape": [c, oh, ow],
        })
        return len(self.layers) - 1

    def dense(self, inp: int, out: int, relu: bool = False,
              w: np.ndarray | None = None, b: np.ndarray | None = None) -> int:
        in_len = int(np.prod(self.shape_of(inp)))
        w = self._rand_w((out, in_len)) if w is None else w.astype(np.int32)
        b = self._rand_b(out) if b is None else b.astype(np.int32)
        self.layers.append({
            "op": "dense", "inputs": [inp],
            "w": self._tensor(w, INT8), "b": self._tensor(b, INT32),
            "shift": None, "relu": relu,
            "in_len": in_len, "out_shape": [out],
        })
        return len(self.layers) - 1

    def maxpool(self, inp: int, k: int, stride: int) -> int:
        c, ih, iw = self.shape_of(inp)
        oh = (ih - k) // stride + 1
        ow = (iw - k) // stride + 1
        assert oh >= 1 and ow >= 1, f"{self.name}: maxpool output empty"
        self.layers.append({
            "op": "maxpool", "inputs": [inp], "k": k, "stride": stride,
            "in_shape": [c, ih, iw], "out_shape": [c, oh, ow],
        })
        return len(self.layers) - 1

    def avgpool2d(self, inp: int, k: int, stride: int) -> int:
        c, ih, iw = self.shape_of(inp)
        shift = (k * k - 1).bit_length()
        assert (1 << shift) == k * k, "avgpool2d window must be power of two"
        oh = (ih - k) // stride + 1
        ow = (iw - k) // stride + 1
        assert oh >= 1 and ow >= 1, f"{self.name}: avgpool output empty"
        self.layers.append({
            "op": "avgpool2d", "inputs": [inp], "k": k, "stride": stride,
            "shift": shift,
            "in_shape": [c, ih, iw], "out_shape": [c, oh, ow],
        })
        return len(self.layers) - 1

    def avgpool_global(self, inp: int) -> int:
        c, ih, iw = self.shape_of(inp)
        shift = (ih * iw - 1).bit_length()
        assert (1 << shift) == ih * iw, \
            f"{self.name}: global avgpool window {ih}x{iw} not a power of two"
        self.layers.append({
            "op": "avgpool_global", "inputs": [inp], "shift": shift,
            "in_shape": [c, ih, iw], "out_shape": [c, 1, 1],
        })
        return len(self.layers) - 1

    def add(self, a: int, b: int, relu: bool = False) -> int:
        sa, sb = self.shape_of(a), self.shape_of(b)
        assert sa == sb, f"{self.name}: add shape mismatch {sa} vs {sb}"
        self.layers.append({
            "op": "add", "inputs": [a, b], "relu": relu, "out_shape": sa,
        })
        return len(self.layers) - 1

    def concat(self, inps: list[int]) -> int:
        shapes = [self.shape_of(i) for i in inps]
        h, w = shapes[0][1], shapes[0][2]
        assert all(s[1:] == [h, w] for s in shapes), \
            f"{self.name}: concat spatial mismatch {shapes}"
        c = sum(s[0] for s in shapes)
        self.layers.append({
            "op": "concat", "inputs": list(inps), "out_shape": [c, h, w],
        })
        return len(self.layers) - 1

    def finish(self, num_classes: int, profile: str, seed: int) -> dict:
        spec = {
            "name": self.name,
            "profile": profile,
            "seed": seed,
            "input_shape": self.input_shape,
            "num_classes": num_classes,
            "layers": self.layers,
        }
        weights = {k: v for k, v in self.weights.items()
                   if not k.endswith("/dtype")}
        dtypes = {k[:-len("/dtype")]: v for k, v in self.weights.items()
                  if k.endswith("/dtype")}
        spec["tensor_dtypes"] = dtypes
        return spec, weights


# ---------------------------------------------------------------------------
# The six models (paper §II.A.1 / Table 9)
# ---------------------------------------------------------------------------

def lenet5(profile: str = "quick", seed: int = 7,
           trained: dict | None = None):
    """LeNet-5* exactly per Table 9 (both profiles are identical; the paper's
    LeNet-5* is already tiny).  ``trained`` may carry *already-quantized*
    int32 tensors from train.quantize_trained():
    {"conv1_w","conv1_b","conv2_w","conv2_b","fc_w","fc_b"}.
    """
    b = SpecBuilder("lenet5", [1, 28, 28], seed)
    t = trained or {}
    c1 = b.conv2d(-1, 12, k=6, stride=2, pad=0, relu=True,
                  w=t.get("conv1_w"), b=t.get("conv1_b"))
    c2 = b.conv2d(c1, 32, k=6, stride=2, pad=0, relu=True,
                  w=t.get("conv2_w"), b=t.get("conv2_b"))
    b.dense(c2, 10, relu=False, w=t.get("fc_w"), b=t.get("fc_b"))
    return b.finish(10, profile, seed)


def mobilenet_v1(profile: str = "quick", seed: int = 11):
    alpha, hw = (0.25, 32) if profile == "quick" else (1.0, 64)
    b = SpecBuilder("mobilenet_v1", [3, hw, hw], seed)
    c = _scale_ch(32, alpha)
    x = b.conv2d(-1, c, k=3, stride=2, pad=1, relu=True)
    blocks = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
              (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
              (1024, 1)]
    for oc, s in blocks:
        x = b.dwconv2d(x, k=3, stride=s, pad=1, relu=True)
        x = b.conv2d(x, _scale_ch(oc, alpha), k=1, stride=1, pad=0, relu=True)
    x = b.avgpool_global(x)
    b.dense(x, 2, relu=False)
    return b.finish(2, profile, seed)


def mobilenet_v2(profile: str = "quick", seed: int = 13):
    alpha, hw = (0.25, 32) if profile == "quick" else (1.0, 64)
    b = SpecBuilder("mobilenet_v2", [3, hw, hw], seed)
    x = b.conv2d(-1, _scale_ch(32, alpha), k=3, stride=2, pad=1, relu=True)
    # (expansion t, out channels, repeats, first stride)
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, oc, n, s0 in cfg:
        oc = _scale_ch(oc, alpha)
        for i in range(n):
            s = s0 if i == 0 else 1
            cin = b.shape_of(x)[0]
            inner = x
            if t != 1:
                inner = b.conv2d(inner, cin * t, k=1, stride=1, pad=0,
                                 relu=True)
            inner = b.dwconv2d(inner, k=3, stride=s, pad=1, relu=True)
            inner = b.conv2d(inner, oc, k=1, stride=1, pad=0, relu=False)
            if s == 1 and cin == oc:
                x = b.add(x, inner, relu=False)
            else:
                x = inner
    x = b.conv2d(x, _scale_ch(1280, alpha, div=8), k=1, stride=1, pad=0,
                 relu=True)
    x = b.avgpool_global(x)
    b.dense(x, 2, relu=False)
    return b.finish(2, profile, seed)


def resnet50(profile: str = "quick", seed: int = 17):
    width, hw = (0.25, 32) if profile == "quick" else (1.0, 64)
    b = SpecBuilder("resnet50", [3, hw, hw], seed)
    c64 = _scale_ch(64, width)
    x = b.conv2d(-1, c64, k=7, stride=2, pad=3, relu=True)
    x = b.maxpool(x, k=3, stride=2)
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    for cbase, n, s0 in stages:
        c = _scale_ch(cbase, width)
        for i in range(n):
            s = s0 if i == 0 else 1
            cin = b.shape_of(x)[0]
            cout = c * 4
            # bottleneck 1x1 -> 3x3 -> 1x1
            y = b.conv2d(x, c, k=1, stride=1, pad=0, relu=True)
            y = b.conv2d(y, c, k=3, stride=s, pad=1, relu=True)
            y = b.conv2d(y, cout, k=1, stride=1, pad=0, relu=False)
            if s != 1 or cin != cout:
                sc = b.conv2d(x, cout, k=1, stride=s, pad=0, relu=False)
            else:
                sc = x
            x = b.add(y, sc, relu=True)
    x = b.avgpool_global(x)
    b.dense(x, 2, relu=False)
    return b.finish(2, profile, seed)


def vgg16(profile: str = "quick", seed: int = 19):
    width, hw = (0.125, 32) if profile == "quick" else (1.0, 64)
    b = SpecBuilder("vgg16", [3, hw, hw], seed)
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    x = -1
    for v in cfg:
        if v == "M":
            x = b.maxpool(x, k=2, stride=2)
        else:
            x = b.conv2d(x, _scale_ch(v, width), k=3, stride=1, pad=1,
                         relu=True)
    # Scaled classifier head (paper uses 4096-wide FCs on the full model).
    fc1 = _scale_ch(4096, width, div=8) if profile == "full" else 64
    x = b.dense(x, fc1, relu=True)
    x = b.dense(x, fc1, relu=True)
    b.dense(x, 2, relu=False)
    return b.finish(2, profile, seed)


def densenet121(profile: str = "quick", seed: int = 23):
    growth, hw = (8, 64) if profile == "quick" else (32, 64)
    b = SpecBuilder("densenet121", [3, hw, hw], seed)
    c0 = 2 * growth
    x = b.conv2d(-1, c0, k=7, stride=2, pad=3, relu=True)
    x = b.maxpool(x, k=3, stride=2)
    blocks = [6, 12, 24, 16]
    for bi, n in enumerate(blocks):
        for _ in range(n):
            # bottleneck: 1x1 (4*growth) -> 3x3 (growth), concat
            y = b.conv2d(x, 4 * growth, k=1, stride=1, pad=0, relu=True)
            y = b.conv2d(y, growth, k=3, stride=1, pad=1, relu=True)
            x = b.concat([x, y])
        if bi != len(blocks) - 1:
            # transition: 1x1 halve channels, 2x2 avg pool
            c = b.shape_of(x)[0] // 2
            x = b.conv2d(x, c, k=1, stride=1, pad=0, relu=True)
            x = b.avgpool2d(x, k=2, stride=2)
    x = b.avgpool_global(x)
    b.dense(x, 2, relu=False)
    return b.finish(2, profile, seed)


ZOO = {
    "lenet5": lenet5,
    "mobilenet_v1": mobilenet_v1,
    "mobilenet_v2": mobilenet_v2,
    "resnet50": resnet50,
    "vgg16": vgg16,
    "densenet121": densenet121,
}

MODEL_NAMES = list(ZOO.keys())


def build(name: str, profile: str = "quick", **kw):
    """Build (spec, weights) for a zoo model."""
    return ZOO[name](profile=profile, **kw)
