"""Transfer-learning substitute: train LeNet-5* in float JAX, then quantize.

The paper's step II.A.2 fine-tunes pretrained Keras models; offline we train
the (tiny) LeNet-5* from scratch on the synthetic digit dataset — a real
gradient-descent run whose loss curve is logged to
``artifacts/train/lenet_train_log.json`` and summarized in EXPERIMENTS.md.
The trained float weights are then symmetrically quantized (weights to int8;
biases to the accumulator scale) and handed to specs.lenet5(trained=...).

Hand-rolled Adam — no optax dependency needed for a 19k-parameter model.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen
from .quant import quantize_weights_np


def _init_params(rng: np.random.Generator) -> dict:
    def he(shape, fan_in):
        return rng.normal(0, np.sqrt(2.0 / fan_in), size=shape)
    return {
        "conv1_w": he((12, 1, 6, 6), 36),
        "conv1_b": np.zeros(12),
        "conv2_w": he((32, 12, 6, 6), 12 * 36),
        "conv2_b": np.zeros(32),
        "fc_w": he((10, 512), 512),
        "fc_b": np.zeros(10),
    }


def _forward(p, x):
    """Float LeNet-5* forward. x: (N, 1, 28, 28) in [-0.5, 0.5]."""
    from jax import lax
    y = lax.conv_general_dilated(x, p["conv1_w"], (2, 2), "VALID",
                                 dimension_numbers=("NCHW", "OIHW", "NCHW"))
    y = jax.nn.relu(y + p["conv1_b"][None, :, None, None])
    y = lax.conv_general_dilated(y, p["conv2_w"], (2, 2), "VALID",
                                 dimension_numbers=("NCHW", "OIHW", "NCHW"))
    y = jax.nn.relu(y + p["conv2_b"][None, :, None, None])
    y = y.reshape(y.shape[0], -1)
    return y @ p["fc_w"].T + p["fc_b"]


def _loss(p, x, y):
    logits = _forward(p, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


def train_lenet(steps: int = 300, batch: int = 64, lr: float = 1e-3,
                seed: int = 42, log_every: int = 10):
    """Train and return (float_params, log_dict)."""
    rng = np.random.default_rng(seed)
    params = {k: jnp.asarray(v, jnp.float32)
              for k, v in _init_params(rng).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v) for k, v in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(jax.value_and_grad(_loss))
    xs_all, ys_all = datagen.digits(8192, seed=seed)
    xf = xs_all.astype(np.float32) / 255.0  # ~[-0.5, 0.5]
    curve = []
    for step in range(1, steps + 1):
        idx = rng.integers(0, len(xf), size=batch)
        loss, g = grad_fn(params, jnp.asarray(xf[idx]), jnp.asarray(ys_all[idx]))
        for k in params:
            m[k] = b1 * m[k] + (1 - b1) * g[k]
            v[k] = b2 * v[k] + (1 - b2) * g[k] ** 2
            mh = m[k] / (1 - b1 ** step)
            vh = v[k] / (1 - b2 ** step)
            params[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
        if step % log_every == 0 or step == 1:
            curve.append({"step": step, "loss": float(loss)})

    # held-out accuracy
    xs_te, ys_te = datagen.digits(512, seed=seed + 1)
    logits = _forward(params, jnp.asarray(xs_te.astype(np.float32) / 255.0))
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ys_te)))
    log = {"steps": steps, "batch": batch, "lr": lr, "seed": seed,
           "loss_curve": curve, "float_test_acc": acc}
    return {k: np.asarray(v) for k, v in params.items()}, log


def quantize_trained(params: dict) -> dict:
    """Float params -> int tensors for specs.lenet5(trained=...).

    Weights: symmetric int8.  Biases: quantized at the accumulator scale
    s_w · s_x of their layer so `acc = Σ q_w·q_x + q_b` stays proportional
    to the float pre-activation.  Activations enter as x/255 in float but as
    (x_int8) in the int model, i.e. s_x = 1/255 relative to the int domain.
    """
    out = {}
    sx = 1.0 / 255.0
    for conv, wk, bk in (("conv1", "conv1_w", "conv1_b"),
                         ("conv2", "conv2_w", "conv2_b"),
                         ("fc", "fc_w", "fc_b")):
        qw, sw = quantize_weights_np(params[wk])
        out[wk] = qw
        qb = np.round(params[bk] / (sw * sx)).astype(np.int64)
        out[bk] = np.clip(qb, -(2**30), 2**30).astype(np.int32)
        sx = sx  # activation scale is re-normalized by calibration shifts
    return out


def save_log(log: dict, path: str):
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
