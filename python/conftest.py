"""Make `import compile...` work when pytest is invoked from the repo root
(`pytest python/tests/`) as well as from `python/`."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
