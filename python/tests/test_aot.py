"""AOT lowering: the HLO text artifact must be well-formed and the lowered
computation must reproduce the ref model bit-exactly when re-executed."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, datagen, model, quantize, specs


def _small():
    spec, w = specs.build("lenet5")
    xs, _ = datagen.dataset_for(spec, 2, seed=21)
    quantize.calibrate(spec, w, xs)
    return spec, w, xs


def test_hlo_text_wellformed():
    spec, w, _ = _small()
    hlo = aot.lower_model(spec, w)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # rust loads with return_tuple=True: root must be a tuple
    assert "s32[10]" in hlo  # logits shape appears


def test_hlo_text_does_not_elide_constants():
    """Regression: as_hlo_text() defaults to eliding large constants as
    "{...}", which the rust-side HLO parser silently zero-fills — the baked
    weights must survive the text round-trip."""
    spec, w, _ = _small()
    hlo = aot.lower_model(spec, w)
    assert "{...}" not in hlo
    # a real weight value from conv1 must appear in some constant literal
    w0 = int(np.asarray(w["t0"]).ravel()[0])
    assert f"{w0}" in hlo


def test_lowered_computation_matches_ref():
    spec, w, xs = _small()
    fn = jax.jit(model.build_model_fn(spec, w, backend="pallas"))
    y_pallas = fn(jnp.asarray(xs[0], jnp.int32))[0]
    y_ref = model.run_batch_np(spec, w, xs[:1], backend="ref")[0]
    np.testing.assert_array_equal(np.asarray(y_pallas), y_ref)


def test_train_quantize_pipeline_smoke():
    from compile import train
    params, log = train.train_lenet(steps=12, batch=32, log_every=6)
    assert log["loss_curve"][0]["loss"] > 0
    q = train.quantize_trained(params)
    spec, w = specs.build("lenet5", trained=q)
    xs, _ = datagen.dataset_for(spec, 2, seed=2)
    quantize.calibrate(spec, w, xs)
    y = model.run_batch_np(spec, w, xs, backend="ref")
    assert y.shape == (2, 10)
