"""Synthetic dataset generators: shapes, ranges, determinism, learnability
signal (class means differ)."""

import numpy as np

from compile import datagen


def test_digits_shapes_and_range():
    xs, ys = datagen.digits(16, seed=3)
    assert xs.shape == (16, 1, 28, 28)
    assert ys.shape == (16,)
    assert xs.min() >= -128 and xs.max() <= 127
    assert set(ys.tolist()) <= set(range(10))


def test_digits_deterministic():
    a, la = datagen.digits(8, seed=5)
    b, lb = datagen.digits(8, seed=5)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    c, _ = datagen.digits(8, seed=6)
    assert not np.array_equal(a, c)


def test_digits_classes_distinguishable():
    xs, ys = datagen.digits(400, seed=7)
    m1 = xs[ys == 1].mean()
    m8 = xs[ys == 8].mean()
    # digit 8 lights all 7 segments, digit 1 only two: mean intensity differs
    assert m8 > m1 + 5


def test_cars_shapes_and_range():
    xs, ys = datagen.cars(8, hw=32, seed=11)
    assert xs.shape == (8, 3, 32, 32)
    assert xs.min() >= -128 and xs.max() <= 127
    assert set(ys.tolist()) <= {0, 1}


def test_cars_hw_parameter():
    xs, _ = datagen.cars(2, hw=64, seed=1)
    assert xs.shape == (2, 3, 64, 64)


def test_dataset_for_matches_spec():
    from compile import specs
    for name in ("lenet5", "vgg16", "densenet121"):
        spec, _ = specs.build(name)
        xs, ys = datagen.dataset_for(spec, 3, seed=2)
        assert list(xs.shape[1:]) == spec["input_shape"]
        assert len(ys) == 3
