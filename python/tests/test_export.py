"""Exporter round-trip: the JSON/bin the rust compiler reads must decode back
to exactly the tensors we exported."""

import json
import os

import numpy as np
import pytest

from compile import datagen, export, quantize, specs


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("arts"))
    spec, w = specs.build("lenet5")
    xs, _ = datagen.dataset_for(spec, 2, seed=1)
    quantize.calibrate(spec, w, xs)
    doc = export.export_model(spec, w, out)
    ys = export.export_golden_io(spec, w, xs, out)
    return out, spec, w, doc, xs, ys


def _decode_tensor(blob: bytes, entry: dict) -> np.ndarray:
    off, size = entry["offset"], entry["size"]
    if entry["dtype"] == "i8":
        raw = np.frombuffer(blob[off:off + size], dtype=np.int8)
    else:
        raw = np.frombuffer(blob[off:off + 4 * size], dtype="<i4")
    return raw.astype(np.int32).reshape(entry["shape"])


def test_weights_roundtrip(exported):
    out, spec, w, doc, _, _ = exported
    blob = open(os.path.join(out, "models", "lenet5.bin"), "rb").read()
    assert len(doc["tensors"]) == len(w)
    for entry in doc["tensors"]:
        got = _decode_tensor(blob, entry)
        np.testing.assert_array_equal(got, np.asarray(w[entry["name"]]),
                                      err_msg=entry["name"])


def test_json_loads_and_has_shifts(exported):
    out, *_ = exported
    doc = json.load(open(os.path.join(out, "models", "lenet5.json")))
    assert doc["name"] == "lenet5"
    for layer in doc["layers"]:
        if layer["op"] in ("conv2d", "dwconv2d", "dense"):
            assert isinstance(layer["shift"], int)


def test_golden_io_roundtrip(exported):
    out, spec, w, _, xs, ys = exported
    meta = json.load(open(os.path.join(out, "data", "lenet5_io.json")))
    assert meta["n"] == xs.shape[0]
    x_raw = np.frombuffer(
        open(os.path.join(out, "data", "lenet5_x.bin"), "rb").read(),
        dtype=np.int8).reshape(xs.shape)
    np.testing.assert_array_equal(x_raw.astype(np.int32), xs)
    y_raw = np.frombuffer(
        open(os.path.join(out, "data", "lenet5_y.bin"), "rb").read(),
        dtype="<i4").reshape(ys.shape)
    np.testing.assert_array_equal(y_raw, ys)


def test_tensor_offsets_non_overlapping(exported):
    _, _, _, doc, _, _ = exported
    spans = []
    for e in doc["tensors"]:
        nbytes = e["size"] * (1 if e["dtype"] == "i8" else 4)
        spans.append((e["offset"], e["offset"] + nbytes))
    spans.sort()
    for (a0, a1), (b0, _) in zip(spans, spans[1:]):
        assert a1 <= b0
