"""L1 correctness: every Pallas kernel vs its independent jnp oracle.

Hypothesis sweeps shapes, strides, padding, shifts and dtypes — this is the
CORE correctness signal for the compute layer of the AOT artifact.
Comparisons are exact (integer semantics) except the float sweep, which uses
allclose.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

S = settings(max_examples=25, deadline=None)


def _arr(rng, shape, lo=-128, hi=128):
    return jnp.asarray(rng.integers(lo, hi, size=shape), jnp.int32)


conv_params = st.tuples(
    st.integers(1, 5),     # ic
    st.integers(1, 6),     # oc
    st.integers(1, 6),     # k
    st.integers(1, 3),     # stride
    st.integers(0, 2),     # pad
    st.integers(4, 12),    # ih
    st.integers(4, 12),    # iw
    st.integers(0, 12),    # shift
    st.booleans(),         # relu
    st.integers(0, 2**32 - 1),
)


@given(conv_params)
@S
def test_conv2d_vs_ref(p):
    ic, oc, k, stride, pad, ih, iw, shift, relu, seed = p
    if ih + 2 * pad < k or iw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (ic, ih, iw))
    w = _arr(rng, (oc, ic, k, k), -127)
    b = _arr(rng, (oc,), -1000, 1000)
    got = kernels.conv2d(x, w, b, stride=stride, pad=pad, shift=shift,
                         relu=relu)
    want = ref.conv2d_ref(x, w, b, stride=stride, pad=pad, shift=shift,
                          relu=relu)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(conv_params)
@S
def test_dwconv2d_vs_ref(p):
    c, _, k, stride, pad, ih, iw, shift, relu, seed = p
    if ih + 2 * pad < k or iw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, ih, iw))
    w = _arr(rng, (c, k, k), -127)
    b = _arr(rng, (c,), -1000, 1000)
    got = kernels.dwconv2d(x, w, b, stride=stride, pad=pad, shift=shift,
                           relu=relu)
    want = ref.dwconv2d_ref(x, w, b, stride=stride, pad=pad, shift=shift,
                            relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 14),
       st.booleans(), st.integers(0, 2**32 - 1))
@S
def test_dense_vs_ref(i, o, shift, relu, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (i,))
    w = _arr(rng, (o, i), -127)
    b = _arr(rng, (o,), -1000, 1000)
    got = kernels.dense(x, w, b, shift=shift, relu=relu)
    want = ref.dense_ref(x, w, b, shift=shift, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 6), st.integers(2, 4), st.integers(1, 3),
       st.integers(4, 12), st.integers(0, 2**32 - 1))
@S
def test_maxpool_vs_ref(c, k, stride, hw, seed):
    if hw < k:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, hw, hw))
    got = kernels.maxpool(x, k=k, stride=stride)
    want = ref.maxpool_ref(x, k=k, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 6), st.integers(1, 3), st.integers(4, 12),
       st.integers(0, 2**32 - 1))
@S
def test_avgpool2d_vs_ref(c, stride, hw, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, hw, hw))
    got = kernels.avgpool2d(x, k=2, stride=stride)
    want = ref.avgpool2d_ref(x, k=2, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 8), st.sampled_from([1, 2, 4, 8]),
       st.integers(0, 2**32 - 1))
@S
def test_avgpool_global_vs_ref(c, hw, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, hw, hw))
    shift = (hw * hw - 1).bit_length()
    got = kernels.avgpool_global(x, shift=shift)
    want = ref.avgpool_global_ref(x, shift=shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 6), st.integers(2, 10), st.booleans(),
       st.integers(0, 2**32 - 1))
@S
def test_add_vs_ref(c, hw, relu, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, (c, hw, hw))
    b = _arr(rng, (c, hw, hw))
    got = kernels.add(a, b, relu=relu)
    want = ref.add_ref(a, b, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 8), st.integers(0, 12), st.booleans(),
       st.integers(0, 2**32 - 1))
@S
def test_requantize_vs_quant(c, shift, relu, seed):
    from compile.quant import requant
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-(2**20), 2**20, size=(c, 3, 3)), jnp.int32)
    got = kernels.requantize(x, shift=shift, relu=relu)
    want = requant(x, shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---- float dtype sweep -----------------------------------------------------

@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 2), st.integers(0, 1), st.integers(4, 9),
       st.integers(0, 2**32 - 1))
@S
def test_conv2d_f32_vs_ref(ic, oc, k, stride, pad, hw, seed):
    if hw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(ic, hw, hw)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(oc, ic, k, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(oc,)), jnp.float32)
    got = kernels.conv2d_f32(x, w, b, stride=stride, pad=pad)
    want = ref.conv2d_ref_f32(x, w, b, stride=stride, pad=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 32), st.integers(1, 8), st.integers(0, 2**32 - 1))
@S
def test_dense_f32_vs_matmul(i, o, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(i,)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(o, i)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(o,)), jnp.float32)
    got = kernels.dense_f32(x, w, b)
    want = jnp.matmul(w, x) + b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
