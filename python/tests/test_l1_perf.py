"""L1 structural perf analyzer: VMEM budgets and MXU utilization estimates
(DESIGN.md §8) over the real model zoo."""

from compile import l1_perf, quantize, specs, datagen


def _spec(name):
    spec, w = specs.build(name)
    xs, _ = datagen.dataset_for(spec, 2, seed=1)
    quantize.calibrate(spec, w, xs)
    return spec


def test_kernel_size_recovery():
    spec = _spec("lenet5")
    conv1 = spec["layers"][0]
    assert l1_perf._k_of(conv1) == 6  # Table 9: 6x6 kernels


def test_conv_block_stats_math():
    st = l1_perf.conv_block_stats((128, 16, 16), 3, 64)
    # x: 128*16*16*4 + w: 128*9*4 + out bound: 16*16*4
    assert st["vmem_bytes"] == 128 * 256 * 4 + 128 * 9 * 4 + 256 * 4
    assert st["reduction"] == 128 * 9
    # 1152 reduction -> padded to 1152 (9*128): perfect utilization
    assert st["mxu_util"] == 1.0
    assert st["vmem_ok"]


def test_all_zoo_models_fit_vmem():
    for name in specs.MODEL_NAMES:
        r = l1_perf.analyze_spec(_spec(name))
        assert r["all_fit_vmem"], name
        assert 0.0 < r["mean_mxu_util"] <= 1.0


def test_util_padded_lanes():
    # reduction of 1 pads to 128 lanes: 1/128 utilization
    st = l1_perf.conv_block_stats((1, 4, 4), 1, 1)
    assert abs(st["mxu_util"] - 1 / 128) < 1e-9
