"""L2 correctness: spec interpreter, calibration, and model-level
pallas-vs-ref agreement on real zoo models."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import datagen, model, quantize, specs


def _calibrated(name):
    spec, w = specs.build(name)
    xs, _ = datagen.dataset_for(spec, 2, seed=9)
    quantize.calibrate(spec, w, xs)
    return spec, w, xs


@pytest.mark.parametrize("name", ["lenet5", "mobilenet_v1", "vgg16"])
def test_pallas_matches_ref_end_to_end(name):
    spec, w, xs = _calibrated(name)
    x = jnp.asarray(xs[0], jnp.int32)
    yp = jax.jit(model.build_model_fn(spec, w, "pallas"))(x)[0]
    yr = jax.jit(model.build_model_fn(spec, w, "ref"))(x)[0]
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))


@pytest.mark.parametrize("name", specs.MODEL_NAMES)
def test_calibration_fills_all_shifts(name):
    spec, w, _ = _calibrated(name)
    for li, layer in enumerate(spec["layers"]):
        if layer["op"] in ("conv2d", "dwconv2d", "dense"):
            assert layer["shift"] is not None, f"layer {li} uncalibrated"
            assert 0 <= layer["shift"] <= 31


def test_calibrated_outputs_in_int8_range():
    spec, w, xs = _calibrated("mobilenet_v1")
    y = model.run_batch_np(spec, w, xs, backend="ref")
    assert y.min() >= -128 and y.max() <= 127


def test_uncalibrated_spec_raises():
    spec, w = specs.build("lenet5")
    x = jnp.zeros(tuple(spec["input_shape"]), jnp.int32)
    with pytest.raises(ValueError, match="uncalibrated"):
        model.run_spec(spec, w, x, backend="ref")


def test_resnet_and_densenet_graph_ops():
    """Residual adds (resnet) and concats (densenet) appear and run."""
    spec, w, xs = _calibrated("resnet50")
    assert any(l["op"] == "add" for l in spec["layers"])
    y = model.run_batch_np(spec, w, xs[:1], backend="ref")
    assert y.shape == (1, 2)

    spec, w, xs = _calibrated("densenet121")
    assert any(l["op"] == "concat" for l in spec["layers"])
    assert any(l["op"] == "avgpool2d" for l in spec["layers"])
    y = model.run_batch_np(spec, w, xs[:1], backend="ref")
    assert y.shape == (1, 2)


def test_spec_shapes_consistent():
    """Every layer's recorded shapes chain correctly through the DAG."""
    for name in specs.MODEL_NAMES:
        spec, _ = specs.build(name)
        for layer in spec["layers"]:
            for i in layer["inputs"]:
                src = (spec["input_shape"] if i == -1
                       else spec["layers"][i]["out_shape"])
                if "in_shape" in layer:
                    if layer["op"] != "add" and len(layer["inputs"]) == 1:
                        assert src == layer["in_shape"], (name, layer)


def test_deterministic_specs():
    s1, w1 = specs.build("mobilenet_v1")
    s2, w2 = specs.build("mobilenet_v1")
    assert s1 == s2
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
