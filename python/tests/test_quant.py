"""Unit tests for the quantized-arithmetic contract (compile.quant).

These pin down the exact semantics the rust side mirrors
(rust/src/quant/mod.rs) — especially rounding of negative accumulators,
which is where a naive C-style division would diverge from srai.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.quant import (INT8_MAX, INT8_MIN, requant, requant_np,
                           round_shift, saturating_add, quantize_weights_np)


def test_round_shift_zero_is_identity():
    x = jnp.arange(-10, 10, dtype=jnp.int32)
    assert (round_shift(x, 0) == x).all()


def test_round_shift_half_up_positive():
    # (5 + 2) >> 2 = 1 ; (6 + 2) >> 2 = 2  (ties round up)
    assert int(round_shift(jnp.int32(5), 2)) == 1
    assert int(round_shift(jnp.int32(6), 2)) == 2
    assert int(round_shift(jnp.int32(7), 2)) == 2


def test_round_shift_negative_is_arithmetic():
    # srai semantics: (-5 + 2) >> 2 = -3 >> 2 = -1 (floor of -0.75)
    assert int(round_shift(jnp.int32(-5), 2)) == -1
    # (-6 + 2) >> 2 = -1 ; (-7 + 2) >> 2 = -2
    assert int(round_shift(jnp.int32(-6), 2)) == -1
    assert int(round_shift(jnp.int32(-7), 2)) == -2


@given(st.integers(-10**7, 10**7), st.integers(0, 20))
@settings(max_examples=200, deadline=None)
def test_round_shift_matches_float_round_half_up(acc, s):
    got = int(round_shift(jnp.int32(acc), s))
    want = int(np.floor(acc / (1 << s) + 0.5)) if s else acc
    assert got == want


@given(st.integers(-10**7, 10**7), st.integers(0, 16), st.booleans())
@settings(max_examples=200, deadline=None)
def test_requant_np_matches_jnp(acc, s, relu):
    a = int(requant(jnp.int32(acc), s, relu))
    b = int(requant_np(np.array([acc]), s, relu)[0])
    assert a == b


@given(st.integers(INT8_MIN, INT8_MAX), st.integers(INT8_MIN, INT8_MAX))
@settings(max_examples=100, deadline=None)
def test_saturating_add_range(a, b):
    out = int(saturating_add(jnp.int32(a), jnp.int32(b)))
    assert INT8_MIN <= out <= INT8_MAX
    assert out == max(INT8_MIN, min(INT8_MAX, a + b))


def test_quantize_weights_symmetric():
    w = np.array([-1.0, 0.5, 1.0])
    q, s = quantize_weights_np(w)
    assert q.tolist() == [-127, 64, 127]
    assert abs(s - 1 / 127) < 1e-9


def test_quantize_weights_zero_tensor():
    q, s = quantize_weights_np(np.zeros((3, 3)))
    assert (q == 0).all() and s == 1.0
