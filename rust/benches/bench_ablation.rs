//! Ablation bench: standalone contribution of each ISA extension (cores the
//! paper never synthesized — mac-only, add2i-only, fusedmac-only, zol-only,
//! pairs-without-quad) vs the cumulative v0→v4 ladder, answering the
//! §II.C.3 "is fusedmac redundant?" question quantitatively.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{ablation, available_models};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    let models = available_models(&arts);
    let secs = common::time_runs(0, 1, || {
        println!("{}", ablation::render(&arts, &models).unwrap());
    });
    common::report("ablation/all-models", secs, None);
}
