//! Cluster-backend scaling bench (DESIGN.md §18): the same synthetic
//! sweep pushed through `cluster:1`, `cluster:2` and `cluster:4` loopback
//! daemon fleets, reported as jobs/s.  The curve is the headline — the
//! socket transport must scale with hosts the way the shard backend
//! scales with processes — and every run re-asserts the determinism
//! contract by checking results against the in-process reference.
//!
//! Loopback daemons share one machine, so past the core count the curve
//! flattens; the gate tracks per-row regressions (`BENCH_cluster.json`),
//! not the inter-row ratio.

#[path = "common.rs"]
mod common;

use std::path::Path;

use marvel::compiler::pack_input;
use marvel::sim::cluster::ClusterExec;
use marvel::sim::exec::{Executor, JobSpec};
use marvel::sim::shard::{self, run_descs_local, JobDesc};
use marvel::sim::{V0, V4};
use marvel::util::rng::Rng;

/// Deterministic job list over two synthetic model classes × two ladder
/// rungs, interleaved so consecutive jobs hit different compile-cache
/// entries and DM footprints on every host.
fn zoo_descs(n_inputs: usize) -> Vec<JobDesc> {
    let artifacts = Path::new("artifacts");
    let mut hyd = shard::Hydrator::new(artifacts);
    let models = ["synth:lenet:5", "synth:dwconv:9"];
    let mut per_model: Vec<Vec<JobDesc>> = Vec::new();
    for (mi, model) in models.iter().enumerate() {
        let spec = marvel::models::resolve(artifacts, model).unwrap();
        let mut rng = Rng::new(900 + mi as u64);
        let mut descs = Vec::new();
        for v in [V0, V4] {
            let (c, _) = hyd.hydrate(model, v.name).unwrap();
            for _ in 0..n_inputs {
                let input = marvel::models::synth::Builder::random_input(
                    &spec, &mut rng,
                );
                let packed = pack_input(&input).unwrap();
                descs.push(shard::desc_for(model, &c, &packed, 1 << 33));
            }
        }
        per_model.push(descs);
    }
    let mut out = Vec::new();
    let longest = per_model.iter().map(Vec::len).max().unwrap();
    for i in 0..longest {
        for m in &per_model {
            if let Some(d) = m.get(i) {
                out.push(d.clone());
            }
        }
    }
    out
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let descs = zoo_descs(if smoke { 2 } else { 8 });
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);
    assert!(reference.iter().all(|r| r.is_ok()));

    for hosts in [1usize, 2, 4] {
        let mut exec = ClusterExec::spawn_loopback_cmd(
            Path::new(env!("CARGO_BIN_EXE_marvel")),
            Path::new("artifacts"),
            hosts,
            None,
        )
        .unwrap();
        // Warmup doubles as the bit-identity check: daemon-side compile
        // caches fill here, so the timed runs measure steady state.
        for d in &descs {
            exec.submit(JobSpec::named(d.clone()));
        }
        for (i, (g, r)) in
            exec.run().iter().zip(&reference).enumerate()
        {
            assert_eq!(
                g.as_ref().unwrap(),
                r.as_ref().unwrap(),
                "cluster:{hosts} job {i} diverged from the reference"
            );
        }
        let secs = common::time_runs(1, 5, || {
            for d in &descs {
                exec.submit(JobSpec::named(d.clone()));
            }
            let rs = exec.run();
            assert!(rs.iter().all(|r| r.is_ok()));
        });
        common::report(
            &format!(
                "cluster/{} jobs/{hosts} host{}",
                descs.len(),
                if hosts == 1 { "" } else { "s" }
            ),
            secs,
            Some((descs.len() as f64, "job")),
        );
    }
}
