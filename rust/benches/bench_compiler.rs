//! §Perf bench: compiler throughput — spec → planned → rewritten → flattened
//! → encoded machine code, per variant, on the largest available model.

#[path = "common.rs"]
mod common;

use marvel::compiler::compile;
use marvel::models::synth::residual_net;
use marvel::sim::VARIANTS;

fn main() {
    let specs: Vec<(String, marvel::compiler::spec::ModelSpec)> =
        match common::artifacts() {
            Some(arts) => marvel::models::load_available(&arts)
                .into_iter()
                .collect(),
            None => vec![("residual(synth)".into(), residual_net(3))],
        };

    for (name, spec) in &specs {
        for v in VARIANTS {
            let c = compile(spec, v).unwrap();
            let n_instrs = c.instrs().len() as f64;
            let secs = common::time_runs(1, 5, || {
                let _ = compile(spec, v).unwrap();
            });
            common::report(
                &format!("compile/{name}/{} ({} instrs)", v.name, c.instrs().len()),
                secs,
                Some((n_instrs, "instr")),
            );
        }
    }
}
