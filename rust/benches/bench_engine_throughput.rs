//! §Perf L3 bench: batch-engine throughput — the full variants × inputs
//! sweep of one model as a single job list, timed at 1 worker and at one
//! worker per core, through both the one-shot `run_batch` primitive and
//! the persistent `LocalExec` pool (DESIGN.md §13): the delta between the
//! two is the per-batch thread spawn/join cost the executor amortizes.
//! Tracks aggregate instr/s next to `bench_iss`'s single-machine number;
//! the ratio is the engine's scaling factor on this host (DESIGN.md §10).

#[path = "common.rs"]
mod common;

use marvel::compiler::{make_job, pack_input, CompileCache};
use marvel::models::synth::{lenet_shaped, Builder};
use marvel::sim::engine::{default_threads, run_batch, Job};
use marvel::sim::exec::{Executor, JobSpec, LocalExec};
use marvel::sim::VARIANTS;
use marvel::util::rng::Rng;

fn main() {
    let (spec, inputs) = match common::artifacts() {
        Some(arts) => {
            let spec = marvel::models::load(&arts, "lenet5").unwrap();
            let io = marvel::runtime::load_golden_io(&arts, "lenet5").unwrap();
            (spec, io.inputs)
        }
        None => {
            let spec = lenet_shaped(1);
            let mut rng = Rng::new(1);
            let inputs: Vec<Vec<i32>> = (0..4)
                .map(|_| Builder::random_input(&spec, &mut rng))
                .collect();
            (spec, inputs)
        }
    };

    let packed: Vec<Vec<u8>> =
        inputs.iter().map(|x| pack_input(x).unwrap()).collect();
    let cache = CompileCache::new();
    let compiled: Vec<_> = VARIANTS
        .iter()
        .map(|&v| cache.get_or_compile(&spec, v).unwrap())
        .collect();
    let mut jobs: Vec<Job<'_>> = Vec::new();
    for c in &compiled {
        for x in &packed {
            jobs.push(make_job(c, &spec, x, 1 << 36));
        }
    }

    // One sequential pass establishes the total retired-instruction work
    // (identical on every run — the engine is deterministic).
    let total_instrs: u64 = run_batch(&jobs, 1)
        .into_iter()
        .map(|r| r.unwrap().stats.instrs)
        .sum();

    let all = default_threads();
    let mut configs = vec![1usize];
    if all > 1 {
        configs.push(all);
    }
    for threads in &configs {
        let threads = *threads;
        let secs = common::time_runs(1, 5, || {
            let rs = run_batch(&jobs, threads);
            assert!(rs.iter().all(|r| r.is_ok()));
        });
        common::report(
            &format!(
                "engine/{}x{} jobs/{threads} thread{}",
                compiled.len(),
                inputs.len(),
                if threads == 1 { "" } else { "s" }
            ),
            secs,
            Some((total_instrs as f64, "instr")),
        );
    }

    // The same sweep through the persistent executor pool: workers (and
    // their pooled machines) live across every timed batch instead of
    // being respawned per call.
    let out_elems = spec.output_elems();
    for threads in &configs {
        let threads = *threads;
        let mut exec = LocalExec::new(std::path::Path::new("artifacts"), threads);
        let secs = common::time_runs(1, 5, || {
            for c in &compiled {
                for x in &packed {
                    exec.submit(JobSpec::hydrated(
                        &spec.name, c, out_elems, x, 1 << 36,
                    ));
                }
            }
            let rs = exec.run();
            assert!(rs.iter().all(|r| r.is_ok()));
        });
        common::report(
            &format!(
                "exec/local/{}x{} jobs/{threads} thread{}",
                compiled.len(),
                inputs.len(),
                if threads == 1 { "" } else { "s" }
            ),
            secs,
            Some((total_instrs as f64, "instr")),
        );
    }
}
