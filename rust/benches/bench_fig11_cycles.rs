//! Regenerates Fig 11 (cycle & instruction counts per model × variant, with
//! golden verification) — the paper's core performance figure — and times
//! the end-to-end flow per model.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{available_models, fig11_cycles};
use marvel::coordinator::{run_flow, FlowOptions};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    let opts = FlowOptions::default();
    let mut flows = Vec::new();
    for m in available_models(&arts) {
        let secs = common::time_runs(0, 1, || {
            flows.push(run_flow(&arts, &m, &opts).unwrap());
        });
        common::report(&format!("fig11/flow/{m}"), secs, None);
    }
    println!("\n{}", fig11_cycles::render(&flows));
}
