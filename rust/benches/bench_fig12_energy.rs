//! Regenerates Fig 12 (energy per inference, eq. 1) across the model zoo.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{available_models, fig12_energy};
use marvel::coordinator::{run_flow, FlowOptions};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    let opts = FlowOptions::default();
    let flows: Vec<_> = available_models(&arts)
        .iter()
        .map(|m| run_flow(&arts, m, &opts).unwrap())
        .collect();
    println!("{}", fig12_energy::render(&flows));
    // the energy model itself is trivially cheap; time the render
    let secs = common::time_runs(5, 50, || {
        let _ = fig12_energy::render(&flows);
    });
    common::report("fig12/render", secs, None);
}
