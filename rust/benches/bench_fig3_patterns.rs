//! Regenerates Fig 3 (pattern execution counts on v0 across the model zoo)
//! and times the profiling pass.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{available_models, fig3_patterns};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    let models = available_models(&arts);
    let secs = common::time_runs(0, 1, || {
        let table = fig3_patterns::render(&arts, &models).unwrap();
        println!("{table}");
    });
    common::report("fig3/profile-all-models", secs, None);
}
