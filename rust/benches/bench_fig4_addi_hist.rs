//! Regenerates Fig 4 (consecutive-addi immediate histogram + the add2i
//! 5/10-bit coverage analysis) across the model zoo.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{available_models, fig4_addi_hist};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    let models = available_models(&arts);
    let secs = common::time_runs(0, 1, || {
        let out = fig4_addi_hist::render(&arts, &models, 10).unwrap();
        println!("{out}");
    });
    common::report("fig4/histogram-all-models", secs, None);
}
