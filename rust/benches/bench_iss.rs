//! §Perf L3 bench: raw simulator throughput (instructions/second) on the
//! real LeNet-5* workload, v0 and v4, with and without the profiling hook.
//! Target (DESIGN.md §10): ≥100 M instr/s in the NopHook configuration.
//!
//! The lowered micro-op loop (DESIGN.md §11) is timed against the
//! reference decode-enum interpreter it replaced, and the speedup is
//! printed directly; the two paths' `RunStats` are asserted identical
//! first, so the number is a like-for-like comparison.
//!
//! Two further row families cover the PR-6 hot-loop work (DESIGN.md §15):
//! `iss/{v}/dispatch:threaded` vs `iss/{v}/dispatch:match` isolates the
//! direct-threaded dispatch table against the central-`match` loop it
//! replaced, and `iss/v4/lanes:{1,4,8}` steps 8 same-program inferences
//! as software-SIMT lane groups of each width (units = the whole
//! 8-inference batch, so the rows are directly comparable).  The lanes
//! rows carry the engine's `packs_formed`/`lane_occupancy` counters as
//! extra JSON fields, and `iss/{class}/superops:{on,off}` times the
//! PR-10 superinstruction fusion (DESIGN.md §19) per synth model class
//! at lane width 8, bit-identity asserted first.

#[path = "common.rs"]
mod common;

use marvel::compiler::{compile, execute_compiled, load_input, make_sim};
use marvel::models::synth::{lenet_shaped, Builder};
use marvel::profiler::ProfileHook;
use marvel::sim::{lane_stats, Machine, NopHook, V0, V4};
use marvel::util::rng::Rng;

fn median(secs: &[f64]) -> f64 {
    let mut v = secs.to_vec();
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

fn main() {
    let (spec, input) = match common::artifacts() {
        Some(arts) => {
            let spec = marvel::models::load(&arts, "lenet5").unwrap();
            let io = marvel::runtime::load_golden_io(&arts, "lenet5").unwrap();
            (spec, io.inputs[0].clone())
        }
        None => {
            let spec = lenet_shaped(1);
            let mut rng = Rng::new(1);
            let input = Builder::random_input(&spec, &mut rng);
            (spec, input)
        }
    };

    for variant in [V0, V4] {
        let c = compile(&spec, variant).unwrap();
        let (_, stats) =
            execute_compiled(&c, &spec, &input, 1 << 36, &mut NopHook).unwrap();
        // steady-state: reuse one sim, re-inject input, reset cpu
        let mut sim = make_sim(&c).unwrap();

        // sanity: lowered and reference agree before we compare speeds
        sim.reset_cpu();
        load_input(&mut sim, &c, &input).unwrap();
        let ref_stats = sim.run_reference(1 << 36, &mut NopHook).unwrap();
        assert_eq!(ref_stats, stats, "lowered/reference RunStats diverged");

        let lowered_secs = common::time_runs(2, 10, || {
            sim.reset_cpu();
            load_input(&mut sim, &c, &input).unwrap();
            sim.run_fast(1 << 36).unwrap();
        });
        common::report(
            &format!("iss/{}/nop-hook ({} instrs)", variant.name, stats.instrs),
            lowered_secs.clone(),
            Some((stats.instrs as f64, "instr")),
        );

        let reference_secs = common::time_runs(2, 10, || {
            sim.reset_cpu();
            load_input(&mut sim, &c, &input).unwrap();
            sim.run_reference(1 << 36, &mut NopHook).unwrap();
        });
        common::report(
            &format!("iss/{}/reference-interp", variant.name),
            reference_secs.clone(),
            Some((stats.instrs as f64, "instr")),
        );
        println!(
            "iss/{}: lowered-vs-reference speedup {:.2}x",
            variant.name,
            median(&reference_secs) / median(&lowered_secs)
        );

        // Dispatch-flavor rows: the same lowered program through the kept
        // central-`match` loop vs the direct-threaded handler table (the
        // default `run` path, so its row re-reports `lowered_secs`).
        let match_secs = common::time_runs(2, 10, || {
            sim.reset_cpu();
            load_input(&mut sim, &c, &input).unwrap();
            sim.run_match(1 << 36, &mut NopHook).unwrap();
        });
        common::report(
            &format!("iss/{}/dispatch:match", variant.name),
            match_secs.clone(),
            Some((stats.instrs as f64, "instr")),
        );
        common::report(
            &format!("iss/{}/dispatch:threaded", variant.name),
            lowered_secs.clone(),
            Some((stats.instrs as f64, "instr")),
        );
        println!(
            "iss/{}: threaded-vs-match speedup {:.2}x",
            variant.name,
            median(&match_secs) / median(&lowered_secs)
        );

        let secs = common::time_runs(1, 5, || {
            sim.reset_cpu();
            load_input(&mut sim, &c, &input).unwrap();
            let mut hook = ProfileHook::new(c.words().len());
            sim.run(1 << 36, &mut hook).unwrap();
        });
        common::report(
            &format!("iss/{}/profile-hook", variant.name),
            secs,
            Some((stats.instrs as f64, "instr")),
        );
    }

    // Multi-lane scenario (DESIGN.md §15): 8 independent inferences of the
    // same v4 program, stepped as lane groups of width 1 (scalar
    // back-to-back), 4 and 8.  Units are the whole batch, so a width's
    // `units_per_s` is directly its batch throughput.
    let c = compile(&spec, V4).unwrap();
    let (_, stats) =
        execute_compiled(&c, &spec, &input, 1 << 36, &mut NopHook).unwrap();
    let mut lanes: Vec<Machine> =
        (0..8).map(|_| make_sim(&c).unwrap()).collect();
    let budgets = [1u64 << 36; 8];
    for width in [1usize, 4, 8] {
        lane_stats::reset();
        let secs = common::time_runs(2, 10, || {
            for m in lanes.iter_mut() {
                m.reset_cpu();
                load_input(m, &c, &input).unwrap();
            }
            if width == 1 {
                for m in lanes.iter_mut() {
                    m.run_fast(1 << 36).unwrap();
                }
            } else {
                for chunk in lanes.chunks_mut(width) {
                    let n = chunk.len();
                    // The bench is the pack former here (the exec layer is
                    // bypassed), so it records its packs like exec does.
                    lane_stats::record_pack(n, width);
                    let rs = Machine::run_lane_group(chunk, &budgets[..n])
                        .expect("uniform same-program lanes must group");
                    for r in rs {
                        r.unwrap();
                    }
                }
            }
        });
        let ls = lane_stats::snapshot();
        common::report_extra(
            &format!("iss/v4/lanes:{width}"),
            secs,
            Some((8.0 * stats.instrs as f64, "instr")),
            &[
                ("packs_formed", ls.packs_formed as f64),
                ("lane_occupancy", ls.lane_occupancy()),
            ],
        );
    }

    // Superinstruction rows (DESIGN.md §19): 8 same-program inferences at
    // lane width 8 per synth class, fusion off vs on.  Bit-identity is
    // asserted before timing, so the on/off delta is pure execution-shape.
    for (label, model) in [
        ("lenet", "synth:lenet:1"),
        ("dwconv", "synth:dwconv:9"),
        ("rnn", "synth:rnn:11"),
    ] {
        let spec =
            marvel::models::resolve(std::path::Path::new("artifacts"), model)
                .unwrap();
        let mut rng = Rng::new(7);
        let input = Builder::random_input(&spec, &mut rng);
        let c = compile(&spec, V4).unwrap();
        let (_, stats) =
            execute_compiled(&c, &spec, &input, 1 << 36, &mut NopHook)
                .unwrap();
        let mut lanes: Vec<Machine> =
            (0..8).map(|_| make_sim(&c).unwrap()).collect();
        let mut medians = Vec::new();
        for fused in [false, true] {
            for m in lanes.iter_mut() {
                m.superops = fused;
            }
            // sanity: fused lane groups retire the exact same RunStats
            for m in lanes.iter_mut() {
                m.reset_cpu();
                load_input(m, &c, &input).unwrap();
            }
            let rs = Machine::run_lane_group(&mut lanes, &budgets)
                .expect("uniform same-program lanes must group");
            for r in rs {
                assert_eq!(
                    r.unwrap(),
                    stats,
                    "iss/{label}: superops:{fused} RunStats diverged"
                );
            }
            let secs = common::time_runs(2, 10, || {
                for m in lanes.iter_mut() {
                    m.reset_cpu();
                    load_input(m, &c, &input).unwrap();
                }
                let rs = Machine::run_lane_group(&mut lanes, &budgets)
                    .expect("uniform same-program lanes must group");
                for r in rs {
                    r.unwrap();
                }
            });
            common::report(
                &format!(
                    "iss/{label}/superops:{}",
                    if fused { "on" } else { "off" }
                ),
                secs.clone(),
                Some((8.0 * stats.instrs as f64, "instr")),
            );
            medians.push(median(&secs));
        }
        println!(
            "iss/{label}: superops on-vs-off speedup {:.2}x at lanes:8",
            medians[0] / medians[1]
        );
    }
}
