//! §Robustness overload bench: trace-replay through the serving front
//! under deadline pressure (DESIGN.md §16).
//!
//! A chatty lenet-shaped tenant floods the server while a quiet
//! tiny-conv tenant submits deadline-carrying requests.  Three arrival
//! patterns are replayed — `bursty` (synchronized bursts), `diurnal`
//! (alternating peak/trough phases) and `adversarial` (a full backlog
//! committed *before* the tight-deadline requests arrive) — once per
//! policy (fifo, edf).  The headline metric is **goodput under
//! deadline** for the quiet tenant: the fraction of its
//! deadline-carrying requests answered within the deadline, with both
//! admission sheds and served-but-late replies counting against it
//! (`goodput` rows, gated higher-is-better in CI next to `units_per_s`).
//! The interesting comparison is the adversarial trace: under fifo the
//! tight requests drain behind the whole backlog and miss; under edf
//! they ride the next batch and meet.
//!
//! Deadlines are calibrated, not hard-coded: the trace unit `L` is the
//! measured cost of one chatty inference on a warm single-thread
//! server, so the same trace expresses the same *relative* pressure on
//! any machine.  Results land in `BENCH_overload.json` (CI sets
//! `BENCH_JSON`).

#[path = "common.rs"]
mod common;

use std::time::Duration;

use marvel::compiler::CompileCache;
use marvel::models::synth::{lenet_shaped, tiny_conv_net, Builder};
use marvel::sim::exec::LocalExec;
use marvel::sim::serve::{build_serve_models, model_key, ReqMeta, Server,
                         ServeModel};
use marvel::sim::{PolicyKind, ServeOptions, V4};
use marvel::util::rng::Rng;

const CHATTY: &str = "synth:lenet:1";
const QUIET: &str = "synth:tiny:3";

fn units(cache: &CompileCache) -> Vec<ServeModel> {
    build_serve_models(
        std::path::Path::new("artifacts"),
        &[CHATTY.to_string(), QUIET.to_string()],
        &[V4],
        cache,
    )
    .unwrap()
}

fn exec1() -> Box<LocalExec> {
    // One worker thread: batch cost is the sum of its jobs, so the
    // calibrated unit L translates directly into backlog drain time.
    Box::new(LocalExec::new(std::path::Path::new("artifacts"), 1))
}

fn one_input(
    spec: &marvel::compiler::spec::ModelSpec,
    seed: u64,
) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    Builder::random_input(spec, &mut rng)
        .iter()
        .map(|&v| v as i8 as u8)
        .collect()
}

/// One replayed arrival: which tenant, the think-time gap before it, and
/// its scheduling metadata.
struct Ev {
    chatty: bool,
    gap: Duration,
    deadline: Option<Duration>,
    priority: u8,
}

impl Ev {
    fn chatty(gap: Duration) -> Ev {
        Ev { chatty: true, gap, deadline: None, priority: 0 }
    }

    fn tiny(gap: Duration, deadline: Duration) -> Ev {
        Ev { chatty: false, gap, deadline: Some(deadline), priority: 200 }
    }
}

/// Synchronized bursts: each burst opens with two tight-deadline quiet
/// requests followed by six chatty ones, then the line goes idle.
fn bursty(l: Duration) -> Vec<Ev> {
    let mut t = Vec::new();
    for burst in 0..3u32 {
        let gap = if burst == 0 { Duration::ZERO } else { 3 * l };
        t.push(Ev::tiny(gap, 10 * l));
        t.push(Ev::tiny(Duration::ZERO, 10 * l));
        for _ in 0..6 {
            t.push(Ev::chatty(Duration::ZERO));
        }
    }
    t
}

/// Alternating peak/trough phases: a dense daytime flood with riders,
/// then a sparse night trickle.
fn diurnal(l: Duration) -> Vec<Ev> {
    let mut t = Vec::new();
    for _ in 0..2u32 {
        for _ in 0..6 {
            t.push(Ev::chatty(l / 4));
        }
        for _ in 0..2 {
            t.push(Ev::tiny(l / 2, 10 * l));
        }
        for _ in 0..2 {
            t.push(Ev::chatty(2 * l));
        }
        t.push(Ev::tiny(2 * l, 10 * l));
    }
    t
}

/// The worst case for arrival-order scheduling: the whole chatty backlog
/// (24 requests ≈ 24 L of work) is committed before the first
/// tight-deadline request arrives.  Fifo drains the backlog first and
/// blows the 10 L deadlines; edf pulls the quiet requests into the next
/// batch.
fn adversarial(l: Duration) -> Vec<Ev> {
    let mut t = Vec::new();
    for _ in 0..24u32 {
        t.push(Ev::chatty(Duration::ZERO));
    }
    t.push(Ev::tiny(l / 2, 10 * l));
    for _ in 0..5 {
        t.push(Ev::tiny(Duration::ZERO, 10 * l));
    }
    t
}

/// The trace unit: median cost of one chatty inference on a warm
/// single-thread server, floored at 1 ms so sleep granularity can't
/// distort the replayed gaps.  Doubles as a gated throughput row.
fn calibrate(cache: &CompileCache, input: &[u8]) -> Duration {
    let (server, client) =
        Server::start(units(cache), ServeOptions::default(), exec1());
    let key = model_key(CHATTY, "v4");
    let secs = common::time_runs(2, 3, || {
        client.infer(&key, input.to_vec()).unwrap();
    });
    common::report(
        "overload/calibrate-chatty",
        secs.clone(),
        Some((1.0, "inference")),
    );
    drop(client);
    server.join();
    let mut secs = secs;
    secs.sort_by(f64::total_cmp);
    Duration::from_secs_f64(secs[secs.len() / 2])
        .max(Duration::from_millis(1))
}

/// Replay one trace on a fresh server; returns `(met, total)` over the
/// quiet tenant's deadline-carrying requests (server-side accounting:
/// sheds and late replies both count in `total`).
fn run_trace(
    pattern: &str,
    policy: PolicyKind,
    trace: &[Ev],
    cache: &CompileCache,
    chatty_input: &[u8],
    quiet_input: &[u8],
) -> (u64, u64) {
    let opts = ServeOptions {
        max_batch: 4,
        queue_cap: 4096,
        policy,
        slo: Some(Duration::from_millis(200)),
        ..ServeOptions::default()
    }
    .fixed_window(Duration::from_micros(500));
    let (server, client) = Server::start(units(cache), opts, exec1());
    let chatty_key = model_key(CHATTY, "v4");
    let quiet_key = model_key(QUIET, "v4");
    // Warm the measured server (pool + machine allocation); no deadline,
    // so these never touch the goodput accounting.
    client.infer(&chatty_key, chatty_input.to_vec()).unwrap();
    client.infer(&quiet_key, quiet_input.to_vec()).unwrap();

    let mut tickets = Vec::new();
    for ev in trace {
        if !ev.gap.is_zero() {
            std::thread::sleep(ev.gap);
        }
        let (key, input) = if ev.chatty {
            (&chatty_key, chatty_input)
        } else {
            (&quiet_key, quiet_input)
        };
        let meta = ReqMeta { deadline: ev.deadline, priority: ev.priority };
        match client.submit_with(key, input.to_vec(), meta) {
            Ok(t) => tickets.push(t),
            // Structured backpressure is a legal answer under overload —
            // it counts as a drop, not a crash.
            Err(e) => assert_eq!(e.kind, "overload", "{e}"),
        }
    }
    for t in tickets {
        // Sheds and failed jobs answer with a structured error; both are
        // already counted server-side.
        let _ = t.wait_detailed();
    }
    drop(client);
    let report = server.join();
    let row = report
        .slo
        .rows
        .iter()
        .find(|r| r.key == quiet_key)
        .expect("quiet tenant row");
    common::report_latency(
        &format!("overload {pattern} {policy} quiet p99"),
        row.p50_ms / 1e3,
        row.p95_ms / 1e3,
        row.p99_ms / 1e3,
        row.attainment,
    );
    (row.deadline_met, row.deadline_met + row.deadline_missed + row.shed)
}

fn main() {
    let cache = CompileCache::new();
    let chatty_input = one_input(&lenet_shaped(1), 7);
    let quiet_input = one_input(&tiny_conv_net(3), 8);
    let l = calibrate(&cache, &chatty_input);
    println!(
        "overload: calibrated chatty cost L = {:.2} ms",
        l.as_secs_f64() * 1e3
    );
    type Mk = fn(Duration) -> Vec<Ev>;
    let patterns: [(&str, Mk); 3] = [
        ("bursty", bursty),
        ("diurnal", diurnal),
        ("adversarial", adversarial),
    ];
    for (pattern, mk) in patterns {
        for policy in [PolicyKind::Fifo, PolicyKind::Edf] {
            let trace = mk(l);
            let (met, total) = run_trace(
                pattern, policy, &trace, &cache, &chatty_input, &quiet_input,
            );
            common::report_goodput(
                &format!("overload {pattern} {policy} goodput"),
                met,
                total,
            );
        }
    }
}
