//! §Perf serving bench: a multi-tenant skewed-arrival scenario through
//! the scheduler subsystem (DESIGN.md §14).
//!
//! Two tenants share one server at a 10:1 request-rate skew — a chatty
//! lenet-shaped stream next to a quiet tiny-conv stream, the MobileNet-
//! class-floods-the-front situation the scheduler exists for.  The
//! scenario runs once per `--policy` (fifo, drr); for each it reports the
//! submit→reply throughput of the mixed stream (`units_per_s`, gated like
//! the ISS numbers) and the server's own per-model p50/p95/p99 + SLO
//! attainment (`p99_s`, gated as lower-is-better).  The interesting
//! comparison is the *quiet* tenant's p99 across policies: under fifo it
//! rides behind the chatty backlog, under drr it keeps its round-robin
//! share of every batch.  Results land in `BENCH_serve.json` (CI sets
//! `BENCH_JSON`).

#[path = "common.rs"]
mod common;

use std::time::Duration;

use marvel::compiler::CompileCache;
use marvel::models::synth::{lenet_shaped, tiny_conv_net, Builder};
use marvel::sim::exec::LocalExec;
use marvel::sim::serve::{build_serve_models, model_key, Server};
use marvel::sim::{PolicyKind, ServeOptions, ServeReport, V4};
use marvel::util::rng::Rng;

/// Requests per round per tenant: the 10:1 skew of the scenario.
const CHATTY_PER_ROUND: usize = 10;
const QUIET_PER_ROUND: usize = 1;

fn inputs_for(spec: &marvel::compiler::spec::ModelSpec, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..16)
        .map(|_| {
            Builder::random_input(spec, &mut rng)
                .iter()
                .map(|&v| v as i8 as u8)
                .collect()
        })
        .collect()
}

fn scenario(policy: PolicyKind, rounds: usize) -> ServeReport {
    let chatty_model = "synth:lenet:1".to_string();
    let quiet_model = "synth:tiny:3".to_string();
    let cache = CompileCache::new();
    let units = build_serve_models(
        std::path::Path::new("artifacts"),
        &[chatty_model.clone(), quiet_model.clone()],
        &[V4],
        &cache,
    )
    .unwrap();
    let chatty_key = model_key(&chatty_model, "v4");
    let quiet_key = model_key(&quiet_model, "v4");
    let chatty_inputs = inputs_for(&lenet_shaped(1), 7);
    let quiet_inputs = inputs_for(&tiny_conv_net(3), 8);

    let opts = ServeOptions {
        window_min: Duration::from_micros(200),
        window_max: Duration::from_millis(2),
        max_batch: 32,
        queue_cap: 4096,
        policy,
        slo: Some(Duration::from_millis(50)),
        ..ServeOptions::default()
    };
    // Warm the compile/lowering caches (shared via `cache` and memoized on
    // the Arc'd programs) through a throwaway server, so the measured
    // server's histograms — the rows CI gates — never contain the cold
    // compile/lowering sample.  (The measured server's own warm pass below
    // IS recorded, deliberately: it absorbs pool setup while staying a
    // near-steady-state sample, and every gated run shares the same
    // warmup-plus-rounds structure, so the comparison stays apples-to-
    // apples.  The timed skew rounds produce strictly larger samples than
    // a solo warm inference, so the p99 rank lands on a flood sample.)
    {
        let warm_units = build_serve_models(
            std::path::Path::new("artifacts"),
            &[chatty_model.clone(), quiet_model.clone()],
            &[V4],
            &cache,
        )
        .unwrap();
        let (wserver, wclient) = Server::start(
            warm_units,
            opts,
            Box::new(LocalExec::new(std::path::Path::new("artifacts"), 0)),
        );
        wclient.infer(&chatty_key, chatty_inputs[0].clone()).unwrap();
        wclient.infer(&quiet_key, quiet_inputs[0].clone()).unwrap();
        drop(wclient);
        wserver.join();
    }

    let exec = Box::new(LocalExec::new(std::path::Path::new("artifacts"), 0));
    let (server, client) = Server::start(units, opts, exec);
    // One warm pass through the *measured* server as well: compile and
    // lowering are already hot (throwaway server above, shared cache), so
    // these two samples only absorb this executor's pool/machine
    // allocation instead of letting it inflate the first timed round.
    client.infer(&chatty_key, chatty_inputs[0].clone()).unwrap();
    client.infer(&quiet_key, quiet_inputs[0].clone()).unwrap();

    let per_round = CHATTY_PER_ROUND + QUIET_PER_ROUND;
    let secs = common::time_runs(1, rounds, || {
        // One round = the skewed burst: 10 chatty submissions, then 1
        // quiet rider; the round's time is until the slowest reply.
        let chatty = (0..CHATTY_PER_ROUND).map(|i| {
            client
                .submit(&chatty_key, chatty_inputs[i % chatty_inputs.len()].clone())
                .unwrap()
        });
        let tickets: Vec<_> = chatty
            .chain((0..QUIET_PER_ROUND).map(|i| {
                client
                    .submit(&quiet_key, quiet_inputs[i % quiet_inputs.len()].clone())
                    .unwrap()
            }))
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
    });
    common::report(
        &format!("serve skew 10:1 {policy} c={per_round}"),
        secs,
        Some((per_round as f64, "inference")),
    );
    drop(client);
    server.join()
}

fn main() {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let rounds = if smoke { 2 } else { 20 };
    for policy in [PolicyKind::Fifo, PolicyKind::Drr] {
        let report = scenario(policy, rounds);
        for row in &report.slo.rows {
            // Tenant-labeled latency rows: the quiet tenant's p99 under
            // drr vs fifo is the scheduler's headline number.
            let tenant = if row.key.starts_with("synth:lenet") {
                "chatty"
            } else {
                "quiet"
            };
            common::report_latency(
                &format!("serve {policy} {tenant} p99"),
                row.p50_ms / 1e3,
                row.p95_ms / 1e3,
                row.p99_ms / 1e3,
                row.attainment,
            );
        }
        println!(
            "serve {policy}: {} batches dispatched",
            report.batches
        );
    }
}
