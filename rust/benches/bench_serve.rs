//! §Perf serving bench: request latency through the async batching front
//! (DESIGN.md §12) at increasing levels of concurrency.
//!
//! The offline engine benches measure *throughput* over a fixed job list;
//! this one measures what a caller of `marvel serve` experiences: the
//! wall-clock of `submit → wait` while other clients are in flight.  The
//! interesting number is how the p50 moves as concurrency grows — flat
//! p50 with rising concurrency means the window batching is amortizing the
//! engine across callers rather than serializing them.

#[path = "common.rs"]
mod common;

use std::time::Duration;

use marvel::compiler::CompileCache;
use marvel::models::synth::{lenet_shaped, Builder};
use marvel::sim::exec::LocalExec;
use marvel::sim::serve::{build_serve_models, model_key, Server};
use marvel::sim::{ServeOptions, V4};
use marvel::util::rng::Rng;

fn main() {
    let model = "synth:lenet:1".to_string();
    let spec = lenet_shaped(1);
    let cache = CompileCache::new();
    let units = build_serve_models(
        std::path::Path::new("artifacts"),
        &[model.clone()],
        &[V4],
        &cache,
    )
    .unwrap();
    let key = model_key(&model, "v4");

    let opts =
        ServeOptions { window: Duration::from_millis(2), max_batch: 64 };
    let exec = Box::new(LocalExec::new(std::path::Path::new("artifacts"), 0));
    let (server, client) = Server::start(units, opts, exec);

    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<u8>> = (0..16)
        .map(|_| {
            Builder::random_input(&spec, &mut rng)
                .iter()
                .map(|&v| v as i8 as u8)
                .collect()
        })
        .collect();

    // Warm the compile/lowering caches through the front once.
    client.infer(&key, inputs[0].clone()).unwrap();

    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let rounds = if smoke { 2 } else { 20 };
    for concurrency in [1usize, 4, 16] {
        let secs = common::time_runs(1, rounds, || {
            // `concurrency` clients submit together; the round's time is
            // until the slowest reply (all share at most ceil(c/64)
            // batches).
            let tickets: Vec<_> = (0..concurrency)
                .map(|i| {
                    client
                        .submit(&key, inputs[i % inputs.len()].clone())
                        .unwrap()
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        });
        common::report(
            &format!("serve lenet-shaped v4 c={concurrency}"),
            secs,
            Some((concurrency as f64, "inference")),
        );
    }
    drop(client);
    let batches = server.join();
    println!("serve: {batches} batches dispatched");
}
