//! Regenerates Table 10 (data/program memory per model × variant).

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::{available_models, table10_memory};
use marvel::coordinator::{run_flow, FlowOptions};

fn main() {
    let Some(arts) = common::artifacts() else { return };
    // memory numbers need compilation only; flow with 1 input keeps it cheap
    let opts = FlowOptions { n_inputs: 1, ..FlowOptions::default() };
    let flows: Vec<_> = available_models(&arts)
        .iter()
        .map(|m| run_flow(&arts, m, &opts).unwrap())
        .collect();
    println!("{}", table10_memory::render(&flows));
    let secs = common::time_runs(0, 1, || {
        let _ = table10_memory::render(&flows);
    });
    common::report("table10/render", secs, None);
}
