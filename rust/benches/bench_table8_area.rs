//! Regenerates Table 8 (FPGA utilisation per variant) and Fig 10 (relative
//! proportions) from the calibrated area/power model.

#[path = "common.rs"]
mod common;

use marvel::coordinator::experiments::table8_area;

fn main() {
    println!("{}", table8_area::render());
    println!("{}", table8_area::render_fig10());
    let secs = common::time_runs(10, 100, || {
        let _ = table8_area::render();
    });
    common::report("table8/render", secs, None);
}
