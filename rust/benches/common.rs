//! Shared micro-benchmark harness for the `cargo bench` targets (criterion
//! is unavailable offline).  Reports min/median/mean over N timed runs after
//! warmup, plus a derived throughput line.

use std::time::Instant;

/// Time `f` `iters` times (after `warmup` runs); returns per-run seconds.
/// `BENCH_SMOKE=1` (CI) caps warmup at 1 and iters at 2 so the benches
/// double as smoke tests.
pub fn time_runs<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let warmup = if smoke { warmup.min(1) } else { warmup };
    let iters = if smoke { iters.clamp(1, 2) } else { iters };
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Render a stats line: `name: median 12.3 ms (min 11.9, mean 12.5) [x units/s]`.
///
/// With `BENCH_JSON=<path>` set, also appends one JSON object per line to
/// `<path>` (`{"name", "median_s", "min_s", "mean_s", "units_per_s"?}`) —
/// CI uploads the file as the per-PR perf-trajectory artifact.
pub fn report(name: &str, secs: Vec<f64>, work: Option<(f64, &str)>) {
    report_extra(name, secs, work, &[]);
}

/// [`report`] with extra numeric JSON fields appended to the row (e.g. the
/// engine's `packs_formed` / `lane_occupancy` counters next to a lanes
/// row).  The gate/trend tools only probe their known measurement fields,
/// so extra diagnostics ride along without changing a row's kind.
#[allow(dead_code)] // only the iss bench records extra fields
pub fn report_extra(
    name: &str,
    mut secs: Vec<f64>,
    work: Option<(f64, &str)>,
    extra: &[(&str, f64)],
) {
    secs.sort_by(f64::total_cmp);
    let min = secs[0];
    let median = secs[secs.len() / 2];
    let mean: f64 = secs.iter().sum::<f64>() / secs.len() as f64;
    let mut line = format!(
        "{name}: median {} (min {}, mean {})",
        fmt_t(median),
        fmt_t(min),
        fmt_t(mean)
    );
    if let Some((units, label)) = work {
        line.push_str(&format!("  [{:.1} M{label}/s]", units / median / 1e6));
    }
    for (k, v) in extra {
        line.push_str(&format!("  {k}={v:.3}"));
    }
    println!("{line}");

    if let Some(path) = std::env::var_os("BENCH_JSON") {
        use std::io::Write;
        let mut json = format!(
            "{{\"name\":\"{}\",\"median_s\":{median:.9},\"min_s\":{min:.9},\
             \"mean_s\":{mean:.9}",
            name.replace('\\', "\\\\").replace('"', "\\\"")
        );
        if let Some((units, _)) = work {
            json.push_str(&format!(",\"units_per_s\":{:.1}", units / median));
        }
        for (k, v) in extra {
            json.push_str(&format!(",\"{k}\":{v:.4}"));
        }
        json.push_str("}\n");
        match std::fs::OpenOptions::new().create(true).append(true).open(&path)
        {
            Ok(mut f) => {
                let _ = f.write_all(json.as_bytes());
            }
            Err(e) => eprintln!("BENCH_JSON: cannot open {path:?}: {e}"),
        }
    }
}

/// Render + record a latency-quantile measurement (the serve bench's
/// per-model SLO rows).  With `BENCH_JSON=<path>` set, appends
/// `{"name","p50_s","p95_s","p99_s","slo_attainment"?}` — the gate/trend
/// tools treat `p99_s` as lower-is-better, next to the higher-is-better
/// `units_per_s` throughput rows.
#[allow(dead_code)] // only the serve bench records latency rows
pub fn report_latency(
    name: &str,
    p50_s: f64,
    p95_s: f64,
    p99_s: f64,
    attainment: Option<f64>,
) {
    println!(
        "{name}: p50 {} p95 {} p99 {}{}",
        fmt_t(p50_s),
        fmt_t(p95_s),
        fmt_t(p99_s),
        match attainment {
            Some(a) => format!("  [SLO attainment {:.1}%]", a * 100.0),
            None => String::new(),
        }
    );
    if let Some(path) = std::env::var_os("BENCH_JSON") {
        use std::io::Write;
        let mut json = format!(
            "{{\"name\":\"{}\",\"p50_s\":{p50_s:.9},\"p95_s\":{p95_s:.9},\
             \"p99_s\":{p99_s:.9}",
            name.replace('\\', "\\\\").replace('"', "\\\"")
        );
        if let Some(a) = attainment {
            json.push_str(&format!(",\"slo_attainment\":{a:.4}"));
        }
        json.push_str("}\n");
        match std::fs::OpenOptions::new().create(true).append(true).open(&path)
        {
            Ok(mut f) => {
                let _ = f.write_all(json.as_bytes());
            }
            Err(e) => eprintln!("BENCH_JSON: cannot open {path:?}: {e}"),
        }
    }
}

/// Render + record a goodput measurement (the overload bench's headline:
/// the fraction of deadline-carrying requests answered *within* their
/// deadline — sheds and misses both count against it).  With
/// `BENCH_JSON=<path>` set, appends `{"name","goodput","met","total"}` —
/// the gate/trend tools treat `goodput` as higher-is-better.
#[allow(dead_code)] // only the overload bench records goodput rows
pub fn report_goodput(name: &str, met: u64, total: u64) {
    let goodput = if total == 0 { 0.0 } else { met as f64 / total as f64 };
    println!(
        "{name}: goodput {:.1}% ({met}/{total} within deadline)",
        goodput * 100.0
    );
    if let Some(path) = std::env::var_os("BENCH_JSON") {
        use std::io::Write;
        let json = format!(
            "{{\"name\":\"{}\",\"goodput\":{goodput:.4},\"met\":{met},\
             \"total\":{total}}}\n",
            name.replace('\\', "\\\\").replace('"', "\\\"")
        );
        match std::fs::OpenOptions::new().create(true).append(true).open(&path)
        {
            Ok(mut f) => {
                let _ = f.write_all(json.as_bytes());
            }
            Err(e) => eprintln!("BENCH_JSON: cannot open {path:?}: {e}"),
        }
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Artifacts dir if the models are exported (benches degrade gracefully).
pub fn artifacts() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("models").join("lenet5.json").exists() {
        Some(p)
    } else {
        println!("NOTE: artifacts not built — run `make artifacts` for the full bench");
        None
    }
}
