//! Structured assembly: the compiler's internal program form.
//!
//! Generated DNN code is a tree of *counted loops* over straight-line
//! instructions (TVM-style: every trip count is a compile-time constant —
//! exactly the property the paper's `zol` extension exploits, §II.C.4).
//! [`Item`] captures that structure; [`flatten`] lowers it to a flat
//! instruction vector per processor variant:
//!
//! * v0–v3: count-down loops (`li ctr, n; L: body; addi ctr,ctr,-1;
//!   blt x0, ctr, L`), falling back to a `beq`+`jal` epilogue when the body
//!   exceeds the ±4 KiB branch reach;
//! * v4: *innermost* loops become zero-overhead hardware loops
//!   (`dlpi`/`dlp`), eliminating both the `blt` and the counter update —
//!   the paper's Fig 5 transformation.
//!
//! The clamp pseudo-items expand to a fixed-offset forward branch over a
//! single `mv`, so no label machinery is needed anywhere: all other control
//! flow is structured.

use anyhow::{bail, Result};

use crate::isa::{AluImmOp, AluOp, BranchOp, Instr, Reg};
use crate::sim::Variant;

/// Register convention of the generated code (documented in DESIGN.md §4):
/// the MAC datapath registers are fixed by the ISA extension itself.
pub const ACC: Reg = crate::isa::MAC_RD; // x20: accumulator
pub const OPA: Reg = crate::isa::MAC_RS1; // x21: multiplicand (loaded value)
pub const OPB: Reg = crate::isa::MAC_RS2; // x22: multiplier (loaded weight)
pub const SCR: Reg = 23; // x23: mul scratch (dead after accumulate)

/// Loop counters, assigned by nesting depth.  Loops lower to the count-up
/// form TVM-generated C compiles to (`addi ctr,ctr,1; blt ctr,lim,L` —
/// paper Fig 5), so each depth also holds its limit in [`LIMIT_POOL`].
pub const COUNTER_POOL: [Reg; 6] = [5, 6, 7, 9, 28, 29];
/// Loop limits, by nesting depth.
pub const LIMIT_POOL: [Reg; 6] = [30, 31, 1, 2, 3, 4];
/// Pointer registers (per-layer, allocated by codegen).
pub const PTR_POOL: [Reg; 8] = [10, 11, 12, 13, 14, 15, 16, 17];
/// Constant registers (per-layer, allocated by codegen).
pub const CONST_POOL: [Reg; 7] = [24, 25, 26, 27, 18, 19, 8];

/// Structured assembly item.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    /// A concrete straight-line instruction.
    Op(Instr),
    /// Counted loop with a compile-time trip count (n executions of body).
    Loop { n: u32, body: Vec<Item> },
    /// `reg = max(reg, bound)` — `bge reg, bound, +8; mv reg, bound`.
    ClampBelow { reg: Reg, bound: Reg },
    /// `reg = min(reg, bound)` — `bge bound, reg, +8; mv reg, bound`.
    ClampAbove { reg: Reg, bound: Reg },
}

/// How many flat instructions an item expands to (branch-form loops).
fn flat_len(item: &Item, variant: &Variant, depth: usize) -> Result<usize> {
    Ok(match item {
        Item::Op(_) => 1,
        Item::ClampBelow { .. } | Item::ClampAbove { .. } => 2,
        Item::Loop { n, body } => {
            let inner: usize = body
                .iter()
                .map(|i| flat_len(i, variant, depth + 1))
                .sum::<Result<usize>>()?;
            match loop_form(*n, body, inner, variant)? {
                LoopForm::Skip => 0,
                LoopForm::Once => inner,
                LoopForm::Zol { setup } => setup + inner,
                // li ctr,0 + li lim,n + body + addi + branch [+ jal]
                LoopForm::Blt { li_len } => 1 + li_len + inner + 2,
                LoopForm::BeqJal { li_len } => 1 + li_len + inner + 3,
            }
        }
    })
}

enum LoopForm {
    Skip,
    Once,
    /// dlpi (setup 1) or li+dlp (setup depends on count size)
    Zol { setup: usize },
    Blt { li_len: usize },
    BeqJal { li_len: usize },
}

fn li_len(v: i32) -> usize {
    if (-2048..=2047).contains(&v) {
        1
    } else if v & 0xfff == 0 {
        1
    } else {
        2
    }
}

fn is_innermost(body: &[Item]) -> bool {
    body.iter().all(|i| !matches!(i, Item::Loop { .. }))
}

fn loop_form(
    n: u32,
    body: &[Item],
    inner_len: usize,
    variant: &Variant,
) -> Result<LoopForm> {
    if n == 0 {
        return Ok(LoopForm::Skip);
    }
    if n == 1 {
        return Ok(LoopForm::Once);
    }
    if variant.zol && is_innermost(body) && inner_len >= 1 && inner_len <= 4095 {
        let setup = if n <= 31 { 1 } else { li_len(n as i32) + 1 };
        return Ok(LoopForm::Zol { setup });
    }
    // branch-form: blt reach is body + the counter addi (offset -(4*(L+1)))
    let l = li_len(n as i32);
    if inner_len + 1 <= 1023 {
        Ok(LoopForm::Blt { li_len: l })
    } else if inner_len <= 200_000 {
        Ok(LoopForm::BeqJal { li_len: l })
    } else {
        bail!("loop body too large to lower: {inner_len} instrs");
    }
}

/// Emit `li rd, v` (1–2 instructions).
pub fn emit_li(rd: Reg, v: i32, out: &mut Vec<Instr>) {
    if (-2048..=2047).contains(&v) {
        out.push(Instr::OpImm { op: AluImmOp::Addi, rd, rs1: 0, imm: v });
    } else {
        // hi/lo split with carry correction for negative lo
        let lo = ((v << 20) >> 20) as i32; // sign-extended low 12
        let hi = v.wrapping_sub(lo);
        out.push(Instr::Lui { rd, imm: hi });
        if lo != 0 {
            out.push(Instr::OpImm { op: AluImmOp::Addi, rd, rs1: rd, imm: lo });
        }
    }
}

/// Statistics from flattening (zol adoption count feeds the reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct FlattenStats {
    pub zol_loops: u64,
    pub blt_loops: u64,
    pub jal_loops: u64,
    pub inlined_once: u64,
}

/// Lower structured items to flat instructions for `variant`.
pub fn flatten(
    items: &[Item],
    variant: &Variant,
    out: &mut Vec<Instr>,
    stats: &mut FlattenStats,
) -> Result<()> {
    flatten_at(items, variant, 0, out, stats)
}

fn flatten_at(
    items: &[Item],
    variant: &Variant,
    depth: usize,
    out: &mut Vec<Instr>,
    stats: &mut FlattenStats,
) -> Result<()> {
    for item in items {
        match item {
            Item::Op(i) => out.push(*i),
            Item::ClampBelow { reg, bound } => {
                // bge reg, bound, +8 ; mv reg, bound
                out.push(Instr::Branch {
                    op: BranchOp::Bge,
                    rs1: *reg,
                    rs2: *bound,
                    offset: 8,
                });
                out.push(Instr::Op {
                    op: AluOp::Add,
                    rd: *reg,
                    rs1: *bound,
                    rs2: 0,
                });
            }
            Item::ClampAbove { reg, bound } => {
                out.push(Instr::Branch {
                    op: BranchOp::Bge,
                    rs1: *bound,
                    rs2: *reg,
                    offset: 8,
                });
                out.push(Instr::Op {
                    op: AluOp::Add,
                    rd: *reg,
                    rs1: *bound,
                    rs2: 0,
                });
            }
            Item::Loop { n, body } => {
                let mut inner = Vec::new();
                flatten_at(body, variant, depth + 1, &mut inner, stats)?;
                match loop_form(*n, body, inner.len(), variant)? {
                    LoopForm::Skip => {}
                    LoopForm::Once => {
                        stats.inlined_once += 1;
                        out.extend(inner);
                    }
                    LoopForm::Zol { .. } => {
                        stats.zol_loops += 1;
                        let len = inner.len() as u16;
                        if *n <= 31 {
                            out.push(Instr::Dlpi { count: *n as u8, body_len: len });
                        } else {
                            if depth >= COUNTER_POOL.len() {
                                bail!("loop nesting too deep: {depth}");
                            }
                            let ctr = COUNTER_POOL[depth];
                            emit_li(ctr, *n as i32, out);
                            out.push(Instr::Dlp { rs1: ctr, body_len: len });
                        }
                        out.extend(inner);
                    }
                    form @ (LoopForm::Blt { .. } | LoopForm::BeqJal { .. }) => {
                        if depth >= COUNTER_POOL.len() {
                            bail!("loop nesting too deep: {depth}");
                        }
                        // count-up form, as TVM-compiled C (paper Fig 5):
                        //   li ctr, 0 ; li lim, n
                        //   L: body ; addi ctr,ctr,1 ; blt ctr,lim,L
                        let ctr = COUNTER_POOL[depth];
                        let lim = LIMIT_POOL[depth];
                        emit_li(ctr, 0, out);
                        emit_li(lim, *n as i32, out);
                        let top = out.len();
                        out.extend(inner);
                        out.push(Instr::OpImm {
                            op: AluImmOp::Addi,
                            rd: ctr,
                            rs1: ctr,
                            imm: 1,
                        });
                        match form {
                            LoopForm::Blt { .. } => {
                                stats.blt_loops += 1;
                                let dist = (out.len() - top + 1) as i32;
                                out.push(Instr::Branch {
                                    op: BranchOp::Blt,
                                    rs1: ctr,
                                    rs2: lim,
                                    offset: -4 * (dist - 1),
                                });
                            }
                            LoopForm::BeqJal { .. } => {
                                stats.jal_loops += 1;
                                out.push(Instr::Branch {
                                    op: BranchOp::Bge,
                                    rs1: ctr,
                                    rs2: lim,
                                    offset: 8,
                                });
                                let dist = (out.len() - top) as i32;
                                out.push(Instr::Jal { rd: 0, offset: -4 * dist });
                            }
                            _ => unreachable!(),
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Total flat length without emitting (used by planners/reports).
pub fn measure(items: &[Item], variant: &Variant) -> Result<usize> {
    items.iter().map(|i| flat_len(i, variant, 0)).sum()
}

// ---------------------------------------------------------------------------
// Emission context used by the per-op code generators
// ---------------------------------------------------------------------------

/// Builder over `Vec<Item>` with loop scoping and per-layer register pools.
pub struct Emit {
    pub items: Vec<Item>,
    next_ptr: usize,
    next_const: usize,
    consts: Vec<(i32, Reg)>,
}

impl Default for Emit {
    fn default() -> Self {
        Self::new()
    }
}

impl Emit {
    pub fn new() -> Self {
        Emit { items: Vec::new(), next_ptr: 0, next_const: 0, consts: Vec::new() }
    }

    /// Allocate a pointer register (per-layer; panics on exhaustion — the
    /// templates are written to fit the pool).
    pub fn ptr_reg(&mut self) -> Reg {
        assert!(
            self.next_ptr < PTR_POOL.len(),
            "pointer register pool exhausted"
        );
        let r = PTR_POOL[self.next_ptr];
        self.next_ptr += 1;
        r
    }

    /// Materialize a constant in a register (deduplicated per layer).
    /// Must be called before entering the loops that use it.
    pub fn const_reg(&mut self, v: i32) -> Reg {
        if let Some(&(_, r)) = self.consts.iter().find(|(cv, _)| *cv == v) {
            return r;
        }
        assert!(
            self.next_const < CONST_POOL.len(),
            "constant register pool exhausted"
        );
        let r = CONST_POOL[self.next_const];
        self.next_const += 1;
        self.li(r, v);
        self.consts.push((v, r));
        r
    }

    pub fn op(&mut self, i: Instr) {
        self.items.push(Item::Op(i));
    }

    /// `li rd, v` (pseudo).
    pub fn li(&mut self, rd: Reg, v: i32) {
        let mut tmp = Vec::new();
        emit_li(rd, v, &mut tmp);
        for i in tmp {
            self.op(i);
        }
    }

    /// `mv rd, rs`.
    pub fn mv(&mut self, rd: Reg, rs: Reg) {
        self.op(Instr::Op { op: AluOp::Add, rd, rs1: rs, rs2: 0 });
    }

    /// `addi rd, rd, imm` — or register-add for out-of-range immediates
    /// (the caller must have materialized the constant *outside* loops via
    /// [`Emit::const_reg`] when it knows the bump is loop-resident; this
    /// convenience handles the in-range case only).
    pub fn bump(&mut self, rd: Reg, imm: i32) {
        if imm == 0 {
            return;
        }
        assert!(
            (-2048..=2047).contains(&imm),
            "bump immediate out of range: {imm} (materialize a const reg)"
        );
        self.op(Instr::OpImm { op: AluImmOp::Addi, rd, rs1: rd, imm });
    }

    /// `add rd, rd, creg` for a (typically large/negative) constant bump.
    pub fn bump_by_reg(&mut self, rd: Reg, creg: Reg) {
        self.op(Instr::Op { op: AluOp::Add, rd, rs1: rd, rs2: creg });
    }

    /// Counted loop with structured body.
    pub fn loop_n(&mut self, n: u32, f: impl FnOnce(&mut Emit)) {
        if n == 0 {
            return;
        }
        let saved = std::mem::take(&mut self.items);
        f(self);
        let body = std::mem::replace(&mut self.items, saved);
        self.items.push(Item::Loop { n, body });
    }

    pub fn clamp_below(&mut self, reg: Reg, bound: Reg) {
        self.items.push(Item::ClampBelow { reg, bound });
    }

    pub fn clamp_above(&mut self, reg: Reg, bound: Reg) {
        self.items.push(Item::ClampAbove { reg, bound });
    }

    /// lb rd, 0(rs)
    pub fn lb(&mut self, rd: Reg, rs: Reg) {
        self.op(Instr::Load { op: crate::isa::LoadOp::Lb, rd, rs1: rs, offset: 0 });
    }

    /// sb rs2, 0(rs1)
    pub fn sb(&mut self, rs2: Reg, rs1: Reg) {
        self.op(Instr::Store { op: crate::isa::StoreOp::Sb, rs2, rs1, offset: 0 });
    }

    /// lw rd, 0(rs)
    pub fn lw(&mut self, rd: Reg, rs: Reg) {
        self.op(Instr::Load { op: crate::isa::LoadOp::Lw, rd, rs1: rs, offset: 0 });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Sim, V0, V4};

    fn run(items: &[Item], variant: Variant) -> (Sim, crate::sim::RunStats) {
        let mut out = Vec::new();
        let mut st = FlattenStats::default();
        flatten(items, &variant, &mut out, &mut st).unwrap();
        out.push(Instr::Ecall);
        let mut sim = Sim::from_instrs(variant, out, 1 << 16).unwrap();
        let stats = sim.run_fast(10_000_000).unwrap();
        (sim, stats)
    }

    fn addi(rd: Reg, rs1: Reg, imm: i32) -> Item {
        Item::Op(Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm })
    }

    #[test]
    fn nested_loops_execute_correct_trip_counts() {
        // x1 counts total inner iterations: 3 * 4 = 12
        let items = vec![Item::Loop {
            n: 3,
            body: vec![Item::Loop { n: 4, body: vec![addi(1, 1, 1)] }],
        }];
        let (sim, _) = run(&items, V0);
        assert_eq!(sim.regs[1], 12);
        let (sim, _) = run(&items, V4);
        assert_eq!(sim.regs[1], 12);
    }

    #[test]
    fn v4_innermost_uses_zol() {
        let items = vec![Item::Loop {
            n: 3,
            body: vec![Item::Loop { n: 4, body: vec![addi(1, 1, 1)] }],
        }];
        let mut out = Vec::new();
        let mut st = FlattenStats::default();
        flatten(&items, &V4, &mut out, &mut st).unwrap();
        assert_eq!(st.zol_loops, 1);
        assert_eq!(st.blt_loops, 1);
        assert!(out.iter().any(|i| matches!(i, Instr::Dlpi { .. })));
        // v0 version must not contain custom instructions
        let mut out0 = Vec::new();
        let mut st0 = FlattenStats::default();
        flatten(&items, &V0, &mut out0, &mut st0).unwrap();
        assert!(out0.iter().all(|i| !i.is_custom()));
        assert_eq!(st0.blt_loops, 2);
    }

    #[test]
    fn v4_saves_cycles_vs_v0() {
        let items = vec![Item::Loop { n: 100, body: vec![addi(1, 1, 1)] }];
        let (_, s0) = run(&items, V0);
        let (_, s4) = run(&items, V4);
        assert!(s4.cycles < s0.cycles, "v4 {} !< v0 {}", s4.cycles, s0.cycles);
        // v0: li + 100*(addi+addi+blt[2c taken,1 last]) ;
        // v4: count 100 > 31 -> li + dlp + 100 addi (+ ecall)
        assert_eq!(s4.cycles, 2 + 100 + 1);
    }

    #[test]
    fn loop_count_one_inlined_and_zero_skipped() {
        let items = vec![
            Item::Loop { n: 1, body: vec![addi(1, 1, 5)] },
            Item::Loop { n: 0, body: vec![addi(1, 1, 100)] },
        ];
        let (sim, _) = run(&items, V0);
        assert_eq!(sim.regs[1], 5);
    }

    #[test]
    fn clamps() {
        // x1 = max(min(x1, 100), -5) for x1 = 300
        let items = vec![
            addi(1, 0, 300),
            addi(2, 0, 100),
            addi(3, 0, -5),
            Item::ClampAbove { reg: 1, bound: 2 },
            Item::ClampBelow { reg: 1, bound: 3 },
        ];
        let (sim, _) = run(&items, V0);
        assert_eq!(sim.regs[1], 100);
        let items = vec![
            addi(1, 0, -300),
            addi(2, 0, 100),
            addi(3, 0, -5),
            Item::ClampAbove { reg: 1, bound: 2 },
            Item::ClampBelow { reg: 1, bound: 3 },
        ];
        let (sim, _) = run(&items, V0);
        assert_eq!(sim.regs[1], -5);
    }

    #[test]
    fn clamp_as_last_item_of_zol_body() {
        // The clamp's forward branch target == ZE: loop-back must still fire.
        let items = vec![Item::Loop {
            n: 5,
            body: vec![
                addi(1, 1, 10),
                addi(2, 0, 25),
                Item::ClampAbove { reg: 1, bound: 2 },
            ],
        }];
        let (sim, _) = run(&items, V4);
        assert_eq!(sim.regs[1], 25);
        let (sim0, _) = run(&items, V0);
        assert_eq!(sim0.regs[1], 25);
    }

    #[test]
    fn big_body_uses_jal_form() {
        // body of 1500 instructions exceeds blt reach
        let body: Vec<Item> = (0..1500).map(|_| addi(1, 1, 1)).collect();
        let items = vec![Item::Loop { n: 3, body }];
        let mut out = Vec::new();
        let mut st = FlattenStats::default();
        flatten(&items, &V0, &mut out, &mut st).unwrap();
        assert_eq!(st.jal_loops, 1);
        let mut sim = {
            let mut prog = out.clone();
            prog.push(Instr::Ecall);
            Sim::from_instrs(V0, prog, 64).unwrap()
        };
        sim.run_fast(10_000_000).unwrap();
        assert_eq!(sim.regs[1], 4500);
    }

    #[test]
    fn li_expansion() {
        let mut out = Vec::new();
        emit_li(1, 5, &mut out);
        assert_eq!(out.len(), 1);
        emit_li(1, 0x12345, &mut out);
        assert_eq!(out.len(), 3); // lui+addi
        // verify semantics on the sim for tricky values
        for v in [0, 1, -1, 2047, -2048, 2048, -2049, 0x7fff_ffff,
                  i32::MIN, 0x1000, 0xfff, -4096] {
            let mut prog = Vec::new();
            emit_li(1, v, &mut prog);
            prog.push(Instr::Ecall);
            let mut sim = Sim::from_instrs(V0, prog, 64).unwrap();
            sim.run_fast(10).unwrap();
            assert_eq!(sim.regs[1], v, "li {v:#x}");
        }
    }

    #[test]
    fn measure_matches_flatten() {
        let items = vec![
            addi(1, 0, 3),
            Item::Loop {
                n: 7,
                body: vec![
                    addi(1, 1, 1),
                    Item::ClampAbove { reg: 1, bound: 2 },
                    Item::Loop { n: 40, body: vec![addi(2, 2, 1)] },
                ],
            },
        ];
        for v in [V0, V4] {
            let mut out = Vec::new();
            let mut st = FlattenStats::default();
            flatten(&items, &v, &mut out, &mut st).unwrap();
            assert_eq!(out.len(), measure(&items, &v).unwrap(), "{}", v.name);
        }
    }
}
