//! Conv2d / depthwise-conv templates — the paper's hot spot.
//!
//! Loop nest (standard TVM scalar schedule, NCHW):
//!
//! ```text
//! for oc { bias_v = bias[oc]; wrow = wp
//!   for oy { for ox {
//!     acc = bias_v; wp = wrow
//!     for ic { for ky { for kx {          // kx innermost -> zol on v4
//!       x21 = lb [xp]; x22 = lb [wp]
//!       x23 = mul x21, x22; x20 += x23    // -> mac
//!       xp += 1; wp += 1                  // -> add2i; all 4 -> fusedmac
//!     } xp += WP-KW } xp += (HP-KH)*WP }
//!     xp += S - IC*HP*WP                  // strength-reduced fixups
//!     requant(acc); sb [op]; op += 1
//!   } xp += S*WP - OW*S }
//!   xp -= OH*S*WP; bp += 4
//! }
//! ```
//!
//! Depthwise drops the `ic` loop and advances the channel base per `c`.

use anyhow::{ensure, Result};

use super::{emit_pad_copy, Bump, Requant};
use crate::compiler::asm::{Emit, ACC, OPA, OPB, SCR};
use crate::compiler::plan::Plan;
use crate::compiler::spec::{Layer, ModelSpec};
use crate::isa::{AluOp, Instr};

pub fn emit(
    e: &mut Emit,
    spec: &ModelSpec,
    plan: &Plan,
    li: usize,
    layer: &Layer,
) -> Result<()> {
    match layer {
        Layer::Conv2d {
            input, w, b, stride, pad, shift, relu, in_shape, out_shape,
        } => {
            let wt = spec.tensor(w)?;
            let (kh, kw) = (wt.shape[2], wt.shape[3]);
            emit_conv(
                e,
                ConvGeo {
                    x_addr: plan.src_addr(*input),
                    scratch: plan.scratch_addr[li],
                    w_addr: plan.weight(w)?,
                    b_addr: plan.weight(b)?,
                    o_addr: plan.layer_out_addr[li],
                    in_shape: *in_shape,
                    out_shape: *out_shape,
                    kh,
                    kw,
                    stride: *stride,
                    pad: *pad,
                    shift: *shift,
                    relu: *relu,
                    depthwise: false,
                },
            )
        }
        Layer::DwConv2d {
            input, w, b, stride, pad, shift, relu, in_shape, out_shape,
        } => {
            let wt = spec.tensor(w)?;
            let (kh, kw) = (wt.shape[1], wt.shape[2]);
            emit_conv(
                e,
                ConvGeo {
                    x_addr: plan.src_addr(*input),
                    scratch: plan.scratch_addr[li],
                    w_addr: plan.weight(w)?,
                    b_addr: plan.weight(b)?,
                    o_addr: plan.layer_out_addr[li],
                    in_shape: *in_shape,
                    out_shape: *out_shape,
                    kh,
                    kw,
                    stride: *stride,
                    pad: *pad,
                    shift: *shift,
                    relu: *relu,
                    depthwise: true,
                },
            )
        }
        _ => unreachable!("conv::emit on non-conv layer"),
    }
}

struct ConvGeo {
    x_addr: u32,
    scratch: Option<u32>,
    w_addr: u32,
    b_addr: u32,
    o_addr: u32,
    in_shape: [usize; 3],
    out_shape: [usize; 3],
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    shift: u32,
    relu: bool,
    depthwise: bool,
}

fn emit_conv(e: &mut Emit, g: ConvGeo) -> Result<()> {
    let [ic, ih, iw] = g.in_shape;
    let [oc, oh, ow] = g.out_shape;
    let (s, kh, kw) = (g.stride as i64, g.kh as i64, g.kw as i64);

    // padded geometry (scratch buffer) or raw
    let (xb, hp, wp_len) = if g.pad > 0 {
        let scratch = g.scratch.expect("planner must provide pad scratch");
        emit_pad_copy(e, g.x_addr, scratch, ic, ih, iw, g.pad)?;
        (scratch, (ih + 2 * g.pad) as i64, (iw + 2 * g.pad) as i64)
    } else {
        (g.x_addr, ih as i64, iw as i64)
    };

    ensure!(
        (oh as i64 - 1) * s + kh <= hp && (ow as i64 - 1) * s + kw <= wp_len,
        "conv geometry out of bounds"
    );

    // pointer registers
    let xp = e.ptr_reg();
    let wp = e.ptr_reg();
    let op = e.ptr_reg();
    let bp = e.ptr_reg();
    let wrow = e.ptr_reg();
    let bias_v = e.ptr_reg();

    // requant + loop-tail fixup constants (materialized outside the loops)
    let rq = Requant::new(e, g.shift, g.relu);
    let icl = if g.depthwise { 1i64 } else { ic as i64 }; // reduction chans
    let d_ky = Bump::new(e, wp_len - kw);
    let d_ic = Bump::new(e, (hp - kh) * wp_len);
    // after the reduction, rewind to this (oy,ox) anchor, then step +s.
    // conv rewinds IC channels; depthwise stays inside the current channel.
    let d_ox = Bump::new(e, s - icl * hp * wp_len);
    let d_oy = Bump::new(e, s * wp_len - (ow as i64) * s);
    // per-oc tail: conv rewinds to XB; depthwise advances to next channel.
    let d_oc = if g.depthwise {
        Bump::new(e, hp * wp_len - (oh as i64) * s * wp_len)
    } else {
        Bump::new(e, -((oh as i64) * s * wp_len))
    };

    e.li(xp, xb as i32);
    e.li(wp, g.w_addr as i32);
    e.li(bp, g.b_addr as i32);
    e.li(op, g.o_addr as i32);

    e.loop_n(oc as u32, |e| {
        e.lw(bias_v, bp); // bias_v = bias[oc]
        e.mv(wrow, wp); // weight row anchor for this output channel
        e.loop_n(oh as u32, |e| {
            e.loop_n(ow as u32, |e| {
                e.mv(ACC, bias_v);
                e.mv(wp, wrow);
                let reduction = |e: &mut Emit| {
                    e.loop_n(kh as u32, |e| {
                        e.loop_n(kw as u32, |e| {
                            e.lb(OPA, xp);
                            e.lb(OPB, wp);
                            e.op(Instr::Op {
                                op: AluOp::Mul,
                                rd: SCR,
                                rs1: OPA,
                                rs2: OPB,
                            });
                            e.op(Instr::Op {
                                op: AluOp::Add,
                                rd: ACC,
                                rs1: ACC,
                                rs2: SCR,
                            });
                            e.bump(xp, 1);
                            e.bump(wp, 1);
                        });
                        d_ky.apply(e, xp);
                    });
                    d_ic.apply(e, xp);
                };
                if g.depthwise {
                    reduction(e);
                } else {
                    e.loop_n(ic as u32, reduction);
                }
                d_ox.apply(e, xp);
                rq.apply(e);
                e.sb(ACC, op);
                e.bump(op, 1);
            });
            d_oy.apply(e, xp);
        });
        d_oc.apply(e, xp);
        // wp ends the oc body at wrow + row_len: the next iteration's
        // `mv wrow, wp` picks it up as the new anchor.
        e.bump(bp, 4);
    });
    Ok(())
}
