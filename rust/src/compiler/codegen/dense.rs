//! Fully-connected template: the purest mac/zol workload (one long
//! reduction per output neuron).

use anyhow::Result;

use super::{Bump, Requant};
use crate::compiler::asm::{Emit, ACC, OPA, OPB, SCR};
use crate::compiler::plan::Plan;
use crate::compiler::spec::{Layer, ModelSpec};
use crate::isa::{AluOp, Instr};

pub fn emit(
    e: &mut Emit,
    spec: &ModelSpec,
    plan: &Plan,
    li: usize,
    layer: &Layer,
) -> Result<()> {
    let Layer::Dense { input, w, b, shift, relu, in_len, out_len } = layer
    else {
        unreachable!("dense::emit on non-dense layer")
    };
    let _ = spec;
    let x_addr = plan.src_addr(*input);
    let w_addr = plan.weight(w)?;
    let b_addr = plan.weight(b)?;
    let o_addr = plan.layer_out_addr[li];

    let xp = e.ptr_reg();
    let wp = e.ptr_reg();
    let op = e.ptr_reg();
    let bp = e.ptr_reg();

    let rq = Requant::new(e, *shift, *relu);
    let d_o = Bump::new(e, -(*in_len as i64)); // rewind x per output neuron

    e.li(xp, x_addr as i32);
    e.li(wp, w_addr as i32);
    e.li(bp, b_addr as i32);
    e.li(op, o_addr as i32);

    e.loop_n(*out_len as u32, |e| {
        e.lw(ACC, bp); // acc = bias[o]
        e.loop_n(*in_len as u32, |e| {
            e.lb(OPA, xp);
            e.lb(OPB, wp);
            e.op(Instr::Op { op: AluOp::Mul, rd: SCR, rs1: OPA, rs2: OPB });
            e.op(Instr::Op { op: AluOp::Add, rd: ACC, rs1: ACC, rs2: SCR });
            e.bump(xp, 1);
            e.bump(wp, 1);
        });
        d_o.apply(e, xp);
        rq.apply(e);
        e.sb(ACC, op);
        e.bump(op, 1);
        e.bump(bp, 4);
    });
    Ok(())
}
