//! Elementwise templates: residual add (saturating int8) and channel concat
//! (pure copies).  These are the glue ops of the ResNet / MobileNetV2 /
//! DenseNet model classes.

use anyhow::Result;

use crate::compiler::asm::{Emit, ACC, OPA, OPB};
use crate::compiler::plan::Plan;
use crate::compiler::spec::Layer;
use crate::isa::{AluOp, Instr};

pub fn emit(e: &mut Emit, plan: &Plan, li: usize, layer: &Layer) -> Result<()> {
    match layer {
        Layer::Add { a, b, relu, shape } => {
            let n: usize = shape.iter().product();
            emit_add(
                e,
                plan.src_addr(*a),
                plan.src_addr(*b),
                plan.layer_out_addr[li],
                n,
                *relu,
            )
        }
        Layer::Concat { inputs, in_shapes, .. } => {
            let srcs: Vec<(u32, usize)> = inputs
                .iter()
                .zip(in_shapes)
                .map(|(&i, s)| (plan.src_addr(i), s.iter().product()))
                .collect();
            emit_concat(e, &srcs, plan.layer_out_addr[li])
        }
        _ => unreachable!("eltwise::emit on non-eltwise layer"),
    }
}

fn emit_add(
    e: &mut Emit,
    a_addr: u32,
    b_addr: u32,
    o_addr: u32,
    n: usize,
    relu: bool,
) -> Result<()> {
    let pa = e.ptr_reg();
    let pb = e.ptr_reg();
    let po = e.ptr_reg();
    let lo = e.const_reg(-128);
    let hi = e.const_reg(127);

    e.li(pa, a_addr as i32);
    e.li(pb, b_addr as i32);
    e.li(po, o_addr as i32);
    e.loop_n(n as u32, |e| {
        e.lb(OPA, pa);
        e.lb(OPB, pb);
        e.op(Instr::Op { op: AluOp::Add, rd: ACC, rs1: OPA, rs2: OPB });
        // saturate to int8, then the optional ReLU floor (x0 == 0)
        e.clamp_below(ACC, lo);
        e.clamp_above(ACC, hi);
        if relu {
            e.clamp_below(ACC, 0);
        }
        e.sb(ACC, po);
        e.bump(pa, 1);
        e.bump(pb, 1);
        e.bump(po, 1);
    });
    Ok(())
}

fn emit_concat(e: &mut Emit, srcs: &[(u32, usize)], o_addr: u32) -> Result<()> {
    let ps = e.ptr_reg();
    let po = e.ptr_reg();
    e.li(po, o_addr as i32);
    for &(src, n) in srcs {
        e.li(ps, src as i32);
        e.loop_n(n as u32, |e| {
            e.lb(OPA, ps);
            e.sb(OPA, po);
            e.bump(ps, 1);
            e.bump(po, 1);
        });
    }
    Ok(())
}
