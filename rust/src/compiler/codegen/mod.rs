//! Code generation: one RV32 template per operator.
//!
//! The templates emit the same code *class* as TVM's generated C compiled
//! for a scalar RV32IM core: perfectly nested counted loops, pointer-walking
//! address arithmetic (`addi` bumps with strength-reduced loop-tail fixups),
//! int8 loads (`lb`), int32 accumulation (`mul`+`add` on the fixed
//! x21/x22 → x20 datapath), and shift-requantization.  That code shape is
//! the whole point: it is what makes the paper's profile (Fig 3) show the
//! mac / add2i / fusedmac / blt patterns, and what the rewrite passes then
//! fuse per variant.
//!
//! Layer boundaries never share registers: every template allocates its
//! pointers/constants fresh from the per-layer pools in [`asm::Emit`].

pub mod conv;
pub mod dense;
pub mod eltwise;
pub mod pool;

use anyhow::{Context, Result};

use super::asm::{Emit, ACC};
use super::plan::Plan;
use super::spec::{Layer, ModelSpec};
use crate::isa::{AluImmOp, Instr, Reg};

/// Emit the code for one layer.
pub fn emit_layer(
    e: &mut Emit,
    spec: &ModelSpec,
    plan: &Plan,
    li: usize,
    layer: &Layer,
) -> Result<()> {
    match layer {
        Layer::Conv2d { .. } | Layer::DwConv2d { .. } => {
            conv::emit(e, spec, plan, li, layer)
        }
        Layer::Dense { .. } => dense::emit(e, spec, plan, li, layer),
        Layer::MaxPool { .. } | Layer::AvgPool2d { .. }
        | Layer::AvgPoolGlobal { .. } => pool::emit(e, plan, li, layer),
        Layer::Add { .. } | Layer::Concat { .. } => {
            eltwise::emit(e, plan, li, layer)
        }
    }
    .with_context(|| format!("codegen for layer {li} ({})", layer.op_name()))
}

/// Pointer bump by `delta`: `addi` when in range, otherwise an `add` with a
/// pre-materialized constant register.  Constants MUST be materialized
/// before the enclosing loops, so callers pass a closure that was already
/// resolved — use [`Bump`] built at template setup time.
#[derive(Clone, Copy, Debug)]
pub enum Bump {
    None,
    Imm(i32),
    Reg(Reg),
}

impl Bump {
    /// Decide the bump form for `delta`, materializing a constant register
    /// now (i.e. at template setup, outside all loops) when needed.
    pub fn new(e: &mut Emit, delta: i64) -> Self {
        if delta == 0 {
            Bump::None
        } else if (-2048..=2047).contains(&delta) {
            Bump::Imm(delta as i32)
        } else {
            let r = e.const_reg(i32::try_from(delta).expect("bump overflow"));
            Bump::Reg(r)
        }
    }

    /// Apply to pointer register `rd` at the current emission point.
    pub fn apply(&self, e: &mut Emit, rd: Reg) {
        match self {
            Bump::None => {}
            Bump::Imm(v) => e.bump(rd, *v),
            Bump::Reg(r) => e.bump_by_reg(rd, *r),
        }
    }
}

/// Requantization constants, materialized once per layer.
pub struct Requant {
    pub shift: u32,
    /// `1 << (shift-1)` — an `addi` immediate when it fits, else a register.
    rnd: Option<Bump>,
    lo: Reg,
    hi: Reg,
}

impl Requant {
    /// Set up constants (call at template setup, outside loops).
    pub fn new(e: &mut Emit, shift: u32, relu: bool) -> Self {
        let rnd = (shift > 0).then(|| Bump::new(e, 1i64 << (shift - 1)));
        // relu floor is 0 == x0: no constant register needed
        let lo = if relu { 0 } else { e.const_reg(-128) };
        let hi = e.const_reg(127);
        Requant { shift, rnd, lo, hi }
    }

    /// Requantize the accumulator (x20) in place: round-shift + clamp.
    pub fn apply(&self, e: &mut Emit) {
        if let Some(rnd) = &self.rnd {
            match rnd {
                Bump::Imm(v) => e.op(Instr::OpImm {
                    op: AluImmOp::Addi,
                    rd: ACC,
                    rs1: ACC,
                    imm: *v,
                }),
                Bump::Reg(r) => e.op(Instr::Op {
                    op: crate::isa::AluOp::Add,
                    rd: ACC,
                    rs1: ACC,
                    rs2: *r,
                }),
                Bump::None => {}
            }
            e.op(Instr::OpImm {
                op: AluImmOp::Srai,
                rd: ACC,
                rs1: ACC,
                imm: self.shift as i32,
            });
        }
        e.clamp_below(ACC, self.lo);
        e.clamp_above(ACC, self.hi);
    }
}

/// Pad-copy stage: memset a scratch buffer to zero, then copy the source
/// activation into its interior (the TVM pad stage; used by conv/dw with
/// pad > 0 so the hot loops stay branch-free).
pub fn emit_pad_copy(
    e: &mut Emit,
    src: u32,
    dst: u32,
    c: usize,
    h: usize,
    w: usize,
    pad: usize,
) -> Result<()> {
    use crate::compiler::asm::OPA;
    let wp = w + 2 * pad;
    let hp = h + 2 * pad;
    let total = (c * hp * wp) as u32;

    let pd = e.ptr_reg();
    let ps = e.ptr_reg();

    // memset(dst, 0, total)
    e.li(pd, dst as i32);
    e.loop_n(total, |e| {
        e.sb(0, pd); // store x0
        e.bump(pd, 1);
    });

    // copy rows into the interior
    let skip_cols = Bump::new(e, (2 * pad) as i64);
    let skip_rows = Bump::new(e, (2 * pad * wp) as i64);
    e.li(ps, src as i32);
    e.li(pd, (dst as usize + pad * wp + pad) as i32);
    e.loop_n(c as u32, |e| {
        e.loop_n(h as u32, |e| {
            e.loop_n(w as u32, |e| {
                e.lb(OPA, ps);
                e.sb(OPA, pd);
                e.bump(ps, 1);
                e.bump(pd, 1);
            });
            skip_cols.apply(e, pd);
        });
        skip_rows.apply(e, pd);
    });
    Ok(())
}
