//! Pooling templates: max, windowed average, global average.
//!
//! Same pointer-walking loop scheme as the conv template, minus weights.
//! Max pooling accumulates with the branch-based `max` idiom (ClampBelow),
//! average pooling sums and round-shifts by log2(window).

use anyhow::Result;

use super::{Bump, Requant};
use crate::compiler::asm::{Emit, ACC, OPA};
use crate::compiler::plan::Plan;
use crate::compiler::spec::Layer;
use crate::isa::{AluOp, Instr};

pub fn emit(e: &mut Emit, plan: &Plan, li: usize, layer: &Layer) -> Result<()> {
    match layer {
        Layer::MaxPool { input, k, stride, in_shape, out_shape } => {
            emit_window_pool(
                e,
                plan.src_addr(*input),
                plan.layer_out_addr[li],
                *in_shape,
                *out_shape,
                *k,
                *stride,
                PoolKind::Max,
            )
        }
        Layer::AvgPool2d { input, k, stride, shift, in_shape, out_shape } => {
            emit_window_pool(
                e,
                plan.src_addr(*input),
                plan.layer_out_addr[li],
                *in_shape,
                *out_shape,
                *k,
                *stride,
                PoolKind::Avg { shift: *shift },
            )
        }
        Layer::AvgPoolGlobal { input, shift, in_shape, .. } => {
            emit_global_pool(
                e,
                plan.src_addr(*input),
                plan.layer_out_addr[li],
                *in_shape,
                *shift,
            )
        }
        _ => unreachable!("pool::emit on non-pool layer"),
    }
}

enum PoolKind {
    Max,
    Avg { shift: u32 },
}

#[allow(clippy::too_many_arguments)]
fn emit_window_pool(
    e: &mut Emit,
    x_addr: u32,
    o_addr: u32,
    in_shape: [usize; 3],
    out_shape: [usize; 3],
    k: usize,
    stride: usize,
    kind: PoolKind,
) -> Result<()> {
    let [c, ih, iw] = in_shape;
    let [_, oh, ow] = out_shape;
    let (s, kl, ihl, iwl) = (stride as i64, k as i64, ih as i64, iw as i64);

    let xp = e.ptr_reg();
    let op = e.ptr_reg();

    // max needs the int8 floor as init; avg needs requant consts
    let (init_lo, rq) = match kind {
        PoolKind::Max => (Some(e.const_reg(-128)), None),
        PoolKind::Avg { shift } => (None, Some(Requant::new(e, shift, false))),
    };
    let d_ky = Bump::new(e, iwl - kl);
    let d_ox = Bump::new(e, s - kl * iwl);
    let d_oy = Bump::new(e, s * iwl - (ow as i64) * s);
    let d_c = Bump::new(e, ihl * iwl - (oh as i64) * s * iwl);

    e.li(xp, x_addr as i32);
    e.li(op, o_addr as i32);

    e.loop_n(c as u32, |e| {
        e.loop_n(oh as u32, |e| {
            e.loop_n(ow as u32, |e| {
                match init_lo {
                    Some(lo) => e.mv(ACC, lo), // acc = -128
                    None => e.mv(ACC, 0),      // acc = 0
                }
                e.loop_n(k as u32, |e| {
                    e.loop_n(k as u32, |e| {
                        e.lb(OPA, xp);
                        match kind {
                            PoolKind::Max => e.clamp_below(ACC, OPA),
                            PoolKind::Avg { .. } => e.op(Instr::Op {
                                op: AluOp::Add,
                                rd: ACC,
                                rs1: ACC,
                                rs2: OPA,
                            }),
                        }
                        e.bump(xp, 1);
                    });
                    d_ky.apply(e, xp);
                });
                d_ox.apply(e, xp);
                if let Some(rq) = &rq {
                    rq.apply(e);
                }
                e.sb(ACC, op);
                e.bump(op, 1);
            });
            d_oy.apply(e, xp);
        });
        d_c.apply(e, xp);
    });
    Ok(())
}

fn emit_global_pool(
    e: &mut Emit,
    x_addr: u32,
    o_addr: u32,
    in_shape: [usize; 3],
    shift: u32,
) -> Result<()> {
    let [c, h, w] = in_shape;
    let xp = e.ptr_reg();
    let op = e.ptr_reg();
    let rq = Requant::new(e, shift, false);

    e.li(xp, x_addr as i32);
    e.li(op, o_addr as i32);
    e.loop_n(c as u32, |e| {
        e.mv(ACC, 0);
        e.loop_n((h * w) as u32, |e| {
            e.lb(OPA, xp);
            e.op(Instr::Op { op: AluOp::Add, rd: ACC, rs1: ACC, rs2: OPA });
            e.bump(xp, 1);
        });
        rq.apply(e);
        e.sb(ACC, op);
        e.bump(op, 1);
    });
    Ok(())
}
