//! The MARVEL compiler: model spec → planned memory → structured RV32
//! assembly → variant-specific rewrites → flat machine code.
//!
//! This module stands in for the paper's TVM → Chess pipeline (§II.A/§II.D):
//! it consumes the same model description the JAX side AOT-exports, emits
//! TVM-class loop nests ([`codegen`]), applies the `chess_rewrite`-style
//! fusion passes ([`rewrite`]) per processor variant, and lowers counted
//! loops to `blt` or zero-overhead hardware loops ([`asm::flatten`]).

pub mod asm;
pub mod codegen;
pub mod plan;
pub mod rewrite;
pub mod spec;

use anyhow::{Context, Result};

use crate::isa::encode::encode;
use crate::isa::Instr;
use crate::sim::{RetireHook, RunStats, Sim, SimError, Variant};
use asm::FlattenStats;
use rewrite::RewriteStats;
use spec::ModelSpec;

/// A fully compiled model for one processor variant.
pub struct Compiled {
    pub variant: Variant,
    pub instrs: Vec<Instr>,
    /// Encoded machine words (PM image).
    pub words: Vec<u32>,
    pub plan: plan::Plan,
    /// Per-layer [start, end) instruction index ranges.
    pub layer_ranges: Vec<(usize, usize)>,
    pub rewrite_stats: RewriteStats,
    pub flatten_stats: FlattenStats,
}

impl Compiled {
    /// Program-memory footprint in bytes (Table 10 PM column).
    pub fn pm_bytes(&self) -> u32 {
        (self.words.len() * 4) as u32
    }

    /// Data-memory footprint in bytes (Table 10 DM column).
    pub fn dm_bytes(&self) -> u32 {
        self.plan.dm_size
    }
}

/// Compile a model for a processor variant.
pub fn compile(spec: &ModelSpec, variant: Variant) -> Result<Compiled> {
    spec.validate()?;
    let plan = plan::plan(spec)?;
    let mut instrs: Vec<Instr> = Vec::new();
    let mut layer_ranges = Vec::new();
    let mut rewrite_stats = RewriteStats::default();
    let mut flatten_stats = FlattenStats::default();

    for (li, layer) in spec.layers.iter().enumerate() {
        let mut e = asm::Emit::new();
        codegen::emit_layer(&mut e, spec, &plan, li, layer)?;
        let rs = rewrite::apply(&mut e.items, &variant);
        rewrite_stats.fusedmac += rs.fusedmac;
        rewrite_stats.mac += rs.mac;
        rewrite_stats.add2i += rs.add2i;
        let start = instrs.len();
        asm::flatten(&e.items, &variant, &mut instrs, &mut flatten_stats)
            .with_context(|| format!("flatten layer {li}"))?;
        layer_ranges.push((start, instrs.len()));
    }
    instrs.push(Instr::Ecall);

    let words = instrs.iter().map(encode).collect();
    Ok(Compiled {
        variant,
        instrs,
        words,
        plan,
        layer_ranges,
        rewrite_stats,
        flatten_stats,
    })
}

/// Instantiate a simulator with the compiled program + weights loaded.
pub fn make_sim(c: &Compiled) -> Result<Sim, SimError> {
    let mut sim =
        Sim::from_instrs(c.variant, c.instrs.clone(), c.plan.dm_size as usize)?;
    sim.mem
        .write_block(c.plan.weights_base, &c.plan.weights_image)
        .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    Ok(sim)
}

/// Write an int8 input tensor into the sim's DM.
pub fn load_input(sim: &mut Sim, c: &Compiled, input: &[i32]) -> Result<()> {
    let bytes: Vec<u8> = input
        .iter()
        .map(|&v| {
            anyhow::ensure!(
                (-128..=127).contains(&v),
                "input value {v} out of int8 range"
            );
            Ok(v as i8 as u8)
        })
        .collect::<Result<_>>()?;
    sim.mem
        .write_block(c.plan.input_addr, &bytes)
        .map_err(|fault| anyhow::anyhow!("input write fault at {:#x}", fault.addr))?;
    Ok(())
}

/// Read the final logits back from DM.
pub fn read_output(sim: &Sim, c: &Compiled, n: usize) -> Result<Vec<i32>> {
    sim.mem
        .read_i8s(c.plan.output_addr, n)
        .map_err(|fault| anyhow::anyhow!("output read fault at {:#x}", fault.addr))
}

/// Compile-and-run convenience: one inference through the ISS.
pub fn execute(
    spec: &ModelSpec,
    variant: Variant,
    input: &[i32],
    max_instrs: u64,
) -> Result<(Vec<i32>, RunStats)> {
    let c = compile(spec, variant)?;
    execute_compiled(&c, spec, input, max_instrs, &mut crate::sim::NopHook)
}

/// Run one inference on an already-compiled model with a retire hook.
pub fn execute_compiled<H: RetireHook>(
    c: &Compiled,
    spec: &ModelSpec,
    input: &[i32],
    max_instrs: u64,
    hook: &mut H,
) -> Result<(Vec<i32>, RunStats)> {
    let mut sim = make_sim(c).map_err(|e| anyhow::anyhow!("{e}"))?;
    load_input(&mut sim, c, input)?;
    let stats = sim
        .run(max_instrs, hook)
        .map_err(|e| anyhow::anyhow!("simulation failed: {e}"))?;
    let out = read_output(&sim, c, spec.output_elems())?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth::{lenet_shaped, residual_net, tiny_conv_net, Builder};
    use crate::refexec;
    use crate::sim::{VARIANTS, V0, V4};
    use crate::util::rng::Rng;

    fn check_model(spec: &ModelSpec, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Builder::random_input(spec, &mut rng);
        let want = refexec::run(spec, &input).unwrap();
        for v in VARIANTS {
            let (got, _) = execute(spec, v, &input, 500_000_000)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", spec.name, v.name));
            assert_eq!(got, want, "{} on {}", spec.name, v.name);
        }
    }

    #[test]
    fn tiny_net_all_variants_match_reference() {
        check_model(&tiny_conv_net(3), 100);
    }

    #[test]
    fn lenet_shaped_all_variants_match_reference() {
        check_model(&lenet_shaped(5), 101);
    }

    #[test]
    fn residual_net_all_variants_match_reference() {
        check_model(&residual_net(7), 102);
    }

    #[test]
    fn v4_is_faster_and_smaller() {
        let spec = lenet_shaped(9);
        let mut rng = Rng::new(1);
        let input = Builder::random_input(&spec, &mut rng);
        let c0 = compile(&spec, V0).unwrap();
        let c4 = compile(&spec, V4).unwrap();
        let (_, s0) =
            execute_compiled(&c0, &spec, &input, 1 << 32, &mut crate::sim::NopHook)
                .unwrap();
        let (_, s4) =
            execute_compiled(&c4, &spec, &input, 1 << 32, &mut crate::sim::NopHook)
                .unwrap();
        assert!(
            s4.cycles * 3 < s0.cycles * 2,
            "expected >1.5x speedup: v0={} v4={}",
            s0.cycles,
            s4.cycles
        );
        assert!(c4.pm_bytes() < c0.pm_bytes());
        assert!(c4.rewrite_stats.fusedmac > 0);
        assert!(c4.flatten_stats.zol_loops > 0);
    }

    #[test]
    fn rewrites_fire_per_variant() {
        let spec = tiny_conv_net(11);
        let c0 = compile(&spec, V0).unwrap();
        assert_eq!(c0.rewrite_stats, RewriteStats::default());
        assert!(c0.instrs.iter().all(|i| !i.is_custom()));
        let c4 = compile(&spec, V4).unwrap();
        assert!(c4.rewrite_stats.fusedmac > 0);
        assert!(c4.rewrite_stats.add2i > 0);
        assert!(c4.instrs.iter().any(|i| i.is_custom()));
    }

    #[test]
    fn deterministic_compilation() {
        let spec = tiny_conv_net(13);
        let a = compile(&spec, V4).unwrap();
        let b = compile(&spec, V4).unwrap();
        assert_eq!(a.words, b.words);
    }
}
