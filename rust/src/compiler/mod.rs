//! The MARVEL compiler: model spec → planned memory → structured RV32
//! assembly → variant-specific rewrites → flat machine code.
//!
//! This module stands in for the paper's TVM → Chess pipeline (§II.A/§II.D):
//! it consumes the same model description the JAX side AOT-exports, emits
//! TVM-class loop nests ([`codegen`]), applies the `chess_rewrite`-style
//! fusion passes ([`rewrite`]) per processor variant, and lowers counted
//! loops to `blt` or zero-overhead hardware loops ([`asm::flatten`]).

pub mod asm;
pub mod codegen;
pub mod plan;
pub mod rewrite;
pub mod spec;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{ensure, Context, Result};

use crate::isa::Instr;
use crate::sim::engine::Job;
use crate::sim::{Machine, Program, RetireHook, RunStats, SimError, Variant};
use asm::FlattenStats;
use rewrite::RewriteStats;
use spec::ModelSpec;

/// A fully compiled model for one processor variant.
///
/// The instruction stream and PM image live in a shared [`Program`]: any
/// number of [`Machine`]s / batch-engine jobs execute it via a cheap `Arc`
/// handle — nothing on the per-inference path clones instructions.
pub struct Compiled {
    /// The validated, decode-once program (instructions + PM image).
    pub program: Arc<Program>,
    pub plan: plan::Plan,
    /// Prebuilt base DM image: `plan.dm_size` bytes, zeroed, with the
    /// weights image already written at `plan.weights_base`.  Built once
    /// per compilation so every run initializes memory with a single
    /// `copy_from_slice` ([`crate::sim::engine::Job::base_image`]) instead
    /// of zero-fill + block writes.
    pub base_dm: Vec<u8>,
    /// Per-layer [start, end) instruction index ranges.
    pub layer_ranges: Vec<(usize, usize)>,
    pub rewrite_stats: RewriteStats,
    pub flatten_stats: FlattenStats,
    /// Memoized wire fingerprint of `base_dm` (see [`Self::base_dm_fp`]).
    base_dm_fp: OnceLock<u64>,
}

impl Compiled {
    /// The variant this model was compiled for (authoritative copy lives
    /// in the validated [`Program`]).
    pub fn variant(&self) -> Variant {
        self.program.variant()
    }

    /// Predecoded instruction stream.
    pub fn instrs(&self) -> &[Instr] {
        self.program.instrs()
    }

    /// Encoded machine words (PM image).
    pub fn words(&self) -> &[u32] {
        self.program.words()
    }

    /// Program-memory footprint in bytes (Table 10 PM column).
    pub fn pm_bytes(&self) -> u32 {
        self.program.pm_bytes()
    }

    /// Data-memory footprint in bytes (Table 10 DM column).
    pub fn dm_bytes(&self) -> u32 {
        self.plan.dm_size
    }

    /// FNV-1a of the prebuilt base DM image — the fingerprint job
    /// descriptions carry on the wire ([`crate::sim::shard`]).  Memoized:
    /// hashed once per compilation, not per job, so per-request callers
    /// (the serve dispatcher, `PreparedFlow::specs`) pay nothing.
    pub fn base_dm_fp(&self) -> u64 {
        *self
            .base_dm_fp
            .get_or_init(|| crate::util::fnv1a(&self.base_dm))
    }
}

/// Compile a model for a processor variant.
pub fn compile(spec: &ModelSpec, variant: Variant) -> Result<Compiled> {
    spec.validate()?;
    let plan = plan::plan(spec)?;
    let mut instrs: Vec<Instr> = Vec::new();
    let mut layer_ranges = Vec::new();
    let mut rewrite_stats = RewriteStats::default();
    let mut flatten_stats = FlattenStats::default();

    for (li, layer) in spec.layers.iter().enumerate() {
        let mut e = asm::Emit::new();
        codegen::emit_layer(&mut e, spec, &plan, li, layer)?;
        let rs = rewrite::apply(&mut e.items, &variant);
        rewrite_stats.fusedmac += rs.fusedmac;
        rewrite_stats.mac += rs.mac;
        rewrite_stats.add2i += rs.add2i;
        rewrite_stats.xwin += rs.xwin;
        let start = instrs.len();
        asm::flatten(&e.items, &variant, &mut instrs, &mut flatten_stats)
            .with_context(|| format!("flatten layer {li}"))?;
        layer_ranges.push((start, instrs.len()));
    }
    instrs.push(Instr::Ecall);

    let program = Arc::new(
        Program::from_instrs(variant, instrs)
            .map_err(|e| anyhow::anyhow!("compiled program rejected: {e}"))?,
    );
    let mut base_dm = vec![0u8; plan.dm_size as usize];
    let wb = plan.weights_base as usize;
    let wend = wb + plan.weights_image.len();
    ensure!(wend <= base_dm.len(), "weights image exceeds planned DM");
    base_dm[wb..wend].copy_from_slice(&plan.weights_image);
    Ok(Compiled {
        program,
        plan,
        base_dm,
        layer_ranges,
        rewrite_stats,
        flatten_stats,
        base_dm_fp: OnceLock::new(),
    })
}

/// Differential oracle for the rewrite refactor: run the generic
/// spec-driven engine and the legacy hand-written passes side by side on
/// every layer of `spec` and require bit-identical output (identical item
/// streams imply identical flattened/encoded words — `asm::flatten` and
/// `isa::encode` are pure).  `marvel extsearch --check-legacy` and CI call
/// this on v1..v4.
pub fn check_rewrite_legacy(spec: &ModelSpec, variant: Variant) -> Result<()> {
    ensure!(
        variant.xwin == 0,
        "legacy oracle only covers ladder variants (got {})",
        variant.name
    );
    spec.validate()?;
    let plan = plan::plan(spec)?;
    for (li, layer) in spec.layers.iter().enumerate() {
        let mut e = asm::Emit::new();
        codegen::emit_layer(&mut e, spec, &plan, li, layer)?;
        let mut oracle = e.items.clone();
        let gs = rewrite::apply(&mut e.items, &variant);
        let ls = rewrite::legacy::apply_legacy(&mut oracle, &variant);
        ensure!(
            gs == ls,
            "{} layer {li} on {}: stats diverge (generic {gs:?}, legacy {ls:?})",
            spec.name,
            variant.name
        );
        ensure!(
            e.items == oracle,
            "{} layer {li} on {}: rewritten streams diverge",
            spec.name,
            variant.name
        );
    }
    Ok(())
}

/// Process-wide compile cache keyed by (model name, variant feature mask).
///
/// Sweeps — Fig 11/12, Table 10, the ablation grid, `report all` — revisit
/// the same (model, variant) pairs; the cache hands back the same
/// `Arc<Compiled>` (and therefore the same shared [`Program`]) instead of
/// recompiling.  Thread-safe: callers can share one cache across batch
/// workers.
#[derive(Default)]
pub struct CompileCache {
    map: Mutex<HashMap<String, Arc<Compiled>>>,
}

impl CompileCache {
    pub fn new() -> CompileCache {
        CompileCache::default()
    }

    /// FNV-1a over the spec's content: two specs that share a name but
    /// differ in anything that affects codegen (layer kinds, scalar params
    /// like shift/relu/stride/pad, graph wiring, weights) must not collide.
    /// The layer graph goes in via its `Debug` rendering, which covers
    /// every field; the weight payload is hashed directly.
    fn fingerprint(spec: &ModelSpec) -> u64 {
        use crate::util::{fnv1a_extend, FNV_OFFSET};
        fn eat(h: &mut u64, v: u64) {
            *h = fnv1a_extend(*h, &v.to_le_bytes());
        }
        let mut h: u64 = FNV_OFFSET;
        eat(&mut h, spec.num_classes as u64);
        for d in spec.input_shape {
            eat(&mut h, d as u64);
        }
        h = fnv1a_extend(h, format!("{:?}", spec.layers).as_bytes());
        for t in spec.tensors.values() {
            eat(&mut h, t.shape.len() as u64);
            for &d in &t.shape {
                eat(&mut h, d as u64);
            }
            eat(&mut h, t.data.len() as u64);
            for &x in &t.data {
                eat(&mut h, x as u64);
            }
        }
        h
    }


    /// Return the cached compilation or compile-and-insert.
    ///
    /// One-off convenience: fingerprints the spec on every call.  Sweeps
    /// compiling several variants of one spec should use [`Self::for_spec`]
    /// so the weight payload is hashed once.
    pub fn get_or_compile(
        &self,
        spec: &ModelSpec,
        variant: Variant,
    ) -> Result<Arc<Compiled>> {
        self.for_spec(spec).get_or_compile(variant)
    }

    /// Bind the cache to one spec, computing its content fingerprint once.
    pub fn for_spec<'c, 's>(
        &'c self,
        spec: &'s ModelSpec,
    ) -> SpecCompileCache<'c, 's> {
        SpecCompileCache { cache: self, spec, fingerprint: Self::fingerprint(spec) }
    }

    /// Number of cached compilations.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A [`CompileCache`] bound to one spec with its fingerprint precomputed —
/// the handle sweeps use to compile many variants without re-hashing the
/// weight payload per lookup.
pub struct SpecCompileCache<'c, 's> {
    cache: &'c CompileCache,
    spec: &'s ModelSpec,
    fingerprint: u64,
}

impl SpecCompileCache<'_, '_> {
    /// The full feature mask participates so custom variants (ablation
    /// cores) with reused names cannot collide — including the mined
    /// window mask, which changes the emitted code like any ladder bit.
    fn key(&self, v: &Variant) -> String {
        format!(
            "{}|{:016x}|{}|{}{}{}{}|x{:02x}",
            self.spec.name,
            self.fingerprint,
            v.name,
            v.mac as u8,
            v.add2i as u8,
            v.fusedmac as u8,
            v.zol as u8,
            v.xwin
        )
    }

    /// Return the cached compilation or compile-and-insert.
    pub fn get_or_compile(&self, variant: Variant) -> Result<Arc<Compiled>> {
        let key = self.key(&variant);
        if let Some(c) = self.cache.map.lock().unwrap().get(&key) {
            return Ok(Arc::clone(c));
        }
        // Compile outside the lock: a sweep's first pass may race to build
        // the same entry twice, but never blocks other variants behind one
        // long compilation.
        let c = Arc::new(compile(self.spec, variant)?);
        let mut map = self.cache.map.lock().unwrap();
        let entry = map.entry(key).or_insert_with(|| Arc::clone(&c));
        Ok(Arc::clone(entry))
    }
}

/// Instantiate a simulator with the compiled program + weights loaded.
/// The program is shared, not cloned; DM is one copy of the prebuilt
/// base image.
pub fn make_sim(c: &Compiled) -> Result<Machine, SimError> {
    let mut sim = Machine::new(Arc::clone(&c.program), 0);
    sim.mem
        .reset_from(&c.base_dm, c.plan.dm_size as usize)
        .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    Ok(sim)
}

/// Validate + pack an int8 input tensor into DM bytes.  Pack once per
/// input and feed the same slice to every variant's [`make_job`].
pub fn pack_input(input: &[i32]) -> Result<Vec<u8>> {
    input
        .iter()
        .map(|&v| {
            anyhow::ensure!(
                (-128..=127).contains(&v),
                "input value {v} out of int8 range"
            );
            Ok(v as i8 as u8)
        })
        .collect()
}

/// Build a batch-engine [`Job`] for one inference on a compiled model.
/// The base DM image and the packed input (see [`pack_input`]) are
/// borrowed, the program `Arc`-shared — a job costs no copies, and the
/// engine initializes DM with a single `copy_from_slice` of `base_dm`.
pub fn make_job<'a>(
    c: &'a Compiled,
    spec: &ModelSpec,
    input: &'a [u8],
    max_instrs: u64,
) -> Job<'a> {
    Job {
        program: Arc::clone(&c.program),
        dm_size: c.plan.dm_size as usize,
        base_image: Some(&c.base_dm),
        preload: Vec::new(),
        input: (c.plan.input_addr, input),
        output: (c.plan.output_addr, spec.output_elems()),
        max_instrs,
    }
}

/// Write an int8 input tensor into the sim's DM.
pub fn load_input(sim: &mut Machine, c: &Compiled, input: &[i32]) -> Result<()> {
    let bytes = pack_input(input)?;
    sim.mem
        .write_block(c.plan.input_addr, &bytes)
        .map_err(|fault| anyhow::anyhow!("input write fault at {:#x}", fault.addr))?;
    Ok(())
}

/// Read the final logits back from DM.
pub fn read_output(sim: &Machine, c: &Compiled, n: usize) -> Result<Vec<i32>> {
    sim.mem
        .read_i8s(c.plan.output_addr, n)
        .map_err(|fault| anyhow::anyhow!("output read fault at {:#x}", fault.addr))
}

/// Compile-and-run convenience: one inference through the ISS.
pub fn execute(
    spec: &ModelSpec,
    variant: Variant,
    input: &[i32],
    max_instrs: u64,
) -> Result<(Vec<i32>, RunStats)> {
    let c = compile(spec, variant)?;
    execute_compiled(&c, spec, input, max_instrs, &mut crate::sim::NopHook)
}

/// Run one inference on an already-compiled model with a retire hook.
pub fn execute_compiled<H: RetireHook>(
    c: &Compiled,
    spec: &ModelSpec,
    input: &[i32],
    max_instrs: u64,
    hook: &mut H,
) -> Result<(Vec<i32>, RunStats)> {
    let mut sim = make_sim(c).map_err(|e| anyhow::anyhow!("{e}"))?;
    load_input(&mut sim, c, input)?;
    let stats = sim
        .run(max_instrs, hook)
        .map_err(|e| anyhow::anyhow!("simulation failed: {e}"))?;
    let out = read_output(&sim, c, spec.output_elems())?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth::{lenet_shaped, residual_net, tiny_conv_net, Builder};
    use crate::refexec;
    use crate::sim::{VARIANTS, V0, V4};
    use crate::util::rng::Rng;

    fn check_model(spec: &ModelSpec, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Builder::random_input(spec, &mut rng);
        let want = refexec::run(spec, &input).unwrap();
        for v in VARIANTS {
            let (got, _) = execute(spec, v, &input, 500_000_000)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", spec.name, v.name));
            assert_eq!(got, want, "{} on {}", spec.name, v.name);
        }
    }

    #[test]
    fn tiny_net_all_variants_match_reference() {
        check_model(&tiny_conv_net(3), 100);
    }

    #[test]
    fn lenet_shaped_all_variants_match_reference() {
        check_model(&lenet_shaped(5), 101);
    }

    #[test]
    fn residual_net_all_variants_match_reference() {
        check_model(&residual_net(7), 102);
    }

    #[test]
    fn v4_is_faster_and_smaller() {
        let spec = lenet_shaped(9);
        let mut rng = Rng::new(1);
        let input = Builder::random_input(&spec, &mut rng);
        let c0 = compile(&spec, V0).unwrap();
        let c4 = compile(&spec, V4).unwrap();
        let (_, s0) =
            execute_compiled(&c0, &spec, &input, 1 << 32, &mut crate::sim::NopHook)
                .unwrap();
        let (_, s4) =
            execute_compiled(&c4, &spec, &input, 1 << 32, &mut crate::sim::NopHook)
                .unwrap();
        assert!(
            s4.cycles * 3 < s0.cycles * 2,
            "expected >1.5x speedup: v0={} v4={}",
            s0.cycles,
            s4.cycles
        );
        assert!(c4.pm_bytes() < c0.pm_bytes());
        assert!(c4.rewrite_stats.fusedmac > 0);
        assert!(c4.flatten_stats.zol_loops > 0);
    }

    #[test]
    fn rewrites_fire_per_variant() {
        let spec = tiny_conv_net(11);
        let c0 = compile(&spec, V0).unwrap();
        assert_eq!(c0.rewrite_stats, RewriteStats::default());
        assert!(c0.instrs().iter().all(|i| !i.is_custom()));
        let c4 = compile(&spec, V4).unwrap();
        assert!(c4.rewrite_stats.fusedmac > 0);
        assert!(c4.rewrite_stats.add2i > 0);
        assert!(c4.instrs().iter().any(|i| i.is_custom()));
    }

    #[test]
    fn generic_rewrite_matches_legacy_on_ladder_variants() {
        // the ISSUE's differential acceptance gate: the spec-driven engine
        // must reproduce the hand-written passes bit-identically on v0..v4
        for spec in [tiny_conv_net(3), lenet_shaped(5), residual_net(7)] {
            for v in VARIANTS {
                check_rewrite_legacy(&spec, v)
                    .unwrap_or_else(|e| panic!("{}", e));
            }
        }
    }

    #[test]
    fn window_variant_compiles_and_matches_reference() {
        let spec = tiny_conv_net(21);
        let mut rng = Rng::new(77);
        let input = Builder::random_input(&spec, &mut rng);
        let want = refexec::run(&spec, &input).unwrap();
        let full = (1u8 << crate::fusion::N_WINDOW) - 1;
        let v = Variant::with_window(V4, full).unwrap();
        let (got, sx) = execute(&spec, v, &input, 500_000_000).unwrap();
        assert_eq!(got, want, "mined fusions must preserve semantics");
        let c = compile(&spec, v).unwrap();
        assert!(c.rewrite_stats.xwin > 0, "mined fusions must fire");
        assert!(c
            .instrs()
            .iter()
            .any(|i| matches!(i, Instr::Custom { .. })));
        // strictly smaller and faster than the plain v4 ladder
        let c4 = compile(&spec, V4).unwrap();
        assert!(c.pm_bytes() < c4.pm_bytes());
        let (_, s4) =
            execute_compiled(&c4, &spec, &input, 1 << 32, &mut crate::sim::NopHook)
                .unwrap();
        assert!(
            sx.cycles < s4.cycles,
            "window variant must beat v4: {} vs {}",
            sx.cycles,
            s4.cycles
        );
    }

    #[test]
    fn cache_splits_window_variants() {
        let spec = tiny_conv_net(23);
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&spec, V4).unwrap();
        let v = Variant::with_window(V4, 1).unwrap();
        let b = cache.get_or_compile(&spec, v).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "xwin must participate in the key");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn spec_rejects_out_of_range_shift() {
        // shift >= 32 must die at validation (clean error), never reach
        // quant::round_shift's checked precondition as a panic.
        let mut spec = tiny_conv_net(19);
        if let spec::Layer::Conv2d { shift, .. } = &mut spec.layers[0] {
            *shift = 32;
        } else {
            panic!("tiny_conv_net layer 0 should be conv");
        }
        let e = compile(&spec, V0).unwrap_err().to_string();
        assert!(e.contains("requant shift 32 out of range"), "{e}");
    }

    #[test]
    fn deterministic_compilation() {
        let spec = tiny_conv_net(13);
        let a = compile(&spec, V4).unwrap();
        let b = compile(&spec, V4).unwrap();
        assert_eq!(a.words(), b.words());
    }

    #[test]
    fn compile_cache_shares_programs() {
        let spec = tiny_conv_net(17);
        let cache = CompileCache::new();
        let a = cache.get_or_compile(&spec, V4).unwrap();
        let b = cache.get_or_compile(&spec, V4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same (model, variant) must hit");
        let c0 = cache.get_or_compile(&spec, V0).unwrap();
        assert!(!Arc::ptr_eq(&a, &c0));
        assert_eq!(cache.len(), 2);
        // same name, different seed (different weights) must not collide
        let other = tiny_conv_net(18);
        let d = cache.get_or_compile(&other, V4).unwrap();
        assert!(!Arc::ptr_eq(&a, &d), "content fingerprint must split key");
        assert_eq!(cache.len(), 3);
        // the cached program is the one the sims execute — no copies
        let sim = make_sim(&a).unwrap();
        assert!(Arc::ptr_eq(sim.program(), &a.program));
    }
}
