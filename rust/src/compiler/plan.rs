//! Data-memory planner: lays out input, weights, activations and padding
//! scratch in the core's DM (Table 10's "Data Memory" column).
//!
//! Weights and the model input are pinned; activation buffers are allocated
//! with liveness-based reuse (a buffer dies after its last consumer), which
//! is what keeps e.g. DenseNet's concat chains from exploding the footprint.
//! Conv/dw layers with `pad > 0` additionally get a scratch buffer holding
//! the zero-padded input for the duration of that layer only (the generated
//! code pad-copies into it, like TVM's pad stage).

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use super::spec::{Dtype, Layer, ModelSpec};

/// Word alignment for every allocation (the BRAM interface is 32-bit).
const ALIGN: u32 = 4;

fn align(v: u32) -> u32 {
    v.div_ceil(ALIGN) * ALIGN
}

/// The complete DM layout for one compiled model.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Total data memory needed (bytes) — the Table 10 DM number.
    pub dm_size: u32,
    /// Model input tensor (int8 bytes, CHW).
    pub input_addr: u32,
    /// Final layer output.
    pub output_addr: u32,
    /// Per-weight-tensor base address.
    pub weight_addr: BTreeMap<String, u32>,
    /// Per-layer output buffer base address.
    pub layer_out_addr: Vec<u32>,
    /// Per-layer padded-input scratch (conv/dw with pad > 0).
    pub scratch_addr: Vec<Option<u32>>,
    /// Bytes of weights (for reports).
    pub weights_bytes: u32,
    /// Peak activation bytes (for reports).
    pub act_bytes: u32,
    /// The initial DM image (weights only; input is injected at run time).
    pub weights_image: Vec<u8>,
    /// Offset where the weights image starts.
    pub weights_base: u32,
}

/// Simple first-fit free-list allocator over a growing arena.
struct Arena {
    /// (addr, len) free blocks, sorted by addr.
    free: Vec<(u32, u32)>,
    base: u32,
    top: u32,
}

impl Arena {
    fn new(base: u32) -> Self {
        Arena { free: Vec::new(), base, top: base }
    }

    fn alloc(&mut self, size: u32) -> u32 {
        let size = align(size.max(1));
        // best-fit over the free list to curb fragmentation
        let mut best: Option<usize> = None;
        for (i, &(_, len)) in self.free.iter().enumerate() {
            if len >= size && best.is_none_or(|b| self.free[b].1 > len) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            let (addr, len) = self.free[i];
            if len == size {
                self.free.remove(i);
            } else {
                self.free[i] = (addr + size, len - size);
            }
            return addr;
        }
        let addr = self.top;
        self.top += size;
        addr
    }

    fn free(&mut self, addr: u32, size: u32) {
        let size = align(size.max(1));
        // insert sorted + coalesce neighbours
        let pos = self.free.partition_point(|&(a, _)| a < addr);
        self.free.insert(pos, (addr, size));
        let mut i = pos.saturating_sub(1);
        while i + 1 < self.free.len() {
            let (a0, l0) = self.free[i];
            let (a1, l1) = self.free[i + 1];
            if a0 + l0 == a1 {
                self.free[i] = (a0, l0 + l1);
                self.free.remove(i + 1);
            } else {
                i += 1;
            }
        }
    }

    fn peak(&self) -> u32 {
        self.top - self.base
    }
}

/// Padded input scratch size (bytes) for a conv/dw layer, if any.
pub fn scratch_bytes(layer: &Layer) -> Option<u32> {
    match layer {
        Layer::Conv2d { pad, in_shape, .. }
        | Layer::DwConv2d { pad, in_shape, .. }
            if *pad > 0 =>
        {
            let [c, h, w] = *in_shape;
            Some((c * (h + 2 * pad) * (w + 2 * pad)) as u32)
        }
        _ => None,
    }
}

/// Build the memory plan for a model.
pub fn plan(spec: &ModelSpec) -> Result<Plan> {
    // --- pinned regions: input, then weights ---
    let input_addr = 0u32;
    let mut cursor = align(spec.input_elems() as u32);

    let weights_base = cursor;
    let mut weight_addr = BTreeMap::new();
    let mut image: Vec<u8> = Vec::new();
    for (name, t) in &spec.tensors {
        // keep `image` aligned with the running cursor
        while (cursor + image.len() as u32) % ALIGN != 0 {
            image.push(0);
        }
        let addr = weights_base + image.len() as u32;
        weight_addr.insert(name.clone(), addr);
        match t.dtype {
            Dtype::I8 => {
                for &v in &t.data {
                    ensure!(
                        (-128..=127).contains(&v),
                        "tensor {name}: {v} out of int8 range"
                    );
                    image.push(v as i8 as u8);
                }
            }
            Dtype::I32 => {
                for &v in &t.data {
                    image.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    cursor = align(weights_base + image.len() as u32);
    let weights_bytes = cursor - weights_base;

    // --- activation arena with liveness ---
    // last consumer index per layer output (the final layer lives forever)
    let n = spec.layers.len();
    ensure!(n > 0, "model has no layers");
    let mut last_use = vec![0usize; n];
    for (li, layer) in spec.layers.iter().enumerate() {
        for i in layer.inputs() {
            if i >= 0 {
                last_use[i as usize] = li;
            }
        }
    }
    last_use[n - 1] = usize::MAX;

    let mut arena = Arena::new(cursor);
    let mut layer_out_addr = vec![0u32; n];
    let mut scratch_addr = vec![None; n];
    // (addr, size, dies_at)
    let mut live: Vec<(u32, u32, usize)> = Vec::new();

    for (li, layer) in spec.layers.iter().enumerate() {
        // scratch for this layer (lives only during the layer itself)
        let scratch = scratch_bytes(layer).map(|sz| {
            let a = arena.alloc(sz);
            (a, sz)
        });
        scratch_addr[li] = scratch.map(|(a, _)| a);

        // output buffer
        let out_sz = layer.out_elems() as u32;
        let addr = arena.alloc(out_sz);
        layer_out_addr[li] = addr;
        live.push((addr, out_sz, last_use[li]));

        // release the scratch now that the layer "ran"
        if let Some((a, sz)) = scratch {
            arena.free(a, sz);
        }
        // release buffers whose last consumer was this layer
        live.retain(|&(a, sz, dies)| {
            if dies == li {
                arena.free(a, sz);
                false
            } else {
                true
            }
        });
    }

    let act_bytes = arena.peak();
    let output_addr = layer_out_addr[n - 1];
    let dm_size = align(arena.top).max(64);

    Ok(Plan {
        dm_size,
        input_addr,
        output_addr,
        weight_addr,
        layer_out_addr,
        scratch_addr,
        weights_bytes,
        act_bytes,
        weights_image: image,
        weights_base,
    })
}

impl Plan {
    /// Address of a weight tensor.
    pub fn weight(&self, name: &str) -> Result<u32> {
        self.weight_addr
            .get(name)
            .copied()
            .with_context(|| format!("unplanned tensor {name:?}"))
    }

    /// Address of a layer input (-1 = model input).
    pub fn src_addr(&self, idx: i32) -> u32 {
        if idx == -1 {
            self.input_addr
        } else {
            self.layer_out_addr[idx as usize]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth::tiny_conv_net;

    #[test]
    fn arena_reuses_freed_blocks() {
        let mut a = Arena::new(0);
        let x = a.alloc(100);
        let y = a.alloc(50);
        a.free(x, 100);
        let z = a.alloc(60); // fits in the freed 100-block
        assert_eq!(z, x);
        assert!(y > 0);
    }

    #[test]
    fn arena_coalesces() {
        let mut a = Arena::new(0);
        let x = a.alloc(64);
        let y = a.alloc(64);
        let _z = a.alloc(64);
        a.free(x, 64);
        a.free(y, 64);
        // coalesced 128 bytes at the front
        assert_eq!(a.alloc(128), 0);
    }

    #[test]
    fn plan_basics() {
        let spec = tiny_conv_net(42);
        let p = plan(&spec).unwrap();
        assert_eq!(p.input_addr, 0);
        assert!(p.weights_base >= spec.input_elems() as u32);
        assert_eq!(p.layer_out_addr.len(), spec.layers.len());
        assert!(p.dm_size >= p.weights_base + p.weights_bytes);
        // all weight addrs aligned & inside the weights region
        for (_, &a) in &p.weight_addr {
            assert_eq!(a % 4, 0);
            assert!(a >= p.weights_base && a < p.weights_base + p.weights_bytes);
        }
    }

    #[test]
    fn no_live_overlap() {
        // Buffers that are simultaneously live must not overlap.
        let spec = tiny_conv_net(7);
        let p = plan(&spec).unwrap();
        let n = spec.layers.len();
        let mut last_use = vec![0usize; n];
        for (li, layer) in spec.layers.iter().enumerate() {
            for i in layer.inputs() {
                if i >= 0 {
                    last_use[i as usize] = li;
                }
            }
        }
        last_use[n - 1] = usize::MAX;
        for i in 0..n {
            for j in (i + 1)..n {
                // j's buffer is created at j; i's is live until last_use[i]
                if last_use[i] >= j {
                    let (a0, s0) = (p.layer_out_addr[i],
                                    spec.layers[i].out_elems() as u32);
                    let (a1, s1) = (p.layer_out_addr[j],
                                    spec.layers[j].out_elems() as u32);
                    assert!(
                        a0 + s0 <= a1 || a1 + s1 <= a0,
                        "layers {i} and {j} overlap: {a0}+{s0} vs {a1}+{s1}"
                    );
                }
            }
        }
    }
}
