//! The original hand-written ladder passes, kept verbatim as the
//! differential oracle for the generic spec-driven engine (DESIGN.md §17).
//!
//! [`apply_legacy`] must stay bit-identical to [`super::apply`] on every
//! ladder variant (`xwin == 0`): `marvel extsearch --check-legacy` and the
//! rewrite differential tests compare the two pass-for-pass.  Do not
//! refactor these passes to share code with the generic engine — an oracle
//! that shares its subject's bugs checks nothing.

use crate::compiler::asm::Item;
use crate::isa::Instr;
use crate::sim::Variant;

use super::patterns::{match_addi_pair, match_mul_acc};
use super::{op_at, RewriteStats};

/// Apply the legacy hand-written ladder passes (in place).  Ignores
/// `variant.xwin`: the legacy engine predates the mined window, which is
/// exactly why it can serve as the ladder oracle.
pub fn apply_legacy(items: &mut Vec<Item>, variant: &Variant) -> RewriteStats {
    let mut stats = RewriteStats::default();
    rewrite_vec(items, variant, &mut stats);
    stats
}

fn rewrite_vec(items: &mut Vec<Item>, variant: &Variant, stats: &mut RewriteStats) {
    // recurse into loop bodies first
    for item in items.iter_mut() {
        if let Item::Loop { body, .. } = item {
            rewrite_vec(body, variant, stats);
        }
    }
    if variant.fusedmac {
        pass_fusedmac(items, stats);
    }
    if variant.mac {
        pass_mac(items, stats);
    }
    if variant.add2i {
        pass_add2i(items, stats);
    }
}

/// v3: the 4-instruction conv inner-loop pattern.
fn pass_fusedmac(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b), Some(c), Some(d)) = (
            op_at(items, i),
            op_at(items, i + 1),
            op_at(items, i + 2),
            op_at(items, i + 3),
        ) {
            if match_mul_acc(a, b) {
                if let Some((rs1, rs2, i1, i2)) = match_addi_pair(c, d) {
                    out.push(Item::Op(Instr::FusedMac { rs1, rs2, i1, i2 }));
                    stats.fusedmac += 1;
                    i += 4;
                    continue;
                }
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

/// v1: mul+add accumulate on the fixed registers.
fn pass_mac(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b)) = (op_at(items, i), op_at(items, i + 1)) {
            if match_mul_acc(a, b) {
                out.push(Item::Op(Instr::Mac));
                stats.mac += 1;
                i += 2;
                continue;
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

/// v2: two consecutive in-place addi to distinct registers.
fn pass_add2i(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b)) = (op_at(items, i), op_at(items, i + 1)) {
            if let Some((rs1, rs2, i1, i2)) = match_addi_pair(a, b) {
                out.push(Item::Op(Instr::Add2i { rs1, rs2, i1, i2 }));
                stats.add2i += 1;
                i += 2;
                continue;
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}
