//! The Chess-compiler analogue: peephole rewrite passes that replace
//! baseline instruction groups with the custom instructions (paper §II.D,
//! Listing 4's `chess_rewrite` rules).
//!
//! Each pass walks every straight-line window of the structured assembly
//! (recursing into loop bodies — patterns never straddle a loop boundary)
//! and fuses:
//!
//! * [`fusedmac`]: `mul x23,x21,x22; add x20,x20,x23; addi rA,rA,i1;
//!   addi rB,rB,i2` → `fusedmac rA,rB,i1,i2` (v3+),
//! * [`mac`]: `mul x23,x21,x22; add x20,x20,x23` → `mac` (v1+),
//! * [`add2i`]: `addi rA,rA,i1; addi rB,rB,i2` → `add2i rA,rB,i1,i2` (v2+),
//!
//! under the same constraints the hardware imposes: the fixed x20/x21/x22
//! MAC registers, in-place `addi` (rd == rs1), distinct target registers,
//! and the 5/10-bit immediate split of Fig 4 (commuting the two `addi`s —
//! which are independent by the rA ≠ rB check — when only the swapped order
//! fits).  Passes run in fusion-size order so the quad wins over the pairs.

pub mod patterns;

use crate::compiler::asm::Item;
use crate::isa::Instr;
use crate::sim::Variant;
use patterns::{match_addi_pair, match_mul_acc};

/// Fusion counts (static, i.e. rewrite sites — the dynamic counts come from
/// the profiler).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RewriteStats {
    pub fusedmac: u64,
    pub mac: u64,
    pub add2i: u64,
}

/// Apply all rewrite passes enabled by `variant` (in place).
pub fn apply(items: &mut Vec<Item>, variant: &Variant) -> RewriteStats {
    let mut stats = RewriteStats::default();
    rewrite_vec(items, variant, &mut stats);
    stats
}

fn rewrite_vec(items: &mut Vec<Item>, variant: &Variant, stats: &mut RewriteStats) {
    // recurse into loop bodies first
    for item in items.iter_mut() {
        if let Item::Loop { body, .. } = item {
            rewrite_vec(body, variant, stats);
        }
    }
    if variant.fusedmac {
        pass_fusedmac(items, stats);
    }
    if variant.mac {
        pass_mac(items, stats);
    }
    if variant.add2i {
        pass_add2i(items, stats);
    }
}

fn op_at(items: &[Item], i: usize) -> Option<&Instr> {
    match items.get(i) {
        Some(Item::Op(instr)) => Some(instr),
        _ => None,
    }
}

/// v3: the 4-instruction conv inner-loop pattern.
fn pass_fusedmac(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b), Some(c), Some(d)) = (
            op_at(items, i),
            op_at(items, i + 1),
            op_at(items, i + 2),
            op_at(items, i + 3),
        ) {
            if match_mul_acc(a, b) {
                if let Some((rs1, rs2, i1, i2)) = match_addi_pair(c, d) {
                    out.push(Item::Op(Instr::FusedMac { rs1, rs2, i1, i2 }));
                    stats.fusedmac += 1;
                    i += 4;
                    continue;
                }
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

/// v1: mul+add accumulate on the fixed registers.
fn pass_mac(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b)) = (op_at(items, i), op_at(items, i + 1)) {
            if match_mul_acc(a, b) {
                out.push(Item::Op(Instr::Mac));
                stats.mac += 1;
                i += 2;
                continue;
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

/// v2: two consecutive in-place addi to distinct registers.
fn pass_add2i(items: &mut Vec<Item>, stats: &mut RewriteStats) {
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        if let (Some(a), Some(b)) = (op_at(items, i), op_at(items, i + 1)) {
            if let Some((rs1, rs2, i1, i2)) = match_addi_pair(a, b) {
                out.push(Item::Op(Instr::Add2i { rs1, rs2, i1, i2 }));
                stats.add2i += 1;
                i += 2;
                continue;
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::asm::{ACC, OPA, OPB, SCR};
    use crate::isa::{AluImmOp, AluOp};
    use crate::sim::{V1, V2, V3};

    fn mul_scr() -> Item {
        Item::Op(Instr::Op { op: AluOp::Mul, rd: SCR, rs1: OPA, rs2: OPB })
    }
    fn acc_add() -> Item {
        Item::Op(Instr::Op { op: AluOp::Add, rd: ACC, rs1: ACC, rs2: SCR })
    }
    fn addi(rd: u8, rs1: u8, imm: i32) -> Item {
        Item::Op(Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm })
    }

    #[test]
    fn mac_pair_fused_on_v1() {
        let mut items = vec![mul_scr(), acc_add()];
        let st = apply(&mut items, &V1);
        assert_eq!(st.mac, 1);
        assert_eq!(items, vec![Item::Op(Instr::Mac)]);
    }

    #[test]
    fn mac_requires_fixed_registers() {
        // mul into a different scratch or accumulate into non-x20: no fuse
        let mut items = vec![
            Item::Op(Instr::Op { op: AluOp::Mul, rd: 12, rs1: OPA, rs2: OPB }),
            acc_add(),
        ];
        assert_eq!(apply(&mut items, &V1).mac, 0);
        let mut items = vec![
            mul_scr(),
            Item::Op(Instr::Op { op: AluOp::Add, rd: 11, rs1: 11, rs2: SCR }),
        ];
        assert_eq!(apply(&mut items, &V1).mac, 0);
    }

    #[test]
    fn add2i_fuses_in_range_pairs() {
        let mut items = vec![addi(10, 10, 1), addi(11, 11, 600)];
        let st = apply(&mut items, &V2);
        assert_eq!(st.add2i, 1);
        assert_eq!(
            items,
            vec![Item::Op(Instr::Add2i { rs1: 10, rs2: 11, i1: 1, i2: 600 })]
        );
    }

    #[test]
    fn add2i_commutes_when_only_swap_fits() {
        // first imm 600 (too big for i1), second 3: swapped order fits
        let mut items = vec![addi(10, 10, 600), addi(11, 11, 3)];
        let st = apply(&mut items, &V2);
        assert_eq!(st.add2i, 1);
        assert_eq!(
            items,
            vec![Item::Op(Instr::Add2i { rs1: 11, rs2: 10, i1: 3, i2: 600 })]
        );
    }

    #[test]
    fn add2i_rejects_bad_pairs() {
        // same register: not independent
        let mut items = vec![addi(10, 10, 1), addi(10, 10, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // not in-place (rd != rs1, a move)
        let mut items = vec![addi(10, 12, 1), addi(11, 11, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // negative immediate (loop counter decrement)
        let mut items = vec![addi(10, 10, -1), addi(11, 11, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // both too large for the 5-bit slot
        let mut items = vec![addi(10, 10, 600), addi(11, 11, 700)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
    }

    #[test]
    fn fusedmac_wins_over_parts_on_v3() {
        let mut items = vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V3);
        assert_eq!((st.fusedmac, st.mac, st.add2i), (1, 0, 0));
        assert_eq!(
            items,
            vec![Item::Op(Instr::FusedMac { rs1: 10, rs2: 11, i1: 1, i2: 1 })]
        );
    }

    #[test]
    fn v2_gets_mac_plus_add2i_for_same_window() {
        let mut items = vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V2);
        assert_eq!((st.fusedmac, st.mac, st.add2i), (0, 1, 1));
        assert_eq!(items.len(), 2);
    }

    #[test]
    fn fusedmac_addi_on_mac_registers_rejected() {
        // pointer bumps touching the MAC datapath registers can't fuse
        let mut items = vec![mul_scr(), acc_add(), addi(ACC, ACC, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V3);
        assert_eq!(st.fusedmac, 0);
        assert_eq!(st.mac, 1); // the pair still fuses
    }

    #[test]
    fn rewrites_recurse_into_loops() {
        let mut items = vec![Item::Loop {
            n: 5,
            body: vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)],
        }];
        let st = apply(&mut items, &V3);
        assert_eq!(st.fusedmac, 1);
    }

    #[test]
    fn clamp_items_break_windows() {
        let mut items = vec![
            mul_scr(),
            Item::ClampAbove { reg: ACC, bound: 24 },
            acc_add(),
        ];
        let st = apply(&mut items, &V3);
        assert_eq!(st.mac + st.fusedmac, 0);
    }
}
