//! The Chess-compiler analogue: peephole rewrite passes that replace
//! baseline instruction groups with the custom instructions (paper §II.D,
//! Listing 4's `chess_rewrite` rules).
//!
//! The engine is *spec-driven* (DESIGN.md §17): every fusable instruction —
//! the paper's ladder and the mined window slots alike — is described by a
//! [`FusionSpec`], and one generic pass ([`pass_spec`]) walks every
//! straight-line window of the structured assembly (recursing into loop
//! bodies — patterns never straddle a loop boundary), replacing each match
//! with the spec's emitted instruction via [`crate::fusion::try_match`].
//!
//! Passes run in fusion-size order so the quad wins over the pairs
//! (`fusedmac`, then `mac`, then `add2i`), followed by the window specs
//! enabled by [`Variant::xwin`] — window patterns match *post-ladder* code
//! (they end in the ladder's fused forms), so they must run last.
//!
//! The constraints are the ones the hardware imposes: the fixed x20/x21/x22
//! MAC registers, in-place `addi` (rd == rs1), distinct target registers,
//! and the 5/10-bit immediate split of Fig 4 (commuting the two `addi`s —
//! which are independent by the rA ≠ rB check — when only the swapped order
//! fits).  The original hand-written passes survive verbatim in [`legacy`]
//! as the differential oracle.

pub mod legacy;
pub mod patterns;

use crate::compiler::asm::Item;
use crate::fusion::{self, FusedEmit, FusionSpec};
use crate::isa::Instr;
use crate::sim::Variant;

/// Fusion counts (static, i.e. rewrite sites — the dynamic counts come from
/// the profiler).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RewriteStats {
    pub fusedmac: u64,
    pub mac: u64,
    pub add2i: u64,
    /// Mined window fusions (all slots combined).
    pub xwin: u64,
}

/// Apply all rewrite passes enabled by `variant` (in place).
pub fn apply(items: &mut Vec<Item>, variant: &Variant) -> RewriteStats {
    let mut stats = RewriteStats::default();
    rewrite_vec(items, variant, &mut stats);
    stats
}

fn rewrite_vec(items: &mut Vec<Item>, variant: &Variant, stats: &mut RewriteStats) {
    // recurse into loop bodies first
    for item in items.iter_mut() {
        if let Item::Loop { body, .. } = item {
            rewrite_vec(body, variant, stats);
        }
    }
    if variant.fusedmac {
        pass_spec(items, &fusion::FUSEDMAC, stats);
    }
    if variant.mac {
        pass_spec(items, &fusion::MAC, stats);
    }
    if variant.add2i {
        pass_spec(items, &fusion::ADD2I, stats);
    }
    // window specs consume the ladder's fused forms, so they run last
    for spec in fusion::mask_specs(variant.xwin) {
        pass_spec(items, spec, stats);
    }
}

pub(crate) fn op_at(items: &[Item], i: usize) -> Option<&Instr> {
    match items.get(i) {
        Some(Item::Op(instr)) => Some(instr),
        _ => None,
    }
}

/// Longest pattern in the spec pool (the ladder's fusedmac quad).
const MAX_PATTERN: usize = 4;

/// One generic peephole pass: scan for `spec.pattern`-shaped straight-line
/// windows and replace each match with the spec's fused instruction.  The
/// scan discipline is exactly the legacy passes': advance by the pattern
/// length on a match, by one item otherwise, never re-scanning emitted
/// fusions.
fn pass_spec(items: &mut Vec<Item>, spec: &FusionSpec, stats: &mut RewriteStats) {
    let plen = spec.pattern.len();
    debug_assert!(plen <= MAX_PATTERN, "{}", spec.name);
    let mut out: Vec<Item> = Vec::with_capacity(items.len());
    let mut i = 0;
    while i < items.len() {
        let mut window = [Instr::Ecall; MAX_PATTERN];
        let mut n = 0;
        while n < plen {
            match op_at(items, i + n) {
                Some(instr) => {
                    window[n] = *instr;
                    n += 1;
                }
                None => break,
            }
        }
        if n == plen {
            if let Some(fused) = fusion::try_match(spec, &window[..plen]) {
                out.push(Item::Op(fused));
                match spec.emit {
                    FusedEmit::Mac => stats.mac += 1,
                    FusedEmit::Add2i => stats.add2i += 1,
                    FusedEmit::FusedMac => stats.fusedmac += 1,
                    FusedEmit::Custom(_) => stats.xwin += 1,
                }
                i += plen;
                continue;
            }
        }
        out.push(items[i].clone());
        i += 1;
    }
    *items = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::asm::{ACC, OPA, OPB, SCR};
    use crate::isa::{AluImmOp, AluOp, LoadOp};
    use crate::sim::{V1, V2, V3, V4};
    use crate::util::rng::Rng;

    fn mul_scr() -> Item {
        Item::Op(Instr::Op { op: AluOp::Mul, rd: SCR, rs1: OPA, rs2: OPB })
    }
    fn acc_add() -> Item {
        Item::Op(Instr::Op { op: AluOp::Add, rd: ACC, rs1: ACC, rs2: SCR })
    }
    fn addi(rd: u8, rs1: u8, imm: i32) -> Item {
        Item::Op(Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm })
    }
    fn lb(rd: u8, rp: u8) -> Item {
        Item::Op(Instr::Load { op: LoadOp::Lb, rd, rs1: rp, offset: 0 })
    }

    #[test]
    fn mac_pair_fused_on_v1() {
        let mut items = vec![mul_scr(), acc_add()];
        let st = apply(&mut items, &V1);
        assert_eq!(st.mac, 1);
        assert_eq!(items, vec![Item::Op(Instr::Mac)]);
    }

    #[test]
    fn mac_requires_fixed_registers() {
        // mul into a different scratch or accumulate into non-x20: no fuse
        let mut items = vec![
            Item::Op(Instr::Op { op: AluOp::Mul, rd: 12, rs1: OPA, rs2: OPB }),
            acc_add(),
        ];
        assert_eq!(apply(&mut items, &V1).mac, 0);
        let mut items = vec![
            mul_scr(),
            Item::Op(Instr::Op { op: AluOp::Add, rd: 11, rs1: 11, rs2: SCR }),
        ];
        assert_eq!(apply(&mut items, &V1).mac, 0);
    }

    #[test]
    fn add2i_fuses_in_range_pairs() {
        let mut items = vec![addi(10, 10, 1), addi(11, 11, 600)];
        let st = apply(&mut items, &V2);
        assert_eq!(st.add2i, 1);
        assert_eq!(
            items,
            vec![Item::Op(Instr::Add2i { rs1: 10, rs2: 11, i1: 1, i2: 600 })]
        );
    }

    #[test]
    fn add2i_commutes_when_only_swap_fits() {
        // first imm 600 (too big for i1), second 3: swapped order fits
        let mut items = vec![addi(10, 10, 600), addi(11, 11, 3)];
        let st = apply(&mut items, &V2);
        assert_eq!(st.add2i, 1);
        assert_eq!(
            items,
            vec![Item::Op(Instr::Add2i { rs1: 11, rs2: 10, i1: 3, i2: 600 })]
        );
    }

    #[test]
    fn add2i_rejects_bad_pairs() {
        // same register: not independent
        let mut items = vec![addi(10, 10, 1), addi(10, 10, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // not in-place (rd != rs1, a move)
        let mut items = vec![addi(10, 12, 1), addi(11, 11, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // negative immediate (loop counter decrement)
        let mut items = vec![addi(10, 10, -1), addi(11, 11, 2)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
        // both too large for the 5-bit slot
        let mut items = vec![addi(10, 10, 600), addi(11, 11, 700)];
        assert_eq!(apply(&mut items, &V2).add2i, 0);
    }

    #[test]
    fn fusedmac_wins_over_parts_on_v3() {
        let mut items = vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V3);
        assert_eq!((st.fusedmac, st.mac, st.add2i), (1, 0, 0));
        assert_eq!(
            items,
            vec![Item::Op(Instr::FusedMac { rs1: 10, rs2: 11, i1: 1, i2: 1 })]
        );
    }

    #[test]
    fn v2_gets_mac_plus_add2i_for_same_window() {
        let mut items = vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V2);
        assert_eq!((st.fusedmac, st.mac, st.add2i), (0, 1, 1));
        assert_eq!(items.len(), 2);
    }

    #[test]
    fn fusedmac_addi_on_mac_registers_rejected() {
        // pointer bumps touching the MAC datapath registers can't fuse
        let mut items = vec![mul_scr(), acc_add(), addi(ACC, ACC, 1), addi(11, 11, 1)];
        let st = apply(&mut items, &V3);
        assert_eq!(st.fusedmac, 0);
        assert_eq!(st.mac, 1); // the pair still fuses
    }

    #[test]
    fn rewrites_recurse_into_loops() {
        let mut items = vec![Item::Loop {
            n: 5,
            body: vec![mul_scr(), acc_add(), addi(10, 10, 1), addi(11, 11, 1)],
        }];
        let st = apply(&mut items, &V3);
        assert_eq!(st.fusedmac, 1);
    }

    #[test]
    fn clamp_items_break_windows() {
        let mut items = vec![
            mul_scr(),
            Item::ClampAbove { reg: ACC, bound: 24 },
            acc_add(),
        ];
        let st = apply(&mut items, &V3);
        assert_eq!(st.mac + st.fusedmac, 0);
    }

    #[test]
    fn window_spec_fuses_conv_inner_loop_on_v4() {
        // the v4 steady state: lb; lb; (mul; add; addi; addi → fusedmac),
        // then the enabled ldmacpp slot folds the loads in
        let body = || vec![lb(OPA, 10), lb(OPB, 11), mul_scr(), acc_add(),
                           addi(10, 10, 1), addi(11, 11, 1)];
        let v = Variant::with_window(V4, 0b10).unwrap();
        let mut items = body();
        let st = apply(&mut items, &v);
        assert_eq!((st.fusedmac, st.xwin), (1, 1));
        assert_eq!(
            items,
            vec![Item::Op(Instr::Custom { idx: 1, rs1: 10, rs2: 11, i1: 1, i2: 1 })]
        );
        // without the slot enabled the ladder result is untouched
        let mut plain = body();
        let st = apply(&mut plain, &V4);
        assert_eq!((st.fusedmac, st.xwin), (1, 0));
        assert_eq!(
            plain,
            vec![
                lb(OPA, 10),
                lb(OPB, 11),
                Item::Op(Instr::FusedMac { rs1: 10, rs2: 11, i1: 1, i2: 1 })
            ]
        );
    }

    #[test]
    fn ldmac_fuses_bare_mac_window() {
        // a mac whose addi pair didn't fuse (clamp in between) still gets
        // its loads folded by slot 0
        let v = Variant::with_window(V4, 0b01).unwrap();
        let mut items = vec![
            lb(OPA, 12),
            lb(OPB, 13),
            mul_scr(),
            acc_add(),
            Item::ClampAbove { reg: ACC, bound: 24 },
            addi(12, 12, 1),
        ];
        let st = apply(&mut items, &v);
        assert_eq!((st.mac, st.xwin), (1, 1));
        assert_eq!(
            items[0],
            Item::Op(Instr::Custom { idx: 0, rs1: 12, rs2: 13, i1: 0, i2: 0 })
        );
        assert_eq!(items.len(), 3);
    }

    /// Random structured-assembly streams built from the vocabulary the
    /// codegen actually emits (plus near-miss junk), for the differential
    /// oracle below.
    fn random_items(rng: &mut Rng, depth: usize) -> Vec<Item> {
        let n = rng.range_usize(4, 32);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            match rng.int_in(0, 11) {
                0 | 1 => v.push(mul_scr()),
                2 | 3 => v.push(acc_add()),
                4..=6 => {
                    let r = rng.int_in(1, 31) as u8;
                    v.push(addi(r, r, rng.int_in(-4, 1200)));
                }
                7 => {
                    // non-in-place addi (a move): must never fuse
                    v.push(addi(
                        rng.int_in(1, 31) as u8,
                        rng.int_in(0, 31) as u8,
                        rng.int_in(0, 40),
                    ));
                }
                8 => v.push(lb(
                    *rng.choice(&[OPA, OPB, 9]),
                    rng.int_in(1, 31) as u8,
                )),
                9 if depth > 0 => v.push(Item::Loop {
                    n: 2,
                    body: random_items(rng, depth - 1),
                }),
                10 => v.push(Item::ClampAbove { reg: ACC, bound: 24 }),
                _ => v.push(Item::Op(Instr::Mac)),
            }
        }
        v
    }

    #[test]
    fn generic_engine_matches_legacy_oracle_bit_for_bit() {
        let mut rng = Rng::new(0xE5E5);
        for case in 0..400 {
            let items = random_items(&mut rng, 2);
            for v in [V1, V2, V3, V4] {
                let mut generic = items.clone();
                let mut oracle = items.clone();
                let gs = apply(&mut generic, &v);
                let ls = legacy::apply_legacy(&mut oracle, &v);
                assert_eq!(gs, ls, "case {case} stats on {}", v.name);
                assert_eq!(generic, oracle, "case {case} items on {}", v.name);
            }
        }
    }
}
