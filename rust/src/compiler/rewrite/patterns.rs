//! Pattern matchers shared by the rewrite passes (compile time) and the
//! profiler (run time, Fig 3) — one definition of "what counts as a
//! mac / add2i / fusedmac opportunity" for both sides of the flow.

use crate::compiler::asm::{ACC, OPA, OPB, SCR};
use crate::isa::{AluImmOp, AluOp, Instr, Reg};

/// `mul x23, x21, x22` followed by `add x20, x20, x23` — the mac pattern
/// with the paper's fixed-register constraint.
pub fn match_mul_acc(a: &Instr, b: &Instr) -> bool {
    matches!(a, Instr::Op { op: AluOp::Mul, rd, rs1, rs2 }
        if *rd == SCR && *rs1 == OPA && *rs2 == OPB)
        && matches!(b, Instr::Op { op: AluOp::Add, rd, rs1, rs2 }
        if *rd == ACC && *rs1 == ACC && *rs2 == SCR)
}

/// Any `mul` followed by an `add` accumulating its result (register-free
/// variant used by the *profiler*, which counts opportunities before the
/// register convention is imposed — the paper's `mul_add_count`).
pub fn match_mul_add_loose(a: &Instr, b: &Instr) -> bool {
    if let Instr::Op { op: AluOp::Mul, rd: mrd, .. } = a {
        if let Instr::Op { op: AluOp::Add, rd, rs1, rs2 } = b {
            return (rs1 == mrd || rs2 == mrd) && (rd == rs1 || rd == rs2);
        }
    }
    false
}

/// Two consecutive in-place `addi`s to distinct registers whose immediates
/// fit the 5/10-bit split (commuting if needed).  Returns the add2i operand
/// assignment `(rs1, rs2, i1, i2)`.
pub fn match_addi_pair(a: &Instr, b: &Instr) -> Option<(Reg, Reg, u8, u16)> {
    let (ra, ia) = match_inplace_addi(a)?;
    let (rb, ib) = match_inplace_addi(b)?;
    if ra == rb {
        return None; // not independent: cannot commute / dual-issue
    }
    // the MAC datapath registers are architecturally reserved in the fused
    // formats (the hardware write ports are spoken for)
    for r in [ra, rb] {
        if [ACC, OPA, OPB, SCR].contains(&r) {
            return None;
        }
    }
    fits(ra, ia, rb, ib).or_else(|| fits(rb, ib, ra, ia))
}

fn fits(r1: Reg, i1: i32, r2: Reg, i2: i32) -> Option<(Reg, Reg, u8, u16)> {
    if (0..=31).contains(&i1) && (0..=1023).contains(&i2) {
        Some((r1, r2, i1 as u8, i2 as u16))
    } else {
        None
    }
}

/// In-place addi (`addi r, r, imm`) → (reg, imm).
pub fn match_inplace_addi(i: &Instr) -> Option<(Reg, i32)> {
    match i {
        Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm } if rd == rs1 && *rd != 0 => {
            Some((*rd, *imm))
        }
        _ => None,
    }
}

/// Loose consecutive-addi pair (profiler's `addi_addi_count` and the Fig 4
/// immediate histogram): in-place, distinct registers, any immediates.
pub fn match_addi_pair_loose(a: &Instr, b: &Instr) -> Option<(i32, i32)> {
    let (ra, ia) = match_inplace_addi(a)?;
    let (rb, ib) = match_inplace_addi(b)?;
    if ra == rb {
        None
    } else {
        Some((ia, ib))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addi(rd: Reg, rs1: Reg, imm: i32) -> Instr {
        Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm }
    }

    #[test]
    fn loose_mul_add() {
        let m = Instr::Op { op: AluOp::Mul, rd: 12, rs1: 5, rs2: 6 };
        let a = Instr::Op { op: AluOp::Add, rd: 7, rs1: 7, rs2: 12 };
        assert!(match_mul_add_loose(&m, &a));
        // add not consuming the product
        let a2 = Instr::Op { op: AluOp::Add, rd: 7, rs1: 7, rs2: 13 };
        assert!(!match_mul_add_loose(&m, &a2));
    }

    #[test]
    fn inplace_addi_only() {
        assert_eq!(match_inplace_addi(&addi(5, 5, 9)), Some((5, 9)));
        assert_eq!(match_inplace_addi(&addi(5, 6, 9)), None);
        assert_eq!(match_inplace_addi(&addi(0, 0, 0)), None); // nop on x0
    }

    #[test]
    fn pair_immediate_split() {
        // canonical: small then large
        let p = match_addi_pair(&addi(10, 10, 31), &addi(11, 11, 1023));
        assert_eq!(p, Some((10, 11, 31, 1023)));
        // boundary violations
        assert_eq!(match_addi_pair(&addi(10, 10, 32), &addi(11, 11, 40)), None);
        assert_eq!(
            match_addi_pair(&addi(10, 10, 32), &addi(11, 11, 7)),
            Some((11, 10, 7, 32))
        );
    }
}
