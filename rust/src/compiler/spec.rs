//! Model spec loading — the rust half of the contract defined by
//! `python/compile/specs.py` + `export.py`.
//!
//! A spec is the hardware-agnostic model description (the TVM-Relay analogue
//! of the paper's flow).  The exporter writes `models/<name>.json` plus a
//! raw weight blob `models/<name>.bin`; this module decodes both into
//! [`ModelSpec`], which every downstream stage (planner, codegen, reference
//! executor, golden comparison) consumes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{self, Value};

/// A named weight tensor (values held as i32; int8 tensors store
/// int8-range values).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub data: Vec<i32>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    I8,
    I32,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// One layer of the model DAG. `inputs` index earlier layers; -1 is the
/// model input.
#[derive(Clone, Debug)]
pub enum Layer {
    Conv2d {
        input: i32,
        w: String,
        b: String,
        stride: usize,
        pad: usize,
        shift: u32,
        relu: bool,
        in_shape: [usize; 3],
        out_shape: [usize; 3],
    },
    DwConv2d {
        input: i32,
        w: String,
        b: String,
        stride: usize,
        pad: usize,
        shift: u32,
        relu: bool,
        in_shape: [usize; 3],
        out_shape: [usize; 3],
    },
    Dense {
        input: i32,
        w: String,
        b: String,
        shift: u32,
        relu: bool,
        in_len: usize,
        out_len: usize,
    },
    MaxPool {
        input: i32,
        k: usize,
        stride: usize,
        in_shape: [usize; 3],
        out_shape: [usize; 3],
    },
    AvgPool2d {
        input: i32,
        k: usize,
        stride: usize,
        shift: u32,
        in_shape: [usize; 3],
        out_shape: [usize; 3],
    },
    AvgPoolGlobal {
        input: i32,
        shift: u32,
        in_shape: [usize; 3],
        out_shape: [usize; 3],
    },
    Add {
        a: i32,
        b: i32,
        relu: bool,
        shape: Vec<usize>,
    },
    Concat {
        inputs: Vec<i32>,
        in_shapes: Vec<[usize; 3]>,
        out_shape: [usize; 3],
    },
}

impl Layer {
    /// Producer layer indices feeding this layer.
    pub fn inputs(&self) -> Vec<i32> {
        match self {
            Layer::Conv2d { input, .. }
            | Layer::DwConv2d { input, .. }
            | Layer::Dense { input, .. }
            | Layer::MaxPool { input, .. }
            | Layer::AvgPool2d { input, .. }
            | Layer::AvgPoolGlobal { input, .. } => vec![*input],
            Layer::Add { a, b, .. } => vec![*a, *b],
            Layer::Concat { inputs, .. } => inputs.clone(),
        }
    }

    /// Number of elements in this layer's output.
    pub fn out_elems(&self) -> usize {
        match self {
            Layer::Conv2d { out_shape, .. }
            | Layer::DwConv2d { out_shape, .. }
            | Layer::MaxPool { out_shape, .. }
            | Layer::AvgPool2d { out_shape, .. }
            | Layer::AvgPoolGlobal { out_shape, .. }
            | Layer::Concat { out_shape, .. } => out_shape.iter().product(),
            Layer::Dense { out_len, .. } => *out_len,
            Layer::Add { shape, .. } => shape.iter().product(),
        }
    }

    pub fn op_name(&self) -> &'static str {
        match self {
            Layer::Conv2d { .. } => "conv2d",
            Layer::DwConv2d { .. } => "dwconv2d",
            Layer::Dense { .. } => "dense",
            Layer::MaxPool { .. } => "maxpool",
            Layer::AvgPool2d { .. } => "avgpool2d",
            Layer::AvgPoolGlobal { .. } => "avgpool_global",
            Layer::Add { .. } => "add",
            Layer::Concat { .. } => "concat",
        }
    }
}

/// A fully-loaded model: graph + weights.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub profile: String,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub layers: Vec<Layer>,
    pub tensors: BTreeMap<String, Tensor>,
}

impl ModelSpec {
    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_elems(&self) -> usize {
        self.layers
            .last()
            .map(|l| l.out_elems())
            .unwrap_or(0)
    }

    /// Total multiply-accumulates of one inference.
    pub fn total_macs(&self) -> u64 {
        let mut total = 0u64;
        for l in &self.layers {
            total += match l {
                Layer::Conv2d { w, out_shape, .. } => {
                    let wt = &self.tensors[w];
                    // w: (OC, IC, KH, KW); per output pixel: IC*KH*KW
                    let per = wt.shape[1] * wt.shape[2] * wt.shape[3];
                    (out_shape.iter().product::<usize>() * per) as u64
                }
                Layer::DwConv2d { w, out_shape, .. } => {
                    let wt = &self.tensors[w];
                    let per = wt.shape[1] * wt.shape[2];
                    (out_shape.iter().product::<usize>() * per) as u64
                }
                Layer::Dense { in_len, out_len, .. } => {
                    (*in_len * *out_len) as u64
                }
                _ => 0,
            };
        }
        total
    }

    /// Validate the DAG: input indices in range, shapes chain, tensors exist.
    pub fn validate(&self) -> Result<()> {
        for (li, layer) in self.layers.iter().enumerate() {
            for i in layer.inputs() {
                ensure!(
                    i >= -1 && (i as i64) < li as i64,
                    "layer {li}: bad input index {i}"
                );
            }
            // The requant shift is a checked precondition of
            // `quant::round_shift` (< 32, see its contract); reject it here
            // so a bad spec is a load-time error, not a simulator panic.
            if let Layer::Conv2d { shift, .. }
            | Layer::DwConv2d { shift, .. }
            | Layer::Dense { shift, .. }
            | Layer::AvgPool2d { shift, .. }
            | Layer::AvgPoolGlobal { shift, .. } = layer
            {
                ensure!(
                    *shift < 32,
                    "layer {li}: requant shift {shift} out of range (< 32)"
                );
            }
            match layer {
                Layer::Conv2d { w, b, in_shape, out_shape, stride, pad, .. } => {
                    let wt = self.tensor(w)?;
                    ensure!(wt.shape.len() == 4, "conv w must be 4-d");
                    ensure!(
                        wt.shape[1] == in_shape[0],
                        "layer {li}: conv ic mismatch"
                    );
                    ensure!(wt.shape[0] == out_shape[0], "conv oc mismatch");
                    let (kh, kw) = (wt.shape[2], wt.shape[3]);
                    let oh = (in_shape[1] + 2 * pad - kh) / stride + 1;
                    let ow = (in_shape[2] + 2 * pad - kw) / stride + 1;
                    ensure!(
                        [out_shape[1], out_shape[2]] == [oh, ow],
                        "layer {li}: conv output shape mismatch"
                    );
                    ensure!(self.tensor(b)?.len() == out_shape[0], "bias len");
                }
                Layer::DwConv2d { w, b, in_shape, out_shape, .. } => {
                    let wt = self.tensor(w)?;
                    ensure!(wt.shape.len() == 3, "dw w must be 3-d");
                    ensure!(wt.shape[0] == in_shape[0], "dw c mismatch");
                    ensure!(out_shape[0] == in_shape[0], "dw c mismatch");
                    ensure!(self.tensor(b)?.len() == out_shape[0], "bias len");
                }
                Layer::Dense { w, b, in_len, out_len, .. } => {
                    let wt = self.tensor(w)?;
                    ensure!(
                        wt.shape == vec![*out_len, *in_len],
                        "layer {li}: dense w shape"
                    );
                    ensure!(self.tensor(b)?.len() == *out_len, "bias len");
                }
                _ => {}
            }
        }
        Ok(())
    }
}

fn shape3(v: &Value, key: &str) -> Result<[usize; 3]> {
    let s = v.usize_list(key)?;
    ensure!(s.len() == 3, "{key} must have 3 dims, got {s:?}");
    Ok([s[0], s[1], s[2]])
}

fn parse_layer(v: &Value, li: usize) -> Result<Layer> {
    let op = v.get("op")?.as_str()?;
    let inputs: Vec<i32> = v
        .get("inputs")
        .ok()
        .map(|arr| -> Result<Vec<i32>> {
            arr.as_arr()?.iter().map(|x| Ok(x.as_i64()? as i32)).collect()
        })
        .transpose()?
        .unwrap_or_default();
    let one_input = || -> Result<i32> {
        ensure!(inputs.len() == 1, "layer {li} ({op}): expected 1 input");
        Ok(inputs[0])
    };
    let shift = |v: &Value| -> Result<u32> {
        Ok(v.get("shift")?.as_i64()? as u32)
    };
    Ok(match op {
        "conv2d" => Layer::Conv2d {
            input: one_input()?,
            w: v.get("w")?.as_str()?.to_string(),
            b: v.get("b")?.as_str()?.to_string(),
            stride: v.get("stride")?.as_usize()?,
            pad: v.get("pad")?.as_usize()?,
            shift: shift(v)?,
            relu: v.get("relu")?.as_bool()?,
            in_shape: shape3(v, "in_shape")?,
            out_shape: shape3(v, "out_shape")?,
        },
        "dwconv2d" => Layer::DwConv2d {
            input: one_input()?,
            w: v.get("w")?.as_str()?.to_string(),
            b: v.get("b")?.as_str()?.to_string(),
            stride: v.get("stride")?.as_usize()?,
            pad: v.get("pad")?.as_usize()?,
            shift: shift(v)?,
            relu: v.get("relu")?.as_bool()?,
            in_shape: shape3(v, "in_shape")?,
            out_shape: shape3(v, "out_shape")?,
        },
        "dense" => Layer::Dense {
            input: one_input()?,
            w: v.get("w")?.as_str()?.to_string(),
            b: v.get("b")?.as_str()?.to_string(),
            shift: shift(v)?,
            relu: v.get("relu")?.as_bool()?,
            in_len: v.get("in_len")?.as_usize()?,
            out_len: {
                let s = v.usize_list("out_shape")?;
                ensure!(s.len() == 1, "dense out_shape");
                s[0]
            },
        },
        "maxpool" => Layer::MaxPool {
            input: one_input()?,
            k: v.get("k")?.as_usize()?,
            stride: v.get("stride")?.as_usize()?,
            in_shape: shape3(v, "in_shape")?,
            out_shape: shape3(v, "out_shape")?,
        },
        "avgpool2d" => Layer::AvgPool2d {
            input: one_input()?,
            k: v.get("k")?.as_usize()?,
            stride: v.get("stride")?.as_usize()?,
            shift: shift(v)?,
            in_shape: shape3(v, "in_shape")?,
            out_shape: shape3(v, "out_shape")?,
        },
        "avgpool_global" => Layer::AvgPoolGlobal {
            input: one_input()?,
            shift: shift(v)?,
            in_shape: shape3(v, "in_shape")?,
            out_shape: shape3(v, "out_shape")?,
        },
        "add" => {
            ensure!(inputs.len() == 2, "add needs 2 inputs");
            Layer::Add {
                a: inputs[0],
                b: inputs[1],
                relu: v.get("relu")?.as_bool()?,
                shape: v.usize_list("out_shape")?,
            }
        }
        "concat" => {
            ensure!(!inputs.is_empty(), "concat needs inputs");
            Layer::Concat {
                inputs: inputs.clone(),
                in_shapes: Vec::new(), // filled by caller from producers
                out_shape: shape3(v, "out_shape")?,
            }
        }
        other => bail!("layer {li}: unknown op {other:?}"),
    })
}

/// Decode the weight blob per the JSON `tensors` table.
fn parse_tensors(doc: &Value, blob: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut out = BTreeMap::new();
    for entry in doc.get("tensors")?.as_arr()? {
        let name = entry.get("name")?.as_str()?.to_string();
        let shape = entry.usize_list("shape")?;
        let size = entry.get("size")?.as_usize()?;
        let offset = entry.get("offset")?.as_usize()?;
        let dtype = match entry.get("dtype")?.as_str()? {
            "i8" => Dtype::I8,
            "i32" => Dtype::I32,
            d => bail!("tensor {name}: unknown dtype {d:?}"),
        };
        ensure!(
            shape.iter().product::<usize>() == size,
            "tensor {name}: shape/size mismatch"
        );
        let data: Vec<i32> = match dtype {
            Dtype::I8 => {
                ensure!(offset + size <= blob.len(), "tensor {name}: blob oob");
                blob[offset..offset + size]
                    .iter()
                    .map(|&b| b as i8 as i32)
                    .collect()
            }
            Dtype::I32 => {
                ensure!(
                    offset + 4 * size <= blob.len(),
                    "tensor {name}: blob oob"
                );
                blob[offset..offset + 4 * size]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
        };
        out.insert(name.clone(), Tensor { name, shape, dtype, data });
    }
    Ok(out)
}

/// Parse a spec from JSON text + weight blob bytes.
pub fn parse_spec(json_text: &str, blob: &[u8]) -> Result<ModelSpec> {
    let doc = json::parse(json_text)?;
    let input_shape = {
        let s = doc.usize_list("input_shape")?;
        ensure!(s.len() == 3, "input_shape must be CHW");
        [s[0], s[1], s[2]]
    };
    let mut layers = Vec::new();
    let raw_layers = doc.get("layers")?.as_arr()?;
    for (li, lv) in raw_layers.iter().enumerate() {
        let mut layer = parse_layer(lv, li)
            .with_context(|| format!("layer {li}"))?;
        // fill concat input shapes from producers
        if let Layer::Concat { inputs, in_shapes, .. } = &mut layer {
            for &i in inputs.iter() {
                let s = if i == -1 {
                    input_shape
                } else {
                    match &layers[i as usize] {
                        Layer::Conv2d { out_shape, .. }
                        | Layer::DwConv2d { out_shape, .. }
                        | Layer::MaxPool { out_shape, .. }
                        | Layer::AvgPool2d { out_shape, .. }
                        | Layer::AvgPoolGlobal { out_shape, .. }
                        | Layer::Concat { out_shape, .. } => *out_shape,
                        Layer::Add { shape, .. } => {
                            ensure!(shape.len() == 3, "add feeding concat");
                            [shape[0], shape[1], shape[2]]
                        }
                        Layer::Dense { .. } => bail!("dense feeding concat"),
                    }
                };
                in_shapes.push(s);
            }
        }
        layers.push(layer);
    }
    let spec = ModelSpec {
        name: doc.get("name")?.as_str()?.to_string(),
        profile: doc
            .get_opt("profile")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "quick".into()),
        input_shape,
        num_classes: doc.get("num_classes")?.as_usize()?,
        layers,
        tensors: parse_tensors(&doc, blob)?,
    };
    spec.validate()?;
    Ok(spec)
}

/// Load `models/<name>.json` + `models/<name>.bin` from an artifacts dir.
pub fn load_spec(artifacts: &Path, name: &str) -> Result<ModelSpec> {
    let jp = artifacts.join("models").join(format!("{name}.json"));
    let bp = artifacts.join("models").join(format!("{name}.bin"));
    let text = std::fs::read_to_string(&jp)
        .with_context(|| format!("reading {}", jp.display()))?;
    let blob = std::fs::read(&bp)
        .with_context(|| format!("reading {}", bp.display()))?;
    parse_spec(&text, &blob)
}
