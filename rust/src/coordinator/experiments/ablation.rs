//! Ablation study: standalone contribution of each ISA extension.
//!
//! The paper's Table 1 ladder (v0→v4) is *cumulative*, which leaves two
//! design questions open that §II.C.3 argues informally:
//!
//! 1. what does each extension buy **alone** on the baseline core?
//! 2. is `fusedmac` redundant given `mac`+`add2i` (it fuses the same
//!    instructions), or does the 4-way fusion earn its opcode?
//!
//! The simulator's [`Variant`] is an arbitrary feature mask, so we can build
//! cores the paper never synthesized and measure exactly that.  The area
//! model prices each combination with the same calibrated FU costs.

use std::path::Path;

use anyhow::Result;

use crate::compiler::{self, CompileCache};
use crate::hw::area_of;
use crate::models;
use crate::runtime;
use crate::sim::engine::{run_batch, Job};
use crate::sim::{Variant, V0, V4};
use crate::util::tables::{fmt_si, Table};

/// The ablation cores: baseline, each extension alone, the pair-fusions
/// without the quad, and the full v4.
pub fn ablation_variants() -> Vec<Variant> {
    vec![
        V0,
        Variant { name: "mac-only", mac: true, add2i: false, fusedmac: false, zol: false, xwin: 0 },
        Variant { name: "add2i-only", mac: false, add2i: true, fusedmac: false, zol: false, xwin: 0 },
        Variant { name: "fusedmac-only", mac: false, add2i: false, fusedmac: true, zol: false, xwin: 0 },
        Variant { name: "zol-only", mac: false, add2i: false, fusedmac: false, zol: true, xwin: 0 },
        Variant { name: "pairs(no quad)", mac: true, add2i: true, fusedmac: false, zol: true, xwin: 0 },
        V4,
    ]
}

/// One ablation row.
pub struct AblationPoint {
    pub variant: Variant,
    pub cycles: u64,
    pub speedup: f64,
    pub lut_delta: i64,
    /// Speedup per 1k extra LUTs — the efficiency of the area spent.
    pub speedup_per_klut: f64,
}

/// Measure the ablation grid for one model.
pub fn measure(artifacts: &Path, name: &str) -> Result<Vec<AblationPoint>> {
    measure_cached(artifacts, name, &CompileCache::new(), 0)
}

/// [`measure`] on the batch engine with a shared compile cache: all
/// ablation cores simulate concurrently (`threads` 0 = one per core).
pub fn measure_cached(
    artifacts: &Path,
    name: &str,
    cache: &CompileCache,
    threads: usize,
) -> Result<Vec<AblationPoint>> {
    let spec = models::load(artifacts, name)?;
    let io = runtime::load_golden_io(artifacts, name)?;
    let input = compiler::pack_input(&io.inputs[0])?;
    let variants = ablation_variants();

    let scache = cache.for_spec(&spec);
    let compiled = variants
        .iter()
        .map(|&v| scache.get_or_compile(v))
        .collect::<Result<Vec<_>>>()?;
    let jobs: Vec<Job<'_>> = compiled
        .iter()
        .map(|c| compiler::make_job(c, &spec, &input, 1 << 36))
        .collect();
    let results = run_batch(&jobs, threads);

    let mut runs = Vec::with_capacity(variants.len());
    for (variant, r) in variants.iter().zip(results) {
        let run = r.map_err(|e| {
            anyhow::anyhow!("{name} on {}: simulation failed: {e}", variant.name)
        })?;
        anyhow::ensure!(
            run.output == io.outputs[0],
            "{name} on {}: output mismatch",
            variant.name
        );
        runs.push(run);
    }
    let v0_cycles = variants
        .iter()
        .position(|v| *v == V0)
        .map(|i| runs[i].stats.cycles)
        .expect("ablation grid always contains V0");

    let mut out = Vec::new();
    for (variant, run) in variants.into_iter().zip(runs) {
        let lut_delta = area_of(&variant).lut - area_of(&V0).lut;
        let speedup = v0_cycles as f64 / run.stats.cycles as f64;
        out.push(AblationPoint {
            variant,
            cycles: run.stats.cycles,
            speedup,
            lut_delta,
            speedup_per_klut: if lut_delta > 0 {
                (speedup - 1.0) / (lut_delta as f64 / 1000.0)
            } else {
                0.0
            },
        });
    }
    Ok(out)
}

/// Render the ablation table for the given models.
pub fn render(artifacts: &Path, models: &[String]) -> Result<String> {
    render_cached(artifacts, models, &CompileCache::new(), 0)
}

/// [`render`] with a shared compile cache + thread override.
pub fn render_cached(
    artifacts: &Path,
    models: &[String],
    cache: &CompileCache,
    threads: usize,
) -> Result<String> {
    let mut out = String::new();
    for name in models {
        let points = measure_cached(artifacts, name, cache, threads)?;
        let mut t = Table::new(&[
            "core", "cycles", "speedup", "ΔLUT", "speedup/kLUT",
        ])
        .with_title(&format!(
            "Ablation — {name}: standalone value of each extension \
             (outputs verified on every core)"
        ));
        for p in &points {
            t.row(vec![
                p.variant.name.to_string(),
                fmt_si(p.cycles),
                format!("{:.3}x", p.speedup),
                format!("{:+}", p.lut_delta),
                if p.lut_delta > 0 {
                    format!("{:.3}", p.speedup_per_klut)
                } else {
                    "-".into()
                },
            ]);
        }
        out.push_str(&t.render());
        // the §II.C.3 question, answered quantitatively
        let quad = points.iter().find(|p| p.variant.name == "pairs(no quad)");
        let v4 = points.last();
        if let (Some(pairs), Some(v4)) = (quad, v4) {
            out.push_str(&format!(
                "fusedmac beyond mac+add2i on {name}: {:.1}% extra cycles saved \
                 (pairs {:.3}x -> full {:.3}x)\n\n",
                (1.0 - v4.cycles as f64 / pairs.cycles as f64) * 100.0,
                pairs.speedup,
                v4.speedup
            ));
        }
    }
    Ok(out)
}
