//! Fig 11: cycle and instruction counts per model on all five variants
//! (averaged over inferences, as the paper does for its two-inference runs).

use crate::coordinator::flow::FlowResult;
use crate::util::tables::{fmt_si, Table};

/// Render Fig 11 from completed flow results.
pub fn render(flows: &[FlowResult]) -> String {
    let mut t = Table::new(&[
        "model", "variant", "instructions", "cycles", "speedup", "verified",
    ])
    .with_title("Fig 11 — cycle & instruction count per inference across variants");
    for f in flows {
        for m in &f.metrics {
            t.row(vec![
                f.model.clone(),
                m.variant.name.to_string(),
                fmt_si(m.instrs),
                fmt_si(m.cycles),
                format!("{:.2}x", m.speedup),
                match (f.verified_golden, f.verified_pjrt) {
                    (true, Some(true)) => "golden+pjrt".into(),
                    (true, None) => "golden".into(),
                    (true, Some(false)) => "golden, PJRT MISMATCH".into(),
                    (false, _) => "MISMATCH".into(),
                },
            ]);
        }
    }
    t.render()
}
