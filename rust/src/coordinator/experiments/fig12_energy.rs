//! Fig 12: energy per inference (eq. 1, E = P·C/f at 100 MHz) per model and
//! variant.

use crate::coordinator::flow::FlowResult;
use crate::util::tables::Table;

/// Render Fig 12 from completed flow results.
pub fn render(flows: &[FlowResult]) -> String {
    let mut t = Table::new(&[
        "model",
        "variant",
        "power (mW)",
        "time (ms)",
        "energy/inference (mJ)",
        "vs v0",
    ])
    .with_title("Fig 12 — energy per inference on RISC-V variants (E = P*C/f @ 100 MHz)");
    for f in flows {
        let e0 = f.metrics.first().map(|m| m.energy.energy_mj).unwrap_or(0.0);
        for m in &f.metrics {
            t.row(vec![
                f.model.clone(),
                m.variant.name.to_string(),
                format!("{:.0}", m.energy.power_mw),
                format!("{:.3}", m.energy.time_ms),
                format!("{:.4}", m.energy.energy_mj),
                format!("{:.2}x", e0 / m.energy.energy_mj.max(1e-12)),
            ]);
        }
    }
    t.render()
}
