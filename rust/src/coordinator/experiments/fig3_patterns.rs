//! Fig 3: normalized execution counts of the profiled instruction patterns
//! on the baseline core, per model (legend defined by Table 2).

use std::path::Path;

use anyhow::Result;

use crate::compiler;
use crate::models;
use crate::profiler::{PatternCounts, ProfileHook};
use crate::runtime;
use crate::sim::V0;
use crate::util::tables::{fmt_si, Table};

/// Profile one model on v0 with its first golden input.
pub fn profile_model(artifacts: &Path, name: &str) -> Result<PatternCounts> {
    let spec = models::load(artifacts, name)?;
    let io = runtime::load_golden_io(artifacts, name)?;
    let c = compiler::compile(&spec, V0)?;
    let mut hook = ProfileHook::new(c.words().len());
    compiler::execute_compiled(&c, &spec, &io.inputs[0], 1 << 36, &mut hook)?;
    Ok(hook.finish())
}

/// Render the Fig 3 table for all available models.
pub fn render(artifacts: &Path, models: &[String]) -> Result<String> {
    let mut t = Table::new(&[
        "model",
        "total",
        "add",
        "mul",
        "mul_add",
        "addi",
        "addi_addi",
        "fusedmac",
        "blt",
    ])
    .with_title(
        "Fig 3 — frequently executed patterns on baseline v0 \
         (count and share of retired instructions)",
    );
    let norm = |n: u64, tot: u64| format!("{} ({:.1}%)", fmt_si(n), pct(n, tot));
    for name in models {
        let c = profile_model(artifacts, name)?;
        t.row(vec![
            name.clone(),
            fmt_si(c.total),
            norm(c.count("add"), c.total),
            norm(c.count("mul"), c.total),
            norm(c.mul_add, c.total),
            norm(c.count("addi"), c.total),
            norm(c.addi_addi, c.total),
            norm(c.fusedmac, c.total),
            norm(c.count("blt"), c.total),
        ]);
    }
    Ok(t.render())
}

fn pct(n: u64, tot: u64) -> f64 {
    n as f64 / tot.max(1) as f64 * 100.0
}
