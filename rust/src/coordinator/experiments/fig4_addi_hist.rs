//! Fig 4: histogram of consecutive-`addi` immediate pairs (pattern "X_Y")
//! plus the §II.C.2 coverage numbers for the 5/10-bit add2i split.

use std::path::Path;

use anyhow::Result;

use super::fig3_patterns::profile_model;
use crate::profiler::{best_split, split_coverage};
use crate::util::tables::{fmt_count, Table};

/// Render the Fig 4 histogram (top pairs) + coverage analysis per model.
pub fn render(artifacts: &Path, models: &[String], top_n: usize) -> Result<String> {
    let mut out = String::new();
    let mut cov = Table::new(&[
        "model",
        "addi pairs",
        "5/10 coverage",
        "best split",
        "best coverage",
    ])
    .with_title("Fig 4 (analysis) — add2i immediate-width allocation");

    for name in models {
        let c = profile_model(artifacts, name)?;
        let mut t = Table::new(&["pattern X_Y", "count"])
            .with_title(&format!("Fig 4 — {name}: consecutive addi immediates"));
        for ((i1, i2), n) in c.top_addi_pairs(top_n) {
            t.row(vec![format!("{i1}_{i2}"), fmt_count(n)]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let paper = split_coverage(&c.addi_imm_hist, 5, 10);
        let (a, b, best) = best_split(&c.addi_imm_hist);
        cov.row(vec![
            name.clone(),
            fmt_count(c.addi_addi),
            format!("{:.2}%", paper * 100.0),
            format!("{a}+{b} bits"),
            format!("{:.2}%", best * 100.0),
        ]);
    }
    out.push_str(&cov.render());
    Ok(out)
}
