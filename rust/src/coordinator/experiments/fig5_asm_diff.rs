//! Fig 5: side-by-side assembly of one convolution layer on v0 vs the fully
//! extended v4, with per-instruction cycle counts from the simulator — the
//! paper's evidence that the `blt` (and the counter `addi`) vanish under
//! `zol` while the inner loop collapses to `fusedmac`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::compiler::{self, Compiled};
use crate::isa::disasm::disasm;
use crate::models;
use crate::profiler::ProfileHook;
use crate::runtime;
use crate::sim::Variant;

/// One listing line: pc, word, asm, cycles spent there, retires.
pub struct AsmLine {
    pub pc: u32,
    pub word: u32,
    pub asm: String,
    pub cycles: u64,
    pub retires: u64,
}

/// Compile `name` for `variant`, run one golden input with per-PC cycle
/// attribution, and return the listing of layer `layer_idx`.
pub fn layer_listing(
    artifacts: &Path,
    name: &str,
    variant: Variant,
    layer_idx: usize,
) -> Result<(Vec<AsmLine>, u64)> {
    let spec = models::load(artifacts, name)?;
    ensure!(layer_idx < spec.layers.len(), "layer index out of range");
    let io = runtime::load_golden_io(artifacts, name)?;
    let c: Compiled = compiler::compile(&spec, variant)?;
    let mut hook = ProfileHook::new(c.words().len());
    compiler::execute_compiled(&c, &spec, &io.inputs[0], 1 << 36, &mut hook)?;

    let (start, end) = c.layer_ranges[layer_idx];
    let mut lines = Vec::new();
    let mut layer_cycles = 0;
    for i in start..end {
        let cycles = hook.pc_cycles[i];
        layer_cycles += cycles;
        lines.push(AsmLine {
            pc: (i * 4) as u32,
            word: c.words()[i],
            asm: disasm(&c.instrs()[i]),
            cycles,
            retires: hook.pc_retires[i],
        });
    }
    Ok((lines, layer_cycles))
}

/// Index of the first conv2d layer (the Fig 5 subject).
pub fn first_conv_layer(artifacts: &Path, name: &str) -> Result<usize> {
    let spec = models::load(artifacts, name)?;
    spec.layers
        .iter()
        .position(|l| matches!(l, crate::compiler::spec::Layer::Conv2d { .. }))
        .context("model has no conv2d layer")
}

/// Render the two listings side by side (sequentially, like the paper's
/// subfigures b/c).
pub fn render(artifacts: &Path, name: &str, layer_idx: Option<usize>) -> Result<String> {
    let li = match layer_idx {
        Some(i) => i,
        None => first_conv_layer(artifacts, name)?,
    };
    let mut out = String::new();
    let mut totals = Vec::new();
    for variant in [crate::sim::V0, crate::sim::V4] {
        let (lines, cyc) = layer_listing(artifacts, name, variant, li)?;
        totals.push(cyc);
        out.push_str(&format!(
            "Fig 5 — {name} layer {li} on {} ({} instructions, {} cycles in layer):\n",
            variant.name,
            lines.len(),
            cyc
        ));
        for l in &lines {
            out.push_str(&format!(
                "  {:#07x}  {:08x}  {:<28} ; {:>12} cycles, {:>10} retires\n",
                l.pc, l.word, l.asm, l.cycles, l.retires
            ));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "layer speedup v0/v4: {:.2}x  (blt eliminated by zol, inner loop fused)\n",
        totals[0] as f64 / totals[1].max(1) as f64
    ));
    Ok(out)
}
