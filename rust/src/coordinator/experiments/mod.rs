//! Experiment regeneration: one module per table/figure of the paper's
//! evaluation (DESIGN.md §5 maps them).
//!
//! Every generator returns rendered text (the tables the paper prints) so
//! the CLI, the benches and EXPERIMENTS.md all share one source of truth.

pub mod ablation;
pub mod fig11_cycles;
pub mod fig12_energy;
pub mod fig3_patterns;
pub mod fig4_addi_hist;
pub mod fig5_asm_diff;
pub mod table10_memory;
pub mod table8_area;

use std::path::Path;

use anyhow::Result;

use super::flow::{FlowOptions, FlowResult, PreparedFlow};
use crate::compiler::CompileCache;
use crate::models::PAPER_MODELS;
use crate::sim::exec::Executor;

/// Models present in the artifacts dir, paper order.
pub fn available_models(artifacts: &Path) -> Vec<String> {
    PAPER_MODELS
        .iter()
        .filter(|n| {
            artifacts.join("models").join(format!("{n}.json")).exists()
        })
        .map(|s| s.to_string())
        .collect()
}

/// THE sweep entry point (DESIGN.md §13): run the flows for a model list
/// as **one global cross-model batch** on any execution backend.
///
/// Preparation (compile + goldens, against the shared `cache`) and
/// verification/aggregation stay on the caller; only the simulation jobs
/// go through `exec`.  The backend drains a single global job list, so a
/// small model finishing early never leaves workers idle while a big one
/// still runs (the tail problem of per-model batching).  Results are
/// per-model, in `names` order, and — by the executor contract —
/// byte-identical to running each flow alone, on any backend
/// (`tests/shard.rs` and `marvel shard-sweep --check` hold the
/// local-vs-sharded differential).
pub fn run_flows(
    artifacts: &Path,
    names: &[String],
    opts: &FlowOptions,
    cache: &CompileCache,
    exec: &mut dyn Executor,
) -> Result<Vec<FlowResult>> {
    let flows: Vec<PreparedFlow> = names
        .iter()
        .map(|m| PreparedFlow::prepare(artifacts, m, opts, cache))
        .collect::<Result<_>>()?;
    for f in &flows {
        for spec in f.specs() {
            exec.submit(spec);
        }
    }
    let mut raw = exec.run().into_iter();
    flows
        .iter()
        .map(|f| {
            let chunk: Vec<_> = raw.by_ref().take(f.n_jobs()).collect();
            f.finish(chunk)
        })
        .collect()
}
