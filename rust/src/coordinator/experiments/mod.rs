//! Experiment regeneration: one module per table/figure of the paper's
//! evaluation (DESIGN.md §5 maps them).
//!
//! Every generator returns rendered text (the tables the paper prints) so
//! the CLI, the benches and EXPERIMENTS.md all share one source of truth.

pub mod ablation;
pub mod fig11_cycles;
pub mod fig12_energy;
pub mod fig3_patterns;
pub mod fig4_addi_hist;
pub mod fig5_asm_diff;
pub mod table10_memory;
pub mod table8_area;

use std::path::Path;

use anyhow::Result;

use super::flow::{FlowOptions, FlowResult, PreparedFlow};
use crate::compiler::CompileCache;
use crate::models::PAPER_MODELS;
use crate::sim::engine::{run_batch, Job};
use crate::sim::shard::{JobDesc, ShardPool};

/// Models present in the artifacts dir, paper order.
pub fn available_models(artifacts: &Path) -> Vec<String> {
    PAPER_MODELS
        .iter()
        .filter(|n| {
            artifacts.join("models").join(format!("{n}.json")).exists()
        })
        .map(|s| s.to_string())
        .collect()
}

/// Run the full flow for every available model (shared by Fig 11 / Fig 12 /
/// Table 10 so the simulations run once).
pub fn run_all_flows(
    artifacts: &Path,
    opts: &FlowOptions,
) -> Result<Vec<FlowResult>> {
    run_all_flows_cached(artifacts, opts, &CompileCache::new())
}

/// [`run_all_flows`] against a shared compile cache: every model's
/// variants × inputs jobs are submitted as **one global batch**, and the
/// cache lets follow-up generators (e.g. the ablation grid in `report
/// all`) reuse every compilation.
pub fn run_all_flows_cached(
    artifacts: &Path,
    opts: &FlowOptions,
    cache: &CompileCache,
) -> Result<Vec<FlowResult>> {
    run_flows_cached(artifacts, &available_models(artifacts), opts, cache)
}

/// Run the flows for an explicit model list as one cross-model batch:
/// the workers drain a single global job list, so a small model finishing
/// early never leaves cores idle while a big one still runs (the tail
/// problem of per-model batching).  Results are per-model, in `names`
/// order, and byte-identical to running each flow alone.
pub fn run_flows_cached(
    artifacts: &Path,
    names: &[String],
    opts: &FlowOptions,
    cache: &CompileCache,
) -> Result<Vec<FlowResult>> {
    let flows: Vec<PreparedFlow> = names
        .iter()
        .map(|m| PreparedFlow::prepare(artifacts, m, opts, cache))
        .collect::<Result<_>>()?;
    let jobs: Vec<Job<'_>> = flows.iter().flat_map(PreparedFlow::jobs).collect();
    let mut raw = run_batch(&jobs, opts.threads).into_iter();
    flows
        .iter()
        .map(|f| {
            let chunk: Vec<_> = raw.by_ref().take(f.n_jobs()).collect();
            f.finish(chunk)
        })
        .collect()
}

/// [`run_flows_cached`] with the global job list dispatched across a
/// [`ShardPool`] of worker processes instead of in-process threads.
/// Preparation (compile + goldens) and verification/aggregation stay on
/// the coordinator; only the simulation jobs travel.  The pool's
/// submission-ordered merge makes the per-model results bit-identical to
/// the in-process path — `tests/shard.rs` and `marvel shard-sweep --check`
/// hold that differential.
pub fn run_flows_sharded(
    artifacts: &Path,
    names: &[String],
    opts: &FlowOptions,
    cache: &CompileCache,
    pool: &mut ShardPool,
) -> Result<Vec<FlowResult>> {
    let flows: Vec<PreparedFlow> = names
        .iter()
        .map(|m| PreparedFlow::prepare(artifacts, m, opts, cache))
        .collect::<Result<_>>()?;
    let descs: Vec<JobDesc> =
        flows.iter().flat_map(PreparedFlow::descs).collect();
    let mut raw = pool.run(&descs).into_iter();
    flows
        .iter()
        .map(|f| {
            let chunk: Vec<_> = raw.by_ref().take(f.n_jobs()).collect();
            f.finish(chunk)
        })
        .collect()
}
