//! Table 10: data-memory and program-memory usage per model × variant,
//! with the "total memory saved" row.
//!
//! Known deviation (DESIGN.md §9): our DM is variant-independent by
//! construction (the planner's layout does not depend on the ISA), so the
//! paper's v0→v1 DM drops — an artifact of the authors' hand-coded build —
//! do not appear; the PM column shows the fusion/zol shrinkage trend.

use crate::coordinator::flow::FlowResult;
use crate::util::tables::Table;

fn kb(bytes: u32) -> String {
    format!("{:.2}", bytes as f64 / 1024.0)
}

/// Render Table 10 from completed flow results.
pub fn render(flows: &[FlowResult]) -> String {
    let mut t = Table::new(&["model", "variant", "DM (kB)", "PM (kB)"])
        .with_title("Table 10 — data & program memory usage across processor versions");
    for f in flows {
        for m in &f.metrics {
            t.row(vec![
                f.model.clone(),
                m.variant.name.to_string(),
                kb(m.dm_bytes),
                kb(m.pm_bytes),
            ]);
        }
        if let (Some(v0), Some(vl)) = (f.metrics.first(), f.metrics.last()) {
            let dm_saved = 100.0 * (1.0 - vl.dm_bytes as f64 / v0.dm_bytes as f64);
            let pm_saved = 100.0 * (1.0 - vl.pm_bytes as f64 / v0.pm_bytes as f64);
            t.row(vec![
                f.model.clone(),
                "saved (%)".to_string(),
                format!("{dm_saved:.2}"),
                format!("{pm_saved:.2}"),
            ]);
        }
    }
    t.render()
}
