//! Table 8 + Fig 10: FPGA utilisation and power of the five core variants,
//! from the calibrated area model, including the overhead row and the
//! Fig 10 proportions-of-baseline view.

use crate::hw::{area_of, overhead, BASELINE};
use crate::sim::VARIANTS;
use crate::util::tables::Table;

/// Render Table 8.
pub fn render() -> String {
    let mut t = Table::new(&["Processor", "LUT", "MUX", "Registers", "DSP", "Power"])
        .with_title("Table 8 — FPGA utilisation of all processor variants (calibrated model)");
    let names = [
        "v0: Baseline",
        "v1: v0 + mac",
        "v2: v1 + add2i",
        "v3: v2 + fusedmac",
        "v4: v3 + hardware loops",
    ];
    for (v, label) in VARIANTS.iter().zip(names) {
        let a = area_of(v);
        t.row(vec![
            label.to_string(),
            a.lut.to_string(),
            a.mux.to_string(),
            a.regs.to_string(),
            a.dsp.to_string(),
            format!("{:.0} mW", a.power_mw),
        ]);
    }
    let o = overhead(&crate::sim::V4);
    t.row(vec![
        "Overhead:".to_string(),
        format!("{} ({:.2}%)", o[0].1, o[0].2),
        format!("{} ({:.1}%)", o[1].1, o[1].2),
        format!("{} ({:.2}%)", o[2].1, o[2].2),
        format!("{} ({:.0}%)", o[3].1, o[3].2),
        format!(
            "{:.0} mW ({:.2}%)",
            area_of(&crate::sim::V4).power_mw - BASELINE.power_mw,
            (area_of(&crate::sim::V4).power_mw - BASELINE.power_mw)
                / BASELINE.power_mw
                * 100.0
        ),
    ]);
    t.render()
}

/// Render Fig 10 (utilisation as a proportion of the base core).
pub fn render_fig10() -> String {
    let mut t = Table::new(&["Processor", "LUT x", "MUX x", "Registers x", "Power x"])
        .with_title("Fig 10 — resource utilisation relative to baseline");
    for v in &VARIANTS {
        let a = area_of(v);
        t.row(vec![
            v.name.to_string(),
            format!("{:.3}", a.lut as f64 / BASELINE.lut as f64),
            format!("{:.3}", a.mux as f64 / BASELINE.mux as f64),
            format!("{:.3}", a.regs as f64 / BASELINE.regs as f64),
            format!("{:.3}", a.power_mw / BASELINE.power_mw),
        ]);
    }
    t.render()
}
