//! `marvel extsearch` — the closed mining loop over the model zoo
//! (DESIGN.md §17): profile → propose → rewrite → re-measure, per model.
//!
//! For each model the search profiles the *post-ladder* stream (v4, where
//! the window counters fire), asks [`crate::extgen::propose`] which
//! [`crate::fusion::WINDOW`] specs pay for themselves, folds the accepted
//! slots into an executable variant (`Variant::with_window`), and then
//! re-measures v0 / v4 / v4+mined through the executor seam — the same
//! [`run_flow_on`] path every sweep uses, so `--backend shard:N` produces
//! bit-identical rows.  Per-model-class speedups land in
//! `BENCH_extgen.json` via the CLI (`--json`).
//!
//! Profiling itself always runs in-process: profile hooks observe every
//! retired instruction and deliberately do not cross the executor wire
//! (DESIGN.md §13) — only the re-measure sweep is backend-switchable.

use std::path::Path;

use anyhow::{Context, Result};

use crate::compiler::{self, CompileCache};
use crate::coordinator::flow::{run_flow_on, FlowOptions};
use crate::extgen;
use crate::models;
use crate::profiler::{PatternCounts, ProfileHook};
use crate::sim::exec::Executor;
use crate::sim::{Variant, V0, V4, VARIANTS};
use crate::util::rng::Rng;

/// Search configuration.
#[derive(Clone, Debug)]
pub struct ExtSearchOptions {
    /// Minimum dynamic-savings fraction a proposal must clear
    /// (`extgen::propose`'s noise filter).
    pub min_savings: f64,
    /// Golden inputs per re-measure run.
    pub n_inputs: usize,
    /// Also run the generic-vs-legacy rewrite differential on every
    /// ladder variant before measuring (the CI oracle check).
    pub check_legacy: bool,
}

impl Default for ExtSearchOptions {
    fn default() -> Self {
        ExtSearchOptions { min_savings: 0.005, n_inputs: 2, check_legacy: false }
    }
}

/// One measured (variant, cycles, speedup-vs-v0) row.
#[derive(Clone, Debug)]
pub struct SearchRow {
    pub variant: Variant,
    pub cycles: u64,
    pub instrs: u64,
    pub speedup: f64,
}

/// The search outcome for one model.
#[derive(Clone, Debug)]
pub struct ModelSearch {
    pub model: String,
    /// Names of the mined window proposals that cleared the bar.
    pub mined: Vec<&'static str>,
    /// The [`Variant::xwin`] mask those proposals select (0 = none).
    pub mask: u8,
    /// v0 / v4 / (v4 + mined) measurements, flow order.
    pub rows: Vec<SearchRow>,
    /// Every measured variant matched the golden logits.
    pub verified: bool,
}

/// The default search zoo: one model per class the paper's argument turns
/// on — plain conv (lenet-shaped), depthwise-separable, and recurrent —
/// so the emitted rows show how the *same* mined extension pays off
/// differently per model class.
pub const DEFAULT_ZOO: [&str; 3] =
    ["synth:lenet:5", "synth:dwconv:9", "synth:rnn:11"];

/// Profile one model's post-ladder (v4) stream with a deterministic
/// synthetic input — the stream the window counters are defined on.
pub fn profile_post_ladder(
    artifacts: &Path,
    name: &str,
    cache: &CompileCache,
) -> Result<PatternCounts> {
    let spec = models::resolve(artifacts, name)?;
    let c = cache.for_spec(&spec).get_or_compile(V4)?;
    let mut hook = ProfileHook::new(c.words().len());
    let mut rng = Rng::new(crate::util::fnv1a(name.as_bytes()));
    let input = models::synth::Builder::random_input(&spec, &mut rng);
    compiler::execute_compiled(&c, &spec, &input, 1 << 36, &mut hook)
        .with_context(|| format!("profiling {name} on v4"))?;
    Ok(hook.finish())
}

/// Run the full search over `model_names` on `exec`.
pub fn search(
    artifacts: &Path,
    model_names: &[String],
    opts: &ExtSearchOptions,
    cache: &CompileCache,
    exec: &mut dyn Executor,
) -> Result<Vec<ModelSearch>> {
    let mut out = Vec::with_capacity(model_names.len());
    for name in model_names {
        if opts.check_legacy {
            let spec = models::resolve(artifacts, name)?;
            for v in VARIANTS {
                compiler::check_rewrite_legacy(&spec, v).with_context(|| {
                    format!("generic-vs-legacy diff on {name} {}", v.name)
                })?;
            }
        }

        // mine: post-ladder profile → proposals → enable mask
        let profile = profile_post_ladder(artifacts, name, cache)?;
        let props = extgen::propose(&profile, opts.min_savings);
        let mask = extgen::window_mask(&props);
        let mined: Vec<&'static str> = props
            .iter()
            .filter(|p| p.window_slot.is_some())
            .map(|p| p.name)
            .collect();

        // re-measure: v0 baseline, the ladder top, and the mined variant
        let mut variants = vec![V0, V4];
        if let Some(v) = Variant::with_window(V4, mask) {
            if mask != 0 {
                variants.push(v);
            }
        }
        let fopts = FlowOptions {
            n_inputs: opts.n_inputs,
            variants,
            ..FlowOptions::default()
        };
        let f = run_flow_on(artifacts, name, &fopts, cache, exec)
            .with_context(|| format!("re-measuring {name}"))?;
        let rows = f
            .metrics
            .iter()
            .map(|m| SearchRow {
                variant: m.variant,
                cycles: m.cycles,
                instrs: m.instrs,
                speedup: m.speedup,
            })
            .collect();
        out.push(ModelSearch {
            model: name.clone(),
            mined,
            mask,
            rows,
            verified: f.verified_golden,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::LocalExec;

    #[test]
    fn mined_variant_beats_the_ladder_on_conv_classes() {
        let artifacts = Path::new("artifacts");
        let cache = CompileCache::new();
        let mut exec = LocalExec::new(artifacts, 1);
        let models: Vec<String> =
            ["synth:lenet:5", "synth:dwconv:9"].map(String::from).to_vec();
        let opts = ExtSearchOptions { n_inputs: 1, ..Default::default() };
        let res = search(artifacts, &models, &opts, &cache, &mut exec).unwrap();
        for r in &res {
            assert!(r.verified, "{}: golden mismatch", r.model);
            assert_ne!(r.mask, 0, "{}: conv-class code must mine a window", r.model);
            assert!(r.mined.contains(&"ldmacpp"), "{}: {:?}", r.model, r.mined);
            // rows are v0, v4, v4+x<mask>; the mined variant must beat v4
            assert_eq!(r.rows.len(), 3);
            let v4 = &r.rows[1];
            let mined = &r.rows[2];
            assert!(mined.variant.xwin != 0 && v4.variant.xwin == 0);
            assert!(
                mined.cycles < v4.cycles,
                "{}: mined {} !< v4 {}",
                r.model,
                mined.cycles,
                v4.cycles
            );
            assert!(mined.speedup > v4.speedup);
        }
    }

    #[test]
    fn rnn_class_measures_even_when_mining_differs() {
        // The rnn class exercises dense matrix-vector chains plus the
        // eltwise add-chains the `ldadd` window spec exists for.  With the
        // noise floor out of the way the miner must find that slot — the
        // class-distinct win — and the mined core must beat plain v4.
        let artifacts = Path::new("artifacts");
        let cache = CompileCache::new();
        let mut exec = LocalExec::new(artifacts, 1);
        let models = vec!["synth:rnn:11".to_string()];
        let opts = ExtSearchOptions {
            n_inputs: 1,
            min_savings: 0.0,
            ..Default::default()
        };
        let res = search(artifacts, &models, &opts, &cache, &mut exec).unwrap();
        let r = &res[0];
        assert!(r.verified);
        assert!(r.rows.len() >= 3, "rnn must mine a window variant");
        assert!(r.rows[1].speedup > 1.0, "v4 speedup {}", r.rows[1].speedup);
        assert!(
            r.mask & 0b100 != 0,
            "rnn must mine the add-chain (ldadd) slot, got mask {:#b}",
            r.mask
        );
        assert!(r.mined.contains(&"ldadd"), "mined {:?}", r.mined);
        // every fused add-chain hit saves cycles, so the win is strict
        let last = r.rows.last().unwrap();
        assert!(
            last.cycles < r.rows[1].cycles,
            "mined {} vs v4 {}",
            last.cycles,
            r.rows[1].cycles
        );
    }

    #[test]
    fn check_legacy_mode_passes_on_the_zoo() {
        let artifacts = Path::new("artifacts");
        let cache = CompileCache::new();
        let mut exec = LocalExec::new(artifacts, 1);
        let models = vec!["synth:tiny:3".to_string()];
        let opts = ExtSearchOptions {
            n_inputs: 1,
            check_legacy: true,
            ..Default::default()
        };
        let res = search(artifacts, &models, &opts, &cache, &mut exec).unwrap();
        assert!(res[0].verified);
    }
}
