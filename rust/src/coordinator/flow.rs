//! One-model end-to-end flow: spec → 5 compiled cores → simulate → verify
//! → measure.  This is the rust twin of the paper's Fig 1 pipeline with the
//! FPGA replaced by the cycle-accurate core model.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::compiler::{self, Compiled};
use crate::hw::{area_of, energy_mj, AreaReport, EnergyPoint};
use crate::models;
use crate::runtime;
use crate::sim::{NopHook, Variant, VARIANTS};

/// Flow configuration.
#[derive(Clone, Debug)]
pub struct FlowOptions {
    /// How many golden inputs to run (the paper averages 2 inferences).
    pub n_inputs: usize,
    /// Also execute the AOT HLO artifact via PJRT and cross-check.
    pub use_pjrt: bool,
    /// Watchdog budget per inference.
    pub max_instrs: u64,
    /// Which variants to build/run.
    pub variants: Vec<Variant>,
}

impl Default for FlowOptions {
    fn default() -> Self {
        FlowOptions {
            n_inputs: 2,
            use_pjrt: false,
            max_instrs: 1 << 36,
            variants: VARIANTS.to_vec(),
        }
    }
}

/// Measured results for one core variant.
#[derive(Clone, Debug)]
pub struct VariantMetrics {
    pub variant: Variant,
    /// Average per-inference retired instructions.
    pub instrs: u64,
    /// Average per-inference cycles.
    pub cycles: u64,
    /// Program memory bytes.
    pub pm_bytes: u32,
    /// Data memory bytes.
    pub dm_bytes: u32,
    pub area: AreaReport,
    pub energy: EnergyPoint,
    /// Speedup vs v0 (cycles ratio).
    pub speedup: f64,
    pub rewrite: compiler::rewrite::RewriteStats,
    pub zol_loops: u64,
}

/// End-to-end result for one model.
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub model: String,
    pub n_inputs: usize,
    /// ISS outputs matched the exporter's golden logits on every variant.
    pub verified_golden: bool,
    /// ISS outputs matched the PJRT-executed HLO artifact (if requested).
    pub verified_pjrt: Option<bool>,
    pub metrics: Vec<VariantMetrics>,
    pub total_macs: u64,
}

/// Compile + simulate + verify one model across core variants.
pub fn run_flow(artifacts: &Path, name: &str, opts: &FlowOptions) -> Result<FlowResult> {
    let spec = models::load(artifacts, name)
        .with_context(|| format!("loading model {name}"))?;
    let io = runtime::load_golden_io(artifacts, name)
        .with_context(|| format!("loading golden I/O for {name}"))?;
    ensure!(!io.inputs.is_empty(), "{name}: no golden inputs");
    let n = opts.n_inputs.min(io.inputs.len());

    // optional PJRT golden path (executes the AOT HLO artifact)
    let pjrt = if opts.use_pjrt {
        let rt = runtime::Runtime::cpu()?;
        Some(rt.load_model(artifacts, name, spec.input_shape, spec.output_elems())?)
    } else {
        None
    };

    let mut verified_golden = true;
    let mut verified_pjrt = opts.use_pjrt.then_some(true);
    let mut metrics = Vec::new();
    let mut v0_cycles = None;

    for &variant in &opts.variants {
        let c: Compiled = compiler::compile(&spec, variant)
            .with_context(|| format!("compiling {name} for {}", variant.name))?;
        let mut tot_instrs = 0u64;
        let mut tot_cycles = 0u64;
        for (i, input) in io.inputs.iter().take(n).enumerate() {
            let (got, stats) = compiler::execute_compiled(
                &c,
                &spec,
                input,
                opts.max_instrs,
                &mut NopHook,
            )?;
            tot_instrs += stats.instrs;
            tot_cycles += stats.cycles;
            if got != io.outputs[i] {
                verified_golden = false;
            }
            if let Some(g) = &pjrt {
                let want = g.run(input)?;
                if got != want {
                    verified_pjrt = Some(false);
                }
            }
        }
        let cycles = tot_cycles / n as u64;
        let v0c = *v0_cycles.get_or_insert(cycles);
        metrics.push(VariantMetrics {
            variant,
            instrs: tot_instrs / n as u64,
            cycles,
            pm_bytes: c.pm_bytes(),
            dm_bytes: c.dm_bytes(),
            area: area_of(&variant),
            energy: energy_mj(&variant, cycles),
            speedup: v0c as f64 / cycles as f64,
            rewrite: c.rewrite_stats,
            zol_loops: c.flatten_stats.zol_loops,
        });
    }

    Ok(FlowResult {
        model: name.to_string(),
        n_inputs: n,
        verified_golden,
        verified_pjrt,
        metrics,
        total_macs: spec.total_macs(),
    })
}
