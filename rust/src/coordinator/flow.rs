//! One-model end-to-end flow: spec → compiled cores → simulate → verify
//! → measure.  This is the rust twin of the paper's Fig 1 pipeline with the
//! FPGA replaced by the cycle-accurate core model.
//!
//! The flow is split into three phases so sweeps can batch *across*
//! models (DESIGN.md §3, §13):
//!
//! 1. [`PreparedFlow::prepare`] — load spec + golden I/O, compile every
//!    requested variant (plus the hidden v0 baseline), pack the inputs;
//! 2. [`PreparedFlow::specs`] — the flow's variants × inputs as canonical
//!    executor [`JobSpec`]s (pre-hydrated, so a local backend runs this
//!    coordinator's compilations and a sharded backend ships only the
//!    wire half).  `run_flow` submits one model's list alone;
//!    `experiments::run_flows` concatenates every model's list into one
//!    global batch on any backend, so small models don't leave workers
//!    idle at the tail;
//! 3. [`PreparedFlow::finish`] — verify outputs against the golden (and
//!    optionally PJRT) references and aggregate the per-variant metrics.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::compiler::spec::ModelSpec;
use crate::compiler::{self, CompileCache, Compiled};
use crate::hw::{area_of, energy_mj, AreaReport, EnergyPoint};
use crate::models;
use crate::runtime;
use crate::sim::engine::JobOutput;
use crate::sim::exec::{Executor, JobSpec, LocalExec};
use crate::sim::{SimError, Variant, V0, VARIANTS};

/// Flow configuration.
#[derive(Clone, Debug)]
pub struct FlowOptions {
    /// How many golden inputs to run (the paper averages 2 inferences).
    pub n_inputs: usize,
    /// Also execute the AOT HLO artifact via PJRT and cross-check.
    pub use_pjrt: bool,
    /// Watchdog budget per inference.
    pub max_instrs: u64,
    /// Which variants to build/run.
    pub variants: Vec<Variant>,
    /// Local-backend worker threads (0 = one per core, honoring the
    /// `MARVEL_THREADS` override; 1 = sequential).  A caller-built
    /// [`Executor`] brings its own parallelism.
    pub threads: usize,
}

impl Default for FlowOptions {
    fn default() -> Self {
        FlowOptions {
            n_inputs: 2,
            use_pjrt: false,
            max_instrs: 1 << 36,
            variants: VARIANTS.to_vec(),
            threads: 0,
        }
    }
}

/// Measured results for one core variant.
#[derive(Clone, Debug)]
pub struct VariantMetrics {
    pub variant: Variant,
    /// Average per-inference retired instructions.
    pub instrs: u64,
    /// Average per-inference cycles.
    pub cycles: u64,
    /// Program memory bytes.
    pub pm_bytes: u32,
    /// Data memory bytes.
    pub dm_bytes: u32,
    pub area: AreaReport,
    pub energy: EnergyPoint,
    /// Speedup vs the v0 baseline (cycles ratio).  The baseline is always
    /// measured on the real [`V0`] core — if v0 is not among
    /// `FlowOptions::variants` an extra hidden baseline run provides it.
    pub speedup: f64,
    pub rewrite: compiler::rewrite::RewriteStats,
    pub zol_loops: u64,
}

/// End-to-end result for one model.
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub model: String,
    pub n_inputs: usize,
    /// ISS outputs matched the exporter's golden logits on every variant.
    pub verified_golden: bool,
    /// ISS outputs matched the PJRT-executed HLO artifact (if requested).
    pub verified_pjrt: Option<bool>,
    pub metrics: Vec<VariantMetrics>,
    pub total_macs: u64,
}

/// A model flow with everything compiled/loaded and ready to simulate:
/// the unit of cross-model batching.
pub struct PreparedFlow {
    name: String,
    opts: FlowOptions,
    spec: ModelSpec,
    io: runtime::GoldenIo,
    pjrt: Option<runtime::GoldenModel>,
    /// Compiled units, requested variants first; the tail may hold the
    /// hidden V0 baseline.
    units: Vec<Arc<Compiled>>,
    /// How many of `units` were requested (and are golden-verified).
    reported: usize,
    /// Packed int8 input images, one per golden input used.
    packed: Vec<Vec<u8>>,
    /// Inputs per unit.
    n: usize,
}

impl PreparedFlow {
    /// Load, compile and pack everything `name` needs — no simulation yet.
    pub fn prepare(
        artifacts: &Path,
        name: &str,
        opts: &FlowOptions,
        cache: &CompileCache,
    ) -> Result<PreparedFlow> {
        ensure!(!opts.variants.is_empty(), "{name}: no variants requested");
        // `resolve`/`resolve_io` accept `synth:<kind>:<seed>` names (the
        // reference executor provides synthetic goldens), so flows — and
        // therefore sharded sweeps and serving — run without artifacts.
        let spec = models::resolve(artifacts, name)
            .with_context(|| format!("loading model {name}"))?;
        let io = models::resolve_io(artifacts, name, &spec, opts.n_inputs)
            .with_context(|| format!("loading golden I/O for {name}"))?;
        ensure!(!io.inputs.is_empty(), "{name}: no golden inputs");
        let n = opts.n_inputs.min(io.inputs.len()).max(1);

        // optional PJRT golden path (executes the AOT HLO artifact)
        let pjrt = if opts.use_pjrt {
            let rt = runtime::Runtime::cpu()?;
            Some(rt.load_model(
                artifacts,
                name,
                spec.input_shape,
                spec.output_elems(),
            )?)
        } else {
            None
        };

        // Compile every requested variant, plus a hidden V0 baseline when
        // the request omits it: `speedup` is defined against the real v0
        // core, not against whichever variant happens to be listed first.
        let reported = opts.variants.len();
        let scache = cache.for_spec(&spec);
        let mut units: Vec<Arc<Compiled>> = opts
            .variants
            .iter()
            .map(|&v| {
                scache
                    .get_or_compile(v)
                    .with_context(|| format!("compiling {name} for {}", v.name))
            })
            .collect::<Result<_>>()?;
        if !opts.variants.contains(&V0) {
            units.push(
                scache
                    .get_or_compile(V0)
                    .with_context(|| format!("compiling {name} baseline v0"))?,
            );
        }

        // Inputs are packed once and borrowed by every variant's job.
        let packed: Vec<Vec<u8>> = io
            .inputs
            .iter()
            .take(n)
            .map(|x| compiler::pack_input(x))
            .collect::<Result<_>>()?;

        Ok(PreparedFlow {
            name: name.to_string(),
            opts: opts.clone(),
            spec,
            io,
            pjrt,
            units,
            reported,
            packed,
            n,
        })
    }

    /// Number of simulation jobs this flow contributes.
    pub fn n_jobs(&self) -> usize {
        self.units.len() * self.n
    }

    /// The flow's executor job list, unit-major (`specs[u * n + i]` =
    /// unit `u`, input `i`) — one canonical [`JobSpec`] per simulation,
    /// valid on any [`Executor`].  Each spec is pre-hydrated with this
    /// coordinator's compilation (an in-process backend runs it directly)
    /// *and* carries the wire description with program/base-DM
    /// fingerprints (a cross-process backend ships that half, and a
    /// worker whose hydration diverges fails loudly).  Concatenate
    /// several flows' lists for a cross-model batch.
    pub fn specs(&self) -> Vec<JobSpec> {
        let out_elems = self.spec.output_elems();
        let mut specs = Vec::with_capacity(self.n_jobs());
        for c in &self.units {
            for input in &self.packed {
                specs.push(JobSpec::hydrated(
                    &self.name,
                    c,
                    out_elems,
                    input,
                    self.opts.max_instrs,
                ));
            }
        }
        specs
    }

    /// Verify + aggregate the engine results for this flow's jobs (in the
    /// order [`Self::specs`] produced them).
    pub fn finish(
        &self,
        raw: Vec<Result<JobOutput, SimError>>,
    ) -> Result<FlowResult> {
        ensure!(
            raw.len() == self.n_jobs(),
            "{}: expected {} results, got {}",
            self.name,
            self.n_jobs(),
            raw.len()
        );
        let n = self.n;
        let mut outputs = Vec::with_capacity(raw.len());
        for (j, r) in raw.into_iter().enumerate() {
            let (u, i) = (j / n, j % n);
            let out = r.map_err(|e| {
                anyhow!(
                    "{} on {} input {i}: simulation failed: {e}",
                    self.name,
                    self.units[u].variant().name
                )
            })?;
            outputs.push(out);
        }

        // Per-unit aggregates; the baseline comes from the real V0 unit
        // (reported or hidden).  Golden verification covers only the
        // variants the caller requested — the hidden baseline exists purely
        // to define `speedup` (its simulation errors still abort above,
        // since a broken baseline means no speedup can be reported).
        let mut verified_golden = true;
        let mut avg = Vec::with_capacity(self.units.len());
        for u in 0..self.units.len() {
            let runs = &outputs[u * n..u * n + n];
            let instrs =
                runs.iter().map(|r| r.stats.instrs).sum::<u64>() / n as u64;
            let cycles =
                runs.iter().map(|r| r.stats.cycles).sum::<u64>() / n as u64;
            if u < self.reported {
                for (i, r) in runs.iter().enumerate() {
                    if r.output != self.io.outputs[i] {
                        verified_golden = false;
                    }
                }
            }
            avg.push((instrs, cycles));
        }
        let v0_cycles =
            match self.units.iter().position(|c| c.variant() == V0) {
                Some(u) => avg[u].1,
                None => bail!("{}: V0 baseline missing from flow units", self.name),
            };

        // PJRT cross-check: one golden execution per input, compared
        // against every reported variant's logits.
        let mut verified_pjrt = self.opts.use_pjrt.then_some(true);
        if let Some(g) = &self.pjrt {
            for (i, input) in self.io.inputs.iter().take(n).enumerate() {
                let want = g.run(input)?;
                for u in 0..self.reported {
                    if outputs[u * n + i].output != want {
                        verified_pjrt = Some(false);
                    }
                }
            }
        }

        let metrics = self
            .units
            .iter()
            .take(self.reported)
            .enumerate()
            .map(|(u, c)| {
                let (instrs, cycles) = avg[u];
                let variant = c.variant();
                VariantMetrics {
                    variant,
                    instrs,
                    cycles,
                    pm_bytes: c.pm_bytes(),
                    dm_bytes: c.dm_bytes(),
                    area: area_of(&variant),
                    energy: energy_mj(&variant, cycles),
                    speedup: v0_cycles as f64 / cycles as f64,
                    rewrite: c.rewrite_stats,
                    zol_loops: c.flatten_stats.zol_loops,
                }
            })
            .collect();

        Ok(FlowResult {
            model: self.name.clone(),
            n_inputs: n,
            verified_golden,
            verified_pjrt,
            metrics,
            total_macs: self.spec.total_macs(),
        })
    }
}

/// Compile + simulate + verify one model across core variants.
pub fn run_flow(artifacts: &Path, name: &str, opts: &FlowOptions) -> Result<FlowResult> {
    run_flow_cached(artifacts, name, opts, &CompileCache::new())
}

/// [`run_flow`] against a shared compile cache — sweeps (`report all`, the
/// experiment generators, benches) pass one cache so each (model, variant)
/// compiles exactly once per process.  Runs on a one-shot local executor;
/// multi-model sweeps and other backends go through
/// `experiments::run_flows` with a caller-built [`Executor`].
pub fn run_flow_cached(
    artifacts: &Path,
    name: &str,
    opts: &FlowOptions,
    cache: &CompileCache,
) -> Result<FlowResult> {
    let mut exec = LocalExec::new(artifacts, opts.threads);
    run_flow_on(artifacts, name, opts, cache, &mut exec)
}

/// [`run_flow_cached`] on a caller-supplied execution backend.
pub fn run_flow_on(
    artifacts: &Path,
    name: &str,
    opts: &FlowOptions,
    cache: &CompileCache,
    exec: &mut dyn Executor,
) -> Result<FlowResult> {
    let flow = PreparedFlow::prepare(artifacts, name, opts, cache)?;
    for spec in flow.specs() {
        exec.submit(spec);
    }
    flow.finish(exec.run())
}
