//! The end-to-end MARVEL flow (paper Fig 1/Fig 2) and the experiment
//! regeneration harness.
//!
//! `flow` drives one model through the whole system — load the AOT-exported
//! spec, compile for all five core variants, simulate, verify against the
//! exporter's golden outputs (and optionally the PJRT-executed HLO
//! artifact), and attach the area/power/energy models.  `experiments`
//! regenerates every table and figure of the paper's evaluation from those
//! runs (see DESIGN.md §5 for the experiment index).

pub mod experiments;
pub mod extsearch;
pub mod flow;

pub use extsearch::{ExtSearchOptions, ModelSearch};
pub use flow::{run_flow, run_flow_cached, run_flow_on, FlowOptions,
               FlowResult, PreparedFlow, VariantMetrics};
