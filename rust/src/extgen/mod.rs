//! Extension generation: the "model-class aware" discovery at the heart of
//! MARVEL.
//!
//! Given a v0 profile ([`crate::profiler::PatternCounts`]), this module
//! reproduces the paper's §II.C methodology end to end:
//!
//! 1. rank the fusable consecutive patterns by *estimated dynamic cycle
//!    savings* (count × cycles-eliminated);
//! 2. allocate immediate widths for the dual-`addi` fusion from the Fig 4
//!    histogram (searching all 15-bit splits, as the paper does before
//!    settling on 5 + 10);
//! 3. assign the free RISC-V custom opcodes (Table 3);
//! 4. price each proposal with the calibrated FU area model (Table 8);
//! 5. emit an nML-style model fragment for each accepted proposal (Fig 6) —
//!    the hand-off artifact the paper feeds to ASIP Designer's Go compiler.
//!
//! `extgen::propose` is pure analysis: it does not enable anything.  The
//! accepted set maps 1:1 onto the v1..v4 variant ladder, which is the
//! validation loop the coordinator closes (profile → propose → build →
//! re-measure).

pub mod nml;

use crate::hw::{FuCost, FU_COSTS};
use crate::profiler::{best_split, PatternCounts};

/// One proposed ISA extension.
#[derive(Clone, Debug)]
pub struct Proposal {
    /// Suggested mnemonic.
    pub name: &'static str,
    /// Human-readable fused pattern.
    pub pattern: &'static str,
    /// Suggested opcode (one of the free custom opcodes of Table 3).
    pub opcode: u32,
    /// Dynamic occurrences observed in the profile.
    pub occurrences: u64,
    /// Baseline cycles spent in the pattern.
    pub cycles_before: u64,
    /// Cycles after fusion.
    pub cycles_after: u64,
    /// Estimated share of total cycles saved (0..1).
    pub savings_frac: f64,
    /// Calibrated area/power increment.
    pub cost: FuCost,
    /// Immediate-width allocation, if the format carries immediates.
    pub imm_split: Option<(u32, u32, f64)>,
    /// For mined proposals: the [`crate::fusion::WINDOW`] slot whose spec
    /// this proposal enables.  `None` for the v1..v4 ladder proposals,
    /// which map onto variant feature bits instead.
    pub window_slot: Option<u8>,
    /// nML-style hardware model fragment (Fig 6).
    pub nml: String,
}

/// The [`crate::sim::Variant::xwin`] enable mask a proposal set selects —
/// how mined proposals become executable ISS variants
/// (`Variant::with_window`).
pub fn window_mask(props: &[Proposal]) -> u8 {
    props
        .iter()
        .filter_map(|p| p.window_slot)
        .fold(0, |m, s| m | (1 << s))
}

/// Derive extension proposals from a v0 profile.
///
/// `min_savings` filters noise (the paper keeps patterns that are "frequent
/// enough to justify dedicated hardware" — fusedmac clears the bar at ~10 %
/// of retired instructions).
pub fn propose(profile: &PatternCounts, min_savings: f64) -> Vec<Proposal> {
    let total_cycles = profile.cycles.max(1) as f64;
    let mut out = Vec::new();

    // --- mac: mul+add pair -> 1 cycle ---
    {
        let occ = profile.mul_add;
        let before = 2 * occ;
        let after = occ;
        let savings = (before - after) as f64 / total_cycles;
        if savings >= min_savings {
            out.push(Proposal {
                name: "mac",
                pattern: "mul rd,rs1,rs2 ; add rd2,rd2,rd",
                opcode: crate::isa::opcodes::CUSTOM2_MAC,
                occurrences: occ,
                cycles_before: before,
                cycles_after: after,
                savings_frac: savings,
                cost: FU_COSTS[0],
                imm_split: None,
                window_slot: None,
                nml: nml::mac_nml(),
            });
        }
    }

    // --- add2i: addi+addi pair -> 1 cycle, needs an immediate split ---
    let split = best_split(&profile.addi_imm_hist);
    {
        let occ = profile.addi_addi;
        let before = 2 * occ;
        // only covered pairs fuse; the rest stay 2 cycles
        let covered = (occ as f64 * split.2) as u64;
        let after = before - covered;
        let savings = covered as f64 / total_cycles;
        if savings >= min_savings {
            out.push(Proposal {
                name: "add2i",
                pattern: "addi rs1,rs1,i1 ; addi rs2,rs2,i2",
                opcode: crate::isa::opcodes::CUSTOM1_ADD2I,
                occurrences: occ,
                cycles_before: before,
                cycles_after: after,
                savings_frac: savings,
                cost: FU_COSTS[1],
                imm_split: Some(split),
                window_slot: None,
                nml: nml::add2i_nml(split.0, split.1),
            });
        }
    }

    // --- fusedmac: the 4-instruction group -> 1 cycle ---
    {
        let occ = profile.fusedmac;
        let before = 4 * occ;
        let after = occ;
        let savings = (before - after) as f64 / total_cycles;
        if savings >= min_savings {
            out.push(Proposal {
                name: "fusedmac",
                pattern: "mul ; add(acc) ; addi ; addi",
                opcode: crate::isa::opcodes::CUSTOM0_FUSEDMAC,
                occurrences: occ,
                cycles_before: before,
                cycles_after: after,
                savings_frac: savings,
                cost: FU_COSTS[2],
                imm_split: Some(split),
                window_slot: None,
                nml: nml::fusedmac_nml(split.0, split.1),
            });
        }
    }

    // --- zol: loop control (taken branch 2c + counter addi 1c) -> 0 ---
    {
        let occ = profile.branches_taken;
        let before = 3 * occ;
        let savings = before as f64 / total_cycles;
        if savings >= min_savings {
            out.push(Proposal {
                name: "zol",
                pattern: "addi ctr,ctr,-1 ; blt/bne back-edge",
                opcode: crate::isa::opcodes::ZOL1,
                occurrences: occ,
                cycles_before: before,
                cycles_after: 0,
                savings_frac: savings,
                cost: FU_COSTS[3],
                imm_split: None,
                window_slot: None,
                nml: nml::zol_nml(),
            });
        }
    }

    // --- mined window specs: post-ladder fusions over the spec pool ---
    // Their counters only fire on post-ladder streams (profile on v4), so
    // a v0 profile proposes exactly the paper's four — the window rung of
    // the pipeline is strictly additive.
    for (i, spec) in crate::fusion::WINDOW.iter().enumerate() {
        let occ = profile.window[i];
        if occ == 0 {
            continue;
        }
        let saved = spec.cycles_saved * occ;
        let before = spec.pattern.len() as u64 * occ;
        let savings = saved as f64 / total_cycles;
        if savings >= min_savings {
            let has_imms = spec
                .sem
                .iter()
                .any(|s| matches!(s, crate::fusion::SemOp::AddImm1
                                    | crate::fusion::SemOp::AddImm2));
            let opcode = crate::isa::opcodes::XWIN[i];
            out.push(Proposal {
                name: spec.name,
                pattern: spec.desc,
                opcode,
                occurrences: occ,
                cycles_before: before,
                cycles_after: before - saved,
                savings_frac: savings,
                cost: spec.cost,
                // immediates arrive pre-encoded from the fused forms the
                // pattern consumes, so the split covers them by definition
                imm_split: has_imms
                    .then_some((spec.split.bits1, spec.split.bits2, 1.0)),
                window_slot: Some(i as u8),
                nml: nml::window_nml(spec, opcode),
            });
        }
    }

    // rank by savings, exactly the paper's "most cycle-intensive first"
    out.sort_by(|a, b| b.savings_frac.total_cmp(&a.savings_frac));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, execute_compiled};
    use crate::models::synth::{lenet_shaped, Builder};
    use crate::profiler::ProfileHook;
    use crate::sim::V0;
    use crate::util::rng::Rng;

    fn lenet_profile() -> PatternCounts {
        let spec = lenet_shaped(33);
        let c = compile(&spec, V0).unwrap();
        let mut hook = ProfileHook::new(c.words().len());
        let mut rng = Rng::new(2);
        let input = Builder::random_input(&spec, &mut rng);
        execute_compiled(&c, &spec, &input, 1 << 33, &mut hook).unwrap();
        hook.finish()
    }

    #[test]
    fn discovers_all_four_paper_extensions() {
        let profile = lenet_profile();
        let props = propose(&profile, 0.005);
        let names: Vec<_> = props.iter().map(|p| p.name).collect();
        for expected in ["mac", "add2i", "fusedmac", "zol"] {
            assert!(names.contains(&expected), "missing {expected}: {names:?}");
        }
        // savings-ranked
        for w in props.windows(2) {
            assert!(w[0].savings_frac >= w[1].savings_frac);
        }
        // conv-class code: the mac pattern saves a double-digit share
        let mac = props.iter().find(|p| p.name == "mac").unwrap();
        assert!(mac.savings_frac > 0.08, "mac savings {}", mac.savings_frac);
    }

    #[test]
    fn immediate_split_matches_paper_choice() {
        // Our generated conv code's histogram is dominated by small/small
        // pairs, so any split with >=5 bits small side covers ~everything;
        // the paper's 5/10 must be at least as good as the best by <=1%.
        let profile = lenet_profile();
        let (a, b, cov) = best_split(&profile.addi_imm_hist);
        let paper = crate::profiler::split_coverage(&profile.addi_imm_hist, 5, 10);
        assert!(cov >= paper);
        assert!(paper > 0.95, "5/10 coverage {paper}");
        assert_eq!(a + b, 15);
    }

    #[test]
    fn v4_profile_mines_window_proposals() {
        // profile the post-ladder stream: the conv inner loop retires
        // lb; lb; fusedmac, which is exactly the ldmacpp opportunity
        let spec = lenet_shaped(33);
        let c = compile(&spec, crate::sim::V4).unwrap();
        let mut hook = ProfileHook::new(c.words().len());
        let mut rng = Rng::new(2);
        let input = Builder::random_input(&spec, &mut rng);
        execute_compiled(&c, &spec, &input, 1 << 33, &mut hook).unwrap();
        let profile = hook.finish();

        let props = propose(&profile, 0.005);
        let pp = props
            .iter()
            .find(|p| p.name == "ldmacpp")
            .expect("ldmacpp must clear the default bar on conv code");
        assert_eq!(pp.window_slot, Some(1));
        assert_eq!(pp.occurrences, profile.window[1]);
        assert!(pp.cycles_after < pp.cycles_before);
        assert!(pp.nml.contains("ldmacpp_instr"));
        // the selected mask builds a runnable variant
        let mask = window_mask(&props);
        assert_ne!(mask & 0b10, 0);
        assert!(crate::sim::Variant::with_window(crate::sim::V4, mask).is_some());
        // a v0 profile proposes no window slots at all
        assert_eq!(window_mask(&propose(&lenet_profile(), 0.0)), 0);
    }

    #[test]
    fn min_savings_filters() {
        let profile = lenet_profile();
        let all = propose(&profile, 0.0);
        let none = propose(&profile, 1.1);
        assert!(all.len() >= 4);
        assert!(none.is_empty());
    }

    #[test]
    fn proposals_price_area() {
        let profile = lenet_profile();
        for p in propose(&profile, 0.001) {
            // every proposal carries a calibrated FU cost and an nML model
            assert!(!p.nml.is_empty());
            assert!(p.cost.lut != 0 || p.cost.regs != 0 || p.cost.dsp != 0);
            assert!(p.cycles_after < p.cycles_before);
        }
    }
}
