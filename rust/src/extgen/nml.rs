//! nML-style model fragments (the paper's Fig 6 hand-off artifact).
//!
//! ASIP Designer consumes nML + PDG to generate both the RTL and the
//! retargeted compiler; we emit the same *shape* of description for each
//! proposed extension so a user of the real Synopsys flow could paste it
//! into the trv32p3 model.  (Offline these are documentation artifacts: our
//! ISS + rewrite passes play the roles of Go/Chess.)

/// nML for the fixed-register mac (compare paper Listing 1 / Fig 6).
pub fn mac_nml() -> String {
    r#"opn mac_instr()
{
  action {
    stage EX:
      x20 = add(x20, mul(x21, x22)) @alu;
  }
  syntax : "mac";
  image  : "0100000"::"00000"::"00000"::"000"::"00000"::"1011011";
}
"#
    .to_string()
}

/// nML for add2i with an (a, b)-bit immediate split.
pub fn add2i_nml(bits_small: u32, bits_large: u32) -> String {
    format!(
        r#"opn add2i_instr(rs1: c5u, rs2: c5u, i1: c{bits_small}u, i2: c{bits_large}u)
{{
  action {{
    stage EX:
      rs1 = add(rs1, i1) @alu;
      rs2 = add(rs2, i2) @alu2;
  }}
  syntax : "add2i " rs1 "," rs2 "," i1 "," i2;
  image  : i2::i1[4..3]::rs2::i1[2..0]::rs1::"0101011";
}}
"#
    )
}

/// nML for fusedmac (paper Fig 6).
pub fn fusedmac_nml(bits_small: u32, bits_large: u32) -> String {
    format!(
        r#"opn fusedmac_instr(rs1: c5u, rs2: c5u, i1: c{bits_small}u, i2: c{bits_large}u)
{{
  action {{
    stage EX:
      x20 = add(x20, mul(x21, x22)) @mac;
      rs1 = add(rs1, i1) @alu;
      rs2 = add(rs2, i2) @alu2;
  }}
  syntax : "fusedmac " rs1 "," rs2 "," i1 "," i2;
  image  : i2::i1[4..3]::rs2::i1[2..0]::rs1::"0001011";
}}
"#
    )
}

/// nML for a mined window spec: the action block is rendered straight from
/// the spec's executable [`crate::fusion::SemOp`] micro-program, so the
/// hand-off artifact can never desynchronize from what the ISS executes.
pub fn window_nml(spec: &crate::fusion::FusionSpec, opcode: u32) -> String {
    use crate::fusion::SemOp;
    let mut actions = String::new();
    for op in spec.sem {
        let line = match op {
            SemOp::MacStep => "      x20 = add(x20, mul(x21, x22)) @mac;",
            SemOp::AddImm1 => "      rs1 = add(rs1, i1) @alu;",
            SemOp::AddImm2 => "      rs2 = add(rs2, i2) @alu2;",
            SemOp::LoadByteA => "      x21 = sext8(DM[rs1]) @ld;",
            SemOp::LoadByteB => "      x22 = sext8(DM[rs2]) @ld2;",
        };
        actions.push_str(line);
        actions.push('\n');
    }
    format!(
        r#"opn {name}_instr(rs1: c5u, rs2: c5u, i1: c{b1}u, i2: c{b2}u)
{{
  action {{
    stage EX:
{actions}  }}
  syntax : "{name} " rs1 "," rs2 "," i1 "," i2;
  image  : i2::i1[4..3]::rs2::i1[2..0]::rs1::"{opc:07b}";
}}
"#,
        name = spec.name,
        b1 = spec.split.bits1,
        b2 = spec.split.bits2,
        opc = opcode & 0x7f,
    )
}

/// nML for the zero-overhead-loop register file + PCU hooks.
pub fn zol_nml() -> String {
    r#"reg ZC<1,32>;  // loop count
reg ZS<1,32>;  // start address
reg ZE<1,32>;  // end address

opn dlpi_instr(cnt: c5u, len: c12u)
{
  action {
    stage EX:
      ZC = cnt; ZS = add(PC, 4) @pcu; ZE = add(PC, add(4, mul(len, 4))) @pcu;
  }
  syntax : "dlpi " cnt "," len;
  image  : len::cnt::"001"::"00000"::"1110111";
}
// PCU: if (nPC == ZE && ZC > 1) { ZC = ZC - 1; nPC = ZS; }
"#
    .to_string()
}

#[cfg(test)]
mod tests {
    #[test]
    fn fragments_mention_key_fields() {
        assert!(super::mac_nml().contains("1011011"));
        let a = super::add2i_nml(5, 10);
        assert!(a.contains("c5u") && a.contains("c10u") && a.contains("0101011"));
        assert!(super::fusedmac_nml(5, 10).contains("0001011"));
        assert!(super::zol_nml().contains("ZC"));
    }

    #[test]
    fn window_fragment_renders_the_sem_program() {
        let spec = crate::fusion::window_spec(1);
        let opc = crate::isa::opcodes::XWIN[1];
        let w = super::window_nml(spec, opc);
        assert!(w.contains("ldmacpp_instr"));
        // one action line per SemOp, in program order
        assert!(w.contains("DM[rs1]") && w.contains("DM[rs2]"));
        assert!(w.contains("mul(x21, x22)"));
        assert!(w.contains("rs2 = add(rs2, i2)"));
        assert!(w.contains(&format!("{:07b}", opc & 0x7f)));
    }
}
