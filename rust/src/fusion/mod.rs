//! The `FusionSpec` IR — one description per fusable instruction, shared
//! by every layer (DESIGN.md §17).
//!
//! A [`FusionSpec`] says four things about one custom instruction:
//!
//! 1. **What to match** ([`PatElem`] template + operand/immediate
//!    constraints): the straight-line instruction window the rewrite
//!    engine replaces ([`crate::compiler::rewrite`]).
//! 2. **What to emit** ([`FusedEmit`]): either one of the paper's ladder
//!    encodings (`mac`/`add2i`/`fusedmac`, Table 3) or a slot in the
//!    spec-driven custom-opcode *window* ([`crate::isa::opcodes::XWIN`]),
//!    which is how *mined* instructions get encodings without touching the
//!    ISA layer.
//! 3. **What it costs** ([`FuCost`] area/power increment, priced into
//!    [`crate::hw::area_of`] per enabled window slot) and what it saves
//!    (`cycles_saved` per dynamic hit under the default cycle model).
//! 4. **What it does** (`sem`: a [`SemOp`] micro-program interpreted by
//!    [`exec_sem`]).  The reference interpreter, the lowered threaded
//!    handler, and the lowered central-match loop all call the *same*
//!    interpreter, so the three execution paths are bit-identical on
//!    mined instructions by construction.
//!
//! The three hand-written ladder passes survive as canned specs
//! ([`FUSEDMAC`], [`MAC`], [`ADD2I`]); their legacy implementations are
//! kept verbatim in `compiler::rewrite::legacy` as the differential
//! oracle.  Mined specs live in [`WINDOW`]: a *static* pool, because
//! shard workers rehydrate programs from `(model, variant-name)` alone —
//! the variant name carries which slots are enabled
//! ([`crate::sim::Variant::xwin`]), the pool carries what each slot means.

use crate::hw::FuCost;
use crate::isa::{Instr, Reg};
use crate::sim::memory::MemFault;
use crate::sim::Memory;

/// One element of a fusion pattern template.  Capture slots: `A` is the
/// first pointer/addi register captured, `B` the second.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PatElem {
    /// `mul x23, x21, x22` — exact MAC-datapath multiply.
    MulScr,
    /// `add x20, x20, x23` — exact accumulate.
    AddAcc,
    /// An already-fused `mac` (window patterns match *post-ladder* code).
    Mac,
    /// In-place `addi rA, rA, imm` — captures `(A, immA)`.  `rA` must not
    /// be one of the reserved MAC datapath registers.
    InplaceAddiA,
    /// Second in-place `addi rB, rB, imm` — captures `(B, immB)`, requires
    /// `rB != rA` and `rB` outside the MAC registers.
    InplaceAddiB,
    /// `lb x21, 0(rA)` — multiplicand byte load, captures `A`.
    LbA,
    /// `lb x22, 0(rB)` — multiplier byte load, captures `B`.
    LbB,
    /// An already-fused `add2i rA, rB, i1, i2` whose registers are exactly
    /// the previously captured `A`/`B` — captures `(i1, i2)` pre-split.
    Add2iAB,
    /// An already-fused `fusedmac rA, rB, i1, i2` on exactly the captured
    /// `A`/`B` (what the v3+ ladder leaves behind in the conv/dense inner
    /// loop) — captures `(i1, i2)` pre-split.  Field order must be exact:
    /// a commuted `fusedmac rB, rA, …` cannot fold into the window formats,
    /// whose loads and post-increments share the same register fields.
    FusedMacAB,
    /// `add x20, x21, x22` — the eltwise accumulate the residual/rnn
    /// add-chains emit (`lb; lb; add` element bodies).  Unlike [`Mac`] or
    /// [`FusedMacAB`] this is a base RV32IM instruction, so patterns ending
    /// in it match on *any* stream, ladder or not.
    AddAb,
}

/// What a matched window is replaced with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusedEmit {
    /// `Instr::Mac` (ladder v1).
    Mac,
    /// `Instr::Add2i` from the captured addi pair (ladder v2).
    Add2i,
    /// `Instr::FusedMac` from the captured addi pair (ladder v3).
    FusedMac,
    /// `Instr::Custom { idx }` — slot `idx` of the custom-opcode window.
    Custom(u8),
}

/// The immediate-width allocation of a dual-immediate encoding: `bits1`
/// for the small field, `bits2` for the large one (the paper's Fig 4
/// 15-bit split, 5+10 for the ladder).
///
/// [`ImmSplit::encodes`] is the rewrite-time validity gate the
/// `extgen::best_split` satellite requires: an observed immediate pair
/// that the split — or the *hardware field widths* (5- and 10-bit slots
/// in the fused encoding, [`crate::isa::encode`]) — cannot represent
/// rejects the fusion instead of silently truncating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ImmSplit {
    pub bits1: u32,
    pub bits2: u32,
}

/// Hardware width of the `i1` field in the fused encoding layout.
pub const ENC_BITS_I1: u32 = 5;
/// Hardware width of the `i2` field in the fused encoding layout.
pub const ENC_BITS_I2: u32 = 10;

impl ImmSplit {
    /// The paper's chosen split (Fig 4): 5 + 10.
    pub const PAPER: ImmSplit = ImmSplit { bits1: 5, bits2: 10 };

    /// Largest value the small field can hold: bounded by both the split's
    /// bit budget and the physical encoding field.
    pub fn max1(&self) -> i32 {
        (1i64 << self.bits1.min(ENC_BITS_I1)) as i32 - 1
    }

    /// Largest value the large field can hold.
    pub fn max2(&self) -> i32 {
        (1i64 << self.bits2.min(ENC_BITS_I2)) as i32 - 1
    }

    /// Can `(i1, i2)` be encoded as-is (no commuting)?  Immediates are
    /// unsigned in the fused formats, so negatives always reject.
    pub fn encodes(&self, i1: i32, i2: i32) -> bool {
        (0..=self.max1()).contains(&i1) && (0..=self.max2()).contains(&i2)
    }

    /// Fit `(ia, ib)` into the split, commuting when allowed and only the
    /// swapped order fits — the one definition of "the immediates fit"
    /// shared by the ladder and every mined spec.  Returns the field
    /// assignment `(first, second, i1, i2)` over the caller's pair labels.
    pub fn fit<T: Copy>(
        &self,
        commute: bool,
        a: (T, i32),
        b: (T, i32),
    ) -> Option<(T, T, u8, u16)> {
        if self.encodes(a.1, b.1) {
            Some((a.0, b.0, a.1 as u8, b.1 as u16))
        } else if commute && self.encodes(b.1, a.1) {
            Some((b.0, a.0, b.1 as u8, a.1 as u16))
        } else {
            None
        }
    }
}

/// One micro-step of a fused instruction's semantics.  The operand names
/// refer to the encoded fields: `rs1`/`rs2` are the two register operands,
/// `i1`/`i2` the two immediates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SemOp {
    /// `x20 += x21 * x22` (wrapping).
    MacStep,
    /// `r[rs1] += i1` (wrapping; x0 stays hardwired).
    AddImm1,
    /// `r[rs2] += i2`.
    AddImm2,
    /// `x21 = sext8(dm[r[rs1]])` — multiplicand byte load.
    LoadByteA,
    /// `x22 = sext8(dm[r[rs2]])` — multiplier byte load.
    LoadByteB,
    /// `x20 = x21 + x22` (wrapping) — the eltwise accumulate.
    AddAb,
}

/// One fusable instruction, end to end.
#[derive(Debug)]
pub struct FusionSpec {
    /// Stable identifier; doubles as the disassembly mnemonic.
    pub name: &'static str,
    /// Human-readable pattern description (reports, proposals).
    pub desc: &'static str,
    /// The instruction window the rewrite engine replaces.
    pub pattern: &'static [PatElem],
    /// What the window is replaced with.
    pub emit: FusedEmit,
    /// May the captured addi pair swap fields to fit the split?
    pub commute: bool,
    /// Immediate-width allocation for the captured pair.
    pub split: ImmSplit,
    /// Area/power increment when a core enables this spec.
    pub cost: FuCost,
    /// Cycles saved per dynamic hit under the default cycle model
    /// (pattern length minus the one fused cycle — every replaced
    /// instruction is 1-cycle in the default model).
    pub cycles_saved: u64,
    /// Executable semantics, in original program order.
    pub sem: &'static [SemOp],
}

/// Ladder spec: `mul x23,x21,x22; add x20,x20,x23` → `mac` (v1+).
pub static MAC: FusionSpec = FusionSpec {
    name: "mac",
    desc: "mul x23,x21,x22 ; add x20,x20,x23",
    pattern: &[PatElem::MulScr, PatElem::AddAcc],
    emit: FusedEmit::Mac,
    commute: false,
    split: ImmSplit::PAPER,
    cost: crate::hw::FU_COSTS[0],
    cycles_saved: 1,
    sem: &[SemOp::MacStep],
};

/// Ladder spec: `addi rA,rA,i1; addi rB,rB,i2` → `add2i` (v2+).
pub static ADD2I: FusionSpec = FusionSpec {
    name: "add2i",
    desc: "addi rA,rA,i1 ; addi rB,rB,i2",
    pattern: &[PatElem::InplaceAddiA, PatElem::InplaceAddiB],
    emit: FusedEmit::Add2i,
    commute: true,
    split: ImmSplit::PAPER,
    cost: crate::hw::FU_COSTS[1],
    cycles_saved: 1,
    sem: &[SemOp::AddImm1, SemOp::AddImm2],
};

/// Ladder spec: the 4-instruction conv inner-loop quad → `fusedmac` (v3+).
pub static FUSEDMAC: FusionSpec = FusionSpec {
    name: "fusedmac",
    desc: "mul ; add(acc) ; addi rA ; addi rB",
    pattern: &[
        PatElem::MulScr,
        PatElem::AddAcc,
        PatElem::InplaceAddiA,
        PatElem::InplaceAddiB,
    ],
    emit: FusedEmit::FusedMac,
    commute: true,
    split: ImmSplit::PAPER,
    cost: crate::hw::FU_COSTS[2],
    cycles_saved: 3,
    sem: &[SemOp::MacStep, SemOp::AddImm1, SemOp::AddImm2],
};

/// The mined-spec pool: slot `idx` of the custom-opcode window.  These
/// match *post-ladder* code (their patterns end in the ladder's fused
/// `mac`/`fusedmac`), so the generic engine runs them after the ladder
/// passes.
///
/// Slot 0, `ldmac`: a bare `mac` still spends two load cycles feeding the
/// datapath registers; fuse `lb x21,0(rA); lb x22,0(rB); mac` into one
/// cycle.
///
/// Slot 1, `ldmacpp`: the v4 conv/dense steady state — after the ladder
/// the whole inner-loop body is `lb; lb; fusedmac rA,rB,i1,i2`; fold the
/// two loads into the fusedmac (load-load-mac-bump in one cycle).
///
/// Slot 2, `ldadd`: the eltwise add-chain body residual and rnn classes
/// emit (`lb x21,0(rA); lb x22,0(rB); add x20,x21,x22`).  Its pattern is
/// all base RV32IM — no ladder dependency — so it is the one spec whose
/// counters fire on ladder-less streams too; it exists to give the
/// `synth:rnn`/residual classes a class-distinct win the conv specs never
/// touch.
pub static WINDOW: [&FusionSpec; 3] = [
    &FusionSpec {
        name: "ldmac",
        desc: "lb x21,0(rA) ; lb x22,0(rB) ; mac",
        pattern: &[PatElem::LbA, PatElem::LbB, PatElem::Mac],
        emit: FusedEmit::Custom(0),
        commute: false,
        split: ImmSplit::PAPER,
        // Dual byte-load ports into the MAC operand registers: address
        // muxes + byte-select logic, no extra DSP (reuses the MAC slice).
        cost: FuCost { name: "ldmac", lut: 214, mux: 46, regs: 12, dsp: 0,
                       power_mw: 6.0 },
        cycles_saved: 2,
        sem: &[SemOp::LoadByteA, SemOp::LoadByteB, SemOp::MacStep],
    },
    &FusionSpec {
        name: "ldmacpp",
        desc: "lb x21,0(rA) ; lb x22,0(rB) ; fusedmac rA,rB,i1,i2",
        pattern: &[PatElem::LbA, PatElem::LbB, PatElem::FusedMacAB],
        emit: FusedEmit::Custom(1),
        commute: false,
        split: ImmSplit::PAPER,
        // ldmac's load ports plus the dual post-increment adders.
        cost: FuCost { name: "ldmacpp", lut: 298, mux: 58, regs: 12, dsp: 0,
                       power_mw: 9.0 },
        cycles_saved: 2,
        sem: &[
            SemOp::LoadByteA,
            SemOp::LoadByteB,
            SemOp::MacStep,
            SemOp::AddImm1,
            SemOp::AddImm2,
        ],
    },
    &FusionSpec {
        name: "ldadd",
        desc: "lb x21,0(rA) ; lb x22,0(rB) ; add x20,x21,x22",
        pattern: &[PatElem::LbA, PatElem::LbB, PatElem::AddAb],
        emit: FusedEmit::Custom(2),
        commute: false,
        split: ImmSplit::PAPER,
        // ldmac's dual byte-load ports feeding a plain adder instead of
        // the MAC slice: slightly less mux, no DSP.
        cost: FuCost { name: "ldadd", lut: 182, mux: 40, regs: 12, dsp: 0,
                       power_mw: 5.0 },
        cycles_saved: 2,
        sem: &[SemOp::LoadByteA, SemOp::LoadByteB, SemOp::AddAb],
    },
];

/// Number of window slots (≤ the free custom opcodes reserved in
/// [`crate::isa::opcodes::XWIN`]).
pub const N_WINDOW: usize = WINDOW.len();

/// The spec behind window slot `idx`.  Panics on an out-of-pool index —
/// unreachable from decoded programs, because decode only recognizes the
/// [`N_WINDOW`] reserved opcodes.
#[inline]
pub fn window_spec(idx: u8) -> &'static FusionSpec {
    WINDOW[idx as usize]
}

/// The canned ladder specs in canonical pass order (fusion-size order, so
/// the quad wins over the pairs — exactly the legacy pass order).
pub static LADDER: [&FusionSpec; 3] = [&FUSEDMAC, &MAC, &ADD2I];

/// Execute a spec's semantics against architectural state.  The one
/// interpreter every execution path calls ([`crate::sim::cpu`] reference,
/// the lowered threaded handler, and the lowered central-match oracle), so
/// a mined instruction cannot mean different things on different paths.
///
/// Steps run in original program order; a memory fault aborts mid-sequence
/// with earlier steps committed — exactly what the unfused instruction
/// sequence would have architecturally visible at the faulting load.
#[inline]
pub fn exec_sem(
    sem: &[SemOp],
    regs: &mut [i32; 32],
    mem: &mut Memory,
    rs1: Reg,
    rs2: Reg,
    i1: u8,
    i2: u16,
) -> Result<(), MemFault> {
    #[inline]
    fn wr(regs: &mut [i32; 32], rd: Reg, v: i32) {
        if rd != 0 {
            regs[rd as usize] = v;
        }
    }
    for op in sem {
        match op {
            SemOp::MacStep => {
                let v = regs[crate::isa::MAC_RD as usize].wrapping_add(
                    regs[crate::isa::MAC_RS1 as usize]
                        .wrapping_mul(regs[crate::isa::MAC_RS2 as usize]),
                );
                wr(regs, crate::isa::MAC_RD, v);
            }
            SemOp::AddImm1 => {
                let v = regs[rs1 as usize].wrapping_add(i1 as i32);
                wr(regs, rs1, v);
            }
            SemOp::AddImm2 => {
                let v = regs[rs2 as usize].wrapping_add(i2 as i32);
                wr(regs, rs2, v);
            }
            SemOp::LoadByteA => {
                let addr = regs[rs1 as usize] as u32;
                let b = mem.load_u8(addr)? as i8 as i32;
                wr(regs, crate::isa::MAC_RS1, b);
            }
            SemOp::LoadByteB => {
                let addr = regs[rs2 as usize] as u32;
                let b = mem.load_u8(addr)? as i8 as i32;
                wr(regs, crate::isa::MAC_RS2, b);
            }
            SemOp::AddAb => {
                let v = regs[crate::isa::MAC_RS1 as usize]
                    .wrapping_add(regs[crate::isa::MAC_RS2 as usize]);
                wr(regs, crate::isa::MAC_RD, v);
            }
        }
    }
    Ok(())
}

/// Build the emitted instruction for a spec from its captured operands.
pub fn emit_instr(
    spec: &FusionSpec,
    rs1: Reg,
    rs2: Reg,
    i1: u8,
    i2: u16,
) -> Instr {
    match spec.emit {
        FusedEmit::Mac => Instr::Mac,
        FusedEmit::Add2i => Instr::Add2i { rs1, rs2, i1, i2 },
        FusedEmit::FusedMac => Instr::FusedMac { rs1, rs2, i1, i2 },
        FusedEmit::Custom(idx) => Instr::Custom { idx, rs1, rs2, i1, i2 },
    }
}

/// The specs a window-enable bitmask selects, in slot order.
pub fn mask_specs(xwin: u8) -> impl Iterator<Item = &'static FusionSpec> {
    (0..N_WINDOW as u8)
        .filter(move |idx| xwin & (1 << idx) != 0)
        .map(window_spec)
}

/// Operand captures threaded through one pattern match: the `A`/`B`
/// register-immediate pairs and (for patterns over already-fused code)
/// the pre-split immediates.
#[derive(Clone, Copy, Debug, Default)]
pub struct Captures {
    /// First captured register and its immediate (0 for pointer captures).
    pub a: Option<(Reg, i32)>,
    /// Second captured register and immediate; always distinct from `a`.
    pub b: Option<(Reg, i32)>,
    /// Immediates captured pre-split from an already-fused instruction.
    pub imms: Option<(u8, u16)>,
}

/// The MAC datapath registers are architecturally reserved in the fused
/// formats — their write ports are spoken for (same rule the legacy
/// `match_addi_pair` imposes).
fn reserved(r: Reg) -> bool {
    use crate::compiler::asm::{ACC, OPA, OPB, SCR};
    r == ACC || r == OPA || r == OPB || r == SCR
}

/// Match one pattern element against one instruction, updating `cap`.
///
/// This is the single definition of "what counts as a fusion opportunity"
/// for the generic rewrite engine ([`crate::compiler::rewrite`]) and the
/// profiler's window counters ([`crate::profiler`]) — the legacy matchers
/// in `compiler::rewrite::patterns` survive only as the differential
/// oracle's vocabulary.
pub fn match_elem(el: PatElem, instr: &Instr, cap: &mut Captures) -> bool {
    use crate::compiler::asm::{ACC, OPA, OPB, SCR};
    use crate::isa::{AluImmOp, AluOp, LoadOp};
    match el {
        PatElem::MulScr => matches!(instr,
            Instr::Op { op: AluOp::Mul, rd, rs1, rs2 }
                if *rd == SCR && *rs1 == OPA && *rs2 == OPB),
        PatElem::AddAcc => matches!(instr,
            Instr::Op { op: AluOp::Add, rd, rs1, rs2 }
                if *rd == ACC && *rs1 == ACC && *rs2 == SCR),
        PatElem::AddAb => matches!(instr,
            Instr::Op { op: AluOp::Add, rd, rs1, rs2 }
                if *rd == ACC && *rs1 == OPA && *rs2 == OPB),
        PatElem::Mac => matches!(instr, Instr::Mac),
        PatElem::InplaceAddiA | PatElem::InplaceAddiB => {
            let (r, imm) = match instr {
                Instr::OpImm { op: AluImmOp::Addi, rd, rs1, imm }
                    if rd == rs1 && *rd != 0 =>
                {
                    (*rd, *imm)
                }
                _ => return false,
            };
            if reserved(r) {
                return false;
            }
            if el == PatElem::InplaceAddiA {
                cap.a = Some((r, imm));
            } else {
                match cap.a {
                    // must be independent of A for the dual adder
                    Some((ra, _)) if ra != r => cap.b = Some((r, imm)),
                    _ => return false,
                }
            }
            true
        }
        PatElem::LbA | PatElem::LbB => {
            let (rd, rp) = match instr {
                Instr::Load { op: LoadOp::Lb, rd, rs1, offset: 0 } => {
                    (*rd, *rs1)
                }
                _ => return false,
            };
            if rp == 0 || reserved(rp) {
                return false;
            }
            if el == PatElem::LbA {
                if rd != OPA {
                    return false;
                }
                cap.a = Some((rp, 0));
            } else {
                if rd != OPB {
                    return false;
                }
                match cap.a {
                    Some((ra, _)) if ra != rp => cap.b = Some((rp, 0)),
                    _ => return false,
                }
            }
            true
        }
        PatElem::Add2iAB => match (instr, cap.a, cap.b) {
            (Instr::Add2i { rs1, rs2, i1, i2 }, Some((ra, _)), Some((rb, _)))
                if *rs1 == ra && *rs2 == rb =>
            {
                cap.imms = Some((*i1, *i2));
                true
            }
            _ => false,
        },
        PatElem::FusedMacAB => match (instr, cap.a, cap.b) {
            (
                Instr::FusedMac { rs1, rs2, i1, i2 },
                Some((ra, _)),
                Some((rb, _)),
            ) if *rs1 == ra && *rs2 == rb => {
                cap.imms = Some((*i1, *i2));
                true
            }
            _ => false,
        },
    }
}

/// Match `spec.pattern` against a straight-line instruction window of
/// exactly the pattern's length and build the fused replacement.
///
/// `None` when the window doesn't match, or when the captured immediates
/// don't fit the spec's split ([`ImmSplit::fit`]/[`ImmSplit::encodes`] —
/// the rewrite-time immediate-width gate: an unrepresentable pair rejects
/// the fusion instead of silently truncating).
pub fn try_match(spec: &FusionSpec, window: &[Instr]) -> Option<Instr> {
    if window.len() != spec.pattern.len() {
        return None;
    }
    let mut cap = Captures::default();
    for (el, instr) in spec.pattern.iter().zip(window) {
        if !match_elem(*el, instr, &mut cap) {
            return None;
        }
    }
    match spec.emit {
        FusedEmit::Mac => Some(Instr::Mac),
        FusedEmit::Add2i | FusedEmit::FusedMac => {
            let (rs1, rs2, i1, i2) =
                spec.split.fit(spec.commute, cap.a?, cap.b?)?;
            Some(emit_instr(spec, rs1, rs2, i1, i2))
        }
        FusedEmit::Custom(_) => {
            let (ra, _) = cap.a?;
            let (rb, _) = cap.b?;
            let (i1, i2) = cap.imms.unwrap_or((0, 0));
            if !spec.split.encodes(i32::from(i1), i32::from(i2)) {
                return None;
            }
            Some(emit_instr(spec, ra, rb, i1, i2))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_boundaries_accept_and_reject() {
        let s = ImmSplit::PAPER;
        // exact field maxima encode; one past each rejects
        assert!(s.encodes(31, 1023));
        assert!(!s.encodes(32, 0));
        assert!(!s.encodes(0, 1024));
        // negatives always reject (unsigned fields)
        assert!(!s.encodes(-1, 0));
        assert!(!s.encodes(0, -1));
        assert!(s.encodes(0, 0));
    }

    #[test]
    fn split_clamped_by_hardware_field_widths() {
        // A mined 3+12 split would overflow the 10-bit i2 hardware slot:
        // values past 1023 must reject even though they fit 12 bits.
        let s = ImmSplit { bits1: 3, bits2: 12 };
        assert_eq!(s.max1(), 7);
        assert_eq!(s.max2(), 1023, "i2 clamped to the encoding field");
        assert!(s.encodes(7, 1023));
        assert!(!s.encodes(8, 0), "past the split's own 3-bit budget");
        assert!(!s.encodes(0, 1500), "fits 12 bits but not the hardware");
    }

    #[test]
    fn fit_commutes_only_when_allowed() {
        let s = ImmSplit::PAPER;
        assert_eq!(s.fit(true, ('a', 600), ('b', 3)), Some(('b', 'a', 3, 600)));
        assert_eq!(s.fit(false, ('a', 600), ('b', 3)), None);
        assert_eq!(s.fit(false, ('a', 3), ('b', 600)), Some(('a', 'b', 3, 600)));
        assert_eq!(s.fit(true, ('a', 600), ('b', 700)), None);
    }

    #[test]
    fn window_slots_are_dense_and_self_describing() {
        for (i, spec) in WINDOW.iter().enumerate() {
            assert_eq!(spec.emit, FusedEmit::Custom(i as u8), "{}", spec.name);
            assert_eq!(spec.cost.name, spec.name);
            assert!(!spec.sem.is_empty(), "{} must be executable", spec.name);
            assert_eq!(
                spec.cycles_saved as usize,
                spec.pattern.len() - 1,
                "{}: every replaced op is 1 cycle in the default model",
                spec.name
            );
        }
    }

    #[test]
    fn exec_sem_matches_unfused_ldmac_semantics() {
        let mut mem = Memory::new(64);
        mem.store_u8(16, 0x85).unwrap(); // -123 as i8
        mem.store_u8(20, 7).unwrap();
        let mut regs = [0i32; 32];
        regs[5] = 16;
        regs[6] = 20;
        regs[crate::isa::MAC_RD as usize] = 1000;
        exec_sem(window_spec(0).sem, &mut regs, &mut mem, 5, 6, 0, 0).unwrap();
        assert_eq!(regs[crate::isa::MAC_RS1 as usize], -123);
        assert_eq!(regs[crate::isa::MAC_RS2 as usize], 7);
        assert_eq!(regs[crate::isa::MAC_RD as usize], 1000 - 123 * 7);
    }

    #[test]
    fn exec_sem_ldmacpp_bumps_pointers_after_mac() {
        let mut mem = Memory::new(64);
        mem.store_u8(8, 2).unwrap();
        mem.store_u8(12, 3).unwrap();
        let mut regs = [0i32; 32];
        regs[5] = 8;
        regs[6] = 12;
        exec_sem(window_spec(1).sem, &mut regs, &mut mem, 5, 6, 1, 4).unwrap();
        assert_eq!(regs[crate::isa::MAC_RD as usize], 6);
        assert_eq!(regs[5], 9, "rs1 += i1 after the loads");
        assert_eq!(regs[6], 16, "rs2 += i2");
    }

    #[test]
    fn exec_sem_fault_commits_earlier_steps() {
        // Second load faults: the first load must already be architectural,
        // mirroring the unfused sequence faulting at its second lb.
        let mut mem = Memory::new(16);
        mem.store_u8(4, 9).unwrap();
        let mut regs = [0i32; 32];
        regs[5] = 4;
        regs[6] = 1 << 20; // out of bounds
        let err = exec_sem(window_spec(0).sem, &mut regs, &mut mem, 5, 6, 0, 0);
        assert!(err.is_err());
        assert_eq!(regs[crate::isa::MAC_RS1 as usize], 9, "first lb committed");
        assert_eq!(regs[crate::isa::MAC_RD as usize], 0, "mac never ran");
    }

    fn lb(rd: Reg, rp: Reg) -> Instr {
        Instr::Load { op: crate::isa::LoadOp::Lb, rd, rs1: rp, offset: 0 }
    }

    #[test]
    fn try_match_ladder_specs() {
        use crate::compiler::asm::{ACC, OPA, OPB, SCR};
        let mul = Instr::Op {
            op: crate::isa::AluOp::Mul, rd: SCR, rs1: OPA, rs2: OPB,
        };
        let acc = Instr::Op {
            op: crate::isa::AluOp::Add, rd: ACC, rs1: ACC, rs2: SCR,
        };
        let addi = |r: Reg, imm: i32| Instr::OpImm {
            op: crate::isa::AluImmOp::Addi, rd: r, rs1: r, imm,
        };
        assert_eq!(try_match(&MAC, &[mul, acc]), Some(Instr::Mac));
        // commuting: first imm too big for the 5-bit slot, swap fits
        assert_eq!(
            try_match(&FUSEDMAC, &[mul, acc, addi(10, 600), addi(11, 3)]),
            Some(Instr::FusedMac { rs1: 11, rs2: 10, i1: 3, i2: 600 })
        );
        // reserved register in the addi pair rejects
        assert_eq!(
            try_match(&FUSEDMAC, &[mul, acc, addi(ACC, 1), addi(11, 1)]),
            None
        );
        // same register twice: not independent
        assert_eq!(try_match(&ADD2I, &[addi(10, 1), addi(10, 2)]), None);
    }

    #[test]
    fn try_match_ldmac_captures_pointers() {
        assert_eq!(
            try_match(WINDOW[0], &[lb(21, 5), lb(22, 6), Instr::Mac]),
            Some(Instr::Custom { idx: 0, rs1: 5, rs2: 6, i1: 0, i2: 0 })
        );
        // same pointer feeding both loads: no dual port
        assert_eq!(
            try_match(WINDOW[0], &[lb(21, 5), lb(22, 5), Instr::Mac]),
            None
        );
        // wrong destination registers
        assert_eq!(
            try_match(WINDOW[0], &[lb(21, 5), lb(23, 6), Instr::Mac]),
            None
        );
        // reserved pointer register
        assert_eq!(
            try_match(WINDOW[0], &[lb(21, 20), lb(22, 6), Instr::Mac]),
            None
        );
    }

    #[test]
    fn try_match_ldmacpp_requires_exact_fusedmac_operands() {
        let fm = Instr::FusedMac { rs1: 5, rs2: 6, i1: 1, i2: 4 };
        assert_eq!(
            try_match(WINDOW[1], &[lb(21, 5), lb(22, 6), fm]),
            Some(Instr::Custom { idx: 1, rs1: 5, rs2: 6, i1: 1, i2: 4 })
        );
        // commuted fusedmac: loads and bumps would disagree on fields
        let swapped = Instr::FusedMac { rs1: 6, rs2: 5, i1: 1, i2: 4 };
        assert_eq!(try_match(WINDOW[1], &[lb(21, 5), lb(22, 6), swapped]), None);
    }

    #[test]
    fn try_match_ldadd_matches_eltwise_add_body() {
        use crate::compiler::asm::{ACC, OPA, OPB, SCR};
        let add = Instr::Op {
            op: crate::isa::AluOp::Add, rd: ACC, rs1: OPA, rs2: OPB,
        };
        assert_eq!(
            try_match(WINDOW[2], &[lb(21, 5), lb(22, 6), add]),
            Some(Instr::Custom { idx: 2, rs1: 5, rs2: 6, i1: 0, i2: 0 })
        );
        // the ladder's accumulate shape (add x20,x20,x23) must not match —
        // ldadd is strictly the eltwise form
        let acc = Instr::Op {
            op: crate::isa::AluOp::Add, rd: ACC, rs1: ACC, rs2: SCR,
        };
        assert_eq!(try_match(WINDOW[2], &[lb(21, 5), lb(22, 6), acc]), None);
        // shared pointer rejects, like every dual-port spec
        assert_eq!(try_match(WINDOW[2], &[lb(21, 5), lb(22, 5), add]), None);
    }

    #[test]
    fn exec_sem_ldadd_is_the_unfused_add_chain() {
        let mut mem = Memory::new(64);
        mem.store_u8(16, 0x85).unwrap(); // -123 as i8
        mem.store_u8(20, 7).unwrap();
        let mut regs = [0i32; 32];
        regs[5] = 16;
        regs[6] = 20;
        regs[crate::isa::MAC_RD as usize] = 1000; // overwritten, not accumulated
        exec_sem(window_spec(2).sem, &mut regs, &mut mem, 5, 6, 0, 0).unwrap();
        assert_eq!(regs[crate::isa::MAC_RS1 as usize], -123);
        assert_eq!(regs[crate::isa::MAC_RS2 as usize], 7);
        assert_eq!(regs[crate::isa::MAC_RD as usize], -123 + 7);
        // pointers untouched: ldadd has no post-increment
        assert_eq!((regs[5], regs[6]), (16, 20));
    }

    #[test]
    fn exec_sem_x0_operand_stays_hardwired() {
        // add2i with rs1 = x0 (possible in decoded/random programs): the
        // write must be discarded exactly like the reference write_reg.
        let mut mem = Memory::new(16);
        let mut regs = [0i32; 32];
        exec_sem(ADD2I.sem, &mut regs, &mut mem, 0, 3, 5, 7).unwrap();
        assert_eq!(regs[0], 0);
        assert_eq!(regs[3], 7);
    }
}
