//! FPGA area/power model calibrated against the paper's Table 8.
//!
//! Per-extension increments are the successive deltas of the measured
//! variants (v1−v0, v2−v1, …).  Two of the numbers deserve comment:
//! `fusedmac`'s **negative** LUT delta reproduces the paper's observation
//! that v3 synthesizes smaller than v2 (the fused datapath lets Vivado share
//! the mac/add2i logic it had duplicated), and `zol`'s register-heavy delta
//! is the three new ZC/ZS/ZE loop registers plus the PCU changes (§II.C.4).

use crate::sim::Variant;

/// Resource vector for one core (the Table 8 columns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaReport {
    pub lut: i64,
    pub mux: i64,
    pub regs: i64,
    pub dsp: i64,
    /// Post-implementation power estimate, milliwatts.
    pub power_mw: f64,
}

impl AreaReport {
    pub fn add(&self, d: &FuCost) -> AreaReport {
        AreaReport {
            lut: self.lut + d.lut,
            mux: self.mux + d.mux,
            regs: self.regs + d.regs,
            dsp: self.dsp + d.dsp,
            power_mw: self.power_mw + d.power_mw,
        }
    }
}

/// Incremental cost of one functional unit / extension.
#[derive(Clone, Copy, Debug)]
pub struct FuCost {
    pub name: &'static str,
    pub lut: i64,
    pub mux: i64,
    pub regs: i64,
    pub dsp: i64,
    pub power_mw: f64,
}

/// Baseline trv32p3 (Table 8 row v0).
pub const BASELINE: AreaReport = AreaReport {
    lut: 4492,
    mux: 905,
    regs: 1923,
    dsp: 4,
    power_mw: 830.0,
};

/// Calibrated per-extension increments (successive Table 8 deltas).
pub const FU_COSTS: [FuCost; 4] = [
    // v1 − v0: the 32-bit single-cycle MAC unit maps to 3 extra DSP slices
    FuCost { name: "mac", lut: 971, mux: -1, regs: 4, dsp: 3, power_mw: 22.0 },
    // v2 − v1: dual-immediate adder + the wide-immediate decoder
    FuCost { name: "add2i", lut: 946, mux: 8, regs: 19, dsp: 0, power_mw: -2.0 },
    // v3 − v2: fusing lets synthesis share the mac/add2i datapaths (< 0)
    FuCost { name: "fusedmac", lut: -564, mux: -2, regs: -8, dsp: 0, power_mw: -3.0 },
    // v4 − v3: ZC/ZS/ZE registers + PCU loop-back mux
    FuCost { name: "zol", lut: 362, mux: 0, regs: 330, dsp: 0, power_mw: 2.0 },
];

/// Area/power of a core variant.
pub fn area_of(v: &Variant) -> AreaReport {
    let mut a = BASELINE;
    if v.mac {
        a = a.add(&FU_COSTS[0]);
    }
    if v.add2i {
        a = a.add(&FU_COSTS[1]);
    }
    if v.fusedmac {
        a = a.add(&FU_COSTS[2]);
    }
    if v.zol {
        a = a.add(&FU_COSTS[3]);
    }
    // Mined window slots price in per enabled bit (DESIGN.md §17) — the
    // spec pool carries each slot's calibrated increment.
    for spec in crate::fusion::mask_specs(v.xwin) {
        a = a.add(&spec.cost);
    }
    a
}

/// Overhead of `v` relative to the baseline, as (absolute, percent) per
/// resource — the Table 8 "Overhead" row.
pub fn overhead(v: &Variant) -> Vec<(&'static str, i64, f64)> {
    let a = area_of(v);
    let b = BASELINE;
    vec![
        ("LUT", a.lut - b.lut, pct(a.lut, b.lut)),
        ("MUX", a.mux - b.mux, pct(a.mux, b.mux)),
        ("Registers", a.regs - b.regs, pct(a.regs, b.regs)),
        ("DSP", a.dsp - b.dsp, pct(a.dsp, b.dsp)),
    ]
}

fn pct(a: i64, b: i64) -> f64 {
    (a - b) as f64 / b as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{V0, V1, V2, V3, V4};

    #[test]
    fn reproduces_table8_rows() {
        // Paper Table 8, LUT / MUX / Registers / DSP / Power
        let rows = [
            (V0, 4492, 905, 1923, 4, 830.0),
            (V1, 5463, 904, 1927, 7, 852.0),
            (V2, 6409, 912, 1946, 7, 850.0),
            (V3, 5845, 910, 1938, 7, 847.0),
            (V4, 6207, 910, 2268, 7, 849.0),
        ];
        for (v, lut, mux, regs, dsp, mw) in rows {
            let a = area_of(&v);
            assert_eq!(
                (a.lut, a.mux, a.regs, a.dsp),
                (lut, mux, regs, dsp),
                "{}",
                v.name
            );
            assert!((a.power_mw - mw).abs() < 1e-9, "{} power", v.name);
        }
    }

    #[test]
    fn reproduces_table8_overhead_row() {
        // Paper: LUT +1,715 (38.17%), MUX +5 (0.5%), regs +345 (17.94%),
        // DSP +3 (75%), power +19 mW (2.28%)
        let o = overhead(&V4);
        assert_eq!(o[0].1, 1715);
        assert!((o[0].2 - 38.17).abs() < 0.02, "LUT% {}", o[0].2);
        assert_eq!(o[1].1, 5);
        assert!((o[1].2 - 0.55).abs() < 0.06, "MUX% {}", o[1].2);
        assert_eq!(o[2].1, 345);
        assert!((o[2].2 - 17.94).abs() < 0.02);
        assert_eq!(o[3].1, 3);
        assert!((o[3].2 - 75.0).abs() < 1e-9);
        let p = area_of(&V4).power_mw - BASELINE.power_mw;
        assert!((p - 19.0).abs() < 1e-9);
        assert!((p / BASELINE.power_mw * 100.0 - 2.28).abs() < 0.02);
    }

    #[test]
    fn window_slots_price_exactly_their_spec_cost() {
        let base = area_of(&V4);
        for idx in 0..crate::fusion::N_WINDOW {
            let v = Variant::with_window(V4, 1 << idx).unwrap();
            let a = area_of(&v);
            let c = crate::fusion::window_spec(idx as u8).cost;
            assert_eq!(a.lut - base.lut, c.lut, "slot {idx} lut");
            assert_eq!(a.mux - base.mux, c.mux, "slot {idx} mux");
            assert_eq!(a.regs - base.regs, c.regs, "slot {idx} regs");
            assert_eq!(a.dsp - base.dsp, c.dsp, "slot {idx} dsp");
            assert!((a.power_mw - base.power_mw - c.power_mw).abs() < 1e-9);
        }
        // both slots together = sum of increments
        let full = (1u8 << crate::fusion::N_WINDOW) - 1;
        let v = Variant::with_window(V4, full).unwrap();
        let a = area_of(&v);
        let want: i64 = crate::fusion::mask_specs(full).map(|s| s.cost.lut).sum();
        assert_eq!(a.lut - base.lut, want);
    }
}
