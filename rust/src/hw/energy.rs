//! Energy model — the paper's eq. (1): E = P · (C / f).
//!
//! P comes from the calibrated area/power model, C from the cycle-accurate
//! simulation, f is the 100 MHz evaluation clock (§III.B: chosen because v4
//! meets timing at 100 MHz on the ZCU104 with no RTL changes).

use super::area::area_of;
use crate::sim::Variant;

/// Evaluation clock (Hz).
pub const CLOCK_HZ: f64 = 100_000_000.0;

/// One (variant, model) energy measurement.
#[derive(Clone, Copy, Debug)]
pub struct EnergyPoint {
    pub cycles: u64,
    pub power_mw: f64,
    pub time_ms: f64,
    pub energy_mj: f64,
}

/// Energy per inference in millijoules for `cycles` on `variant`.
pub fn energy_mj(variant: &Variant, cycles: u64) -> EnergyPoint {
    let power_mw = area_of(variant).power_mw;
    let time_s = cycles as f64 / CLOCK_HZ;
    EnergyPoint {
        cycles,
        power_mw,
        time_ms: time_s * 1e3,
        energy_mj: power_mw * time_s, // mW · s = mJ
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{V0, V4};

    #[test]
    fn eq1_arithmetic() {
        // 1e8 cycles at 100 MHz = 1 s; at 830 mW that is 830 mJ.
        let e = energy_mj(&V0, 100_000_000);
        assert!((e.time_ms - 1000.0).abs() < 1e-9);
        assert!((e.energy_mj - 830.0).abs() < 1e-9);
    }

    #[test]
    fn v4_halving_cycles_halves_energy_modulo_power_delta() {
        let e0 = energy_mj(&V0, 2_000_000);
        let e4 = energy_mj(&V4, 1_000_000);
        // 2x cycle reduction at +2.3% power => ~1.96x energy reduction
        let ratio = e0.energy_mj / e4.energy_mj;
        assert!(ratio > 1.9 && ratio < 2.0, "ratio {ratio}");
    }
}
