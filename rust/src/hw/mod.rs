//! Hardware cost models: FPGA area, power, and energy per inference.
//!
//! The paper implements each core variant on a ZCU104 with Vivado and
//! reports LUT/MUX/register/DSP utilisation and post-implementation power
//! (Table 8, Fig 10) plus energy per inference E = P·C/f at f = 100 MHz
//! (eq. 1, Fig 12).  Offline we replace Vivado with a **parametric model**:
//! a baseline-core cost plus one calibrated increment per functional unit,
//! where the increments are the exact deltas of the paper's Table 8 — so the
//! variant table reproduces the paper by construction, and `extgen` can
//! price *proposed* extensions with the same unit costs (DESIGN.md §2).

pub mod area;
pub mod energy;

pub use area::{area_of, overhead, AreaReport, FuCost, BASELINE, FU_COSTS};
pub use energy::{energy_mj, EnergyPoint, CLOCK_HZ};
