//! Instruction decoding: 32-bit machine words → [`Instr`].
//!
//! `decode` is total over the words `encode` produces (round-trip property
//! tested) and returns a structured error for everything else — the
//! simulator surfaces that as an illegal-instruction trap, which is also how
//! running v1..v4 binaries on a v0 core fails loudly rather than silently.

use super::*;

/// Decode failure (the simulator's illegal-instruction trap payload).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecodeError {
    pub word: u32,
    pub reason: &'static str,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal instruction {:#010x}: {}", self.word, self.reason)
    }
}

impl std::error::Error for DecodeError {}

fn err(word: u32, reason: &'static str) -> Result<Instr, DecodeError> {
    Err(DecodeError { word, reason })
}

#[inline]
fn rd(w: u32) -> Reg {
    ((w >> 7) & 0x1f) as Reg
}
#[inline]
fn rs1(w: u32) -> Reg {
    ((w >> 15) & 0x1f) as Reg
}
#[inline]
fn rs2(w: u32) -> Reg {
    ((w >> 20) & 0x1f) as Reg
}
#[inline]
fn funct3(w: u32) -> u32 {
    (w >> 12) & 0b111
}
#[inline]
fn funct7(w: u32) -> u32 {
    w >> 25
}

/// Sign-extended 12-bit I-type immediate.
#[inline]
fn imm_i(w: u32) -> i32 {
    (w as i32) >> 20
}

/// Decode one machine word.
pub fn decode(w: u32) -> Result<Instr, DecodeError> {
    use opcodes::*;
    match w & 0x7f {
        LUI => Ok(Instr::Lui { rd: rd(w), imm: (w & 0xffff_f000) as i32 }),
        AUIPC => Ok(Instr::Auipc { rd: rd(w), imm: (w & 0xffff_f000) as i32 }),
        JAL => {
            let i = ((((w >> 31) & 1) << 20)
                | (((w >> 12) & 0xff) << 12)
                | (((w >> 20) & 1) << 11)
                | (((w >> 21) & 0x3ff) << 1)) as i32;
            let offset = (i << 11) >> 11; // sign-extend 21 bits
            Ok(Instr::Jal { rd: rd(w), offset })
        }
        JALR => {
            if funct3(w) != 0 {
                return err(w, "jalr funct3");
            }
            Ok(Instr::Jalr { rd: rd(w), rs1: rs1(w), offset: imm_i(w) })
        }
        BRANCH => {
            let op = match funct3(w) {
                0b000 => BranchOp::Beq,
                0b001 => BranchOp::Bne,
                0b100 => BranchOp::Blt,
                0b101 => BranchOp::Bge,
                0b110 => BranchOp::Bltu,
                0b111 => BranchOp::Bgeu,
                _ => return err(w, "branch funct3"),
            };
            let i = ((((w >> 31) & 1) << 12)
                | (((w >> 7) & 1) << 11)
                | (((w >> 25) & 0x3f) << 5)
                | (((w >> 8) & 0xf) << 1)) as i32;
            let offset = (i << 19) >> 19; // sign-extend 13 bits
            Ok(Instr::Branch { op, rs1: rs1(w), rs2: rs2(w), offset })
        }
        LOAD => {
            let op = match funct3(w) {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => return err(w, "load funct3"),
            };
            Ok(Instr::Load { op, rd: rd(w), rs1: rs1(w), offset: imm_i(w) })
        }
        STORE => {
            let op = match funct3(w) {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => return err(w, "store funct3"),
            };
            let offset =
                ((((w >> 25) << 5) | ((w >> 7) & 0x1f)) as i32) << 20 >> 20;
            Ok(Instr::Store { op, rs2: rs2(w), rs1: rs1(w), offset })
        }
        OP_IMM => {
            let (op, imm) = match funct3(w) {
                0b000 => (AluImmOp::Addi, imm_i(w)),
                0b010 => (AluImmOp::Slti, imm_i(w)),
                0b011 => (AluImmOp::Sltiu, imm_i(w)),
                0b100 => (AluImmOp::Xori, imm_i(w)),
                0b110 => (AluImmOp::Ori, imm_i(w)),
                0b111 => (AluImmOp::Andi, imm_i(w)),
                0b001 => {
                    if funct7(w) != 0 {
                        return err(w, "slli funct7");
                    }
                    (AluImmOp::Slli, ((w >> 20) & 0x1f) as i32)
                }
                0b101 => match funct7(w) {
                    0b000_0000 => (AluImmOp::Srli, ((w >> 20) & 0x1f) as i32),
                    0b010_0000 => (AluImmOp::Srai, ((w >> 20) & 0x1f) as i32),
                    _ => return err(w, "srli/srai funct7"),
                },
                _ => unreachable!(),
            };
            Ok(Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm })
        }
        OP => {
            let op = match (funct7(w), funct3(w)) {
                (0b000_0000, 0b000) => AluOp::Add,
                (0b010_0000, 0b000) => AluOp::Sub,
                (0b000_0000, 0b001) => AluOp::Sll,
                (0b000_0000, 0b010) => AluOp::Slt,
                (0b000_0000, 0b011) => AluOp::Sltu,
                (0b000_0000, 0b100) => AluOp::Xor,
                (0b000_0000, 0b101) => AluOp::Srl,
                (0b010_0000, 0b101) => AluOp::Sra,
                (0b000_0000, 0b110) => AluOp::Or,
                (0b000_0000, 0b111) => AluOp::And,
                (0b000_0001, 0b000) => AluOp::Mul,
                (0b000_0001, 0b001) => AluOp::Mulh,
                (0b000_0001, 0b010) => AluOp::Mulhsu,
                (0b000_0001, 0b011) => AluOp::Mulhu,
                (0b000_0001, 0b100) => AluOp::Div,
                (0b000_0001, 0b101) => AluOp::Divu,
                (0b000_0001, 0b110) => AluOp::Rem,
                (0b000_0001, 0b111) => AluOp::Remu,
                _ => return err(w, "op funct7/funct3"),
            };
            Ok(Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) })
        }
        MISC_MEM => Ok(Instr::Fence),
        SYSTEM => match w >> 20 {
            0 => Ok(Instr::Ecall),
            1 => Ok(Instr::Ebreak),
            _ => err(w, "system imm"),
        },
        // --- custom ---
        CUSTOM2_MAC => {
            if funct7(w) == 0b010_0000 && funct3(w) == 0 {
                Ok(Instr::Mac)
            } else {
                err(w, "mac funct fields")
            }
        }
        CUSTOM1_ADD2I => {
            let (r1, r2, i1, i2) = fused_fields(w);
            Ok(Instr::Add2i { rs1: r1, rs2: r2, i1, i2 })
        }
        CUSTOM0_FUSEDMAC => {
            let (r1, r2, i1, i2) = fused_fields(w);
            Ok(Instr::FusedMac { rs1: r1, rs2: r2, i1, i2 })
        }
        ZOL1 => {
            let body_len = (w >> 20) as u16;
            if body_len == 0 {
                return err(w, "zol body_len 0");
            }
            match funct3(w) {
                0b000 => Ok(Instr::Dlp { rs1: rs1(w), body_len }),
                0b001 => {
                    let count = rs1(w);
                    if count == 0 {
                        return err(w, "dlpi count 0");
                    }
                    Ok(Instr::Dlpi { count, body_len })
                }
                0b010 => Ok(Instr::Zlp { rs1: rs1(w), body_len }),
                _ => err(w, "zol1 funct3"),
            }
        }
        ZOL2 => match funct3(w) {
            0b000 => Ok(Instr::SetZc { rs1: rs1(w) }),
            0b001 => Ok(Instr::SetZs { rs1: rs1(w) }),
            0b010 => Ok(Instr::SetZe { rs1: rs1(w) }),
            _ => err(w, "zol2 funct3"),
        },
        opc => {
            // Window slots: the opcode *is* the slot index (one reserved
            // opcode per pool entry, fused field layout).
            for (idx, &xop) in XWIN.iter().enumerate() {
                if opc == xop && idx < crate::fusion::N_WINDOW {
                    let (r1, r2, i1, i2) = fused_fields(w);
                    return Ok(Instr::Custom {
                        idx: idx as u8,
                        rs1: r1,
                        rs2: r2,
                        i1,
                        i2,
                    });
                }
            }
            err(w, "unknown opcode")
        }
    }
}

/// Shared field extraction for add2i/fusedmac (Tables 5/6).
fn fused_fields(w: u32) -> (Reg, Reg, u8, u16) {
    let r1 = rd(w); // rs1 sits in the rd slot
    let r2 = rs1(w); // rs2 sits in the rs1 slot
    let i1 = ((funct3(w) as u8) & 0b111) | ((((w >> 20) & 0b11) as u8) << 3);
    let i2 = (w >> 22) as u16;
    (r1, r2, i1, i2)
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::*;

    #[test]
    fn decode_known_words() {
        // addi x10, x11, -3
        assert_eq!(
            decode(0xffd5_8513).unwrap(),
            Instr::OpImm { op: AluImmOp::Addi, rd: 10, rs1: 11, imm: -3 }
        );
        // ecall
        assert_eq!(decode(0x0000_0073).unwrap(), Instr::Ecall);
    }

    #[test]
    fn illegal_words_rejected() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_0000).is_err()); // opcode 0 is not valid
        // branch with funct3=010 is illegal
        let bad = 0b0000000_00001_00010_010_00000_1100011u32;
        assert!(decode(bad).is_err());
    }

    #[test]
    fn negative_offsets_roundtrip() {
        for &off in &[-4096i32, -2, 0, 2, 4094] {
            let i = Instr::Branch {
                op: BranchOp::Blt,
                rs1: 3,
                rs2: 4,
                offset: off,
            };
            assert_eq!(decode(encode(&i)).unwrap(), i, "offset {off}");
        }
        for &off in &[-(1 << 20), -2, 0, 2, (1 << 20) - 2] {
            let i = Instr::Jal { rd: 1, offset: off };
            assert_eq!(decode(encode(&i)).unwrap(), i, "offset {off}");
        }
    }

    #[test]
    fn fused_fields_roundtrip() {
        for (i1, i2) in [(0u8, 0u16), (31, 1023), (5, 1), (24, 512)] {
            let i = Instr::FusedMac { rs1: 9, rs2: 10, i1, i2 };
            assert_eq!(decode(encode(&i)).unwrap(), i);
            let i = Instr::Add2i { rs1: 30, rs2: 31, i1, i2 };
            assert_eq!(decode(encode(&i)).unwrap(), i);
        }
    }
}
