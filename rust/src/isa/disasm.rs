//! Disassembly: [`Instr`] → assembly text (the format used by the Fig 5
//! listings and the simulator traces).

use super::*;

fn r(reg: Reg) -> &'static str {
    REG_NAMES[reg as usize]
}

/// Render one instruction as assembly text.
pub fn disasm(i: &Instr) -> String {
    match *i {
        Instr::Lui { rd, imm } => format!("lui {}, {:#x}", r(rd), (imm as u32) >> 12),
        Instr::Auipc { rd, imm } => {
            format!("auipc {}, {:#x}", r(rd), (imm as u32) >> 12)
        }
        Instr::Jal { rd, offset } => format!("jal {}, {}", r(rd), offset),
        Instr::Jalr { rd, rs1, offset } => {
            format!("jalr {}, {}({})", r(rd), offset, r(rs1))
        }
        Instr::Branch { rs1, rs2, offset, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rs1), r(rs2), offset)
        }
        Instr::Load { rd, rs1, offset, .. } => {
            format!("{} {}, {}({})", i.mnemonic(), r(rd), offset, r(rs1))
        }
        Instr::Store { rs2, rs1, offset, .. } => {
            format!("{} {}, {}({})", i.mnemonic(), r(rs2), offset, r(rs1))
        }
        Instr::OpImm { rd, rs1, imm, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rd), r(rs1), imm)
        }
        Instr::Op { rd, rs1, rs2, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rd), r(rs1), r(rs2))
        }
        Instr::Fence => "fence".into(),
        Instr::Ecall => "ecall".into(),
        Instr::Ebreak => "ebreak".into(),
        Instr::Mac => "mac".into(), // fixed x20, x21, x22 (Listing 1)
        Instr::Add2i { rs1, rs2, i1, i2 } => {
            format!("add2i {}, {}, {}, {}", r(rs1), r(rs2), i1, i2)
        }
        Instr::FusedMac { rs1, rs2, i1, i2 } => {
            format!("fusedmac {}, {}, {}, {}", r(rs1), r(rs2), i1, i2)
        }
        Instr::Dlp { rs1, body_len } => format!("dlp {}, {}", r(rs1), body_len),
        Instr::Dlpi { count, body_len } => {
            format!("dlpi {}, {}", count, body_len)
        }
        Instr::Zlp { rs1, body_len } => format!("zlp {}, {}", r(rs1), body_len),
        Instr::SetZc { rs1 } => format!("set.zc {}", r(rs1)),
        Instr::SetZs { rs1 } => format!("set.zs {}", r(rs1)),
        Instr::SetZe { rs1 } => format!("set.ze {}", r(rs1)),
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&disasm(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(
            disasm(&Instr::OpImm { op: AluImmOp::Addi, rd: 10, rs1: 10, imm: 1 }),
            "addi x10, x10, 1"
        );
        assert_eq!(
            disasm(&Instr::Load { op: LoadOp::Lb, rd: 21, rs1: 5, offset: -4 }),
            "lb x21, -4(x5)"
        );
        assert_eq!(disasm(&Instr::Mac), "mac");
        assert_eq!(
            disasm(&Instr::FusedMac { rs1: 5, rs2: 6, i1: 1, i2: 128 }),
            "fusedmac x5, x6, 1, 128"
        );
        assert_eq!(disasm(&Instr::Dlpi { count: 7, body_len: 3 }), "dlpi 7, 3");
    }
}
