//! Disassembly: [`Instr`] → assembly text (the format used by the Fig 5
//! listings and the simulator traces).

use super::*;

fn r(reg: Reg) -> &'static str {
    REG_NAMES[reg as usize]
}

/// The 20-bit U-type immediate field an assembler expects after `lui` /
/// `auipc`.  `imm` is carried as the full shifted 32-bit value (what the
/// instruction deposits in `rd`); the *logical* u32 shift drops the low 12
/// bits and cannot sign-extend, so the result is exactly the encoded
/// word's top 20 bits for every `imm`, negative ones and hand-built
/// non-canonical ones (low 12 bits set) included.  The encode→decode→
/// disasm round-trip tests below pin that equivalence over the boundary
/// immediates.
fn u_imm_field(imm: i32) -> u32 {
    (imm as u32) >> 12
}

/// Render one instruction as assembly text.
pub fn disasm(i: &Instr) -> String {
    match *i {
        Instr::Lui { rd, imm } => {
            format!("lui {}, {:#x}", r(rd), u_imm_field(imm))
        }
        Instr::Auipc { rd, imm } => {
            format!("auipc {}, {:#x}", r(rd), u_imm_field(imm))
        }
        Instr::Jal { rd, offset } => format!("jal {}, {}", r(rd), offset),
        Instr::Jalr { rd, rs1, offset } => {
            format!("jalr {}, {}({})", r(rd), offset, r(rs1))
        }
        Instr::Branch { rs1, rs2, offset, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rs1), r(rs2), offset)
        }
        Instr::Load { rd, rs1, offset, .. } => {
            format!("{} {}, {}({})", i.mnemonic(), r(rd), offset, r(rs1))
        }
        Instr::Store { rs2, rs1, offset, .. } => {
            format!("{} {}, {}({})", i.mnemonic(), r(rs2), offset, r(rs1))
        }
        Instr::OpImm { rd, rs1, imm, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rd), r(rs1), imm)
        }
        Instr::Op { rd, rs1, rs2, .. } => {
            format!("{} {}, {}, {}", i.mnemonic(), r(rd), r(rs1), r(rs2))
        }
        Instr::Fence => "fence".into(),
        Instr::Ecall => "ecall".into(),
        Instr::Ebreak => "ebreak".into(),
        Instr::Mac => "mac".into(), // fixed x20, x21, x22 (Listing 1)
        Instr::Add2i { rs1, rs2, i1, i2 } => {
            format!("add2i {}, {}, {}, {}", r(rs1), r(rs2), i1, i2)
        }
        Instr::FusedMac { rs1, rs2, i1, i2 } => {
            format!("fusedmac {}, {}, {}, {}", r(rs1), r(rs2), i1, i2)
        }
        Instr::Dlp { rs1, body_len } => format!("dlp {}, {}", r(rs1), body_len),
        Instr::Dlpi { count, body_len } => {
            format!("dlpi {}, {}", count, body_len)
        }
        Instr::Zlp { rs1, body_len } => format!("zlp {}, {}", r(rs1), body_len),
        Instr::SetZc { rs1 } => format!("set.zc {}", r(rs1)),
        Instr::SetZs { rs1 } => format!("set.zs {}", r(rs1)),
        Instr::SetZe { rs1 } => format!("set.ze {}", r(rs1)),
        Instr::Custom { idx, rs1, rs2, i1, i2 } => {
            // the spec's name is the mnemonic (e.g. `ldmac x5, x6, 0, 0`)
            format!(
                "{} {}, {}, {}, {}",
                crate::fusion::window_spec(idx).name,
                r(rs1), r(rs2), i1, i2
            )
        }
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&disasm(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode::decode;
    use crate::isa::encode::encode;

    /// encode → decode → disasm round-trip over negative and boundary
    /// U-type immediates: the decoded instruction must equal the original
    /// and the printed field must be exactly the encoded word's top 20
    /// bits.
    #[test]
    fn u_type_roundtrip_negative_and_boundary() {
        for imm in [
            0i32,
            0x1000,
            0x7fff_f000,          // most positive canonical imm
            -4096,                // 0xffff_f000: smallest negative
            i32::MIN,             // 0x8000_0000: sign-bit-only field
            i32::MIN + 0x1000,    // 0x8000_1000
            0x0012_3000,
            -0x0012_3000i32 & !0xfff,
        ] {
            for instr in [
                Instr::Lui { rd: 5, imm },
                Instr::Auipc { rd: 7, imm },
            ] {
                let w = encode(&instr);
                let back = decode(w).unwrap();
                assert_eq!(back, instr, "decode({w:#010x})");
                let field = w >> 12;
                let want_tail = format!("{field:#x}");
                let text = disasm(&back);
                assert!(
                    text.ends_with(&want_tail),
                    "disasm({instr:?}) = {text:?}, want field {want_tail} \
                     (word {w:#010x})"
                );
            }
        }
    }

    /// A hand-built non-canonical immediate (low 12 bits set) must not
    /// leak into the printed 20-bit field.
    #[test]
    fn u_type_non_canonical_imm_masked() {
        let text = disasm(&Instr::Lui { rd: 1, imm: 0x1234_5fff_u32 as i32 });
        assert_eq!(text, "lui x1, 0x12345");
        let text = disasm(&Instr::Auipc { rd: 2, imm: -1 }); // 0xffff_ffff
        assert_eq!(text, "auipc x2, 0xfffff");
    }

    #[test]
    fn formats() {
        assert_eq!(
            disasm(&Instr::OpImm { op: AluImmOp::Addi, rd: 10, rs1: 10, imm: 1 }),
            "addi x10, x10, 1"
        );
        assert_eq!(
            disasm(&Instr::Load { op: LoadOp::Lb, rd: 21, rs1: 5, offset: -4 }),
            "lb x21, -4(x5)"
        );
        assert_eq!(disasm(&Instr::Mac), "mac");
        assert_eq!(
            disasm(&Instr::FusedMac { rs1: 5, rs2: 6, i1: 1, i2: 128 }),
            "fusedmac x5, x6, 1, 128"
        );
        assert_eq!(disasm(&Instr::Dlpi { count: 7, body_len: 3 }), "dlpi 7, 3");
    }
}
