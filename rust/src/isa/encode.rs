//! Instruction encoding: [`Instr`] → 32-bit machine words.
//!
//! Standard RV32 formats for the base ISA; the custom formats follow the
//! paper's Tables 4–6 (see module docs in `isa`).  Encoding validates field
//! ranges (immediate widths, register indices) and panics on violations —
//! the assembler is responsible for only constructing encodable instructions
//! (checked at codegen time), so a violation here is a compiler bug.

use super::*;

fn check_reg(r: Reg) -> u32 {
    assert!(r < 32, "register index out of range: {r}");
    r as u32
}

fn imm12(imm: i32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "imm12 out of range: {imm}");
    (imm as u32) & 0xfff
}

fn r_type(funct7: u32, rs2: Reg, rs1: Reg, funct3: u32, rd: Reg, op: u32) -> u32 {
    (funct7 << 25)
        | (check_reg(rs2) << 20)
        | (check_reg(rs1) << 15)
        | (funct3 << 12)
        | (check_reg(rd) << 7)
        | op
}

fn i_type(imm: i32, rs1: Reg, funct3: u32, rd: Reg, op: u32) -> u32 {
    (imm12(imm) << 20)
        | (check_reg(rs1) << 15)
        | (funct3 << 12)
        | (check_reg(rd) << 7)
        | op
}

fn s_type(imm: i32, rs2: Reg, rs1: Reg, funct3: u32, op: u32) -> u32 {
    let i = imm12(imm);
    ((i >> 5) << 25)
        | (check_reg(rs2) << 20)
        | (check_reg(rs1) << 15)
        | (funct3 << 12)
        | ((i & 0x1f) << 7)
        | op
}

fn b_type(offset: i32, rs2: Reg, rs1: Reg, funct3: u32, op: u32) -> u32 {
    assert!(
        (-4096..=4094).contains(&offset) && offset % 2 == 0,
        "branch offset out of range/misaligned: {offset}"
    );
    let i = (offset as u32) & 0x1fff;
    (((i >> 12) & 1) << 31)
        | (((i >> 5) & 0x3f) << 25)
        | (check_reg(rs2) << 20)
        | (check_reg(rs1) << 15)
        | (funct3 << 12)
        | (((i >> 1) & 0xf) << 8)
        | (((i >> 11) & 1) << 7)
        | op
}

fn u_type(imm: i32, rd: Reg, op: u32) -> u32 {
    assert_eq!(imm & 0xfff, 0, "u-type imm must be 4KiB aligned: {imm:#x}");
    (imm as u32) | (check_reg(rd) << 7) | op
}

fn j_type(offset: i32, rd: Reg, op: u32) -> u32 {
    assert!(
        (-(1 << 20)..(1 << 20)).contains(&offset) && offset % 2 == 0,
        "jal offset out of range/misaligned: {offset}"
    );
    let i = (offset as u32) & 0x1f_ffff;
    (((i >> 20) & 1) << 31)
        | (((i >> 1) & 0x3ff) << 21)
        | (((i >> 11) & 1) << 20)
        | (((i >> 12) & 0xff) << 12)
        | (check_reg(rd) << 7)
        | op
}

/// add2i/fusedmac format (Tables 5/6):
/// `[31:22]=i2[9:0]  [21:20]=i1[4:3]  [19:15]=rs2  [14:12]=i1[2:0]  [11:7]=rs1`
fn fused_type(rs1: Reg, rs2: Reg, i1: u8, i2: u16, op: u32) -> u32 {
    assert!(i1 < 32, "add2i i1 out of range (5 bits): {i1}");
    assert!(i2 < 1024, "add2i i2 out of range (10 bits): {i2}");
    ((i2 as u32) << 22)
        | ((((i1 as u32) >> 3) & 0b11) << 20)
        | (check_reg(rs2) << 15)
        | (((i1 as u32) & 0b111) << 12)
        | (check_reg(rs1) << 7)
        | op
}

fn zol_body_len(body_len: u16) -> u32 {
    assert!(
        (1..=4095).contains(&body_len),
        "zol body_len out of range (12 bits, >=1): {body_len}"
    );
    body_len as u32
}

use opcodes::*;

/// Encode an instruction to its machine word.
pub fn encode(i: &Instr) -> u32 {
    match *i {
        Instr::Lui { rd, imm } => u_type(imm, rd, LUI),
        Instr::Auipc { rd, imm } => u_type(imm, rd, AUIPC),
        Instr::Jal { rd, offset } => j_type(offset, rd, JAL),
        Instr::Jalr { rd, rs1, offset } => i_type(offset, rs1, 0b000, rd, JALR),
        Instr::Branch { op, rs1, rs2, offset } => {
            let f3 = match op {
                BranchOp::Beq => 0b000,
                BranchOp::Bne => 0b001,
                BranchOp::Blt => 0b100,
                BranchOp::Bge => 0b101,
                BranchOp::Bltu => 0b110,
                BranchOp::Bgeu => 0b111,
            };
            b_type(offset, rs2, rs1, f3, BRANCH)
        }
        Instr::Load { op, rd, rs1, offset } => {
            let f3 = match op {
                LoadOp::Lb => 0b000,
                LoadOp::Lh => 0b001,
                LoadOp::Lw => 0b010,
                LoadOp::Lbu => 0b100,
                LoadOp::Lhu => 0b101,
            };
            i_type(offset, rs1, f3, rd, LOAD)
        }
        Instr::Store { op, rs2, rs1, offset } => {
            let f3 = match op {
                StoreOp::Sb => 0b000,
                StoreOp::Sh => 0b001,
                StoreOp::Sw => 0b010,
            };
            s_type(offset, rs2, rs1, f3, STORE)
        }
        Instr::OpImm { op, rd, rs1, imm } => match op {
            AluImmOp::Addi => i_type(imm, rs1, 0b000, rd, OP_IMM),
            AluImmOp::Slti => i_type(imm, rs1, 0b010, rd, OP_IMM),
            AluImmOp::Sltiu => i_type(imm, rs1, 0b011, rd, OP_IMM),
            AluImmOp::Xori => i_type(imm, rs1, 0b100, rd, OP_IMM),
            AluImmOp::Ori => i_type(imm, rs1, 0b110, rd, OP_IMM),
            AluImmOp::Andi => i_type(imm, rs1, 0b111, rd, OP_IMM),
            AluImmOp::Slli => {
                assert!((0..32).contains(&imm), "shamt: {imm}");
                i_type(imm, rs1, 0b001, rd, OP_IMM)
            }
            AluImmOp::Srli => {
                assert!((0..32).contains(&imm), "shamt: {imm}");
                i_type(imm, rs1, 0b101, rd, OP_IMM)
            }
            AluImmOp::Srai => {
                assert!((0..32).contains(&imm), "shamt: {imm}");
                i_type(imm | 0x400, rs1, 0b101, rd, OP_IMM)
            }
        },
        Instr::Op { op, rd, rs1, rs2 } => {
            let (f7, f3) = match op {
                AluOp::Add => (0b000_0000, 0b000),
                AluOp::Sub => (0b010_0000, 0b000),
                AluOp::Sll => (0b000_0000, 0b001),
                AluOp::Slt => (0b000_0000, 0b010),
                AluOp::Sltu => (0b000_0000, 0b011),
                AluOp::Xor => (0b000_0000, 0b100),
                AluOp::Srl => (0b000_0000, 0b101),
                AluOp::Sra => (0b010_0000, 0b101),
                AluOp::Or => (0b000_0000, 0b110),
                AluOp::And => (0b000_0000, 0b111),
                AluOp::Mul => (0b000_0001, 0b000),
                AluOp::Mulh => (0b000_0001, 0b001),
                AluOp::Mulhsu => (0b000_0001, 0b010),
                AluOp::Mulhu => (0b000_0001, 0b011),
                AluOp::Div => (0b000_0001, 0b100),
                AluOp::Divu => (0b000_0001, 0b101),
                AluOp::Rem => (0b000_0001, 0b110),
                AluOp::Remu => (0b000_0001, 0b111),
            };
            r_type(f7, rs2, rs1, f3, rd, OP)
        }
        Instr::Fence => i_type(0, 0, 0b000, 0, MISC_MEM),
        Instr::Ecall => i_type(0, 0, 0b000, 0, SYSTEM),
        Instr::Ebreak => i_type(1, 0, 0b000, 0, SYSTEM),
        // --- custom (fields hardwired per Table 4: encoded as zeros) ---
        Instr::Mac => r_type(0b010_0000, 0, 0, 0b000, 0, CUSTOM2_MAC),
        Instr::Add2i { rs1, rs2, i1, i2 } => {
            fused_type(rs1, rs2, i1, i2, CUSTOM1_ADD2I)
        }
        Instr::FusedMac { rs1, rs2, i1, i2 } => {
            fused_type(rs1, rs2, i1, i2, CUSTOM0_FUSEDMAC)
        }
        Instr::Dlp { rs1, body_len } => {
            (zol_body_len(body_len) << 20) | (check_reg(rs1) << 15) | ZOL1
        }
        Instr::Dlpi { count, body_len } => {
            assert!((1..32).contains(&count), "dlpi count (5 bits, >=1): {count}");
            (zol_body_len(body_len) << 20)
                | ((count as u32) << 15)
                | (0b001 << 12)
                | ZOL1
        }
        Instr::Zlp { rs1, body_len } => {
            (zol_body_len(body_len) << 20)
                | (check_reg(rs1) << 15)
                | (0b010 << 12)
                | ZOL1
        }
        Instr::SetZc { rs1 } => (check_reg(rs1) << 15) | ZOL2,
        Instr::SetZs { rs1 } => (check_reg(rs1) << 15) | (0b001 << 12) | ZOL2,
        Instr::SetZe { rs1 } => (check_reg(rs1) << 15) | (0b010 << 12) | ZOL2,
        // Window slots reuse the fused field layout on their reserved
        // opcode — the slot index is the opcode, so decode needs no extra
        // discriminator field.
        Instr::Custom { idx, rs1, rs2, i1, i2 } => {
            assert!(
                (idx as usize) < crate::fusion::N_WINDOW,
                "custom window slot out of pool: {idx}"
            );
            fused_type(rs1, rs2, i1, i2, XWIN[idx as usize])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_matches_paper_table4() {
        // Table 4: funct7=0100000, rs2=0, rs1=0, funct3=000, rd=0,
        // opcode=1011011
        assert_eq!(encode(&Instr::Mac), 0b0100000_00000_00000_000_00000_1011011);
    }

    #[test]
    fn addi_standard_encoding() {
        // addi x10, x11, -3  (classic riscv encoding check)
        let w = encode(&Instr::OpImm {
            op: AluImmOp::Addi,
            rd: 10,
            rs1: 11,
            imm: -3,
        });
        assert_eq!(w, 0xffd5_8513);
    }

    #[test]
    fn add2i_field_packing() {
        let w = encode(&Instr::Add2i { rs1: 5, rs2: 6, i1: 0b11010, i2: 0x3ff });
        assert_eq!(w & 0x7f, opcodes::CUSTOM1_ADD2I);
        assert_eq!((w >> 7) & 0x1f, 5); // rs1
        assert_eq!((w >> 12) & 0b111, 0b010); // i1[2:0]
        assert_eq!((w >> 15) & 0x1f, 6); // rs2
        assert_eq!((w >> 20) & 0b11, 0b11); // i1[4:3]
        assert_eq!(w >> 22, 0x3ff); // i2
    }

    #[test]
    #[should_panic(expected = "i2 out of range")]
    fn add2i_i2_range_enforced() {
        encode(&Instr::Add2i { rs1: 1, rs2: 2, i1: 0, i2: 1024 });
    }

    #[test]
    #[should_panic(expected = "imm12 out of range")]
    fn imm12_range_enforced() {
        encode(&Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 2048 });
    }

    #[test]
    #[should_panic(expected = "body_len")]
    fn zol_body_len_enforced() {
        encode(&Instr::Dlpi { count: 3, body_len: 0 });
    }
}
