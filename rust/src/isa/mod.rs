//! The RV32IM instruction set plus MARVEL's four custom extensions.
//!
//! The baseline matches the Synopsys trv32p3 the paper starts from: RV32I
//! integer instructions + the M extension (hardware mul/div/rem).  The
//! custom extensions occupy exactly the opcodes of the paper's Table 3:
//!
//! | extension  | opcode      | paper encoding            |
//! |------------|-------------|---------------------------|
//! | `fusedmac` | `0001011`   | custom-0 (Table 6)        |
//! | `add2i`    | `0101011`   | custom-1 (Table 5)        |
//! | `mac`      | `1011011`   | custom-2 (Table 4)        |
//! | `zol` 1/2  | `1110111`   | reserved row 11/col 101   |
//! | `zol` 2/2  | `1011111`   | row 10/col 111            |
//!
//! The paper's Table 7 (zol decoding) is not fully legible in the source
//! scan; our zol encodings keep the documented opcode split and the five
//! instruction names (`dlp`, `dlpi`, `zlp`, `set.zc/zs/ze`) with a
//! conventional I-type field layout (documented on [`Instr`]).

pub mod decode;
pub mod disasm;
pub mod encode;

/// Architectural register index (x0..x31).
pub type Reg = u8;

/// ABI names for pretty-printing.
pub const REG_NAMES: [&str; 32] = [
    "x0", "x1", "x2", "x3", "x4", "x5", "x6", "x7", "x8", "x9", "x10", "x11",
    "x12", "x13", "x14", "x15", "x16", "x17", "x18", "x19", "x20", "x21",
    "x22", "x23", "x24", "x25", "x26", "x27", "x28", "x29", "x30", "x31",
];

/// The fixed registers of the `mac` / `fusedmac` datapath (paper §II.C.1:
/// rd = x20, rs1 = x21, rs2 = x22, hardwired to cut decoder area).
pub const MAC_RD: Reg = 20;
pub const MAC_RS1: Reg = 21;
pub const MAC_RS2: Reg = 22;

/// Register-register ALU ops (OP opcode, incl. the M extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add, Sub, Sll, Slt, Sltu, Xor, Srl, Sra, Or, And,
    Mul, Mulh, Mulhsu, Mulhu, Div, Divu, Rem, Remu,
}

/// Register-immediate ALU ops (OP-IMM opcode).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AluImmOp {
    Addi, Slti, Sltiu, Xori, Ori, Andi, Slli, Srli, Srai,
}

/// Conditional branches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BranchOp {
    Beq, Bne, Blt, Bge, Bltu, Bgeu,
}

/// Loads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoadOp {
    Lb, Lh, Lw, Lbu, Lhu,
}

/// Stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StoreOp {
    Sb, Sh, Sw,
}

/// A decoded instruction.
///
/// Custom-extension semantics:
/// - `Mac`: `x20 += x21 * x22` (1 cycle; replaces `mul`+`add`).
/// - `Add2i { rs1, rs2, i1, i2 }`: `rs1 += i1; rs2 += i2` with
///   i1 ∈ [0, 31] (5 bits), i2 ∈ [0, 1023] (10 bits) — the split chosen
///   from the paper's Fig 4 histogram analysis.
/// - `FusedMac`: `Mac` + `Add2i` in one cycle (the 4-instruction
///   `addi,addi,mul,add` conv inner-loop pattern).
/// - `Dlp { rs1, body_len }`: arm the zero-overhead loop — `ZC = x[rs1]`,
///   `ZS = pc+4`, `ZE = pc+4+4·body_len`; hardware loops back from ZE to ZS
///   `ZC` times at zero cycle cost.  `Dlpi` takes a 5-bit immediate count;
///   `Zlp` is the zero-iteration-safe variant (skips the body when
///   `x[rs1] == 0`).  `SetZc/SetZs/SetZe` write the loop registers directly
///   (used when the body is produced far from the loop head).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Instr {
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, offset: i32 },
    Jalr { rd: Reg, rs1: Reg, offset: i32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, offset: i32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, offset: i32 },
    Store { op: StoreOp, rs2: Reg, rs1: Reg, offset: i32 },
    OpImm { op: AluImmOp, rd: Reg, rs1: Reg, imm: i32 },
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    Fence,
    Ecall,
    Ebreak,
    // --- MARVEL custom extensions ---
    Mac,
    Add2i { rs1: Reg, rs2: Reg, i1: u8, i2: u16 },
    FusedMac { rs1: Reg, rs2: Reg, i1: u8, i2: u16 },
    Dlp { rs1: Reg, body_len: u16 },
    Dlpi { count: u8, body_len: u16 },
    Zlp { rs1: Reg, body_len: u16 },
    SetZc { rs1: Reg },
    SetZs { rs1: Reg },
    SetZe { rs1: Reg },
    /// Slot `idx` of the spec-driven custom-opcode *window*: a mined
    /// fusion from the static pool [`crate::fusion::WINDOW`], using the
    /// add2i/fusedmac field layout on the free opcode
    /// [`opcodes::XWIN`]`[idx]`.  Semantics live entirely in the spec's
    /// [`crate::fusion::SemOp`] program — the ISA layer only carries the
    /// operands.
    Custom { idx: u8, rs1: Reg, rs2: Reg, i1: u8, i2: u16 },
}

/// Opcode constants (Table 3).
pub mod opcodes {
    pub const LOAD: u32 = 0b000_0011;
    pub const CUSTOM0_FUSEDMAC: u32 = 0b000_1011;
    pub const OP_IMM: u32 = 0b001_0011;
    pub const AUIPC: u32 = 0b001_0111;
    pub const STORE: u32 = 0b010_0011;
    pub const CUSTOM1_ADD2I: u32 = 0b010_1011;
    pub const OP: u32 = 0b011_0011;
    pub const LUI: u32 = 0b011_0111;
    pub const CUSTOM2_MAC: u32 = 0b101_1011;
    pub const ZOL2: u32 = 0b101_1111;
    pub const BRANCH: u32 = 0b110_0011;
    pub const JALR: u32 = 0b110_0111;
    pub const JAL: u32 = 0b110_1111;
    pub const SYSTEM: u32 = 0b111_0011;
    pub const ZOL1: u32 = 0b111_0111;
    pub const MISC_MEM: u32 = 0b000_1111;

    /// The custom-opcode *window*: free `xx11` major opcodes reserved for
    /// mined fusion specs, one per [`crate::fusion::WINDOW`] slot.  Only
    /// the first [`crate::fusion::N_WINDOW`] entries decode; the rest are
    /// headroom for a deeper pool.
    pub const XWIN: [u32; 4] =
        [0b111_1011, 0b101_0111, 0b010_1111, 0b000_0111];
}

impl Instr {
    /// Mnemonic class used by the profiler's pattern tables (Fig 3 legend).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Lui { .. } => "lui",
            Instr::Auipc { .. } => "auipc",
            Instr::Jal { .. } => "jal",
            Instr::Jalr { .. } => "jalr",
            Instr::Branch { op, .. } => match op {
                BranchOp::Beq => "beq",
                BranchOp::Bne => "bne",
                BranchOp::Blt => "blt",
                BranchOp::Bge => "bge",
                BranchOp::Bltu => "bltu",
                BranchOp::Bgeu => "bgeu",
            },
            Instr::Load { op, .. } => match op {
                LoadOp::Lb => "lb",
                LoadOp::Lh => "lh",
                LoadOp::Lw => "lw",
                LoadOp::Lbu => "lbu",
                LoadOp::Lhu => "lhu",
            },
            Instr::Store { op, .. } => match op {
                StoreOp::Sb => "sb",
                StoreOp::Sh => "sh",
                StoreOp::Sw => "sw",
            },
            Instr::OpImm { op, .. } => match op {
                AluImmOp::Addi => "addi",
                AluImmOp::Slti => "slti",
                AluImmOp::Sltiu => "sltiu",
                AluImmOp::Xori => "xori",
                AluImmOp::Ori => "ori",
                AluImmOp::Andi => "andi",
                AluImmOp::Slli => "slli",
                AluImmOp::Srli => "srli",
                AluImmOp::Srai => "srai",
            },
            Instr::Op { op, .. } => match op {
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::Sll => "sll",
                AluOp::Slt => "slt",
                AluOp::Sltu => "sltu",
                AluOp::Xor => "xor",
                AluOp::Srl => "srl",
                AluOp::Sra => "sra",
                AluOp::Or => "or",
                AluOp::And => "and",
                AluOp::Mul => "mul",
                AluOp::Mulh => "mulh",
                AluOp::Mulhsu => "mulhsu",
                AluOp::Mulhu => "mulhu",
                AluOp::Div => "div",
                AluOp::Divu => "divu",
                AluOp::Rem => "rem",
                AluOp::Remu => "remu",
            },
            Instr::Fence => "fence",
            Instr::Ecall => "ecall",
            Instr::Ebreak => "ebreak",
            Instr::Mac => "mac",
            Instr::Add2i { .. } => "add2i",
            Instr::FusedMac { .. } => "fusedmac",
            Instr::Dlp { .. } => "dlp",
            Instr::Dlpi { .. } => "dlpi",
            Instr::Zlp { .. } => "zlp",
            Instr::SetZc { .. } => "set.zc",
            Instr::SetZs { .. } => "set.zs",
            Instr::SetZe { .. } => "set.ze",
            Instr::Custom { idx, .. } => crate::fusion::window_spec(*idx).name,
        }
    }

    /// Dense mnemonic index for array-indexed counters (the profiler's hot
    /// path — avoids a map lookup per retired instruction).  Indices are
    /// stable positions in [`MNEMONICS`].
    #[inline]
    pub fn mnemonic_idx(&self) -> usize {
        match self {
            Instr::Lui { .. } => 0,
            Instr::Auipc { .. } => 1,
            Instr::Jal { .. } => 2,
            Instr::Jalr { .. } => 3,
            Instr::Branch { op, .. } => 4 + *op as usize,
            Instr::Load { op, .. } => 10 + *op as usize,
            Instr::Store { op, .. } => 15 + *op as usize,
            Instr::OpImm { op, .. } => 18 + *op as usize,
            Instr::Op { op, .. } => 27 + *op as usize,
            Instr::Fence => 45,
            Instr::Ecall => 46,
            Instr::Ebreak => 47,
            Instr::Mac => 48,
            Instr::Add2i { .. } => 49,
            Instr::FusedMac { .. } => 50,
            Instr::Dlp { .. } => 51,
            Instr::Dlpi { .. } => 52,
            Instr::Zlp { .. } => 53,
            Instr::SetZc { .. } => 54,
            Instr::SetZs { .. } => 55,
            Instr::SetZe { .. } => 56,
            Instr::Custom { idx, .. } => 57 + *idx as usize,
        }
    }

    /// Is this one of the four MARVEL extensions?
    pub fn is_custom(&self) -> bool {
        matches!(
            self,
            Instr::Mac
                | Instr::Add2i { .. }
                | Instr::FusedMac { .. }
                | Instr::Dlp { .. }
                | Instr::Dlpi { .. }
                | Instr::Zlp { .. }
                | Instr::SetZc { .. }
                | Instr::SetZs { .. }
                | Instr::SetZe { .. }
                | Instr::Custom { .. }
        )
    }
}

/// Mnemonic table indexed by [`Instr::mnemonic_idx`].  The tail entries
/// (index 57+) are the window slots, in [`crate::fusion::WINDOW`] order —
/// pinned by `mnemonics_tail_matches_window_pool` below.
pub const MNEMONICS: [&str; 60] = [
    "lui", "auipc", "jal", "jalr",
    "beq", "bne", "blt", "bge", "bltu", "bgeu",
    "lb", "lh", "lw", "lbu", "lhu",
    "sb", "sh", "sw",
    "addi", "slti", "sltiu", "xori", "ori", "andi", "slli", "srli", "srai",
    "add", "sub", "sll", "slt", "sltu", "xor", "srl", "sra", "or", "and",
    "mul", "mulh", "mulhsu", "mulhu", "div", "divu", "rem", "remu",
    "fence", "ecall", "ebreak",
    "mac", "add2i", "fusedmac", "dlp", "dlpi", "zlp",
    "set.zc", "set.zs", "set.ze",
    "ldmac", "ldmacpp", "ldadd",
];

/// Generate a random *valid* instruction (all fields in encodable range) —
/// shared by the round-trip property test and the ISS fuzzers.
pub fn random_instr(rng: &mut crate::util::rng::Rng) -> Instr {
    let reg = |rng: &mut crate::util::rng::Rng| rng.int_in(0, 31) as Reg;
    let imm12 = |rng: &mut crate::util::rng::Rng| rng.int_in(-2048, 2047);
    match rng.int_in(0, 18) {
        0 => Instr::Lui { rd: reg(rng), imm: (rng.next_u32() & 0xffff_f000) as i32 },
        1 => Instr::Auipc { rd: reg(rng), imm: (rng.next_u32() & 0xffff_f000) as i32 },
        2 => Instr::Jal { rd: reg(rng), offset: rng.int_in(-(1 << 19), (1 << 19) - 1) * 2 },
        3 => Instr::Jalr { rd: reg(rng), rs1: reg(rng), offset: imm12(rng) },
        4 => {
            let op = *rng.choice(&[
                BranchOp::Beq, BranchOp::Bne, BranchOp::Blt,
                BranchOp::Bge, BranchOp::Bltu, BranchOp::Bgeu,
            ]);
            Instr::Branch { op, rs1: reg(rng), rs2: reg(rng),
                            offset: rng.int_in(-2048, 2047) * 2 }
        }
        5 => {
            let op = *rng.choice(&[LoadOp::Lb, LoadOp::Lh, LoadOp::Lw,
                                   LoadOp::Lbu, LoadOp::Lhu]);
            Instr::Load { op, rd: reg(rng), rs1: reg(rng), offset: imm12(rng) }
        }
        6 => {
            let op = *rng.choice(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]);
            Instr::Store { op, rs2: reg(rng), rs1: reg(rng), offset: imm12(rng) }
        }
        7 => {
            let op = *rng.choice(&[
                AluImmOp::Addi, AluImmOp::Slti, AluImmOp::Sltiu, AluImmOp::Xori,
                AluImmOp::Ori, AluImmOp::Andi, AluImmOp::Slli, AluImmOp::Srli,
                AluImmOp::Srai,
            ]);
            let imm = match op {
                AluImmOp::Slli | AluImmOp::Srli | AluImmOp::Srai => rng.int_in(0, 31),
                _ => imm12(rng),
            };
            Instr::OpImm { op, rd: reg(rng), rs1: reg(rng), imm }
        }
        8 => {
            let op = *rng.choice(&[
                AluOp::Add, AluOp::Sub, AluOp::Sll, AluOp::Slt, AluOp::Sltu,
                AluOp::Xor, AluOp::Srl, AluOp::Sra, AluOp::Or, AluOp::And,
                AluOp::Mul, AluOp::Mulh, AluOp::Mulhsu, AluOp::Mulhu,
                AluOp::Div, AluOp::Divu, AluOp::Rem, AluOp::Remu,
            ]);
            Instr::Op { op, rd: reg(rng), rs1: reg(rng), rs2: reg(rng) }
        }
        9 => Instr::Fence,
        10 => Instr::Ecall,
        11 => Instr::Mac,
        12 => Instr::Add2i {
            rs1: reg(rng), rs2: reg(rng),
            i1: rng.int_in(0, 31) as u8, i2: rng.int_in(0, 1023) as u16,
        },
        13 => Instr::FusedMac {
            rs1: reg(rng), rs2: reg(rng),
            i1: rng.int_in(0, 31) as u8, i2: rng.int_in(0, 1023) as u16,
        },
        14 => Instr::Dlp { rs1: reg(rng), body_len: rng.int_in(1, 4095) as u16 },
        15 => Instr::Dlpi {
            count: rng.int_in(1, 31) as u8,
            body_len: rng.int_in(1, 4095) as u16,
        },
        16 => Instr::Zlp { rs1: reg(rng), body_len: rng.int_in(1, 4095) as u16 },
        17 => match rng.int_in(0, 2) {
            0 => Instr::SetZc { rs1: reg(rng) },
            1 => Instr::SetZs { rs1: reg(rng) },
            _ => Instr::SetZe { rs1: reg(rng) },
        },
        _ => Instr::Custom {
            idx: rng.int_in(0, crate::fusion::N_WINDOW as i32 - 1) as u8,
            rs1: reg(rng), rs2: reg(rng),
            i1: rng.int_in(0, 31) as u8, i2: rng.int_in(0, 1023) as u16,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn prop_encode_decode_roundtrip() {
        check("decode(encode(i)) == i", 20_000, |rng: &mut Rng| {
            let i = random_instr(rng);
            let w = encode::encode(&i);
            let back = decode::decode(w)
                .map_err(|e| format!("decode failed for {i:?}: {e}"))?;
            prop_assert_eq!(back, i, "word {w:#010x}");
            Ok(())
        });
    }

    #[test]
    fn prop_mnemonic_idx_consistent_with_table() {
        check("MNEMONICS[idx] == mnemonic()", 5_000, |rng: &mut Rng| {
            let i = random_instr(rng);
            prop_assert_eq!(MNEMONICS[i.mnemonic_idx()], i.mnemonic(),
                            "instr {i:?}");
            Ok(())
        });
    }

    #[test]
    fn mnemonics_tail_matches_window_pool() {
        assert_eq!(MNEMONICS.len(), 57 + crate::fusion::N_WINDOW);
        for (i, spec) in crate::fusion::WINDOW.iter().enumerate() {
            assert_eq!(MNEMONICS[57 + i], spec.name, "window slot {i}");
        }
        // every window slot has a reserved opcode left in the table
        assert!(crate::fusion::N_WINDOW <= opcodes::XWIN.len());
    }

    #[test]
    fn prop_custom_opcodes_disjoint_from_rv32im() {
        // Custom instructions must decode back as custom, never shadowing a
        // base instruction (opcode-space correctness of Table 3).
        check("custom stays custom", 5_000, |rng: &mut Rng| {
            let i = random_instr(rng);
            let back = decode::decode(encode::encode(&i)).unwrap();
            prop_assert_eq!(back.is_custom(), i.is_custom(), "instr {i:?}");
            Ok(())
        });
    }
}
