//! MARVEL — model-class aware custom RISC-V ISA extension generation for
//! lightweight AI (reproduction).
//!
//! This crate is the Layer-3 coordinator of the three-layer architecture
//! (see DESIGN.md): it owns the end-to-end flow the paper contributes —
//! profiling TVM-class generated code on a baseline RV32IM core, mining the
//! model-class instruction patterns, generating the extended cores
//! (v1 `mac`, v2 `add2i`, v3 `fusedmac`, v4 `zol`), compiling models with
//! the pattern-rewriting compiler, and regenerating every table and figure
//! of the paper's evaluation.
//!
//! Module map:
//! - [`util`] — JSON, RNG, ASCII tables, property-test harness (offline
//!   substitutes for serde/proptest/criterion).
//! - [`isa`] — RV32IM + custom instruction encode/decode/disassemble.
//! - [`sim`] — the instruction/cycle-accurate trv32p3-class simulator:
//!   shared decode-once [`sim::Program`], per-run [`sim::Machine`], and the
//!   [`sim::engine`] parallel batch layer.
//! - [`quant`] — the int8/int32 shift-requant arithmetic contract.
//! - [`compiler`] — model spec → RV32 assembly → machine code, with the
//!   Chess-style rewrite passes.
//! - [`refexec`] — rust-native quantized reference executor (oracle).
//! - [`models`] — spec loading + synthetic spec builders for tests.
//! - [`profiler`] — retired-stream pattern mining (Fig 3, Fig 4).
//! - [`extgen`] — automatic extension proposal from profiles (the
//!   "model-class aware" discovery) + pseudo-nML emission (Fig 6).
//! - [`fusion`] — the `FusionSpec` IR: one description per fusable
//!   instruction (pattern, encoding slot, cost, executable semantics)
//!   shared by the rewrite engine, the ISA window, both interpreters and
//!   the extension search (DESIGN.md §17).
//! - [`hw`] — area/power/energy models calibrated to Table 8.
//! - [`runtime`] — PJRT CPU client executing the AOT HLO golden model.
//! - [`coordinator`] — flow orchestration + per-experiment report
//!   generators (Fig 3/4/5/10/11/12, Tables 8/10).

pub mod compiler;
pub mod coordinator;
pub mod extgen;
pub mod fusion;
pub mod hw;
pub mod isa;
pub mod models;
pub mod profiler;
pub mod quant;
pub mod refexec;
pub mod runtime;
pub mod sim;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
