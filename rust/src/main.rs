//! `marvel` — the MARVEL flow CLI (leader entrypoint).
//!
//! Subcommands mirror the paper's Fig 1 pipeline stages:
//!
//! ```text
//! marvel flow     --model lenet5            end-to-end: compile x5, simulate,
//!                                           verify vs golden (+ --pjrt), report
//! marvel run      --model m --variant v4    one inference, cycle/instr stats
//! marvel compile  --model m --variant v4    compile only; --dump-asm listing
//! marvel profile  --model m                 v0 pattern profile (Fig 3 metrics)
//! marvel extgen   --model m                 propose ISA extensions + nML
//! marvel extsearch [--models a,b] [--backend B] [--min-savings F]
//!                 [--json PATH] [--check-legacy]
//!                                           closed mining loop: profile v4,
//!                                           propose window specs, re-measure
//!                                           v0/v4/v4+mined per model class
//! marvel report   fig3|fig4|fig5|table8|fig10|fig11|fig12|table10|all
//!                 [--backend B]             sweep on backend B
//! marvel hw       [--fig10]                 area/power model
//! marvel golden   --model m                 run the AOT HLO artifact via PJRT
//! marvel shard-worker                       job protocol on stdin/stdout
//! marvel cluster-worker [--listen ADDR]     job protocol daemon on a TCP
//!                                           socket (cluster host)
//! marvel shard-sweep  [--backend B] [--check] model-zoo sweep
//!                                           (--check: diff vs in-process)
//! marvel serve    [--models a,b] [--variants v0,v4] [--backend B]
//!                 [--policy fifo|drr|edf] [--queue-cap N] [--window-min MS]
//!                 [--window-max MS] [--slo-ms MS] [--slo-window-ms MS]
//!                                           scheduled inference requests
//!                                           as JSON lines on stdin
//! ```
//!
//! Every sweep-style command executes through one swappable backend
//! (DESIGN.md §13), selected by
//! `--backend local[:T] | shard:N | cluster:N|<addr>,…|@<file>` and
//! parsed in exactly one place ([`backend_arg`]); results are
//! bit-identical across backends.  `--threads T` fills an unspecified
//! local thread count, and `--shard N` / `--workers N` survive as aliases
//! for `shard:N`.  `MARVEL_THREADS=N` overrides the "one worker per core"
//! default wherever a thread count is 0/omitted.  `--superops` (or
//! `MARVEL_SUPEROPS=1`) turns on superinstruction fusion in the lowered
//! ISS (DESIGN.md §19); results stay bit-identical either way.
//!
//! `--chaos <plan>` (or `MARVEL_CHAOS=<plan>`) arms deterministic fault
//! injection on any sweep-style command (DESIGN.md §16): exec-site faults
//! wrap the backend in a [`marvel::sim::ChaosExec`], worker-site faults are
//! exported into the environment so spawned shard workers act them out.
//! Within the retry budgets the observable results stay bit-identical to a
//! fault-free run — that invariant is what `shard-sweep --check --chaos`
//! exercises in CI.
//!
//! `flow`, `run`, `compile`, `report --model`, `shard-*` and `serve`
//! accept `synth:<kind>:<seed>` model names (self-contained synthetic
//! specs — no artifacts dir needed; goldens come from the reference
//! executor).  `profile`, `extgen` and `golden` need exported artifacts.
//! Arguments are hand-parsed (clap is unavailable offline).

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use marvel::coordinator::experiments::{self, ablation, fig11_cycles,
                                       fig12_energy, fig3_patterns,
                                       fig4_addi_hist, fig5_asm_diff,
                                       table10_memory, table8_area};
use marvel::coordinator::{run_flow, FlowOptions};
use marvel::sim::chaos::{self, FaultPlan, MARVEL_CHAOS_ENV};
use marvel::sim::exec::{BackendSpec, Executor, LocalExec};
use marvel::sim::{serve, Variant};
use marvel::util::tables::{fmt_si, Table};
use marvel::{compiler, extgen, models, profiler, refexec, runtime};

/// Parsed command line: free args + --key[=value] options.
struct Args {
    free: Vec<String>,
    opts: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut free = Vec::new();
        let mut opts = std::collections::BTreeMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    opts.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    opts.insert(key.to_string(), "true".to_string());
                }
            } else {
                free.push(a.clone());
            }
        }
        Args { free, opts }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    fn model(&self) -> Result<String> {
        self.get("model")
            .map(str::to_string)
            .context("--model <name> is required")
    }

    fn variant(&self) -> Result<Variant> {
        let name = self.get("variant").unwrap_or("v4");
        Variant::by_name(name).with_context(|| format!("unknown variant {name}"))
    }

    fn artifacts(&self) -> PathBuf {
        PathBuf::from(self.get("artifacts").unwrap_or("artifacts"))
    }

    fn usize_opt(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// A `--key MS` duration in (fractional) milliseconds.  Bounded to
    /// ~11 days so the f64→Duration conversion can never panic.
    fn ms_opt(&self, key: &str) -> Result<Option<std::time::Duration>> {
        const MAX_MS: f64 = 1e9;
        match self.get(key) {
            None => Ok(None),
            Some(s) => {
                let ms: f64 = s
                    .parse()
                    .ok()
                    .filter(|v: &f64| v.is_finite() && (0.0..=MAX_MS).contains(v))
                    .with_context(|| {
                        format!(
                            "--{key} wants a millisecond value in 0..={MAX_MS}, \
                             got {s:?}"
                        )
                    })?;
                Ok(Some(std::time::Duration::from_secs_f64(ms / 1e3)))
            }
        }
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    // `--superops[=VAL]` is the CLI spelling of `MARVEL_SUPEROPS`: export
    // it before any backend or machine is built so spawned shard workers
    // and lazily-lowered programs all see the same default (DESIGN.md
    // §19).  Bare `--superops` parses as "true", which the override
    // accepts as on; `--superops off` turns fusion off explicitly.
    if let Some(v) = args.get("superops") {
        std::env::set_var("MARVEL_SUPEROPS", v);
    }
    match cmd {
        "flow" => cmd_flow(&args),
        "run" => cmd_run(&args),
        "compile" => cmd_compile(&args),
        "profile" => cmd_profile(&args),
        "extgen" => cmd_extgen(&args),
        "extsearch" => cmd_extsearch(&args),
        "report" => cmd_report(&args),
        "hw" => cmd_hw(&args),
        "golden" => cmd_golden(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "cluster-worker" => cmd_cluster_worker(&args),
        "shard-sweep" => cmd_shard_sweep(&args),
        "serve" => cmd_serve(&args),
        "version" => {
            println!("marvel {}", marvel::version());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `marvel help`)"),
    }
}

fn print_usage() {
    println!(
        "marvel {} — model-class aware custom RISC-V extension generation\n\n\
         usage: marvel <flow|run|compile|profile|extgen|extsearch|report|hw|\
         golden|shard-worker|cluster-worker|shard-sweep|serve> \
         [--model NAME] [--variant v0..v4] [--artifacts DIR] \
         [--backend local[:T]|shard:N|cluster:… (execution backend for \
         report/shard-sweep/serve/extsearch; results are bit-identical \
         across backends)] \
         [--threads N (local backend workers, 0 = all cores)] \
         [--shard N (alias for --backend shard:N)] \
         [--superops[=on|off] (fuse hot straight-line micro-op runs into \
         superinstructions in the lowered ISS; sets MARVEL_SUPEROPS for \
         this process and its workers)] ...\n\n\
         synthetic models: `synth:<kind>:<seed>` with kind ∈ \
         tiny|lenet|residual|dwconv|rnn builds a\n\
         deterministic in-process spec (no artifacts dir needed) — one per \
         model class\n\
         (small conv, lenet-shaped conv, residual/concat, \
         depthwise-separable, unrolled rnn)\n\n\
         extension mining (DESIGN.md §17):\n  \
         extsearch             closed loop per model: profile the \
         post-ladder v4\n                        \
         stream, propose fusion specs over the window pool,\n                        \
         re-measure v0/v4/v4+mined through the backend\n  \
         --models a,b          search zoo (default: one model per class —\n                        \
         synth:lenet, synth:dwconv, synth:rnn)\n  \
         --min-savings F       proposal noise floor as a cycle fraction\n                        \
         (default 0.005)\n  \
         --json PATH           append bench-JSON speedup rows \
         (BENCH_extgen.json)\n  \
         --check-legacy        also diff the generic rewrite engine \
         against the\n                        \
         legacy passes on every ladder variant\n\n\
         serve scheduler (DESIGN.md §14, §16):\n  \
         --policy fifo|drr|edf batch-forming policy across per-model \
         queues:\n                        fifo = strict arrival order, \
         drr = deficit\n                        round-robin fairness, edf \
         = earliest deadline\n                        first (default \
         fifo)\n  \
         --queue-cap N         per-model queue bound; requests past it \
         are\n                        rejected with a structured error \
         (default 1024)\n  \
         --window-min MS       lower bound of the auto-tuned batching \
         window\n                        (fractional ms, default 1)\n  \
         --window-max MS       upper bound of the auto-tuned batching \
         window\n                        (default 8)\n  \
         --window-ms MS        pin a fixed window (sets min = max)\n  \
         --max-batch N         hard batch-size cap (default 64)\n  \
         --slo-ms MS           latency target for the SLO-attainment \
         column of\n                        the shutdown report (default: \
         no SLO)\n  \
         --slo-window-ms MS    emit + reset a recent-traffic SLO snapshot \
         on\n                        stderr every MS (default: lifetime \
         only)\n\n\
         cluster backend (DESIGN.md §18):\n  \
         cluster-worker        host daemon: serves the job protocol over \
         TCP;\n                        \
         --listen ADDR (default 127.0.0.1:0) binds the\n                        \
         socket, the bound address is announced as one\n                        \
         JSON line on stdout\n  \
         --backend cluster:N   spawn N loopback daemons of this binary \
         and\n                        \
         sweep across them (CI/bench form)\n  \
         --backend cluster:a,b dial externally started daemons at \
         addresses\n                        \
         a,b,… (host:port each)\n  \
         --backend cluster:@F  read the address list from discovery file \
         F\n                        \
         (one per line, '#' comments and blanks skipped)\n\n\
         fault injection (DESIGN.md §16):\n  \
         --chaos PLAN          deterministic fault plan for shard-sweep/\
         report/serve,\n                        \
         e.g. 'worker:kill@3,exec:transient@5x2'; also\n                        \
         read from MARVEL_CHAOS; within retry budgets\n                        \
         results stay bit-identical to a fault-free run\n\n\
         environment variables:\n  \
         MARVEL_THREADS=N      overrides the one-worker-per-core default\n                        \
         wherever a thread count is 0 or omitted\n  \
         MARVEL_LANES=N        lanes per worker thread for the software-\
         SIMT\n                        \
         engine (1 = scalar; capped at MAX_LANES)\n  \
         MARVEL_SUPEROPS=B     1/on enables superinstruction fusion in \
         the\n                        \
         lowered ISS (default off; `--superops` sets it);\n                        \
         fused runs stay bit-identical to scalar execution\n  \
         MARVEL_JOB_TIMEOUT_MS=N\n                        \
         per-job wall-clock deadline on the shard and\n                        \
         cluster pools before a straggler is re-dispatched\n                        \
         (0 disables; default scales with batch size)\n  \
         MARVEL_CHAOS=PLAN     arms fault injection like --chaos",
        marvel::version()
    );
}

/// The fault-injection plan for this invocation: `--chaos <plan>` wins
/// over the `MARVEL_CHAOS` env (and is re-exported into the environment,
/// so shard workers spawned by the backend inherit their worker-site
/// faults exactly as they would under the env spelling).  Call this
/// *before* building the backend — worker processes read the env at
/// spawn time.
fn chaos_arg(args: &Args) -> Result<Option<FaultPlan>> {
    match args.get("chaos") {
        Some(s) => {
            let plan = FaultPlan::parse(s)
                .with_context(|| format!("parsing --chaos {s:?}"))?;
            std::env::set_var(MARVEL_CHAOS_ENV, s);
            Ok(Some(plan))
        }
        None => FaultPlan::from_env(),
    }
}

/// The execution backend a sweep-style command uses — THE one place the
/// `--backend local[:T] | shard:N | cluster:…` spec is parsed
/// (DESIGN.md §13).
/// `--shard N` / `--workers N` stay as lenient aliases for `shard:N`:
/// `0` or a non-number falls back to the command's default instead of
/// erroring (old `--shard 0` meant in-process; old `--workers 0` clamped
/// to one worker, and now gets the default pool instead).  `--threads T`
/// fills in an unspecified local thread count.
fn backend_arg(args: &Args, default: &str) -> Result<BackendSpec> {
    let mut spec = match args.get("backend") {
        Some(s) => BackendSpec::parse(s)?,
        None => match args
            .get("shard")
            .or_else(|| args.get("workers"))
            .and_then(|n| n.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            Some(workers) => BackendSpec::Shard { workers },
            None => BackendSpec::parse(default)?,
        },
    };
    if let BackendSpec::Local { threads } = &mut spec {
        if *threads == 0 {
            *threads = args.usize_opt("threads", 0);
        }
    }
    Ok(spec)
}

/// Comma-separated `--models`, defaulting to the artifact models and, with
/// no artifacts dir, to a self-contained synthetic zoo.
fn models_arg(args: &Args) -> Vec<String> {
    match args.get("models") {
        Some(s) => s
            .split(',')
            .map(|m| m.trim().to_string())
            .filter(|m| !m.is_empty())
            .collect(),
        None => {
            let avail = experiments::available_models(&args.artifacts());
            if avail.is_empty() {
                ["synth:tiny:3", "synth:lenet:5", "synth:residual:7"]
                    .map(String::from)
                    .to_vec()
            } else {
                avail
            }
        }
    }
}

fn cmd_shard_worker(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    marvel::sim::shard::worker_loop(&artifacts, stdin.lock(), stdout.lock())
}

/// The cluster host daemon (DESIGN.md §18): bind `--listen` (default
/// `127.0.0.1:0` — kernel-assigned port), announce the bound address as
/// one JSON line on stdout (the only stdout output ever; coordinators
/// spawning loopback fleets read it for discovery), then serve sessions
/// until killed.
fn cmd_cluster_worker(args: &Args) -> Result<()> {
    use std::io::Write;
    let artifacts = args.artifacts();
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding cluster listener on {listen}"))?;
    let addr = listener.local_addr().context("reading the bound address")?;
    {
        let mut out = std::io::stdout().lock();
        writeln!(
            out,
            "{}",
            marvel::sim::cluster::encode_listening(&addr.to_string())
        )?;
        out.flush()?;
    }
    eprintln!(
        "marvel cluster-worker {}: listening on {addr} (artifacts {})",
        marvel::version(),
        artifacts.display()
    );
    marvel::sim::cluster::serve(&artifacts, listener)
}

fn cmd_shard_sweep(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let models = models_arg(args);
    let opts = FlowOptions {
        n_inputs: args.usize_opt("n", 2),
        threads: args.usize_opt("threads", 0),
        ..FlowOptions::default()
    };
    let cache = compiler::CompileCache::new();
    // Chaos is armed before the backend builds: shard workers read the
    // exported plan from their environment at spawn time.
    let plan = chaos_arg(args)?;
    let mut exec = chaos::wrap(
        backend_arg(args, "shard:2")?.build(&artifacts)?,
        plan.as_ref(),
    );
    let t0 = std::time::Instant::now();
    let sharded = experiments::run_flows(
        &artifacts, &models, &opts, &cache, exec.as_mut(),
    )?;
    let dt = t0.elapsed();

    let mut t = Table::new(&["model", "golden", "variants", "v4 speedup"])
        .with_title(&format!(
            "sharded sweep — {} models × {} inputs on backend {} \
             ({:.1} ms)",
            sharded.len(),
            opts.n_inputs,
            exec.describe(),
            dt.as_secs_f64() * 1e3
        ));
    for f in &sharded {
        let v4 = f
            .metrics
            .iter()
            .find(|m| m.variant.name == "v4")
            .map(|m| format!("{:.2}x", m.speedup))
            .unwrap_or_else(|| "-".into());
        let golden = if f.verified_golden { "VERIFIED" } else { "FAILED" };
        t.row(vec![
            f.model.clone(),
            golden.to_string(),
            f.metrics.len().to_string(),
            v4,
        ]);
    }
    println!("{}", t.render());

    if args.flag("check") {
        // Built-in differential: the same sweep on the in-process backend
        // must be bit-identical (the executor contract, end to end).
        let mut local = LocalExec::new(&artifacts, opts.threads);
        let reference = experiments::run_flows(
            &artifacts, &models, &opts, &cache, &mut local,
        )?;
        compare_flow_results(&sharded, &reference)?;
        println!(
            "check: {} ≡ local (bit-identical metrics, {} models)",
            exec.describe(),
            sharded.len()
        );
    }
    if sharded.iter().any(|f| !f.verified_golden) {
        bail!("golden verification failed");
    }
    Ok(())
}

/// Bit-exact comparison of two sweep results (`--check` differential).
fn compare_flow_results(
    a: &[marvel::coordinator::FlowResult],
    b: &[marvel::coordinator::FlowResult],
) -> Result<()> {
    if a.len() != b.len() {
        bail!("model count differs: {} vs {}", a.len(), b.len());
    }
    for (x, y) in a.iter().zip(b) {
        if x.model != y.model || x.verified_golden != y.verified_golden {
            bail!("{}: verification diverged (sharded {} vs local {})",
                  x.model, x.verified_golden, y.verified_golden);
        }
        if x.metrics.len() != y.metrics.len() {
            bail!("{}: metric count differs", x.model);
        }
        for (m, n) in x.metrics.iter().zip(&y.metrics) {
            if m.variant != n.variant
                || m.instrs != n.instrs
                || m.cycles != n.cycles
                || m.pm_bytes != n.pm_bytes
                || m.dm_bytes != n.dm_bytes
                || m.speedup.to_bits() != n.speedup.to_bits()
            {
                bail!(
                    "{} on {}: sharded ({} instrs, {} cycles) != local \
                     ({} instrs, {} cycles)",
                    x.model, m.variant.name, m.instrs, m.cycles,
                    n.instrs, n.cycles
                );
            }
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let models = models_arg(args);
    let variants: Vec<Variant> = match args.get("variants") {
        Some(s) => s
            .split(',')
            .map(|v| {
                Variant::by_name(v.trim())
                    .with_context(|| format!("unknown variant {v:?}"))
            })
            .collect::<Result<_>>()?,
        None => vec![marvel::sim::V0, marvel::sim::V4],
    };
    // Parallelism lives in the backend (--backend/--threads via
    // backend_arg), not in the scheduler options.
    let opts = serve_opts_arg(args)?;
    let cache = compiler::CompileCache::new();
    let units =
        serve::build_serve_models(&artifacts, &models, &variants, &cache)?;
    let plan = chaos_arg(args)?;
    let exec = chaos::wrap(
        backend_arg(args, "local")?.build(&artifacts)?,
        plan.as_ref(),
    );
    eprintln!(
        "serving {} (model, variant) units on backend {}; policy {}, \
         window {:?}..{:?}, max batch {}, queue cap {}{} — JSON request \
         lines on stdin",
        units.len(),
        exec.describe(),
        opts.policy,
        opts.window_min,
        opts.window_max,
        opts.max_batch,
        opts.queue_cap,
        match opts.slo {
            Some(s) => format!(", SLO {:.1} ms", s.as_secs_f64() * 1e3),
            None => String::new(),
        }
    );
    let stdin = std::io::stdin();
    // Unlocked Stdout: the response writer runs on its own thread and
    // needs a Send sink (StdoutLock is not Send).
    let report = serve::serve_lines(
        units, opts, exec, stdin.lock(), std::io::stdout(),
    )?;
    // The protocol owns stdout; the SLO report goes to stderr.
    eprintln!("{}", report.slo.render());
    eprintln!("serve: {} batches dispatched", report.batches);
    Ok(())
}

/// The serving scheduler's knobs, parsed next to [`backend_arg`] —
/// `--policy fifo|drr|edf`, `--queue-cap N`, `--window-min/--window-max
/// MS` (auto-tune bounds; `--window-ms MS` pins a fixed window),
/// `--max-batch N`, `--slo-ms MS` and `--slo-window-ms MS` (periodic
/// recent-traffic SLO snapshots; DESIGN.md §14, §16).
fn serve_opts_arg(args: &Args) -> Result<marvel::sim::ServeOptions> {
    let mut opts = marvel::sim::ServeOptions {
        max_batch: args.usize_opt("max-batch", 64),
        queue_cap: args.usize_opt("queue-cap", 1024),
        policy: marvel::sim::PolicyKind::parse(
            args.get("policy").unwrap_or("fifo"),
        )?,
        slo: args.ms_opt("slo-ms")?,
        slo_window: args.ms_opt("slo-window-ms")?,
        ..Default::default()
    };
    if let Some(w) = args.ms_opt("window-ms")? {
        opts = opts.fixed_window(w);
    }
    if let Some(w) = args.ms_opt("window-min")? {
        opts.window_min = w;
    }
    if let Some(w) = args.ms_opt("window-max")? {
        opts.window_max = w;
    }
    anyhow::ensure!(
        opts.window_min <= opts.window_max,
        "--window-min ({:?}) must not exceed --window-max ({:?})",
        opts.window_min,
        opts.window_max
    );
    Ok(opts)
}

fn cmd_flow(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let opts = FlowOptions {
        n_inputs: args.usize_opt("n", 2),
        use_pjrt: args.flag("pjrt"),
        threads: args.usize_opt("threads", 0),
        ..FlowOptions::default()
    };
    let model = args.model()?;
    let f = run_flow(&artifacts, &model, &opts)?;
    let mut t = Table::new(&[
        "variant", "instrs", "cycles", "speedup", "PM (kB)", "DM (kB)",
        "energy (mJ)",
    ])
    .with_title(&format!(
        "MARVEL flow — {} ({} MACs, {} inferences, golden {}{})",
        f.model,
        fmt_si(f.total_macs),
        f.n_inputs,
        if f.verified_golden { "VERIFIED" } else { "FAILED" },
        match f.verified_pjrt {
            Some(true) => ", pjrt VERIFIED",
            Some(false) => ", pjrt FAILED",
            None => "",
        }
    ));
    for m in &f.metrics {
        t.row(vec![
            m.variant.name.to_string(),
            fmt_si(m.instrs),
            fmt_si(m.cycles),
            format!("{:.2}x", m.speedup),
            format!("{:.2}", m.pm_bytes as f64 / 1024.0),
            format!("{:.2}", m.dm_bytes as f64 / 1024.0),
            format!("{:.4}", m.energy.energy_mj),
        ]);
    }
    println!("{}", t.render());
    if !f.verified_golden || f.verified_pjrt == Some(false) {
        bail!("verification failed");
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let model = args.model()?;
    let variant = args.variant()?;
    let spec = models::resolve(&artifacts, &model)?;
    let want_idx = args.usize_opt("input", 0);
    let io = models::resolve_io(&artifacts, &model, &spec, want_idx + 1)?;
    let idx = want_idx.min(io.inputs.len() - 1);
    let c = compiler::compile(&spec, variant)?;
    // --trace N: print the first N retired instructions (the OCD/JTAG
    // debugging substitute, paper §II.E.3)
    let trace_n = args.usize_opt("trace", 0);
    let (out, stats) = if trace_n > 0 {
        let mut tracer = marvel::sim::TraceHook::new(trace_n);
        let r = compiler::execute_compiled(
            &c, &spec, &io.inputs[idx], 1 << 36, &mut tracer,
        )?;
        for line in &tracer.lines {
            println!("{line}");
        }
        r
    } else {
        compiler::execute_compiled(
            &c,
            &spec,
            &io.inputs[idx],
            1 << 36,
            &mut marvel::sim::NopHook,
        )?
    };
    println!(
        "{model} on {}: {} instrs, {} cycles ({:.3} ms @100MHz)",
        variant.name,
        fmt_si(stats.instrs),
        fmt_si(stats.cycles),
        stats.cycles as f64 / 1e5
    );
    println!("logits: {out:?}");
    println!("golden: {:?}", io.outputs[idx]);
    println!(
        "match:  {}",
        if out == io.outputs[idx] { "YES" } else { "NO" }
    );
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let model = args.model()?;
    let variant = args.variant()?;
    let spec = models::resolve(&artifacts, &model)?;
    let c = compiler::compile(&spec, variant)?;
    println!(
        "{model} for {}: {} instrs, PM {:.2} kB, DM {:.2} kB",
        variant.name,
        c.instrs().len(),
        c.pm_bytes() as f64 / 1024.0,
        c.dm_bytes() as f64 / 1024.0
    );
    println!(
        "rewrites: {} fusedmac, {} mac, {} add2i; {} zol loops",
        c.rewrite_stats.fusedmac,
        c.rewrite_stats.mac,
        c.rewrite_stats.add2i,
        c.flatten_stats.zol_loops
    );
    if let Some(out) = args.get("out") {
        let bytes: Vec<u8> =
            c.words().iter().flat_map(|w| w.to_le_bytes()).collect();
        std::fs::write(out, &bytes)?;
        println!("PM image written to {out}");
    }
    if args.flag("dump-asm") {
        for (li, (s, e)) in c.layer_ranges.iter().enumerate() {
            println!("; layer {li} ({})", spec.layers[li].op_name());
            for i in *s..*e {
                println!(
                    "  {:#07x}  {:08x}  {}",
                    i * 4,
                    c.words()[i],
                    marvel::isa::disasm::disasm(&c.instrs()[i])
                );
            }
        }
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let model = args.model()?;
    let counts = fig3_patterns::profile_model(&artifacts, &model)?;
    println!("{}", fig3_patterns::render(&artifacts, &[model.clone()])?);
    println!("top addi immediate pairs (Fig 4):");
    for ((a, b), n) in counts.top_addi_pairs(args.usize_opt("top", 12)) {
        println!("  {a}_{b}: {}", fmt_si(n));
    }
    let (sa, sb, cov) = profiler::best_split(&counts.addi_imm_hist);
    println!(
        "add2i split: best {sa}+{sb} bits covers {:.2}%; paper 5+10 covers {:.2}%",
        cov * 100.0,
        profiler::split_coverage(&counts.addi_imm_hist, 5, 10) * 100.0
    );
    Ok(())
}

fn cmd_extgen(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let model = args.model()?;
    let counts = fig3_patterns::profile_model(&artifacts, &model)?;
    let min = args
        .get("min-savings")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.005);
    let props = extgen::propose(&counts, min);
    println!(
        "extgen — {model}: {} proposals (min dynamic savings {:.1}%)\n",
        props.len(),
        min * 100.0
    );
    for p in &props {
        println!(
            "== {} (opcode {:#04x}) ==\n  pattern:    {}\n  dynamic:    \
             {} occurrences, {} -> {} cycles ({:.1}% of total)\n  area:       \
             {:+} LUT, {:+} regs, {:+} DSP, {:+.0} mW",
            p.name,
            p.opcode,
            p.pattern,
            fmt_si(p.occurrences),
            fmt_si(p.cycles_before),
            fmt_si(p.cycles_after),
            p.savings_frac * 100.0,
            p.cost.lut,
            p.cost.regs,
            p.cost.dsp,
            p.cost.power_mw,
        );
        if let Some((a, b, cov)) = p.imm_split {
            println!("  imm split:  {a}+{b} bits ({:.2}% coverage)", cov * 100.0);
        }
        if args.flag("nml") {
            println!("  nML model:\n{}", indent(&p.nml, 4));
        }
        println!();
    }
    Ok(())
}

fn cmd_extsearch(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let models = match args.get("models") {
        Some(s) => s
            .split(',')
            .map(|m| m.trim().to_string())
            .filter(|m| !m.is_empty())
            .collect(),
        // the per-model-class default zoo, not the artifact models: the
        // search's point is comparing classes (conv/depthwise/rnn)
        None => marvel::coordinator::extsearch::DEFAULT_ZOO
            .map(String::from)
            .to_vec(),
    };
    let opts = marvel::coordinator::ExtSearchOptions {
        min_savings: args
            .get("min-savings")
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.005),
        n_inputs: args.usize_opt("n", 2),
        check_legacy: args.flag("check-legacy"),
    };
    let cache = compiler::CompileCache::new();
    let plan = chaos_arg(args)?;
    let mut exec = chaos::wrap(
        backend_arg(args, "local")?.build(&artifacts)?,
        plan.as_ref(),
    );
    let results = marvel::coordinator::extsearch::search(
        &artifacts, &models, &opts, &cache, exec.as_mut(),
    )?;

    let mut t = Table::new(&[
        "model", "golden", "variant", "instrs", "cycles", "speedup", "mined",
    ])
    .with_title(&format!(
        "extsearch — {} models on backend {} (min savings {:.1}%{})",
        results.len(),
        exec.describe(),
        opts.min_savings * 100.0,
        if opts.check_legacy { ", legacy diff VERIFIED" } else { "" }
    ));
    for r in &results {
        for row in &r.rows {
            let mined = if row.variant.xwin != 0 {
                format!("{} (x{:02x})", r.mined.join("+"), r.mask)
            } else {
                "-".into()
            };
            t.row(vec![
                r.model.clone(),
                if r.verified { "VERIFIED" } else { "FAILED" }.into(),
                row.variant.name.to_string(),
                fmt_si(row.instrs),
                fmt_si(row.cycles),
                format!("{:.2}x", row.speedup),
                mined,
            ]);
        }
    }
    println!("{}", t.render());

    // `--json PATH`: one row per (model, variant) in the bench-JSON shape
    // the gate/trend tools consume (`speedup` is higher-is-better).
    if let Some(path) = args.get("json") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {path}"))?;
        for r in &results {
            for row in &r.rows {
                writeln!(
                    f,
                    "{{\"name\":\"extsearch/{}/{}\",\"speedup\":{:.4},\
                     \"cycles\":{}}}",
                    r.model, row.variant.name, row.speedup, row.cycles
                )?;
            }
        }
        eprintln!("extsearch rows appended to {path}");
    }

    if results.iter().any(|r| !r.verified) {
        bail!("golden verification failed");
    }
    // the mining loop must pay off somewhere: at least one model's mined
    // variant beats its own ladder top
    let improved = results.iter().any(|r| {
        r.mask != 0
            && r.rows.len() >= 3
            && r.rows[2].cycles < r.rows[1].cycles
    });
    if !improved {
        bail!("no mined variant improved on v4 — mining loop found nothing");
    }
    Ok(())
}

fn indent(s: &str, n: usize) -> String {
    let pad = " ".repeat(n);
    s.lines()
        .map(|l| format!("{pad}{l}"))
        .collect::<Vec<_>>()
        .join("\n")
}

fn cmd_report(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let which = args.free.first().map(String::as_str).unwrap_or("all");
    let models = match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => experiments::available_models(&artifacts),
    };
    if models.is_empty() {
        bail!(
            "no model artifacts found under {} — run `make artifacts`",
            artifacts.display()
        );
    }
    // One compile cache for the whole report: the flow sweeps and the
    // ablation grid revisit the same (model, variant) pairs.
    let cache = marvel::compiler::CompileCache::new();
    let threads = args.usize_opt("threads", 0);
    let needs_flows = matches!(which, "fig11" | "fig12" | "table10" | "all");
    let flows = if needs_flows {
        let opts = FlowOptions {
            n_inputs: args.usize_opt("n", 2),
            use_pjrt: args.flag("pjrt"),
            threads,
            ..FlowOptions::default()
        };
        // One global cross-model batch on the selected backend: the
        // backend drains every model's jobs from a single list, closing
        // the tail small models leave behind, and `--backend shard:N`
        // dispatches that same list across N worker processes instead
        // (bit-identical results — the executor contract).
        let plan = chaos_arg(args)?;
        let mut exec = chaos::wrap(
            backend_arg(args, "local")?.build(&artifacts)?,
            plan.as_ref(),
        );
        marvel::coordinator::experiments::run_flows(
            &artifacts, &models, &opts, &cache, exec.as_mut(),
        )?
    } else {
        Vec::new()
    };

    let mut out = String::new();
    if matches!(which, "fig3" | "all") {
        out.push_str(&fig3_patterns::render(&artifacts, &models)?);
        out.push('\n');
    }
    if matches!(which, "fig4" | "all") {
        out.push_str(&fig4_addi_hist::render(
            &artifacts,
            &models,
            args.usize_opt("top", 10),
        )?);
        out.push('\n');
    }
    if matches!(which, "fig5" | "all") {
        let m = models.iter().find(|m| *m != "lenet5").unwrap_or(&models[0]);
        out.push_str(&fig5_asm_diff::render(&artifacts, m, None)?);
        out.push('\n');
    }
    if matches!(which, "table8" | "all") {
        out.push_str(&table8_area::render());
        out.push('\n');
    }
    if matches!(which, "fig10" | "all") {
        out.push_str(&table8_area::render_fig10());
        out.push('\n');
    }
    if matches!(which, "fig11" | "all") {
        out.push_str(&fig11_cycles::render(&flows));
        out.push('\n');
    }
    if matches!(which, "fig12" | "all") {
        out.push_str(&fig12_energy::render(&flows));
        out.push('\n');
    }
    if matches!(which, "table10" | "all") {
        out.push_str(&table10_memory::render(&flows));
        out.push('\n');
    }
    if matches!(which, "ablation" | "all") {
        out.push_str(&ablation::render_cached(&artifacts, &models, &cache, threads)?);
        out.push('\n');
    }
    if out.is_empty() {
        bail!("unknown report {which:?}");
    }
    println!("{out}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, &out)?;
        eprintln!("report written to {path}");
    }
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    println!("{}", table8_area::render());
    if args.flag("fig10") {
        println!("{}", table8_area::render_fig10());
    }
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let artifacts = args.artifacts();
    let model = args.model()?;
    let spec = models::load(&artifacts, &model)?;
    let io = runtime::load_golden_io(&artifacts, &model)?;
    let rt = runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let g = rt.load_model(&artifacts, &model, spec.input_shape,
                          spec.output_elems())?;
    let mut ok = true;
    for (i, x) in io.inputs.iter().enumerate() {
        let got = g.run(x)?;
        let want_ref = refexec::run(&spec, x)?;
        let exported = &io.outputs[i];
        let matches = got == *exported && got == want_ref;
        ok &= matches;
        println!(
            "input {i}: pjrt {:?} exported {:?} refexec {:?} -> {}",
            got,
            exported,
            want_ref,
            if matches { "MATCH" } else { "MISMATCH" }
        );
    }
    if !ok {
        bail!("golden verification failed");
    }
    println!("golden model verified: PJRT == exporter == refexec");
    Ok(())
}
