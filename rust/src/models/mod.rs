//! Model access: artifact loading plus synthetic spec builders.
//!
//! Real models come from the AOT exporter (`artifacts/models/*.json`, loaded
//! via [`crate::compiler::spec::load_spec`]).  The [`synth`] module builds
//! small in-process specs for tests and property fuzzing — no artifacts
//! required, which keeps `cargo test` self-contained.

pub mod synth;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compiler::spec::{load_spec, ModelSpec};
use crate::runtime::GoldenIo;
use crate::util::rng::Rng;

/// Paper model names, Table-10 order.
pub const PAPER_MODELS: [&str; 6] = [
    "lenet5",
    "mobilenet_v1",
    "resnet50",
    "vgg16",
    "mobilenet_v2",
    "densenet121",
];

/// Load one model from the artifacts directory.
pub fn load(artifacts: &Path, name: &str) -> Result<ModelSpec> {
    load_spec(artifacts, name)
}

/// The synthetic model kinds [`resolve`] accepts — one per model class the
/// extsearch sweep covers (conv, conv, residual, depthwise, rnn).
pub const SYNTH_KINDS: [&str; 5] = ["tiny", "lenet", "residual", "dwconv", "rnn"];

/// Resolve a model name that may be synthetic.
///
/// `synth:<kind>:<seed>` (kind ∈ [`SYNTH_KINDS`]:
/// `tiny`/`lenet`/`residual`/`dwconv`/`rnn`) builds the corresponding
/// [`synth`] spec in-process — deterministic in the seed, so a
/// shard worker in another process hydrates the *same* model the
/// coordinator compiled (verified by program fingerprint, see
/// [`crate::sim::shard`]).  Anything else loads from the artifacts dir.
pub fn resolve(artifacts: &Path, name: &str) -> Result<ModelSpec> {
    let Some(rest) = name.strip_prefix("synth:") else {
        return load(artifacts, name);
    };
    let (kind, seed) = rest
        .split_once(':')
        .with_context(|| format!("bad synthetic model name {name:?} (want synth:<kind>:<seed>)"))?;
    let seed: u64 = seed
        .parse()
        .with_context(|| format!("bad seed in synthetic model name {name:?}"))?;
    match kind {
        "tiny" => Ok(synth::tiny_conv_net(seed)),
        "lenet" => Ok(synth::lenet_shaped(seed)),
        "residual" => Ok(synth::residual_net(seed)),
        "dwconv" => Ok(synth::dwconv_net(seed)),
        "rnn" => Ok(synth::rnn_net(seed)),
        other => bail!(
            "unknown synthetic model kind {other:?} in {name:?} \
             (known kinds: {})",
            SYNTH_KINDS.join(", ")
        ),
    }
}

/// Golden I/O for a possibly-synthetic model.
///
/// Artifact models load the exporter's recorded inputs/logits; `synth:`
/// models get `n_inputs` deterministic random inputs (seeded from the full
/// name) with the native reference executor providing the golden logits —
/// which makes the full `PreparedFlow` verification path (and therefore
/// sharded sweeps and serving) runnable with no artifacts directory.
pub fn resolve_io(
    artifacts: &Path,
    name: &str,
    spec: &ModelSpec,
    n_inputs: usize,
) -> Result<GoldenIo> {
    if !name.starts_with("synth:") {
        return crate::runtime::load_golden_io(artifacts, name);
    }
    let mut rng = Rng::new(crate::util::fnv1a(name.as_bytes()));
    let n = n_inputs.max(1);
    let mut inputs = Vec::with_capacity(n);
    let mut outputs = Vec::with_capacity(n);
    for _ in 0..n {
        let x = synth::Builder::random_input(spec, &mut rng);
        let y = crate::refexec::run(spec, &x)
            .with_context(|| format!("reference executor on {name}"))?;
        inputs.push(x);
        outputs.push(y);
    }
    Ok(GoldenIo { inputs, outputs })
}

/// Load every paper model present in the artifacts directory.
pub fn load_available(artifacts: &Path) -> Vec<(String, ModelSpec)> {
    PAPER_MODELS
        .iter()
        .filter_map(|name| {
            load_spec(artifacts, name).ok().map(|s| (name.to_string(), s))
        })
        .collect()
}
