//! Model access: artifact loading plus synthetic spec builders.
//!
//! Real models come from the AOT exporter (`artifacts/models/*.json`, loaded
//! via [`crate::compiler::spec::load_spec`]).  The [`synth`] module builds
//! small in-process specs for tests and property fuzzing — no artifacts
//! required, which keeps `cargo test` self-contained.

pub mod synth;

use std::path::Path;

use anyhow::Result;

use crate::compiler::spec::{load_spec, ModelSpec};

/// Paper model names, Table-10 order.
pub const PAPER_MODELS: [&str; 6] = [
    "lenet5",
    "mobilenet_v1",
    "resnet50",
    "vgg16",
    "mobilenet_v2",
    "densenet121",
];

/// Load one model from the artifacts directory.
pub fn load(artifacts: &Path, name: &str) -> Result<ModelSpec> {
    load_spec(artifacts, name)
}

/// Load every paper model present in the artifacts directory.
pub fn load_available(artifacts: &Path) -> Vec<(String, ModelSpec)> {
    PAPER_MODELS
        .iter()
        .filter_map(|name| {
            load_spec(artifacts, name).ok().map(|s| (name.to_string(), s))
        })
        .collect()
}
