//! Synthetic spec builders: deterministic tiny models and a randomized
//! model generator for the compile→simulate≡reference property tests.
//!
//! The generator exercises every operator the six paper models use
//! (conv/dw/dense/pools/add/concat), random strides/pads/shifts, and weights
//! spanning the full int8 range — saturation and rounding paths included.
//! No calibration: equivalence between the ISS and the reference executor
//! must hold for *any* shift, not just non-saturating ones.

use std::collections::BTreeMap;

use crate::compiler::spec::{Dtype, Layer, ModelSpec, Tensor};
use crate::util::rng::Rng;

/// Incremental spec builder (rust twin of python's SpecBuilder).
pub struct Builder {
    name: String,
    input_shape: [usize; 3],
    layers: Vec<Layer>,
    tensors: BTreeMap<String, Tensor>,
    rng: Rng,
    tid: usize,
}

impl Builder {
    pub fn new(name: &str, input_shape: [usize; 3], seed: u64) -> Self {
        Builder {
            name: name.into(),
            input_shape,
            layers: Vec::new(),
            tensors: BTreeMap::new(),
            rng: Rng::new(seed),
            tid: 0,
        }
    }

    pub fn shape_of(&self, idx: i32) -> [usize; 3] {
        if idx == -1 {
            self.input_shape
        } else {
            match &self.layers[idx as usize] {
                Layer::Conv2d { out_shape, .. }
                | Layer::DwConv2d { out_shape, .. }
                | Layer::MaxPool { out_shape, .. }
                | Layer::AvgPool2d { out_shape, .. }
                | Layer::AvgPoolGlobal { out_shape, .. }
                | Layer::Concat { out_shape, .. } => *out_shape,
                Layer::Add { shape, .. } => [shape[0], shape[1], shape[2]],
                Layer::Dense { out_len, .. } => [*out_len, 1, 1],
            }
        }
    }

    pub fn last(&self) -> i32 {
        self.layers.len() as i32 - 1
    }

    fn tensor(&mut self, shape: Vec<usize>, dtype: Dtype, data: Vec<i32>) -> String {
        let name = format!("t{}", self.tid);
        self.tid += 1;
        self.tensors.insert(
            name.clone(),
            Tensor { name: name.clone(), shape, dtype, data },
        );
        name
    }

    fn rand_w(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.rng.int_in(-127, 127)).collect()
    }

    fn rand_b(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.rng.int_in(-1000, 1000)).collect()
    }

    pub fn conv2d(
        &mut self,
        input: i32,
        oc: usize,
        k: usize,
        stride: usize,
        pad: usize,
        shift: u32,
        relu: bool,
    ) -> i32 {
        let [ic, ih, iw] = self.shape_of(input);
        let oh = (ih + 2 * pad - k) / stride + 1;
        let ow = (iw + 2 * pad - k) / stride + 1;
        let wdata = self.rand_w(oc * ic * k * k);
        let w = self.tensor(vec![oc, ic, k, k], Dtype::I8, wdata);
        let bdata = self.rand_b(oc);
        let b = self.tensor(vec![oc], Dtype::I32, bdata);
        self.layers.push(Layer::Conv2d {
            input, w, b, stride, pad, shift, relu,
            in_shape: [ic, ih, iw],
            out_shape: [oc, oh, ow],
        });
        self.last()
    }

    pub fn dwconv2d(
        &mut self,
        input: i32,
        k: usize,
        stride: usize,
        pad: usize,
        shift: u32,
        relu: bool,
    ) -> i32 {
        let [c, ih, iw] = self.shape_of(input);
        let oh = (ih + 2 * pad - k) / stride + 1;
        let ow = (iw + 2 * pad - k) / stride + 1;
        let wdata = self.rand_w(c * k * k);
        let w = self.tensor(vec![c, k, k], Dtype::I8, wdata);
        let bdata = self.rand_b(c);
        let b = self.tensor(vec![c], Dtype::I32, bdata);
        self.layers.push(Layer::DwConv2d {
            input, w, b, stride, pad, shift, relu,
            in_shape: [c, ih, iw],
            out_shape: [c, oh, ow],
        });
        self.last()
    }

    pub fn dense(&mut self, input: i32, out_len: usize, shift: u32, relu: bool) -> i32 {
        let [c, h, w] = self.shape_of(input);
        let in_len = c * h * w;
        let wdata = self.rand_w(out_len * in_len);
        let wt = self.tensor(vec![out_len, in_len], Dtype::I8, wdata);
        let bdata = self.rand_b(out_len);
        let b = self.tensor(vec![out_len], Dtype::I32, bdata);
        self.layers.push(Layer::Dense {
            input, w: wt, b, shift, relu, in_len, out_len,
        });
        self.last()
    }

    pub fn maxpool(&mut self, input: i32, k: usize, stride: usize) -> i32 {
        let [c, ih, iw] = self.shape_of(input);
        let out_shape = [c, (ih - k) / stride + 1, (iw - k) / stride + 1];
        self.layers.push(Layer::MaxPool {
            input, k, stride, in_shape: [c, ih, iw], out_shape,
        });
        self.last()
    }

    pub fn avgpool2d(&mut self, input: i32, k: usize, stride: usize) -> i32 {
        let [c, ih, iw] = self.shape_of(input);
        let shift = (k * k).trailing_zeros();
        assert!(k * k == 1 << shift, "avgpool window must be a power of two");
        let out_shape = [c, (ih - k) / stride + 1, (iw - k) / stride + 1];
        self.layers.push(Layer::AvgPool2d {
            input, k, stride, shift, in_shape: [c, ih, iw], out_shape,
        });
        self.last()
    }

    pub fn avgpool_global(&mut self, input: i32) -> i32 {
        let [c, h, w] = self.shape_of(input);
        let shift = (h * w).trailing_zeros();
        assert!(h * w == 1 << shift, "global pool window must be 2^k");
        self.layers.push(Layer::AvgPoolGlobal {
            input, shift, in_shape: [c, h, w], out_shape: [c, 1, 1],
        });
        self.last()
    }

    pub fn add(&mut self, a: i32, b: i32, relu: bool) -> i32 {
        let sa = self.shape_of(a);
        assert_eq!(sa, self.shape_of(b), "add shape mismatch");
        self.layers.push(Layer::Add { a, b, relu, shape: sa.to_vec() });
        self.last()
    }

    pub fn concat(&mut self, inputs: Vec<i32>) -> i32 {
        let shapes: Vec<[usize; 3]> =
            inputs.iter().map(|&i| self.shape_of(i)).collect();
        let (h, w) = (shapes[0][1], shapes[0][2]);
        assert!(shapes.iter().all(|s| s[1] == h && s[2] == w));
        let c = shapes.iter().map(|s| s[0]).sum();
        self.layers.push(Layer::Concat {
            inputs,
            in_shapes: shapes,
            out_shape: [c, h, w],
        });
        self.last()
    }

    pub fn finish(self, num_classes: usize) -> ModelSpec {
        let spec = ModelSpec {
            name: self.name,
            profile: "synth".into(),
            input_shape: self.input_shape,
            num_classes,
            layers: self.layers,
            tensors: self.tensors,
        };
        spec.validate().expect("synthetic spec invalid");
        spec
    }

    /// Random int8 input for this model.
    pub fn random_input(spec: &ModelSpec, rng: &mut Rng) -> Vec<i32> {
        (0..spec.input_elems()).map(|_| rng.int8()).collect()
    }
}

/// Small fixed net covering conv (padded + unpadded), pool, dw and dense.
pub fn tiny_conv_net(seed: u64) -> ModelSpec {
    let mut b = Builder::new("tiny", [2, 8, 8], seed);
    let c1 = b.conv2d(-1, 4, 3, 1, 1, 6, true); // padded conv
    let p1 = b.maxpool(c1, 2, 2);
    let d1 = b.dwconv2d(p1, 3, 1, 1, 5, true);
    let c2 = b.conv2d(d1, 6, 3, 1, 0, 7, false); // valid conv
    b.dense(c2, 5, 4, false);
    b.finish(5)
}

/// A LeNet-5*-shaped net (Table 9) with random weights.
pub fn lenet_shaped(seed: u64) -> ModelSpec {
    let mut b = Builder::new("lenet_shaped", [1, 28, 28], seed);
    let c1 = b.conv2d(-1, 12, 6, 2, 0, 7, true);
    let c2 = b.conv2d(c1, 32, 6, 2, 0, 8, true);
    b.dense(c2, 10, 7, false);
    b.finish(10)
}

/// Residual + concat net (the ResNet/DenseNet graph shapes).
pub fn residual_net(seed: u64) -> ModelSpec {
    let mut b = Builder::new("residual", [3, 8, 8], seed);
    let c1 = b.conv2d(-1, 8, 3, 1, 1, 6, true);
    let c2 = b.conv2d(c1, 8, 3, 1, 1, 6, false);
    let a = b.add(c1, c2, true);
    let c3 = b.conv2d(a, 4, 1, 1, 0, 5, true);
    let cat = b.concat(vec![a, c3]);
    let t = b.conv2d(cat, 8, 1, 1, 0, 6, true);
    let p = b.avgpool2d(t, 2, 2);
    let g = b.avgpool_global(p);
    b.dense(g, 3, 5, false);
    b.finish(3)
}

/// Depthwise-separable net (the MobileNet model class): a strided stem,
/// then depthwise + pointwise pairs — the workload where the dw inner loop
/// (short filter rows, per-channel) dominates the retire stream.
pub fn dwconv_net(seed: u64) -> ModelSpec {
    let mut b = Builder::new("dwconv", [3, 12, 12], seed);
    let c1 = b.conv2d(-1, 8, 3, 2, 1, 6, true); // stem: 8x6x6
    let d1 = b.dwconv2d(c1, 3, 1, 1, 6, true);
    let p1 = b.conv2d(d1, 12, 1, 1, 0, 6, true); // pointwise
    let d2 = b.dwconv2d(p1, 3, 2, 1, 6, true); // 12x3x3
    let p2 = b.conv2d(d2, 16, 1, 1, 0, 7, true);
    b.dense(p2, 6, 6, false);
    b.finish(6)
}

/// Unrolled recurrent net (the RNN model class): an input projection, then
/// T Elman-style steps `h = relu(h + W·h)` over a persistent state vector —
/// chains of small matrix-vector products with none of conv's spatial
/// reuse, which is what makes its extension profile distinct.
pub fn rnn_net(seed: u64) -> ModelSpec {
    let mut b = Builder::new("rnn", [24, 1, 1], seed);
    let mut h = b.dense(-1, 24, 6, true);
    for _ in 0..6 {
        let z = b.dense(h, 24, 6, false);
        h = b.add(h, z, true);
    }
    b.dense(h, 8, 6, false);
    b.finish(8)
}

/// Fully random model for property fuzzing.
pub fn random_net(rng: &mut Rng) -> ModelSpec {
    let c0 = rng.range_usize(1, 4);
    let hw = *rng.choice(&[4usize, 6, 8, 9]);
    let seed = rng.next_u64();
    let mut b = Builder::new("fuzz", [c0, hw, hw], seed);
    let mut cur: i32 = -1;
    let n_layers = rng.range_usize(1, 6);
    for _ in 0..n_layers {
        let [c, h, w] = b.shape_of(cur);
        let shift = rng.int_in(0, 10) as u32;
        let relu = rng.bool();
        match rng.int_in(0, 5) {
            0 => {
                let k = *rng.choice(&[1usize, 2, 3]);
                let stride = rng.range_usize(1, 3);
                let pad = rng.range_usize(0, 2);
                if h + 2 * pad >= k && w + 2 * pad >= k {
                    let oc = rng.range_usize(1, 5);
                    cur = b.conv2d(cur, oc, k, stride, pad, shift, relu);
                }
            }
            1 => {
                let k = *rng.choice(&[1usize, 3]);
                let pad = rng.range_usize(0, 2);
                if h + 2 * pad >= k && w + 2 * pad >= k {
                    cur = b.dwconv2d(cur, k, rng.range_usize(1, 3), pad, shift,
                                     relu);
                }
            }
            2 => {
                if h >= 2 && w >= 2 {
                    cur = b.maxpool(cur, 2, rng.range_usize(1, 3));
                }
            }
            3 => {
                if h >= 2 && w >= 2 {
                    cur = b.avgpool2d(cur, 2, rng.range_usize(1, 3));
                }
            }
            4 => {
                // residual around a 3x3 same conv
                if h >= 3 && w >= 3 {
                    let y = b.conv2d(cur, c, 3, 1, 1, shift, false);
                    cur = b.add(cur, y, relu);
                }
            }
            _ => {
                // dense branch + concat
                if c <= 4 && h <= 6 {
                    let y = b.conv2d(cur, rng.range_usize(1, 3), 1, 1, 0, shift,
                                     relu);
                    cur = b.concat(vec![cur, y]);
                }
            }
        }
    }
    let classes = rng.range_usize(2, 6);
    b.dense(cur, classes, rng.int_in(0, 10) as u32, false);
    b.finish(classes)
}
