//! Execution profiling: the data-driven half of MARVEL (paper §II.C).
//!
//! The paper's pitch is that its ISA extensions come from *profiling* the
//! generated code on the baseline core rather than from assumed hotspots.
//! [`ProfileHook`] watches the retired instruction stream of a v0 run and
//! collects exactly the metrics of Fig 3 (pattern execution counts), Fig 4
//! (consecutive-`addi` immediate-pair histogram) and the per-instruction
//! cycle attribution behind Fig 5; [`crate::extgen`] then turns the profile
//! into extension proposals.

pub mod patterns;

pub use patterns::{PatternCounts, ProfileHook};

use std::collections::BTreeMap;

/// The add2i immediate-split coverage analysis of §II.C.2: given the Fig 4
/// histogram, what fraction of consecutive-addi pairs (weighted by their
/// 2-cycle baseline cost — proportional to raw count) is covered by an
/// (a, b)-bit unsigned immediate split, commuting the pair when needed?
pub fn split_coverage(
    hist: &BTreeMap<(i32, i32), u64>,
    bits_small: u32,
    bits_large: u32,
) -> f64 {
    let max_s = (1i64 << bits_small) - 1;
    let max_l = (1i64 << bits_large) - 1;
    let mut total = 0u64;
    let mut covered = 0u64;
    for (&(i1, i2), &n) in hist {
        total += n;
        let (a, b) = (i1 as i64, i2 as i64);
        let fits = |x: i64, y: i64| x >= 0 && y >= 0 && x <= max_s && y <= max_l;
        if fits(a, b) || fits(b, a) {
            covered += n;
        }
    }
    if total == 0 {
        return 1.0;
    }
    covered as f64 / total as f64
}

/// Search all 15-bit splits (the encoding budget of the fused format) for
/// the coverage-maximizing allocation — reproducing the paper's choice of
/// 5 + 10 bits.
pub fn best_split(hist: &BTreeMap<(i32, i32), u64>) -> (u32, u32, f64) {
    let mut best = (0, 15, 0.0f64);
    for a in 0..=15u32 {
        let b = 15 - a;
        let c = split_coverage(hist, a, b);
        if c > best.2 {
            best = (a, b, c);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(entries: &[((i32, i32), u64)]) -> BTreeMap<(i32, i32), u64> {
        entries.iter().copied().collect()
    }

    #[test]
    fn coverage_counts_commuted_pairs() {
        // (600, 3): only fits with the small slot taking 3
        let h = hist(&[((600, 3), 10)]);
        assert_eq!(split_coverage(&h, 5, 10), 1.0);
        // (600, 700): needs both large
        let h = hist(&[((600, 700), 10)]);
        assert_eq!(split_coverage(&h, 5, 10), 0.0);
        // negative immediates are never covered
        let h = hist(&[((-1, 3), 5)]);
        assert_eq!(split_coverage(&h, 5, 10), 0.0);
    }

    #[test]
    fn best_split_prefers_skewed_histograms() {
        // mostly (1, 512)-like pairs: needs >=10 bits on the large side
        let h = hist(&[((1, 512), 90), ((4, 900), 10)]);
        let (a, b, c) = best_split(&h);
        assert!(b >= 10, "split {a}/{b}");
        assert_eq!(c, 1.0);
    }

    #[test]
    fn empty_histogram_is_fully_covered() {
        assert_eq!(split_coverage(&BTreeMap::new(), 5, 10), 1.0);
    }
}
