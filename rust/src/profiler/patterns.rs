//! The retire-stream pattern miner (the instrument behind Fig 3 and Fig 4).
//!
//! Counts per-mnemonic retires and the consecutive patterns the paper's
//! Table 2 defines:
//!
//! * `mul_add_count` — `mul` immediately followed by an `add` that
//!   accumulates its product;
//! * `addi_addi_count` — two consecutive in-place `addi`s to distinct
//!   registers (+ the (i1, i2) immediate histogram of Fig 4);
//! * `fusedmac_count` — the 4-instruction conv inner-loop group
//!   (`mul, add, addi, addi` in our generated order; the paper lists the
//!   same four instructions);
//!
//! plus taken/total branch counts (the `blt` motivation for `zol`) and
//! per-PC cycle attribution (Fig 5's highlighted columns).

use std::collections::BTreeMap;

use crate::compiler::rewrite::patterns::{
    match_addi_pair_loose, match_mul_add_loose,
};
use crate::isa::Instr;
use crate::sim::RetireHook;

/// Aggregated pattern statistics from one (or more) runs.
#[derive(Clone, Debug)]
pub struct PatternCounts {
    /// Retired instructions per mnemonic, indexed by
    /// [`crate::isa::Instr::mnemonic_idx`] (array-indexed: this counter is
    /// bumped once per retired instruction — §Perf iteration 3).
    pub mnem: [u64; crate::isa::MNEMONICS.len()],
    /// Total retired instructions.
    pub total: u64,
    /// Total cycles.
    pub cycles: u64,
    /// `mul`+`add` accumulate pairs (Table 2: mul_add_count).
    pub mul_add: u64,
    /// Consecutive in-place `addi` pairs (Table 2: addi_addi_count).
    pub addi_addi: u64,
    /// The 4-instruction fusedmac group (Table 2: fusedmac_count).
    pub fusedmac: u64,
    /// Taken branches (pipeline-refill cycles — the zol target).
    pub branches_taken: u64,
    /// Fig 4 histogram: (first, second) immediate of consecutive addi pairs.
    pub addi_imm_hist: BTreeMap<(i32, i32), u64>,
    /// Dynamic occurrences of each mined window spec's pattern
    /// ([`crate::fusion::WINDOW`], per slot) in the retire stream — the
    /// counters `extgen::propose` turns into window proposals.  The conv
    /// specs' patterns end in `mac`/`fusedmac`, so those slots only count
    /// on *post-ladder* streams; `ldadd` ends in the base-ISA eltwise
    /// `add x20,x21,x22` and counts on any stream that retires it.
    pub window: [u64; crate::fusion::N_WINDOW],
}

impl Default for PatternCounts {
    fn default() -> Self {
        PatternCounts {
            mnem: [0; crate::isa::MNEMONICS.len()],
            total: 0,
            cycles: 0,
            mul_add: 0,
            addi_addi: 0,
            fusedmac: 0,
            branches_taken: 0,
            addi_imm_hist: BTreeMap::new(),
            window: [0; crate::fusion::N_WINDOW],
        }
    }
}

impl PatternCounts {
    pub fn count(&self, mnemonic: &str) -> u64 {
        crate::isa::MNEMONICS
            .iter()
            .position(|&m| m == mnemonic)
            .map(|i| self.mnem[i])
            .unwrap_or(0)
    }

    /// Per-mnemonic counts as a (sparse) sorted map, for reports.
    pub fn by_mnemonic(&self) -> BTreeMap<&'static str, u64> {
        crate::isa::MNEMONICS
            .iter()
            .zip(self.mnem.iter())
            .filter(|(_, &n)| n > 0)
            .map(|(&m, &n)| (m, n))
            .collect()
    }

    /// Merge another run's counts (multi-input profiling).
    pub fn merge(&mut self, other: &PatternCounts) {
        for (a, b) in self.mnem.iter_mut().zip(other.mnem.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.cycles += other.cycles;
        self.mul_add += other.mul_add;
        self.addi_addi += other.addi_addi;
        self.fusedmac += other.fusedmac;
        self.branches_taken += other.branches_taken;
        for (k, v) in &other.addi_imm_hist {
            *self.addi_imm_hist.entry(*k).or_insert(0) += v;
        }
        for (a, b) in self.window.iter_mut().zip(other.window.iter()) {
            *a += b;
        }
    }

    /// Top-n immediate pairs of the Fig 4 histogram (count-descending).
    pub fn top_addi_pairs(&self, n: usize) -> Vec<((i32, i32), u64)> {
        let mut v: Vec<_> =
            self.addi_imm_hist.iter().map(|(k, c)| (*k, *c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

/// Retire hook that mines the pattern counts with a 4-instruction window.
///
/// §Perf: pattern matching is gated on the class of the *retiring*
/// instruction (every mined pattern ends in `add`, `addi`, or — for the
/// window specs — a ladder fusion `mac`/`fusedmac`), and the
/// Fig 4 histogram keeps a one-entry cache for the hot bucket (the `1_1`
/// inner-loop pair dominates every conv workload) so the BTreeMap is only
/// touched on key changes.
pub struct ProfileHook {
    pub counts: PatternCounts,
    window: [Option<Instr>; 3],
    /// Cached histogram accumulator: (key, pending count).
    hist_cache: ((i32, i32), u64),
    /// Per-PC cycles/retires (Fig 5), sized to the program.
    pub pc_cycles: Vec<u64>,
    pub pc_retires: Vec<u64>,
}

impl ProfileHook {
    pub fn new(program_words: usize) -> Self {
        ProfileHook {
            counts: PatternCounts::default(),
            window: [None; 3],
            hist_cache: ((0, 0), 0),
            pc_cycles: vec![0; program_words],
            pc_retires: vec![0; program_words],
        }
    }

    #[inline]
    fn hist_bump(&mut self, key: (i32, i32)) {
        if self.hist_cache.1 > 0 && self.hist_cache.0 != key {
            let (k, n) = self.hist_cache;
            *self.counts.addi_imm_hist.entry(k).or_insert(0) += n;
            self.hist_cache = (key, 1);
        } else {
            self.hist_cache = (key, self.hist_cache.1 + 1);
        }
    }

    /// Flush the histogram cache (called automatically by `finish`).
    fn flush(&mut self) {
        if self.hist_cache.1 > 0 {
            let (k, n) = self.hist_cache;
            *self.counts.addi_imm_hist.entry(k).or_insert(0) += n;
            self.hist_cache.1 = 0;
        }
    }

    /// Finalize and take the counts (flushes internal caches).
    pub fn finish(mut self) -> PatternCounts {
        self.flush();
        self.counts
    }

    /// Borrowing accessor that flushes first (for in-place use).
    pub fn counts_flushed(&mut self) -> &PatternCounts {
        self.flush();
        &self.counts
    }

    /// The per-slot retire counts in the shape
    /// [`crate::sim::LowerOpts::profile`] expects (index = `pc/4`): feed a
    /// profiling run's retire distribution back into lowering so
    /// superinstruction fusion keys on the hottest straight-line runs
    /// instead of every static one (DESIGN.md §19).
    pub fn superop_profile(&self) -> Vec<u64> {
        self.pc_retires.clone()
    }

    /// Replay the retire window through the one generic matcher the
    /// rewrite engine uses, so "countable" and "fusable" can't drift.
    #[inline]
    fn replay_window(&mut self, hist: [Option<Instr>; 3], instr: &Instr) {
        for (i, spec) in crate::fusion::WINDOW.iter().enumerate() {
            let plen = spec.pattern.len();
            debug_assert!((2..=4).contains(&plen), "{}", spec.name);
            let mut buf = [*instr; 4];
            let mut ok = true;
            for k in 0..plen - 1 {
                match hist[4 - plen + k] {
                    Some(x) => buf[k] = x,
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && crate::fusion::try_match(spec, &buf[..plen]).is_some() {
                self.counts.window[i] += 1;
            }
        }
    }
}

impl RetireHook for ProfileHook {
    fn retire(&mut self, pc: u32, instr: &Instr, cycles: u64) {
        {
            let c = &mut self.counts;
            c.mnem[instr.mnemonic_idx()] += 1;
            c.total += 1;
            c.cycles += cycles;
        }

        // pattern windows, gated on the retiring instruction's class:
        // every mined pattern ends in `add` (mac) or `addi` (add2i, quad)
        let [p3, p2, p1] = self.window;
        match instr {
            Instr::Op { op: crate::isa::AluOp::Add, rd, rs1, rs2 } => {
                if let Some(p1) = p1 {
                    if match_mul_add_loose(&p1, instr) {
                        self.counts.mul_add += 1;
                    }
                }
                // the eltwise accumulate (`add x20,x21,x22`) terminates the
                // ldadd window pattern on any stream; the shape pre-filter
                // keeps the hot generic-add path replay-free
                {
                    use crate::compiler::asm::{ACC, OPA, OPB};
                    if *rd == ACC && *rs1 == OPA && *rs2 == OPB {
                        self.replay_window([p3, p2, p1], instr);
                    }
                }
            }
            Instr::OpImm { op: crate::isa::AluImmOp::Addi, .. } => {
                if let Some(p1) = p1 {
                    if let Some(pair) = match_addi_pair_loose(&p1, instr) {
                        self.counts.addi_addi += 1;
                        self.hist_bump(pair);
                        // mul, add(acc), addi, addi — the fusedmac group
                        if let (Some(p3), Some(p2)) = (p3, p2) {
                            if match_mul_add_loose(&p3, &p2) {
                                self.counts.fusedmac += 1;
                            }
                        }
                    }
                }
            }
            Instr::Branch { .. } => {
                // cycle cost > not-taken cost means the branch redirected
                if cycles > 1 {
                    self.counts.branches_taken += 1;
                }
            }
            // conv-class mined-window opportunities end in the ladder's
            // fused forms (ldadd's terminator is handled in the Add arm)
            Instr::Mac | Instr::FusedMac { .. } => {
                self.replay_window([p3, p2, p1], instr);
            }
            _ => {}
        }
        self.window = [p2, p1, Some(*instr)];

        let idx = (pc / 4) as usize;
        if idx < self.pc_cycles.len() {
            self.pc_cycles[idx] += cycles;
            self.pc_retires[idx] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, execute_compiled};
    use crate::models::synth::{tiny_conv_net, Builder};
    use crate::sim::V0;
    use crate::util::rng::Rng;

    fn profile_tiny() -> PatternCounts {
        let spec = tiny_conv_net(21);
        let c = compile(&spec, V0).unwrap();
        let mut hook = ProfileHook::new(c.words().len());
        let mut rng = Rng::new(5);
        let input = Builder::random_input(&spec, &mut rng);
        execute_compiled(&c, &spec, &input, 1 << 32, &mut hook).unwrap();
        hook.finish()
    }

    #[test]
    fn conv_workload_shows_paper_patterns() {
        let c = profile_tiny();
        assert!(c.total > 1000);
        // the Fig 3 patterns must all be present in generated conv code
        assert!(c.mul_add > 0, "mul+add pairs: {}", c.mul_add);
        assert!(c.addi_addi > 0, "addi pairs: {}", c.addi_addi);
        assert!(c.fusedmac > 0, "fusedmac quads: {}", c.fusedmac);
        assert!(c.branches_taken > 0);
        // conv inner loop: every mul is followed by its accumulate
        assert_eq!(c.mul_add, c.count("mul"));
        // fusedmac groups can't outnumber their parts
        assert!(c.fusedmac <= c.mul_add);
        assert!(c.fusedmac <= c.addi_addi);
        // histogram dominated by the (1, 1) inner-loop bump pair
        let top = c.top_addi_pairs(1);
        assert_eq!(top[0].0, (1, 1), "top pair {:?}", top);
    }

    #[test]
    fn merge_accumulates() {
        let a = profile_tiny();
        let mut m = a.clone();
        m.merge(&a);
        assert_eq!(m.total, 2 * a.total);
        assert_eq!(m.fusedmac, 2 * a.fusedmac);
        assert_eq!(
            m.addi_imm_hist.values().sum::<u64>(),
            2 * a.addi_imm_hist.values().sum::<u64>()
        );
    }

    #[test]
    fn superop_profile_feeds_profile_guided_lowering() {
        use crate::sim::{CycleModel, LowerOpts, Program, SUPEROP_TOPK};
        let spec = tiny_conv_net(21);
        let c = compile(&spec, V0).unwrap();
        let mut hook = ProfileHook::new(c.words().len());
        let mut rng = Rng::new(5);
        let input = Builder::random_input(&spec, &mut rng);
        execute_compiled(&c, &spec, &input, 1 << 32, &mut hook).unwrap();
        let profile = hook.superop_profile();
        assert_eq!(profile.len(), c.words().len());
        assert!(profile.iter().any(|&n| n > 0));
        let p = Program::decode_shared(V0, c.words()).unwrap();
        let cm = CycleModel::default();
        let all = p
            .lowered_with(&cm, &LowerOpts { superops: true, profile: None })
            .unwrap();
        let guided = p
            .lowered_with(
                &cm,
                &LowerOpts {
                    superops: true,
                    profile: Some(std::sync::Arc::new(profile)),
                },
            )
            .unwrap();
        // The hot conv inner loop is a fusible straight-line run, so the
        // guided table is non-empty; top-K caps it; and it can only be a
        // subset of the unprofiled (fuse-everything) table.
        assert!(guided.n_superops() >= 1);
        assert!(guided.n_superops() <= SUPEROP_TOPK);
        assert!(guided.n_superops() <= all.n_superops());
    }

    #[test]
    fn window_counters_fire_on_post_ladder_streams_only() {
        // v0 stream has no mac/fusedmac retires: counters stay zero
        let c0 = profile_tiny();
        assert_eq!(c0.window, [0; crate::fusion::N_WINDOW]);
        // v4 stream: the conv inner loop retires lb; lb; fusedmac — the
        // ldmacpp opportunity the extsearch flow mines
        let spec = tiny_conv_net(21);
        let c = compile(&spec, crate::sim::V4).unwrap();
        let mut hook = ProfileHook::new(c.words().len());
        let mut rng = Rng::new(5);
        let input = Builder::random_input(&spec, &mut rng);
        execute_compiled(&c, &spec, &input, 1 << 32, &mut hook).unwrap();
        let c4 = hook.finish();
        assert!(c4.window[1] > 0, "ldmacpp opportunities: {:?}", c4.window);
        // merge doubles them like every other counter
        let mut m = c4.clone();
        m.merge(&c4);
        assert_eq!(m.window[1], 2 * c4.window[1]);
    }
}
