//! The rust mirror of `python/compile/quant.py` — MARVEL's quantized
//! arithmetic contract.
//!
//! Everything downstream (the native reference executor, the codegen
//! constants, the golden comparison against the PJRT artifact) depends on
//! these four functions matching the Python definitions bit-for-bit.  The
//! generated RV32 code implements `requant` as:
//!
//! ```text
//! add  acc, acc, rnd   ; rnd = 1 << (shift-1), hoisted outside the loops
//! srai acc, acc, shift
//! blt/bge clamp to [relu ? 0 : -128, 127]
//! ```

pub const INT8_MIN: i32 = -128;
pub const INT8_MAX: i32 = 127;

/// Round-half-up arithmetic right shift (shift == 0 is the identity).
///
/// **Contract** (mirrors the generated RV32 code, see module docs):
///
/// - `shift` must be `< 32`.  The hardware has no requant shift ≥ 32 (the
///   field is derived from layer scales, all ≤ 31) and `1 << (shift - 1)`
///   would be UB-adjacent (release builds would silently mask the shift
///   amount); it is therefore a *checked* precondition, not a debug
///   assert — a spec that smuggles one in fails loudly on every build.
/// - The rounding add is **wrapping**, exactly like the RV32 `add` the
///   codegen emits: for `acc > i32::MAX - 2^(shift-1)` the sum wraps
///   negative and the result diverges from the arbitrary-precision Python
///   model (`quant.py` promotes to int64).  This is intentional — the rust
///   side mirrors the *hardware*, and real accumulators stay far below the
///   boundary (int8 × int8 MACs would need ~2^16 terms to get close).  The
///   property tests pin both regimes: bit-equality with the Python/i64
///   model on the non-overflowing domain, and the exact wrap semantics at
///   the boundary.
#[inline]
pub fn round_shift(acc: i32, shift: u32) -> i32 {
    assert!(shift < 32, "requant shift {shift} out of range (must be < 32)");
    if shift == 0 {
        acc
    } else {
        acc.wrapping_add(1 << (shift - 1)) >> shift
    }
}

/// Requantize an int32 accumulator to int8 range: shift, clamp, optional
/// ReLU floor (clamp order matches the generated code and the jnp model).
#[inline]
pub fn requant(acc: i32, shift: u32, relu: bool) -> i32 {
    let v = round_shift(acc, shift);
    let lo = if relu { 0 } else { INT8_MIN };
    v.clamp(lo, INT8_MAX)
}

/// Saturating int8 add (residual connections), with optional ReLU.
#[inline]
pub fn saturating_add(a: i32, b: i32, relu: bool) -> i32 {
    let v = (a + b).clamp(INT8_MIN, INT8_MAX);
    if relu {
        v.max(0)
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn round_shift_matches_python_examples() {
        // mirrors python/tests/test_quant.py
        assert_eq!(round_shift(5, 2), 1);
        assert_eq!(round_shift(6, 2), 2);
        assert_eq!(round_shift(7, 2), 2);
        assert_eq!(round_shift(-5, 2), -1);
        assert_eq!(round_shift(-6, 2), -1);
        assert_eq!(round_shift(-7, 2), -2);
        assert_eq!(round_shift(42, 0), 42);
    }

    #[test]
    fn prop_round_shift_is_round_half_up() {
        check("round_shift ≡ floor(x/2^s + 1/2)", 2000, |rng| {
            let acc = rng.int_in(-10_000_000, 10_000_000);
            let s = rng.int_in(0, 20) as u32;
            let got = round_shift(acc, s);
            let want = ((acc as f64) / f64::from(1u32 << s) + 0.5).floor() as i32;
            prop_assert_eq!(got, want, "acc={acc} s={s}");
            Ok(())
        });
    }

    /// The Python kernel reference (`quant.py::requant_np`): round-half-up
    /// in int64, where `acc + 2^(s-1)` can never wrap.
    fn python_round_shift_i64(acc: i64, shift: u32) -> i64 {
        if shift == 0 {
            acc
        } else {
            (acc + (1i64 << (shift - 1))) >> shift
        }
    }

    #[test]
    fn prop_round_shift_matches_python_up_to_overflow_boundary() {
        // On the whole domain where the i32 rounding add cannot wrap, the
        // hardware-mirroring implementation is bit-equal to the Python/i64
        // model — including accumulators *at* the last safe value.
        check("round_shift ≡ python model (non-wrapping domain)", 4000, |rng| {
            let s = rng.int_in(1, 31) as u32;
            let rnd = 1i32 << (s - 1);
            let hi = i32::MAX - rnd; // last acc whose rounding add fits
            let acc = match rng.int_in(0, 9) {
                0 => hi,                   // exact boundary
                1 => hi - 1,
                2 => i32::MIN,             // negative side never wraps
                _ => rng.int_in(i32::MIN, hi),
            };
            let got = round_shift(acc, s);
            let want = python_round_shift_i64(acc as i64, s);
            prop_assert_eq!(got as i64, want, "acc={acc} s={s}");
            Ok(())
        });
    }

    #[test]
    fn round_shift_wraps_like_rv32_add_past_boundary() {
        // One past the boundary the add wraps — the documented
        // hardware-mirroring divergence from the int64 Python model.
        for s in [1u32, 8, 15, 31] {
            let rnd = 1i32 << (s - 1);
            let acc = i32::MAX - rnd + 1; // acc + rnd == i32::MIN (wrapped)
            let got = round_shift(acc, s);
            let want_hw = i32::MIN >> s; // srai of the wrapped sum
            assert_eq!(got, want_hw, "s={s}");
            let python = python_round_shift_i64(acc as i64, s);
            assert_ne!(got as i64, python, "s={s}: wrap must be observable");
        }
    }

    #[test]
    #[should_panic(expected = "requant shift 32 out of range")]
    fn round_shift_rejects_shift_32() {
        round_shift(1, 32);
    }

    #[test]
    fn round_shift_full_shift_range_is_defined() {
        // Every legal shift 0..=31 has defined, python-matching semantics
        // for small accumulators (the common case).
        for s in 0..32u32 {
            assert_eq!(
                round_shift(1000, s) as i64,
                python_round_shift_i64(1000, s),
                "s={s}"
            );
        }
    }

    #[test]
    fn prop_requant_in_range() {
        check("requant lands in int8 range", 2000, |rng| {
            let acc = rng.int_in(i32::MIN / 4, i32::MAX / 4);
            let s = rng.int_in(0, 24) as u32;
            let relu = rng.bool();
            let v = requant(acc, s, relu);
            let lo = if relu { 0 } else { INT8_MIN };
            prop_assert!(v >= lo && v <= INT8_MAX, "v={v} acc={acc} s={s}");
            Ok(())
        });
    }

    #[test]
    fn saturating_add_edges() {
        assert_eq!(saturating_add(127, 127, false), 127);
        assert_eq!(saturating_add(-128, -128, false), -128);
        assert_eq!(saturating_add(-5, 2, true), 0);
        assert_eq!(saturating_add(-5, 2, false), -3);
    }
}
