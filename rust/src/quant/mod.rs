//! The rust mirror of `python/compile/quant.py` — MARVEL's quantized
//! arithmetic contract.
//!
//! Everything downstream (the native reference executor, the codegen
//! constants, the golden comparison against the PJRT artifact) depends on
//! these four functions matching the Python definitions bit-for-bit.  The
//! generated RV32 code implements `requant` as:
//!
//! ```text
//! add  acc, acc, rnd   ; rnd = 1 << (shift-1), hoisted outside the loops
//! srai acc, acc, shift
//! blt/bge clamp to [relu ? 0 : -128, 127]
//! ```

pub const INT8_MIN: i32 = -128;
pub const INT8_MAX: i32 = 127;

/// Round-half-up arithmetic right shift (shift == 0 is the identity).
#[inline]
pub fn round_shift(acc: i32, shift: u32) -> i32 {
    debug_assert!(shift < 32);
    if shift == 0 {
        acc
    } else {
        acc.wrapping_add(1 << (shift - 1)) >> shift
    }
}

/// Requantize an int32 accumulator to int8 range: shift, clamp, optional
/// ReLU floor (clamp order matches the generated code and the jnp model).
#[inline]
pub fn requant(acc: i32, shift: u32, relu: bool) -> i32 {
    let v = round_shift(acc, shift);
    let lo = if relu { 0 } else { INT8_MIN };
    v.clamp(lo, INT8_MAX)
}

/// Saturating int8 add (residual connections), with optional ReLU.
#[inline]
pub fn saturating_add(a: i32, b: i32, relu: bool) -> i32 {
    let v = (a + b).clamp(INT8_MIN, INT8_MAX);
    if relu {
        v.max(0)
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn round_shift_matches_python_examples() {
        // mirrors python/tests/test_quant.py
        assert_eq!(round_shift(5, 2), 1);
        assert_eq!(round_shift(6, 2), 2);
        assert_eq!(round_shift(7, 2), 2);
        assert_eq!(round_shift(-5, 2), -1);
        assert_eq!(round_shift(-6, 2), -1);
        assert_eq!(round_shift(-7, 2), -2);
        assert_eq!(round_shift(42, 0), 42);
    }

    #[test]
    fn prop_round_shift_is_round_half_up() {
        check("round_shift ≡ floor(x/2^s + 1/2)", 2000, |rng| {
            let acc = rng.int_in(-10_000_000, 10_000_000);
            let s = rng.int_in(0, 20) as u32;
            let got = round_shift(acc, s);
            let want = ((acc as f64) / f64::from(1u32 << s) + 0.5).floor() as i32;
            prop_assert_eq!(got, want, "acc={acc} s={s}");
            Ok(())
        });
    }

    #[test]
    fn prop_requant_in_range() {
        check("requant lands in int8 range", 2000, |rng| {
            let acc = rng.int_in(i32::MIN / 4, i32::MAX / 4);
            let s = rng.int_in(0, 24) as u32;
            let relu = rng.bool();
            let v = requant(acc, s, relu);
            let lo = if relu { 0 } else { INT8_MIN };
            prop_assert!(v >= lo && v <= INT8_MAX, "v={v} acc={acc} s={s}");
            Ok(())
        });
    }

    #[test]
    fn saturating_add_edges() {
        assert_eq!(saturating_add(127, 127, false), 127);
        assert_eq!(saturating_add(-128, -128, false), -128);
        assert_eq!(saturating_add(-5, 2, true), 0);
        assert_eq!(saturating_add(-5, 2, false), -3);
    }
}
