//! Rust-native quantized reference executor — the in-process oracle.
//!
//! Executes a [`ModelSpec`] directly (plain nested loops over i32 buffers),
//! mirroring `python/compile/kernels/ref.py` operator for operator.  Three
//! uses:
//! 1. oracle for the compiler round-trip property tests (compile → simulate
//!    → compare), with no artifacts required;
//! 2. fast golden path for the coordinator when the PJRT runtime is not
//!    needed;
//! 3. itself cross-validated against the AOT HLO artifact in the
//!    `golden_artifacts` integration test, closing the Python↔Rust loop.
//!
//! Layouts match the exporter: activations CHW row-major, conv weights
//! (OC, IC, KH, KW), dw weights (C, KH, KW), dense (O, I).

use anyhow::{ensure, Result};

use crate::compiler::spec::{Layer, ModelSpec};
use crate::quant::{requant, saturating_add};

/// In-bounds (zero-padded) input fetch for convolutions.
#[inline]
fn at_pad(x: &[i32], shape: [usize; 3], c: usize, y: isize, xc: isize) -> i32 {
    let (h, w) = (shape[1] as isize, shape[2] as isize);
    if y < 0 || y >= h || xc < 0 || xc >= w {
        0
    } else {
        x[c * (h as usize) * (w as usize)
            + (y as usize) * (w as usize)
            + xc as usize]
    }
}

#[allow(clippy::too_many_arguments)]
fn conv2d(
    x: &[i32],
    in_shape: [usize; 3],
    w: &[i32],
    wshape: &[usize],
    b: &[i32],
    stride: usize,
    pad: usize,
    shift: u32,
    relu: bool,
    out_shape: [usize; 3],
) -> Vec<i32> {
    let [oc, oh, ow] = out_shape;
    let (ic, kh, kw) = (wshape[1], wshape[2], wshape[3]);
    let mut out = vec![0i32; oc * oh * ow];
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = b[o];
                for i in 0..ic {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let y = (oy * stride + ky) as isize - pad as isize;
                            let xx = (ox * stride + kx) as isize - pad as isize;
                            let xv = at_pad(x, in_shape, i, y, xx);
                            let wv = w[((o * ic + i) * kh + ky) * kw + kx];
                            acc = acc.wrapping_add(xv.wrapping_mul(wv));
                        }
                    }
                }
                out[(o * oh + oy) * ow + ox] = requant(acc, shift, relu);
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn dwconv2d(
    x: &[i32],
    in_shape: [usize; 3],
    w: &[i32],
    wshape: &[usize],
    b: &[i32],
    stride: usize,
    pad: usize,
    shift: u32,
    relu: bool,
    out_shape: [usize; 3],
) -> Vec<i32> {
    let [c, oh, ow] = out_shape;
    let (kh, kw) = (wshape[1], wshape[2]);
    let mut out = vec![0i32; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = b[ch];
                for ky in 0..kh {
                    for kx in 0..kw {
                        let y = (oy * stride + ky) as isize - pad as isize;
                        let xx = (ox * stride + kx) as isize - pad as isize;
                        let xv = at_pad(x, in_shape, ch, y, xx);
                        let wv = w[(ch * kh + ky) * kw + kx];
                        acc = acc.wrapping_add(xv.wrapping_mul(wv));
                    }
                }
                out[(ch * oh + oy) * ow + ox] = requant(acc, shift, relu);
            }
        }
    }
    out
}

fn dense(
    x: &[i32],
    w: &[i32],
    b: &[i32],
    in_len: usize,
    out_len: usize,
    shift: u32,
    relu: bool,
) -> Vec<i32> {
    let mut out = vec![0i32; out_len];
    for o in 0..out_len {
        let mut acc = b[o];
        for i in 0..in_len {
            acc = acc.wrapping_add(x[i].wrapping_mul(w[o * in_len + i]));
        }
        out[o] = requant(acc, shift, relu);
    }
    out
}

fn maxpool(
    x: &[i32],
    in_shape: [usize; 3],
    k: usize,
    stride: usize,
    out_shape: [usize; 3],
) -> Vec<i32> {
    let [c, oh, ow] = out_shape;
    let (ih, iw) = (in_shape[1], in_shape[2]);
    let _ = ih;
    let mut out = vec![0i32; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = i32::MIN;
                for ky in 0..k {
                    for kx in 0..k {
                        let v = x[ch * ih * iw
                            + (oy * stride + ky) * iw
                            + (ox * stride + kx)];
                        m = m.max(v);
                    }
                }
                out[(ch * oh + oy) * ow + ox] = m;
            }
        }
    }
    out
}

fn avgpool2d(
    x: &[i32],
    in_shape: [usize; 3],
    k: usize,
    stride: usize,
    shift: u32,
    out_shape: [usize; 3],
) -> Vec<i32> {
    let [c, oh, ow] = out_shape;
    let (ih, iw) = (in_shape[1], in_shape[2]);
    let _ = ih;
    let mut out = vec![0i32; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i32;
                for ky in 0..k {
                    for kx in 0..k {
                        acc += x[ch * ih * iw
                            + (oy * stride + ky) * iw
                            + (ox * stride + kx)];
                    }
                }
                out[(ch * oh + oy) * ow + ox] = requant(acc, shift, false);
            }
        }
    }
    out
}

fn avgpool_global(x: &[i32], in_shape: [usize; 3], shift: u32) -> Vec<i32> {
    let [c, h, w] = in_shape;
    (0..c)
        .map(|ch| {
            let acc: i32 = x[ch * h * w..(ch + 1) * h * w].iter().sum();
            requant(acc, shift, false)
        })
        .collect()
}

/// Execute every layer; returns all intermediate activations (the last entry
/// is the logits).
pub fn run_all(spec: &ModelSpec, input: &[i32]) -> Result<Vec<Vec<i32>>> {
    ensure!(
        input.len() == spec.input_elems(),
        "input len {} != expected {}",
        input.len(),
        spec.input_elems()
    );
    let mut outs: Vec<Vec<i32>> = Vec::with_capacity(spec.layers.len());
    fn src<'a>(input: &'a [i32], outs: &'a [Vec<i32>], i: i32) -> &'a [i32] {
        if i == -1 {
            input
        } else {
            &outs[i as usize]
        }
    }
    for layer in &spec.layers {
        let out = match layer {
            Layer::Conv2d {
                input: inp, w, b, stride, pad, shift, relu, in_shape, out_shape,
            } => {
                let x = src(input, &outs, *inp);
                let wt = spec.tensor(w)?;
                let bt = spec.tensor(b)?;
                conv2d(x, *in_shape, &wt.data, &wt.shape, &bt.data, *stride,
                       *pad, *shift, *relu, *out_shape)
            }
            Layer::DwConv2d {
                input: inp, w, b, stride, pad, shift, relu, in_shape, out_shape,
            } => {
                let x = src(input, &outs, *inp);
                let wt = spec.tensor(w)?;
                let bt = spec.tensor(b)?;
                dwconv2d(x, *in_shape, &wt.data, &wt.shape, &bt.data, *stride,
                         *pad, *shift, *relu, *out_shape)
            }
            Layer::Dense { input: inp, w, b, shift, relu, in_len, out_len } => {
                let x = src(input, &outs, *inp);
                let wt = spec.tensor(w)?;
                let bt = spec.tensor(b)?;
                dense(x, &wt.data, &bt.data, *in_len, *out_len, *shift, *relu)
            }
            Layer::MaxPool { input: inp, k, stride, in_shape, out_shape } => {
                maxpool(src(input, &outs, *inp), *in_shape, *k, *stride,
                        *out_shape)
            }
            Layer::AvgPool2d {
                input: inp, k, stride, shift, in_shape, out_shape,
            } => avgpool2d(src(input, &outs, *inp), *in_shape, *k, *stride,
                           *shift, *out_shape),
            Layer::AvgPoolGlobal { input: inp, shift, in_shape, .. } => {
                avgpool_global(src(input, &outs, *inp), *in_shape, *shift)
            }
            Layer::Add { a, b, relu, .. } => {
                let xa = src(input, &outs, *a);
                let xb = src(input, &outs, *b);
                ensure!(xa.len() == xb.len(), "add operand size mismatch");
                xa.iter()
                    .zip(xb)
                    .map(|(&p, &q)| saturating_add(p, q, *relu))
                    .collect()
            }
            Layer::Concat { inputs, .. } => {
                let mut out = Vec::new();
                for &i in inputs {
                    out.extend_from_slice(src(input, &outs, i));
                }
                out
            }
        };
        outs.push(out);
    }
    Ok(outs)
}

/// Execute and return only the final logits.
pub fn run(spec: &ModelSpec, input: &[i32]) -> Result<Vec<i32>> {
    Ok(run_all(spec, input)?.pop().expect("model has layers"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        let x = vec![5, -3, 100, -120];
        let out = conv2d(&x, [1, 2, 2], &[1], &[1, 1, 1, 1], &[0], 1, 0, 0,
                         false, [1, 2, 2]);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_padding_zeroes() {
        let x = vec![0, 0, 0, 0, 1, 0, 0, 0, 0];
        let w = vec![1; 9];
        let out = conv2d(&x, [1, 3, 3], &w, &[1, 1, 3, 3], &[0], 1, 1, 0,
                         false, [1, 3, 3]);
        assert_eq!(out, vec![1; 9]);
    }

    #[test]
    fn conv_requant_and_relu() {
        let x = vec![100, -100];
        let w = vec![3];
        let out = conv2d(&x, [1, 1, 2], &w, &[1, 1, 1, 1], &[0], 1, 0, 1,
                         true, [1, 1, 2]);
        assert_eq!(out, vec![127, 0]);
    }

    #[test]
    fn maxpool_basics() {
        let x = vec![1, 2, 3, 4, 5, 6, 7, 8, -1, -2, -3, -4, -5, -6, -7, -8];
        let out = maxpool(&x, [1, 4, 4], 2, 2, [1, 2, 2]);
        assert_eq!(out, vec![6, 8, -1, -3]);
    }

    #[test]
    fn avgpool_rounding() {
        let out = avgpool2d(&[1, 1, 1, 2], [1, 2, 2], 2, 2, 2, [1, 1, 1]);
        assert_eq!(out, vec![1]);
        let out = avgpool_global(&[1, 1, 1, 2], [1, 2, 2], 2);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn dense_basics() {
        let out = dense(&[1, 2, 3], &[1, 1, 1, 2, 0, -2], &[0, 10], 3, 2, 0,
                        false);
        assert_eq!(out, vec![6, 6]);
    }
}
