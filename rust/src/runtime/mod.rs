//! PJRT golden-model runtime: loads the AOT HLO artifacts and executes them
//! on the XLA CPU client.
//!
//! This is the request-path half of the AOT bridge: python/jax lowered the
//! L2 model (built from the L1 Pallas kernels) to HLO **text** at build
//! time; here the rust coordinator compiles that text once with
//! `PjRtClient::cpu()` and executes it for golden-output verification of
//! the ISS runs.  Python never runs at this point.
//!
//! HLO text (not serialized HloModuleProto) is mandatory: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The PJRT path wraps the `xla` crate, which cannot be vendored offline —
//! it is therefore gated behind the `pjrt` cargo feature (add `xla` to
//! `[dependencies]` when enabling it).  Without the feature, [`Runtime`]
//! and [`GoldenModel`] compile to stubs that report themselves unavailable,
//! and everything else in this module ([`GoldenIo`], [`load_golden_io`])
//! works unchanged — flows simply run with `use_pjrt: false`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// A compiled golden model (one HLO executable + its I/O geometry).
    pub struct GoldenModel {
        exe: xla::PjRtLoadedExecutable,
        input_shape: [usize; 3],
        output_len: usize,
    }

    /// Shared PJRT CPU client (one per process).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create the PJRT CPU client.
        pub fn cpu() -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile `artifacts/hlo/<name>.hlo.txt`.
        pub fn load_model(
            &self,
            artifacts: &Path,
            name: &str,
            input_shape: [usize; 3],
            output_len: usize,
        ) -> Result<GoldenModel> {
            let path = artifacts.join("hlo").join(format!("{name}.hlo.txt"));
            ensure!(path.exists(), "missing HLO artifact {}", path.display());
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(GoldenModel { exe, input_shape, output_len })
        }
    }

    impl GoldenModel {
        /// Run one inference: int8-range CHW input -> logits.
        pub fn run(&self, input: &[i32]) -> Result<Vec<i32>> {
            let [c, h, w] = self.input_shape;
            ensure!(
                input.len() == c * h * w,
                "input len {} != {c}x{h}x{w}",
                input.len()
            );
            let lit = xla::Literal::vec1(input)
                .reshape(&[c as i64, h as i64, w as i64])
                .context("reshaping input literal")?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .context("executing golden model")?[0][0]
                .to_literal_sync()
                .context("fetching result")?;
            // lowered with return_tuple=True -> 1-tuple of logits
            let out = result.to_tuple1().context("unwrapping result tuple")?;
            let logits = out.to_vec::<i32>().context("reading logits")?;
            ensure!(
                logits.len() == self.output_len,
                "golden output len {} != expected {}",
                logits.len(),
                self.output_len
            );
            Ok(logits)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use super::*;
    use anyhow::bail;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the \
         `pjrt` cargo feature (requires the `xla` crate)";

    /// Stub standing in for the PJRT-compiled HLO executable.
    pub struct GoldenModel {
        _private: (),
    }

    /// Stub standing in for the PJRT CPU client.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_model(
            &self,
            _artifacts: &Path,
            _name: &str,
            _input_shape: [usize; 3],
            _output_len: usize,
        ) -> Result<GoldenModel> {
            bail!("{UNAVAILABLE}")
        }
    }

    impl GoldenModel {
        pub fn run(&self, _input: &[i32]) -> Result<Vec<i32>> {
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use pjrt_impl::{GoldenModel, Runtime};

/// Golden I/O bundle exported by the AOT step (`data/<name>_{x,y}.bin`).
pub struct GoldenIo {
    pub inputs: Vec<Vec<i32>>,
    pub outputs: Vec<Vec<i32>>,
}

/// Load the exporter's golden inputs and reference logits.
pub fn load_golden_io(artifacts: &Path, name: &str) -> Result<GoldenIo> {
    let meta = crate::util::json::parse_file(
        &artifacts.join("data").join(format!("{name}_io.json")),
    )?;
    let n = meta.get("n")?.as_usize()?;
    let ishape = meta.usize_list("input_shape")?;
    let in_elems: usize = ishape.iter().product();
    let out_len = meta.get("output_len")?.as_usize()?;

    let xs = std::fs::read(artifacts.join("data").join(format!("{name}_x.bin")))
        .context("reading golden inputs")?;
    ensure!(xs.len() == n * in_elems, "golden x size mismatch");
    let ys = std::fs::read(artifacts.join("data").join(format!("{name}_y.bin")))
        .context("reading golden outputs")?;
    ensure!(ys.len() == n * out_len * 4, "golden y size mismatch");

    let inputs = (0..n)
        .map(|i| {
            xs[i * in_elems..(i + 1) * in_elems]
                .iter()
                .map(|&b| b as i8 as i32)
                .collect()
        })
        .collect();
    let outputs = (0..n)
        .map(|i| {
            ys[i * out_len * 4..(i + 1) * out_len * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        })
        .collect();
    Ok(GoldenIo { inputs, outputs })
}
