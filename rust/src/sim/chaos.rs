//! Deterministic fault injection for the execution stack (DESIGN.md §16).
//!
//! A [`FaultPlan`] is a seeded, fully deterministic schedule of faults —
//! worker kills, corrupted wire lines, transient worker/hydration
//! failures, delayed and duplicated responses — injected at one of two
//! seams:
//!
//! - **worker site** (`worker:` prefix): inside [`super::shard::worker_loop`],
//!   triggered on the wire `seq` of the job being handled.  This exercises
//!   the *real* coordinator recovery machinery: death requeue + respawn
//!   (PR 4), retry/backoff budgets and straggler re-dispatch
//!   ([`super::shard::ShardPool`]).  The plan reaches the worker process
//!   via the `MARVEL_CHAOS` environment variable, which the coordinator
//!   sets *explicitly per incarnation* ([`FaultPlan::strip_one_shot`]):
//!   death-causing faults (kill, corrupt) go to exactly one process ever,
//!   so an injected death can never re-fire on the re-dispatched job and
//!   masquerade as a poison job.
//! - **exec site** (no prefix, or `exec:`): inside [`ChaosExec`], an
//!   [`Executor`] wrapper over *any* backend, triggered on the global
//!   submission index.  Faults are simulated at the trait seam (a "kill"
//!   becomes a retryable failure of that job), and `ChaosExec` heals its
//!   own injections with a bounded retry + exponential-backoff loop
//!   ([`CHAOS_EXEC_RETRIES`]) — a plan within budget is invisible in the
//!   results; a plan past budget surfaces a *fatal* classified
//!   [`SimError::Remote`] at exactly the faulted index.
//!
//! Every fault is replayable: the plan is a pure value (`parse` ∘
//! `Display` round-trips), triggers are indices rather than clocks, and
//! the `seed:<S>:<N>` generator expands to the same schedule for the same
//! seed on every machine.
//!
//! **Grammar** — comma-separated entries, each
//! `[site:]fault@N[xK][:MS]`:
//!
//! ```text
//! worker:kill@4            kill the worker process handling wire seq 4
//! worker:corrupt@2         garbage line instead of seq 2's result
//! worker:transient@6x2     transient error for seq 6, at most 2 times
//! worker:hydrate@1         transient hydration failure for seq 1
//! worker:delay@3:50        sleep 50 ms before replying to seq 3
//! worker:dup@5             write seq 5's result line twice
//! transient@7              exec-site: job 7 fails retryably once
//! delay@0:10               exec-site: job 0's result delayed 10 ms
//! seed:42:6                6 pseudo-random exec-site faults from seed 42
//! ```

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::cpu::{RemoteKind, SimError};
use super::engine::JobOutput;
use super::exec::{Caps, Executor, JobSpec};

/// Environment variable carrying a rendered [`FaultPlan`]: read by
/// `marvel` commands as the `--chaos` default, and the channel the shard
/// coordinator uses to hand each worker incarnation its (possibly
/// stripped) plan.
pub const MARVEL_CHAOS_ENV: &str = "MARVEL_CHAOS";

/// How many times [`ChaosExec`] re-runs a job whose failure it injected
/// itself before giving up and surfacing a fatal budget-exhausted error.
pub const CHAOS_EXEC_RETRIES: u32 = 3;

/// Base of `ChaosExec`'s exponential backoff between its retry rounds
/// (doubles per attempt).  Tiny on purpose: chaos runs live in tests.
const CHAOS_BACKOFF_BASE: Duration = Duration::from_millis(1);

/// Which seam a fault is injected at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Inside the worker process (`worker_loop`), triggered on wire seq.
    Worker,
    /// Inside [`ChaosExec`], triggered on the global submission index.
    Exec,
}

/// What goes wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worker site: the process exits before replying (a real death, seen
    /// by the coordinator as EOF).  Exec site: simulated as a retryable
    /// failure of the job.  One-shot at the worker site (see
    /// [`FaultPlan::strip_one_shot`]).
    Kill,
    /// Worker site: a garbage line replaces the result (the coordinator's
    /// reader declares a protocol error — a death).  Exec site: simulated
    /// as a retryable failure.  One-shot at the worker site.
    Corrupt,
    /// A transient (retryable) failure of the job — the error message
    /// carries [`RemoteKind::TRANSIENT_MARKER`].
    Transient,
    /// A transient hydration failure (the model could not be resolved /
    /// compiled *this time*), also retryable.
    Hydrate,
    /// The response is delayed by `delay_ms` (straggler simulation).
    Delay,
    /// The response is duplicated: the worker writes the result line
    /// twice; `ChaosExec` runs the job twice and asserts the copies are
    /// bit-identical (the purity contract duplicates rest on).
    Dup,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::Kill => "kill",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Transient => "transient",
            FaultKind::Hydrate => "hydrate",
            FaultKind::Delay => "delay",
            FaultKind::Dup => "dup",
        }
    }

    fn from_name(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "kill" => FaultKind::Kill,
            "corrupt" => FaultKind::Corrupt,
            "transient" => FaultKind::Transient,
            "hydrate" => FaultKind::Hydrate,
            "delay" => FaultKind::Delay,
            "dup" => FaultKind::Dup,
            other => bail!(
                "unknown fault {other:?} (expected kill|corrupt|transient|\
                 hydrate|delay|dup)"
            ),
        })
    }
}

/// One scheduled fault.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fault {
    pub site: Site,
    pub kind: FaultKind,
    /// Trigger index: wire seq (worker site) / global submission index
    /// (exec site).
    pub at: u64,
    /// Fire at most this many times (the `xK` suffix; default 1).  Counted
    /// per process at the worker site, per wrapper at the exec site.
    pub count: u32,
    /// Milliseconds, for [`FaultKind::Delay`].
    pub delay_ms: u64,
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.site == Site::Worker {
            write!(f, "worker:")?;
        }
        write!(f, "{}@{}", self.kind.name(), self.at)?;
        if self.count != 1 {
            write!(f, "x{}", self.count)?;
        }
        if self.kind == FaultKind::Delay {
            write!(f, ":{}", self.delay_ms)?;
        }
        Ok(())
    }
}

/// A deterministic fault schedule: the parsed form of `--chaos` /
/// `MARVEL_CHAOS`.  `parse` ∘ `Display` round-trips (the `seed:` form
/// expands at parse time, so a re-rendered plan lists its concrete
/// faults — which is what lets the coordinator strip and re-serialize it
/// per worker incarnation).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// Parse a plan string (see the module docs for the grammar).  The
    /// empty string is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(rest) = entry.strip_prefix("seed:") {
                let (seed, n) = rest.split_once(':').with_context(|| {
                    format!("chaos entry {entry:?}: expected seed:<S>:<N>")
                })?;
                let seed: u64 = seed.parse().with_context(|| {
                    format!("chaos entry {entry:?}: bad seed")
                })?;
                let n: usize = n.parse().with_context(|| {
                    format!("chaos entry {entry:?}: bad fault count")
                })?;
                ensure!(n <= 1024, "chaos entry {entry:?}: at most 1024 faults");
                faults.extend(generate(seed, n));
                continue;
            }
            let (site, rest) = if let Some(r) = entry.strip_prefix("worker:") {
                (Site::Worker, r)
            } else if let Some(r) = entry.strip_prefix("exec:") {
                (Site::Exec, r)
            } else {
                (Site::Exec, entry)
            };
            let (kind, spec) = rest.split_once('@').with_context(|| {
                format!("chaos entry {entry:?}: expected fault@N")
            })?;
            let kind = FaultKind::from_name(kind)
                .with_context(|| format!("chaos entry {entry:?}"))?;
            let (at_count, ms) = match spec.split_once(':') {
                Some((l, r)) => (l, Some(r)),
                None => (spec, None),
            };
            let (at, count) = match at_count.split_once('x') {
                Some((a, k)) => (a, Some(k)),
                None => (at_count, None),
            };
            let at: u64 = at.parse().with_context(|| {
                format!("chaos entry {entry:?}: bad trigger index")
            })?;
            let count: u32 = match count {
                None => 1,
                Some(k) => {
                    let k = k.parse().with_context(|| {
                        format!("chaos entry {entry:?}: bad repeat count")
                    })?;
                    ensure!(k >= 1, "chaos entry {entry:?}: xK needs K ≥ 1");
                    k
                }
            };
            let delay_ms: u64 = match (kind, ms) {
                (FaultKind::Delay, Some(ms)) => ms.parse().with_context(|| {
                    format!("chaos entry {entry:?}: bad delay ms")
                })?,
                (FaultKind::Delay, None) => bail!(
                    "chaos entry {entry:?}: delay needs :MS (delay@N:MS)"
                ),
                (_, Some(_)) => bail!(
                    "chaos entry {entry:?}: only delay takes a :MS suffix"
                ),
                (_, None) => 0,
            };
            faults.push(Fault { site, kind, at, count, delay_ms });
        }
        Ok(FaultPlan { faults })
    }

    /// Parse the plan from `MARVEL_CHAOS`, if set and non-empty.  A set
    /// but unparseable value is a hard error — a typo must not silently
    /// run without chaos.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(MARVEL_CHAOS_ENV) {
            Ok(s) if !s.trim().is_empty() => {
                let plan = FaultPlan::parse(&s).with_context(|| {
                    format!("parsing {MARVEL_CHAOS_ENV}={s:?}")
                })?;
                Ok(Some(plan))
            }
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The worker-site subset (what a worker process acts on).
    pub fn worker_faults(&self) -> impl Iterator<Item = &Fault> {
        self.faults.iter().filter(|f| f.site == Site::Worker)
    }

    /// The exec-site subset (what [`ChaosExec`] acts on).
    pub fn exec_faults(&self) -> impl Iterator<Item = &Fault> {
        self.faults.iter().filter(|f| f.site == Site::Exec)
    }

    /// The plan with death-causing worker faults (kill, corrupt) removed —
    /// what every worker incarnation *except the first* receives.  A
    /// worker death re-dispatches its jobs, so a death fault that rode
    /// along to the replacement (or to a sibling given the same plan)
    /// would fire again on the same wire seq and accumulate toward the
    /// [`super::shard::POISON_DEATHS`] panic; stripping makes every
    /// injected death exactly once.
    pub fn strip_one_shot(&self) -> FaultPlan {
        FaultPlan {
            faults: self
                .faults
                .iter()
                .filter(|f| {
                    !(f.site == Site::Worker
                        && matches!(
                            f.kind,
                            FaultKind::Kill | FaultKind::Corrupt
                        ))
                })
                .cloned()
                .collect(),
        }
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, fault) in self.faults.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{fault}")?;
        }
        Ok(())
    }
}

/// SplitMix64 — the deterministic generator behind `seed:<S>:<N>`.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Expand `seed:<S>:<N>` into `n` exec-site faults: kinds drawn from the
/// retryable/benign set (transient, hydrate, delay, dup — never a death,
/// so a generated plan is always within a healthy pool's recovery
/// envelope), triggers in `0..32`, delays in `1..=5` ms.
fn generate(seed: u64, n: usize) -> Vec<Fault> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            let kind = match splitmix64(&mut state) % 4 {
                0 => FaultKind::Transient,
                1 => FaultKind::Hydrate,
                2 => FaultKind::Delay,
                _ => FaultKind::Dup,
            };
            let at = splitmix64(&mut state) % 32;
            let delay_ms = if kind == FaultKind::Delay {
                1 + splitmix64(&mut state) % 5
            } else {
                0
            };
            Fault { site: Site::Exec, kind, at, count: 1, delay_ms }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Worker-site runtime
// ---------------------------------------------------------------------------

/// What the worker loop must do to the job it is currently handling, in
/// application order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkerAction {
    /// Sleep this long before anything else (straggler simulation).
    Delay(Duration),
    /// Exit the process without replying (the injected death).
    Kill,
    /// Write a garbage line instead of the result (protocol corruption).
    Corrupt,
    /// Reply with this error instead of running the job.
    ErrorResult(String),
    /// Write the result line twice.
    Dup,
}

/// Per-process worker-site fault state: the worker-site subset of a plan
/// plus fire counts (each fault fires at most `count` times in this
/// process).
pub struct WorkerChaos {
    faults: Vec<(Fault, u32)>,
}

impl WorkerChaos {
    /// Build from a plan's worker-site faults; `None` if there are none.
    pub fn new(plan: &FaultPlan) -> Option<WorkerChaos> {
        let faults: Vec<(Fault, u32)> =
            plan.worker_faults().map(|f| (f.clone(), 0)).collect();
        if faults.is_empty() {
            None
        } else {
            Some(WorkerChaos { faults })
        }
    }

    /// Build from `MARVEL_CHAOS` (the coordinator sets it per
    /// incarnation).  Unparseable plans are a hard error.
    pub fn from_env() -> Result<Option<WorkerChaos>> {
        Ok(FaultPlan::from_env()?.as_ref().and_then(WorkerChaos::new))
    }

    /// The actions to apply while handling wire seq `seq`, in application
    /// order ([`WorkerAction`] variant order).  Advances fire counts.
    pub fn actions(&mut self, seq: u64) -> Vec<WorkerAction> {
        let mut out = Vec::new();
        for (fault, fired) in &mut self.faults {
            if fault.at != seq || *fired >= fault.count {
                continue;
            }
            *fired += 1;
            out.push(match fault.kind {
                FaultKind::Delay => {
                    WorkerAction::Delay(Duration::from_millis(fault.delay_ms))
                }
                FaultKind::Kill => WorkerAction::Kill,
                FaultKind::Corrupt => WorkerAction::Corrupt,
                FaultKind::Transient => WorkerAction::ErrorResult(format!(
                    "chaos: injected transient worker failure at seq {seq}"
                )),
                FaultKind::Hydrate => WorkerAction::ErrorResult(format!(
                    "chaos: injected transient hydration failure at seq {seq}"
                )),
                FaultKind::Dup => WorkerAction::Dup,
            });
        }
        out.sort_by_key(|a| match a {
            WorkerAction::Delay(_) => 0,
            WorkerAction::Kill => 1,
            WorkerAction::Corrupt => 2,
            WorkerAction::ErrorResult(_) => 3,
            WorkerAction::Dup => 4,
        });
        out
    }
}

// ---------------------------------------------------------------------------
// Exec-site wrapper
// ---------------------------------------------------------------------------

/// An [`Executor`] wrapper injecting a plan's exec-site faults over any
/// backend, then healing its own injections with a bounded
/// retry + exponential-backoff loop (the exec-seam twin of the shard
/// pool's budgets).  Faults trigger on the *global* submission index —
/// the `j`-th job ever submitted to this wrapper — so a plan addresses
/// jobs stably across batches.
///
/// Only failures this wrapper injected are retried: a real error from the
/// inner backend (deterministic simulator faults, or a wire error that
/// already exhausted the pool's own budget) passes through untouched.
/// An injection that keeps firing past [`CHAOS_EXEC_RETRIES`] surfaces as
/// a *fatal* [`SimError::Remote`] naming the exhausted budget, at exactly
/// the faulted job's index.
pub struct ChaosExec {
    inner: Box<dyn Executor>,
    faults: Vec<(Fault, u32)>,
    next_index: u64,
    queue: Vec<(u64, JobSpec)>,
}

impl ChaosExec {
    /// Wrap `inner` with `plan`'s exec-site faults.  (A plan with only
    /// worker-site faults yields a transparent wrapper — worker faults
    /// travel by environment, not through this seam.)
    pub fn new(inner: Box<dyn Executor>, plan: &FaultPlan) -> ChaosExec {
        ChaosExec {
            inner,
            faults: plan.exec_faults().map(|f| (f.clone(), 0)).collect(),
            next_index: 0,
            queue: Vec::new(),
        }
    }

    /// Fire every pending fault for global job index `gi`.  Returns the
    /// (possibly replaced) result and whether a *retryable injection*
    /// happened; duplicated-response faults are returned for the caller
    /// to double-run.
    fn inject(
        &mut self,
        gi: u64,
        result: Result<JobOutput, SimError>,
    ) -> (Result<JobOutput, SimError>, bool, bool) {
        let mut result = result;
        let mut injected = false;
        let mut dup = false;
        for (fault, fired) in &mut self.faults {
            if fault.at != gi || *fired >= fault.count {
                continue;
            }
            *fired += 1;
            match fault.kind {
                FaultKind::Delay => {
                    std::thread::sleep(Duration::from_millis(fault.delay_ms));
                }
                FaultKind::Dup => dup = true,
                kind => {
                    let what = match kind {
                        FaultKind::Kill => "injected worker kill",
                        FaultKind::Corrupt => "injected response corruption",
                        FaultKind::Hydrate => "injected hydration failure",
                        _ => "injected failure",
                    };
                    // "(transient)" is RemoteKind::TRANSIENT_MARKER — the
                    // message classifies as retryable on a re-parse too.
                    result = Err(SimError::Remote {
                        msg: format!("chaos: {what} at job {gi} (transient)"),
                        kind: RemoteKind::Retryable,
                    });
                    injected = true;
                }
            }
        }
        (result, injected, dup)
    }

    /// Run `spec` once more on the inner backend, alone.
    fn rerun(&mut self, spec: &JobSpec) -> Result<JobOutput, SimError> {
        self.inner.submit(spec.clone());
        self.inner
            .run()
            .pop()
            .expect("inner executor returned one result for one job")
    }
}

impl Executor for ChaosExec {
    fn caps(&self) -> Caps {
        self.inner.caps()
    }

    fn describe(&self) -> String {
        format!("chaos({})", self.inner.describe())
    }

    fn submit(&mut self, job: JobSpec) -> usize {
        let gi = self.next_index;
        self.next_index += 1;
        self.queue.push((gi, job));
        self.queue.len() - 1
    }

    fn run(&mut self) -> Vec<Result<JobOutput, SimError>> {
        let batch = std::mem::take(&mut self.queue);
        let n = batch.len();
        let mut results: Vec<Option<Result<JobOutput, SimError>>> =
            (0..n).map(|_| None).collect();
        // Local positions still being worked on, and how many injected
        // failures each has absorbed.
        let mut pending: Vec<usize> = (0..n).collect();
        let mut attempts: Vec<u32> = vec![0; n];
        while !pending.is_empty() {
            for &p in &pending {
                self.inner.submit(batch[p].1.clone());
            }
            let ran = self.inner.run();
            let mut retry = Vec::new();
            for (&p, r) in pending.iter().zip(ran) {
                let gi = batch[p].0;
                let (r, injected, dup) = self.inject(gi, r);
                if dup {
                    // Duplicated response: run the job again and hold the
                    // copies to the purity contract duplicates rest on.
                    let copy = self.rerun(&batch[p].1);
                    let identical = match (&r, &copy) {
                        (Ok(a), Ok(b)) => a == b,
                        (Err(_), Err(_)) => true, // both failed: no logits
                        _ => false,
                    };
                    if !identical {
                        results[p] = Some(Err(SimError::Remote {
                            msg: format!(
                                "chaos: duplicated responses diverged at \
                                 job {gi} — job is not pure"
                            ),
                            kind: RemoteKind::Fatal,
                        }));
                        continue;
                    }
                }
                if injected {
                    attempts[p] += 1;
                    if attempts[p] > CHAOS_EXEC_RETRIES {
                        let msg = match &r {
                            Err(SimError::Remote { msg, .. }) => msg.clone(),
                            _ => "injected failure".to_string(),
                        };
                        results[p] = Some(Err(SimError::Remote {
                            msg: format!(
                                "retry budget exhausted after {} attempts: \
                                 {msg}",
                                attempts[p]
                            ),
                            kind: RemoteKind::Fatal,
                        }));
                    } else {
                        retry.push(p);
                    }
                } else {
                    results[p] = Some(r);
                }
            }
            if !retry.is_empty() {
                // Exponential backoff keyed on the round's deepest attempt.
                let round = retry.iter().map(|&p| attempts[p]).max().unwrap();
                std::thread::sleep(CHAOS_BACKOFF_BASE * (1 << (round - 1).min(6)));
            }
            pending = retry;
        }
        results
            .into_iter()
            .map(|r| r.expect("every job resolved"))
            .collect()
    }
}

/// Wrap `exec` with `plan` when a plan is present — the one helper every
/// CLI entry point uses, so `--chaos` / `MARVEL_CHAOS` behave identically
/// everywhere.
pub fn wrap(
    exec: Box<dyn Executor>,
    plan: Option<&FaultPlan>,
) -> Box<dyn Executor> {
    match plan {
        Some(p) if !p.is_empty() => Box::new(ChaosExec::new(exec, p)),
        _ => exec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_roundtrip() {
        let s = "worker:kill@4,worker:corrupt@2,worker:transient@6x2,\
                 worker:hydrate@1,worker:delay@3:50,worker:dup@5,\
                 transient@7,delay@0:10,dup@9x3";
        let plan = FaultPlan::parse(s).unwrap();
        assert_eq!(plan.faults.len(), 9);
        let rendered = plan.to_string();
        assert_eq!(FaultPlan::parse(&rendered).unwrap(), plan);
        assert_eq!(rendered, s.replace(" ", "").replace("\n", ""));
    }

    #[test]
    fn plan_rejects_garbage() {
        for bad in [
            "explode@3",
            "kill",
            "kill@x",
            "kill@3:50",          // only delay takes :MS
            "delay@3",            // delay needs :MS
            "transient@1x0",      // xK needs K ≥ 1
            "seed:42",            // seed needs :N
            "seed:x:3",
            "worker:kill@",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn seed_expansion_is_deterministic() {
        let a = FaultPlan::parse("seed:42:8").unwrap();
        let b = FaultPlan::parse("seed:42:8").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.faults.len(), 8);
        let c = FaultPlan::parse("seed:43:8").unwrap();
        assert_ne!(a, c, "different seeds must differ");
        // Generated faults are exec-site and never death-causing.
        for f in &a.faults {
            assert_eq!(f.site, Site::Exec);
            assert!(!matches!(f.kind, FaultKind::Kill | FaultKind::Corrupt));
        }
        // Round-trips through the expanded rendering.
        assert_eq!(FaultPlan::parse(&a.to_string()).unwrap(), a);
    }

    #[test]
    fn strip_one_shot_removes_worker_deaths_only() {
        let plan = FaultPlan::parse(
            "worker:kill@1,worker:corrupt@2,worker:transient@3,kill@4",
        )
        .unwrap();
        let stripped = plan.strip_one_shot();
        assert_eq!(
            stripped.to_string(),
            "worker:transient@3,kill@4",
            "worker kill/corrupt stripped; exec faults and worker \
             transients kept"
        );
    }

    #[test]
    fn worker_chaos_fires_at_most_count_times() {
        let plan = FaultPlan::parse("worker:transient@5x2,worker:dup@5").unwrap();
        let mut ch = WorkerChaos::new(&plan).unwrap();
        assert!(ch.actions(4).is_empty());
        let first = ch.actions(5);
        assert_eq!(first.len(), 2);
        assert!(matches!(first[0], WorkerAction::ErrorResult(_)));
        assert_eq!(first[1], WorkerAction::Dup);
        let second = ch.actions(5);
        assert_eq!(second.len(), 1, "dup exhausted, transient has one left");
        assert!(ch.actions(5).is_empty(), "both exhausted");
    }

    #[test]
    fn worker_action_order_is_canonical() {
        let plan =
            FaultPlan::parse("worker:dup@1,worker:delay@1:5,worker:kill@1")
                .unwrap();
        let mut ch = WorkerChaos::new(&plan).unwrap();
        let acts = ch.actions(1);
        assert!(matches!(acts[0], WorkerAction::Delay(_)));
        assert_eq!(acts[1], WorkerAction::Kill);
        assert_eq!(acts[2], WorkerAction::Dup);
    }
}
