//! The `marvel cluster-worker` daemon: the shard worker behind a socket.
//!
//! One daemon process serves many concurrent sweeps: every accepted
//! connection gets its own *session* thread with a private
//! [`WorkerCore`] (its own [`Hydrator`] compile cache and pooled
//! machine), so two coordinators hammering one host never contend on
//! simulator state.  What *is* process-wide is the chaos state
//! ([`SharedChaos`]): a one-shot `worker:kill@N` must fire once per
//! daemon, not once per session, or every post-kill reconnect would
//! re-inject the death and compound into a spurious poison panic.
//!
//! **Session lifecycle** — handshake (server hello first, then validate
//! the client's — see [`super::transport`]), a `ready` frame, then the
//! job/result exchange with a bounded in-flight pipeline: a reader
//! thread parses job frames into a [`SESSION_PIPELINE`]-deep channel
//! while the executor drains it, so a coordinator that pipelines is
//! never stalled on the daemon's current job, and a coordinator that
//! floods is backpressured through the channel and the socket instead of
//! buffering without bound.
//!
//! **Death semantics** — a chaos `Kill` (and any write failure) drops
//! the *connection*, not the process: the daemon survives, the
//! coordinator's reader sees EOF, and its re-dial budget decides whether
//! the host comes back.  Killing the daemon process itself is the
//! dead-host case — every re-dial fails and the pool retires the host.
//!
//! [`Hydrator`]: crate::sim::shard::Hydrator

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::transport::{check_hello, encode_hello, parse_hello, read_frame,
                       write_frame};
use crate::sim::shard::{self, encode_ready, parse_line,
                        shared_chaos_from_env, JobDesc, JobReply, Msg,
                        SharedChaos, WorkerCore, MAX_WIRE_BYTES};

/// Jobs a session's reader may queue ahead of the executor.  Deeper than
/// the coordinator-side [`crate::sim::shard::PIPELINE`] so a compliant
/// coordinator is never backpressured; shallow enough that a flooding
/// one is.
pub const SESSION_PIPELINE: usize = 8;

/// How long a freshly accepted connection gets to complete the
/// handshake before its session thread gives up (a port scanner or
/// wedged peer must not pin a thread forever).  Steady-state reads have
/// no deadline — an idle coordinator between sweeps is normal.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept loop: one session thread per connection, forever.  Errors in
/// a session (protocol garbage, handshake refusals, mid-job
/// disconnects) are logged and end that session only.
pub fn serve(artifacts: &Path, listener: TcpListener) -> Result<()> {
    let chaos = shared_chaos_from_env()?;
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let artifacts = artifacts.to_path_buf();
                let chaos = std::sync::Arc::clone(&chaos);
                std::thread::spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".into());
                    if let Err(e) = session(&artifacts, stream, chaos) {
                        eprintln!("cluster-worker: session {peer}: {e:#}");
                    }
                });
            }
            Err(e) => eprintln!("cluster-worker: accept failed: {e}"),
        }
    }
    Ok(())
}

/// One connection's worth of the worker protocol (see the module docs).
fn session(
    artifacts: &Path,
    stream: TcpStream,
    chaos: SharedChaos,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let sock = stream.try_clone().context("cloning the session socket")?;
    let mut wr = BufWriter::new(
        stream.try_clone().context("cloning the session socket")?,
    );
    let mut rd = BufReader::new(stream);
    // Handshake under a deadline; steady-state reads block indefinitely.
    sock.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    write_frame(&mut wr, &encode_hello())?;
    wr.flush()?;
    let line = read_frame(&mut rd, MAX_WIRE_BYTES)
        .context("reading the client hello")?
        .context("peer closed during handshake")?;
    let hello = parse_hello(&line).context("handshake")?;
    if let Err(e) = check_hello(&hello) {
        // Best-effort structured refusal before closing.  The seq is
        // past the JSON-safe job range, so a client that merges it
        // anyway discards it as stale instead of corrupting a slot.
        let _ = write_frame(
            &mut wr,
            &shard::encode_result(1 << 53, &Err(format!("{e:#}"))),
        );
        let _ = wr.flush();
        return Err(e);
    }
    sock.set_read_timeout(None).ok();
    write_frame(&mut wr, &encode_ready())?;
    wr.flush()?;

    // Reader thread: frames -> bounded job channel (the in-flight cap).
    let (jtx, jrx) = mpsc::sync_channel::<(u64, JobDesc)>(SESSION_PIPELINE);
    let reader = std::thread::spawn(move || -> Result<()> {
        loop {
            let Some(line) = read_frame(&mut rd, MAX_WIRE_BYTES)? else {
                return Ok(()); // client closed: session over
            };
            if line.trim().is_empty() {
                continue;
            }
            match parse_line(&line)? {
                Msg::Job { seq, desc } => {
                    if jtx.send((seq, desc)).is_err() {
                        return Ok(()); // executor side ended first
                    }
                }
                Msg::Ready => {}
                Msg::Done { .. } => {
                    bail!("unexpected result message from coordinator")
                }
            }
        }
    });

    let mut core = WorkerCore::new(artifacts, chaos);
    let mut killed = false;
    for (seq, desc) in jrx.iter() {
        match core.handle_job(seq, &desc) {
            // Chaos death = connection death: the daemon survives for
            // the coordinator's re-dial.
            JobReply::Die => {
                killed = true;
                break;
            }
            JobReply::Lines(lines) => {
                let wrote = (|| -> std::io::Result<()> {
                    for l in &lines {
                        write_frame(&mut wr, l)?;
                    }
                    wr.flush()
                })();
                if wrote.is_err() {
                    break; // client gone mid-write: close up
                }
            }
        }
    }
    // Unblock the reader (it may be parked in read_frame) and reap it.
    let _ = sock.shutdown(Shutdown::Both);
    let joined = reader.join();
    if killed {
        eprintln!("cluster-worker: chaos kill — dropped the session");
        return Ok(());
    }
    match joined {
        Ok(r) => r.context("session read"),
        Err(_) => bail!("session reader panicked"),
    }
}
