//! Cluster execution: the shard wire protocol promoted to TCP sockets
//! (DESIGN.md §18).
//!
//! [`super::shard`] scales a sweep across worker *processes* on one
//! machine; this subsystem scales it across *hosts*.  The leverage is
//! that the wire was designed reference-based from the start — a job
//! line names the model and variant, ships only the input image, and
//! carries compilation fingerprints — so nothing about the payload had
//! to change to cross a machine boundary.  What the socket adds is an
//! envelope and a lifecycle:
//!
//! - [`transport`] — length-prefixed frames with a versioned hello
//!   handshake carrying the protocol version and the cache
//!   fingerprint-scheme salt, so a mismatched peer fails loudly at
//!   connect time instead of silently mis-hydrating.
//! - [`daemon`] — the `marvel cluster-worker --listen <addr>` process:
//!   an accept loop serving many concurrent sweeps, one session thread
//!   per connection with its own hydration cache and a bounded
//!   in-flight pipeline, chaos state shared process-wide.
//! - [`pool`] — [`ClusterPool`], the shard pool's recovery model on the
//!   connection axis: generation-tagged events, re-dial budgets
//!   ([`REDIAL_ATTEMPTS`]), dead-host requeue on the poison contract,
//!   cross-host straggler re-dispatch and transient retries on the
//!   shared `JOB_RETRIES`/backoff budget.
//! - [`ClusterExec`] — the pool behind the [`Executor`] trait, selected
//!   as `--backend cluster:<addr>,…` (external daemons),
//!   `cluster:@<file>` (one address per line) or `cluster:N`
//!   ([`LoopbackCluster`]: N daemons of this very binary spawned on
//!   ephemeral loopback ports — the CI/bench form, and the zero-setup
//!   way to exercise the full socket path on one machine).
//!
//! Determinism is inherited, not re-proven: results merge by submission
//! order, jobs are pure, so a cluster run is byte-identical to
//! `local:1` for any host count, chaos schedule (within budgets) or
//! re-dispatch interleaving — `tests/exec_conformance.rs` holds that
//! differential over a real socket pair.  [`super::chaos::ChaosExec`] /
//! `MARVEL_CHAOS` compose over this backend exactly as over the others:
//! exec-site faults wrap the executor, worker-site faults ride the
//! spawned loopback daemons' environment (first daemon full plan, later
//! ones one-shot-stripped, mirroring the shard pool).

pub mod daemon;
pub mod pool;
pub mod transport;

use std::path::Path;
use std::process::{Child, Command, Stdio};

use anyhow::{anyhow, ensure, Context, Result};

use super::chaos;
use super::cpu::SimError;
use super::engine::JobOutput;
use super::exec::{Caps, Executor, JobSpec, Work};
use super::shard::{self, JobDesc, MAX_WIRE_BYTES, PIPELINE};

pub use daemon::{serve, SESSION_PIPELINE};
pub use pool::{ClusterPool, REDIAL_ATTEMPTS};
pub use transport::{encode_listening, fp_salt, parse_listening,
                    PROTO_VERSION};

/// A fleet of `marvel cluster-worker` daemons spawned as child processes
/// on ephemeral loopback ports — hosts for a [`ClusterPool`] without any
/// out-of-band setup.  Discovery is the daemon's one stdout line
/// ([`transport::encode_listening`]), so `--listen 127.0.0.1:0` works
/// and parallel test runs never race over a port.
///
/// The chaos handoff mirrors [`shard::ShardPool`]: the first daemon gets
/// the full worker-fault plan, every later one the one-shot-stripped
/// rendering, so an injected `kill@N` fires exactly once fleet-wide.
pub struct LoopbackCluster {
    children: Vec<Child>,
    addrs: Vec<String>,
}

impl LoopbackCluster {
    /// Spawn `n` daemons, handing them the worker-site faults of the
    /// coordinator's `MARVEL_CHAOS` plan (if any).
    pub fn spawn(artifacts: &Path, n: usize) -> Result<LoopbackCluster> {
        LoopbackCluster::spawn_with_plan(
            artifacts,
            n,
            chaos::FaultPlan::from_env()?.as_ref(),
        )
    }

    /// Spawn `n` daemons under an explicit fault plan (tests inject
    /// plans here without touching the process environment).
    pub fn spawn_with_plan(
        artifacts: &Path,
        n: usize,
        plan: Option<&chaos::FaultPlan>,
    ) -> Result<LoopbackCluster> {
        let exe = std::env::current_exe()
            .context("locating the marvel binary for cluster workers")?;
        LoopbackCluster::spawn_cmd(&exe, artifacts, n, plan)
    }

    /// Spawn `n` daemons of an explicit binary.  Integration tests use
    /// this with `CARGO_BIN_EXE_marvel` — their own `current_exe` is the
    /// test harness, which has no `cluster-worker` subcommand.
    pub fn spawn_cmd(
        exe: &Path,
        artifacts: &Path,
        n: usize,
        plan: Option<&chaos::FaultPlan>,
    ) -> Result<LoopbackCluster> {
        ensure!(n > 0, "loopback cluster needs at least one worker");
        let plans = plan.and_then(|p| {
            if p.worker_faults().next().is_none() {
                return None; // exec-site-only plan: daemons run clean
            }
            Some((p.to_string(), p.strip_one_shot().to_string()))
        });
        let mut lc = LoopbackCluster { children: Vec::new(), addrs: Vec::new() };
        for i in 0..n {
            let mut cmd = Command::new(exe);
            cmd.args(["cluster-worker", "--listen", "127.0.0.1:0", "--artifacts"])
                .arg(artifacts)
                .stdin(Stdio::null())
                .stdout(Stdio::piped());
            // Per-incarnation plan wins over whatever the coordinator's
            // environment says (same discipline as the shard pool).
            cmd.env_remove(chaos::MARVEL_CHAOS_ENV);
            if let Some((full, stripped)) = &plans {
                let plan = if i == 0 { full } else { stripped };
                if !plan.is_empty() {
                    cmd.env(chaos::MARVEL_CHAOS_ENV, plan);
                }
            }
            let mut child = cmd.spawn().with_context(|| {
                format!("spawning loopback cluster worker {i}")
            })?;
            let stdout = child.stdout.take().expect("piped stdout");
            let mut rd = std::io::BufReader::new(stdout);
            let line = shard::read_line_capped(&mut rd, MAX_WIRE_BYTES)
                .context("reading the daemon's listening line")?
                .ok_or_else(|| {
                    anyhow!("loopback cluster worker {i} exited before \
                             listening")
                })?;
            let addr = parse_listening(&line).with_context(|| {
                format!("loopback cluster worker {i} wrote {line:?}")
            })?;
            lc.children.push(child);
            lc.addrs.push(addr);
        }
        Ok(lc)
    }

    /// The daemons' bound addresses, in spawn order.
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// Kill one daemon process outright — the dead-*host* case (every
    /// re-dial fails and the pool retires the slot), as opposed to the
    /// chaos session kill the daemon survives.
    pub fn kill_host(&mut self, i: usize) {
        let _ = self.children[i].kill();
        let _ = self.children[i].wait();
    }
}

impl Drop for LoopbackCluster {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// The multi-host backend: a [`ClusterPool`] behind the [`Executor`]
/// trait.  Only the wire half of a [`Work::Named`] job travels (workers
/// hydrate from their own caches; fingerprints catch divergence);
/// [`Work::Raw`] jobs answer with a capability error at their index —
/// the same cross-process contract as `ShardExec`.
pub struct ClusterExec {
    pool: ClusterPool,
    hosts: usize,
    /// The backend spec string this executor answers to.
    spec: String,
    queue: Vec<JobSpec>,
    /// Owned loopback daemons (`None` when dialing external hosts);
    /// held for the executor's lifetime, killed on drop.
    loopback: Option<LoopbackCluster>,
}

impl ClusterExec {
    /// Dial externally started daemons (`cluster:<addr>,…`).
    pub fn connect(addrs: &[String]) -> Result<ClusterExec> {
        let pool = ClusterPool::connect(addrs)?;
        Ok(ClusterExec {
            hosts: addrs.len(),
            spec: format!("cluster:{}", addrs.join(",")),
            pool,
            queue: Vec::new(),
            loopback: None,
        })
    }

    /// Spawn `n` loopback daemons and dial them (`cluster:N`).
    pub fn spawn_loopback(artifacts: &Path, n: usize) -> Result<ClusterExec> {
        Self::wrap_loopback(LoopbackCluster::spawn(artifacts, n)?, n)
    }

    /// [`ClusterExec::spawn_loopback`] under an explicit fault plan.
    pub fn spawn_loopback_with_plan(
        artifacts: &Path,
        n: usize,
        plan: Option<&chaos::FaultPlan>,
    ) -> Result<ClusterExec> {
        Self::wrap_loopback(
            LoopbackCluster::spawn_with_plan(artifacts, n, plan)?,
            n,
        )
    }

    /// [`ClusterExec::spawn_loopback_with_plan`] with an explicit daemon
    /// binary (see [`LoopbackCluster::spawn_cmd`]).
    pub fn spawn_loopback_cmd(
        exe: &Path,
        artifacts: &Path,
        n: usize,
        plan: Option<&chaos::FaultPlan>,
    ) -> Result<ClusterExec> {
        Self::wrap_loopback(
            LoopbackCluster::spawn_cmd(exe, artifacts, n, plan)?,
            n,
        )
    }

    fn wrap_loopback(lb: LoopbackCluster, n: usize) -> Result<ClusterExec> {
        let pool = ClusterPool::connect(lb.addrs())?;
        Ok(ClusterExec {
            hosts: n,
            spec: format!("cluster:{n}"),
            pool,
            queue: Vec::new(),
            loopback: Some(lb),
        })
    }

    /// The wrapped pool (re-dial counters, live-host count).
    pub fn pool(&self) -> &ClusterPool {
        &self.pool
    }

    /// The owned loopback fleet, when this executor spawned one (tests
    /// kill individual daemons through it).
    pub fn loopback_mut(&mut self) -> Option<&mut LoopbackCluster> {
        self.loopback.as_mut()
    }
}

impl Executor for ClusterExec {
    fn caps(&self) -> Caps {
        Caps {
            persistent_pool: true,
            cross_process: true,
            // Each host connection keeps PIPELINE jobs in flight.
            parallelism: (self.hosts * PIPELINE).max(1),
            // Sessions run jobs scalar as they stream off the wire.
            lanes: 1,
        }
    }

    fn describe(&self) -> String {
        self.spec.clone()
    }

    fn submit(&mut self, job: JobSpec) -> usize {
        self.queue.push(job);
        self.queue.len() - 1
    }

    fn run(&mut self) -> Vec<Result<JobOutput, SimError>> {
        let specs = std::mem::take(&mut self.queue);
        // Compact the dispatchable descriptions; remember, per submitted
        // job, either its desc index or its immediate capability error.
        let mut descs: Vec<JobDesc> = Vec::with_capacity(specs.len());
        let routed: Vec<Result<usize, String>> = specs
            .into_iter()
            .map(|s| match s.work {
                Work::Named { desc, .. } => {
                    descs.push(desc);
                    Ok(descs.len() - 1)
                }
                Work::Raw(_) => Err(
                    "raw memory-image job on a cross-process backend: \
                     raw jobs cannot travel the wire (submit a named job, \
                     or run on a local backend)"
                        .to_string(),
                ),
            })
            .collect();
        let mut ran: Vec<Option<Result<JobOutput, SimError>>> =
            self.pool.run(&descs).into_iter().map(Some).collect();
        routed
            .into_iter()
            .map(|r| match r {
                Ok(i) => ran[i].take().expect("one result per dispatched job"),
                Err(msg) => Err(SimError::remote(msg)),
            })
            .collect()
    }
}
