//! Coordinator side: a pool of worker *hosts* behind TCP connections.
//!
//! [`ClusterPool`] is [`crate::sim::shard::ShardPool`]'s shape with the
//! process axis swapped for a connection axis: a worker slot is a dialed
//! host instead of a spawned child, a death is a lost connection instead
//! of a lost process, and "respawn" becomes *re-dial* — same budget
//! discipline ([`REDIAL_ATTEMPTS`] mirroring `RESPAWN_ATTEMPTS`), same
//! generation-tagged event stream so a replaced connection's late
//! messages are never charged to its successor, and the same recovery
//! contracts: requeue-on-death with [`POISON_DEATHS`] attribution,
//! cross-host straggler re-dispatch and transient-error retries drawing
//! on the shared [`JOB_RETRIES`] budget with exponential backoff, and a
//! submission-ordered merge that keeps results byte-identical to
//! [`crate::sim::shard::run_descs_local`] for any host count, partition
//! or re-dispatch schedule.
//!
//! The distinction the re-dial budget surfaces: a *session* death (chaos
//! kill, transient network drop) re-dials successfully because the
//! daemon process survived — mid-sweep reconnect; a *host* death (the
//! daemon itself is gone) fails every re-dial, the slot is retired, and
//! its jobs fall back to the surviving hosts.

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::transport::{check_hello, encode_hello, parse_hello, read_frame,
                       write_frame};
use crate::sim::cpu::{RemoteKind, SimError};
use crate::sim::engine::JobOutput;
use crate::sim::shard::{encode_job, job_timeout, parse_line, stall_timeout,
                        JobDesc, Msg, JOB_RETRIES, MAX_WIRE_BYTES, PIPELINE,
                        POISON_DEATHS};

/// How many times a lost host connection is re-dialed before its slot is
/// retired for good and its jobs fall back to surviving hosts — the
/// connection-axis mirror of `RESPAWN_ATTEMPTS`.  Death attribution
/// happens before the re-dial, so the poison contract is unchanged.
pub const REDIAL_ATTEMPTS: u32 = 2;

/// Dial + handshake deadline.  Loopback and live hosts answer in
/// microseconds; a black-holed address must not wedge a sweep.
const DIAL_TIMEOUT: Duration = Duration::from_secs(5);

/// Base of the exponential backoff between retries of a transient wire
/// error (doubles per consumed retry) — same shape as the shard pool's.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(10);

enum Event {
    Msg { host: usize, gen: u64, msg: Msg },
    Dead { host: usize, gen: u64, reason: String },
}

/// One result slot per submitted job (`None` = not yet merged).
type Slots = [Option<Result<JobOutput, SimError>>];

struct Host {
    addr: String,
    /// Write half of the live connection (`None` once lost).
    wr: Option<BufWriter<TcpStream>>,
    /// Shutdown handle for the same connection (unblocks the reader).
    sock: Option<TcpStream>,
    alive: bool,
    /// Incarnation counter for this slot: events from a replaced
    /// connection (its reader thread races the re-dial) carry the old
    /// generation and must not be charged to the new one.
    gen: u64,
    /// Job indices (current `run` call) dispatched here and not yet
    /// done, with dispatch time — the per-job timeout clock.
    outstanding: HashMap<usize, Instant>,
}

/// A pool of dialed worker hosts executing [`JobDesc`] batches with
/// submission-ordered merge (see the module docs for the failure model).
/// Connections — and the per-session hydration caches behind them — stay
/// warm across `run` calls.
pub struct ClusterPool {
    hosts: Vec<Host>,
    rx: mpsc::Receiver<Event>,
    tx: mpsc::Sender<Event>,
    next_seq: u64,
    gen_counter: u64,
    /// Remaining re-dials per host slot.
    redials_left: Vec<u32>,
    redials_used: u32,
}

impl ClusterPool {
    /// Dial every address and complete the hello handshake; any initial
    /// dial failure is a hard error (the caller asked for these hosts).
    pub fn connect(addrs: &[String]) -> Result<ClusterPool> {
        ensure!(!addrs.is_empty(), "cluster pool needs at least one host");
        let (tx, rx) = mpsc::channel();
        let mut pool = ClusterPool {
            hosts: Vec::new(),
            rx,
            tx,
            next_seq: 0,
            gen_counter: addrs.len() as u64,
            redials_left: vec![REDIAL_ATTEMPTS; addrs.len()],
            redials_used: 0,
        };
        for (i, addr) in addrs.iter().enumerate() {
            let h = pool
                .dial(addr, i, i as u64)
                .with_context(|| format!("dialing cluster host {addr}"))?;
            pool.hosts.push(h);
        }
        Ok(pool)
    }

    /// Connect + handshake one host and spawn its reader thread for
    /// incarnation `gen`.
    fn dial(&self, addr: &str, host: usize, gen: u64) -> Result<Host> {
        let sa: SocketAddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("{addr} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&sa, DIAL_TIMEOUT)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        // Handshake under a deadline; steady-state reads (on the reader
        // thread below) block until the connection dies.
        stream.set_read_timeout(Some(DIAL_TIMEOUT)).ok();
        let mut rd = BufReader::new(
            stream.try_clone().context("cloning the host socket")?,
        );
        let mut wr = BufWriter::new(
            stream.try_clone().context("cloning the host socket")?,
        );
        let line = read_frame(&mut rd, MAX_WIRE_BYTES)
            .with_context(|| format!("reading the hello from {addr}"))?
            .ok_or_else(|| anyhow!("{addr} closed during handshake"))?;
        let hello =
            parse_hello(&line).with_context(|| format!("handshake with {addr}"))?;
        check_hello(&hello).with_context(|| format!("handshake with {addr}"))?;
        write_frame(&mut wr, &encode_hello())?;
        wr.flush()?;
        stream.set_read_timeout(None).ok();
        let tx = self.tx.clone();
        std::thread::spawn(move || loop {
            let event = match read_frame(&mut rd, MAX_WIRE_BYTES) {
                Ok(None) => {
                    let _ = tx.send(Event::Dead {
                        host,
                        gen,
                        reason: "connection closed".into(),
                    });
                    return;
                }
                Ok(Some(l)) if l.trim().is_empty() => continue,
                Ok(Some(l)) => match parse_line(&l) {
                    Ok(msg) => Event::Msg { host, gen, msg },
                    Err(e) => {
                        let _ = tx.send(Event::Dead {
                            host,
                            gen,
                            reason: format!("protocol error: {e:#}"),
                        });
                        return;
                    }
                },
                Err(e) => {
                    let _ = tx.send(Event::Dead {
                        host,
                        gen,
                        reason: format!("read error: {e}"),
                    });
                    return;
                }
            };
            if tx.send(event).is_err() {
                return;
            }
        });
        Ok(Host {
            addr: addr.to_string(),
            wr: Some(wr),
            sock: Some(stream),
            alive: true,
            gen,
            outstanding: HashMap::new(),
        })
    }

    /// Re-dial a lost host slot, consuming one unit of its
    /// [`REDIAL_ATTEMPTS`] budget per attempt.  Success means the daemon
    /// process is still there (session death — fresh connection, fresh
    /// generation, immediately dispatchable); exhausting the budget
    /// against a dead daemon retires the slot.
    fn try_redial(&mut self, host: usize) {
        while self.redials_left[host] > 0 {
            self.redials_left[host] -= 1;
            self.gen_counter += 1;
            let gen = self.gen_counter;
            let addr = self.hosts[host].addr.clone();
            match self.dial(&addr, host, gen) {
                Ok(h) => {
                    self.redials_used += 1;
                    eprintln!(
                        "cluster host {host} ({addr}) re-dialed ({} attempts \
                         left)",
                        self.redials_left[host]
                    );
                    self.hosts[host] = h;
                    return;
                }
                Err(e) => eprintln!(
                    "cluster host {host} ({addr}) re-dial failed ({} \
                     attempts left): {e:#}",
                    self.redials_left[host]
                ),
            }
        }
    }

    /// Live (connected) host count.
    pub fn live_hosts(&self) -> usize {
        self.hosts.iter().filter(|h| h.alive).count()
    }

    /// Host slot count, live or not.
    pub fn hosts(&self) -> usize {
        self.hosts.len()
    }

    /// How many lost connections have been successfully re-dialed over
    /// the pool's lifetime (observability + the reconnect tests).
    pub fn redials_used(&self) -> u32 {
        self.redials_used
    }

    /// Execute a batch across the hosts.  `results[i]` corresponds to
    /// `descs[i]`, byte-identical to `run_descs_local` for any host
    /// count or re-dispatch schedule.  Panics if a poison job kills
    /// [`POISON_DEATHS`] connections or every host dies — the same
    /// contract as the shard pool, one transport up.
    pub fn run(&mut self, descs: &[JobDesc]) -> Vec<Result<JobOutput, SimError>> {
        let n = descs.len();
        let base = self.next_seq;
        self.next_seq += n as u64;
        let stall = stall_timeout(descs);
        let per_job = job_timeout(descs);
        // Stale outstanding entries are duplicates from a previous batch
        // whose first copy already won; their late results are discarded
        // by the seq-range guard below.
        for h in &mut self.hosts {
            h.outstanding.clear();
        }
        let mut results: Vec<Option<Result<JobOutput, SimError>>> =
            (0..n).map(|_| None).collect();
        let mut done = 0usize;
        // Pre-send wire cap, same as the shard pool: an unsendable job
        // fails at its own index instead of reading as host corruption.
        for (i, d) in descs.iter().enumerate() {
            let wire = encode_job(base + i as u64, d).len();
            if wire > MAX_WIRE_BYTES {
                results[i] = Some(Err(SimError::Remote {
                    msg: format!(
                        "oversized job frame ({wire} bytes exceeds the \
                         {MAX_WIRE_BYTES}-byte wire cap)"
                    ),
                    kind: RemoteKind::Fatal,
                }));
                done += 1;
            }
        }
        let mut queue: VecDeque<usize> =
            (0..n).filter(|&i| results[i].is_none()).collect();
        let mut dispatched: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut deaths: Vec<u32> = vec![0; n];
        let mut retries: Vec<u32> = vec![0; n];
        let mut backoff: Vec<Option<Instant>> = vec![None; n];
        let mut last_event = Instant::now();

        while done < n {
            self.dispatch(
                descs, base, &results, &mut queue, &mut dispatched,
                &mut deaths, &mut retries, &backoff,
            );
            if self.live_hosts() == 0 {
                panic!(
                    "cluster pool: all hosts died with {} of {n} jobs \
                     unfinished",
                    n - done
                );
            }
            let now = Instant::now();
            let mut wait = (last_event + stall).saturating_duration_since(now);
            for b in backoff.iter().flatten() {
                wait = wait.min(b.saturating_duration_since(now));
            }
            for h in self.hosts.iter().filter(|h| h.alive) {
                for t0 in h.outstanding.values() {
                    wait = wait
                        .min((*t0 + per_job).saturating_duration_since(now));
                }
            }
            let event = match self
                .rx
                .recv_timeout(wait.max(Duration::from_millis(1)))
            {
                Ok(e) => {
                    last_event = Instant::now();
                    e
                }
                Err(_) => {
                    if last_event.elapsed() >= stall {
                        panic!(
                            "cluster pool stalled: no host event within \
                             {stall:?} ({} of {n} jobs unfinished)",
                            n - done
                        );
                    }
                    // Per-job timeouts: duplicate over-deadline work onto
                    // another host, budget allowing; first result wins.
                    let now = Instant::now();
                    for h in self.hosts.iter_mut().filter(|h| h.alive) {
                        for (&i, t0) in h.outstanding.iter_mut() {
                            if now.saturating_duration_since(*t0) < per_job
                                || results[i].is_some()
                                || retries[i] >= JOB_RETRIES
                                || queue.contains(&i)
                            {
                                continue;
                            }
                            retries[i] += 1;
                            *t0 = now;
                            queue.push_back(i);
                            eprintln!(
                                "cluster job {i} timed out after \
                                 {per_job:?}; re-dispatching ({} of \
                                 {JOB_RETRIES} budget used)",
                                retries[i]
                            );
                        }
                    }
                    continue;
                }
            };
            match event {
                Event::Msg { msg: Msg::Ready, .. } => {}
                Event::Msg { host, gen, msg: Msg::Done { seq, result } } => {
                    let Some(i) = seq.checked_sub(base).map(|d| d as usize)
                    else {
                        continue; // stale: previous run
                    };
                    if i >= n {
                        continue;
                    }
                    // Results merge whatever their generation (jobs are
                    // pure); only the current incarnation's pipeline
                    // bookkeeping may be touched.
                    if gen == self.hosts[host].gen {
                        self.hosts[host].outstanding.remove(&i);
                    }
                    if results[i].is_some() {
                        continue; // a duplicate's first copy already won
                    }
                    match result {
                        Ok(o) => {
                            results[i] = Some(Ok(o));
                            done += 1;
                        }
                        Err(msg) => {
                            let kind = RemoteKind::classify(&msg);
                            if kind == RemoteKind::Retryable
                                && retries[i] < JOB_RETRIES
                            {
                                retries[i] += 1;
                                backoff[i] = Some(
                                    Instant::now()
                                        + RETRY_BACKOFF_BASE
                                            * (1 << (retries[i] - 1).min(6)),
                                );
                                if !queue.contains(&i) {
                                    queue.push_back(i);
                                }
                                eprintln!(
                                    "cluster job {i} transient failure \
                                     (retry {} of {JOB_RETRIES}): {msg}",
                                    retries[i]
                                );
                            } else {
                                let err = if kind == RemoteKind::Retryable {
                                    SimError::Remote {
                                        msg: format!(
                                            "retry budget exhausted after \
                                             {} attempts: {msg}",
                                            retries[i] + 1
                                        ),
                                        kind: RemoteKind::Fatal,
                                    }
                                } else {
                                    SimError::Remote { msg, kind }
                                };
                                results[i] = Some(Err(err));
                                done += 1;
                            }
                        }
                    }
                }
                Event::Msg { host, gen, msg: Msg::Job { .. } } => {
                    if gen != self.hosts[host].gen {
                        continue; // a replaced connection's last gasp
                    }
                    // A host must never send jobs; treat as corruption.
                    self.kill_host(host, "sent a job message");
                    Self::requeue(
                        &mut self.hosts[host],
                        &results,
                        &mut queue,
                        &mut deaths,
                        descs,
                    );
                    self.try_redial(host);
                }
                Event::Dead { host, gen, reason } => {
                    if gen != self.hosts[host].gen || !self.hosts[host].alive
                    {
                        continue; // already handled (or replaced)
                    }
                    self.kill_host(host, &reason);
                    Self::requeue(
                        &mut self.hosts[host],
                        &results,
                        &mut queue,
                        &mut deaths,
                        descs,
                    );
                    self.try_redial(host);
                }
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("merge filled every slot"))
            .collect()
    }

    /// Send queued jobs to live hosts with pipeline capacity; with an
    /// empty queue, duplicate outstanding jobs onto idle hosts (the
    /// cross-host straggler re-dispatch, charged to [`JOB_RETRIES`]).
    #[allow(clippy::too_many_arguments)] // one call site; the run-loop state
    fn dispatch(
        &mut self,
        descs: &[JobDesc],
        base: u64,
        results: &Slots,
        queue: &mut VecDeque<usize>,
        dispatched: &mut [Vec<usize>],
        deaths: &mut [u32],
        retries: &mut [u32],
        backoff: &[Option<Instant>],
    ) {
        let now = Instant::now();
        loop {
            let Some(h) = self
                .hosts
                .iter()
                .enumerate()
                .filter(|(_, hk)| hk.alive && hk.outstanding.len() < PIPELINE)
                .min_by_key(|(_, hk)| hk.outstanding.len())
                .map(|(i, _)| i)
            else {
                return;
            };
            while queue.front().is_some_and(|&i| results[i].is_some()) {
                queue.pop_front();
            }
            let eligible = queue
                .iter()
                .position(|&i| {
                    results[i].is_none()
                        && backoff[i].is_none_or(|b| b <= now)
                })
                .and_then(|p| queue.remove(p));
            let i = match eligible {
                Some(i) => i,
                None => {
                    if !queue.is_empty() {
                        return; // everything queued is backing off
                    }
                    // Straggler re-dispatch: only fully idle hosts, onto
                    // the least-duplicated job this host has not seen.
                    if !self.hosts[h].outstanding.is_empty() {
                        return;
                    }
                    let Some(i) = (0..descs.len())
                        .filter(|&i| {
                            results[i].is_none()
                                && !dispatched[i].contains(&h)
                                && retries[i] < JOB_RETRIES
                        })
                        .min_by_key(|&i| dispatched[i].len())
                    else {
                        return;
                    };
                    retries[i] += 1; // the duplicate consumes retry budget
                    i
                }
            };
            // Prefer a host that has not seen this job; fall back to the
            // least-loaded (a one-host pool must still retry somewhere).
            let h = if dispatched[i].contains(&h) {
                self.hosts
                    .iter()
                    .enumerate()
                    .filter(|(hi, hk)| {
                        hk.alive
                            && hk.outstanding.len() < PIPELINE
                            && !dispatched[i].contains(hi)
                    })
                    .min_by_key(|(_, hk)| hk.outstanding.len())
                    .map_or(h, |(hi, _)| hi)
            } else {
                h
            };
            let line = encode_job(base + i as u64, &descs[i]);
            let ok = match self.hosts[h].wr.as_mut() {
                Some(wr) => write_frame(wr, &line)
                    .and_then(|()| wr.flush())
                    .is_ok(),
                None => false,
            };
            if ok {
                self.hosts[h].outstanding.insert(i, Instant::now());
                dispatched[i].push(h);
            } else {
                // Broken connection: handle the death here in full (the
                // reader's Dead event carries the replaced generation and
                // is ignored) so its jobs requeue exactly once.
                queue.push_front(i);
                self.kill_host(h, "send failed");
                Self::requeue(
                    &mut self.hosts[h], results, queue, deaths, descs,
                );
                self.try_redial(h);
            }
        }
    }

    fn kill_host(&mut self, host: usize, reason: &str) {
        let h = &mut self.hosts[host];
        h.alive = false;
        h.wr = None;
        if let Some(sock) = h.sock.take() {
            let _ = sock.shutdown(Shutdown::Both);
        }
        eprintln!("cluster host {host} ({}) lost: {reason}", h.addr);
    }

    /// Put a lost host's unfinished jobs back on the queue, attributing
    /// the death to each; a job implicated in [`POISON_DEATHS`] deaths
    /// is propagated as a panic.
    fn requeue(
        host: &mut Host,
        results: &Slots,
        queue: &mut VecDeque<usize>,
        deaths: &mut [u32],
        descs: &[JobDesc],
    ) {
        for (i, _dispatched_at) in std::mem::take(&mut host.outstanding) {
            if results[i].is_some() {
                continue;
            }
            deaths[i] += 1;
            if deaths[i] >= POISON_DEATHS {
                panic!(
                    "cluster job {i} ({} on {}) killed {} host connections \
                     — poison job propagated (in-process contract: a \
                     panicking job panics the batch)",
                    descs[i].model, descs[i].variant, deaths[i]
                );
            }
            if !queue.contains(&i) {
                queue.push_front(i);
            }
        }
    }
}

impl Drop for ClusterPool {
    fn drop(&mut self) {
        for h in &mut self.hosts {
            h.wr = None; // flush + close the write half
            if let Some(sock) = h.sock.take() {
                let _ = sock.shutdown(Shutdown::Both);
            }
        }
    }
}
