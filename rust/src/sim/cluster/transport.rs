//! Framed socket transport: length-prefixed line-JSON plus a versioned
//! hello handshake.
//!
//! The shard wire protocol ([`crate::sim::shard`]) frames messages with
//! `\n` because stdio pipes are byte streams owned end to end by the
//! coordinator.  A TCP socket adds two problems newline framing leaves
//! open: *what* is on the other end (anything can connect to a listening
//! port — an old binary, a different tool, a port scanner), and how to
//! bound a frame before trusting the peer.  This module answers both:
//!
//! - **Framing**: every message travels as a 4-byte big-endian length
//!   prefix followed by exactly that many bytes of UTF-8 line-JSON (no
//!   trailing newline).  The length is validated against the shared
//!   [`MAX_WIRE_BYTES`] cap *before* any allocation, so a garbage or
//!   hostile prefix costs four bytes of reading, not gigabytes of buffer.
//! - **Handshake**: the first frame in each direction is a `hello`
//!   carrying the protocol version and the *fingerprint-scheme salt* —
//!   a hash over the scheme identity that both sides derive locally
//!   ([`fp_salt`]).  The wire ships fingerprints instead of program
//!   bytes, so two peers hashing differently would pass every frame and
//!   still disagree about every job; the salt turns that silent hazard
//!   into a loud connect-time error.  The server (daemon) speaks first.
//!
//! Frame payloads after the handshake are the unchanged shard wire lines
//! ([`crate::sim::shard::encode_job`] and friends) — the cluster layer
//! changes the envelope, never the letter.

use std::io::{Read, Write};

use anyhow::{anyhow, ensure, Result};

use crate::sim::shard::MAX_WIRE_BYTES;
use crate::util::json::{self, ObjBuilder};

/// Cluster wire protocol version; bumped on any framing or message-shape
/// change.  A peer speaking a different version is refused at handshake.
pub const PROTO_VERSION: u64 = 1;

/// The fingerprint-scheme salt: identifies *how* this binary computes the
/// program/base-DM fingerprints job descriptions carry (FNV-1a over the
/// encodings fixed by [`crate::util::fnv1a`] and `Program::fingerprint`).
/// Both ends derive it locally and compare at handshake — equal salts
/// mean a fingerprint match is meaningful, not a coincidence of hashes.
pub fn fp_salt() -> u64 {
    crate::util::fnv1a(b"marvel-fp/fnv1a-v1")
}

/// Write one frame: 4-byte big-endian length + payload bytes.  Payloads
/// past [`MAX_WIRE_BYTES`] are refused locally (`InvalidData`) — the cap
/// is symmetric, so a frame we would not accept is never sent.  The
/// caller flushes.
pub fn write_frame(w: &mut impl Write, payload: &str) -> std::io::Result<()> {
    use std::io::{Error, ErrorKind};
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_WIRE_BYTES {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!(
                "oversized frame: {} bytes exceeds the {MAX_WIRE_BYTES}-byte \
                 wire cap",
                bytes.len()
            ),
        ));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)
}

/// Read one frame under a byte cap: `Ok(None)` on clean EOF (no header
/// byte), `Ok(Some(payload))` on success, and an error on a truncated
/// header/payload, an over-cap length prefix, or non-UTF-8 bytes.  The
/// caller treats any error as peer corruption (a death) — the oversized
/// message deliberately matches the pipe transport's so it classifies as
/// [`crate::sim::cpu::RemoteKind::Fatal`] either way.
pub fn read_frame(
    r: &mut impl Read,
    cap: usize,
) -> std::io::Result<Option<String>> {
    use std::io::{Error, ErrorKind};
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < hdr.len() {
        match r.read(&mut hdr[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(Error::new(
                    ErrorKind::UnexpectedEof,
                    "peer closed mid-frame header",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_be_bytes(hdr) as usize;
    if len > cap {
        return Err(Error::new(
            ErrorKind::InvalidData,
            format!(
                "oversized frame: {len}-byte length prefix exceeds the \
                 {cap}-byte wire cap"
            ),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    match String::from_utf8(buf) {
        Ok(s) => Ok(Some(s)),
        Err(e) => Err(Error::new(
            ErrorKind::InvalidData,
            format!("non-UTF-8 frame: {e}"),
        )),
    }
}

/// A parsed hello frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    pub proto: u64,
    /// The peer's crate version — diagnostic only, never gated on (two
    /// builds with matching proto + salt interoperate by construction).
    pub version: String,
    pub salt: u64,
}

/// Serialize this binary's hello frame.
pub fn encode_hello() -> String {
    json::to_compact_string(
        &ObjBuilder::new()
            .set("type", "hello")
            .set("proto", PROTO_VERSION)
            .set("version", crate::version())
            .set("salt", format!("{:016x}", fp_salt()))
            .build(),
    )
}

/// Parse a hello frame (the strictness is the point: anything that is
/// not a well-formed hello means the peer is not a marvel cluster
/// endpoint, and the connection is refused before any job state exists).
pub fn parse_hello(line: &str) -> Result<Hello> {
    let v = json::parse(line)?;
    let ty = v.get("type")?.as_str()?;
    ensure!(ty == "hello", "expected a hello frame, got type {ty:?}");
    let salt_s = v.get("salt")?.as_str()?;
    let salt = u64::from_str_radix(salt_s, 16)
        .map_err(|e| anyhow!("bad hello salt {salt_s:?}: {e}"))?;
    Ok(Hello {
        proto: v.get("proto")?.as_u64()?,
        version: v.get("version")?.as_str()?.to_string(),
        salt,
    })
}

/// Validate a peer's hello against this binary's protocol version and
/// fingerprint salt.
pub fn check_hello(h: &Hello) -> Result<()> {
    ensure!(
        h.proto == PROTO_VERSION,
        "cluster protocol version mismatch: peer speaks v{} (marvel {}), \
         this side speaks v{PROTO_VERSION} (marvel {})",
        h.proto,
        h.version,
        crate::version()
    );
    ensure!(
        h.salt == fp_salt(),
        "fingerprint-scheme mismatch: peer salt {:016x} (marvel {}), ours \
         {:016x} — hydration cross-checks would be meaningless",
        h.salt,
        h.version,
        fp_salt()
    );
    Ok(())
}

/// Serialize the daemon's one-line stdout discovery message (emitted
/// after binding, so `--listen 127.0.0.1:0` is usable: the kernel picks
/// the port and the spawner reads the actual address here).
pub fn encode_listening(addr: &str) -> String {
    json::to_compact_string(
        &ObjBuilder::new()
            .set("type", "listening")
            .set("addr", addr)
            .build(),
    )
}

/// Parse a daemon's discovery line back to its address.
pub fn parse_listening(line: &str) -> Result<String> {
    let v = json::parse(line)?;
    let ty = v.get("type")?.as_str()?;
    ensure!(ty == "listening", "expected a listening line, got type {ty:?}");
    Ok(v.get("addr")?.as_str()?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, "hello").unwrap();
        write_frame(&mut buf, "").unwrap();
        write_frame(&mut buf, "wörld").unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r, MAX_WIRE_BYTES).unwrap().as_deref(),
            Some("hello")
        );
        assert_eq!(
            read_frame(&mut r, MAX_WIRE_BYTES).unwrap().as_deref(),
            Some("")
        );
        assert_eq!(
            read_frame(&mut r, MAX_WIRE_BYTES).unwrap().as_deref(),
            Some("wörld")
        );
        // clean EOF after the last frame
        assert_eq!(read_frame(&mut r, MAX_WIRE_BYTES).unwrap(), None);
    }

    #[test]
    fn frame_rejects_oversize_both_directions() {
        use crate::sim::cpu::RemoteKind;
        // send side: never write what the peer would refuse
        let mut buf: Vec<u8> = Vec::new();
        let big = "x".repeat(MAX_WIRE_BYTES + 1);
        let err = write_frame(&mut buf, &big).unwrap_err();
        assert!(err.to_string().contains("oversized frame"), "{err}");
        assert!(buf.is_empty(), "nothing may hit the wire");
        // receive side: a hostile length prefix fails before allocation
        let mut wire: Vec<u8> = Vec::new();
        wire.extend_from_slice(&u32::MAX.to_be_bytes());
        wire.extend_from_slice(b"whatever");
        let err = read_frame(&mut &wire[..], 64).unwrap_err();
        assert!(err.to_string().contains("oversized frame"), "{err}");
        // both transports' violations classify as fatal, never retried
        assert_eq!(RemoteKind::classify(&err.to_string()), RemoteKind::Fatal);
    }

    #[test]
    fn frame_rejects_truncation_and_garbage() {
        // header cut short
        let err = read_frame(&mut &[0u8, 0][..], 64).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        // payload cut short
        let mut wire: Vec<u8> = Vec::new();
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        assert!(read_frame(&mut &wire[..], 64).is_err());
        // non-UTF-8 payload
        let mut wire: Vec<u8> = Vec::new();
        wire.extend_from_slice(&2u32.to_be_bytes());
        wire.extend_from_slice(&[0xff, 0xfe]);
        let err = read_frame(&mut &wire[..], 64).unwrap_err();
        assert!(err.to_string().contains("non-UTF-8"), "{err}");
    }

    #[test]
    fn hello_roundtrip_and_checks() {
        let h = parse_hello(&encode_hello()).unwrap();
        assert_eq!(h.proto, PROTO_VERSION);
        assert_eq!(h.version, crate::version());
        assert_eq!(h.salt, fp_salt());
        check_hello(&h).unwrap();
        // a future protocol is refused with both versions in the message
        let e = check_hello(&Hello { proto: PROTO_VERSION + 1, ..h.clone() })
            .unwrap_err();
        assert!(e.to_string().contains("protocol version mismatch"), "{e}");
        // a divergent fingerprint scheme is refused at connect time
        let e = check_hello(&Hello { salt: h.salt ^ 1, ..h }).unwrap_err();
        assert!(e.to_string().contains("fingerprint-scheme"), "{e}");
        // non-hello frames never pass for a handshake
        assert!(parse_hello(&crate::sim::shard::encode_ready()).is_err());
        assert!(parse_hello("not json").is_err());
    }

    #[test]
    fn listening_line_roundtrip() {
        let line = encode_listening("127.0.0.1:39751");
        assert_eq!(parse_listening(&line).unwrap(), "127.0.0.1:39751");
        assert!(parse_listening(&encode_hello()).is_err());
    }
}
