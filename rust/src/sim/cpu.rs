//! The execution core: a lightweight [`Machine`] interpreting a shared,
//! immutable [`Program`] with cycle accounting.
//!
//! The program is decoded once into a dense `Vec<Instr>` inside
//! [`Program`] and shared via `Arc`.  [`Machine::run`] executes the
//! *lowered* micro-op form ([`super::lowered`], DESIGN.md §11) — baked
//! cycle costs, branch targets resolved to instruction indices, no
//! per-instruction pc validation — and falls back to the original
//! decode-enum loop, kept as [`Machine::run_reference`], whenever a
//! program/cycle-model cannot be lowered.  The reference loop is also the
//! oracle the differential tests compare against
//! (`rust/tests/lowered_diff.rs`).  This is the §Perf hot path (target
//! ≥100 M instr/s, see `benches/bench_iss.rs`).  Variant gating (illegal
//! custom instructions on smaller cores) is checked when the `Program` is
//! built so neither loop pays for it, and [`Machine`] carries only mutable
//! architectural state: registers, pc, the ZOL registers and the data
//! memory.

use std::sync::Arc;

use super::hooks::RetireHook;
use super::memory::{MemFault, Memory};
use super::program::Program;
use super::{CycleModel, Variant};
use crate::isa::decode::DecodeError;
use crate::isa::{AluImmOp, AluOp, BranchOp, Instr, LoadOp, StoreOp,
                 MAC_RD, MAC_RS1, MAC_RS2};

/// Simulator fault.
#[derive(Debug)]
pub enum SimError {
    /// Word failed to decode at load time.
    Decode { index: usize, err: DecodeError },
    /// Instruction not supported by the selected variant (load-time check).
    Unsupported { index: usize, instr: Instr, variant: &'static str },
    /// PC left the program.
    PcOutOfRange { pc: u32 },
    /// Data memory fault.
    Mem { pc: u32, fault: MemFault },
    /// Watchdog: instruction budget exhausted without `ecall`.
    Watchdog { max_instrs: u64 },
    /// `ebreak` retired (debugger breakpoint).
    Break { pc: u32 },
    /// A failure reported by a shard worker over the wire
    /// ([`crate::sim::shard`]): the original error arrives as its rendered
    /// message, so it stays a `SimError` for the coordinator-side plumbing
    /// (`PreparedFlow::finish`) without the wire having to encode every
    /// variant structurally.  `kind` classifies the failure for the retry
    /// machinery: [`RemoteKind::Retryable`] failures may be re-dispatched
    /// within the pool's retry budget, [`RemoteKind::Fatal`] failures
    /// surface immediately at the job's submission index.
    Remote { msg: String, kind: RemoteKind },
}

/// Classification of a [`SimError::Remote`] failure (DESIGN.md §16).
///
/// The shard wire carries errors as rendered strings, so the
/// classification rides *in* the message: any message containing the
/// [`RemoteKind::TRANSIENT_MARKER`] substring is [`RemoteKind::Retryable`];
/// everything else — deterministic simulator faults (watchdog, memory
/// fault, decode), fingerprint mismatches, protocol violations — is
/// [`RemoteKind::Fatal`].  Deterministic faults would reproduce on every
/// retry, so retrying them only burns budget and delays the caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemoteKind {
    /// Deterministic: every retry reproduces it.  Surface immediately.
    Fatal,
    /// Environmental (I/O hiccup, injected chaos, transient hydration
    /// failure): a retry on a fresh dispatch may succeed.
    Retryable,
}

impl RemoteKind {
    /// Substring that marks a wire error message as retryable.  Producers
    /// (worker-side transient failures, chaos injection) embed it;
    /// [`RemoteKind::classify`] keys on it.
    pub const TRANSIENT_MARKER: &'static str = "transient";

    /// Classify a wire error message: retryable iff it carries the
    /// [`Self::TRANSIENT_MARKER`] substring.
    pub fn classify(msg: &str) -> RemoteKind {
        if msg.contains(Self::TRANSIENT_MARKER) {
            RemoteKind::Retryable
        } else {
            RemoteKind::Fatal
        }
    }
}

impl SimError {
    /// Build a [`SimError::Remote`] with its kind derived from the message
    /// via [`RemoteKind::classify`] — the one constructor every wire-error
    /// site uses, so classification can never drift between call sites.
    pub fn remote(msg: String) -> SimError {
        let kind = RemoteKind::classify(&msg);
        SimError::Remote { msg, kind }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Decode { index, err } => {
                write!(f, "decode error at word {index}: {err}")
            }
            SimError::Unsupported { index, instr, variant } => write!(
                f,
                "instruction {instr} at word {index} not supported by {variant}"
            ),
            SimError::PcOutOfRange { pc } => write!(f, "pc out of range: {pc:#x}"),
            SimError::Mem { pc, fault } => write!(
                f,
                "memory fault at pc {pc:#x}: addr {:#x} size {} {}",
                fault.addr,
                fault.size,
                if fault.write { "write" } else { "read" }
            ),
            SimError::Watchdog { max_instrs } => {
                write!(f, "watchdog: exceeded {max_instrs} instructions")
            }
            SimError::Break { pc } => write!(f, "ebreak at pc {pc:#x}"),
            SimError::Remote { msg, .. } => write!(f, "shard worker: {msg}"),
        }
    }
}

impl std::error::Error for SimError {}

/// Result of a completed run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunStats {
    pub instrs: u64,
    pub cycles: u64,
}

/// Mutable machine state executing a shared [`Program`]: registers, pc,
/// ZOL registers and data memory.  Cheap to construct per run — the
/// instruction stream is never copied.
pub struct Machine {
    pub cycle_model: CycleModel,
    /// Fuse hot straight-line micro-op runs into superinstructions when
    /// lowering (DESIGN.md §19).  Bit-identity is guaranteed either way;
    /// this only selects which lowered image [`Self::run`] executes.
    /// Defaults from `MARVEL_SUPEROPS` via
    /// [`super::engine::default_superops`].
    pub superops: bool,
    program: Arc<Program>,
    pub regs: [i32; 32],
    pub pc: u32,
    // zero-overhead loop registers (v4)
    pub zc: u32,
    pub zs: u32,
    pub ze: u32,
    pub mem: Memory,
}

/// Historical name for [`Machine`] (pre program/state split).
pub type Sim = Machine;

impl Machine {
    /// Attach fresh architectural state to an already-validated program.
    pub fn new(program: Arc<Program>, dm_size: usize) -> Machine {
        Machine {
            cycle_model: CycleModel::default(),
            superops: super::engine::default_superops(),
            program,
            regs: [0; 32],
            pc: 0,
            zc: 0,
            zs: 0,
            ze: 0,
            mem: Memory::new(dm_size),
        }
    }

    /// Build a simulator for `variant` from raw program words.
    ///
    /// Decodes and validates every word up front via [`Program::decode`];
    /// custom instructions not supported by the variant are a load-time
    /// error (the hardware would trap on first execution — failing early is
    /// strictly more useful for a compiler-driven flow and keeps the hot
    /// loop check-free).
    pub fn load(
        variant: Variant,
        words: &[u32],
        dm_size: usize,
    ) -> Result<Self, SimError> {
        Ok(Machine::new(Arc::new(Program::decode(variant, words)?), dm_size))
    }

    /// Build from already-decoded instructions (used by the compiler's
    /// in-process pipeline and tests).
    pub fn from_instrs(
        variant: Variant,
        instrs: Vec<Instr>,
        dm_size: usize,
    ) -> Result<Self, SimError> {
        Ok(Machine::new(
            Arc::new(Program::from_instrs(variant, instrs)?),
            dm_size,
        ))
    }

    /// The shared program this machine executes.
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// The variant the program was validated against.
    pub fn variant(&self) -> Variant {
        self.program.variant()
    }

    /// Reset architectural state (keeps program + memory contents).
    pub fn reset_cpu(&mut self) {
        self.regs = [0; 32];
        self.pc = 0;
        self.zc = 0;
        self.zs = 0;
        self.ze = 0;
    }

    /// Rebind to a (possibly different) program, resetting CPU state and
    /// the cycle model; data memory is left untouched — callers re-init it
    /// via [`super::Memory::reset`] / [`super::Memory::reset_from`].  The
    /// batch engine's pooled workers use this to reuse one machine's
    /// allocations across jobs (DESIGN.md §3).
    pub fn rebind(&mut self, program: Arc<Program>) {
        self.program = program;
        self.cycle_model = CycleModel::default();
        self.superops = super::engine::default_superops();
        self.reset_cpu();
    }

    /// Recycle into exactly the state `Machine::new(program, dm_size)`
    /// would produce, reusing the DM allocation instead of reallocating.
    pub fn recycle(&mut self, program: Arc<Program>, dm_size: usize) {
        self.rebind(program);
        self.mem.reset(dm_size);
    }

    pub fn program_len(&self) -> usize {
        self.program.len()
    }

    pub fn instr_at(&self, idx: usize) -> Option<&Instr> {
        self.program.instrs().get(idx)
    }

    /// Architectural register write: x0 is hardwired to zero.  Shared by
    /// the reference and lowered interpreters so the invariant lives once.
    #[inline(always)]
    pub(crate) fn write_reg(regs: &mut [i32; 32], rd: u8, v: i32) {
        regs[rd as usize] = v;
        regs[0] = 0;
    }

    /// Run until `ecall`, a fault, or the watchdog. Generic over the retire
    /// hook; pass [`super::NopHook`] for full speed.
    ///
    /// Dispatches over the lowered micro-op form (cached on the shared
    /// [`Program`], DESIGN.md §11); behaviour is bit-identical to
    /// [`Self::run_reference`], which serves as fallback whenever the
    /// program/cycle-model — or an entry state with a manually-armed `ze`
    /// the static lowering does not cover — cannot take the fast path.
    pub fn run<H: RetireHook>(
        &mut self,
        max_instrs: u64,
        hook: &mut H,
    ) -> Result<RunStats, SimError> {
        let program = Arc::clone(&self.program);
        let opts = super::lowered::LowerOpts {
            superops: self.superops,
            profile: None,
        };
        if let Some(lp) = program.lowered_with(&self.cycle_model, &opts) {
            if lp.covers_entry(self.ze) {
                return super::lowered::run_lowered(
                    self,
                    &lp,
                    program.instrs(),
                    max_instrs,
                    hook,
                );
            }
        }
        self.run_reference(max_instrs, hook)
    }

    /// Like [`Self::run`] but forcing the lowered loop's *match* dispatch
    /// — the pre-threaded central `match op.kind` form, kept as a second
    /// differential oracle and the `dispatch:match` bench baseline
    /// (DESIGN.md §15).  Falls back to the reference interpreter exactly
    /// like [`Self::run`].
    pub fn run_match<H: RetireHook>(
        &mut self,
        max_instrs: u64,
        hook: &mut H,
    ) -> Result<RunStats, SimError> {
        let program = Arc::clone(&self.program);
        let opts = super::lowered::LowerOpts {
            superops: self.superops,
            profile: None,
        };
        if let Some(lp) = program.lowered_with(&self.cycle_model, &opts) {
            if lp.covers_entry(self.ze) {
                return super::lowered::run_lowered_match(
                    self,
                    &lp,
                    program.instrs(),
                    max_instrs,
                    hook,
                );
            }
        }
        self.run_reference(max_instrs, hook)
    }

    /// Execute a *lane group*: machines running the **same** program
    /// `Arc` under the **same** cycle model, stepped through one lowered
    /// fetch/decode stream with per-lane registers, DMs and watchdog
    /// budgets (software SIMT, DESIGN.md §15).  `results[l]` is
    /// bit-identical to `lanes[l].run_fast(budgets[l])` run scalar; a
    /// lane that exits early retires individually while its mates keep
    /// stepping.  Lane runs are hook-free ([`super::NopHook`] semantics) —
    /// callers that observe retirement must run scalar.
    ///
    /// Returns `None` when the group cannot take the lane path — empty
    /// group, mixed programs or cycle models, a program the lowering
    /// rejects, or an entry `ze` the static mark set does not cover — so
    /// the caller falls back to per-lane scalar runs.
    pub fn run_lane_group(
        lanes: &mut [Machine],
        budgets: &[u64],
    ) -> Option<Vec<Result<RunStats, SimError>>> {
        assert_eq!(lanes.len(), budgets.len(), "one budget per lane");
        let first = lanes.first()?;
        let program = Arc::clone(&first.program);
        let cm = first.cycle_model;
        let superops = first.superops;
        if !lanes.iter().all(|m| {
            Arc::ptr_eq(&m.program, &program)
                && m.cycle_model == cm
                && m.superops == superops
        }) {
            return None;
        }
        let opts = super::lowered::LowerOpts { superops, profile: None };
        let lp = program.lowered_with(&cm, &opts)?;
        if !lanes.iter().all(|m| lp.covers_entry(m.ze)) {
            return None;
        }
        let mut out = Vec::with_capacity(lanes.len());
        let mut i = 0;
        // Widest-first chunking: 8-wide groups, then 4, 2, and a scalar
        // tail — each width is a distinct monomorphization of the lane
        // stepper, so the group size is a compile-time constant in the
        // hot loop.
        while i < lanes.len() {
            let left = lanes.len() - i;
            let k = if left >= 8 {
                8
            } else if left >= 4 {
                4
            } else if left >= 2 {
                2
            } else {
                1
            };
            let chunk = &mut lanes[i..i + k];
            let chunk_budgets = &budgets[i..i + k];
            match k {
                8 => out.extend(super::lowered::run_lanes::<8>(
                    chunk,
                    &lp,
                    chunk_budgets,
                )),
                4 => out.extend(super::lowered::run_lanes::<4>(
                    chunk,
                    &lp,
                    chunk_budgets,
                )),
                2 => out.extend(super::lowered::run_lanes::<2>(
                    chunk,
                    &lp,
                    chunk_budgets,
                )),
                _ => out.push(super::lowered::run_lowered(
                    &mut chunk[0],
                    &lp,
                    program.instrs(),
                    chunk_budgets[0],
                    &mut super::NopHook,
                )),
            }
            i += k;
        }
        Some(out)
    }

    /// The original decode-enum interpreter — the reference oracle the
    /// lowered loop is differentially tested against, and the fallback for
    /// states/models the lowering cannot bake.
    pub fn run_reference<H: RetireHook>(
        &mut self,
        max_instrs: u64,
        hook: &mut H,
    ) -> Result<RunStats, SimError> {
        let cm = self.cycle_model;
        let mut instrs: u64 = 0;
        let mut cycles: u64 = 0;
        // One Arc clone per run keeps the borrow checker away from the
        // per-field mutations below; the instruction slice itself is shared.
        let program = Arc::clone(&self.program);
        let prog: &[Instr] = program.instrs();
        let plen = (prog.len() as u32) * 4;

        loop {
            if instrs >= max_instrs {
                return Err(SimError::Watchdog { max_instrs });
            }
            let pc = self.pc;
            if pc >= plen || pc % 4 != 0 {
                return Err(SimError::PcOutOfRange { pc });
            }
            let instr = prog[(pc / 4) as usize];
            let mut next_pc = pc.wrapping_add(4);
            let cost: u64;

            macro_rules! reg {
                ($r:expr) => {
                    self.regs[$r as usize]
                };
            }

            match instr {
                Instr::OpImm { op, rd, rs1, imm } => {
                    let a = reg!(rs1);
                    let v = match op {
                        AluImmOp::Addi => a.wrapping_add(imm),
                        AluImmOp::Slti => (a < imm) as i32,
                        AluImmOp::Sltiu => ((a as u32) < (imm as u32)) as i32,
                        AluImmOp::Xori => a ^ imm,
                        AluImmOp::Ori => a | imm,
                        AluImmOp::Andi => a & imm,
                        AluImmOp::Slli => ((a as u32) << (imm & 31)) as i32,
                        AluImmOp::Srli => ((a as u32) >> (imm & 31)) as i32,
                        AluImmOp::Srai => a >> (imm & 31),
                    };
                    Self::write_reg(&mut self.regs, rd, v);
                    cost = cm.alu;
                }
                Instr::Op { op, rd, rs1, rs2 } => {
                    let a = reg!(rs1);
                    let b = reg!(rs2);
                    let (v, c) = match op {
                        AluOp::Add => (a.wrapping_add(b), cm.alu),
                        AluOp::Sub => (a.wrapping_sub(b), cm.alu),
                        AluOp::Sll => (((a as u32) << (b & 31)) as i32, cm.alu),
                        AluOp::Slt => ((a < b) as i32, cm.alu),
                        AluOp::Sltu => (((a as u32) < (b as u32)) as i32, cm.alu),
                        AluOp::Xor => (a ^ b, cm.alu),
                        AluOp::Srl => (((a as u32) >> (b & 31)) as i32, cm.alu),
                        AluOp::Sra => (a >> (b & 31), cm.alu),
                        AluOp::Or => (a | b, cm.alu),
                        AluOp::And => (a & b, cm.alu),
                        AluOp::Mul => (a.wrapping_mul(b), cm.mul),
                        AluOp::Mulh => {
                            ((((a as i64) * (b as i64)) >> 32) as i32, cm.mul)
                        }
                        AluOp::Mulhsu => {
                            ((((a as i64) * (b as u32 as i64)) >> 32) as i32, cm.mul)
                        }
                        AluOp::Mulhu => {
                            ((((a as u32 as u64) * (b as u32 as u64)) >> 32) as i32,
                             cm.mul)
                        }
                        AluOp::Div => (
                            if b == 0 {
                                -1
                            } else if a == i32::MIN && b == -1 {
                                i32::MIN
                            } else {
                                a.wrapping_div(b)
                            },
                            cm.div,
                        ),
                        AluOp::Divu => (
                            if b == 0 { -1 } else { ((a as u32) / (b as u32)) as i32 },
                            cm.div,
                        ),
                        AluOp::Rem => (
                            if b == 0 {
                                a
                            } else if a == i32::MIN && b == -1 {
                                0
                            } else {
                                a.wrapping_rem(b)
                            },
                            cm.div,
                        ),
                        AluOp::Remu => (
                            if b == 0 { a } else { ((a as u32) % (b as u32)) as i32 },
                            cm.div,
                        ),
                    };
                    Self::write_reg(&mut self.regs, rd, v);
                    cost = c;
                }
                Instr::Load { op, rd, rs1, offset } => {
                    let addr = (reg!(rs1) as u32).wrapping_add(offset as u32);
                    let v = match op {
                        LoadOp::Lb => self
                            .mem
                            .load_u8(addr)
                            .map(|b| b as i8 as i32),
                        LoadOp::Lbu => self.mem.load_u8(addr).map(|b| b as i32),
                        LoadOp::Lh => self
                            .mem
                            .load_u16(addr)
                            .map(|h| h as i16 as i32),
                        LoadOp::Lhu => self.mem.load_u16(addr).map(|h| h as i32),
                        LoadOp::Lw => self.mem.load_u32(addr).map(|w| w as i32),
                    }
                    .map_err(|fault| SimError::Mem { pc, fault })?;
                    Self::write_reg(&mut self.regs, rd, v);
                    cost = cm.load;
                }
                Instr::Store { op, rs2, rs1, offset } => {
                    let addr = (reg!(rs1) as u32).wrapping_add(offset as u32);
                    let v = reg!(rs2);
                    match op {
                        StoreOp::Sb => self.mem.store_u8(addr, v as u8),
                        StoreOp::Sh => self.mem.store_u16(addr, v as u16),
                        StoreOp::Sw => self.mem.store_u32(addr, v as u32),
                    }
                    .map_err(|fault| SimError::Mem { pc, fault })?;
                    cost = cm.store;
                }
                Instr::Branch { op, rs1, rs2, offset } => {
                    let a = reg!(rs1);
                    let b = reg!(rs2);
                    let taken = match op {
                        BranchOp::Beq => a == b,
                        BranchOp::Bne => a != b,
                        BranchOp::Blt => a < b,
                        BranchOp::Bge => a >= b,
                        BranchOp::Bltu => (a as u32) < (b as u32),
                        BranchOp::Bgeu => (a as u32) >= (b as u32),
                    };
                    if taken {
                        next_pc = pc.wrapping_add(offset as u32);
                        cost = cm.branch_taken;
                    } else {
                        cost = cm.branch_not_taken;
                    }
                }
                Instr::Jal { rd, offset } => {
                    Self::write_reg(&mut self.regs, rd, (pc + 4) as i32);
                    next_pc = pc.wrapping_add(offset as u32);
                    cost = cm.jump;
                }
                Instr::Jalr { rd, rs1, offset } => {
                    let target =
                        ((reg!(rs1) as u32).wrapping_add(offset as u32)) & !1;
                    Self::write_reg(&mut self.regs, rd, (pc + 4) as i32);
                    next_pc = target;
                    cost = cm.jump;
                }
                Instr::Lui { rd, imm } => {
                    Self::write_reg(&mut self.regs, rd, imm);
                    cost = cm.alu;
                }
                Instr::Auipc { rd, imm } => {
                    Self::write_reg(&mut self.regs, rd,
                                    (pc as i32).wrapping_add(imm));
                    cost = cm.alu;
                }
                Instr::Fence => {
                    cost = cm.alu;
                }
                Instr::Ecall => {
                    if H::OBSERVES {
                        hook.retire(pc, &instr, cm.alu);
                    }
                    return Ok(RunStats { instrs: instrs + 1, cycles: cycles + cm.alu });
                }
                Instr::Ebreak => {
                    return Err(SimError::Break { pc });
                }
                // --- custom extensions ---
                Instr::Mac => {
                    let v = reg!(MAC_RD).wrapping_add(
                        reg!(MAC_RS1).wrapping_mul(reg!(MAC_RS2)),
                    );
                    Self::write_reg(&mut self.regs, MAC_RD, v);
                    cost = cm.custom;
                }
                Instr::Add2i { rs1, rs2, i1, i2 } => {
                    let v1 = reg!(rs1).wrapping_add(i1 as i32);
                    let v2 = reg!(rs2).wrapping_add(i2 as i32);
                    Self::write_reg(&mut self.regs, rs1, v1);
                    Self::write_reg(&mut self.regs, rs2, v2);
                    cost = cm.custom;
                }
                Instr::FusedMac { rs1, rs2, i1, i2 } => {
                    let m = reg!(MAC_RD).wrapping_add(
                        reg!(MAC_RS1).wrapping_mul(reg!(MAC_RS2)),
                    );
                    Self::write_reg(&mut self.regs, MAC_RD, m);
                    let v1 = reg!(rs1).wrapping_add(i1 as i32);
                    let v2 = reg!(rs2).wrapping_add(i2 as i32);
                    Self::write_reg(&mut self.regs, rs1, v1);
                    Self::write_reg(&mut self.regs, rs2, v2);
                    cost = cm.custom;
                }
                Instr::Dlp { rs1, body_len } => {
                    self.zc = reg!(rs1) as u32;
                    self.zs = pc + 4;
                    self.ze = pc + 4 + 4 * body_len as u32;
                    cost = cm.zol_setup;
                }
                Instr::Dlpi { count, body_len } => {
                    self.zc = count as u32;
                    self.zs = pc + 4;
                    self.ze = pc + 4 + 4 * body_len as u32;
                    cost = cm.zol_setup;
                }
                Instr::Zlp { rs1, body_len } => {
                    let n = reg!(rs1) as u32;
                    self.zs = pc + 4;
                    self.ze = pc + 4 + 4 * body_len as u32;
                    if n == 0 {
                        // zero-iteration-safe: skip the body entirely
                        next_pc = self.ze;
                        self.zc = 0;
                        self.ze = 0;
                    } else {
                        self.zc = n;
                    }
                    cost = cm.zol_setup;
                }
                Instr::SetZc { rs1 } => {
                    self.zc = reg!(rs1) as u32;
                    cost = cm.zol_setup;
                }
                Instr::SetZs { rs1 } => {
                    self.zs = reg!(rs1) as u32;
                    cost = cm.zol_setup;
                }
                Instr::SetZe { rs1 } => {
                    self.ze = reg!(rs1) as u32;
                    cost = cm.zol_setup;
                }
                Instr::Custom { idx, rs1, rs2, i1, i2 } => {
                    // Mined window instruction: semantics come from the
                    // spec pool, via the one interpreter every execution
                    // path shares (crate::fusion::exec_sem).
                    let spec = crate::fusion::window_spec(idx);
                    crate::fusion::exec_sem(
                        spec.sem, &mut self.regs, &mut self.mem,
                        rs1, rs2, i1, i2,
                    )
                    .map_err(|fault| SimError::Mem { pc, fault })?;
                    cost = cm.custom;
                }
            }

            // Zero-overhead loop-back: when execution reaches ZE, hardware
            // redirects to ZS and decrements ZC — no cycles, no retire.
            if next_pc == self.ze && self.ze != 0 {
                if self.zc > 1 {
                    self.zc -= 1;
                    next_pc = self.zs;
                } else {
                    self.zc = 0;
                    self.ze = 0; // disarm
                }
            }

            // `OBSERVES` is an associated const, so for `NopHook`-class
            // hooks this branch (and the retire call behind it) folds away
            // at monomorphization time instead of being tested per retire.
            if H::OBSERVES {
                hook.retire(pc, &instr, cost);
            }
            self.pc = next_pc;
            instrs += 1;
            cycles += cost;
        }
    }

    /// Convenience: run with no hook.
    pub fn run_fast(&mut self, max_instrs: u64) -> Result<RunStats, SimError> {
        self.run(max_instrs, &mut super::NopHook)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode;
    use crate::sim::{V0, V4};

    fn asm_words(instrs: &[Instr]) -> Vec<u32> {
        instrs.iter().map(encode).collect()
    }

    fn run_v(variant: Variant, instrs: &[Instr]) -> (Sim, RunStats) {
        let mut sim = Sim::load(variant, &asm_words(instrs), 4096).unwrap();
        let stats = sim.run_fast(1_000_000).unwrap();
        (sim, stats)
    }

    #[test]
    fn arithmetic_basics() {
        use AluImmOp::*;
        use AluOp::*;
        let (sim, _) = run_v(V0, &[
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 40 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 0, imm: -2 },
            Instr::Op { op: Add, rd: 3, rs1: 1, rs2: 2 },
            Instr::Op { op: Mul, rd: 4, rs1: 1, rs2: 2 },
            Instr::Op { op: Sub, rd: 5, rs1: 1, rs2: 2 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[3], 38);
        assert_eq!(sim.regs[4], -80);
        assert_eq!(sim.regs[5], 42);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let (sim, _) = run_v(V0, &[
            Instr::OpImm { op: AluImmOp::Addi, rd: 0, rs1: 0, imm: 99 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[0], 0);
    }

    #[test]
    fn loads_stores_signext() {
        let (sim, _) = run_v(V0, &[
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: -3 },
            Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 16 },
            Instr::Load { op: LoadOp::Lb, rd: 2, rs1: 0, offset: 16 },
            Instr::Load { op: LoadOp::Lbu, rd: 3, rs1: 0, offset: 16 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[2], -3);
        assert_eq!(sim.regs[3], 0xfd);
    }

    #[test]
    fn branch_loop_counts_cycles() {
        use AluImmOp::Addi;
        // for (i = 0; i < 5; i++) ;  -- classic blt loop
        let prog = [
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 0 },  // i = 0
            Instr::OpImm { op: Addi, rd: 2, rs1: 0, imm: 5 },  // n = 5
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },  // loop: i++
            Instr::Branch { op: BranchOp::Blt, rs1: 1, rs2: 2, offset: -4 },
            Instr::Ecall,
        ];
        let (sim, stats) = run_v(V0, &prog);
        assert_eq!(sim.regs[1], 5);
        // 2 setup + 5*(addi+blt) + ecall = 13 instrs
        assert_eq!(stats.instrs, 13);
        // cycles: 2 + 5 addi + 4 taken(2) + 1 not-taken(1) + ecall(1) = 17
        assert_eq!(stats.cycles, 17);
    }

    #[test]
    fn mac_semantics_and_gating() {
        use AluImmOp::Addi;
        let prog = [
            Instr::OpImm { op: Addi, rd: MAC_RD, rs1: 0, imm: 5 },
            Instr::OpImm { op: Addi, rd: MAC_RS1, rs1: 0, imm: 6 },
            Instr::OpImm { op: Addi, rd: MAC_RS2, rs1: 0, imm: 7 },
            Instr::Mac,
            Instr::Ecall,
        ];
        let (sim, _) = run_v(V4, &prog);
        assert_eq!(sim.regs[MAC_RD as usize], 5 + 6 * 7);
        // v0 must reject the custom instruction at load
        let err = match Sim::load(V0, &asm_words(&prog), 64) {
            Err(e) => e,
            Ok(_) => panic!("v0 accepted custom instruction"),
        };
        assert!(matches!(err, SimError::Unsupported { .. }), "{err}");
    }

    #[test]
    fn add2i_and_fusedmac() {
        use AluImmOp::Addi;
        let (sim, _) = run_v(V4, &[
            Instr::OpImm { op: Addi, rd: 5, rs1: 0, imm: 100 },
            Instr::OpImm { op: Addi, rd: 6, rs1: 0, imm: 200 },
            Instr::Add2i { rs1: 5, rs2: 6, i1: 3, i2: 1000 },
            Instr::OpImm { op: Addi, rd: MAC_RD, rs1: 0, imm: 1 },
            Instr::OpImm { op: Addi, rd: MAC_RS1, rs1: 0, imm: 2 },
            Instr::OpImm { op: Addi, rd: MAC_RS2, rs1: 0, imm: 3 },
            Instr::FusedMac { rs1: 5, rs2: 6, i1: 1, i2: 2 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[5], 104); // 100 + 3 + 1
        assert_eq!(sim.regs[6], 1202); // 200 + 1000 + 2
        assert_eq!(sim.regs[MAC_RD as usize], 7); // 1 + 2*3
    }

    #[test]
    fn zol_loop_no_branch_cost() {
        use AluImmOp::Addi;
        // dlpi 5 iterations over a 1-instruction body
        let (sim, stats) = run_v(V4, &[
            Instr::Dlpi { count: 5, body_len: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 2 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[1], 10);
        // dlpi(1) + 5 addi(5) + ecall(1): loop-back costs nothing
        assert_eq!(stats.instrs, 7);
        assert_eq!(stats.cycles, 7);
    }

    #[test]
    fn zol_dlp_register_count_and_zlp_zero() {
        use AluImmOp::Addi;
        let (sim, _) = run_v(V4, &[
            Instr::OpImm { op: Addi, rd: 3, rs1: 0, imm: 7 },
            Instr::Dlp { rs1: 3, body_len: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[1], 7);
        // zlp with a zero count skips the body entirely
        let (sim, _) = run_v(V4, &[
            Instr::Zlp { rs1: 3, body_len: 2 }, // x3 == 0
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[1], 0);
    }

    #[test]
    fn nested_zol_via_set_registers() {
        use AluImmOp::Addi;
        // Manually re-arm a loop with set.zc/zs/ze: run body twice more.
        let (sim, _) = run_v(V4, &[
            Instr::Dlpi { count: 3, body_len: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[1], 3);
    }

    #[test]
    fn faults_reported() {
        // memory out of bounds
        let words = asm_words(&[Instr::Load {
            op: LoadOp::Lw,
            rd: 1,
            rs1: 0,
            offset: 2047,
        }]);
        let mut sim = Sim::load(V0, &words, 64).unwrap();
        assert!(matches!(
            sim.run_fast(10),
            Err(SimError::Mem { .. })
        ));
        // running off the end of the program
        let words = asm_words(&[Instr::OpImm {
            op: AluImmOp::Addi,
            rd: 1,
            rs1: 0,
            imm: 1,
        }]);
        let mut sim = Sim::load(V0, &words, 64).unwrap();
        assert!(matches!(
            sim.run_fast(10),
            Err(SimError::PcOutOfRange { .. })
        ));
        // watchdog
        let words = asm_words(&[Instr::Jal { rd: 0, offset: 0 }]);
        let mut sim = Sim::load(V0, &words, 64).unwrap();
        assert!(matches!(
            sim.run_fast(100),
            Err(SimError::Watchdog { .. })
        ));
    }

    #[test]
    fn div_rem_edge_cases() {
        use AluImmOp::Addi;
        use AluOp::*;
        let (sim, _) = run_v(V0, &[
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 7 },
            Instr::Op { op: Div, rd: 2, rs1: 1, rs2: 0 },  // div by zero = -1
            Instr::Op { op: Rem, rd: 3, rs1: 1, rs2: 0 },  // rem by zero = a
            Instr::Lui { rd: 4, imm: i32::MIN },           // 0x80000000
            Instr::OpImm { op: Addi, rd: 5, rs1: 0, imm: -1 },
            Instr::Op { op: Div, rd: 6, rs1: 4, rs2: 5 },  // overflow = MIN
            Instr::Op { op: Rem, rd: 7, rs1: 4, rs2: 5 },  // overflow rem = 0
            Instr::Ecall,
        ]);
        assert_eq!(sim.regs[2], -1);
        assert_eq!(sim.regs[3], 7);
        assert_eq!(sim.regs[6], i32::MIN);
        assert_eq!(sim.regs[7], 0);
    }
}
