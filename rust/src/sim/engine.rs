//! Parallel batch execution: N inputs × M variants across worker threads.
//!
//! The paper's evaluation (Fig 10–12, Tables 8/10) simulates every model on
//! five core variants over multiple golden inputs.  With the program/state
//! split ([`Program`]/[`super::Machine`]) each of those runs is an
//! independent pure function of its [`Job`], so the engine fans a batch out
//! over `std::thread` workers and reassembles results in submission order —
//! results are deterministic and byte-identical for any worker count
//! (DESIGN.md §3, "threading and determinism contract").
//!
//! The layer is deliberately compiler-agnostic: a [`Job`] describes memory
//! setup as raw `(addr, bytes)` blocks, so the sim crate stays free of
//! model-spec knowledge.  `compiler::make_job` builds jobs from a
//! `Compiled`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::cpu::{Machine, RunStats, SimError};
use super::program::Program;

/// One simulation run: a shared program plus its memory setup.
pub struct Job<'a> {
    /// The shared, decode-once program (cheap `Arc` handle).
    pub program: Arc<Program>,
    /// Data-memory size in bytes.
    pub dm_size: usize,
    /// Blocks written into DM before the run (weights images, constants).
    /// Borrowed — the batch only needs them alive for the call.
    pub preload: Vec<(u32, &'a [u8])>,
    /// Per-run input block, written after `preload`.  Borrowed like
    /// `preload`, so one packed input can feed many variants' jobs.
    pub input: (u32, &'a [u8]),
    /// `(addr, n)`: read back `n` int8 values (widened to i32) after a
    /// successful run.
    pub output: (u32, usize),
    /// Watchdog budget.
    pub max_instrs: u64,
}

/// What one completed job produces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobOutput {
    /// int8 outputs widened to i32 (the model logits).
    pub output: Vec<i32>,
    pub stats: RunStats,
}

/// Execute one job on the current thread.
pub fn run_job(job: &Job<'_>) -> Result<JobOutput, SimError> {
    let mut m = Machine::new(Arc::clone(&job.program), job.dm_size);
    for &(addr, block) in &job.preload {
        m.mem
            .write_block(addr, block)
            .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    }
    m.mem
        .write_block(job.input.0, job.input.1)
        .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    let stats = m.run_fast(job.max_instrs)?;
    let output = m
        .mem
        .read_i8s(job.output.0, job.output.1)
        .map_err(|fault| SimError::Mem { pc: m.pc, fault })?;
    Ok(JobOutput { output, stats })
}

/// One worker thread per core by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Run a batch of jobs on up to `threads` worker threads (`0` = one per
/// core).  `results[i]` always corresponds to `jobs[i]`: each job is a pure
/// function of its inputs, so the output is byte-identical for any worker
/// count — only wall-clock changes.
pub fn run_batch(
    jobs: &[Job<'_>],
    threads: usize,
) -> Vec<Result<JobOutput, SimError>> {
    let n = jobs.len();
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.min(n).max(1);
    if threads == 1 {
        return jobs.iter().map(run_job).collect();
    }

    // Work-stealing by atomic cursor: long jobs (big models) don't leave
    // workers idle the way a static chunking would.
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<JobOutput, SimError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = run_job(&jobs[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluImmOp, Instr};
    use crate::sim::{V0, V4};

    /// load x1 <- dm[0]; x1 += k; store dm[4] <- x1; ecall
    fn add_k_program(k: i32) -> Arc<Program> {
        use crate::isa::{LoadOp, StoreOp};
        Arc::new(
            Program::from_instrs(
                V0,
                vec![
                    Instr::Load { op: LoadOp::Lb, rd: 1, rs1: 0, offset: 0 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: k },
                    Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 4 },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        )
    }

    fn jobs_for<'a>(p: &Arc<Program>, inputs: &'a [[u8; 1]]) -> Vec<Job<'a>> {
        inputs
            .iter()
            .map(|x| Job {
                program: Arc::clone(p),
                dm_size: 64,
                preload: Vec::new(),
                input: (0, &x[..]),
                output: (4, 1),
                max_instrs: 100,
            })
            .collect()
    }

    #[test]
    fn batch_results_in_submission_order() {
        let p = add_k_program(10);
        let inputs: Vec<[u8; 1]> = (0..20u8).map(|x| [x]).collect();
        let jobs = jobs_for(&p, &inputs);
        for threads in [1, 2, 8] {
            let rs = run_batch(&jobs, threads);
            assert_eq!(rs.len(), inputs.len());
            for (i, r) in rs.iter().enumerate() {
                let out = r.as_ref().unwrap();
                assert_eq!(out.output, vec![i as i32 + 10], "threads={threads}");
                assert_eq!(out.stats.instrs, 4);
            }
        }
    }

    #[test]
    fn errors_stay_at_their_index() {
        let p = add_k_program(1);
        let inputs: Vec<[u8; 1]> = vec![[1], [2], [3]];
        let mut jobs = jobs_for(&p, &inputs);
        // job 1 writes its input out of bounds -> Mem fault before the run
        jobs[1].input.0 = 1 << 20;
        let rs = run_batch(&jobs, 4);
        assert!(rs[0].is_ok());
        assert!(matches!(rs[1], Err(SimError::Mem { .. })));
        assert!(rs[2].is_ok());
    }

    #[test]
    fn zol_program_shared_across_threads() {
        // dlpi 5 over addi body — exercises the v4 path under threading
        let p = Arc::new(
            Program::from_instrs(
                V4,
                vec![
                    Instr::Dlpi { count: 5, body_len: 1 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 2 },
                    Instr::Store {
                        op: crate::isa::StoreOp::Sb,
                        rs2: 1,
                        rs1: 0,
                        offset: 4,
                    },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        );
        let zero = [0u8];
        let jobs: Vec<Job<'_>> = (0..8)
            .map(|_| Job {
                program: Arc::clone(&p),
                dm_size: 64,
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            })
            .collect();
        for r in run_batch(&jobs, 3) {
            assert_eq!(r.unwrap().output, vec![10]);
        }
    }
}
