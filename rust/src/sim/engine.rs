//! Parallel batch execution: N inputs × M variants across worker threads.
//!
//! The paper's evaluation (Fig 10–12, Tables 8/10) simulates every model on
//! five core variants over multiple golden inputs.  With the program/state
//! split ([`Program`]/[`super::Machine`]) each of those runs is an
//! independent pure function of its [`Job`], so the engine fans a batch out
//! over `std::thread` workers and reassembles results in submission order —
//! results are deterministic and byte-identical for any worker count
//! (DESIGN.md §3, "threading and determinism contract").
//!
//! Two allocation disciplines keep the per-job overhead flat (DESIGN.md
//! §3):
//!
//! - **Machine/DM pooling** — each worker owns one [`Machine`] and recycles
//!   it across every job it claims ([`run_job_pooled`]); the DM `Vec`
//!   allocation survives job boundaries, so a many-small-model sweep costs
//!   no allocator traffic per run.
//! - **Base DM images** — a job may carry a prebuilt full-DM image
//!   ([`Job::base_image`], typically `compiler::Compiled::base_dm` with the
//!   weights already written), initializing memory with one
//!   `copy_from_slice` instead of zero-fill + per-block writes.
//!
//! Results land in pre-claimed, lock-free slots (the atomic work cursor
//! hands each index to exactly one worker), and a panicking worker is
//! propagated to the caller via `resume_unwind` instead of surfacing as a
//! confusing poisoned-slot error.
//!
//! The layer is deliberately compiler-agnostic: a [`Job`] describes memory
//! setup as raw bytes/blocks, so the sim crate stays free of model-spec
//! knowledge.  `compiler::make_job` builds jobs from a `Compiled`.
//!
//! [`run_batch`] is the one-shot primitive: it spawns scoped workers per
//! call.  Sweep-style callers go through the [`crate::sim::exec`]
//! `Executor` API instead — its `LocalExec` keeps this module's pooling
//! and panic-propagation contract on a worker pool that persists across
//! batches (DESIGN.md §13).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use super::cpu::{Machine, RunStats, SimError};
use super::program::Program;

/// One simulation run: a shared program plus its memory setup.
pub struct Job<'a> {
    /// The shared, decode-once program (cheap `Arc` handle).
    pub program: Arc<Program>,
    /// Data-memory size in bytes.
    pub dm_size: usize,
    /// Optional full base DM image copied in before `preload` (shorter
    /// images are zero-padded to `dm_size`).  Borrowed — typically the
    /// compiler's prebuilt weights image, shared by every job of a model.
    pub base_image: Option<&'a [u8]>,
    /// Blocks written into DM after `base_image` (weights images,
    /// constants).  Borrowed — the batch only needs them alive for the
    /// call.
    pub preload: Vec<(u32, &'a [u8])>,
    /// Per-run input block, written after `preload`.  Borrowed like
    /// `preload`, so one packed input can feed many variants' jobs.
    pub input: (u32, &'a [u8]),
    /// `(addr, n)`: read back `n` int8 values (widened to i32) after a
    /// successful run.
    pub output: (u32, usize),
    /// Watchdog budget.
    pub max_instrs: u64,
}

/// What one completed job produces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobOutput {
    /// int8 outputs widened to i32 (the model logits).
    pub output: Vec<i32>,
    pub stats: RunStats,
}

/// Execute one job on a fresh machine on the current thread.
pub fn run_job(job: &Job<'_>) -> Result<JobOutput, SimError> {
    let mut m = Machine::new(Arc::clone(&job.program), 0);
    run_job_on(&mut m, job)
}

/// Execute one job on an existing machine, recycling its allocations —
/// the pooled path the batch workers use.  Produces output byte-identical
/// to [`run_job`]: the machine is rebound and its memory fully
/// re-initialized, so no state leaks between jobs.
pub fn run_job_on(m: &mut Machine, job: &Job<'_>) -> Result<JobOutput, SimError> {
    setup_job(m, job)?;
    let stats = m.run_fast(job.max_instrs);
    finish_job(m, job, stats)
}

/// Everything of a job that happens *before* the run: rebind the machine
/// to the job's program, re-init its DM (base image or zero-fill, reusing
/// the allocation), write preload blocks and the per-run input.  Shared by
/// the scalar pooled path ([`run_job_on`]) and the lane pack
/// ([`run_lane_pack`]), which sets up each lane with this and then steps
/// all of them together.
fn setup_job(m: &mut Machine, job: &Job<'_>) -> Result<(), SimError> {
    m.rebind(Arc::clone(&job.program));
    m.mem
        .reinit(job.base_image, job.dm_size)
        .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    for &(addr, block) in &job.preload {
        m.mem
            .write_block(addr, block)
            .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    }
    m.mem
        .write_block(job.input.0, job.input.1)
        .map_err(|fault| SimError::Mem { pc: 0, fault })?;
    Ok(())
}

/// Everything *after* the run: propagate the run result and read the
/// output block back.
fn finish_job(
    m: &Machine,
    job: &Job<'_>,
    run: Result<RunStats, SimError>,
) -> Result<JobOutput, SimError> {
    let stats = run?;
    let output = m
        .mem
        .read_i8s(job.output.0, job.output.1)
        .map_err(|fault| SimError::Mem { pc: m.pc, fault })?;
    Ok(JobOutput { output, stats })
}

/// Widest lane group the lowered interpreter monomorphizes
/// (`run_lanes::<8>`); lane packs larger than this are chunked by
/// [`Machine::run_lane_group`].
pub const MAX_LANES: usize = 8;

/// Read a `MARVEL_*` override: parse the variable with `parse`, and when a
/// non-empty value is rejected, warn **once per variable** to stderr with
/// the rejected value (satellite of DESIGN.md §19 — silent fallback made
/// override typos invisible).  Unset or blank values stay silent: clearing
/// a variable to blank is a deliberate "use the default".
fn read_env_override<T>(
    var: &str,
    warned: &'static std::sync::Once,
    parse: fn(Option<&str>) -> Option<T>,
) -> Option<T> {
    let raw = std::env::var(var).ok();
    let parsed = parse(raw.as_deref());
    if parsed.is_none() {
        if let Some(s) = raw.as_deref() {
            if !s.trim().is_empty() {
                warned.call_once(|| {
                    eprintln!(
                        "marvel: ignoring unparseable {var}={s:?}; using default"
                    );
                });
            }
        }
    }
    parsed
}

/// Lane-pack width for callers that take the default: the `MARVEL_LANES`
/// environment override when set to a positive integer (clamped to
/// [`MAX_LANES`]), else [`MAX_LANES`].  `MARVEL_LANES=1` disables lane
/// packing — every job runs scalar.  Rejected values warn once to stderr.
pub fn default_lanes() -> usize {
    static WARNED: std::sync::Once = std::sync::Once::new();
    read_env_override("MARVEL_LANES", &WARNED, lanes_override)
        .unwrap_or(MAX_LANES)
}

/// Parse a `MARVEL_LANES` value: positive integers (surrounding whitespace
/// tolerated) override, clamped to [`MAX_LANES`]; anything else — unset,
/// empty, `0`, garbage — falls back to the default.
pub fn lanes_override(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .map(|n| n.min(MAX_LANES))
}

/// Execute a pack of jobs as one lane group on a pool of recycled
/// machines.  `results[i]` corresponds to `jobs[i]` and is byte-identical
/// to `run_job_on` run per job — lane packing is an execution-shape
/// choice, never a semantics choice (DESIGN.md §15).
///
/// The pack is set up lane by lane (a job whose DM setup faults completes
/// immediately with that error and consumes no lane), then every
/// successfully-set-up lane is stepped through
/// [`Machine::run_lane_group`].  When the group cannot take the lane path
/// (mixed programs, unlowerable program), the already-set-up lanes run
/// scalar instead — callers don't need to pre-validate pack homogeneity.
///
/// `pool` grows to the pack's lane count on first use and is reused (DM
/// allocations and all) across packs, like the scalar pooled path.
pub fn run_lane_pack(
    pool: &mut Vec<Machine>,
    jobs: &[Job<'_>],
) -> Vec<Result<JobOutput, SimError>> {
    let n = jobs.len();
    let mut results: Vec<Option<Result<JobOutput, SimError>>> =
        (0..n).map(|_| None).collect();
    // lane -> job index, for jobs whose setup succeeded.
    let mut lane_jobs: Vec<usize> = Vec::with_capacity(n);
    for (i, job) in jobs.iter().enumerate() {
        let l = lane_jobs.len();
        if pool.len() <= l {
            pool.push(Machine::new(Arc::clone(&job.program), 0));
        }
        match setup_job(&mut pool[l], job) {
            Ok(()) => lane_jobs.push(i),
            Err(e) => results[i] = Some(Err(e)),
        }
    }
    let k = lane_jobs.len();
    let budgets: Vec<u64> =
        lane_jobs.iter().map(|&i| jobs[i].max_instrs).collect();
    match Machine::run_lane_group(&mut pool[..k], &budgets) {
        Some(rs) => {
            for (l, r) in rs.into_iter().enumerate() {
                let i = lane_jobs[l];
                results[i] = Some(finish_job(&pool[l], &jobs[i], r));
            }
        }
        None => {
            // Scalar fallback: the lanes are fully set up already, so just
            // run each in place.
            for (l, &i) in lane_jobs.iter().enumerate() {
                let r = pool[l].run_fast(jobs[i].max_instrs);
                results[i] = Some(finish_job(&pool[l], &jobs[i], r));
            }
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("every job completed"))
        .collect()
}

/// [`run_job_on`] against a lazily-created pool slot: the first call
/// builds the machine, later calls recycle it.
pub fn run_job_pooled(
    pool: &mut Option<Machine>,
    job: &Job<'_>,
) -> Result<JobOutput, SimError> {
    let m = pool
        .get_or_insert_with(|| Machine::new(Arc::clone(&job.program), 0));
    run_job_on(m, job)
}

/// Worker count for `threads == 0`: the `MARVEL_THREADS` environment
/// override when set to a positive integer (documented in `marvel help`),
/// else one worker thread per core.  Rejected values warn once to stderr.
pub fn default_threads() -> usize {
    static WARNED: std::sync::Once = std::sync::Once::new();
    match read_env_override("MARVEL_THREADS", &WARNED, threads_override) {
        Some(n) => n,
        None => std::thread::available_parallelism().map_or(1, |n| n.get()),
    }
}

/// Parse a `MARVEL_THREADS` value: positive integers (surrounding
/// whitespace tolerated) override; anything else — unset, empty, `0`,
/// garbage — falls back to auto.
pub fn threads_override(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Default for [`super::Machine::superops`]: the `MARVEL_SUPEROPS`
/// environment override when parseable, else off.  Rejected values warn
/// once to stderr.
pub fn default_superops() -> bool {
    static WARNED: std::sync::Once = std::sync::Once::new();
    read_env_override("MARVEL_SUPEROPS", &WARNED, superops_override)
        .unwrap_or(false)
}

/// Parse a `MARVEL_SUPEROPS` value: `1`/`true`/`on`/`yes` enable,
/// `0`/`false`/`off`/`no` disable (case-insensitive, surrounding
/// whitespace tolerated); anything else falls back to the default (off).
pub fn superops_override(v: Option<&str>) -> Option<bool> {
    match v?.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

/// Process-wide lane-packing counters (DESIGN.md §19): how many lane packs
/// the executors formed and how full they were.  Recorded where packs are
/// *formed* (the exec layer, which knows the target width), snapshot by
/// `bench_iss` JSON rows so packing regressions show in the trend
/// dashboard rather than only as end throughput.
pub mod lane_stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static PACKS_FORMED: AtomicU64 = AtomicU64::new(0);
    static LANES_FILLED: AtomicU64 = AtomicU64::new(0);
    static LANE_SLOTS: AtomicU64 = AtomicU64::new(0);

    /// One pack was formed with `filled` of `capacity` lane slots
    /// occupied.  Under-filled packs (including singleton tails at a
    /// multi-lane width) are recorded too — lost occupancy is the signal.
    /// Scalar execution (width 1) records nothing.
    pub fn record_pack(filled: usize, capacity: usize) {
        PACKS_FORMED.fetch_add(1, Ordering::Relaxed);
        LANES_FILLED.fetch_add(filled as u64, Ordering::Relaxed);
        LANE_SLOTS.fetch_add(capacity.max(filled) as u64, Ordering::Relaxed);
    }

    /// Counter snapshot; `lane_occupancy()` folds it to the dashboard's
    /// single figure of merit.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct LaneStats {
        pub packs_formed: u64,
        pub lanes_filled: u64,
        pub lane_slots: u64,
    }

    impl LaneStats {
        /// Mean fill ratio of formed packs in `[0, 1]`; `0` when no packs
        /// were formed.
        pub fn lane_occupancy(&self) -> f64 {
            if self.lane_slots == 0 {
                0.0
            } else {
                self.lanes_filled as f64 / self.lane_slots as f64
            }
        }
    }

    /// Current totals since process start (or the last [`reset`]).
    pub fn snapshot() -> LaneStats {
        LaneStats {
            packs_formed: PACKS_FORMED.load(Ordering::Relaxed),
            lanes_filled: LANES_FILLED.load(Ordering::Relaxed),
            lane_slots: LANE_SLOTS.load(Ordering::Relaxed),
        }
    }

    /// Zero the counters and return the totals they held — benches call
    /// this between rows so each row reports only its own packs.
    pub fn reset() -> LaneStats {
        LaneStats {
            packs_formed: PACKS_FORMED.swap(0, Ordering::Relaxed),
            lanes_filled: LANES_FILLED.swap(0, Ordering::Relaxed),
            lane_slots: LANE_SLOTS.swap(0, Ordering::Relaxed),
        }
    }
}

/// Per-job result slots written without locks: the atomic work cursor
/// hands each index to exactly one worker, which is the sole writer of
/// that slot; the buffer is only read back after every worker has been
/// joined (or otherwise synchronized-with).  Shared with the persistent
/// pool in [`crate::sim::exec`].
pub(crate) struct Slots<T>(Vec<UnsafeCell<Option<T>>>);

// SAFETY: see the struct docs — slot `i` is written only by the single
// worker that claimed `i` from the cursor, and read only after join.
unsafe impl<T: Send> Sync for Slots<T> {}

impl<T> Slots<T> {
    pub(crate) fn new(n: usize) -> Slots<T> {
        Slots((0..n).map(|_| UnsafeCell::new(None)).collect())
    }

    /// SAFETY: the caller must hold the unique claim on index `i`.
    pub(crate) unsafe fn write(&self, i: usize, v: T) {
        *self.0[i].get() = Some(v);
    }

    /// SAFETY: the caller must guarantee every writer has quiesced (its
    /// writes happen-before this call) and that no slot has two readers.
    pub(crate) unsafe fn take(&self, i: usize) -> Option<T> {
        (*self.0[i].get()).take()
    }

    fn into_results(self) -> Vec<Option<T>> {
        self.0.into_iter().map(UnsafeCell::into_inner).collect()
    }
}

/// Run a batch of jobs on up to `threads` worker threads (`0` = one per
/// core).  `results[i]` always corresponds to `jobs[i]`: each job is a pure
/// function of its inputs, so the output is byte-identical for any worker
/// count — only wall-clock changes.  A panic on a worker thread (a bug, not
/// a [`SimError`]) is re-raised on the calling thread.
pub fn run_batch(
    jobs: &[Job<'_>],
    threads: usize,
) -> Vec<Result<JobOutput, SimError>> {
    let n = jobs.len();
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.min(n).max(1);
    if threads == 1 {
        let mut pool: Option<Machine> = None;
        return jobs.iter().map(|j| run_job_pooled(&mut pool, j)).collect();
    }

    // Work-stealing by atomic cursor: long jobs (big models) don't leave
    // workers idle the way a static chunking would.  A panicking worker
    // raises `stop` so its siblings quit claiming jobs instead of draining
    // the rest of a possibly-huge batch first.
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Slots<Result<JobOutput, SimError>> = Slots::new(n);
    let panic = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut pool: Option<Machine> = None;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                run_job_pooled(&mut pool, &jobs[i])
                            }),
                        );
                        match r {
                            // SAFETY: the cursor handed index i to this
                            // worker alone.
                            Ok(res) => unsafe { slots.write(i, res) },
                            Err(p) => {
                                stop.store(true, Ordering::Relaxed);
                                std::panic::resume_unwind(p);
                            }
                        }
                    }
                })
            })
            .collect();
        // Join explicitly so a worker panic is captured (and re-raised
        // below) rather than aborting via the scope's implicit join.
        let mut panic = None;
        for h in handles {
            if let Err(p) = h.join() {
                if panic.is_none() {
                    panic = Some(p);
                }
            }
        }
        panic
    });
    if let Some(p) = panic {
        std::panic::resume_unwind(p);
    }
    slots
        .into_results()
        .into_iter()
        .map(|r| r.expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluImmOp, Instr};
    use crate::sim::{V0, V4};

    /// load x1 <- dm[0]; x1 += k; store dm[4] <- x1; ecall
    fn add_k_program(k: i32) -> Arc<Program> {
        use crate::isa::{LoadOp, StoreOp};
        Arc::new(
            Program::from_instrs(
                V0,
                vec![
                    Instr::Load { op: LoadOp::Lb, rd: 1, rs1: 0, offset: 0 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: k },
                    Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 4 },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        )
    }

    fn jobs_for<'a>(p: &Arc<Program>, inputs: &'a [[u8; 1]]) -> Vec<Job<'a>> {
        inputs
            .iter()
            .map(|x| Job {
                program: Arc::clone(p),
                dm_size: 64,
                base_image: None,
                preload: Vec::new(),
                input: (0, &x[..]),
                output: (4, 1),
                max_instrs: 100,
            })
            .collect()
    }

    #[test]
    fn batch_results_in_submission_order() {
        let p = add_k_program(10);
        let inputs: Vec<[u8; 1]> = (0..20u8).map(|x| [x]).collect();
        let jobs = jobs_for(&p, &inputs);
        for threads in [1, 2, 8] {
            let rs = run_batch(&jobs, threads);
            assert_eq!(rs.len(), inputs.len());
            for (i, r) in rs.iter().enumerate() {
                let out = r.as_ref().unwrap();
                assert_eq!(out.output, vec![i as i32 + 10], "threads={threads}");
                assert_eq!(out.stats.instrs, 4);
            }
        }
    }

    #[test]
    fn errors_stay_at_their_index() {
        let p = add_k_program(1);
        let inputs: Vec<[u8; 1]> = vec![[1], [2], [3]];
        let mut jobs = jobs_for(&p, &inputs);
        // job 1 writes its input out of bounds -> Mem fault before the run
        jobs[1].input.0 = 1 << 20;
        let rs = run_batch(&jobs, 4);
        assert!(rs[0].is_ok());
        assert!(matches!(rs[1], Err(SimError::Mem { .. })));
        assert!(rs[2].is_ok());
    }

    #[test]
    fn base_image_initializes_dm() {
        // load x1 <- dm[8] (beyond the input block); add; store dm[4]
        use crate::isa::{LoadOp, StoreOp};
        let p = Arc::new(
            Program::from_instrs(
                V0,
                vec![
                    Instr::Load { op: LoadOp::Lb, rd: 1, rs1: 0, offset: 8 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
                    Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 4 },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        );
        let mut base = vec![0u8; 16];
        base[8] = 41;
        let zero = [0u8];
        let job = Job {
            program: Arc::clone(&p),
            dm_size: 64, // shorter base image is zero-padded
            base_image: Some(&base),
            preload: Vec::new(),
            input: (0, &zero[..]),
            output: (4, 1),
            max_instrs: 100,
        };
        assert_eq!(run_job(&job).unwrap().output, vec![42]);
        // an oversized base image faults instead of truncating
        let big = vec![0u8; 65];
        let bad = Job { base_image: Some(&big), ..job };
        assert!(matches!(run_job(&bad), Err(SimError::Mem { .. })));
    }

    #[test]
    fn pooled_machine_matches_fresh_across_programs() {
        // Alternate two different programs (different k, dm sizes) through
        // one pooled machine; every result must equal the fresh-machine
        // path.
        let p1 = add_k_program(3);
        let p2 = add_k_program(9);
        let inputs: Vec<[u8; 1]> = (0..6u8).map(|x| [x]).collect();
        let mut jobs = Vec::new();
        for (i, x) in inputs.iter().enumerate() {
            let p = if i % 2 == 0 { &p1 } else { &p2 };
            jobs.push(Job {
                program: Arc::clone(p),
                dm_size: if i % 2 == 0 { 64 } else { 128 },
                base_image: None,
                preload: Vec::new(),
                input: (0, &x[..]),
                output: (4, 1),
                max_instrs: 100,
            });
        }
        let mut pool: Option<Machine> = None;
        for job in &jobs {
            let fresh = run_job(job).unwrap();
            let pooled = run_job_pooled(&mut pool, job).unwrap();
            assert_eq!(pooled, fresh);
        }
        // the pool really was reused, not rebuilt
        assert!(pool.is_some());
    }

    #[test]
    fn pool_rebind_never_leaks_bytes_across_dm_sizes() {
        // Interleave jobs with differing DM sizes and base images through
        // ONE pooled machine, in the order that would expose every leak
        // mode of `Memory::reset_from`/`reset`:
        //   big job (0xAA-filled base image)  →  small job (short base
        //   image) → small job (no base image) → big job again.
        // Each probe reads a byte the *previous* job wrote but the current
        // job's init must have cleared; any nonzero read is a leak.
        use crate::isa::{LoadOp, StoreOp};
        // load x1 <- dm[probe]; store dm[4] <- x1; ecall
        let probe_program = |probe: i32| {
            Arc::new(
                Program::from_instrs(
                    V0,
                    vec![
                        Instr::Load {
                            op: LoadOp::Lb,
                            rd: 1,
                            rs1: 0,
                            offset: probe,
                        },
                        Instr::Store {
                            op: StoreOp::Sb,
                            rs2: 1,
                            rs1: 0,
                            offset: 4,
                        },
                        Instr::Ecall,
                    ],
                )
                .unwrap(),
            )
        };
        let zero = [0u8];
        let big_image = vec![0xAAu8; 256]; // poison everything it covers
        let mut small_image = vec![0u8; 16];
        small_image[8] = 0x55;
        let tiny_image = vec![0u8; 4];

        let p_high = probe_program(200); // beyond the small jobs' images
        let p_low = probe_program(8);

        let jobs = [
            // 1: big, poisoned base image — seeds the allocation with 0xAA
            Job {
                program: Arc::clone(&p_high),
                dm_size: 256,
                base_image: Some(&big_image),
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            },
            // 2: small + short base image; probe dm[8] sees ITS image byte
            Job {
                program: Arc::clone(&p_low),
                dm_size: 64,
                base_image: Some(&small_image),
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            },
            // 3: small, NO base image (recycle path); dm[8] must be 0,
            // not small_image's 0x55 or the big job's 0xAA
            Job {
                program: Arc::clone(&p_low),
                dm_size: 64,
                base_image: None,
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            },
            // 4: big again with a short, all-zero base image; dm[200]
            // (covered by nothing since job 1) must be 0, not 0xAA
            Job {
                program: Arc::clone(&p_high),
                dm_size: 256,
                base_image: Some(&tiny_image),
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            },
        ];
        let want = [0xAAu8 as i8 as i32, 0x55, 0, 0];

        // Pooled machine must match both the expectation and a fresh
        // machine per job.
        let mut pool: Option<Machine> = None;
        for (i, job) in jobs.iter().enumerate() {
            let fresh = run_job(job).unwrap();
            let pooled = run_job_pooled(&mut pool, job).unwrap();
            assert_eq!(
                pooled.output,
                vec![want[i]],
                "job {i}: pooled machine leaked prior-job bytes"
            );
            assert_eq!(pooled, fresh, "job {i}: pooled != fresh");
        }
    }

    #[test]
    fn threads_override_parses_only_positive_integers() {
        assert_eq!(threads_override(Some("3")), Some(3));
        assert_eq!(threads_override(Some(" 12 ")), Some(12));
        for bad in [None, Some(""), Some("0"), Some("-1"), Some("two"),
                    Some("3.5")]
        {
            assert_eq!(threads_override(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn marvel_threads_env_overrides_default() {
        // A positive override wins; clearing it restores auto (≥ 1).
        // The value 3 is harmless to any concurrently-running test: the
        // engine contract makes results identical for every worker count.
        std::env::set_var("MARVEL_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::set_var("MARVEL_THREADS", "not a number");
        assert!(default_threads() >= 1);
        std::env::remove_var("MARVEL_THREADS");
        assert!(default_threads() >= 1);
    }

    #[test]
    fn lanes_override_parses_and_clamps() {
        assert_eq!(lanes_override(Some("4")), Some(4));
        assert_eq!(lanes_override(Some(" 2 ")), Some(2));
        assert_eq!(lanes_override(Some("1")), Some(1));
        // clamped to the widest monomorphized group
        assert_eq!(lanes_override(Some("64")), Some(MAX_LANES));
        for bad in [None, Some(""), Some("0"), Some("-1"), Some("four")] {
            assert_eq!(lanes_override(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn superops_override_parses_booleans_case_insensitively() {
        for on in ["1", "true", "on", "yes", " TRUE ", "On"] {
            assert_eq!(superops_override(Some(on)), Some(true), "{on:?}");
        }
        for off in ["0", "false", "off", "no", " OFF "] {
            assert_eq!(superops_override(Some(off)), Some(false), "{off:?}");
        }
        for bad in [None, Some(""), Some("2"), Some("enabled"), Some("y")] {
            assert_eq!(superops_override(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn marvel_superops_env_overrides_default() {
        // Superops selection is bit-identical either way, so flipping the
        // variable is harmless to concurrently-running tests.
        std::env::set_var("MARVEL_SUPEROPS", "on");
        assert!(default_superops());
        std::env::set_var("MARVEL_SUPEROPS", "0");
        assert!(!default_superops());
        // Rejected values fall back to off (and warn once to stderr).
        std::env::set_var("MARVEL_SUPEROPS", "maybe");
        assert!(!default_superops());
        std::env::remove_var("MARVEL_SUPEROPS");
        assert!(!default_superops());
    }

    #[test]
    fn rejected_env_values_warn_once_then_fall_back() {
        // The warn path must not disturb the parsed result: a garbage
        // value behaves exactly like unset, for every variable.
        std::env::set_var("MARVEL_LANES", "eight");
        assert_eq!(default_lanes(), MAX_LANES);
        assert_eq!(default_lanes(), MAX_LANES); // second read: Once already fired
        std::env::remove_var("MARVEL_LANES");
        assert_eq!(default_lanes(), MAX_LANES);
    }

    #[test]
    fn lane_stats_accumulate_and_reset() {
        // Concurrent tests may also record packs; assert on deltas and
        // monotonicity, not absolute totals.
        let before = lane_stats::snapshot();
        lane_stats::record_pack(6, 8);
        lane_stats::record_pack(8, 8);
        let after = lane_stats::snapshot();
        assert!(after.packs_formed >= before.packs_formed + 2);
        assert!(after.lanes_filled >= before.lanes_filled + 14);
        assert!(after.lane_slots >= before.lane_slots + 16);
        let occ = after.lane_occupancy();
        assert!((0.0..=1.0).contains(&occ), "{occ}");
        let drained = lane_stats::reset();
        assert!(drained.packs_formed >= 2);
        assert_eq!(lane_stats::LaneStats::default().lane_occupancy(), 0.0);
    }

    #[test]
    fn lane_pack_matches_scalar_per_job() {
        // Same program, per-job inputs — the shape the engine packs.  Every
        // pack size from below one group to above the widest one, plus a
        // mid-pack setup error, must reproduce the scalar path exactly.
        let p = add_k_program(10);
        let inputs: Vec<[u8; 1]> = (0..13u8).map(|x| [x]).collect();
        for pack in [1usize, 2, 5, 8, 13] {
            let mut jobs = jobs_for(&p, &inputs[..pack]);
            if pack >= 5 {
                jobs[3].input.0 = 1 << 20; // setup fault mid-pack
            }
            let mut pool: Vec<Machine> = Vec::new();
            let packed = run_lane_pack(&mut pool, &jobs);
            assert_eq!(packed.len(), jobs.len());
            for (i, (job, got)) in jobs.iter().zip(&packed).enumerate() {
                let want = run_job(job);
                assert_eq!(
                    format!("{got:?}"),
                    format!("{want:?}"),
                    "pack={pack} job={i}"
                );
            }
        }
    }

    #[test]
    fn lane_pack_falls_back_on_mixed_programs() {
        // A heterogeneous pack can't take the lane path; the scalar
        // fallback inside run_lane_pack must still produce per-job-correct
        // results in submission order.
        let p1 = add_k_program(3);
        let p2 = add_k_program(9);
        let inputs: Vec<[u8; 1]> = (0..6u8).map(|x| [x]).collect();
        let jobs: Vec<Job<'_>> = inputs
            .iter()
            .enumerate()
            .map(|(i, x)| Job {
                program: Arc::clone(if i % 2 == 0 { &p1 } else { &p2 }),
                dm_size: 64,
                base_image: None,
                preload: Vec::new(),
                input: (0, &x[..]),
                output: (4, 1),
                max_instrs: 100,
            })
            .collect();
        let mut pool: Vec<Machine> = Vec::new();
        for (i, r) in run_lane_pack(&mut pool, &jobs).into_iter().enumerate() {
            let k = if i % 2 == 0 { 3 } else { 9 };
            assert_eq!(r.unwrap().output, vec![i as i32 + k]);
        }
    }

    #[test]
    fn lane_pack_reuses_its_pool() {
        let p = add_k_program(1);
        let inputs: Vec<[u8; 1]> = (0..4u8).map(|x| [x]).collect();
        let jobs = jobs_for(&p, &inputs);
        let mut pool: Vec<Machine> = Vec::new();
        run_lane_pack(&mut pool, &jobs);
        assert_eq!(pool.len(), 4);
        run_lane_pack(&mut pool, &jobs);
        assert_eq!(pool.len(), 4, "second pack reuses the pooled machines");
    }

    #[test]
    fn worker_panic_propagates() {
        // dm_size = usize::MAX makes the DM Vec resize panic with
        // "capacity overflow" (an unwinding panic, before any allocation
        // is attempted) inside the worker — a bug class, not a SimError.
        // run_batch must re-raise it, not die on a missing-slot expect.
        let p = add_k_program(1);
        let zero = [0u8];
        let mk = |dm_size: usize| Job {
            program: Arc::clone(&p),
            dm_size,
            base_image: None,
            preload: Vec::new(),
            input: (0, &zero[..]),
            output: (4, 1),
            max_instrs: 100,
        };
        let jobs = vec![mk(64), mk(usize::MAX), mk(64)];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_batch(&jobs, 2)
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
    }

    #[test]
    fn zol_program_shared_across_threads() {
        // dlpi 5 over addi body — exercises the v4 path under threading
        let p = Arc::new(
            Program::from_instrs(
                V4,
                vec![
                    Instr::Dlpi { count: 5, body_len: 1 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 2 },
                    Instr::Store {
                        op: crate::isa::StoreOp::Sb,
                        rs2: 1,
                        rs1: 0,
                        offset: 4,
                    },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        );
        let zero = [0u8];
        let jobs: Vec<Job<'_>> = (0..8)
            .map(|_| Job {
                program: Arc::clone(&p),
                dm_size: 64,
                base_image: None,
                preload: Vec::new(),
                input: (0, &zero[..]),
                output: (4, 1),
                max_instrs: 100,
            })
            .collect();
        for r in run_batch(&jobs, 3) {
            assert_eq!(r.unwrap().output, vec![10]);
        }
    }
}
