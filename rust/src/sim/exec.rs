//! One `Executor` API over every execution substrate (DESIGN.md §13).
//!
//! The repo grew three ways to run a batch of inferences — the in-process
//! thread engine ([`super::engine::run_batch`]), the process-sharded pool
//! ([`super::shard::ShardPool`]) and the serving front's private batcher —
//! each with its own job type and caller glue.  This module is the seam
//! that collapses them: a [`JobSpec`] is the one canonical description of
//! a simulation job, an [`Executor`] is anything that can run a batch of
//! them with the engine's determinism contract, and every sweep-style
//! caller (`run_flows`, `report`, `shard-sweep`, `marvel serve`, the
//! benches) is written against the trait.  Future substrates — a socket
//! transport, multi-host sweeps — implement `Executor` instead of adding a
//! fourth copy of the dispatch plumbing.
//!
//! **Contract** (inherited from DESIGN.md §3/§12, asserted by
//! `tests/exec_conformance.rs` against every backend):
//!
//! - `run()` returns one result per submitted job, in submission order.
//! - Results (logits *and* `RunStats`) are byte-identical across backends
//!   and across repeated runs — execution substrate changes wall-clock,
//!   never bytes.
//! - A per-job failure ([`SimError`]) stays at its index; a *poison* job —
//!   one that panics a worker thread or keeps killing worker processes —
//!   propagates as a panic on the caller.
//!
//! **Backends**:
//!
//! - [`LocalExec`] — a persistent in-process worker pool.  Unlike
//!   `run_batch`, which spawns scoped threads per call, the pool's threads
//!   (and their recycled [`Machine`]s) live for the executor's lifetime,
//!   so a sweep of many small batches pays thread spawn/join once.  It
//!   even survives a poison batch: the panic is re-raised on the caller,
//!   but the workers stay up for the next `run`.
//! - [`ShardExec`] — [`ShardPool`] behind the trait: jobs travel as wire
//!   descriptions and workers hydrate from their own compile caches.  A
//!   dead worker process is relaunched in place up to
//!   [`super::shard::RESPAWN_ATTEMPTS`] times before its slot is retired
//!   and its jobs fall back to survivors.
//! - [`ClusterExec`] ([`super::cluster`]) — the same wire over TCP
//!   sockets: a pool of `marvel cluster-worker` daemons (remote hosts,
//!   or auto-spawned loopback children for `cluster:N`), with re-dial
//!   budgets in place of respawn budgets.
//!
//! Backends are selected everywhere by one spec string, parsed in one
//! place ([`BackendSpec::parse`]): `local[:T]`, `shard:N`, or
//! `cluster:N | cluster:<addr>,… | cluster:@<file>`.

use std::any::Any;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::cluster::ClusterExec;
use super::cpu::{Machine, SimError};
use super::engine::{default_lanes, default_threads, run_lane_pack, Job,
                    JobOutput, Slots};
use super::program::Program;
use super::shard::{self, Hydrator, JobDesc, ShardPool, WorkerCmd};
use crate::compiler::Compiled;

// ---------------------------------------------------------------------------
// The canonical job
// ---------------------------------------------------------------------------

/// A pre-compiled execution unit: what a [`Work::Named`] job hydrates to.
#[derive(Clone)]
pub struct Hydrated {
    pub compiled: Arc<Compiled>,
    /// Logit count read back after a successful run.
    pub out_elems: usize,
}

/// A raw memory-image job — the owned twin of the engine's borrowed
/// [`Job`], for callers below the compiler (hand-built programs, the
/// engine benches, poison-job tests).  Raw jobs cannot travel a wire:
/// backends with the [`Caps::cross_process`] capability refuse them with a
/// per-job [`SimError::Remote`] instead of shipping program bytes.
#[derive(Clone)]
pub struct RawJob {
    pub program: Arc<Program>,
    pub dm_size: usize,
    /// Optional full base DM image (shorter images are zero-padded).
    pub base_image: Option<Vec<u8>>,
    /// Blocks written into DM after `base_image`.
    pub preload: Vec<(u32, Vec<u8>)>,
    /// Per-run input block, written after `preload`.
    pub input: (u32, Vec<u8>),
    /// `(addr, n)`: read back `n` int8 values (widened to i32).
    pub output: (u32, usize),
    /// Watchdog budget.
    pub max_instrs: u64,
}

/// How a [`JobSpec`] describes its work.
#[derive(Clone)]
pub enum Work {
    /// By reference — the wire form ([`JobDesc`]: model/variant names,
    /// input image, watchdog budget, compilation fingerprints).  `hydrated`
    /// optionally carries the submitter's own compilation so in-process
    /// backends skip re-resolution; without it, hydration happens lazily
    /// in whichever process executes the job (local backends hydrate from
    /// their own [`Hydrator`] and cross-check the fingerprints, exactly
    /// like a shard worker).
    Named {
        desc: JobDesc,
        hydrated: Option<Hydrated>,
    },
    /// A raw memory-image job (in-process backends only).
    Raw(RawJob),
}

/// One simulation job, in the one form every [`Executor`] accepts — this
/// subsumes the old `Job` (as [`Work::Raw`]) / `JobDesc` (as
/// [`Work::Named`]) duality.
#[derive(Clone)]
pub struct JobSpec {
    pub work: Work,
}

impl JobSpec {
    /// A by-reference job, hydrated lazily by the executing process.
    pub fn named(desc: JobDesc) -> JobSpec {
        JobSpec { work: Work::Named { desc, hydrated: None } }
    }

    /// A by-reference job carrying the submitter's compilation (`c`,
    /// reading `out_elems` logits) so in-process backends run it without
    /// re-resolving the model.  The description's fingerprints are derived
    /// from `c`, so a cross-process backend whose worker hydration
    /// diverges still fails loudly.
    pub fn hydrated(
        model: &str,
        c: &Arc<Compiled>,
        out_elems: usize,
        input: &[u8],
        max_instrs: u64,
    ) -> JobSpec {
        JobSpec {
            work: Work::Named {
                desc: shard::desc_for(model, c, input, max_instrs),
                hydrated: Some(Hydrated {
                    compiled: Arc::clone(c),
                    out_elems,
                }),
            },
        }
    }

    /// A raw memory-image job (in-process backends only).
    pub fn raw(job: RawJob) -> JobSpec {
        JobSpec { work: Work::Raw(job) }
    }
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// What an execution backend can do — callers branch on capabilities, not
/// on concrete backend types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Caps {
    /// Worker state (pooled machines, hydration/compile caches) survives
    /// across `run` calls, so later batches reuse earlier warm-up.
    pub persistent_pool: bool,
    /// Jobs execute in other processes: only [`Work::Named`] jobs are
    /// accepted ([`Work::Raw`] yields a per-job error), and lazy hydration
    /// happens remotely against the worker's own compile cache.
    pub cross_process: bool,
    /// How many jobs the backend can hold in flight concurrently — the
    /// batch-size hint the serving scheduler sizes its batches and window
    /// to (DESIGN.md §14).  Worker threads for [`LocalExec`]; worker
    /// processes × pipeline depth for [`ShardExec`].  Always ≥ 1.
    pub parallelism: usize,
    /// Width of the same-program lane packs the backend forms inside a
    /// batch (multi-lane lowered execution, DESIGN.md §15).  `1` means
    /// every job runs scalar — packing never changes results, only
    /// wall-clock, so this is purely observability.  Always ≥ 1.
    pub lanes: usize,
}

/// A batch execution backend with the engine's determinism contract (see
/// the module docs).  `submit` enqueues; `run` executes everything
/// enqueued since the last `run` and returns results in submission order.
pub trait Executor: Send {
    /// Capability flags for this backend.
    fn caps(&self) -> Caps;

    /// The backend spec string this executor answers to (e.g. `local:8`,
    /// `shard:2`) — for logs and report titles.
    fn describe(&self) -> String;

    /// Enqueue one job; returns its index in the next `run`'s results.
    fn submit(&mut self, job: JobSpec) -> usize;

    /// Execute the queued batch.  `results[i]` corresponds to the job
    /// whose `submit` returned `i`; the queue is left empty.  Panics only
    /// on a poison job (worker panic / repeated worker death), mirroring
    /// `run_batch`.
    fn run(&mut self) -> Vec<Result<JobOutput, SimError>>;
}

// ---------------------------------------------------------------------------
// Backend spec: one grammar, parsed in one place
// ---------------------------------------------------------------------------

/// A parsed `--backend` value: `local[:T]` (in-process pool, `T` worker
/// threads, 0/omitted = one per core via [`default_threads`]),
/// `shard:N` (`N` worker processes), or a cluster form —
/// `cluster:N` (N loopback daemons spawned on ephemeral ports),
/// `cluster:<addr>,<addr>,…` (externally started daemons), or
/// `cluster:@<file>` (a discovery file, one address per line, `#`
/// comments and blanks skipped — resolved to its addresses at parse
/// time, so `Display` round-trips through the address list).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    Local { threads: usize },
    Shard { workers: usize },
    Cluster(ClusterTarget),
}

/// What a `cluster:` spec names (see [`BackendSpec`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClusterTarget {
    /// Spawn `hosts` loopback `cluster-worker` daemons of this binary.
    Loopback { hosts: usize },
    /// Dial externally started daemons at these addresses.
    Addrs(Vec<String>),
}

impl BackendSpec {
    /// Parse a backend spec string.  Grammar: `local`, `local:T`,
    /// `shard:N` (`N ≥ 1`), `cluster:N`, `cluster:<addr>,…`,
    /// `cluster:@<file>`.
    pub fn parse(s: &str) -> Result<BackendSpec> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        match kind {
            "local" => {
                let threads = match arg {
                    None => 0,
                    Some(a) => a.parse().with_context(|| {
                        format!("bad thread count in backend {s:?}")
                    })?,
                };
                Ok(BackendSpec::Local { threads })
            }
            "shard" => {
                let workers: usize = arg
                    .with_context(|| {
                        format!(
                            "backend {s:?} needs a worker count (shard:N)"
                        )
                    })?
                    .parse()
                    .with_context(|| {
                        format!("bad worker count in backend {s:?}")
                    })?;
                ensure!(workers > 0, "backend {s:?}: shard needs ≥ 1 worker");
                Ok(BackendSpec::Shard { workers })
            }
            "cluster" => {
                let a = arg.with_context(|| {
                    format!(
                        "backend {s:?} needs hosts (cluster:N, \
                         cluster:<addr>,…, or cluster:@<file>)"
                    )
                })?;
                if let Some(path) = a.strip_prefix('@') {
                    let text = std::fs::read_to_string(path).with_context(
                        || format!("reading cluster discovery file {path}"),
                    )?;
                    let addrs: Vec<String> = text
                        .lines()
                        .map(str::trim)
                        .filter(|l| !l.is_empty() && !l.starts_with('#'))
                        .map(String::from)
                        .collect();
                    ensure!(
                        !addrs.is_empty(),
                        "cluster discovery file {path} lists no addresses"
                    );
                    Ok(BackendSpec::Cluster(ClusterTarget::Addrs(addrs)))
                } else if a.bytes().all(|c| c.is_ascii_digit()) && !a.is_empty()
                {
                    let hosts: usize = a.parse().with_context(|| {
                        format!("bad host count in backend {s:?}")
                    })?;
                    ensure!(
                        hosts > 0,
                        "backend {s:?}: cluster needs ≥ 1 host"
                    );
                    Ok(BackendSpec::Cluster(ClusterTarget::Loopback { hosts }))
                } else {
                    let addrs: Vec<String> = a
                        .split(',')
                        .map(str::trim)
                        .filter(|x| !x.is_empty())
                        .map(String::from)
                        .collect();
                    ensure!(
                        !addrs.is_empty(),
                        "backend {s:?} lists no cluster addresses"
                    );
                    Ok(BackendSpec::Cluster(ClusterTarget::Addrs(addrs)))
                }
            }
            other => bail!(
                "unknown backend {other:?} (expected local[:T], shard:N, or \
                 cluster:N | cluster:<addr>,… | cluster:@<file>)"
            ),
        }
    }

    /// Build the executor this spec names.  `artifacts` seeds lazy
    /// hydration (and, for `shard:N` / `cluster:N`, the worker command
    /// line).
    pub fn build(&self, artifacts: &Path) -> Result<Box<dyn Executor>> {
        Ok(match self {
            BackendSpec::Local { threads } => {
                Box::new(LocalExec::new(artifacts, *threads))
            }
            BackendSpec::Shard { workers } => {
                Box::new(ShardExec::spawn(artifacts, *workers)?)
            }
            BackendSpec::Cluster(ClusterTarget::Loopback { hosts }) => {
                Box::new(ClusterExec::spawn_loopback(artifacts, *hosts)?)
            }
            BackendSpec::Cluster(ClusterTarget::Addrs(addrs)) => {
                Box::new(ClusterExec::connect(addrs)?)
            }
        })
    }
}

impl std::fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendSpec::Local { threads: 0 } => write!(f, "local"),
            BackendSpec::Local { threads } => write!(f, "local:{threads}"),
            BackendSpec::Shard { workers } => write!(f, "shard:{workers}"),
            BackendSpec::Cluster(ClusterTarget::Loopback { hosts }) => {
                write!(f, "cluster:{hosts}")
            }
            BackendSpec::Cluster(ClusterTarget::Addrs(addrs)) => {
                write!(f, "cluster:{}", addrs.join(","))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// LocalExec: the persistent in-process worker pool
// ---------------------------------------------------------------------------

/// A hydrated, owned job — what the pool workers actually execute.
enum ReadyJob {
    Unit {
        compiled: Arc<Compiled>,
        out_elems: usize,
        input: Vec<u8>,
        max_instrs: u64,
    },
    Raw(RawJob),
}

impl ReadyJob {
    /// The engine [`Job`] this denotes (borrowing our owned buffers).
    fn as_job(&self) -> Job<'_> {
        match self {
            ReadyJob::Unit { compiled, out_elems, input, max_instrs } => {
                shard::job_of(compiled, *out_elems, input, *max_instrs)
            }
            ReadyJob::Raw(r) => Job {
                program: Arc::clone(&r.program),
                dm_size: r.dm_size,
                base_image: r.base_image.as_deref(),
                preload: r
                    .preload
                    .iter()
                    .map(|(addr, block)| (*addr, block.as_slice()))
                    .collect(),
                input: (r.input.0, r.input.1.as_slice()),
                output: r.output,
                max_instrs: r.max_instrs,
            },
        }
    }
}

/// One in-flight batch, shared with every pool worker.  Hydration
/// failures occupy their slot as `Err` and never enter a pack, mirroring
/// `run_descs_local`.
struct Batch {
    jobs: Vec<Result<ReadyJob, String>>,
    /// Same-program lane packs over `jobs` (job indices, submission order
    /// inside each pack); the unit of work a worker claims.  Every
    /// hydrated job appears in exactly one pack.
    packs: Vec<Vec<usize>>,
    /// Work-stealing cursor over `packs` (same discipline as `run_batch`).
    next: AtomicUsize,
    /// Raised on a worker panic so siblings quit claiming packs.
    stop: AtomicBool,
    slots: Slots<Result<JobOutput, SimError>>,
    /// First worker-panic payload, re-raised on the caller.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Group a batch's hydrated jobs into same-program lane packs of at most
/// `width` jobs, preserving submission order *inside* each pack and
/// first-seen order across packs.  Jobs are keyed by program identity —
/// the `Arc<Compiled>` for named jobs (every job of one compilation shares
/// one program `Arc` through `shard::job_of`), the program `Arc` itself
/// for raw jobs — so a mixed sweep whose submission order interleaves
/// models round-robin still packs each model's jobs together instead of
/// degenerating to scalar.  Result slots are written per job index, so
/// packing never reorders results.
fn make_packs(jobs: &[Result<ReadyJob, String>], width: usize) -> Vec<Vec<usize>> {
    let width = width.max(1);
    let mut packs: Vec<Vec<usize>> = Vec::new();
    // program-identity key -> the index in `packs` of its open pack
    let mut open: HashMap<usize, usize> = HashMap::new();
    for (i, job) in jobs.iter().enumerate() {
        let Ok(ready) = job else { continue };
        let key = match ready {
            ReadyJob::Unit { compiled, .. } => Arc::as_ptr(compiled) as usize,
            ReadyJob::Raw(r) => Arc::as_ptr(&r.program) as usize,
        };
        match open.get(&key) {
            Some(&p) if packs[p].len() < width => packs[p].push(i),
            _ => {
                packs.push(vec![i]);
                open.insert(key, packs.len() - 1);
            }
        }
    }
    // Lane-packing observability (DESIGN.md §19): every pack formed at a
    // multi-lane width counts against that width, so under-filled tails
    // and fragmented same-program runs show up as lost occupancy.  Scalar
    // mode (width 1) records nothing — there are no lanes to fill.
    if width > 1 {
        for p in &packs {
            super::engine::lane_stats::record_pack(p.len(), width);
        }
    }
    packs
}

/// The body of one persistent pool worker: drain each batch's pack
/// cursor, recycling a pool of [`Machine`]s (one per lane) across every
/// pack of every batch.  A panicking pack is *captured* (not re-thrown):
/// the payload parks in the batch for the caller to re-raise, and the
/// worker survives for the next batch — only its possibly-corrupt pooled
/// machines are discarded.
fn pool_worker(rx: mpsc::Receiver<Arc<Batch>>, done: mpsc::Sender<()>) {
    let mut pool: Vec<Machine> = Vec::new();
    for batch in rx {
        loop {
            if batch.stop.load(Ordering::Relaxed) {
                break;
            }
            let pi = batch.next.fetch_add(1, Ordering::Relaxed);
            if pi >= batch.packs.len() {
                break;
            }
            let pack = &batch.packs[pi];
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || {
                    let jobs: Vec<Job<'_>> = pack
                        .iter()
                        .map(|&i| match &batch.jobs[i] {
                            Ok(ready) => ready.as_job(),
                            Err(_) => unreachable!(
                                "packs hold only hydrated jobs"
                            ),
                        })
                        .collect();
                    run_lane_pack(&mut pool, &jobs)
                },
            ));
            match r {
                Ok(results) => {
                    for (&i, res) in pack.iter().zip(results) {
                        // SAFETY: the cursor handed pack `pi` — and with it
                        // every job index it holds — to this worker alone.
                        unsafe { batch.slots.write(i, res) }
                    }
                }
                Err(p) => {
                    batch.stop.store(true, Ordering::Relaxed);
                    let mut first = batch.panic.lock().unwrap();
                    if first.is_none() {
                        *first = Some(p);
                    }
                    drop(first);
                    // The machines may hold arbitrary mid-panic state;
                    // rebuild instead of recycling them.
                    pool = Vec::new();
                }
            }
        }
        if done.send(()).is_err() {
            return;
        }
    }
}

/// The in-process backend: a pool of worker threads that persists across
/// `run` calls (created once, joined when the executor drops), each
/// recycling one [`Machine`] — the engine's pooling contract without the
/// per-batch thread spawn/join of [`super::engine::run_batch`].
///
/// [`Work::Named`] jobs submitted without a [`Hydrated`] unit are
/// hydrated lazily on the calling thread from this executor's own
/// [`Hydrator`] (rooted at `artifacts`), with the description's
/// fingerprints cross-checked; hydration failures stay at their index as
/// [`SimError::Remote`].
pub struct LocalExec {
    threads: usize,
    /// Same-program lane-pack width ([`super::engine::MAX_LANES`] by
    /// default, `MARVEL_LANES` override honored; `1` = scalar).
    lanes: usize,
    hyd: Hydrator,
    queue: Vec<JobSpec>,
    /// One channel per worker; dropping them shuts the pool down.
    txs: Vec<mpsc::Sender<Arc<Batch>>>,
    /// One token per worker per batch.
    done_rx: mpsc::Receiver<()>,
}

impl LocalExec {
    /// Spawn a pool of `threads` workers (`0` = one per core, honoring
    /// the `MARVEL_THREADS` override — see [`default_threads`]).
    pub fn new(artifacts: &Path, threads: usize) -> LocalExec {
        let threads = if threads == 0 { default_threads() } else { threads };
        let (done_tx, done_rx) = mpsc::channel();
        let txs = (0..threads)
            .map(|_| {
                let (tx, rx) = mpsc::channel::<Arc<Batch>>();
                let done = done_tx.clone();
                std::thread::Builder::new()
                    .name("marvel-local-exec".into())
                    .spawn(move || pool_worker(rx, done))
                    .expect("spawn local exec worker");
                tx
            })
            .collect();
        LocalExec {
            threads,
            lanes: default_lanes(),
            hyd: Hydrator::new(artifacts),
            queue: Vec::new(),
            txs,
            done_rx,
        }
    }

    /// Override the lane-pack width (tests / benches; normal callers take
    /// the `MARVEL_LANES`-aware default).  `1` disables packing.  Values
    /// above [`super::engine::MAX_LANES`] are fine — `run_lane_group`
    /// chunks a wide pack into its monomorphized widths.
    pub fn set_lanes(&mut self, lanes: usize) {
        self.lanes = lanes.max(1);
    }

    /// Resolve one spec to an executable job (or its per-job error).
    fn ready(&mut self, spec: JobSpec) -> Result<ReadyJob, String> {
        match spec.work {
            Work::Raw(raw) => Ok(ReadyJob::Raw(raw)),
            Work::Named { desc, hydrated: Some(h) } => Ok(ReadyJob::Unit {
                compiled: h.compiled,
                out_elems: h.out_elems,
                input: desc.input,
                max_instrs: desc.max_instrs,
            }),
            Work::Named { desc, hydrated: None } => {
                let (compiled, out_elems) = self
                    .hyd
                    .hydrate(&desc.model, &desc.variant)
                    .map_err(|e| format!("{e:#}"))?;
                shard::check_fingerprints(&desc, &compiled)
                    .map_err(|e| format!("{e:#}"))?;
                Ok(ReadyJob::Unit {
                    compiled,
                    out_elems,
                    input: desc.input,
                    max_instrs: desc.max_instrs,
                })
            }
        }
    }
}

impl Executor for LocalExec {
    fn caps(&self) -> Caps {
        Caps {
            persistent_pool: true,
            cross_process: false,
            parallelism: self.threads.max(1),
            lanes: self.lanes.max(1),
        }
    }

    fn describe(&self) -> String {
        format!("local:{}", self.threads)
    }

    fn submit(&mut self, job: JobSpec) -> usize {
        self.queue.push(job);
        self.queue.len() - 1
    }

    fn run(&mut self) -> Vec<Result<JobOutput, SimError>> {
        let specs = std::mem::take(&mut self.queue);
        if specs.is_empty() {
            return Vec::new();
        }
        let jobs: Vec<Result<ReadyJob, String>> =
            specs.into_iter().map(|s| self.ready(s)).collect();
        let n = jobs.len();
        let packs = make_packs(&jobs, self.lanes);
        let batch = Arc::new(Batch {
            jobs,
            packs,
            next: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            slots: Slots::new(n),
            panic: Mutex::new(None),
        });
        for tx in &self.txs {
            tx.send(Arc::clone(&batch)).expect("local exec worker died");
        }
        for _ in &self.txs {
            self.done_rx.recv().expect("local exec worker died");
        }
        if let Some(p) = batch.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
        batch
            .jobs
            .iter()
            .enumerate()
            .map(|(i, j)| match j {
                Err(msg) => Err(SimError::remote(msg.clone())),
                // SAFETY: every worker has quiesced — the done tokens
                // above synchronize with their slot writes — and slot i
                // was written only by the worker that claimed i.
                Ok(_) => unsafe { batch.slots.take(i) }
                    .expect("worker filled every slot"),
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// ShardExec: the process pool behind the trait
// ---------------------------------------------------------------------------

/// The cross-process backend: a [`ShardPool`] of `marvel shard-worker`
/// processes behind the [`Executor`] trait.  Only the wire half of a
/// [`Work::Named`] job travels (any [`Hydrated`] unit is dropped — the
/// worker hydrates from its own cache and the fingerprints catch
/// divergence); [`Work::Raw`] jobs answer with a capability error at
/// their index.
pub struct ShardExec {
    pool: ShardPool,
    workers: usize,
    queue: Vec<JobSpec>,
}

impl ShardExec {
    /// Spawn `workers` processes of this very binary (`marvel
    /// shard-worker --artifacts …`).
    pub fn spawn(artifacts: &Path, workers: usize) -> Result<ShardExec> {
        let cmd = WorkerCmd::current_exe(artifacts)?;
        Ok(ShardExec::from_pool(ShardPool::spawn(&cmd, workers)?, workers))
    }

    /// Wrap an existing pool (tests use this to inject custom worker
    /// commands).
    pub fn from_pool(pool: ShardPool, workers: usize) -> ShardExec {
        ShardExec { pool, workers, queue: Vec::new() }
    }

    /// The wrapped pool (respawn counters, live-worker count).
    pub fn pool(&self) -> &ShardPool {
        &self.pool
    }
}

impl Executor for ShardExec {
    fn caps(&self) -> Caps {
        Caps {
            persistent_pool: true,
            cross_process: true,
            // Each worker process keeps PIPELINE jobs in flight.
            parallelism: (self.workers * shard::PIPELINE).max(1),
            // Shard workers run jobs scalar as they stream off the wire.
            lanes: 1,
        }
    }

    fn describe(&self) -> String {
        format!("shard:{}", self.workers)
    }

    fn submit(&mut self, job: JobSpec) -> usize {
        self.queue.push(job);
        self.queue.len() - 1
    }

    fn run(&mut self) -> Vec<Result<JobOutput, SimError>> {
        let specs = std::mem::take(&mut self.queue);
        // Compact the dispatchable descriptions; remember, per submitted
        // job, either its desc index or its immediate capability error.
        let mut descs: Vec<JobDesc> = Vec::with_capacity(specs.len());
        let routed: Vec<Result<usize, String>> = specs
            .into_iter()
            .map(|s| match s.work {
                Work::Named { desc, .. } => {
                    descs.push(desc);
                    Ok(descs.len() - 1)
                }
                Work::Raw(_) => Err(
                    "raw memory-image job on a cross-process backend: \
                     raw jobs cannot travel the wire (submit a named job, \
                     or run on a local backend)"
                        .to_string(),
                ),
            })
            .collect();
        let mut ran: Vec<Option<Result<JobOutput, SimError>>> =
            self.pool.run(&descs).into_iter().map(Some).collect();
        routed
            .into_iter()
            .map(|r| match r {
                Ok(i) => ran[i].take().expect("one result per dispatched job"),
                Err(msg) => Err(SimError::remote(msg)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluImmOp, Instr, LoadOp, StoreOp};
    use crate::sim::V0;

    #[test]
    fn backend_spec_grammar() {
        assert_eq!(
            BackendSpec::parse("local").unwrap(),
            BackendSpec::Local { threads: 0 }
        );
        assert_eq!(
            BackendSpec::parse("local:8").unwrap(),
            BackendSpec::Local { threads: 8 }
        );
        assert_eq!(
            BackendSpec::parse("shard:2").unwrap(),
            BackendSpec::Shard { workers: 2 }
        );
        assert_eq!(
            BackendSpec::parse("cluster:2").unwrap(),
            BackendSpec::Cluster(ClusterTarget::Loopback { hosts: 2 })
        );
        assert_eq!(
            BackendSpec::parse("cluster:10.0.0.1:4000, 10.0.0.2:4000")
                .unwrap(),
            BackendSpec::Cluster(ClusterTarget::Addrs(vec![
                "10.0.0.1:4000".into(),
                "10.0.0.2:4000".into(),
            ]))
        );
        for bad in [
            "",
            "local:x",
            "shard",
            "shard:0",
            "shard:x",
            "remote:1",
            "cluster",
            "cluster:0",
            "cluster:,",
            "cluster:@/nonexistent-discovery-file",
        ] {
            assert!(BackendSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Display round-trips through parse.
        for s in ["local", "local:8", "shard:2", "cluster:2",
                  "cluster:10.0.0.1:4000,10.0.0.2:4000"]
        {
            assert_eq!(BackendSpec::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn cluster_discovery_file_parse() {
        let dir = std::env::temp_dir()
            .join(format!("marvel-disco-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hosts.txt");
        std::fs::write(
            &path,
            "# sweep fleet\n10.0.0.1:4000\n\n  10.0.0.2:4000  \n",
        )
        .unwrap();
        let spec =
            BackendSpec::parse(&format!("cluster:@{}", path.display()))
                .unwrap();
        assert_eq!(
            spec,
            BackendSpec::Cluster(ClusterTarget::Addrs(vec![
                "10.0.0.1:4000".into(),
                "10.0.0.2:4000".into(),
            ]))
        );
        // comments-only files name no hosts and must be refused
        std::fs::write(&path, "# nothing here\n\n").unwrap();
        assert!(BackendSpec::parse(&format!("cluster:@{}", path.display()))
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// load x1 <- dm[0]; x1 += k; store dm[4] <- x1; ecall
    fn add_k_program(k: i32) -> Arc<Program> {
        Arc::new(
            Program::from_instrs(
                V0,
                vec![
                    Instr::Load { op: LoadOp::Lb, rd: 1, rs1: 0, offset: 0 },
                    Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: k },
                    Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 4 },
                    Instr::Ecall,
                ],
            )
            .unwrap(),
        )
    }

    fn raw_job(p: &Arc<Program>, x: u8, dm_size: usize) -> RawJob {
        RawJob {
            program: Arc::clone(p),
            dm_size,
            base_image: None,
            preload: Vec::new(),
            input: (0, vec![x]),
            output: (4, 1),
            max_instrs: 100,
        }
    }

    #[test]
    fn local_exec_runs_raw_jobs_in_submission_order() {
        let p = add_k_program(10);
        let mut exec = LocalExec::new(Path::new("artifacts"), 3);
        assert_eq!(
            exec.caps(),
            Caps {
                persistent_pool: true,
                cross_process: false,
                parallelism: 3,
                lanes: exec.caps().lanes, // MARVEL_LANES-dependent, ≥ 1
            }
        );
        assert!(exec.caps().lanes >= 1);
        assert_eq!(exec.describe(), "local:3");
        for x in 0..20u8 {
            assert_eq!(exec.submit(JobSpec::raw(raw_job(&p, x, 64))), x as usize);
        }
        let rs = exec.run();
        assert_eq!(rs.len(), 20);
        for (i, r) in rs.iter().enumerate() {
            let out = r.as_ref().unwrap();
            assert_eq!(out.output, vec![i as i32 + 10]);
            assert_eq!(out.stats.instrs, 4);
        }
        // The queue drained; an empty run is an empty result.
        assert!(exec.run().is_empty());
    }

    #[test]
    fn local_exec_errors_stay_at_their_index() {
        let p = add_k_program(1);
        let mut exec = LocalExec::new(Path::new("artifacts"), 2);
        exec.submit(JobSpec::raw(raw_job(&p, 1, 64)));
        // out-of-bounds input write -> Mem fault at index 1
        let mut bad = raw_job(&p, 2, 64);
        bad.input.0 = 1 << 20;
        exec.submit(JobSpec::raw(bad));
        // unknown model -> hydration failure at index 2
        exec.submit(JobSpec::named(JobDesc {
            model: "synth:nope:1".into(),
            variant: "v0".into(),
            input: vec![0],
            max_instrs: 100,
            program_fp: 0,
            base_dm_fp: 0,
        }));
        exec.submit(JobSpec::raw(raw_job(&p, 3, 64)));
        let rs = exec.run();
        assert!(rs[0].is_ok());
        assert!(matches!(rs[1], Err(SimError::Mem { .. })));
        match &rs[2] {
            Err(SimError::Remote { msg, .. }) => {
                assert!(msg.contains("synth:nope"), "{msg}")
            }
            other => panic!("expected hydration error, got {other:?}"),
        }
        assert_eq!(rs[3].as_ref().unwrap().output, vec![4]);
    }

    #[test]
    fn local_exec_poison_panics_and_pool_survives() {
        // dm_size = usize::MAX makes the worker's DM resize panic
        // ("capacity overflow") — a bug class, not a SimError.  The panic
        // must reach the caller, and the pool must stay usable.
        let p = add_k_program(1);
        let mut exec = LocalExec::new(Path::new("artifacts"), 2);
        exec.submit(JobSpec::raw(raw_job(&p, 1, 64)));
        exec.submit(JobSpec::raw(raw_job(&p, 2, usize::MAX)));
        exec.submit(JobSpec::raw(raw_job(&p, 3, 64)));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.run()
        }));
        assert!(r.is_err(), "poison job must panic the caller");
        // The persistent pool survives the poison batch.
        exec.submit(JobSpec::raw(raw_job(&p, 7, 64)));
        let rs = exec.run();
        assert_eq!(rs[0].as_ref().unwrap().output, vec![8]);
    }

    #[test]
    fn packs_group_interleaved_programs_without_reordering_results() {
        // A mixed sweep submits models round-robin: A B A B A B A B.
        // Grouping must pull each program's jobs into shared packs (not
        // degenerate to scalar on every program switch) while results stay
        // at their submission indices.
        let pa = add_k_program(10);
        let pb = add_k_program(20);
        let jobs: Vec<Result<ReadyJob, String>> = (0..8u8)
            .map(|i| {
                let p = if i % 2 == 0 { &pa } else { &pb };
                Ok(ReadyJob::Raw(raw_job(p, i, 64)))
            })
            .collect();
        let packs = make_packs(&jobs, 4);
        assert_eq!(packs, vec![vec![0, 2, 4, 6], vec![1, 3, 5, 7]]);
        // width 1 = scalar: one pack per job, submission order
        let scalar = make_packs(&jobs, 1);
        assert_eq!(scalar.len(), 8);
        assert!(scalar.iter().enumerate().all(|(i, p)| *p == vec![i]));
        // a full pack closes and a fresh one opens for the same program
        let packs2 = make_packs(&jobs, 3);
        assert_eq!(
            packs2,
            vec![vec![0, 2, 4], vec![1, 3, 5], vec![6], vec![7]]
        );
        // hydration failures never enter a pack
        let mut with_err = jobs;
        with_err[2] = Err("boom".into());
        let packs3 = make_packs(&with_err, 4);
        assert_eq!(packs3, vec![vec![0, 4, 6], vec![1, 3, 5, 7]]);

        // End to end: the interleaved batch through LocalExec at pack
        // widths 1/4/8 returns identical, submission-ordered results.
        let run_with = |lanes: usize| -> Vec<JobOutput> {
            let mut exec = LocalExec::new(Path::new("artifacts"), 2);
            exec.set_lanes(lanes);
            for i in 0..8u8 {
                let p = if i % 2 == 0 { &pa } else { &pb };
                exec.submit(JobSpec::raw(raw_job(p, i, 64)));
            }
            exec.run().into_iter().map(|r| r.unwrap()).collect()
        };
        let baseline = run_with(1);
        for (i, out) in baseline.iter().enumerate() {
            let k = if i % 2 == 0 { 10 } else { 20 };
            assert_eq!(out.output, vec![i as i32 + k], "job {i}");
        }
        for lanes in [4, 8] {
            assert_eq!(run_with(lanes), baseline, "lanes={lanes}");
        }
    }

    #[test]
    fn local_exec_results_identical_across_pool_sizes() {
        let p = add_k_program(5);
        let mk_specs = || -> Vec<JobSpec> {
            (0..13u8)
                .map(|x| {
                    JobSpec::raw(raw_job(&p, x, if x % 2 == 0 { 64 } else { 256 }))
                })
                .collect()
        };
        let mut one = LocalExec::new(Path::new("artifacts"), 1);
        for s in mk_specs() {
            one.submit(s);
        }
        let baseline: Vec<_> =
            one.run().into_iter().map(|r| r.unwrap()).collect();
        for threads in [2, 8] {
            let mut exec = LocalExec::new(Path::new("artifacts"), threads);
            for s in mk_specs() {
                exec.submit(s);
            }
            let got: Vec<_> =
                exec.run().into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(got, baseline, "threads={threads}");
        }
    }
}
