//! Retirement observers.
//!
//! The profiler (Fig 3/4), the tracer (the JTAG/OCD substitute) and the
//! per-PC cycle attribution (Fig 5) all watch the retired instruction
//! stream through the [`RetireHook`] trait.  The hot path is generic over
//! the hook so the no-op case ([`NopHook`]) compiles to nothing.

use crate::isa::Instr;

/// Observer invoked once per retired instruction.
pub trait RetireHook {
    /// Statically `false` only for hooks that ignore every retirement
    /// ([`NopHook`]); every interpreter loop (reference, lowered match,
    /// lowered threaded) gates its retire call on this associated const, so
    /// the call — and materializing its arguments (pc, `&Instr` lookup) —
    /// folds away at monomorphization time instead of costing a per-retire
    /// branch.
    ///
    /// `OBSERVES` also gates *lane-group* eligibility (DESIGN.md §15):
    /// multi-lane execution interleaves the retire streams of K machines,
    /// so the engine only packs jobs into lanes for `OBSERVES == false`
    /// hooks; trace/profile runs take the scalar path where the stream
    /// stays per-machine and in order.
    const OBSERVES: bool = true;

    /// `pc` is the address of the retiring instruction; `cycles` the cycles
    /// it spent (data-dependent for branches).
    fn retire(&mut self, pc: u32, instr: &Instr, cycles: u64);
}

/// Zero-cost hook for plain runs.
pub struct NopHook;

impl RetireHook for NopHook {
    const OBSERVES: bool = false;

    #[inline(always)]
    fn retire(&mut self, _pc: u32, _instr: &Instr, _cycles: u64) {}
}

/// Capture a window of the retired stream as text (debug / Fig 5 evidence).
pub struct TraceHook {
    pub lines: Vec<String>,
    pub limit: usize,
}

impl TraceHook {
    pub fn new(limit: usize) -> Self {
        TraceHook { lines: Vec::new(), limit }
    }
}

impl RetireHook for TraceHook {
    fn retire(&mut self, pc: u32, instr: &Instr, cycles: u64) {
        if self.lines.len() < self.limit {
            self.lines.push(format!("{pc:#06x}: {instr}  [{cycles}]"));
        }
    }
}

/// Per-PC cycle/retire attribution (the highlighted columns of Fig 5).
pub struct PcCyclesHook {
    /// Indexed by pc/4.
    pub cycles: Vec<u64>,
    pub retires: Vec<u64>,
}

impl PcCyclesHook {
    pub fn new(program_words: usize) -> Self {
        PcCyclesHook {
            cycles: vec![0; program_words],
            retires: vec![0; program_words],
        }
    }
}

impl RetireHook for PcCyclesHook {
    #[inline]
    fn retire(&mut self, pc: u32, _instr: &Instr, cycles: u64) {
        let idx = (pc / 4) as usize;
        if idx < self.cycles.len() {
            self.cycles[idx] += cycles;
            self.retires[idx] += 1;
        }
    }
}
