//! The lowered micro-op form of a [`Program`] — the execution hot loop's
//! native representation (DESIGN.md §11).
//!
//! [`super::cpu::Machine::run`] used to interpret the decoded [`Instr`]
//! enum directly, recomputing per retired instruction what never changes
//! across a run: the `pc % 4` / `pc >= plen` fetch checks, the `pc/4`
//! index division, the per-class cycle cost lookup in the
//! [`CycleModel`], the branch-offset → target arithmetic, and the
//! zero-overhead-loop `next_pc == ZE` compare even in programs that cannot
//! arm a loop.  Lowering bakes all of that in once, at
//! [`Program::lower`] time:
//!
//! - every instruction becomes a flat, fixed-width [`MicroOp`] (one
//!   dispatch, no nested enum matching);
//! - cycle costs are resolved against the [`CycleModel`] and stored in the
//!   op (branches carry both the taken and not-taken cost);
//! - branch/jump offsets are resolved to direct instruction indices, and
//!   every statically-invalid target (fall-off-the-end, misaligned or
//!   out-of-range branch) points at a dedicated trap op — the straight-line
//!   path therefore needs *no* pc validation at all;
//! - the set of possible ZOL loop-end addresses (`ZE` values of every
//!   `dlp`/`dlpi`/`zlp`) is computed up front and only the ops whose
//!   successor could be a loop end carry the `zmark` flag; unmarked ops
//!   skip the loop-back compare entirely.  A program containing `set.ze`
//!   (arbitrary runtime `ZE`) conservatively marks every op.
//!
//! Execution of the lowered form comes in three shapes (DESIGN.md §15):
//! the **direct-threaded** scalar loop ([`run_lowered`], a per-`Kind`
//! handler-function table dispatched by discriminant), the original
//! central-`match` loop kept as [`run_lowered_match`] (bench baseline +
//! second differential oracle), and **multi-lane** execution
//! ([`run_lanes`]) stepping `K` independent machines of the same program
//! through one fetch/decode stream — software SIMT for the engine's
//! same-program lane packs.
//!
//! Every lowered path is behaviourally **bit-identical** to the reference
//! interpreter ([`super::cpu::Machine::run_reference`]): same
//! [`super::cpu::RunStats`], same outputs, same architectural state after
//! the run, same faults, same retire-hook stream.  The reference path
//! survives as the differential-test oracle (`rust/tests/lowered_diff.rs`)
//! and as the fallback when a program/cycle-model cannot be lowered
//! (costs beyond `u32`, a `ZE` out of `u32` range, or an entry state whose
//! armed `ZE` the static mark set does not cover).

use std::collections::{HashMap, HashSet};

use super::cpu::{Machine, RunStats, SimError};
use super::hooks::{NopHook, RetireHook};
use super::memory::MemFault;
use super::program::Program;
use super::CycleModel;
use crate::isa::{AluImmOp, AluOp, BranchOp, Instr, LoadOp, StoreOp, MAC_RD,
                 MAC_RS1, MAC_RS2};

/// Flat micro-op opcode: one variant per executable form, plus the two
/// trap kinds that materialize statically-known-invalid pc targets.
///
/// `repr(u8)` with default (sequential from 0) discriminants: the
/// discriminant doubles as the index into the direct-threaded handler
/// table ([`HANDLERS`]), and the `lowered::tests::kinds_cover_every_discriminant`
/// test pins the `KINDS` order to the declaration order here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
#[rustfmt::skip]
pub(crate) enum Kind {
    Lui, Auipc, Jal, Jalr,
    Beq, Bne, Blt, Bge, Bltu, Bgeu,
    Lb, Lh, Lw, Lbu, Lhu,
    Sb, Sh, Sw,
    Addi, Slti, Sltiu, Xori, Ori, Andi, Slli, Srli, Srai,
    Add, Sub, Sll, Slt, Sltu, Xor, Srl, Sra, Or, And,
    Mul, Mulh, Mulhsu, Mulhu, Div, Divu, Rem, Remu,
    Fence, Ecall, Ebreak,
    Mac, Add2i, FusedMac, Dlp, Dlpi, Zlp, SetZc, SetZs, SetZe,
    /// Mined window instruction ([`crate::fusion::WINDOW`]): `aux[31:16]`
    /// is the slot index, `aux[15:0]` is `i2`, `imm` is `i1`.
    FusedCustom,
    /// Software superinstruction (DESIGN.md §19): head slot of a fused
    /// straight-line run.  `aux` indexes [`LoweredProgram`]'s superop
    /// table, `imm` carries the run length (diagnostics only), `cost` is
    /// the head constituent's cost and `zmark` the *last* constituent's
    /// mark.  Slots `idx+1 .. idx+len` keep their original micro-ops, so
    /// a branch, `jalr` or ZOL loop-start landing inside the run executes
    /// scalar from that point — fusion never changes reachability.
    Super,
    /// Reaching this slot is `PcOutOfRange { pc: imm }` (static bad target).
    Trap,
    /// Reaching this slot is `PcOutOfRange` at the dynamically-recorded pc
    /// (invalid `jalr` target or invalid ZOL loop-start).
    TrapDyn,
}

/// One lowered instruction: 16 bytes, field meaning per [`Kind`].
///
/// | field | use |
/// |-------|-----|
/// | `a`   | rd (ALU/load/jal/jalr/lui/auipc), rs2 of stores, rs1 of add2i/fusedmac |
/// | `b`   | rs1 (ALU/load/store/jalr/zol), rs2 of add2i/fusedmac |
/// | `zmark` | 1 = run the ZOL loop-back compare after this op |
/// | `imm` | immediate/offset; taken-branch cost; `dlpi` count; trap pc |
/// | `aux` | rs2 of reg-reg ALU; resolved target index (branch/jal); ZE byte address (zol); i2 of add2i/fusedmac |
/// | `cost`| retire cost in cycles (not-taken cost for branches) |
#[derive(Clone, Copy, Debug)]
pub(crate) struct MicroOp {
    pub(crate) kind: Kind,
    pub(crate) a: u8,
    pub(crate) b: u8,
    pub(crate) zmark: u8,
    pub(crate) imm: i32,
    pub(crate) aux: u32,
    pub(crate) cost: u32,
}

/// A [`Program`] lowered against one [`CycleModel`].
///
/// Layout of `ops`: indices `0..n` mirror the program's instructions;
/// `ops[n]` is the shared fall-off-the-end trap, `ops[n+1]` the dynamic
/// trap ([`Kind::TrapDyn`]), and further slots hold one trap per distinct
/// statically-invalid branch/jump target.  Every index stored in an op is
/// `< ops.len()` by construction, so the hot loop never validates a pc.
pub struct LoweredProgram {
    ops: Vec<MicroOp>,
    /// Index of the [`Kind::TrapDyn`] slot.
    dyn_trap: usize,
    /// Program length in bytes (`n * 4`).
    plen_bytes: u32,
    /// Possible ZE byte addresses of the program's hardware loops.
    zset: HashSet<u32>,
    /// `set.ze` present: every op carries the loop-back compare.
    all_marked: bool,
    /// Fused straight-line runs ([`Kind::Super`] heads index this table).
    /// Empty unless lowered with [`LowerOpts::superops`].
    superops: Vec<SuperOp>,
}

/// One fused run of consecutive straight-line micro-ops ([`Kind::Super`],
/// DESIGN.md §19).  The constituents are stored in their *original*
/// lowered form, head first, so the fused handler, the match oracle and
/// the head-only decay path all execute the exact ops the unfused program
/// would.
pub(crate) struct SuperOp {
    /// Constituent micro-ops, head first.  Every constituent is a
    /// [`fusible`] kind (straight-line, `Flow::Next`/`Flow::Mem` only) and
    /// every constituent but the last has `zmark == 0`.
    pub(crate) ops: Vec<MicroOp>,
    /// Summed retire cost of all constituents (costs are static for
    /// straight-line kinds — no branch can hide inside a run).
    pub(crate) cost: u64,
}

/// Lowering knobs ([`Program::lower_with`] / [`Program::lowered_with`]):
/// the superinstruction pipeline's entry point (env `MARVEL_SUPEROPS`,
/// CLI `--superops`; DESIGN.md §19).
#[derive(Clone, Debug, Default)]
pub struct LowerOpts {
    /// Fuse straight-line micro-op runs into [`Kind::Super`] slots.
    pub superops: bool,
    /// Per-instruction retire counts (indexed `pc/4`, e.g.
    /// `profiler::ProfileHook::superop_profile`).  When present, only the
    /// [`SUPEROP_TOPK`] hottest runs fuse; when absent every eligible run
    /// does.
    pub profile: Option<std::sync::Arc<Vec<u64>>>,
}

impl LowerOpts {
    /// The process-default knobs: `superops` from the `MARVEL_SUPEROPS`
    /// environment override, no profile.
    pub fn from_env() -> LowerOpts {
        LowerOpts {
            superops: super::engine::default_superops(),
            profile: None,
        }
    }
}

/// Longest run a single [`Kind::Super`] covers.  Longer straight-line
/// spans fuse as back-to-back superops.
pub(crate) const MAX_FUSE: usize = 8;

/// With a retire profile, only this many of the hottest runs fuse — the
/// mining contract keeps the superop table small and hot (DESIGN.md §19).
pub const SUPEROP_TOPK: usize = 16;

/// Can this micro-op join a fused run?  Straight-line kinds only: the
/// handler returns `Flow::Next` or `Flow::Mem`, never redirects `next`,
/// and never touches the ZOL registers — so a fused run re-enters the
/// dispatch loop exactly where the unfused program would.
fn fusible(op: &MicroOp) -> bool {
    matches!(
        op.kind,
        Kind::Lui
            | Kind::Auipc
            | Kind::Lb
            | Kind::Lh
            | Kind::Lw
            | Kind::Lbu
            | Kind::Lhu
            | Kind::Sb
            | Kind::Sh
            | Kind::Sw
            | Kind::Addi
            | Kind::Slti
            | Kind::Sltiu
            | Kind::Xori
            | Kind::Ori
            | Kind::Andi
            | Kind::Slli
            | Kind::Srli
            | Kind::Srai
            | Kind::Add
            | Kind::Sub
            | Kind::Sll
            | Kind::Slt
            | Kind::Sltu
            | Kind::Xor
            | Kind::Srl
            | Kind::Sra
            | Kind::Or
            | Kind::And
            | Kind::Mul
            | Kind::Mulh
            | Kind::Mulhsu
            | Kind::Mulhu
            | Kind::Div
            | Kind::Divu
            | Kind::Rem
            | Kind::Remu
            | Kind::Fence
            | Kind::Mac
            | Kind::Add2i
            | Kind::FusedMac
            | Kind::FusedCustom
    )
}

/// Per-class costs checked into `u32` at lowering time.
struct Baked {
    alu: u32,
    mul: u32,
    div: u32,
    load: u32,
    store: u32,
    branch_taken: u32,
    branch_not_taken: u32,
    jump: u32,
    custom: u32,
    zol_setup: u32,
}

impl Baked {
    fn of(cm: &CycleModel) -> Option<Baked> {
        Some(Baked {
            alu: u32::try_from(cm.alu).ok()?,
            mul: u32::try_from(cm.mul).ok()?,
            div: u32::try_from(cm.div).ok()?,
            load: u32::try_from(cm.load).ok()?,
            store: u32::try_from(cm.store).ok()?,
            branch_taken: u32::try_from(cm.branch_taken).ok()?,
            branch_not_taken: u32::try_from(cm.branch_not_taken).ok()?,
            jump: u32::try_from(cm.jump).ok()?,
            custom: u32::try_from(cm.custom).ok()?,
            zol_setup: u32::try_from(cm.zol_setup).ok()?,
        })
    }
}

impl LoweredProgram {
    /// Lower `program` against `cm` with default knobs (no superops).
    /// `None` when the program cannot be lowered faithfully (see module
    /// docs) — callers fall back to the reference interpreter.
    pub fn lower(program: &Program, cm: &CycleModel) -> Option<LoweredProgram> {
        Self::lower_with(program, cm, &LowerOpts::default())
    }

    /// Lower `program` against `cm` under explicit [`LowerOpts`] — the
    /// superinstruction pipeline's entry point (DESIGN.md §19).
    pub fn lower_with(
        program: &Program,
        cm: &CycleModel,
        opts: &LowerOpts,
    ) -> Option<LoweredProgram> {
        let baked = Baked::of(cm)?;
        let instrs = program.instrs();
        let n = instrs.len();
        if (n as u64) * 4 > u64::from(u32::MAX) {
            return None;
        }
        let plen_bytes = (n * 4) as u32;

        // Pass 1: the static set of possible ZE values.
        let mut zset: HashSet<u32> = HashSet::new();
        let mut all_marked = false;
        for (i, instr) in instrs.iter().enumerate() {
            match instr {
                Instr::Dlp { body_len, .. }
                | Instr::Dlpi { body_len, .. }
                | Instr::Zlp { body_len, .. } => {
                    let ze = (i as u64) * 4 + 4 + 4 * u64::from(*body_len);
                    zset.insert(u32::try_from(ze).ok()?);
                }
                Instr::SetZe { .. } => all_marked = true,
                _ => {}
            }
        }

        // Pass 2: convert, resolving targets.  Statically-invalid targets
        // get dedicated trap slots appended after ops[n] (fall-off trap)
        // and ops[n+1] (dynamic trap).
        let mut trap_at: HashMap<u32, usize> = HashMap::new();
        let mut extra_traps: Vec<u32> = Vec::new();
        let mut ops: Vec<MicroOp> = Vec::with_capacity(n + 2);
        for (i, instr) in instrs.iter().enumerate() {
            let pc = (i as u32) * 4;
            let fall = pc + 4;
            let mut resolve = |byte: u32| -> usize {
                if byte % 4 == 0 && byte < plen_bytes {
                    (byte / 4) as usize
                } else if byte == plen_bytes {
                    n
                } else {
                    *trap_at.entry(byte).or_insert_with(|| {
                        extra_traps.push(byte);
                        n + 1 + extra_traps.len()
                    })
                }
            };

            let mut op = MicroOp {
                kind: Kind::Fence,
                a: 0,
                b: 0,
                zmark: 0,
                imm: 0,
                aux: 0,
                cost: baked.alu,
            };
            // Statically-possible successor addresses (for ZOL marking);
            // `None` entries are unused, `dynamic` covers jalr.
            let mut nexts: [Option<u32>; 2] = [Some(fall), None];
            let mut dynamic_next = false;

            match *instr {
                Instr::Lui { rd, imm } => {
                    op.kind = Kind::Lui;
                    op.a = rd;
                    op.imm = imm;
                }
                Instr::Auipc { rd, imm } => {
                    op.kind = Kind::Auipc;
                    op.a = rd;
                    op.imm = imm;
                }
                Instr::Jal { rd, offset } => {
                    op.kind = Kind::Jal;
                    op.a = rd;
                    let t = pc.wrapping_add(offset as u32);
                    op.aux = resolve(t) as u32;
                    op.cost = baked.jump;
                    nexts = [Some(t), None];
                }
                Instr::Jalr { rd, rs1, offset } => {
                    op.kind = Kind::Jalr;
                    op.a = rd;
                    op.b = rs1;
                    op.imm = offset;
                    op.cost = baked.jump;
                    nexts = [None, None];
                    dynamic_next = true;
                }
                Instr::Branch { op: bop, rs1, rs2, offset } => {
                    op.kind = match bop {
                        BranchOp::Beq => Kind::Beq,
                        BranchOp::Bne => Kind::Bne,
                        BranchOp::Blt => Kind::Blt,
                        BranchOp::Bge => Kind::Bge,
                        BranchOp::Bltu => Kind::Bltu,
                        BranchOp::Bgeu => Kind::Bgeu,
                    };
                    op.a = rs1;
                    op.b = rs2;
                    let t = pc.wrapping_add(offset as u32);
                    op.aux = resolve(t) as u32;
                    op.imm = baked.branch_taken as i32;
                    op.cost = baked.branch_not_taken;
                    nexts = [Some(fall), Some(t)];
                }
                Instr::Load { op: lop, rd, rs1, offset } => {
                    op.kind = match lop {
                        LoadOp::Lb => Kind::Lb,
                        LoadOp::Lh => Kind::Lh,
                        LoadOp::Lw => Kind::Lw,
                        LoadOp::Lbu => Kind::Lbu,
                        LoadOp::Lhu => Kind::Lhu,
                    };
                    op.a = rd;
                    op.b = rs1;
                    op.imm = offset;
                    op.cost = baked.load;
                }
                Instr::Store { op: sop, rs2, rs1, offset } => {
                    op.kind = match sop {
                        StoreOp::Sb => Kind::Sb,
                        StoreOp::Sh => Kind::Sh,
                        StoreOp::Sw => Kind::Sw,
                    };
                    op.a = rs2;
                    op.b = rs1;
                    op.imm = offset;
                    op.cost = baked.store;
                }
                Instr::OpImm { op: aop, rd, rs1, imm } => {
                    op.kind = match aop {
                        AluImmOp::Addi => Kind::Addi,
                        AluImmOp::Slti => Kind::Slti,
                        AluImmOp::Sltiu => Kind::Sltiu,
                        AluImmOp::Xori => Kind::Xori,
                        AluImmOp::Ori => Kind::Ori,
                        AluImmOp::Andi => Kind::Andi,
                        AluImmOp::Slli => Kind::Slli,
                        AluImmOp::Srli => Kind::Srli,
                        AluImmOp::Srai => Kind::Srai,
                    };
                    op.a = rd;
                    op.b = rs1;
                    op.imm = imm;
                }
                Instr::Op { op: rop, rd, rs1, rs2 } => {
                    op.kind = match rop {
                        AluOp::Add => Kind::Add,
                        AluOp::Sub => Kind::Sub,
                        AluOp::Sll => Kind::Sll,
                        AluOp::Slt => Kind::Slt,
                        AluOp::Sltu => Kind::Sltu,
                        AluOp::Xor => Kind::Xor,
                        AluOp::Srl => Kind::Srl,
                        AluOp::Sra => Kind::Sra,
                        AluOp::Or => Kind::Or,
                        AluOp::And => Kind::And,
                        AluOp::Mul => Kind::Mul,
                        AluOp::Mulh => Kind::Mulh,
                        AluOp::Mulhsu => Kind::Mulhsu,
                        AluOp::Mulhu => Kind::Mulhu,
                        AluOp::Div => Kind::Div,
                        AluOp::Divu => Kind::Divu,
                        AluOp::Rem => Kind::Rem,
                        AluOp::Remu => Kind::Remu,
                    };
                    op.a = rd;
                    op.b = rs1;
                    op.aux = u32::from(rs2);
                    op.cost = match rop {
                        AluOp::Mul | AluOp::Mulh | AluOp::Mulhsu
                        | AluOp::Mulhu => baked.mul,
                        AluOp::Div | AluOp::Divu | AluOp::Rem
                        | AluOp::Remu => baked.div,
                        _ => baked.alu,
                    };
                }
                Instr::Fence => {
                    op.kind = Kind::Fence;
                }
                Instr::Ecall => {
                    op.kind = Kind::Ecall;
                    nexts = [None, None];
                }
                Instr::Ebreak => {
                    op.kind = Kind::Ebreak;
                    op.cost = 0;
                    nexts = [None, None];
                }
                Instr::Mac => {
                    op.kind = Kind::Mac;
                    op.cost = baked.custom;
                }
                Instr::Add2i { rs1, rs2, i1, i2 } => {
                    op.kind = Kind::Add2i;
                    op.a = rs1;
                    op.b = rs2;
                    op.imm = i32::from(i1);
                    op.aux = u32::from(i2);
                    op.cost = baked.custom;
                }
                Instr::FusedMac { rs1, rs2, i1, i2 } => {
                    op.kind = Kind::FusedMac;
                    op.a = rs1;
                    op.b = rs2;
                    op.imm = i32::from(i1);
                    op.aux = u32::from(i2);
                    op.cost = baked.custom;
                }
                Instr::Dlp { rs1, body_len } => {
                    op.kind = Kind::Dlp;
                    op.b = rs1;
                    let ze = u64::from(fall) + 4 * u64::from(body_len);
                    op.aux = u32::try_from(ze).ok()?;
                    op.cost = baked.zol_setup;
                }
                Instr::Dlpi { count, body_len } => {
                    op.kind = Kind::Dlpi;
                    op.imm = i32::from(count);
                    let ze = u64::from(fall) + 4 * u64::from(body_len);
                    op.aux = u32::try_from(ze).ok()?;
                    op.cost = baked.zol_setup;
                }
                Instr::Zlp { rs1, body_len } => {
                    op.kind = Kind::Zlp;
                    op.b = rs1;
                    let ze = u64::from(fall) + 4 * u64::from(body_len);
                    op.aux = u32::try_from(ze).ok()?;
                    op.cost = baked.zol_setup;
                    nexts = [Some(fall), Some(op.aux)];
                }
                Instr::SetZc { rs1 } => {
                    op.kind = Kind::SetZc;
                    op.b = rs1;
                    op.cost = baked.zol_setup;
                }
                Instr::SetZs { rs1 } => {
                    op.kind = Kind::SetZs;
                    op.b = rs1;
                    op.cost = baked.zol_setup;
                }
                Instr::SetZe { rs1 } => {
                    op.kind = Kind::SetZe;
                    op.b = rs1;
                    op.cost = baked.zol_setup;
                }
                Instr::Custom { idx, rs1, rs2, i1, i2 } => {
                    op.kind = Kind::FusedCustom;
                    op.a = rs1;
                    op.b = rs2;
                    op.imm = i32::from(i1);
                    op.aux = (u32::from(idx) << 16) | u32::from(i2);
                    op.cost = baked.custom;
                }
            }

            let marked = all_marked
                || (dynamic_next && !zset.is_empty())
                || nexts.iter().flatten().any(|b| zset.contains(b));
            op.zmark = u8::from(marked);
            ops.push(op);
        }

        // Shared fall-off trap (byte pc == plen) and the dynamic trap.
        ops.push(MicroOp {
            kind: Kind::Trap,
            a: 0,
            b: 0,
            zmark: 0,
            imm: plen_bytes as i32,
            aux: 0,
            cost: 0,
        });
        ops.push(MicroOp {
            kind: Kind::TrapDyn,
            a: 0,
            b: 0,
            zmark: 0,
            imm: 0,
            aux: 0,
            cost: 0,
        });
        for byte in extra_traps {
            ops.push(MicroOp {
                kind: Kind::Trap,
                a: 0,
                b: 0,
                zmark: 0,
                imm: byte as i32,
                aux: 0,
                cost: 0,
            });
        }

        let superops = if opts.superops {
            fuse_superops(&mut ops, n, opts.profile.as_deref().map(|v| &v[..]))
        } else {
            Vec::new()
        };

        Some(LoweredProgram {
            ops,
            dyn_trap: n + 1,
            plen_bytes,
            zset,
            all_marked,
            superops,
        })
    }

    /// Total micro-ops including trap slots (diagnostics/tests).
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Fused superinstructions in the table (diagnostics/tests).
    pub fn n_superops(&self) -> usize {
        self.superops.len()
    }

    /// How many ops carry the ZOL loop-back compare (diagnostics/tests).
    pub fn n_marked(&self) -> usize {
        self.ops.iter().filter(|o| o.zmark != 0).count()
    }

    /// Can a run that starts with `ze` already armed execute on the
    /// lowered form?  `ze == 0` (disarmed) always can; an armed `ze` must
    /// be one the static mark set covers.  [`Machine::run`] falls back to
    /// the reference interpreter otherwise.
    pub(crate) fn covers_entry(&self, ze: u32) -> bool {
        ze == 0 || self.all_marked || self.zset.contains(&ze)
    }
}

/// The superinstruction fusion pass (DESIGN.md §19).
///
/// Scans the real slots `0..n` for maximal runs of [`fusible`] micro-ops —
/// at least 2 long, chopped to [`MAX_FUSE`] — where every op but the last
/// has `zmark == 0` (a marked op may only *end* a run: the loop-back
/// compare fires after it, and an unmarked op's successor provably cannot
/// be a live `ZE`).  Each chosen run's head slot is rewritten to
/// [`Kind::Super`]; the tail slots keep their original ops so any control
/// transfer into the middle of a run (branch, `jalr`, ZOL loop-start)
/// executes scalar from that point.
///
/// With a retire `profile` (per-slot counts, indexed `pc/4`), only the
/// [`SUPEROP_TOPK`] hottest runs — ranked by summed retire count, cold
/// runs dropped — are fused: the mining contract that keeps the table
/// small.  Without one, every eligible run fuses.
fn fuse_superops(
    ops: &mut [MicroOp],
    n: usize,
    profile: Option<&[u64]>,
) -> Vec<SuperOp> {
    let mut runs: Vec<(usize, usize)> = Vec::new(); // (start, len)
    let mut i = 0;
    while i < n {
        if !fusible(&ops[i]) || ops[i].zmark != 0 {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        while j < n && j - i < MAX_FUSE && fusible(&ops[j]) {
            let ends_run = ops[j].zmark != 0;
            j += 1;
            if ends_run {
                break;
            }
        }
        if j - i >= 2 {
            runs.push((i, j - i));
        }
        i = j;
    }

    if let Some(weights) = profile {
        let hotness = |&(start, len): &(usize, usize)| -> u64 {
            ops[start..start + len]
                .iter()
                .enumerate()
                .map(|(k, _)| weights.get(start + k).copied().unwrap_or(0))
                .sum()
        };
        runs.retain(|r| hotness(r) > 0);
        runs.sort_by_key(|r| std::cmp::Reverse(hotness(r)));
        runs.truncate(SUPEROP_TOPK);
        // Non-overlapping by construction; order is irrelevant to apply.
    }

    let mut table: Vec<SuperOp> = Vec::with_capacity(runs.len());
    for (start, len) in runs {
        let constituents = ops[start..start + len].to_vec();
        let cost: u64 =
            constituents.iter().map(|c| u64::from(c.cost)).sum();
        ops[start] = MicroOp {
            kind: Kind::Super,
            a: 0,
            b: 0,
            zmark: constituents[len - 1].zmark,
            imm: len as i32,
            aux: table.len() as u32,
            cost: constituents[0].cost,
        };
        table.push(SuperOp { ops: constituents, cost });
    }
    table
}

/// The byte pc a slot stands for: real slots are `idx * 4`, trap slots
/// carry the (possibly misaligned / out-of-range) pc they materialize.
#[inline]
fn byte_of(ops: &[MicroOp], idx: usize, dyn_pc: u32) -> u32 {
    match ops[idx].kind {
        Kind::Trap => ops[idx].imm as u32,
        Kind::TrapDyn => dyn_pc,
        _ => (idx as u32) * 4,
    }
}

// ---------------------------------------------------------------------------
// Direct-threaded dispatch (DESIGN.md §15)
// ---------------------------------------------------------------------------
//
// The central `match op.kind` of the original lowered loop (kept below as
// [`run_lowered_match`], the bench baseline and second differential
// oracle) funnels every retirement through one giant multiway branch.
// The threaded form replaces it with a per-kind handler-function table:
// each step loads the op, loads its handler pointer by discriminant and
// makes one indirect call — the classic direct-threaded interpreter
// shape, which gives the host branch predictor one predictable indirect
// site per handler instead of a single mega-branch carrying every op's
// history.  Handlers receive the machine, the op by value (16 bytes, two
// registers) and a [`StepCtx`] with the per-step redirections; control
// returns to the shared driver ([`step`]) via [`Flow`].

/// What a handler tells the dispatch driver.
enum Flow {
    /// Fall through to the ZOL loop-back check + retire accounting.
    Next,
    /// `ecall` retired — the run completes successfully.
    Ecall,
    /// `ebreak` — `SimError::Break` at this pc.
    Break,
    /// A static trap slot — `PcOutOfRange { pc: op.imm }`.
    Trap,
    /// The dynamic trap slot — `PcOutOfRange` at the recorded dynamic pc.
    TrapDyn,
    /// Data-memory fault at this pc.
    Mem(MemFault),
}

/// Per-step state a handler may read or redirect.
struct StepCtx<'a> {
    /// Byte pc of the executing slot (correct for every real slot; trap
    /// slots never read it).  [`h_super`] advances it to the faulting
    /// constituent's pc on a mid-run memory fault.
    pc: u32,
    /// Successor slot; branch/jump/zlp/super handlers overwrite it.
    next: usize,
    /// Retire cost; branch handlers swap in the taken cost.
    cost: u32,
    /// The pc recorded for the dynamic trap slot.
    dyn_pc: u32,
    /// Program length in bytes (dynamic-target validation).
    plen: u32,
    /// Index of the [`Kind::TrapDyn`] slot.
    dyn_trap: usize,
    /// The lowered program's superop table ([`h_super`] resolves `aux`
    /// through it).
    superops: &'a [SuperOp],
    /// Retires beyond the dispatched op's own 1 — [`h_super`] reports its
    /// tail constituents here; the driver adds them in one go.
    extra_retired: u64,
    /// Cycles beyond `cost` (the tail constituents' summed costs).
    extra_cycles: u64,
}

type Handler = for<'a> fn(&mut Machine, MicroOp, &mut StepCtx<'a>) -> Flow;

macro_rules! h_alu_imm {
    ($name:ident, |$a:ident, $imm:ident| $v:expr) => {
        fn $name(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
            let $a = m.regs[op.b as usize];
            let $imm = op.imm;
            Machine::write_reg(&mut m.regs, op.a, $v);
            Flow::Next
        }
    };
}

h_alu_imm!(h_addi, |a, imm| a.wrapping_add(imm));
h_alu_imm!(h_slti, |a, imm| (a < imm) as i32);
h_alu_imm!(h_sltiu, |a, imm| ((a as u32) < (imm as u32)) as i32);
h_alu_imm!(h_xori, |a, imm| a ^ imm);
h_alu_imm!(h_ori, |a, imm| a | imm);
h_alu_imm!(h_andi, |a, imm| a & imm);
h_alu_imm!(h_slli, |a, imm| ((a as u32) << (imm & 31)) as i32);
h_alu_imm!(h_srli, |a, imm| ((a as u32) >> (imm & 31)) as i32);
h_alu_imm!(h_srai, |a, imm| a >> (imm & 31));

macro_rules! h_alu_reg {
    ($name:ident, |$a:ident, $b:ident| $v:expr) => {
        fn $name(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
            let $a = m.regs[op.b as usize];
            let $b = m.regs[op.aux as usize];
            Machine::write_reg(&mut m.regs, op.a, $v);
            Flow::Next
        }
    };
}

h_alu_reg!(h_add, |a, b| a.wrapping_add(b));
h_alu_reg!(h_sub, |a, b| a.wrapping_sub(b));
h_alu_reg!(h_sll, |a, b| ((a as u32) << (b & 31)) as i32);
h_alu_reg!(h_slt, |a, b| (a < b) as i32);
h_alu_reg!(h_sltu, |a, b| ((a as u32) < (b as u32)) as i32);
h_alu_reg!(h_xor, |a, b| a ^ b);
h_alu_reg!(h_srl, |a, b| ((a as u32) >> (b & 31)) as i32);
h_alu_reg!(h_sra, |a, b| a >> (b & 31));
h_alu_reg!(h_or, |a, b| a | b);
h_alu_reg!(h_and, |a, b| a & b);
h_alu_reg!(h_mul, |a, b| a.wrapping_mul(b));
h_alu_reg!(h_mulh, |a, b| (((a as i64) * (b as i64)) >> 32) as i32);
h_alu_reg!(h_mulhsu, |a, b| (((a as i64) * (b as u32 as i64)) >> 32) as i32);
h_alu_reg!(h_mulhu, |a, b| {
    (((a as u32 as u64) * (b as u32 as u64)) >> 32) as i32
});
h_alu_reg!(h_div, |a, b| if b == 0 {
    -1
} else if a == i32::MIN && b == -1 {
    i32::MIN
} else {
    a.wrapping_div(b)
});
h_alu_reg!(h_divu, |a, b| if b == 0 {
    -1
} else {
    ((a as u32) / (b as u32)) as i32
});
h_alu_reg!(h_rem, |a, b| if b == 0 {
    a
} else if a == i32::MIN && b == -1 {
    0
} else {
    a.wrapping_rem(b)
});
h_alu_reg!(h_remu, |a, b| if b == 0 {
    a
} else {
    ((a as u32) % (b as u32)) as i32
});

macro_rules! h_load {
    ($name:ident, $load:ident, |$raw:ident| $v:expr) => {
        fn $name(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
            let addr =
                (m.regs[op.b as usize] as u32).wrapping_add(op.imm as u32);
            match m.mem.$load(addr) {
                Ok($raw) => {
                    Machine::write_reg(&mut m.regs, op.a, $v);
                    Flow::Next
                }
                Err(fault) => Flow::Mem(fault),
            }
        }
    };
}

h_load!(h_lb, load_u8, |raw| raw as i8 as i32);
h_load!(h_lbu, load_u8, |raw| i32::from(raw));
h_load!(h_lh, load_u16, |raw| raw as i16 as i32);
h_load!(h_lhu, load_u16, |raw| i32::from(raw));
h_load!(h_lw, load_u32, |raw| raw as i32);

macro_rules! h_store {
    ($name:ident, $store:ident, $t:ty) => {
        fn $name(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
            let addr =
                (m.regs[op.b as usize] as u32).wrapping_add(op.imm as u32);
            let v = m.regs[op.a as usize];
            match m.mem.$store(addr, v as $t) {
                Ok(()) => Flow::Next,
                Err(fault) => Flow::Mem(fault),
            }
        }
    };
}

h_store!(h_sb, store_u8, u8);
h_store!(h_sh, store_u16, u16);
h_store!(h_sw, store_u32, u32);

macro_rules! h_branch {
    ($name:ident, |$a:ident, $b:ident| $taken:expr) => {
        fn $name(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
            let $a = m.regs[op.a as usize];
            let $b = m.regs[op.b as usize];
            if $taken {
                cx.next = op.aux as usize;
                cx.cost = op.imm as u32;
            }
            Flow::Next
        }
    };
}

h_branch!(h_beq, |a, b| a == b);
h_branch!(h_bne, |a, b| a != b);
h_branch!(h_blt, |a, b| a < b);
h_branch!(h_bge, |a, b| a >= b);
h_branch!(h_bltu, |a, b| (a as u32) < (b as u32));
h_branch!(h_bgeu, |a, b| (a as u32) >= (b as u32));

fn h_lui(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Machine::write_reg(&mut m.regs, op.a, op.imm);
    Flow::Next
}

fn h_auipc(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    Machine::write_reg(&mut m.regs, op.a, (cx.pc as i32).wrapping_add(op.imm));
    Flow::Next
}

fn h_jal(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    Machine::write_reg(&mut m.regs, op.a, (cx.pc + 4) as i32);
    cx.next = op.aux as usize;
    Flow::Next
}

fn h_jalr(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    // Target from rs1 *before* the link write (rd may alias).
    let target =
        ((m.regs[op.b as usize] as u32).wrapping_add(op.imm as u32)) & !1;
    Machine::write_reg(&mut m.regs, op.a, (cx.pc + 4) as i32);
    if target % 4 == 0 && target < cx.plen {
        cx.next = (target / 4) as usize;
    } else {
        cx.dyn_pc = target;
        cx.next = cx.dyn_trap;
    }
    Flow::Next
}

fn h_fence(_m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Flow::Next
}

fn h_ecall(_m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Flow::Ecall
}

fn h_ebreak(_m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Flow::Break
}

fn h_mac(m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    let v = m.regs[MAC_RD as usize].wrapping_add(
        m.regs[MAC_RS1 as usize].wrapping_mul(m.regs[MAC_RS2 as usize]),
    );
    Machine::write_reg(&mut m.regs, MAC_RD, v);
    Flow::Next
}

fn h_add2i(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    let v1 = m.regs[op.a as usize].wrapping_add(op.imm);
    let v2 = m.regs[op.b as usize].wrapping_add(op.aux as i32);
    Machine::write_reg(&mut m.regs, op.a, v1);
    Machine::write_reg(&mut m.regs, op.b, v2);
    Flow::Next
}

fn h_fusedmac(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    // mac part first, then the add2i part — the fused op's architected
    // order (registers may alias across the halves).
    let _ = h_mac(m, op, cx);
    h_add2i(m, op, cx)
}

fn h_dlp(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    m.zc = m.regs[op.b as usize] as u32;
    m.zs = cx.pc + 4;
    m.ze = op.aux;
    Flow::Next
}

fn h_dlpi(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    m.zc = op.imm as u32;
    m.zs = cx.pc + 4;
    m.ze = op.aux;
    Flow::Next
}

fn h_zlp(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    let count = m.regs[op.b as usize] as u32;
    m.zs = cx.pc + 4;
    m.ze = op.aux;
    if count == 0 {
        // zero-iteration-safe: skip the body entirely
        let ze = op.aux;
        m.zc = 0;
        m.ze = 0;
        if ze % 4 == 0 && ze < cx.plen {
            cx.next = (ze / 4) as usize;
        } else {
            cx.dyn_pc = ze;
            cx.next = cx.dyn_trap;
        }
    } else {
        m.zc = count;
    }
    Flow::Next
}

fn h_setzc(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    m.zc = m.regs[op.b as usize] as u32;
    Flow::Next
}

fn h_setzs(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    m.zs = m.regs[op.b as usize] as u32;
    Flow::Next
}

fn h_setze(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    m.ze = m.regs[op.b as usize] as u32;
    Flow::Next
}

fn h_fused_custom(m: &mut Machine, op: MicroOp, _cx: &mut StepCtx) -> Flow {
    // Semantics come from the spec pool via the shared interpreter, so the
    // threaded path cannot drift from the reference or the match oracle.
    let spec = crate::fusion::window_spec((op.aux >> 16) as u8);
    match crate::fusion::exec_sem(
        spec.sem,
        &mut m.regs,
        &mut m.mem,
        op.a,
        op.b,
        op.imm as u8,
        (op.aux & 0xffff) as u16,
    ) {
        Ok(()) => Flow::Next,
        Err(fault) => Flow::Mem(fault),
    }
}

/// Execute a superop's constituents back-to-back, skipping the per-op
/// driver overhead (watchdog compare, fetch, ZOL compare, retire
/// bookkeeping).  Shared by the threaded handler ([`h_super`]), the match
/// oracle and the converged lane path, so fused semantics exist once.
///
/// `pc0` is the head constituent's byte pc; constituent `k` executes at
/// `pc0 + 4k` (constituents are consecutive real slots by construction).
/// Returns the tail constituents' `(extra_retired, extra_cycles)` on
/// success — the head's own retire/cost stays with the driver — or the
/// faulting constituent's index and fault.  Constituents before a fault
/// stay committed, exactly as the unfused program would leave them.
#[inline(always)]
fn exec_fused(
    m: &mut Machine,
    constituents: &[MicroOp],
    pc0: u32,
) -> Result<(u64, u64), (usize, MemFault)> {
    let mut extra_cycles: u64 = 0;
    for (k, c) in constituents.iter().enumerate() {
        // SAFETY: constituent kinds are valid discriminants (< N_KINDS).
        let h = unsafe { *HANDLERS.get_unchecked(c.kind as usize) };
        let mut cx = StepCtx {
            pc: pc0 + 4 * k as u32,
            next: 0,
            cost: c.cost,
            dyn_pc: 0,
            plen: 0,
            dyn_trap: 0,
            superops: &[],
            extra_retired: 0,
            extra_cycles: 0,
        };
        match h(m, *c, &mut cx) {
            Flow::Next => {
                if k > 0 {
                    // Fusible handlers never touch `cx.cost`, so this is
                    // the constituent's baked cost.
                    extra_cycles += u64::from(c.cost);
                }
            }
            Flow::Mem(fault) => return Err((k, fault)),
            // `fusible` admits only Flow::Next/Flow::Mem kinds.
            _ => unreachable!("non-fusible kind in superop"),
        }
    }
    Ok((constituents.len() as u64 - 1, extra_cycles))
}

fn h_super(m: &mut Machine, op: MicroOp, cx: &mut StepCtx) -> Flow {
    // Budget/observability gating happened in the driver before dispatch
    // (a Super op decays to its head constituent there); reaching this
    // handler commits the full run.
    // SAFETY: `aux` indexes the table it was assigned from at fuse time.
    let sup = unsafe { cx.superops.get_unchecked(op.aux as usize) };
    match exec_fused(m, &sup.ops, cx.pc) {
        Ok((extra_retired, extra_cycles)) => {
            cx.extra_retired = extra_retired;
            cx.extra_cycles = extra_cycles;
            // cx.next arrived as idx + 1; the run retires len slots.
            cx.next = cx.next - 1 + sup.ops.len();
            Flow::Next
        }
        Err((k, fault)) => {
            cx.pc += 4 * k as u32;
            Flow::Mem(fault)
        }
    }
}

fn h_trap(_m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Flow::Trap
}

fn h_trapdyn(_m: &mut Machine, _op: MicroOp, _cx: &mut StepCtx) -> Flow {
    Flow::TrapDyn
}

/// One entry per [`Kind`] discriminant.
const N_KINDS: usize = Kind::TrapDyn as usize + 1;

/// Every `Kind` in discriminant order — pinned by the
/// `kinds_cover_every_discriminant` test, so `HANDLERS[k as usize]` is
/// provably the handler [`handler_for`] names for `k`.
#[rustfmt::skip]
const KINDS: [Kind; N_KINDS] = [
    Kind::Lui, Kind::Auipc, Kind::Jal, Kind::Jalr,
    Kind::Beq, Kind::Bne, Kind::Blt, Kind::Bge, Kind::Bltu, Kind::Bgeu,
    Kind::Lb, Kind::Lh, Kind::Lw, Kind::Lbu, Kind::Lhu,
    Kind::Sb, Kind::Sh, Kind::Sw,
    Kind::Addi, Kind::Slti, Kind::Sltiu, Kind::Xori, Kind::Ori, Kind::Andi,
    Kind::Slli, Kind::Srli, Kind::Srai,
    Kind::Add, Kind::Sub, Kind::Sll, Kind::Slt, Kind::Sltu, Kind::Xor,
    Kind::Srl, Kind::Sra, Kind::Or, Kind::And,
    Kind::Mul, Kind::Mulh, Kind::Mulhsu, Kind::Mulhu,
    Kind::Div, Kind::Divu, Kind::Rem, Kind::Remu,
    Kind::Fence, Kind::Ecall, Kind::Ebreak,
    Kind::Mac, Kind::Add2i, Kind::FusedMac,
    Kind::Dlp, Kind::Dlpi, Kind::Zlp, Kind::SetZc, Kind::SetZs, Kind::SetZe,
    Kind::FusedCustom, Kind::Super,
    Kind::Trap, Kind::TrapDyn,
];

/// The handler a kind dispatches to — an exhaustive match, so adding a
/// `Kind` without a handler is a compile error, not a table hole.
const fn handler_for(k: Kind) -> Handler {
    match k {
        Kind::Lui => h_lui,
        Kind::Auipc => h_auipc,
        Kind::Jal => h_jal,
        Kind::Jalr => h_jalr,
        Kind::Beq => h_beq,
        Kind::Bne => h_bne,
        Kind::Blt => h_blt,
        Kind::Bge => h_bge,
        Kind::Bltu => h_bltu,
        Kind::Bgeu => h_bgeu,
        Kind::Lb => h_lb,
        Kind::Lh => h_lh,
        Kind::Lw => h_lw,
        Kind::Lbu => h_lbu,
        Kind::Lhu => h_lhu,
        Kind::Sb => h_sb,
        Kind::Sh => h_sh,
        Kind::Sw => h_sw,
        Kind::Addi => h_addi,
        Kind::Slti => h_slti,
        Kind::Sltiu => h_sltiu,
        Kind::Xori => h_xori,
        Kind::Ori => h_ori,
        Kind::Andi => h_andi,
        Kind::Slli => h_slli,
        Kind::Srli => h_srli,
        Kind::Srai => h_srai,
        Kind::Add => h_add,
        Kind::Sub => h_sub,
        Kind::Sll => h_sll,
        Kind::Slt => h_slt,
        Kind::Sltu => h_sltu,
        Kind::Xor => h_xor,
        Kind::Srl => h_srl,
        Kind::Sra => h_sra,
        Kind::Or => h_or,
        Kind::And => h_and,
        Kind::Mul => h_mul,
        Kind::Mulh => h_mulh,
        Kind::Mulhsu => h_mulhsu,
        Kind::Mulhu => h_mulhu,
        Kind::Div => h_div,
        Kind::Divu => h_divu,
        Kind::Rem => h_rem,
        Kind::Remu => h_remu,
        Kind::Fence => h_fence,
        Kind::Ecall => h_ecall,
        Kind::Ebreak => h_ebreak,
        Kind::Mac => h_mac,
        Kind::Add2i => h_add2i,
        Kind::FusedMac => h_fusedmac,
        Kind::Dlp => h_dlp,
        Kind::Dlpi => h_dlpi,
        Kind::Zlp => h_zlp,
        Kind::SetZc => h_setzc,
        Kind::SetZs => h_setzs,
        Kind::SetZe => h_setze,
        Kind::FusedCustom => h_fused_custom,
        Kind::Super => h_super,
        Kind::Trap => h_trap,
        Kind::TrapDyn => h_trapdyn,
    }
}

/// Handler table indexed by `Kind` discriminant, built from
/// [`handler_for`] over [`KINDS`] so entry order provably follows the
/// discriminants.
static HANDLERS: [Handler; N_KINDS] = {
    let mut t = [h_fence as Handler; N_KINDS];
    let mut i = 0;
    while i < N_KINDS {
        t[i] = handler_for(KINDS[i]);
        i += 1;
    }
    t
};

/// Entry translation of an architectural pc, exactly as the scalar loops
/// do it: misaligned or out-of-range entry pcs head straight for the
/// dynamic trap slot.  Returns `(slot index, dyn_pc)`.
fn enter(pc: u32, lp: &LoweredProgram) -> (usize, u32) {
    if pc % 4 == 0 && pc < lp.plen_bytes {
        ((pc / 4) as usize, 0)
    } else {
        (lp.dyn_trap, pc)
    }
}

/// One retirement of the threaded-dispatch loop; `Some` when the run
/// finished (successfully or not).  Inlined into the scalar
/// [`run_lowered`] and into every lane of [`run_lanes`]; per-step
/// behaviour is bit-identical to [`run_lowered_match`] and the reference
/// interpreter — watchdog before fetch, same fault pcs, same ZOL
/// loop-back, same retire/cycle accounting.
#[inline(always)]
fn step<H: RetireHook>(
    machine: &mut Machine,
    lp: &LoweredProgram,
    idx: &mut usize,
    dyn_pc: &mut u32,
    retired: &mut u64,
    cycles: &mut u64,
    max_instrs: u64,
    instrs_for_hook: &[Instr],
    hook: &mut H,
) -> Option<Result<RunStats, SimError>> {
    let ops: &[MicroOp] = &lp.ops;
    // Watchdog first: the reference loop checks the budget before
    // validating the pc, and a lowered run must fault identically.
    if *retired >= max_instrs {
        machine.pc = byte_of(ops, *idx, *dyn_pc);
        return Some(Err(SimError::Watchdog { max_instrs }));
    }
    // §Perf: this fetch is the hottest load in the ISS; the bounds check
    // is provably dead, so elide it.  Every value `idx` can hold is
    // `< ops.len()` by construction at lower time: resolved branch/jump
    // targets point at real slots or appended traps, `idx + 1 ≤ n + 1`
    // for the real slot `idx < n` that produced it (trap slots return
    // before the increment is consumed), `dyn_trap = n + 1`, and every
    // dynamic target (`jalr`, ZOL start/skip) is range-checked against
    // `plen` before the `/ 4` conversion (DESIGN.md §15).
    debug_assert!(*idx < ops.len(), "lowered slot index out of range");
    // SAFETY: idx < ops.len() per the invariant above.
    let mut op = unsafe { *ops.get_unchecked(*idx) };
    if op.kind == Kind::Super {
        // Fused-run gating (DESIGN.md §19): a full fuse needs the whole
        // run inside the watchdog budget (the oracle checks the budget
        // before every constituent) and a non-observing hook (observers
        // see one retire per original instruction).  Otherwise the op
        // decays to its head constituent — the tail slots hold the
        // original ops, so execution continues scalar and bit-identical.
        let sup = unsafe { lp.superops.get_unchecked(op.aux as usize) };
        if H::OBSERVES || max_instrs - *retired < sup.ops.len() as u64 {
            op = sup.ops[0];
        }
    }
    // SAFETY: `op.kind as usize` is a valid discriminant (< N_KINDS by
    // repr(u8) sequential numbering), and HANDLERS holds one entry per
    // discriminant.
    let handler = unsafe { *HANDLERS.get_unchecked(op.kind as usize) };
    let mut cx = StepCtx {
        pc: (*idx as u32).wrapping_mul(4),
        next: *idx + 1,
        cost: op.cost,
        dyn_pc: *dyn_pc,
        plen: lp.plen_bytes,
        dyn_trap: lp.dyn_trap,
        superops: &lp.superops,
        extra_retired: 0,
        extra_cycles: 0,
    };
    match handler(machine, op, &mut cx) {
        Flow::Next => {}
        Flow::Ecall => {
            if H::OBSERVES {
                hook.retire(cx.pc, &instrs_for_hook[*idx], u64::from(cx.cost));
            }
            machine.pc = cx.pc;
            return Some(Ok(RunStats {
                instrs: *retired + 1,
                cycles: *cycles + u64::from(cx.cost),
            }));
        }
        Flow::Break => {
            machine.pc = cx.pc;
            return Some(Err(SimError::Break { pc: cx.pc }));
        }
        Flow::Trap => {
            let bad = op.imm as u32;
            machine.pc = bad;
            return Some(Err(SimError::PcOutOfRange { pc: bad }));
        }
        Flow::TrapDyn => {
            machine.pc = *dyn_pc;
            return Some(Err(SimError::PcOutOfRange { pc: *dyn_pc }));
        }
        Flow::Mem(fault) => {
            // cx.pc is the faulting constituent's pc for fused runs.
            machine.pc = cx.pc;
            return Some(Err(SimError::Mem { pc: cx.pc, fault }));
        }
    }
    *dyn_pc = cx.dyn_pc;
    let mut next = cx.next;

    // Zero-overhead loop-back, only on ops whose successor can be a
    // loop end: when execution reaches ZE, hardware redirects to ZS
    // and decrements ZC — no cycles, no retire.  A fused run's head op
    // carries its *last* constituent's mark (non-final constituents are
    // provably unmarked), so the compare runs exactly where the unfused
    // program would run it.
    if op.zmark != 0 && machine.ze != 0 {
        let next_byte = byte_of(ops, next, *dyn_pc);
        if next_byte == machine.ze {
            if machine.zc > 1 {
                machine.zc -= 1;
                let zs = machine.zs;
                if zs % 4 == 0 && zs < lp.plen_bytes {
                    next = (zs / 4) as usize;
                } else {
                    *dyn_pc = zs;
                    next = lp.dyn_trap;
                }
            } else {
                machine.zc = 0;
                machine.ze = 0; // disarm
            }
        }
    }

    if H::OBSERVES {
        hook.retire(cx.pc, &instrs_for_hook[*idx], u64::from(cx.cost));
    }
    *retired += 1 + cx.extra_retired;
    *cycles += u64::from(cx.cost) + cx.extra_cycles;
    *idx = next;
    None
}

/// Execute `machine` over the lowered form via direct-threaded dispatch —
/// same observable behaviour as [`Machine::run_reference`] and
/// [`run_lowered_match`], instruction for instruction (module docs).
///
/// `instrs_for_hook` is the program's decoded stream, used only to feed
/// [`RetireHook::retire`]; hooks with [`RetireHook::OBSERVES`] `== false`
/// (the [`NopHook`] fast path) compile the retire block — and its
/// argument materialization — out entirely: the gate is a
/// monomorphization-time constant, never a per-retire branch.
pub(crate) fn run_lowered<H: RetireHook>(
    machine: &mut Machine,
    lp: &LoweredProgram,
    instrs_for_hook: &[Instr],
    max_instrs: u64,
    hook: &mut H,
) -> Result<RunStats, SimError> {
    let (mut idx, mut dyn_pc) = enter(machine.pc, lp);
    let (mut retired, mut cycles) = (0u64, 0u64);
    loop {
        if let Some(r) = step(
            machine,
            lp,
            &mut idx,
            &mut dyn_pc,
            &mut retired,
            &mut cycles,
            max_instrs,
            instrs_for_hook,
            hook,
        ) {
            return r;
        }
    }
}

/// Step `K` independent machines — same [`LoweredProgram`], per-lane
/// registers / DM / watchdog budget — through one fetch/decode stream
/// (software SIMT, DESIGN.md §15).  Lanes never interact; a lane that
/// exits early (`ecall`, fault, watchdog) retires individually while its
/// mates keep stepping, so per-lane results are bit-identical to `K`
/// scalar runs.  Lane runs are hook-free by construction ([`NopHook`]);
/// observing hooks take the scalar path — the retire stream is
/// per-machine, and interleaving lanes would scramble it.
///
/// Lane state is **structure-of-arrays** (DESIGN.md §19): the slot
/// cursors, dynamic-trap pcs, retire/cycle counters and done flags each
/// live in their own `[_; K]` array instead of an array of per-lane
/// structs.  The scalar stepper touches one element of each, and the
/// converged fused path below strides a whole array contiguously per
/// constituent.
pub(crate) fn run_lanes<const K: usize>(
    lanes: &mut [Machine],
    lp: &LoweredProgram,
    budgets: &[u64],
) -> Vec<Result<RunStats, SimError>> {
    assert_eq!(lanes.len(), K, "lane group width");
    assert_eq!(budgets.len(), K, "one watchdog budget per lane");
    let mut idx = [0usize; K];
    let mut dyn_pc = [0u32; K];
    let mut retired = [0u64; K];
    let mut cycles = [0u64; K];
    for l in 0..K {
        let (i, d) = enter(lanes[l].pc, lp);
        idx[l] = i;
        dyn_pc[l] = d;
    }
    let mut done: [Option<Result<RunStats, SimError>>; K] =
        std::array::from_fn(|_| None);
    let mut live = K;
    while live > 0 {
        // Converged fused fast path (DESIGN.md §19): every lane alive and
        // parked on the same [`Kind::Super`] slot, every budget covering
        // the full run.  Execute constituent-major — each constituent
        // strides across all K lanes before the next one runs — so the
        // lanes' independent dependency chains overlap *within* the fused
        // run, not just across scalar dispatches.  Per-lane results stay
        // bit-identical to scalar fused execution: constituents commit in
        // the same order per lane, and a faulting lane simply stops
        // participating in later constituents.
        if !lp.superops.is_empty() && live == K {
            let i0 = idx[0];
            let op = lp.ops[i0];
            if op.kind == Kind::Super && idx.iter().all(|&i| i == i0) {
                let sup = &lp.superops[op.aux as usize];
                let n = sup.ops.len() as u64;
                if (0..K).all(|l| budgets[l] - retired[l] >= n) {
                    let pc0 = (i0 as u32) * 4;
                    let mut fault: [Option<(usize, MemFault)>; K] =
                        [None; K];
                    for (k, c) in sup.ops.iter().enumerate() {
                        // SAFETY: valid discriminant, one entry per kind.
                        let h = unsafe {
                            *HANDLERS.get_unchecked(c.kind as usize)
                        };
                        for l in 0..K {
                            if fault[l].is_some() {
                                continue;
                            }
                            let mut cx = StepCtx {
                                pc: pc0 + 4 * k as u32,
                                next: 0,
                                cost: c.cost,
                                dyn_pc: 0,
                                plen: lp.plen_bytes,
                                dyn_trap: lp.dyn_trap,
                                superops: &[],
                                extra_retired: 0,
                                extra_cycles: 0,
                            };
                            match h(&mut lanes[l], *c, &mut cx) {
                                Flow::Next => {}
                                Flow::Mem(f) => fault[l] = Some((k, f)),
                                _ => unreachable!(
                                    "non-fusible kind in superop"
                                ),
                            }
                        }
                    }
                    let next = i0 + sup.ops.len();
                    for l in 0..K {
                        match fault[l] {
                            Some((k, f)) => {
                                let pc = pc0 + 4 * k as u32;
                                lanes[l].pc = pc;
                                done[l] =
                                    Some(Err(SimError::Mem { pc, fault: f }));
                                live -= 1;
                            }
                            None => {
                                retired[l] += n;
                                cycles[l] += sup.cost;
                                let mut nl = next;
                                // Same loop-back compare the scalar
                                // stepper runs after a fused head
                                // (zmark = last constituent's mark).
                                let m = &mut lanes[l];
                                if op.zmark != 0 && m.ze != 0 {
                                    let nb =
                                        byte_of(&lp.ops, nl, dyn_pc[l]);
                                    if nb == m.ze {
                                        if m.zc > 1 {
                                            m.zc -= 1;
                                            let zs = m.zs;
                                            if zs % 4 == 0
                                                && zs < lp.plen_bytes
                                            {
                                                nl = (zs / 4) as usize;
                                            } else {
                                                dyn_pc[l] = zs;
                                                nl = lp.dyn_trap;
                                            }
                                        } else {
                                            m.zc = 0;
                                            m.ze = 0; // disarm
                                        }
                                    }
                                }
                                idx[l] = nl;
                            }
                        }
                    }
                    continue;
                }
            }
        }
        // Lane-major inner loop: K independent dependency chains in
        // flight per iteration, which is where the lane win comes from —
        // the host core overlaps their loads/ALU ops where a scalar run
        // serializes on one chain.
        for l in 0..K {
            if done[l].is_some() {
                continue;
            }
            if let Some(r) = step(
                &mut lanes[l],
                lp,
                &mut idx[l],
                &mut dyn_pc[l],
                &mut retired[l],
                &mut cycles[l],
                budgets[l],
                &[],
                &mut NopHook,
            ) {
                done[l] = Some(r);
                live -= 1;
            }
        }
    }
    done.into_iter()
        .map(|r| r.expect("every lane retired"))
        .collect()
}

/// The original central-`match` lowered loop, kept verbatim as the
/// `dispatch:match` bench baseline and a second differential oracle for
/// the threaded path (`tests/lowered_diff.rs` asserts `threaded ≡ match`
/// on top of `lowered ≡ reference`).
pub(crate) fn run_lowered_match<H: RetireHook>(
    machine: &mut Machine,
    lp: &LoweredProgram,
    instrs_for_hook: &[Instr],
    max_instrs: u64,
    hook: &mut H,
) -> Result<RunStats, SimError> {
    let ops: &[MicroOp] = &lp.ops;
    let plen = lp.plen_bytes;
    let mut retired: u64 = 0;
    let mut cycles: u64 = 0;
    // The pc recorded for the dynamic trap slot (invalid jalr / ZOL start).
    let mut dyn_pc: u32 = 0;
    let mut idx: usize = {
        let pc = machine.pc;
        if pc % 4 == 0 && pc < plen {
            (pc / 4) as usize
        } else {
            dyn_pc = pc;
            lp.dyn_trap
        }
    };

    loop {
        // Watchdog first: the reference loop checks the budget before
        // validating the pc, and a lowered run must fault identically.
        if retired >= max_instrs {
            machine.pc = byte_of(ops, idx, dyn_pc);
            return Err(SimError::Watchdog { max_instrs });
        }
        // §Perf: this fetch is the hottest load in the ISS; the bounds
        // check is provably dead, so elide it.  Every value `idx` can hold
        // is `< ops.len()` by construction at lower time: resolved
        // branch/jump targets point at real slots or appended traps,
        // `idx + 1 ≤ n + 1` for the real slot `idx < n` that produced it
        // (trap slots return before the increment is consumed), `dyn_trap
        // = n + 1`, and every dynamic target (`jalr`, ZOL start/skip) is
        // range-checked against `plen` before the `/ 4` conversion.
        debug_assert!(idx < ops.len(), "lowered slot index out of range");
        // SAFETY: idx < ops.len() per the invariant above.
        let mut op = unsafe { *ops.get_unchecked(idx) };
        if op.kind == Kind::Super {
            // Same fused-run gating as the threaded driver: observers and
            // short watchdog budgets decay the head to its original op
            // and the tail slots execute scalar.
            let sup = &lp.superops[op.aux as usize];
            if H::OBSERVES || max_instrs - retired < sup.ops.len() as u64 {
                op = sup.ops[0];
            }
        }
        // Correct for every real slot (idx < n); trap slots never read it.
        let pc = (idx as u32).wrapping_mul(4);
        let mut next = idx + 1;
        let mut cost = op.cost;
        // Super's tail-constituent accounting (zero for every other kind).
        let mut extra_retired: u64 = 0;
        let mut extra_cycles: u64 = 0;

        // Early-return on a data-memory fault, leaving `machine.pc` at the
        // faulting instruction like the reference loop does.
        macro_rules! mem_try {
            ($e:expr) => {
                match $e {
                    Ok(v) => v,
                    Err(fault) => {
                        machine.pc = pc;
                        return Err(SimError::Mem { pc, fault });
                    }
                }
            };
        }

        match op.kind {
            Kind::Addi => {
                let v = machine.regs[op.b as usize].wrapping_add(op.imm);
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Slti => {
                let v = (machine.regs[op.b as usize] < op.imm) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Sltiu => {
                let v = ((machine.regs[op.b as usize] as u32)
                    < (op.imm as u32)) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Xori => {
                let v = machine.regs[op.b as usize] ^ op.imm;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Ori => {
                let v = machine.regs[op.b as usize] | op.imm;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Andi => {
                let v = machine.regs[op.b as usize] & op.imm;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Slli => {
                let v = ((machine.regs[op.b as usize] as u32) << (op.imm & 31))
                    as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Srli => {
                let v = ((machine.regs[op.b as usize] as u32) >> (op.imm & 31))
                    as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Srai => {
                let v = machine.regs[op.b as usize] >> (op.imm & 31);
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Add => {
                let v = machine.regs[op.b as usize]
                    .wrapping_add(machine.regs[op.aux as usize]);
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Sub => {
                let v = machine.regs[op.b as usize]
                    .wrapping_sub(machine.regs[op.aux as usize]);
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Sll => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, ((a as u32) << (b & 31)) as i32);
            }
            Kind::Slt => {
                let v = (machine.regs[op.b as usize]
                    < machine.regs[op.aux as usize]) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Sltu => {
                let v = ((machine.regs[op.b as usize] as u32)
                    < (machine.regs[op.aux as usize] as u32))
                    as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Xor => {
                let v =
                    machine.regs[op.b as usize] ^ machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Srl => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, ((a as u32) >> (b & 31)) as i32);
            }
            Kind::Sra => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, a >> (b & 31));
            }
            Kind::Or => {
                let v =
                    machine.regs[op.b as usize] | machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::And => {
                let v =
                    machine.regs[op.b as usize] & machine.regs[op.aux as usize];
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Mul => {
                let v = machine.regs[op.b as usize]
                    .wrapping_mul(machine.regs[op.aux as usize]);
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Mulh => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v = (((a as i64) * (b as i64)) >> 32) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Mulhsu => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v = (((a as i64) * (b as u32 as i64)) >> 32) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Mulhu => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v = (((a as u32 as u64) * (b as u32 as u64)) >> 32) as i32;
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Div => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v = if b == 0 {
                    -1
                } else if a == i32::MIN && b == -1 {
                    i32::MIN
                } else {
                    a.wrapping_div(b)
                };
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Divu => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v =
                    if b == 0 { -1 } else { ((a as u32) / (b as u32)) as i32 };
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Rem => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v = if b == 0 {
                    a
                } else if a == i32::MIN && b == -1 {
                    0
                } else {
                    a.wrapping_rem(b)
                };
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Remu => {
                let a = machine.regs[op.b as usize];
                let b = machine.regs[op.aux as usize];
                let v =
                    if b == 0 { a } else { ((a as u32) % (b as u32)) as i32 };
                Machine::write_reg(&mut machine.regs, op.a, v);
            }
            Kind::Lb => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let raw = mem_try!(machine.mem.load_u8(addr));
                Machine::write_reg(&mut machine.regs, op.a, raw as i8 as i32);
            }
            Kind::Lbu => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let raw = mem_try!(machine.mem.load_u8(addr));
                Machine::write_reg(&mut machine.regs, op.a, i32::from(raw));
            }
            Kind::Lh => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let raw = mem_try!(machine.mem.load_u16(addr));
                Machine::write_reg(&mut machine.regs, op.a, raw as i16 as i32);
            }
            Kind::Lhu => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let raw = mem_try!(machine.mem.load_u16(addr));
                Machine::write_reg(&mut machine.regs, op.a, i32::from(raw));
            }
            Kind::Lw => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let raw = mem_try!(machine.mem.load_u32(addr));
                Machine::write_reg(&mut machine.regs, op.a, raw as i32);
            }
            Kind::Sb => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let v = machine.regs[op.a as usize];
                mem_try!(machine.mem.store_u8(addr, v as u8));
            }
            Kind::Sh => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let v = machine.regs[op.a as usize];
                mem_try!(machine.mem.store_u16(addr, v as u16));
            }
            Kind::Sw => {
                let addr = (machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32);
                let v = machine.regs[op.a as usize];
                mem_try!(machine.mem.store_u32(addr, v as u32));
            }
            Kind::Beq => {
                if machine.regs[op.a as usize] == machine.regs[op.b as usize] {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Bne => {
                if machine.regs[op.a as usize] != machine.regs[op.b as usize] {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Blt => {
                if machine.regs[op.a as usize] < machine.regs[op.b as usize] {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Bge => {
                if machine.regs[op.a as usize] >= machine.regs[op.b as usize] {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Bltu => {
                if (machine.regs[op.a as usize] as u32)
                    < (machine.regs[op.b as usize] as u32)
                {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Bgeu => {
                if (machine.regs[op.a as usize] as u32)
                    >= (machine.regs[op.b as usize] as u32)
                {
                    next = op.aux as usize;
                    cost = op.imm as u32;
                }
            }
            Kind::Jal => {
                Machine::write_reg(&mut machine.regs, op.a, (pc + 4) as i32);
                next = op.aux as usize;
            }
            Kind::Jalr => {
                // Target from rs1 *before* the link write (rd may alias).
                let target = ((machine.regs[op.b as usize] as u32)
                    .wrapping_add(op.imm as u32))
                    & !1;
                Machine::write_reg(&mut machine.regs, op.a, (pc + 4) as i32);
                if target % 4 == 0 && target < plen {
                    next = (target / 4) as usize;
                } else {
                    dyn_pc = target;
                    next = lp.dyn_trap;
                }
            }
            Kind::Lui => {
                Machine::write_reg(&mut machine.regs, op.a, op.imm);
            }
            Kind::Auipc => {
                Machine::write_reg(&mut machine.regs, op.a, (pc as i32).wrapping_add(op.imm));
            }
            Kind::Fence => {}
            Kind::Ecall => {
                if H::OBSERVES {
                    hook.retire(pc, &instrs_for_hook[idx], u64::from(cost));
                }
                machine.pc = pc;
                return Ok(RunStats {
                    instrs: retired + 1,
                    cycles: cycles + u64::from(cost),
                });
            }
            Kind::Ebreak => {
                machine.pc = pc;
                return Err(SimError::Break { pc });
            }
            Kind::Mac => {
                let v = machine.regs[MAC_RD as usize].wrapping_add(
                    machine.regs[MAC_RS1 as usize]
                        .wrapping_mul(machine.regs[MAC_RS2 as usize]),
                );
                Machine::write_reg(&mut machine.regs, MAC_RD, v);
            }
            Kind::Add2i => {
                let v1 = machine.regs[op.a as usize].wrapping_add(op.imm);
                let v2 =
                    machine.regs[op.b as usize].wrapping_add(op.aux as i32);
                Machine::write_reg(&mut machine.regs, op.a, v1);
                Machine::write_reg(&mut machine.regs, op.b, v2);
            }
            Kind::FusedMac => {
                let m = machine.regs[MAC_RD as usize].wrapping_add(
                    machine.regs[MAC_RS1 as usize]
                        .wrapping_mul(machine.regs[MAC_RS2 as usize]),
                );
                Machine::write_reg(&mut machine.regs, MAC_RD, m);
                let v1 = machine.regs[op.a as usize].wrapping_add(op.imm);
                let v2 =
                    machine.regs[op.b as usize].wrapping_add(op.aux as i32);
                Machine::write_reg(&mut machine.regs, op.a, v1);
                Machine::write_reg(&mut machine.regs, op.b, v2);
            }
            Kind::Dlp => {
                machine.zc = machine.regs[op.b as usize] as u32;
                machine.zs = pc + 4;
                machine.ze = op.aux;
            }
            Kind::Dlpi => {
                machine.zc = op.imm as u32;
                machine.zs = pc + 4;
                machine.ze = op.aux;
            }
            Kind::Zlp => {
                let count = machine.regs[op.b as usize] as u32;
                machine.zs = pc + 4;
                machine.ze = op.aux;
                if count == 0 {
                    // zero-iteration-safe: skip the body entirely
                    let ze = op.aux;
                    machine.zc = 0;
                    machine.ze = 0;
                    if ze % 4 == 0 && ze < plen {
                        next = (ze / 4) as usize;
                    } else {
                        dyn_pc = ze;
                        next = lp.dyn_trap;
                    }
                } else {
                    machine.zc = count;
                }
            }
            Kind::SetZc => {
                machine.zc = machine.regs[op.b as usize] as u32;
            }
            Kind::SetZs => {
                machine.zs = machine.regs[op.b as usize] as u32;
            }
            Kind::SetZe => {
                machine.ze = machine.regs[op.b as usize] as u32;
            }
            Kind::FusedCustom => {
                let spec = crate::fusion::window_spec((op.aux >> 16) as u8);
                mem_try!(crate::fusion::exec_sem(
                    spec.sem,
                    &mut machine.regs,
                    &mut machine.mem,
                    op.a,
                    op.b,
                    op.imm as u8,
                    (op.aux & 0xffff) as u16,
                ));
            }
            Kind::Super => {
                // Shared fused executor — the match oracle and the
                // threaded handler cannot drift.
                let sup = &lp.superops[op.aux as usize];
                match exec_fused(machine, &sup.ops, pc) {
                    Ok((er, ec)) => {
                        extra_retired = er;
                        extra_cycles = ec;
                        next = idx + sup.ops.len();
                    }
                    Err((k, fault)) => {
                        let fpc = pc + 4 * k as u32;
                        machine.pc = fpc;
                        return Err(SimError::Mem { pc: fpc, fault });
                    }
                }
            }
            Kind::Trap => {
                let bad = op.imm as u32;
                machine.pc = bad;
                return Err(SimError::PcOutOfRange { pc: bad });
            }
            Kind::TrapDyn => {
                machine.pc = dyn_pc;
                return Err(SimError::PcOutOfRange { pc: dyn_pc });
            }
        }

        // Zero-overhead loop-back, only on ops whose successor can be a
        // loop end: when execution reaches ZE, hardware redirects to ZS
        // and decrements ZC — no cycles, no retire.
        if op.zmark != 0 && machine.ze != 0 {
            let next_byte = byte_of(ops, next, dyn_pc);
            if next_byte == machine.ze {
                if machine.zc > 1 {
                    machine.zc -= 1;
                    let zs = machine.zs;
                    if zs % 4 == 0 && zs < plen {
                        next = (zs / 4) as usize;
                    } else {
                        dyn_pc = zs;
                        next = lp.dyn_trap;
                    }
                } else {
                    machine.zc = 0;
                    machine.ze = 0; // disarm
                }
            }
        }

        if H::OBSERVES {
            hook.retire(pc, &instrs_for_hook[idx], u64::from(cost));
        }
        retired += 1 + extra_retired;
        cycles += u64::from(cost) + extra_cycles;
        idx = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;
    use crate::sim::{V0, V4};

    fn lowered(
        variant: crate::sim::Variant,
        instrs: Vec<Instr>,
    ) -> LoweredProgram {
        let p = Program::from_instrs(variant, instrs).unwrap();
        LoweredProgram::lower(&p, &CycleModel::default()).unwrap()
    }

    #[test]
    fn straight_line_lowers_without_marks() {
        let lp = lowered(V0, vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 7 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 2, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]);
        // 3 real ops + fall-off trap + dynamic trap
        assert_eq!(lp.n_ops(), 5);
        assert_eq!(lp.n_marked(), 0);
        assert_eq!(lp.ops[0].kind, Kind::Addi);
        assert_eq!(lp.ops[3].kind, Kind::Trap);
        assert_eq!(lp.ops[4].kind, Kind::TrapDyn);
    }

    #[test]
    fn costs_are_baked_per_class() {
        let cm = CycleModel::default();
        let lp = lowered(V0, vec![
            Instr::Op { op: AluOp::Mul, rd: 1, rs1: 2, rs2: 3 },
            Instr::Op { op: AluOp::Div, rd: 1, rs1: 2, rs2: 3 },
            Instr::Load { op: LoadOp::Lw, rd: 1, rs1: 0, offset: 0 },
            Instr::Ecall,
        ]);
        assert_eq!(u64::from(lp.ops[0].cost), cm.mul);
        assert_eq!(u64::from(lp.ops[1].cost), cm.div);
        assert_eq!(u64::from(lp.ops[2].cost), cm.load);
    }

    #[test]
    fn branch_targets_resolve_to_indices_or_traps() {
        let lp = lowered(V0, vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            // taken target = instruction 0
            Instr::Branch { op: BranchOp::Blt, rs1: 1, rs2: 2, offset: -4 },
            // taken target = way out of range -> trap slot (4092 is the
            // largest encodable b-type offset)
            Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 4092 },
            Instr::Ecall,
        ]);
        assert_eq!(lp.ops[1].aux, 0);
        let trap_idx = lp.ops[2].aux as usize;
        assert!(trap_idx > lp.dyn_trap);
        assert_eq!(lp.ops[trap_idx].kind, Kind::Trap);
        assert_eq!(lp.ops[trap_idx].imm as u32, 2 * 4 + 4092);
    }

    #[test]
    fn zol_marks_only_possible_loop_ends() {
        let lp = lowered(V4, vec![
            Instr::Dlpi { count: 3, body_len: 2 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 2, rs1: 2, imm: 1 },
            Instr::Ecall,
        ]);
        // ZE = 12: only the op at index 2 (fallthrough 12) is marked.
        let marks: Vec<u8> =
            lp.ops.iter().take(4).map(|o| o.zmark).collect();
        assert_eq!(marks, vec![0, 0, 1, 0]);
    }

    #[test]
    fn setze_marks_every_op() {
        let lp = lowered(V4, vec![
            Instr::SetZe { rs1: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]);
        assert!(lp.all_marked);
        assert!(lp.ops.iter().take(2).all(|o| o.zmark == 1));
        assert!(lp.covers_entry(0x1234));
    }

    /// The safety net for the `HANDLERS` table: `KINDS` must list every
    /// discriminant in order, so `HANDLERS[k as usize]` is the handler
    /// `handler_for(k)` names.  (The `threaded ≡ match` differential
    /// property in `tests/lowered_diff.rs` is the behavioural backstop.)
    #[test]
    fn kinds_cover_every_discriminant() {
        for (i, k) in KINDS.iter().enumerate() {
            assert_eq!(*k as usize, i, "KINDS[{i}] = {k:?} out of order");
        }
        assert_eq!(N_KINDS, KINDS.len());
    }

    #[test]
    fn unbakeable_cycle_model_falls_back() {
        let p = Program::from_instrs(V0, vec![Instr::Ecall]).unwrap();
        let cm = CycleModel {
            alu: u64::from(u32::MAX) + 1,
            ..CycleModel::default()
        };
        assert!(LoweredProgram::lower(&p, &cm).is_none());
        assert!(LoweredProgram::lower(&p, &CycleModel::default()).is_some());
    }

    // --- superinstruction fusion (DESIGN.md §19) ---

    const SUPER_ON: LowerOpts = LowerOpts { superops: true, profile: None };

    fn fused(
        variant: crate::sim::Variant,
        instrs: Vec<Instr>,
    ) -> LoweredProgram {
        let p = Program::from_instrs(variant, instrs).unwrap();
        LoweredProgram::lower_with(&p, &CycleModel::default(), &SUPER_ON)
            .unwrap()
    }

    /// Run `instrs` through the fused lowered form and the reference
    /// interpreter on fresh machines; both observable outcomes must match
    /// bit for bit.
    fn diff_fused(
        variant: crate::sim::Variant,
        instrs: &[Instr],
        budget: u64,
    ) {
        let lp = fused(variant, instrs.to_vec());
        let mut a =
            Machine::from_instrs(variant, instrs.to_vec(), 256).unwrap();
        let mut b =
            Machine::from_instrs(variant, instrs.to_vec(), 256).unwrap();
        let prog = std::sync::Arc::clone(a.program());
        let ra = run_lowered(&mut a, &lp, prog.instrs(), budget, &mut NopHook);
        let rb = b.run_reference(budget, &mut NopHook);
        assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "budget={budget}");
        assert_eq!(a.regs, b.regs, "budget={budget}");
        assert_eq!(a.pc, b.pc, "budget={budget}");
        assert_eq!((a.zc, a.zs, a.ze), (b.zc, b.zs, b.ze), "budget={budget}");
    }

    #[test]
    fn superops_fuse_straight_line_runs() {
        use AluImmOp::Addi;
        let lp = fused(V0, vec![
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 1 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 1, imm: 2 },
            Instr::OpImm { op: Addi, rd: 3, rs1: 2, imm: 3 },
            Instr::Ecall,
        ]);
        assert_eq!(lp.n_superops(), 1);
        assert_eq!(lp.ops[0].kind, Kind::Super);
        assert_eq!(lp.ops[0].imm, 3);
        // Tail slots keep their original ops: mid-run control transfers
        // execute scalar.
        assert_eq!(lp.ops[1].kind, Kind::Addi);
        assert_eq!(lp.ops[2].kind, Kind::Addi);
        assert_eq!(lp.superops[0].ops.len(), 3);
        assert_eq!(lp.superops[0].cost, 3); // 3 × alu(1)
    }

    #[test]
    fn fused_run_is_bit_identical_to_reference() {
        use AluImmOp::Addi;
        let prog = [
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 40 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 1, imm: 2 },
            Instr::Store { op: StoreOp::Sw, rs2: 2, rs1: 0, offset: 16 },
            Instr::Load { op: LoadOp::Lw, rd: 3, rs1: 0, offset: 16 },
            Instr::Ecall,
        ];
        // Every watchdog budget across the whole run, including the exact
        // fused-run boundaries (0..=n and one beyond).
        for budget in 0..=6 {
            diff_fused(V0, &prog, budget);
        }
    }

    #[test]
    fn fused_mid_run_fault_commits_prefix_and_faults_at_right_pc() {
        use AluImmOp::Addi;
        let prog = [
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 1 }, // commits
            Instr::Load { op: LoadOp::Lw, rd: 2, rs1: 0, offset: 2040 }, // faults (dm=256)
            Instr::OpImm { op: Addi, rd: 3, rs1: 0, imm: 9 }, // never runs
            Instr::Ecall,
        ];
        let lp = fused(V0, prog.to_vec());
        assert_eq!(lp.ops[0].kind, Kind::Super, "run must actually fuse");
        diff_fused(V0, &prog, 100);
        // And explicitly: the fault pc is the mid-run constituent's.
        let mut m = Machine::from_instrs(V0, prog.to_vec(), 256).unwrap();
        let p = std::sync::Arc::clone(m.program());
        let err = run_lowered(&mut m, &lp, p.instrs(), 100, &mut NopHook)
            .unwrap_err();
        assert!(matches!(err, SimError::Mem { pc: 4, .. }), "{err}");
        assert_eq!(m.regs[1], 1, "prefix constituent committed");
        assert_eq!(m.regs[3], 0, "suffix constituent did not run");
    }

    #[test]
    fn branch_into_fused_run_middle_executes_scalar() {
        use AluImmOp::Addi;
        let prog = [
            Instr::Jal { rd: 0, offset: 12 }, // -> slot 3, mid-run
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 2 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 4 },
            Instr::Ecall,
        ];
        let lp = fused(V0, prog.to_vec());
        assert_eq!(lp.ops[1].kind, Kind::Super);
        diff_fused(V0, &prog, 100);
    }

    #[test]
    fn fused_zol_body_loops_back_after_marked_tail() {
        use AluImmOp::Addi;
        let prog = [
            Instr::Dlpi { count: 3, body_len: 2 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 2, imm: 1 }, // zmark
            Instr::Ecall,
        ];
        let lp = fused(V4, prog.to_vec());
        // The whole loop body fuses; the head carries the tail's mark.
        assert_eq!(lp.ops[1].kind, Kind::Super);
        assert_eq!(lp.ops[1].zmark, 1);
        for budget in 0..=9 {
            diff_fused(V4, &prog, budget);
        }
    }

    #[test]
    fn marked_op_only_ends_a_run_and_setze_disables_fusion() {
        use AluImmOp::Addi;
        // set.ze marks every op -> nothing fuses.
        let lp = fused(V4, vec![
            Instr::SetZe { rs1: 1 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 2, imm: 1 },
            Instr::Ecall,
        ]);
        assert_eq!(lp.n_superops(), 0);
    }

    #[test]
    fn profile_limits_fusion_to_hot_runs() {
        use AluImmOp::Addi;
        let instrs = vec![
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 2, imm: 1 },
            Instr::Jal { rd: 0, offset: 4 }, // splits the runs
            Instr::OpImm { op: Addi, rd: 3, rs1: 3, imm: 1 },
            Instr::OpImm { op: Addi, rd: 4, rs1: 4, imm: 1 },
            Instr::Ecall,
        ];
        let p = Program::from_instrs(V0, instrs).unwrap();
        // Only the first run is hot; the cold one must not fuse.
        let profile = std::sync::Arc::new(vec![100, 100, 50, 0, 0, 1]);
        let opts =
            LowerOpts { superops: true, profile: Some(profile) };
        let lp = LoweredProgram::lower_with(&p, &CycleModel::default(), &opts)
            .unwrap();
        assert_eq!(lp.n_superops(), 1);
        assert_eq!(lp.ops[0].kind, Kind::Super);
        assert_eq!(lp.ops[3].kind, Kind::Addi);
        // Without a profile both runs fuse.
        let all = LoweredProgram::lower_with(
            &p,
            &CycleModel::default(),
            &SUPER_ON,
        )
        .unwrap();
        assert_eq!(all.n_superops(), 2);
    }

    #[test]
    fn fused_lanes_match_scalar_fused_runs() {
        use AluImmOp::Addi;
        let prog = vec![
            Instr::OpImm { op: Addi, rd: 1, rs1: 0, imm: 3 },
            Instr::Dlpi { count: 4, body_len: 2 },
            Instr::OpImm { op: Addi, rd: 2, rs1: 2, imm: 5 },
            Instr::OpImm { op: Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ];
        let lp = fused(V4, prog.clone());
        let mk = || Machine::from_instrs(V4, prog.clone(), 64).unwrap();
        let mut lanes = [mk(), mk()];
        // Distinct budgets: lane 1 hits its watchdog mid-run.
        let budgets = [100u64, 3];
        let got = run_lanes::<2>(&mut lanes, &lp, &budgets);
        for (l, r) in got.iter().enumerate() {
            let mut s = mk();
            let want = s.run_reference(budgets[l], &mut NopHook);
            assert_eq!(format!("{r:?}"), format!("{want:?}"), "lane {l}");
            assert_eq!(lanes[l].regs, s.regs, "lane {l}");
            assert_eq!(lanes[l].pc, s.pc, "lane {l}");
        }
    }
}
