//! Data memory of the modified-Harvard core (paper §II.E.1): a flat
//! little-endian byte array backed by (on the FPGA) ZCU104 block RAM.
//! Program memory lives separately in [`crate::sim::cpu::Sim`] as predecoded
//! instructions.

/// Byte-addressable little-endian data memory.
pub struct Memory {
    bytes: Vec<u8>,
}

/// Access failure details (becomes a [`super::SimError`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemFault {
    pub addr: u32,
    pub size: u32,
    pub write: bool,
}

impl Memory {
    pub fn new(size: usize) -> Self {
        Memory { bytes: vec![0; size] }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    #[inline]
    fn check(&self, addr: u32, size: u32, write: bool) -> Result<usize, MemFault> {
        let a = addr as usize;
        // Natural alignment required (BRAM interface, single-cycle reads);
        // the end-of-access bound uses checked_add so `addr + size` cannot
        // wrap on 32-bit hosts and alias low memory.
        match a.checked_add(size as usize) {
            Some(end) if addr % size == 0 && end <= self.bytes.len() => Ok(a),
            _ => Err(MemFault { addr, size, write }),
        }
    }

    #[inline]
    pub fn load_u8(&self, addr: u32) -> Result<u8, MemFault> {
        let a = self.check(addr, 1, false)?;
        Ok(self.bytes[a])
    }

    #[inline]
    pub fn load_u16(&self, addr: u32) -> Result<u16, MemFault> {
        let a = self.check(addr, 2, false)?;
        Ok(u16::from_le_bytes([self.bytes[a], self.bytes[a + 1]]))
    }

    #[inline]
    pub fn load_u32(&self, addr: u32) -> Result<u32, MemFault> {
        let a = self.check(addr, 4, false)?;
        Ok(u32::from_le_bytes(
            self.bytes[a..a + 4].try_into().unwrap(),
        ))
    }

    #[inline]
    pub fn store_u8(&mut self, addr: u32, v: u8) -> Result<(), MemFault> {
        let a = self.check(addr, 1, true)?;
        self.bytes[a] = v;
        Ok(())
    }

    #[inline]
    pub fn store_u16(&mut self, addr: u32, v: u16) -> Result<(), MemFault> {
        let a = self.check(addr, 2, true)?;
        self.bytes[a..a + 2].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    #[inline]
    pub fn store_u32(&mut self, addr: u32, v: u32) -> Result<(), MemFault> {
        let a = self.check(addr, 4, true)?;
        self.bytes[a..a + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Bulk write (program loading / input injection).  The bounds math is
    /// overflow-safe: `addr + len` cannot wrap on 32-bit hosts.
    pub fn write_block(&mut self, addr: u32, data: &[u8]) -> Result<(), MemFault> {
        let a = addr as usize;
        match a.checked_add(data.len()) {
            Some(end) if end <= self.bytes.len() => {
                self.bytes[a..end].copy_from_slice(data);
                Ok(())
            }
            _ => Err(MemFault { addr, size: data.len() as u32, write: true }),
        }
    }

    /// Bulk read (output extraction), overflow-safe like [`Self::write_block`].
    pub fn read_block(&self, addr: u32, len: usize) -> Result<&[u8], MemFault> {
        let a = addr as usize;
        match a.checked_add(len) {
            Some(end) if end <= self.bytes.len() => Ok(&self.bytes[a..end]),
            _ => Err(MemFault { addr, size: len as u32, write: false }),
        }
    }

    /// Reset to `size` zeroed bytes, reusing the existing allocation — the
    /// pooled engine's per-run re-init (DESIGN.md §3).
    pub fn reset(&mut self, size: usize) {
        self.bytes.clear();
        self.bytes.resize(size, 0);
    }

    /// Reset to `size` bytes initialized from `image` (zero-padded tail),
    /// reusing the allocation.  One `copy_from_slice` of a prebuilt base
    /// image replaces zero-fill + per-block writes on the per-run path.
    pub fn reset_from(&mut self, image: &[u8], size: usize) -> Result<(), MemFault> {
        if image.len() > size {
            return Err(MemFault {
                addr: 0,
                size: image.len() as u32,
                write: true,
            });
        }
        self.bytes.clear();
        self.bytes.extend_from_slice(image);
        self.bytes.resize(size, 0);
        Ok(())
    }

    /// Per-run re-init in one call: [`Self::reset_from`] when a base image
    /// is present, [`Self::reset`] otherwise.  This is the per-lane DM
    /// re-init of the engine's lane packs (every lane reuses its pooled
    /// machine's allocation, DESIGN.md §15) and of the scalar pooled path.
    pub fn reinit(
        &mut self,
        image: Option<&[u8]>,
        size: usize,
    ) -> Result<(), MemFault> {
        match image {
            Some(img) => self.reset_from(img, size),
            None => {
                self.reset(size);
                Ok(())
            }
        }
    }

    /// Read `n` little-endian i32 words.
    pub fn read_i32s(&self, addr: u32, n: usize) -> Result<Vec<i32>, MemFault> {
        let raw = self.read_block(addr, n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Read `n` int8 values widened to i32.
    pub fn read_i8s(&self, addr: u32, n: usize) -> Result<Vec<i32>, MemFault> {
        let raw = self.read_block(addr, n)?;
        Ok(raw.iter().map(|&b| b as i8 as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip() {
        let mut m = Memory::new(64);
        m.store_u32(0, 0xdead_beef).unwrap();
        assert_eq!(m.load_u32(0).unwrap(), 0xdead_beef);
        assert_eq!(m.load_u8(0).unwrap(), 0xef); // little endian
        assert_eq!(m.load_u16(2).unwrap(), 0xdead);
        m.store_u8(5, 0x7f).unwrap();
        assert_eq!(m.load_u8(5).unwrap(), 0x7f);
    }

    #[test]
    fn bounds_checked() {
        let mut m = Memory::new(8);
        assert!(m.load_u32(8).is_err());
        assert!(m.load_u32(5).is_err()); // misaligned
        assert!(m.store_u16(7, 1).is_err());
        assert!(m.write_block(4, &[0; 8]).is_err());
        // near-wraparound addresses must fault, not alias low memory
        assert!(m.load_u32(u32::MAX - 3).is_err());
        assert!(m.store_u8(u32::MAX, 1).is_err());
        assert!(m.write_block(u32::MAX - 1, &[0; 4]).is_err());
        assert!(m.read_block(u32::MAX - 1, 4).is_err());
    }

    #[test]
    fn reset_reuses_and_reinitializes() {
        let mut m = Memory::new(16);
        m.store_u32(0, 0xdead_beef).unwrap();
        m.reset(8);
        assert_eq!(m.len(), 8);
        assert_eq!(m.read_block(0, 8).unwrap(), &[0u8; 8]);
        m.reset_from(&[1, 2, 3], 6).unwrap();
        assert_eq!(m.read_block(0, 6).unwrap(), &[1, 2, 3, 0, 0, 0]);
        // image larger than the requested size is a fault
        assert!(m.reset_from(&[0; 9], 8).is_err());
    }

    #[test]
    fn reinit_dispatches_on_image() {
        let mut m = Memory::new(4);
        m.reinit(Some(&[7, 8]), 4).unwrap();
        assert_eq!(m.read_block(0, 4).unwrap(), &[7, 8, 0, 0]);
        m.reinit(None, 3).unwrap();
        assert_eq!(m.read_block(0, 3).unwrap(), &[0u8; 3]);
        assert!(m.reinit(Some(&[0; 9]), 8).is_err());
    }

    #[test]
    fn typed_reads() {
        let mut m = Memory::new(16);
        m.store_u8(0, (-3i8) as u8).unwrap();
        m.store_u8(1, 100).unwrap();
        assert_eq!(m.read_i8s(0, 2).unwrap(), vec![-3, 100]);
        m.store_u32(4, (-7i32) as u32).unwrap();
        m.store_u32(8, 9 as u32).unwrap();
        assert_eq!(m.read_i32s(4, 2).unwrap(), vec![-7, 9]);
    }
}
