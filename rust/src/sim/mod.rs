//! The instruction/cycle-accurate RISC-V simulator (trv32p3 substitute).
//!
//! This is the substrate the paper gets from Synopsys ASIP Designer: an
//! instruction-accurate simulator of a 3-stage RV32IM core on which the
//! generated DNN C code is profiled, plus the five extended core variants of
//! Table 1.  Fig 11 notes the ASIP Designer simulation and the Vivado
//! hardware testbench produced identical counts — an ISS with the same cycle
//! model is therefore the faithful measurement instrument for every cycle
//! number in the evaluation (DESIGN.md §2).

//! The module is split along the program/state seam (DESIGN.md §3):
//! [`Program`] is the immutable decode-once image shared via `Arc`,
//! [`Machine`] the mutable per-run state, [`lowered`] the baked micro-op
//! form the hot loop actually executes (DESIGN.md §11), and [`engine`] the
//! batch layer that runs N inputs × M variants across pooled worker
//! threads.  Above the engine sit the process-scale layers (DESIGN.md
//! §12): [`shard`] partitions a batch across worker *processes* over a
//! line-JSON wire, and [`serve`] is the scheduling front for
//! latency-oriented inference requests — per-model fair queues, an
//! auto-tuned batching window and per-model SLO metrics (DESIGN.md §14).
//! [`exec`] is the seam over all of
//! them (DESIGN.md §13): one `Executor` trait + canonical `JobSpec` that
//! every sweep-style caller is written against, with `LocalExec`
//! (persistent in-process pool), `ShardExec` (process pool) and
//! `ClusterExec` ([`cluster`]: the shard wire over TCP, multi-host —
//! DESIGN.md §18) as the current backends, selected by a
//! `--backend local[:T]|shard:N|cluster:…` spec.

pub mod chaos;
pub mod cluster;
pub mod cpu;
pub mod engine;
pub mod exec;
pub mod hooks;
pub mod lowered;
pub mod memory;
pub mod program;
pub mod serve;
pub mod shard;

pub use chaos::{ChaosExec, FaultPlan};
pub use cluster::{ClusterExec, ClusterPool, LoopbackCluster};
pub use cpu::{Machine, RemoteKind, RunStats, Sim, SimError};
pub use engine::{default_lanes, default_superops, lane_stats,
                 lanes_override, run_batch, run_job, run_job_on,
                 run_job_pooled, run_lane_pack, superops_override, Job,
                 JobOutput, MAX_LANES};
pub use exec::{BackendSpec, Caps, ClusterTarget, Executor, JobSpec,
               LocalExec, RawJob, ShardExec};
pub use hooks::{NopHook, RetireHook, TraceHook};
pub use lowered::{LowerOpts, LoweredProgram, SUPEROP_TOPK};
pub use memory::Memory;
pub use program::Program;
pub use serve::{Client, PolicyKind, Reply, ReqMeta, SchedPolicy, ServeError,
                ServeModel, ServeOptions, ServeReport, Server, SloReport,
                Ticket};
pub use shard::{JobDesc, ShardPool, WorkerCmd};

/// A processor variant = which ISA extensions are enabled (paper Table 1),
/// plus which *mined* window slots ([`crate::fusion::WINDOW`]) the core
/// implements — `xwin` bit `i` enables slot `i`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Variant {
    pub name: &'static str,
    pub mac: bool,
    pub add2i: bool,
    pub fusedmac: bool,
    pub zol: bool,
    pub xwin: u8,
}

/// v0: baseline RV32IM (trv32p3).
pub const V0: Variant = Variant {
    name: "v0", mac: false, add2i: false, fusedmac: false, zol: false, xwin: 0,
};
/// v1: v0 + `mac`.
pub const V1: Variant = Variant {
    name: "v1", mac: true, add2i: false, fusedmac: false, zol: false, xwin: 0,
};
/// v2: v1 + `add2i`.
pub const V2: Variant = Variant {
    name: "v2", mac: true, add2i: true, fusedmac: false, zol: false, xwin: 0,
};
/// v3: v2 + `fusedmac`.
pub const V3: Variant = Variant {
    name: "v3", mac: true, add2i: true, fusedmac: true, zol: false, xwin: 0,
};
/// v4: v3 + zero-overhead hardware loops.
pub const V4: Variant = Variant {
    name: "v4", mac: true, add2i: true, fusedmac: true, zol: true, xwin: 0,
};

/// All five ladder variants, in Table 1 order.
pub const VARIANTS: [Variant; 5] = [V0, V1, V2, V3, V4];

/// Intern table for mined-variant names: `with_window` leaks each distinct
/// `"<base>+x<mask>"` string exactly once so [`Variant`] stays `Copy` with
/// a `&'static str` name (the property shard hydration depends on — a
/// variant travels across process boundaries as its name alone).
static XWIN_NAMES: std::sync::Mutex<Vec<&'static str>> =
    std::sync::Mutex::new(Vec::new());

impl Variant {
    /// Resolve a variant by name: the ladder names (`v0`..`v4`) or the
    /// mined form `"<base>+x<mask>"` (e.g. `"v4+x3"` = v4 with window
    /// slots 0 and 1).  Masks outside the spec pool reject — a worker
    /// must not silently hydrate a core it cannot execute.
    pub fn by_name(name: &str) -> Option<Variant> {
        if let Some(v) = VARIANTS.iter().copied().find(|v| v.name == name) {
            return Some(v);
        }
        let (base, mask) = name.split_once("+x")?;
        let base = VARIANTS.iter().copied().find(|v| v.name == base)?;
        let mask: u8 = mask.parse().ok()?;
        Variant::with_window(base, mask)
    }

    /// `base` extended with the window slots of `mask`.  `None` when the
    /// mask names slots outside [`crate::fusion::WINDOW`].
    pub fn with_window(base: Variant, mask: u8) -> Option<Variant> {
        if mask == 0 {
            return Some(base);
        }
        if base.xwin != 0 || usize::from(mask) >= (1 << crate::fusion::N_WINDOW)
        {
            return None;
        }
        let name = {
            let mut names = XWIN_NAMES.lock().unwrap();
            let want = format!("{}+x{}", base.name, mask);
            match names.iter().find(|n| **n == want) {
                Some(n) => *n,
                None => {
                    let leaked: &'static str = Box::leak(want.into_boxed_str());
                    names.push(leaked);
                    leaked
                }
            }
        };
        Some(Variant { name, xwin: mask, ..base })
    }

    /// Can this variant execute the given instruction?
    pub fn supports(&self, i: &crate::isa::Instr) -> bool {
        use crate::isa::Instr;
        match i {
            Instr::Mac => self.mac,
            Instr::Add2i { .. } => self.add2i,
            Instr::FusedMac { .. } => self.fusedmac,
            Instr::Dlp { .. }
            | Instr::Dlpi { .. }
            | Instr::Zlp { .. }
            | Instr::SetZc { .. }
            | Instr::SetZs { .. }
            | Instr::SetZe { .. } => self.zol,
            Instr::Custom { idx, .. } => self.xwin & (1u8 << idx) != 0,
            _ => true,
        }
    }
}

/// Per-class cycle costs of the 3-stage in-order pipeline (DESIGN.md §4).
///
/// Single-cycle BRAM gives 1-cycle loads/stores; `mul` is single-cycle on
/// the trv32p3 class (hence `mac` halving the mul+add pair, §II.C.1); taken
/// control flow refills the front of the 3-stage pipe (+1 bubble); the
/// iterative divider is multi-cycle but DNN codegen never emits it.
///
/// Equality matters operationally: [`Program::lowered`] memoizes one baked
/// micro-op image per distinct cycle model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CycleModel {
    pub alu: u64,
    pub mul: u64,
    pub div: u64,
    pub load: u64,
    pub store: u64,
    pub branch_taken: u64,
    pub branch_not_taken: u64,
    pub jump: u64,
    pub custom: u64,
    pub zol_setup: u64,
}

impl Default for CycleModel {
    fn default() -> Self {
        CycleModel {
            alu: 1,
            mul: 1,
            div: 18,
            load: 1,
            store: 1,
            branch_taken: 2,
            branch_not_taken: 1,
            jump: 2,
            custom: 1,
            zol_setup: 1,
        }
    }
}

#[cfg(test)]
mod variant_tests {
    use super::*;

    #[test]
    fn by_name_roundtrips_mined_variants() {
        let v = Variant::with_window(V4, 0b11).unwrap();
        assert_eq!(v.name, "v4+x3");
        assert_eq!(Variant::by_name(v.name), Some(v));
        // interning: same mask resolves to the same &'static str
        let again = Variant::with_window(V4, 0b11).unwrap();
        assert!(std::ptr::eq(v.name.as_ptr(), again.name.as_ptr()));
        // ladder names still resolve to the plain consts
        assert_eq!(Variant::by_name("v4"), Some(V4));
        assert_eq!(Variant::by_name("v4+x0"), Some(V4));
    }

    #[test]
    fn with_window_rejects_out_of_pool_masks() {
        let too_big = 1u8 << crate::fusion::N_WINDOW;
        assert_eq!(Variant::with_window(V4, too_big), None);
        assert_eq!(Variant::by_name("v4+x255"), None);
        assert_eq!(Variant::by_name("v9+x1"), None);
        assert_eq!(Variant::by_name("v4+x"), None);
    }

    #[test]
    fn xwin_gates_custom_instrs() {
        use crate::isa::Instr;
        let c0 = Instr::Custom { idx: 0, rs1: 5, rs2: 6, i1: 0, i2: 0 };
        let c1 = Instr::Custom { idx: 1, rs1: 5, rs2: 6, i1: 1, i2: 4 };
        assert!(!V4.supports(&c0));
        let v = Variant::with_window(V4, 0b01).unwrap();
        assert!(v.supports(&c0));
        assert!(!v.supports(&c1));
        let v = Variant::with_window(V4, 0b11).unwrap();
        assert!(v.supports(&c0) && v.supports(&c1));
    }
}
