//! The immutable, decode-once program image.
//!
//! [`Program`] is the shared half of the program/state split (DESIGN.md §3):
//! instructions are decoded and variant-gated exactly once, then the whole
//! image — predecoded [`Instr`]s plus the encoded PM words — is handed out
//! behind an `Arc` so any number of [`super::Machine`]s (across threads, see
//! [`super::engine`]) execute it without ever cloning the instruction
//! stream.  Mutable architectural state (registers, pc, ZOL registers, data
//! memory) lives exclusively in [`super::Machine`].

use std::sync::{Arc, Mutex, OnceLock};

use super::cpu::SimError;
use super::lowered::{LowerOpts, LoweredProgram};
use super::{CycleModel, Variant};
use crate::isa::decode::decode;
use crate::isa::encode::encode;
use crate::isa::Instr;

/// A validated, predecoded program for one processor variant.
///
/// Invariant: every instruction is supported by `variant`, and `words`
/// is the exact encoding of `instrs` (the PM image the hardware would
/// load).  Both are checked/derived at construction, so the execution
/// hot loop never re-validates.
pub struct Program {
    variant: Variant,
    instrs: Vec<Instr>,
    words: Vec<u32>,
    /// Memoized lowered forms, one per (cycle model, superops) pair seen
    /// (DESIGN.md §11, §19) — sweeps re-running one program on many
    /// [`super::Machine`]s lower it exactly once.  Profile-guided lowering
    /// (`LowerOpts::profile`) bypasses this cache: the profile is
    /// run-specific, so memoizing on the boolean alone would alias.
    lowered: Mutex<Vec<(CycleModel, bool, Arc<LoweredProgram>)>>,
    /// Memoized content fingerprint — per-job callers ([`Self::fingerprint`]
    /// via `shard::desc_for`) must not re-hash the PM image per request.
    fingerprint: OnceLock<u64>,
}

impl Program {
    /// Decode raw PM words and gate them against `variant`.
    ///
    /// Unsupported custom instructions are a load-time error: the hardware
    /// would trap on first execution, and failing early is strictly more
    /// useful for a compiler-driven flow.
    pub fn decode(variant: Variant, words: &[u32]) -> Result<Program, SimError> {
        let mut instrs = Vec::with_capacity(words.len());
        for (index, &w) in words.iter().enumerate() {
            let instr = decode(w).map_err(|err| SimError::Decode { index, err })?;
            if !variant.supports(&instr) {
                return Err(SimError::Unsupported {
                    index,
                    instr,
                    variant: variant.name,
                });
            }
            instrs.push(instr);
        }
        Ok(Program {
            variant,
            instrs,
            words: words.to_vec(),
            lowered: Mutex::new(Vec::new()),
            fingerprint: OnceLock::new(),
        })
    }

    /// Build from already-decoded instructions (the compiler's in-process
    /// pipeline); gates against `variant` and derives the PM image.
    pub fn from_instrs(
        variant: Variant,
        instrs: Vec<Instr>,
    ) -> Result<Program, SimError> {
        for (index, instr) in instrs.iter().enumerate() {
            if !variant.supports(instr) {
                return Err(SimError::Unsupported {
                    index,
                    instr: *instr,
                    variant: variant.name,
                });
            }
        }
        let words = instrs.iter().map(encode).collect();
        Ok(Program {
            variant,
            instrs,
            words,
            lowered: Mutex::new(Vec::new()),
            fingerprint: OnceLock::new(),
        })
    }

    /// Convenience: decode + wrap in the `Arc` the machines share.
    pub fn decode_shared(
        variant: Variant,
        words: &[u32],
    ) -> Result<Arc<Program>, SimError> {
        Ok(Arc::new(Program::decode(variant, words)?))
    }

    /// The variant this program was validated against.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Predecoded instruction stream.
    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    /// Encoded PM image (what the FPGA bitstream's BRAM would hold).
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Program-memory footprint in bytes (Table 10 PM column).
    pub fn pm_bytes(&self) -> u32 {
        (self.words.len() * 4) as u32
    }

    /// Content fingerprint (FNV-1a over the variant feature mask and the
    /// encoded PM words).  Two programs with the same fingerprint execute
    /// identically on the same inputs, so the shard layer uses it to verify
    /// that a worker's locally-hydrated compilation matches the
    /// coordinator's without shipping the instruction stream
    /// ([`crate::sim::shard`]).  Memoized: computed once per program, so
    /// per-request callers (the serve dispatcher, `PreparedFlow::specs`)
    /// never re-hash the PM image.
    pub fn fingerprint(&self) -> u64 {
        *self.fingerprint.get_or_init(|| {
            use crate::util::{fnv1a_extend, FNV_OFFSET};
            let flags = [
                self.variant.mac as u8,
                self.variant.add2i as u8,
                self.variant.fusedmac as u8,
                self.variant.zol as u8,
                self.variant.xwin,
            ];
            let mut h = fnv1a_extend(FNV_OFFSET, &flags);
            for w in &self.words {
                h = fnv1a_extend(h, &w.to_le_bytes());
            }
            h
        })
    }

    /// Lower to the baked micro-op form for `cm` (DESIGN.md §11).
    ///
    /// `None` when the combination cannot be lowered faithfully (cycle
    /// costs beyond `u32`, ZOL end addresses beyond `u32`); callers fall
    /// back to [`super::Machine::run_reference`].
    pub fn lower(&self, cm: &CycleModel) -> Option<LoweredProgram> {
        LoweredProgram::lower(self, cm)
    }

    /// [`Self::lower`] with explicit lowering options (superinstruction
    /// fusion, optional retire profile — DESIGN.md §19).
    pub fn lower_with(
        &self,
        cm: &CycleModel,
        opts: &LowerOpts,
    ) -> Option<LoweredProgram> {
        LoweredProgram::lower_with(self, cm, opts)
    }

    /// Memoizing [`Self::lower`]: the lowered image for `cm`, shared via
    /// `Arc` across every machine/run executing this program.
    pub fn lowered(&self, cm: &CycleModel) -> Option<Arc<LoweredProgram>> {
        self.lowered_with(cm, &LowerOpts::default())
    }

    /// Memoizing [`Self::lower_with`], keyed on `(cm, opts.superops)`.
    ///
    /// A run-specific retire profile defeats memoization by design: two
    /// profiles produce different fusion choices, so profile-guided images
    /// are rebuilt per call and never enter the cache.
    pub fn lowered_with(
        &self,
        cm: &CycleModel,
        opts: &LowerOpts,
    ) -> Option<Arc<LoweredProgram>> {
        if opts.profile.is_some() {
            return Some(Arc::new(self.lower_with(cm, opts)?));
        }
        {
            let cache = self.lowered.lock().unwrap();
            if let Some((_, _, lp)) = cache
                .iter()
                .find(|(c, s, _)| c == cm && *s == opts.superops)
            {
                return Some(Arc::clone(lp));
            }
        }
        // Lower outside the lock; a race builds the image twice but never
        // blocks other runs behind the (one-time, O(n)) lowering.
        let lp = Arc::new(self.lower_with(cm, opts)?);
        let mut cache = self.lowered.lock().unwrap();
        if let Some((_, _, existing)) = cache
            .iter()
            .find(|(c, s, _)| c == cm && *s == opts.superops)
        {
            return Some(Arc::clone(existing));
        }
        cache.push((*cm, opts.superops, Arc::clone(&lp)));
        Some(lp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::AluImmOp;
    use crate::sim::{V0, V4};

    #[test]
    fn from_instrs_encodes_words() {
        let instrs = vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 7 },
            Instr::Ecall,
        ];
        let p = Program::from_instrs(V0, instrs.clone()).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.pm_bytes(), 8);
        assert_eq!(p.instrs(), &instrs[..]);
        // words round-trip back to the same program
        let q = Program::decode(V0, p.words()).unwrap();
        assert_eq!(q.instrs(), p.instrs());
    }

    #[test]
    fn variant_gating_at_build() {
        let err = Program::from_instrs(V0, vec![Instr::Mac]);
        assert!(matches!(err, Err(SimError::Unsupported { .. })));
        assert!(Program::from_instrs(V4, vec![Instr::Mac]).is_ok());
    }

    #[test]
    fn lowered_is_memoized_per_cycle_model() {
        let p = Program::from_instrs(V0, vec![Instr::Ecall]).unwrap();
        let cm = CycleModel::default();
        let a = p.lowered(&cm).unwrap();
        let b = p.lowered(&cm).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same cycle model must share the image");
        let slow = CycleModel { alu: 3, ..cm };
        let c = p.lowered(&slow).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "distinct cycle models lower separately");
    }

    #[test]
    fn lowered_memo_keys_on_superops_and_skips_profiled_images() {
        let p = Program::from_instrs(
            V0,
            vec![
                Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 1 },
                Instr::OpImm { op: AluImmOp::Addi, rd: 2, rs1: 1, imm: 2 },
                Instr::Ecall,
            ],
        )
        .unwrap();
        let cm = CycleModel::default();
        let plain = p.lowered(&cm).unwrap();
        let on = LowerOpts { superops: true, profile: None };
        let fused = p.lowered_with(&cm, &on).unwrap();
        assert!(!Arc::ptr_eq(&plain, &fused), "superops key separates images");
        assert_eq!(plain.n_superops(), 0);
        assert_eq!(fused.n_superops(), 1);
        assert!(
            Arc::ptr_eq(&fused, &p.lowered_with(&cm, &on).unwrap()),
            "same (cm, superops) shares the image"
        );
        let profiled = LowerOpts {
            superops: true,
            profile: Some(Arc::new(vec![1, 1, 1])),
        };
        let a = p.lowered_with(&cm, &profiled).unwrap();
        let b = p.lowered_with(&cm, &profiled).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "profiled images bypass the memo");
    }

    #[test]
    fn shared_across_clones_is_same_allocation() {
        let p =
            Program::decode_shared(V0, &[crate::isa::encode::encode(&Instr::Ecall)])
                .unwrap();
        let q = Arc::clone(&p);
        assert!(std::ptr::eq(p.instrs().as_ptr(), q.instrs().as_ptr()));
    }
}
