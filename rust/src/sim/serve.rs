//! An async serving front over the batch engine: the repo's first
//! latency-oriented scenario next to the offline throughput sweeps
//! (DESIGN.md §12).
//!
//! Requests target precompiled `(model, variant)` pairs and are submitted
//! through a non-blocking channel; a dispatcher thread collects them into
//! batches bounded by a **time window** (first request arms a deadline) and
//! a **size cap**, then feeds the whole batch to an [`Executor`]
//! (DESIGN.md §13) — so the backend's pooling/parallelism amortizes across
//! concurrent callers the same way it does across a sweep, whether the
//! backend is the in-process pool (`--backend local`) or a shard of worker
//! processes (`--backend shard:N`).  "Async" here is channels + threads
//! (the offline toolchain has no executor runtime): [`Client::submit`]
//! never blocks on inference, and the ticket it returns is awaited
//! independently.
//!
//! Determinism: one batch's results are computed by the same contract as
//! the offline path, so a served inference is bit-identical to `marvel
//! run` / `run_flow` on the same `(model, variant, input)`, on every
//! backend.  Batching changes only latency, never logits or `RunStats` —
//! asserted by `tests/shard.rs` and the executor conformance suite.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cpu::RunStats;
use super::exec::{Executor, JobSpec};
use crate::compiler::{CompileCache, Compiled};
use crate::models;
use crate::sim::Variant;
use crate::util::json::{self, ObjBuilder};
use crate::util::rng::Rng;

/// Batching policy.  Parallelism is not configured here: it belongs to
/// the [`Executor`] the server batches into.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// How long after the first request of a batch the dispatcher waits
    /// for more before running.
    pub window: Duration,
    /// Hard batch-size cap: a full batch runs immediately.
    pub max_batch: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { window: Duration::from_millis(2), max_batch: 64 }
    }
}

/// One servable `(model, variant)` unit.
pub struct ServeModel {
    /// Registry key (see [`model_key`]).
    pub key: String,
    /// Model name in [`models::resolve`] syntax — the by-reference half of
    /// the [`JobSpec`]s this unit's requests become (the variant comes
    /// from `compiled`).
    pub model: String,
    pub compiled: Arc<Compiled>,
    /// Input image size in bytes (request validation).
    pub in_elems: usize,
    /// Logit count read back after a run.
    pub out_elems: usize,
}

/// Registry key for a `(model, variant)` pair: `"<model>@<variant>"`
/// (model names may themselves contain `:`, e.g. `synth:tiny:3`).
pub fn model_key(model: &str, variant: &str) -> String {
    format!("{model}@{variant}")
}

/// Compile every `models × variants` pair for serving (shared cache, so a
/// pair already compiled by a sweep is reused).
pub fn build_serve_models(
    artifacts: &std::path::Path,
    names: &[String],
    variants: &[Variant],
    cache: &CompileCache,
) -> Result<Vec<ServeModel>> {
    let mut out = Vec::new();
    for name in names {
        let spec = models::resolve(artifacts, name)
            .with_context(|| format!("loading model {name}"))?;
        let scache = cache.for_spec(&spec);
        for &v in variants {
            let compiled = scache
                .get_or_compile(v)
                .with_context(|| format!("compiling {name} for {}", v.name))?;
            out.push(ServeModel {
                key: model_key(name, v.name),
                model: name.clone(),
                compiled,
                in_elems: spec.input_elems(),
                out_elems: spec.output_elems(),
            });
        }
    }
    Ok(out)
}

/// A completed inference.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reply {
    /// int8 logits widened to i32 — bit-identical to the offline engine.
    pub output: Vec<i32>,
    pub stats: RunStats,
    /// How many requests shared this engine batch (observability: a loaded
    /// server should show > 1).
    pub batch_size: usize,
    /// Monotonic batch number.
    pub batch_seq: u64,
}

struct Pending {
    key: String,
    input: Vec<u8>,
    reply: mpsc::Sender<Result<Reply, String>>,
}

/// A ticket for an in-flight request: redeem with [`Ticket::wait`].
pub struct Ticket(mpsc::Receiver<Result<Reply, String>>);

impl Ticket {
    /// Block until the batch containing this request has run.
    pub fn wait(self) -> Result<Reply> {
        self.0
            .recv()
            .map_err(|_| anyhow!("serve dispatcher dropped the request"))?
            .map_err(|e| anyhow!(e))
    }
}

/// Cheap, clonable request submitter.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Pending>,
}

impl Client {
    /// Enqueue an inference without blocking on its execution.
    pub fn submit(&self, key: &str, input: Vec<u8>) -> Result<Ticket> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Pending { key: key.to_string(), input, reply: rtx })
            .map_err(|_| anyhow!("serve dispatcher is gone"))?;
        Ok(Ticket(rrx))
    }

    /// Submit + wait (the simple blocking call).
    pub fn infer(&self, key: &str, input: Vec<u8>) -> Result<Reply> {
        self.submit(key, input)?.wait()
    }
}

/// Handle to the dispatcher thread.  Dropping the last [`Client`] shuts the
/// dispatcher down; [`Server::join`] then returns the batch count.
pub struct Server {
    handle: std::thread::JoinHandle<u64>,
}

impl Server {
    /// Start a server over the given units, batching into `exec`; returns
    /// the server handle and the first client.  The executor moves onto
    /// the dispatcher thread — a persistent backend keeps its pools warm
    /// across every batch the server runs.
    pub fn start(
        units: Vec<ServeModel>,
        opts: ServeOptions,
        exec: Box<dyn Executor>,
    ) -> (Server, Client) {
        let (tx, rx) = mpsc::channel::<Pending>();
        let registry: HashMap<String, ServeModel> =
            units.into_iter().map(|u| (u.key.clone(), u)).collect();
        let handle =
            std::thread::spawn(move || dispatcher(rx, registry, opts, exec));
        (Server { handle }, Client { tx })
    }

    /// Wait for shutdown (all clients dropped); returns batches served.
    pub fn join(self) -> u64 {
        self.handle.join().expect("serve dispatcher panicked")
    }
}

fn dispatcher(
    rx: mpsc::Receiver<Pending>,
    registry: HashMap<String, ServeModel>,
    opts: ServeOptions,
    mut exec: Box<dyn Executor>,
) -> u64 {
    let max_batch = opts.max_batch.max(1);
    let mut batch_seq: u64 = 0;
    loop {
        // Arm the window on the first request of a batch.
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return batch_seq, // all clients gone
        };
        let deadline = Instant::now() + opts.window;
        let mut pending = vec![first];
        while pending.len() < max_batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(p) => pending.push(p),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        batch_seq += 1;

        // Validate against the registry; invalid requests answer
        // immediately and don't occupy a job slot.
        let mut runnable: Vec<&Pending> = Vec::with_capacity(pending.len());
        for p in &pending {
            match registry.get(&p.key) {
                None => {
                    let _ = p.reply.send(Err(format!(
                        "unknown model key {:?} (available: {:?})",
                        p.key,
                        {
                            let mut ks: Vec<&String> = registry.keys().collect();
                            ks.sort();
                            ks
                        }
                    )));
                }
                Some(u) if p.input.len() != u.in_elems => {
                    let _ = p.reply.send(Err(format!(
                        "{}: input is {} bytes, model wants {}",
                        p.key,
                        p.input.len(),
                        u.in_elems
                    )));
                }
                Some(_) => runnable.push(p),
            }
        }
        for p in &runnable {
            let u = &registry[&p.key];
            exec.submit(JobSpec::hydrated(
                &u.model,
                &u.compiled,
                u.out_elems,
                &p.input,
                1 << 36,
            ));
        }
        let results = exec.run();
        let size = runnable.len();
        for (p, r) in runnable.iter().zip(results) {
            let _ = p.reply.send(match r {
                Ok(o) => Ok(Reply {
                    output: o.output,
                    stats: o.stats,
                    batch_size: size,
                    batch_seq,
                }),
                Err(e) => Err(format!("{e}")),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Line protocol (the `marvel serve` CLI and the CI smoke)
// ---------------------------------------------------------------------------

/// Serve requests read as JSON lines, one response line per request, in
/// request order (responses for a batch are written as their tickets
/// resolve; ordering across batches follows submission).
///
/// Request: `{"id":1,"model":"synth:tiny:3","variant":"v4","input":"<hex>"}`
/// — or `"seed":N` instead of `"input"` for a deterministic random image
/// (CI smoke without shipping bytes).  Response:
/// `{"id":1,"output":[...],"instrs":..,"cycles":..,"batch":k}` or
/// `{"id":1,"error":"..."}`.
pub fn serve_lines(
    units: Vec<ServeModel>,
    opts: ServeOptions,
    exec: Box<dyn Executor>,
    input: impl BufRead,
    out: impl Write + Send,
) -> Result<()> {
    // Input sizes for seed-expansion, before the registry moves.
    let sizes: HashMap<String, usize> =
        units.iter().map(|u| (u.key.clone(), u.in_elems)).collect();
    let (server, client) = Server::start(units, opts, exec);

    // The reading loop submits without waiting (so requests read within one
    // window share a batch); a writer thread drains tickets in request
    // order, which keeps output incremental *and* deterministic.
    let (wtx, wrx) = mpsc::channel::<(u64, Result<Ticket, String>)>();
    let writer = std::thread::scope(|s| -> Result<()> {
        let writer = s.spawn(move || -> Result<()> {
            let mut out = out;
            for (id, t) in wrx {
                let b = ObjBuilder::new().set("id", id);
                let b = match t
                    .and_then(|t| t.wait().map_err(|e| format!("{e:#}")))
                {
                    Ok(r) => b
                        .set(
                            "output",
                            r.output
                                .iter()
                                .map(|&v| i64::from(v))
                                .collect::<Vec<i64>>(),
                        )
                        .set("instrs", r.stats.instrs)
                        .set("cycles", r.stats.cycles)
                        .set("batch", r.batch_size),
                    Err(e) => b.set("error", e),
                };
                writeln!(out, "{}", json::to_compact_string(&b.build()))?;
                out.flush()?;
            }
            Ok(())
        });
        for line in input.lines() {
            let line = line.context("reading request line")?;
            if line.trim().is_empty() {
                continue;
            }
            let (id, ticket) = match parse_request(&line, &sizes) {
                Ok((id, key, bytes)) => (
                    id,
                    client.submit(&key, bytes).map_err(|e| format!("{e:#}")),
                ),
                Err(e) => (request_id(&line), Err(format!("{e:#}"))),
            };
            let _ = wtx.send((id, ticket));
        }
        drop(wtx); // EOF: writer drains remaining tickets and exits
        drop(client); // dispatcher runs the tail batch, then shuts down
        writer.join().expect("serve writer panicked")
    });
    writer?;
    server.join();
    Ok(())
}

/// Best-effort id extraction for malformed requests (so the error response
/// still correlates).
fn request_id(line: &str) -> u64 {
    json::parse(line)
        .ok()
        .and_then(|v| v.get("id").ok().and_then(|i| i.as_u64().ok()))
        .unwrap_or(0)
}

fn parse_request(
    line: &str,
    sizes: &HashMap<String, usize>,
) -> Result<(u64, String, Vec<u8>)> {
    let v = json::parse(line)?;
    let id = v.get("id")?.as_u64()?;
    let key = model_key(v.get("model")?.as_str()?, v.get("variant")?.as_str()?);
    let bytes = match v.get_opt("input") {
        Some(h) => super::shard::from_hex(h.as_str()?)?,
        None => {
            let seed = v
                .get("seed")
                .context("request needs \"input\" hex or \"seed\"")?
                .as_u64()?;
            let n = *sizes
                .get(&key)
                .with_context(|| format!("unknown model key {key:?}"))?;
            let mut rng = Rng::new(seed);
            (0..n).map(|_| rng.int8() as i8 as u8).collect()
        }
    };
    Ok((id, key, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth::tiny_conv_net;
    use crate::sim::exec::LocalExec;
    use crate::sim::{V0, V4};

    fn units() -> Vec<ServeModel> {
        let cache = CompileCache::new();
        build_serve_models(
            std::path::Path::new("artifacts"),
            &["synth:tiny:3".to_string()],
            &[V0, V4],
            &cache,
        )
        .unwrap()
    }

    fn local_exec(threads: usize) -> Box<dyn Executor> {
        Box::new(LocalExec::new(std::path::Path::new("artifacts"), threads))
    }

    #[test]
    fn serve_matches_direct_execution() {
        let spec = tiny_conv_net(3);
        let mut rng = Rng::new(9);
        let input = crate::models::synth::Builder::random_input(&spec, &mut rng);
        let packed = crate::compiler::pack_input(&input).unwrap();
        let (want, want_stats) =
            crate::compiler::execute(&spec, V4, &input, 1 << 36).unwrap();

        let (server, client) =
            Server::start(units(), ServeOptions::default(), local_exec(0));
        let r = client
            .infer(&model_key("synth:tiny:3", "v4"), packed)
            .unwrap();
        assert_eq!(r.output, want);
        assert_eq!(r.stats, want_stats);
        assert!(r.batch_size >= 1);
        drop(client);
        assert_eq!(server.join(), 1);
    }

    #[test]
    fn bad_requests_answer_without_jobs() {
        let (server, client) =
            Server::start(units(), ServeOptions::default(), local_exec(1));
        let e = client.infer("nope@v4", vec![0; 4]).unwrap_err().to_string();
        assert!(e.contains("unknown model key"), "{e}");
        let e = client
            .infer(&model_key("synth:tiny:3", "v4"), vec![0; 3])
            .unwrap_err()
            .to_string();
        assert!(e.contains("input is 3 bytes"), "{e}");
        drop(client);
        server.join();
    }

    #[test]
    fn window_batches_concurrent_requests() {
        let spec = tiny_conv_net(3);
        let n_in = spec.input_elems();
        let opts =
            ServeOptions { window: Duration::from_millis(200), max_batch: 8 };
        let (server, client) = Server::start(units(), opts, local_exec(2));
        // Submit 4 requests inside one window, then wait: they must share
        // a batch (size > 1) and each match the offline engine.
        let tickets: Vec<(Vec<u8>, Ticket)> = (0..4u64)
            .map(|i| {
                let mut rng = Rng::new(100 + i);
                let bytes: Vec<u8> =
                    (0..n_in).map(|_| rng.int8() as i8 as u8).collect();
                let t = client
                    .submit(&model_key("synth:tiny:3", "v0"), bytes.clone())
                    .unwrap();
                (bytes, t)
            })
            .collect();
        for (bytes, t) in tickets {
            let r = t.wait().unwrap();
            let input: Vec<i32> =
                bytes.iter().map(|&b| b as i8 as i32).collect();
            let (want, want_stats) =
                crate::compiler::execute(&spec, V0, &input, 1 << 36).unwrap();
            assert_eq!(r.output, want);
            assert_eq!(r.stats, want_stats);
            assert_eq!(r.batch_size, 4, "requests must share the window");
            assert_eq!(r.batch_seq, 1);
        }
        drop(client);
        assert_eq!(server.join(), 1);
    }

    #[test]
    fn line_protocol_end_to_end() {
        let reqs = concat!(
            r#"{"id":1,"model":"synth:tiny:3","variant":"v4","seed":5}"#, "\n",
            r#"{"id":2,"model":"synth:tiny:3","variant":"nope","seed":5}"#, "\n",
            "not json\n",
        );
        let mut out = Vec::new();
        serve_lines(
            units(),
            ServeOptions::default(),
            local_exec(0),
            std::io::Cursor::new(reqs),
            &mut out,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        let r1 = json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").unwrap().as_u64().unwrap(), 1);
        assert!(r1.get_opt("output").is_some(), "{text}");
        assert!(r1.get("cycles").unwrap().as_u64().unwrap() > 0);
        let r2 = json::parse(lines[1]).unwrap();
        assert!(r2.get_opt("error").is_some(), "{text}");
        let r3 = json::parse(lines[2]).unwrap();
        assert!(r3.get_opt("error").is_some(), "{text}");
    }
}
