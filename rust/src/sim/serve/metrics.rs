//! Per-model latency histograms and the SLO report (DESIGN.md §14).
//!
//! The dispatcher records one sample per served request — client submit
//! to reply, so channel wait, queueing delay *and* execution are all
//! inside the number a caller actually experiences — into a log-bucketed
//! histogram per `(model, variant)` key.  Buckets double from 1 µs up
//! (32 buckets ≈ 71 minutes), which keeps recording O(1) — and, after a
//! model's first event, allocation-free — on the dispatcher thread, and
//! makes p50/p95/p99 a cheap cumulative walk with linear
//! interpolation inside the landing bucket (resolution: a factor-of-2
//! envelope, far below scheduling noise).  Admission rejections are
//! counted per key next to the latency data, so a tenant's SLO row shows
//! both how fast it was served and how much of its load was shed.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::{ObjBuilder, Value};
use crate::util::tables::Table;

/// Bucket `i` holds samples in `[2^i, 2^(i+1))` µs (bucket 0 also takes
/// sub-µs samples); the last bucket absorbs everything beyond.
const N_BUCKETS: usize = 32;

/// One model's latency histogram + admission/failure counters.
#[derive(Clone, Debug, Default)]
pub(crate) struct Hist {
    buckets: [u64; N_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
    under_slo: u64,
    rejected: u64,
    errored: u64,
}

impl Hist {
    fn bucket_of(us: u64) -> usize {
        // 0..=1 µs land in bucket 0; each bucket doubles the upper bound.
        ((64 - us.max(1).leading_zeros() as usize) - 1).min(N_BUCKETS - 1)
    }

    fn record(&mut self, latency: Duration, slo: Option<Duration>) {
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
        if slo.is_some_and(|s| latency <= s) {
            self.under_slo += 1;
        }
    }

    /// Quantile estimate in microseconds: cumulative walk to the landing
    /// bucket, linear interpolation across that bucket's `[lo, hi)` span.
    fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let before = seen;
            seen += n;
            if (seen as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (rank - before as f64) / n as f64;
                return (lo + (hi - lo) * frac).min(self.max_us as f64);
            }
        }
        self.max_us as f64
    }
}

/// Accumulates per-model service data on the dispatcher thread.
pub(crate) struct Metrics {
    slo: Option<Duration>,
    per_model: BTreeMap<String, Hist>,
}

impl Metrics {
    pub(crate) fn new(slo: Option<Duration>) -> Metrics {
        Metrics { slo, per_model: BTreeMap::new() }
    }

    /// The key's histogram; allocates the `String` key only on a model's
    /// first event, keeping steady-state recording allocation-free.
    fn hist_mut(&mut self, key: &str) -> &mut Hist {
        if self.per_model.contains_key(key) {
            return self.per_model.get_mut(key).unwrap();
        }
        self.per_model.entry(key.to_string()).or_default()
    }

    pub(crate) fn record(&mut self, key: &str, latency: Duration) {
        let slo = self.slo;
        self.hist_mut(key).record(latency, slo);
    }

    pub(crate) fn reject(&mut self, key: &str) {
        self.hist_mut(key).rejected += 1;
    }

    /// A dispatched job that answered with an engine error (watchdog,
    /// memory fault, remote failure): the caller got a reply, but not
    /// logits — kept out of the latency histogram and `served`.
    pub(crate) fn error(&mut self, key: &str) {
        self.hist_mut(key).errored += 1;
    }

    pub(crate) fn report(&self) -> SloReport {
        SloReport {
            slo_ms: self.slo.map(|s| s.as_secs_f64() * 1e3),
            rows: self
                .per_model
                .iter()
                .map(|(key, h)| ModelStats {
                    key: key.clone(),
                    served: h.count,
                    rejected: h.rejected,
                    errored: h.errored,
                    p50_ms: h.quantile_us(0.50) / 1e3,
                    p95_ms: h.quantile_us(0.95) / 1e3,
                    p99_ms: h.quantile_us(0.99) / 1e3,
                    mean_ms: if h.count == 0 {
                        0.0
                    } else {
                        h.sum_us as f64 / h.count as f64 / 1e3
                    },
                    max_ms: h.max_us as f64 / 1e3,
                    attainment: (self.slo.is_some() && h.count > 0)
                        .then(|| h.under_slo as f64 / h.count as f64),
                })
                .collect(),
        }
    }
}

/// One model's service summary (all latencies in milliseconds).
#[derive(Clone, Debug)]
pub struct ModelStats {
    /// Registry key (`"<model>@<variant>"`).
    pub key: String,
    /// Requests served (replied with logits); only these feed the
    /// latency quantiles.
    pub served: u64,
    /// Requests shed at admission (queue full).
    pub rejected: u64,
    /// Dispatched requests whose engine job failed (replied with an
    /// error, not logits).
    pub errored: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Fraction of served requests within the SLO (`--slo-ms`); `None`
    /// when no SLO was configured or nothing was served.
    pub attainment: Option<f64>,
}

/// The per-model latency/SLO report a server hands back on shutdown.
#[derive(Clone, Debug)]
pub struct SloReport {
    /// The configured SLO target, if any.
    pub slo_ms: Option<f64>,
    /// One row per `(model, variant)` key, sorted by key.
    pub rows: Vec<ModelStats>,
}

impl SloReport {
    /// Rendered table for logs/stderr.
    pub fn render(&self) -> String {
        let title = match self.slo_ms {
            Some(slo) => format!("serve SLO report — target {slo:.1} ms"),
            None => "serve latency report — no SLO configured".to_string(),
        };
        let mut t = Table::new(&[
            "model@variant", "served", "rejected", "errored", "p50 ms",
            "p95 ms", "p99 ms", "mean ms", "max ms", "SLO att.",
        ])
        .with_title(&title);
        for r in &self.rows {
            t.row(vec![
                r.key.clone(),
                r.served.to_string(),
                r.rejected.to_string(),
                r.errored.to_string(),
                format!("{:.3}", r.p50_ms),
                format!("{:.3}", r.p95_ms),
                format!("{:.3}", r.p99_ms),
                format!("{:.3}", r.mean_ms),
                format!("{:.3}", r.max_ms),
                match r.attainment {
                    Some(a) => format!("{:.1}%", a * 100.0),
                    None => "-".to_string(),
                },
            ]);
        }
        t.render()
    }

    /// Machine-readable form of the report (latencies in ms).  Note: the
    /// serve bench does NOT use this — `BENCH_serve.json` rows are flat
    /// `p99_s`-style objects written by `benches/common.rs` for the
    /// gate/trend tools.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                let b = ObjBuilder::new()
                    .set("key", r.key.as_str())
                    .set("served", r.served)
                    .set("rejected", r.rejected)
                    .set("errored", r.errored)
                    .set("p50_ms", r.p50_ms)
                    .set("p95_ms", r.p95_ms)
                    .set("p99_ms", r.p99_ms)
                    .set("mean_ms", r.mean_ms)
                    .set("max_ms", r.max_ms);
                match r.attainment {
                    Some(a) => b.set("slo_attainment", a).build(),
                    None => b.build(),
                }
            })
            .collect();
        let b = ObjBuilder::new().set("rows", rows);
        match self.slo_ms {
            Some(slo) => b.set("slo_ms", slo).build(),
            None => b.build(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn bucket_of_doubles() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 0);
        assert_eq!(Hist::bucket_of(2), 1);
        assert_eq!(Hist::bucket_of(3), 1);
        assert_eq!(Hist::bucket_of(4), 2);
        assert_eq!(Hist::bucket_of(1024), 10);
        assert_eq!(Hist::bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_the_distribution_envelope() {
        let mut h = Hist::default();
        // 90 fast samples (~1 ms), 10 slow (~64 ms).
        for _ in 0..90 {
            h.record(ms(1), None);
        }
        for _ in 0..10 {
            h.record(ms(64), None);
        }
        let p50 = h.quantile_us(0.50) / 1e3;
        let p99 = h.quantile_us(0.99) / 1e3;
        assert!((0.5..=1.1).contains(&p50), "p50 {p50}");
        assert!((32.0..=64.1).contains(&p99), "p99 {p99}");
        assert!(p50 < p99);
        // Quantiles never exceed the observed max.
        assert!(h.quantile_us(1.0) <= h.max_us as f64);
    }

    #[test]
    fn empty_hist_reports_zeros() {
        let h = Hist::default();
        assert_eq!(h.quantile_us(0.99), 0.0);
    }

    #[test]
    fn slo_attainment_counts_at_record_time() {
        let mut m = Metrics::new(Some(ms(10)));
        m.record("a@v4", ms(2));
        m.record("a@v4", ms(4));
        m.record("a@v4", ms(50));
        m.reject("a@v4");
        m.error("a@v4");
        m.record("b@v0", ms(1));
        let r = m.report();
        assert_eq!(r.slo_ms, Some(10.0));
        assert_eq!(r.rows.len(), 2);
        let a = &r.rows[0];
        assert_eq!(
            (a.key.as_str(), a.served, a.rejected, a.errored),
            ("a@v4", 3, 1, 1)
        );
        let att = a.attainment.unwrap();
        assert!((att - 2.0 / 3.0).abs() < 1e-9, "{att}");
        assert!(a.max_ms >= 50.0 && a.max_ms < 51.0);
        // Render + JSON smoke: every row appears.
        let text = r.render();
        assert!(text.contains("a@v4") && text.contains("b@v0"), "{text}");
        let j = r.to_json();
        assert_eq!(j.get("slo_ms").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn no_slo_means_no_attainment_column() {
        let mut m = Metrics::new(None);
        m.record("a@v4", ms(2));
        let r = m.report();
        assert_eq!(r.slo_ms, None);
        assert_eq!(r.rows[0].attainment, None);
        assert!(r.to_json().get_opt("slo_ms").is_none());
    }
}
