//! Per-model latency histograms and the SLO report (DESIGN.md §14).
//!
//! The dispatcher records one sample per served request — client submit
//! to reply, so channel wait, queueing delay *and* execution are all
//! inside the number a caller actually experiences — into a log-bucketed
//! histogram per `(model, variant)` key.  Buckets double from 1 µs up
//! (32 buckets ≈ 71 minutes), which keeps recording O(1) — and, after a
//! model's first event, allocation-free — on the dispatcher thread, and
//! makes p50/p95/p99 a cheap cumulative walk with linear
//! interpolation inside the landing bucket (resolution: a factor-of-2
//! envelope, far below scheduling noise).  Admission rejections are
//! counted per key next to the latency data, so a tenant's SLO row shows
//! both how fast it was served and how much of its load was shed.
//!
//! Two robustness additions (DESIGN.md §16): per-request **deadline
//! accounting** (met / missed / shed-at-admission, summarized as
//! *goodput* — the fraction of deadline-carrying requests that made
//! their deadline), and **windowed rollover** — with a window configured
//! (`--slo-window-ms`) the recorder keeps a second, recent-window set of
//! histograms and [`Metrics::roll_if_due`] snapshots + resets it
//! periodically, so a long-running server can report *recent*
//! p50/p95/p99/attainment instead of lifetime aggregates that stale
//! history dominates.  The lifetime report is unchanged and still what
//! shutdown returns.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::{ObjBuilder, Value};
use crate::util::tables::Table;

/// Bucket `i` holds samples in `[2^i, 2^(i+1))` µs (bucket 0 also takes
/// sub-µs samples); the last bucket absorbs everything beyond.
const N_BUCKETS: usize = 32;

/// One model's latency histogram + admission/failure/deadline counters.
#[derive(Clone, Debug, Default)]
pub(crate) struct Hist {
    buckets: [u64; N_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
    under_slo: u64,
    rejected: u64,
    errored: u64,
    /// Shed at admission because the deadline was already infeasible.
    shed: u64,
    /// Served deadline-carrying requests that made their deadline.
    dl_met: u64,
    /// Served deadline-carrying requests that replied past it.
    dl_missed: u64,
}

impl Hist {
    fn bucket_of(us: u64) -> usize {
        // 0..=1 µs land in bucket 0; each bucket doubles the upper bound.
        ((64 - us.max(1).leading_zeros() as usize) - 1).min(N_BUCKETS - 1)
    }

    fn record(
        &mut self,
        latency: Duration,
        slo: Option<Duration>,
        deadline_met: Option<bool>,
    ) {
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
        if slo.is_some_and(|s| latency <= s) {
            self.under_slo += 1;
        }
        match deadline_met {
            Some(true) => self.dl_met += 1,
            Some(false) => self.dl_missed += 1,
            None => {}
        }
    }

    /// Quantile estimate in microseconds: cumulative walk to the landing
    /// bucket, linear interpolation across that bucket's `[lo, hi)` span.
    fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let before = seen;
            seen += n;
            if (seen as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (rank - before as f64) / n as f64;
                return (lo + (hi - lo) * frac).min(self.max_us as f64);
            }
        }
        self.max_us as f64
    }
}

/// Rollover state for the recent-window histograms (`--slo-window-ms`).
struct WindowState {
    len: Duration,
    started: Instant,
    /// Completed-window ordinal (1-based in emitted snapshots).
    rolled: u64,
    recent: BTreeMap<String, Hist>,
}

/// Accumulates per-model service data on the dispatcher thread.
pub(crate) struct Metrics {
    slo: Option<Duration>,
    per_model: BTreeMap<String, Hist>,
    window: Option<WindowState>,
}

/// The key's histogram in `map`; allocates the `String` key only on a
/// model's first event, keeping steady-state recording allocation-free.
fn hist_of<'m>(map: &'m mut BTreeMap<String, Hist>, key: &str) -> &'m mut Hist {
    if map.contains_key(key) {
        return map.get_mut(key).unwrap();
    }
    map.entry(key.to_string()).or_default()
}

impl Metrics {
    pub(crate) fn new(
        slo: Option<Duration>,
        window: Option<Duration>,
    ) -> Metrics {
        Metrics {
            slo,
            per_model: BTreeMap::new(),
            window: window.filter(|w| !w.is_zero()).map(|len| WindowState {
                len,
                started: Instant::now(),
                rolled: 0,
                recent: BTreeMap::new(),
            }),
        }
    }

    /// Apply one event to the lifetime histogram and, when a window is
    /// configured, to the recent-window histogram too.
    fn each_hist(&mut self, key: &str, f: impl Fn(&mut Hist)) {
        f(hist_of(&mut self.per_model, key));
        if let Some(w) = &mut self.window {
            f(hist_of(&mut w.recent, key));
        }
    }

    /// `deadline_met` is `Some` for deadline-carrying requests: whether
    /// the reply landed inside the deadline (goodput accounting).
    pub(crate) fn record(
        &mut self,
        key: &str,
        latency: Duration,
        deadline_met: Option<bool>,
    ) {
        let slo = self.slo;
        self.each_hist(key, |h| h.record(latency, slo, deadline_met));
    }

    pub(crate) fn reject(&mut self, key: &str) {
        self.each_hist(key, |h| h.rejected += 1);
    }

    /// A deadline-carrying request shed at admission because it could not
    /// make its deadline (counts against goodput, separate from queue-full
    /// rejections).
    pub(crate) fn shed(&mut self, key: &str) {
        self.each_hist(key, |h| h.shed += 1);
    }

    /// A dispatched job that answered with an engine error (watchdog,
    /// memory fault, remote failure): the caller got a reply, but not
    /// logits — kept out of the latency histogram and `served`.
    pub(crate) fn error(&mut self, key: &str) {
        self.each_hist(key, |h| h.errored += 1);
    }

    /// Roll the recent window if one is configured and due: returns the
    /// completed window's snapshot (when it saw any event) and resets the
    /// recent histograms.  The lifetime report is untouched.
    pub(crate) fn roll_if_due(&mut self, now: Instant) -> Option<SloReport> {
        let slo = self.slo;
        let w = self.window.as_mut()?;
        if now.saturating_duration_since(w.started) < w.len {
            return None;
        }
        w.started = now;
        w.rolled += 1;
        if w.recent.is_empty() {
            return None; // idle window: nothing to report
        }
        let mut report = report_of(slo, &w.recent);
        report.window = Some(w.rolled);
        w.recent.clear();
        Some(report)
    }

    pub(crate) fn report(&self) -> SloReport {
        report_of(self.slo, &self.per_model)
    }
}

/// Build an [`SloReport`] from one histogram set (lifetime or a window).
fn report_of(
    slo: Option<Duration>,
    per_model: &BTreeMap<String, Hist>,
) -> SloReport {
    SloReport {
        slo_ms: slo.map(|s| s.as_secs_f64() * 1e3),
        window: None,
        rows: per_model
            .iter()
            .map(|(key, h)| ModelStats {
                key: key.clone(),
                served: h.count,
                rejected: h.rejected,
                errored: h.errored,
                shed: h.shed,
                deadline_met: h.dl_met,
                deadline_missed: h.dl_missed,
                p50_ms: h.quantile_us(0.50) / 1e3,
                p95_ms: h.quantile_us(0.95) / 1e3,
                p99_ms: h.quantile_us(0.99) / 1e3,
                mean_ms: if h.count == 0 {
                    0.0
                } else {
                    h.sum_us as f64 / h.count as f64 / 1e3
                },
                max_ms: h.max_us as f64 / 1e3,
                attainment: (slo.is_some() && h.count > 0)
                    .then(|| h.under_slo as f64 / h.count as f64),
                goodput: {
                    let dl_total = h.dl_met + h.dl_missed + h.shed;
                    (dl_total > 0)
                        .then(|| h.dl_met as f64 / dl_total as f64)
                },
            })
            .collect(),
    }
}

/// One model's service summary (all latencies in milliseconds).
#[derive(Clone, Debug)]
pub struct ModelStats {
    /// Registry key (`"<model>@<variant>"`).
    pub key: String,
    /// Requests served (replied with logits); only these feed the
    /// latency quantiles.
    pub served: u64,
    /// Requests rejected at admission (queue full).
    pub rejected: u64,
    /// Dispatched requests whose engine job failed (replied with an
    /// error, not logits).
    pub errored: u64,
    /// Deadline-carrying requests shed at admission as infeasible.
    pub shed: u64,
    /// Served deadline-carrying requests that made their deadline.
    pub deadline_met: u64,
    /// Served deadline-carrying requests that replied past it.
    pub deadline_missed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Fraction of served requests within the SLO (`--slo-ms`); `None`
    /// when no SLO was configured or nothing was served.
    pub attainment: Option<f64>,
    /// Goodput under deadline: `met / (met + missed + shed)` over the
    /// deadline-carrying requests; `None` when none carried a deadline.
    pub goodput: Option<f64>,
}

/// The per-model latency/SLO report a server hands back on shutdown.
#[derive(Clone, Debug)]
pub struct SloReport {
    /// The configured SLO target, if any.
    pub slo_ms: Option<f64>,
    /// `Some(n)` when this is the n-th *windowed* snapshot
    /// (`--slo-window-ms`) rather than the lifetime report.
    pub window: Option<u64>,
    /// One row per `(model, variant)` key, sorted by key.
    pub rows: Vec<ModelStats>,
}

impl SloReport {
    /// Rendered table for logs/stderr.
    pub fn render(&self) -> String {
        let mut title = match self.slo_ms {
            Some(slo) => format!("serve SLO report — target {slo:.1} ms"),
            None => "serve latency report — no SLO configured".to_string(),
        };
        if let Some(n) = self.window {
            title.push_str(&format!(" (window #{n})"));
        }
        let mut t = Table::new(&[
            "model@variant", "served", "rejected", "errored", "shed",
            "p50 ms", "p95 ms", "p99 ms", "mean ms", "max ms", "SLO att.",
            "goodput",
        ])
        .with_title(&title);
        for r in &self.rows {
            t.row(vec![
                r.key.clone(),
                r.served.to_string(),
                r.rejected.to_string(),
                r.errored.to_string(),
                r.shed.to_string(),
                format!("{:.3}", r.p50_ms),
                format!("{:.3}", r.p95_ms),
                format!("{:.3}", r.p99_ms),
                format!("{:.3}", r.mean_ms),
                format!("{:.3}", r.max_ms),
                match r.attainment {
                    Some(a) => format!("{:.1}%", a * 100.0),
                    None => "-".to_string(),
                },
                match r.goodput {
                    Some(g) => format!("{:.1}%", g * 100.0),
                    None => "-".to_string(),
                },
            ]);
        }
        t.render()
    }

    /// Machine-readable form of the report (latencies in ms).  Note: the
    /// serve bench does NOT use this — `BENCH_serve.json` rows are flat
    /// `p99_s`-style objects written by `benches/common.rs` for the
    /// gate/trend tools.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                let mut b = ObjBuilder::new()
                    .set("key", r.key.as_str())
                    .set("served", r.served)
                    .set("rejected", r.rejected)
                    .set("errored", r.errored)
                    .set("shed", r.shed)
                    .set("deadline_met", r.deadline_met)
                    .set("deadline_missed", r.deadline_missed)
                    .set("p50_ms", r.p50_ms)
                    .set("p95_ms", r.p95_ms)
                    .set("p99_ms", r.p99_ms)
                    .set("mean_ms", r.mean_ms)
                    .set("max_ms", r.max_ms);
                if let Some(a) = r.attainment {
                    b = b.set("slo_attainment", a);
                }
                if let Some(g) = r.goodput {
                    b = b.set("goodput", g);
                }
                b.build()
            })
            .collect();
        let mut b = ObjBuilder::new().set("rows", rows);
        if let Some(slo) = self.slo_ms {
            b = b.set("slo_ms", slo);
        }
        if let Some(n) = self.window {
            b = b.set("window", n);
        }
        b.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn bucket_of_doubles() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 0);
        assert_eq!(Hist::bucket_of(2), 1);
        assert_eq!(Hist::bucket_of(3), 1);
        assert_eq!(Hist::bucket_of(4), 2);
        assert_eq!(Hist::bucket_of(1024), 10);
        assert_eq!(Hist::bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_the_distribution_envelope() {
        let mut h = Hist::default();
        // 90 fast samples (~1 ms), 10 slow (~64 ms).
        for _ in 0..90 {
            h.record(ms(1), None, None);
        }
        for _ in 0..10 {
            h.record(ms(64), None, None);
        }
        let p50 = h.quantile_us(0.50) / 1e3;
        let p99 = h.quantile_us(0.99) / 1e3;
        assert!((0.5..=1.1).contains(&p50), "p50 {p50}");
        assert!((32.0..=64.1).contains(&p99), "p99 {p99}");
        assert!(p50 < p99);
        // Quantiles never exceed the observed max.
        assert!(h.quantile_us(1.0) <= h.max_us as f64);
    }

    #[test]
    fn empty_hist_reports_zeros() {
        let h = Hist::default();
        assert_eq!(h.quantile_us(0.99), 0.0);
    }

    #[test]
    fn slo_attainment_counts_at_record_time() {
        let mut m = Metrics::new(Some(ms(10)), None);
        m.record("a@v4", ms(2), None);
        m.record("a@v4", ms(4), None);
        m.record("a@v4", ms(50), None);
        m.reject("a@v4");
        m.error("a@v4");
        m.record("b@v0", ms(1), None);
        let r = m.report();
        assert_eq!(r.slo_ms, Some(10.0));
        assert_eq!(r.rows.len(), 2);
        let a = &r.rows[0];
        assert_eq!(
            (a.key.as_str(), a.served, a.rejected, a.errored),
            ("a@v4", 3, 1, 1)
        );
        let att = a.attainment.unwrap();
        assert!((att - 2.0 / 3.0).abs() < 1e-9, "{att}");
        assert!(a.max_ms >= 50.0 && a.max_ms < 51.0);
        assert_eq!(a.goodput, None, "no deadline-carrying requests");
        // Render + JSON smoke: every row appears.
        let text = r.render();
        assert!(text.contains("a@v4") && text.contains("b@v0"), "{text}");
        let j = r.to_json();
        assert_eq!(j.get("slo_ms").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn no_slo_means_no_attainment_column() {
        let mut m = Metrics::new(None, None);
        m.record("a@v4", ms(2), None);
        let r = m.report();
        assert_eq!(r.slo_ms, None);
        assert_eq!(r.rows[0].attainment, None);
        assert!(r.to_json().get_opt("slo_ms").is_none());
    }

    #[test]
    fn goodput_counts_met_missed_and_shed() {
        let mut m = Metrics::new(None, None);
        m.record("a@v4", ms(2), Some(true));
        m.record("a@v4", ms(2), Some(true));
        m.record("a@v4", ms(30), Some(false));
        m.shed("a@v4");
        let r = m.report();
        let a = &r.rows[0];
        assert_eq!(
            (a.served, a.shed, a.deadline_met, a.deadline_missed),
            (3, 1, 2, 1)
        );
        let g = a.goodput.unwrap();
        assert!((g - 0.5).abs() < 1e-9, "2 met of 4 deadline-carrying: {g}");
        let j = r.to_json();
        let row = &j.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("shed").unwrap().as_u64().unwrap(), 1);
        assert_eq!(row.get("goodput").unwrap().as_f64().unwrap(), 0.5);
    }

    #[test]
    fn window_rollover_snapshots_recent_not_lifetime() {
        let mut m = Metrics::new(Some(ms(10)), Some(ms(100)));
        let t0 = Instant::now();
        // Window 1: two slow samples.
        m.record("a@v4", ms(50), None);
        m.record("a@v4", ms(50), None);
        assert!(m.roll_if_due(t0).is_none(), "not due yet");
        let snap = m.roll_if_due(t0 + ms(150)).unwrap();
        assert_eq!(snap.window, Some(1));
        assert_eq!(snap.rows[0].served, 2);
        assert!(snap.rows[0].p50_ms > 10.0, "window 1 is slow");
        // Window 2: one fast sample — the snapshot must NOT be dominated
        // by window 1's history.
        m.record("a@v4", ms(1), None);
        let snap = m.roll_if_due(t0 + ms(300)).unwrap();
        assert_eq!(snap.window, Some(2));
        assert_eq!(snap.rows[0].served, 1, "recent only");
        assert!(snap.rows[0].p50_ms <= 2.0, "window 2 is fast: {snap:?}");
        // An idle window yields no snapshot (but still advances).
        assert!(m.roll_if_due(t0 + ms(500)).is_none());
        // The lifetime report still aggregates everything.
        let life = m.report();
        assert_eq!(life.window, None);
        assert_eq!(life.rows[0].served, 3);
    }

    #[test]
    fn no_window_configured_never_rolls() {
        let mut m = Metrics::new(None, None);
        m.record("a@v4", ms(1), None);
        assert!(m
            .roll_if_due(Instant::now() + ms(1 << 20))
            .is_none());
    }
}
