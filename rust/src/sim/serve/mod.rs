//! The serving front: a scheduler subsystem over the batch engine
//! (DESIGN.md §14; the original single-FIFO front was §12).
//!
//! Requests target precompiled `(model, variant)` pairs and are submitted
//! through a non-blocking channel ([`Client::submit`] → [`Ticket`]).  The
//! dispatcher validates each arrival against the registry, admits it into
//! its model's **bounded queue** ([`queue`], `--queue-cap`; admission
//! pressure answers the ticket with a structured error instead of growing
//! an unbounded backlog), and forms engine batches by asking a
//! **scheduling policy** ([`policy`]: strict [`policy::Fifo`] or
//! [`policy::DeficitRoundRobin`] fairness) to drain the queues.  The
//! batching **window auto-tunes** from an EWMA of the observed arrival
//! gap — it stretches toward `--window-max` when requests trickle and
//! shrinks toward `--window-min` under load, targeting just enough
//! arrivals to fill the executor's parallel lanes
//! ([`crate::sim::exec::Caps::parallelism`]).  Each batch feeds a
//! `Box<dyn Executor>` (DESIGN.md §13), so `local` and `shard:N` backends
//! serve identically; per-request latency (client submit → reply, so
//! channel wait during a busy batch is counted) lands in per-model
//! histograms ([`metrics`]) and [`Server::join`] returns the SLO report.
//!
//! Determinism: one batch's results are computed by the same contract as
//! the offline path, so a served inference is bit-identical to `marvel
//! run` / `run_flow` on the same `(model, variant, input)`, on every
//! backend and under every policy.  Scheduling changes only *latency* —
//! which batch a request rides in — never logits or `RunStats`
//! (`tests/serve_sched.rs`, `tests/shard.rs`, the exec conformance
//! suite).
//!
//! Overload contract (DESIGN.md §16): requests may carry a
//! **deadline** (`"deadline_ms"`) and a **priority** (`"priority"`,
//! 0–255) that the EDF policy ([`policy::Edf`]) schedules by; a
//! deadline-carrying request that *cannot* make its deadline — already
//! past it, or past it once the EWMA-estimated batch cost is added — is
//! **shed at admission** with a structured `deadline` error instead of
//! wasting a job slot; a full per-model queue **rejects** with an
//! `overload` error carrying a `retry_after_ms` hint; and a full
//! submission channel **backpressures** the same way without blocking.
//! Every error is a typed [`ServeError`] (kind + message +
//! optional retry-after), rendered as a JSON object on the line
//! protocol, so clients can back off instead of tearing down.

pub mod metrics;
pub mod policy;
pub mod queue;

pub use metrics::{ModelStats, SloReport};
pub use policy::{BatchHint, PolicyKind, SchedPolicy};
pub use queue::{Pending, QueueSet};

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cpu::RunStats;
use super::exec::{Executor, JobSpec};
use crate::compiler::{CompileCache, Compiled};
use crate::models;
use crate::sim::Variant;
use crate::util::json::{self, ObjBuilder};
use crate::util::rng::Rng;

use metrics::Metrics;

/// Scheduler configuration.  Parallelism is not configured here: it
/// belongs to the [`Executor`] the server batches into (and feeds back
/// into the window tuner via [`super::exec::Caps::parallelism`]).
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Lower bound of the auto-tuned batching window.
    pub window_min: Duration,
    /// Upper bound of the auto-tuned batching window (also the window
    /// used before any arrival-rate data exists).
    pub window_max: Duration,
    /// Hard batch-size cap: a full queue set stops collecting and runs.
    pub max_batch: usize,
    /// Per-model queue bound; admission past it rejects the request with
    /// a structured [`Ticket`] error.
    pub queue_cap: usize,
    /// Batch-forming discipline across the per-model queues.
    pub policy: PolicyKind,
    /// Latency target for the SLO-attainment column of the final report.
    pub slo: Option<Duration>,
    /// Windowed-snapshot period (`--slo-window-ms`): when set, the
    /// dispatcher emits a *recent-traffic* SLO snapshot to stderr every
    /// time the window elapses (and resets the windowed counters), on
    /// top of the lifetime report [`Server::join`] returns.  `None`
    /// keeps the legacy lifetime-only accounting.
    pub slo_window: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            window_min: Duration::from_millis(1),
            window_max: Duration::from_millis(8),
            max_batch: 64,
            queue_cap: 1024,
            policy: PolicyKind::Fifo,
            slo: None,
            slo_window: None,
        }
    }
}

impl ServeOptions {
    /// Pin the batching window to exactly `w` (no auto-tuning) — the
    /// legacy fixed-window behavior, and what `--window-ms` sets.
    pub fn fixed_window(mut self, w: Duration) -> Self {
        self.window_min = w;
        self.window_max = w;
        self
    }
}

/// One servable `(model, variant)` unit.
pub struct ServeModel {
    /// Registry key (see [`model_key`]).
    pub key: String,
    /// Model name in [`models::resolve`] syntax — the by-reference half of
    /// the [`JobSpec`]s this unit's requests become (the variant comes
    /// from `compiled`).
    pub model: String,
    pub compiled: Arc<Compiled>,
    /// Input image size in bytes (request validation).
    pub in_elems: usize,
    /// Logit count read back after a run.
    pub out_elems: usize,
}

/// Registry key for a `(model, variant)` pair: `"<model>@<variant>"`
/// (model names may themselves contain `:`, e.g. `synth:tiny:3`).
pub fn model_key(model: &str, variant: &str) -> String {
    format!("{model}@{variant}")
}

/// Compile every `models × variants` pair for serving (shared cache, so a
/// pair already compiled by a sweep is reused).
pub fn build_serve_models(
    artifacts: &std::path::Path,
    names: &[String],
    variants: &[Variant],
    cache: &CompileCache,
) -> Result<Vec<ServeModel>> {
    let mut out = Vec::new();
    for name in names {
        let spec = models::resolve(artifacts, name)
            .with_context(|| format!("loading model {name}"))?;
        let scache = cache.for_spec(&spec);
        for &v in variants {
            let compiled = scache
                .get_or_compile(v)
                .with_context(|| format!("compiling {name} for {}", v.name))?;
            out.push(ServeModel {
                key: model_key(name, v.name),
                model: name.clone(),
                compiled,
                in_elems: spec.input_elems(),
                out_elems: spec.output_elems(),
            });
        }
    }
    Ok(out)
}

/// A completed inference.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reply {
    /// int8 logits widened to i32 — bit-identical to the offline engine.
    pub output: Vec<i32>,
    pub stats: RunStats,
    /// How many requests shared this engine batch (observability: a loaded
    /// server should show > 1).
    pub batch_size: usize,
    /// Monotonic batch number.
    pub batch_seq: u64,
}

/// What the dispatcher hands back on shutdown.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Engine batches dispatched.
    pub batches: u64,
    /// Per-model latency histograms + SLO attainment.
    pub slo: SloReport,
}

/// A structured serve-side failure: a stable machine-readable `kind`, a
/// human-readable message, and — for pressure errors the client should
/// retry — a backoff hint.  This is the one error currency of the
/// serving front: tickets resolve to it ([`Ticket::wait_detailed`]) and
/// the line protocol renders it as a JSON object
/// (`{"error":{"kind":..,"msg":..[,"retry_after_ms":..]}}`), so a client
/// can tell *transient pressure* (`overload` — back off and retry) from
/// *final answers* (`deadline`, `bad_request`, `unknown_model`,
/// `bad_input`) and *server faults* (`exec`, `internal`).
#[derive(Clone, Debug)]
pub struct ServeError {
    /// Stable classification: `unknown_model`, `bad_input`,
    /// `bad_request`, `overload`, `deadline`, `exec` or `internal`.
    pub kind: &'static str,
    pub msg: String,
    /// Backoff hint for retryable pressure (`overload`): how long to
    /// wait before resubmitting, derived from the EWMA batch cost.
    pub retry_after_ms: Option<u64>,
}

impl ServeError {
    fn new(kind: &'static str, msg: impl Into<String>) -> ServeError {
        ServeError { kind, msg: msg.into(), retry_after_ms: None }
    }

    fn retry_after(mut self, ms: u64) -> ServeError {
        self.retry_after_ms = Some(ms);
        self
    }

    /// The wire shape of this error (the line protocol's `"error"` value).
    pub fn to_json(&self) -> json::Value {
        let b = ObjBuilder::new()
            .set("kind", self.kind)
            .set("msg", self.msg.as_str());
        match self.retry_after_ms {
            Some(ms) => b.set("retry_after_ms", ms).build(),
            None => b.build(),
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(ms) = self.retry_after_ms {
            write!(f, "; retry after {ms} ms")?;
        }
        Ok(())
    }
}

impl std::error::Error for ServeError {}

/// Where a request's reply — or its structured error — goes.
pub(crate) type ReplyTx = mpsc::Sender<Result<Reply, ServeError>>;

/// Per-request scheduling metadata ([`Client::submit_with`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReqMeta {
    /// Completion deadline, relative to submission.  Drives EDF ordering
    /// ([`policy::Edf`]) and admission-time shedding; `None` means "no
    /// deadline" (never shed, scheduled after every deadline-carrying
    /// request under EDF).
    pub deadline: Option<Duration>,
    /// Priority 0–255 (higher is more urgent); tie-breaks equal
    /// deadlines under EDF.
    pub priority: u8,
}

/// A freshly-submitted request, before validation/admission.
struct Submit {
    key: String,
    input: Vec<u8>,
    reply: ReplyTx,
    /// When the client submitted — the latency clock starts here, so the
    /// histograms include time spent in the submission channel while the
    /// dispatcher is busy executing a batch (the overload regime is
    /// exactly what the SLO report exists to measure).
    submitted: Instant,
    /// Absolute deadline (`submitted + meta.deadline`), resolved at
    /// submission so queue data never needs a clock.
    deadline: Option<Instant>,
    priority: u8,
}

/// A ticket for an in-flight request: redeem with [`Ticket::wait`] (or
/// [`Ticket::wait_detailed`] for the typed error).
pub struct Ticket(mpsc::Receiver<Result<Reply, ServeError>>);

impl Ticket {
    /// Block until the batch containing this request has run (or the
    /// request was rejected: unknown key, bad input size, queue full,
    /// infeasible deadline).
    pub fn wait(self) -> Result<Reply> {
        self.wait_detailed().map_err(|e| anyhow!(e))
    }

    /// [`Ticket::wait`], keeping the structured [`ServeError`] so callers
    /// can branch on [`ServeError::kind`] / honor
    /// [`ServeError::retry_after_ms`].
    pub fn wait_detailed(self) -> Result<Reply, ServeError> {
        self.0.recv().map_err(|_| {
            ServeError::new("internal", "serve dispatcher dropped the request")
        })?
    }
}

/// Upper bound on buffered, not-yet-admitted submissions.  The per-model
/// queue caps can only act when the dispatcher drains the channel — it
/// doesn't while a batch executes — so without this second line of
/// defense a flood arriving mid-batch would buffer unboundedly.  Hitting
/// it fails [`Client::submit`] with an overload error (still without
/// blocking).
const SUBMIT_CHANNEL_CAP: usize = 1 << 16;

/// Cheap, clonable request submitter.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Submit>,
    /// EWMA batch cost in µs, published by the dispatcher — the basis of
    /// the `retry_after_ms` hint on backpressure errors.
    cost_us: Arc<AtomicU64>,
}

impl Client {
    /// Enqueue an inference without blocking on its execution.
    pub fn submit(&self, key: &str, input: Vec<u8>) -> Result<Ticket> {
        self.submit_with(key, input, ReqMeta::default())
            .map_err(|e| anyhow!(e))
    }

    /// [`Client::submit`] with scheduling metadata (deadline/priority).
    /// A full submission channel is *backpressure*, not a panic: the
    /// error is `overload` with a `retry_after_ms` hint and the call
    /// never blocks.
    pub fn submit_with(
        &self,
        key: &str,
        input: Vec<u8>,
        meta: ReqMeta,
    ) -> Result<Ticket, ServeError> {
        let (rtx, rrx) = mpsc::channel();
        let submitted = Instant::now();
        self.tx
            .try_send(Submit {
                key: key.to_string(),
                input,
                reply: rtx,
                submitted,
                deadline: meta.deadline.map(|d| submitted + d),
                priority: meta.priority,
            })
            .map_err(|e| match e {
                mpsc::TrySendError::Full(_) => ServeError::new(
                    "overload",
                    format!(
                        "serve overloaded: {SUBMIT_CHANNEL_CAP} submissions \
                         buffered ahead of admission control"
                    ),
                )
                .retry_after(
                    (self.cost_us.load(Ordering::Relaxed) / 1000).max(1),
                ),
                mpsc::TrySendError::Disconnected(_) => {
                    ServeError::new("internal", "serve dispatcher is gone")
                }
            })?;
        Ok(Ticket(rrx))
    }

    /// Submit + wait (the simple blocking call).
    pub fn infer(&self, key: &str, input: Vec<u8>) -> Result<Reply> {
        self.submit(key, input)?.wait()
    }
}

/// Handle to the dispatcher thread.  Dropping the last [`Client`] shuts the
/// dispatcher down; [`Server::join`] then returns the [`ServeReport`].
pub struct Server {
    handle: std::thread::JoinHandle<ServeReport>,
}

impl Server {
    /// Start a server over the given units, batching into `exec`; returns
    /// the server handle and the first client.  The executor moves onto
    /// the dispatcher thread — a persistent backend keeps its pools warm
    /// across every batch the server runs.
    pub fn start(
        units: Vec<ServeModel>,
        opts: ServeOptions,
        exec: Box<dyn Executor>,
    ) -> (Server, Client) {
        let (tx, rx) = mpsc::sync_channel::<Submit>(SUBMIT_CHANNEL_CAP);
        let registry: HashMap<String, ServeModel> =
            units.into_iter().map(|u| (u.key.clone(), u)).collect();
        let cost_us = Arc::new(AtomicU64::new(0));
        let cost = cost_us.clone();
        let handle = std::thread::spawn(move || {
            dispatcher(rx, registry, opts, exec, cost)
        });
        (Server { handle }, Client { tx, cost_us })
    }

    /// Wait for shutdown (all clients dropped); returns the serve report.
    pub fn join(self) -> ServeReport {
        self.handle.join().expect("serve dispatcher panicked")
    }
}

/// EWMA smoothing factor for the arrival-gap estimate (≈ the last 5
/// arrivals dominate).
const ARRIVAL_EWMA_ALPHA: f64 = 0.2;

/// Auto-tunes the batching window from the observed arrival rate: the
/// window aims to collect `target_fill` arrivals (enough to fill the
/// executor's parallel lanes, never more than the batch cap), estimated
/// as `EWMA(inter-arrival gap) × target_fill`, clamped to
/// `[window_min, window_max]`.  With no data yet — or min == max
/// ([`ServeOptions::fixed_window`]) — the window is the configured
/// maximum, which reproduces the legacy fixed-window dispatcher.
struct WindowTuner {
    min: Duration,
    max: Duration,
    target_fill: f64,
    ewma_gap_s: Option<f64>,
    last_arrival: Option<Instant>,
}

impl WindowTuner {
    fn new(opts: &ServeOptions, hint: &BatchHint) -> WindowTuner {
        WindowTuner {
            min: opts.window_min.min(opts.window_max),
            max: opts.window_max.max(opts.window_min),
            target_fill: hint.target_fill() as f64,
            ewma_gap_s: None,
            last_arrival: None,
        }
    }

    /// Feed one admitted arrival at time `now`.
    fn observe(&mut self, now: Instant) {
        if let Some(last) = self.last_arrival {
            let gap = now.saturating_duration_since(last).as_secs_f64();
            self.ewma_gap_s = Some(match self.ewma_gap_s {
                Some(e) => {
                    ARRIVAL_EWMA_ALPHA * gap + (1.0 - ARRIVAL_EWMA_ALPHA) * e
                }
                None => gap,
            });
        }
        self.last_arrival = Some(now);
    }

    /// The window to arm for the next batch.
    fn window(&self) -> Duration {
        match self.ewma_gap_s {
            None => self.max,
            Some(gap) => Duration::from_secs_f64(
                (gap * self.target_fill)
                    .clamp(self.min.as_secs_f64(), self.max.as_secs_f64()),
            ),
        }
    }
}

/// Validate one submission against the registry and admit it into its
/// queue; invalid or shed requests answer their ticket immediately and
/// never occupy a job slot.
///
/// `cost_us` is the dispatcher's current EWMA batch cost: a request
/// whose deadline cannot survive one more batch (`now + cost > deadline`)
/// is **shed here**, before it consumes a queue slot or an engine lane —
/// serving it would burn capacity on an answer the client already
/// declared worthless.
fn admit(
    sub: Submit,
    registry: &HashMap<String, ServeModel>,
    queues: &mut QueueSet,
    metrics: &mut Metrics,
    tuner: &mut WindowTuner,
    cost_us: u64,
) {
    match registry.get(&sub.key) {
        None => {
            let _ = sub.reply.send(Err(ServeError::new(
                "unknown_model",
                format!("unknown model key {:?} (available: {:?})", sub.key, {
                    let mut ks: Vec<&String> = registry.keys().collect();
                    ks.sort();
                    ks
                }),
            )));
        }
        Some(u) if sub.input.len() != u.in_elems => {
            let _ = sub.reply.send(Err(ServeError::new(
                "bad_input",
                format!(
                    "{}: input is {} bytes, model wants {}",
                    sub.key,
                    sub.input.len(),
                    u.in_elems
                ),
            )));
        }
        Some(_) => {
            // Arrival rate is measured at submission time, not at the
            // (possibly batch-delayed) moment the dispatcher drains the
            // channel.
            tuner.observe(sub.submitted);
            if let Some(dl) = sub.deadline {
                let now = Instant::now();
                if now + Duration::from_micros(cost_us) > dl {
                    metrics.shed(&sub.key);
                    let _ = sub.reply.send(Err(ServeError::new(
                        "deadline",
                        format!(
                            "{}: shed at admission — deadline cannot be met \
                             (estimated batch cost {:.1} ms)",
                            sub.key,
                            cost_us as f64 / 1e3
                        ),
                    )));
                    return;
                }
            }
            if let Err((reply, msg)) = queues.admit(
                sub.key.clone(),
                sub.input,
                sub.reply,
                sub.submitted,
                sub.deadline,
                sub.priority,
            ) {
                metrics.reject(&sub.key);
                let _ = reply.send(Err(ServeError::new("overload", msg)
                    .retry_after((cost_us / 1000).max(1))));
            }
        }
    }
}

/// EWMA smoothing factor for the batch-cost estimate that drives
/// deadline shedding and `retry_after_ms` hints.
const COST_EWMA_ALPHA: f64 = 0.2;

fn dispatcher(
    rx: mpsc::Receiver<Submit>,
    registry: HashMap<String, ServeModel>,
    opts: ServeOptions,
    mut exec: Box<dyn Executor>,
    shared_cost_us: Arc<AtomicU64>,
) -> ServeReport {
    let hint = BatchHint {
        max_batch: opts.max_batch.max(1),
        parallelism: exec.caps().parallelism,
        lanes: exec.caps().lanes,
    };
    let mut policy = opts.policy.build();
    let mut queues = QueueSet::new(opts.queue_cap);
    let mut metrics = Metrics::new(opts.slo, opts.slo_window);
    let mut tuner = WindowTuner::new(&opts, &hint);
    let mut batch_seq: u64 = 0;
    // EWMA of observed batch execution wall time, in µs.  0 = no data
    // yet, which makes shedding maximally permissive at startup (only
    // already-expired deadlines shed) — the estimate tightens as real
    // batch costs arrive.
    let mut cost_us: u64 = 0;
    // `false` once every Client is dropped: drain the backlog, then stop.
    let mut open = true;
    loop {
        if queues.is_empty() {
            if !open {
                break;
            }
            // Idle: block for the first request of the next batch, which
            // arms the (auto-tuned) window.
            match rx.recv() {
                Ok(s) => admit(
                    s, &registry, &mut queues, &mut metrics, &mut tuner,
                    cost_us,
                ),
                Err(_) => break,
            }
            // Window collection.  Everything that has *already arrived* is
            // always drained into the queues — admission control
            // (`queue_cap`), not the batch cap, bounds the backlog, and a
            // policy must see the whole cross-tenant backlog to be fair.
            // Only the *waiting* is bounded: once a full batch's worth is
            // queued (or the window closes), stop waiting and dispatch.
            let deadline = Instant::now() + tuner.window();
            loop {
                loop {
                    match rx.try_recv() {
                        Ok(s) => admit(
                            s, &registry, &mut queues, &mut metrics,
                            &mut tuner, cost_us,
                        ),
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                if !open || queues.total() >= hint.max_batch {
                    break;
                }
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(s) => admit(
                        s, &registry, &mut queues, &mut metrics, &mut tuner,
                        cost_us,
                    ),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
        } else {
            // Backlog: the queued requests already waited their window —
            // pick up whatever else has arrived, but don't wait for more.
            loop {
                match rx.try_recv() {
                    Ok(s) => admit(
                        s, &registry, &mut queues, &mut metrics, &mut tuner,
                        cost_us,
                    ),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if queues.is_empty() {
            // Every arrival so far was invalid/rejected — nothing to run.
            continue;
        }

        let batch = policy.next_batch(&mut queues, &hint);
        assert!(
            !batch.is_empty() && batch.len() <= hint.max_batch,
            "policy {} broke the batch contract ({} requests, cap {})",
            policy.name(),
            batch.len(),
            hint.max_batch
        );
        batch_seq += 1;
        for p in &batch {
            let u = &registry[&p.key];
            exec.submit(JobSpec::hydrated(
                &u.model,
                &u.compiled,
                u.out_elems,
                &p.input,
                1 << 36,
            ));
        }
        let t_exec = Instant::now();
        let results = exec.run();
        let done = Instant::now();
        // Fold the batch's wall time into the cost estimate the shed
        // rule and retry-after hints use; publish it for clients.
        let dt_us = done.duration_since(t_exec).as_micros() as f64;
        let ewma = if cost_us == 0 {
            dt_us
        } else {
            COST_EWMA_ALPHA * dt_us + (1.0 - COST_EWMA_ALPHA) * cost_us as f64
        };
        cost_us = ewma as u64;
        shared_cost_us.store(cost_us, Ordering::Relaxed);
        let size = batch.len();
        for (p, r) in batch.iter().zip(results) {
            // Only successful inferences feed the latency histogram —
            // a job error is counted on its own so `served` and the
            // quantiles always mean "replied with logits".  Deadline
            // attainment is judged against the batch-completion instant,
            // shared by every request the batch carried.
            let _ = p.reply.send(match r {
                Ok(o) => {
                    let dl_met = p.deadline.map(|dl| done <= dl);
                    metrics.record(&p.key, p.submitted.elapsed(), dl_met);
                    Ok(Reply {
                        output: o.output,
                        stats: o.stats,
                        batch_size: size,
                        batch_seq,
                    })
                }
                Err(e) => {
                    metrics.error(&p.key);
                    Err(ServeError::new("exec", format!("{e}")))
                }
            });
        }
        if let Some(snap) = metrics.roll_if_due(Instant::now()) {
            eprintln!("{}", snap.render());
        }
    }
    ServeReport { batches: batch_seq, slo: metrics.report() }
}

// ---------------------------------------------------------------------------
// Line protocol (the `marvel serve` CLI and the CI smoke)
// ---------------------------------------------------------------------------

/// Serve requests read as JSON lines, one response line per request, in
/// request order (responses for a batch are written as their tickets
/// resolve; ordering across batches follows submission).  Returns the
/// dispatcher's [`ServeReport`] once the input stream ends.
///
/// Request: `{"id":1,"model":"synth:tiny:3","variant":"v4","input":"<hex>"}`
/// — or `"seed":N` instead of `"input"` for a deterministic random image
/// (CI smoke without shipping bytes).  Optional fields: `"deadline_ms"`
/// (finite, `0..=1e9`; relative to arrival) and `"priority"` (`0..=255`)
/// feed EDF scheduling and admission-time shedding.  Response:
/// `{"id":1,"output":[...],"instrs":..,"cycles":..,"batch":k}` or
/// `{"id":1,"error":{"kind":..,"msg":..[,"retry_after_ms":..]}}`.
///
/// The session survives bad input: a malformed request line, an unknown
/// model key, an out-of-range deadline/priority, or an unreadable line
/// (e.g. invalid UTF-8) each answer with a structured error object and
/// the loop reads on — only EOF ends the session.
pub fn serve_lines(
    units: Vec<ServeModel>,
    opts: ServeOptions,
    exec: Box<dyn Executor>,
    input: impl BufRead,
    out: impl Write + Send,
) -> Result<ServeReport> {
    // Input sizes for seed-expansion, before the registry moves.
    let sizes: HashMap<String, usize> =
        units.iter().map(|u| (u.key.clone(), u.in_elems)).collect();
    let (server, client) = Server::start(units, opts, exec);

    // The reading loop submits without waiting (so requests read within one
    // window share a batch); a writer thread drains tickets in request
    // order, which keeps output incremental *and* deterministic.
    let (wtx, wrx) = mpsc::channel::<(u64, Result<Ticket, ServeError>)>();
    let writer = std::thread::scope(|s| -> Result<()> {
        let writer = s.spawn(move || -> Result<()> {
            let mut out = out;
            for (id, t) in wrx {
                let b = ObjBuilder::new().set("id", id);
                let b = match t.and_then(Ticket::wait_detailed) {
                    Ok(r) => b
                        .set(
                            "output",
                            r.output
                                .iter()
                                .map(|&v| i64::from(v))
                                .collect::<Vec<i64>>(),
                        )
                        .set("instrs", r.stats.instrs)
                        .set("cycles", r.stats.cycles)
                        .set("batch", r.batch_size),
                    Err(e) => b.set("error", e.to_json()),
                };
                writeln!(out, "{}", json::to_compact_string(&b.build()))?;
                out.flush()?;
            }
            Ok(())
        });
        for line in input.lines() {
            // An unreadable line (invalid UTF-8, transient I/O error) is a
            // structured error response, not the end of the session.
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    let _ = wtx.send((
                        0,
                        Err(ServeError::new(
                            "bad_request",
                            format!("reading request line: {e}"),
                        )),
                    ));
                    continue;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            let (id, ticket) = match parse_request(&line, &sizes) {
                Ok((id, key, bytes, meta)) => {
                    (id, client.submit_with(&key, bytes, meta))
                }
                Err(e) => (
                    request_id(&line),
                    Err(ServeError::new("bad_request", format!("{e:#}"))),
                ),
            };
            let _ = wtx.send((id, ticket));
        }
        drop(wtx); // EOF: writer drains remaining tickets and exits
        drop(client); // dispatcher runs the tail batches, then shuts down
        writer.join().expect("serve writer panicked")
    });
    writer?;
    Ok(server.join())
}

/// Best-effort id extraction for malformed requests (so the error response
/// still correlates).
fn request_id(line: &str) -> u64 {
    json::parse(line)
        .ok()
        .and_then(|v| v.get("id").ok().and_then(|i| i.as_u64().ok()))
        .unwrap_or(0)
}

/// Widest accepted `"deadline_ms"` value (~11.6 days) — same bound as
/// the CLI's millisecond flags, so `1e400` (which parses to `inf`),
/// `NaN`-producing garbage and negative values are all *rejected
/// requests*, never a poisoned `Duration` inside the scheduler.
const MAX_DEADLINE_MS: f64 = 1e9;

fn parse_request(
    line: &str,
    sizes: &HashMap<String, usize>,
) -> Result<(u64, String, Vec<u8>, ReqMeta)> {
    let v = json::parse(line)?;
    let id = v.get("id")?.as_u64()?;
    let key = model_key(v.get("model")?.as_str()?, v.get("variant")?.as_str()?);
    let bytes = match v.get_opt("input") {
        Some(h) => super::shard::from_hex(h.as_str()?)?,
        None => {
            let seed = v
                .get("seed")
                .context("request needs \"input\" hex or \"seed\"")?
                .as_u64()?;
            let n = *sizes
                .get(&key)
                .with_context(|| format!("unknown model key {key:?}"))?;
            let mut rng = Rng::new(seed);
            (0..n).map(|_| rng.int8() as i8 as u8).collect()
        }
    };
    let deadline = match v.get_opt("deadline_ms") {
        None => None,
        Some(d) => {
            let ms = d.as_f64().context("\"deadline_ms\" must be a number")?;
            anyhow::ensure!(
                ms.is_finite() && (0.0..=MAX_DEADLINE_MS).contains(&ms),
                "\"deadline_ms\" wants a finite value in 0..={MAX_DEADLINE_MS}, \
                 got {ms}"
            );
            Some(Duration::from_secs_f64(ms / 1e3))
        }
    };
    let priority = match v.get_opt("priority") {
        None => 0,
        Some(p) => {
            let n = p.as_u64().context(
                "\"priority\" must be a non-negative integer",
            )?;
            anyhow::ensure!(n <= 255, "\"priority\" wants 0..=255, got {n}");
            n as u8
        }
    };
    Ok((id, key, bytes, ReqMeta { deadline, priority }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::synth::tiny_conv_net;
    use crate::sim::exec::LocalExec;
    use crate::sim::{V0, V4};

    fn units() -> Vec<ServeModel> {
        let cache = CompileCache::new();
        build_serve_models(
            std::path::Path::new("artifacts"),
            &["synth:tiny:3".to_string()],
            &[V0, V4],
            &cache,
        )
        .unwrap()
    }

    fn local_exec(threads: usize) -> Box<dyn Executor> {
        Box::new(LocalExec::new(std::path::Path::new("artifacts"), threads))
    }

    #[test]
    fn serve_matches_direct_execution() {
        let spec = tiny_conv_net(3);
        let mut rng = Rng::new(9);
        let input = crate::models::synth::Builder::random_input(&spec, &mut rng);
        let packed = crate::compiler::pack_input(&input).unwrap();
        let (want, want_stats) =
            crate::compiler::execute(&spec, V4, &input, 1 << 36).unwrap();

        let (server, client) =
            Server::start(units(), ServeOptions::default(), local_exec(0));
        let r = client
            .infer(&model_key("synth:tiny:3", "v4"), packed)
            .unwrap();
        assert_eq!(r.output, want);
        assert_eq!(r.stats, want_stats);
        assert!(r.batch_size >= 1);
        drop(client);
        let report = server.join();
        assert_eq!(report.batches, 1);
        assert_eq!(report.slo.rows.len(), 1);
        let row = &report.slo.rows[0];
        assert_eq!(row.key, model_key("synth:tiny:3", "v4"));
        assert_eq!((row.served, row.rejected), (1, 0));
        assert!(row.p99_ms > 0.0);
    }

    #[test]
    fn bad_requests_answer_without_jobs() {
        let (server, client) =
            Server::start(units(), ServeOptions::default(), local_exec(1));
        let e = client.infer("nope@v4", vec![0; 4]).unwrap_err().to_string();
        assert!(e.contains("unknown model key"), "{e}");
        let e = client
            .infer(&model_key("synth:tiny:3", "v4"), vec![0; 3])
            .unwrap_err()
            .to_string();
        assert!(e.contains("input is 3 bytes"), "{e}");
        drop(client);
        let report = server.join();
        assert_eq!(report.batches, 0, "invalid requests never form a batch");
    }

    #[test]
    fn window_batches_concurrent_requests() {
        let spec = tiny_conv_net(3);
        let n_in = spec.input_elems();
        let opts = ServeOptions { max_batch: 8, ..ServeOptions::default() }
            .fixed_window(Duration::from_millis(200));
        let (server, client) = Server::start(units(), opts, local_exec(2));
        // Submit 4 requests inside one window, then wait: they must share
        // a batch (size > 1) and each match the offline engine.
        let tickets: Vec<(Vec<u8>, Ticket)> = (0..4u64)
            .map(|i| {
                let mut rng = Rng::new(100 + i);
                let bytes: Vec<u8> =
                    (0..n_in).map(|_| rng.int8() as i8 as u8).collect();
                let t = client
                    .submit(&model_key("synth:tiny:3", "v0"), bytes.clone())
                    .unwrap();
                (bytes, t)
            })
            .collect();
        for (bytes, t) in tickets {
            let r = t.wait().unwrap();
            let input: Vec<i32> =
                bytes.iter().map(|&b| b as i8 as i32).collect();
            let (want, want_stats) =
                crate::compiler::execute(&spec, V0, &input, 1 << 36).unwrap();
            assert_eq!(r.output, want);
            assert_eq!(r.stats, want_stats);
            assert_eq!(r.batch_size, 4, "requests must share the window");
            assert_eq!(r.batch_seq, 1);
        }
        drop(client);
        assert_eq!(server.join().batches, 1);
    }

    #[test]
    fn line_protocol_end_to_end() {
        let reqs = concat!(
            r#"{"id":1,"model":"synth:tiny:3","variant":"v4","seed":5}"#, "\n",
            r#"{"id":2,"model":"synth:tiny:3","variant":"nope","seed":5}"#, "\n",
            "not json\n",
        );
        let mut out = Vec::new();
        serve_lines(
            units(),
            ServeOptions::default(),
            local_exec(0),
            std::io::Cursor::new(reqs),
            &mut out,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        let r1 = json::parse(lines[0]).unwrap();
        assert_eq!(r1.get("id").unwrap().as_u64().unwrap(), 1);
        assert!(r1.get_opt("output").is_some(), "{text}");
        assert!(r1.get("cycles").unwrap().as_u64().unwrap() > 0);
        let r2 = json::parse(lines[1]).unwrap();
        assert!(r2.get_opt("error").is_some(), "{text}");
        let r3 = json::parse(lines[2]).unwrap();
        assert!(r3.get_opt("error").is_some(), "{text}");
    }

    /// Satellite regression: every bad-input shape — malformed JSON, an
    /// unknown model key via the hex-input path *and* the seed path, an
    /// unreadable (non-UTF-8) line — answers with a structured JSON error
    /// and the session keeps serving the requests that follow.
    #[test]
    fn line_protocol_survives_bad_requests_mid_session() {
        let good =
            br#"{"id":7,"model":"synth:tiny:3","variant":"v4","seed":5}"#;
        let mut reqs: Vec<u8> = Vec::new();
        reqs.extend_from_slice(b"{\"id\":1,\"model\":\"nope\",\"variant\":\"v4\",\"seed\":3}\n");
        reqs.extend_from_slice(b"{\"id\":2,\"model\":\"nope\",\"variant\":\"v4\",\"input\":\"00ff\"}\n");
        reqs.extend_from_slice(b"{\"id\":3,\"model\":");
        reqs.extend_from_slice(b"\n");
        reqs.extend_from_slice(&[0xff, 0xfe, b'\n']); // invalid UTF-8 line
        reqs.extend_from_slice(good);
        reqs.extend_from_slice(b"\n");
        let mut out = Vec::new();
        serve_lines(
            units(),
            ServeOptions::default(),
            local_exec(1),
            std::io::Cursor::new(reqs),
            &mut out,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5, "{text}");
        // Line 3 is malformed JSON, so even its id is unrecoverable (0).
        for (i, want_id) in [(0usize, 1u64), (1, 2), (2, 0), (3, 0)] {
            let v = json::parse(lines[i]).unwrap();
            assert_eq!(v.get("id").unwrap().as_u64().unwrap(), want_id, "{text}");
            let eo = v.get("error").unwrap();
            let kind = eo.get("kind").unwrap().as_str().unwrap().to_string();
            let err = eo.get("msg").unwrap().as_str().unwrap().to_string();
            assert!(!kind.is_empty() && !err.is_empty(), "{text}");
            if i < 2 {
                assert!(err.contains("unknown model key"), "{err}");
            }
        }
        // The session survived: the final valid request was served.
        let last = json::parse(lines[4]).unwrap();
        assert_eq!(last.get("id").unwrap().as_u64().unwrap(), 7);
        assert!(last.get_opt("output").is_some(), "{text}");
    }

    #[test]
    fn window_tuner_tracks_arrival_rate_within_bounds() {
        let opts = ServeOptions {
            window_min: Duration::from_millis(1),
            window_max: Duration::from_millis(8),
            ..ServeOptions::default()
        };
        let hint = BatchHint { max_batch: 64, parallelism: 4, lanes: 1 };
        let mut t = WindowTuner::new(&opts, &hint);
        // No data: the window is the configured max.
        assert_eq!(t.window(), Duration::from_millis(8));
        let t0 = Instant::now();
        // Fast arrivals (100 µs apart): 4 lanes × 100 µs = 400 µs target,
        // clamped up to window_min.
        for i in 0..20u32 {
            t.observe(t0 + i * Duration::from_micros(100));
        }
        assert_eq!(t.window(), Duration::from_millis(1));
        // Slow arrivals (50 ms apart) stretch the window to the cap.
        let mut t = WindowTuner::new(&opts, &hint);
        for i in 0..20u32 {
            t.observe(t0 + i * Duration::from_millis(50));
        }
        assert_eq!(t.window(), Duration::from_millis(8));
        // Mid-rate arrivals land between the bounds: 1 ms gaps × 4 lanes.
        let mut t = WindowTuner::new(&opts, &hint);
        for i in 0..50u32 {
            t.observe(t0 + i * Duration::from_millis(1));
        }
        let w = t.window();
        assert!(
            w > Duration::from_millis(1) && w < Duration::from_millis(8),
            "{w:?}"
        );
        // A fixed window never moves, whatever the rate.
        let fixed = ServeOptions::default()
            .fixed_window(Duration::from_millis(2));
        let mut t = WindowTuner::new(&fixed, &hint);
        for i in 0..20u32 {
            t.observe(t0 + i * Duration::from_micros(10));
        }
        assert_eq!(t.window(), Duration::from_millis(2));
    }

    #[test]
    fn queue_cap_rejection_is_a_ticket_error() {
        // Cap 2, one-worker backend, a long fixed window: the 3rd..6th
        // concurrent submissions must be shed with a structured error —
        // not a panic, not a hang — and the admitted ones still serve.
        let opts = ServeOptions {
            queue_cap: 2,
            max_batch: 64,
            ..ServeOptions::default()
        }
        .fixed_window(Duration::from_millis(300));
        let spec = tiny_conv_net(3);
        let n_in = spec.input_elems();
        let (server, client) = Server::start(units(), opts, local_exec(1));
        let key = model_key("synth:tiny:3", "v0");
        let tickets: Vec<Ticket> = (0..6)
            .map(|_| client.submit(&key, vec![0; n_in]).unwrap())
            .collect();
        let results: Vec<Result<Reply, ServeError>> =
            tickets.into_iter().map(Ticket::wait_detailed).collect();
        let served = results.iter().filter(|r| r.is_ok()).count();
        let shed: Vec<&ServeError> =
            results.iter().filter_map(|r| r.as_ref().err()).collect();
        assert_eq!(served, 2, "cap 2 admits exactly 2 of a 6-burst");
        assert_eq!(shed.len(), 4);
        for e in &shed {
            assert_eq!(e.kind, "overload");
            assert!(e.retry_after_ms.is_some(), "rejection must hint backoff");
            assert!(e.msg.contains("admission rejected"), "{}", e.msg);
            assert!(e.msg.contains("queue full"), "{}", e.msg);
        }
        drop(client);
        let report = server.join();
        let row = &report.slo.rows[0];
        assert_eq!((row.served, row.rejected), (2, 4));
    }

    /// Tentpole regression: a deadline the scheduler cannot possibly meet
    /// (already expired at admission) is shed with a typed `deadline`
    /// error and never forms a batch; a generous deadline serves and
    /// counts toward goodput — so the report splits 1 met / 1 shed.
    #[test]
    fn expired_deadline_is_shed_with_structured_error() {
        let spec = tiny_conv_net(3);
        let n_in = spec.input_elems();
        let (server, client) =
            Server::start(units(), ServeOptions::default(), local_exec(1));
        let key = model_key("synth:tiny:3", "v4");
        let meta = ReqMeta { deadline: Some(Duration::ZERO), priority: 0 };
        let e = client
            .submit_with(&key, vec![0; n_in], meta)
            .unwrap()
            .wait_detailed()
            .unwrap_err();
        assert_eq!(e.kind, "deadline");
        assert!(e.msg.contains("shed at admission"), "{}", e.msg);
        let meta =
            ReqMeta { deadline: Some(Duration::from_secs(120)), priority: 3 };
        let r = client
            .submit_with(&key, vec![0; n_in], meta)
            .unwrap()
            .wait_detailed()
            .unwrap();
        assert!(r.batch_size >= 1);
        drop(client);
        let report = server.join();
        assert_eq!(report.batches, 1, "the shed request never ran");
        let row = &report.slo.rows[0];
        assert_eq!((row.served, row.shed), (1, 1));
        assert_eq!((row.deadline_met, row.deadline_missed), (1, 0));
        assert_eq!(row.goodput, Some(0.5));
    }

    /// Satellite regression: out-of-range `deadline_ms` / `priority`
    /// values — negative, non-finite (1e400 overflows to inf), too large
    /// — are *rejected requests* with structured errors, never poisoned
    /// scheduler state; valid metadata on the same session still serves.
    #[test]
    fn line_protocol_rejects_malformed_deadline_and_priority() {
        let reqs = concat!(
            r#"{"id":1,"model":"synth:tiny:3","variant":"v4","seed":5,"deadline_ms":-3}"#, "\n",
            r#"{"id":2,"model":"synth:tiny:3","variant":"v4","seed":5,"deadline_ms":1e400}"#, "\n",
            r#"{"id":3,"model":"synth:tiny:3","variant":"v4","seed":5,"priority":300}"#, "\n",
            r#"{"id":4,"model":"synth:tiny:3","variant":"v4","seed":5,"deadline_ms":60000,"priority":7}"#, "\n",
        );
        let mut out = Vec::new();
        serve_lines(
            units(),
            ServeOptions::default(),
            local_exec(1),
            std::io::Cursor::new(reqs),
            &mut out,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
        for (i, want) in [(0usize, "deadline_ms"), (1, ""), (2, "priority")] {
            let v = json::parse(lines[i]).unwrap();
            let eo = v.get("error").unwrap();
            assert_eq!(
                eo.get("kind").unwrap().as_str().unwrap(),
                "bad_request",
                "{text}"
            );
            let msg = eo.get("msg").unwrap().as_str().unwrap();
            assert!(msg.contains(want), "{msg:?} should mention {want:?}");
        }
        let last = json::parse(lines[3]).unwrap();
        assert_eq!(last.get("id").unwrap().as_u64().unwrap(), 4);
        assert!(last.get_opt("output").is_some(), "{text}");
    }
}
