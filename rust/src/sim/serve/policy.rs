//! Scheduling policies: how the dispatcher picks the next engine batch
//! from the per-model queues (DESIGN.md §14).
//!
//! A [`SchedPolicy`] sees the whole [`QueueSet`] and drains up to
//! [`BatchHint::max_batch`] requests per call.  Three implementations
//! ship:
//!
//! - [`Fifo`] — strict global arrival order, bit-identical in service
//!   order to the pre-scheduler dispatcher (one shared FIFO).  Simple and
//!   throughput-optimal, but a chatty tenant that floods the queue ahead
//!   of a quiet one delays every later arrival behind its whole backlog.
//! - [`DeficitRoundRobin`] — classic deficit round-robin across the
//!   non-empty model queues.  Every round each active queue earns a
//!   quantum of service; a tenant with a 10:1 arrival-rate advantage
//!   still only gets its round-robin share of each batch, so the
//!   low-rate tenant's queueing delay stays bounded by the batch period,
//!   not by the flood (asserted by `tests/serve_sched.rs`).
//! - [`Edf`] — earliest deadline first across the queue heads
//!   (DESIGN.md §16): a tight-deadline request jumps ahead of a
//!   loose-deadline backlog, which is what keeps goodput-under-deadline
//!   up when a burst of cheap urgent work lands behind expensive patient
//!   work.  Deadlines are *data on the request* ([`Pending::deadline`]),
//!   so the policy stays a pure function of queue state — no clock reads.
//!
//! Policies never reorder one model's requests relative to each other —
//! per-model FIFO is part of the trait contract, so replies stay
//! deterministic for a fixed arrival sequence.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::queue::{Pending, QueueSet};

/// What the dispatcher tells the policy about the batch it may form.
#[derive(Clone, Copy, Debug)]
pub struct BatchHint {
    /// Hard batch-size cap (`--max-batch`).
    pub max_batch: usize,
    /// The executor's concurrent-lane count
    /// ([`crate::sim::exec::Caps::parallelism`]): worker threads for a
    /// local backend, workers × pipeline depth for a shard.  Policies use
    /// it to size batches to what the substrate can actually overlap.
    pub parallelism: usize,
    /// Same-program lane-pack width the executor forms
    /// ([`crate::sim::exec::Caps::lanes`]).  The engine packs
    /// same-fingerprint jobs into SIMT-style lane groups of this width, so
    /// a batch whose per-model run lengths are lane multiples executes with
    /// full packs; a mixed tail strands lanes (DESIGN.md §19).  Policies
    /// prefer finishing a model's run at a multiple of this before
    /// switching tenants.  `1` (or `0`) = scalar backend, no preference.
    pub lanes: usize,
}

impl BatchHint {
    /// The batch size worth filling: the hard cap, or the executor's
    /// parallel lane count when that is smaller — a batch larger than the
    /// lane count only adds queueing delay inside the backend.
    pub fn target_fill(&self) -> usize {
        self.max_batch.min(self.parallelism.max(1)).max(1)
    }
}

/// A batch-forming discipline over the per-model queues.
///
/// **Contract** (relied on by the dispatcher, asserted by the scheduler
/// tests):
///
/// - `next_batch` returns a **non-empty** batch whenever `queues` is
///   non-empty (the dispatcher would otherwise spin), and never more than
///   `hint.max_batch` requests.
/// - Per-model FIFO order is preserved: one model's requests are only
///   ever popped from the queue head, never reordered.
/// - Decisions are a pure function of the queue state and the policy's
///   own counters — no clocks, no randomness — so a fixed arrival
///   sequence always forms the same batches.
///
/// Policies should additionally *prefer* (not guarantee) same-model run
/// lengths that are multiples of [`BatchHint::lanes`], so the engine's
/// lane packer downstream forms full packs (DESIGN.md §19).  The
/// preference never overrides the contract above: a queue that runs dry
/// mid-run leaves a short run rather than stalling or reordering.
pub trait SchedPolicy: Send {
    /// Policy name (logs, reports, `describe` strings).
    fn name(&self) -> &'static str;

    /// Drain up to `hint.max_batch` requests from `queues` into the next
    /// engine batch.
    fn next_batch(&mut self, queues: &mut QueueSet, hint: &BatchHint)
        -> Vec<Pending>;
}

/// Which scheduling policy to run — the parsed `--policy` value.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolicyKind {
    /// Strict global arrival order (the legacy dispatcher's behavior).
    #[default]
    Fifo,
    /// Deficit round-robin fairness across models.
    Drr,
    /// Earliest deadline first across queue heads.
    Edf,
}

impl PolicyKind {
    /// Parse a `--policy` value: `fifo`, `drr` or `edf`.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "fifo" => Ok(PolicyKind::Fifo),
            "drr" => Ok(PolicyKind::Drr),
            "edf" => Ok(PolicyKind::Edf),
            other => {
                bail!("unknown policy {other:?} (expected fifo, drr or edf)")
            }
        }
    }

    /// Build a fresh policy instance of this kind.
    pub fn build(self) -> Box<dyn SchedPolicy> {
        match self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Drr => Box::new(DeficitRoundRobin::new()),
            PolicyKind::Edf => Box::new(Edf),
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Drr => "drr",
            PolicyKind::Edf => "edf",
        })
    }
}

/// Strict global arrival order: repeatedly serve the queue holding the
/// globally-oldest request.  This reconstructs exactly the one shared
/// FIFO of the pre-scheduler dispatcher, so `--policy fifo` replies are
/// bit-identical to the legacy serve path (at `lanes: 1`; a multi-lane
/// backend tops same-model runs up to lane multiples, which only ever
/// pulls a model's *own* later requests forward).
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn next_batch(
        &mut self,
        queues: &mut QueueSet,
        hint: &BatchHint,
    ) -> Vec<Pending> {
        let lanes = hint.lanes.max(1);
        let mut batch = Vec::new();
        while batch.len() < hint.max_batch {
            let Some(p) = queues.pop_oldest() else { break };
            let key = p.key.clone();
            batch.push(p);
            // Lane-pack top-up: extend this model's run to a multiple of
            // the lane width from its own queue before returning to global
            // arrival order.  Running dry leaves a short run — never stall.
            let mut run = 1;
            while run % lanes != 0 && batch.len() < hint.max_batch {
                match queues.pop(&key) {
                    Some(q) => {
                        batch.push(q);
                        run += 1;
                    }
                    None => break,
                }
            }
        }
        batch
    }
}

/// Earliest deadline first: repeatedly serve the queue whose *head* has
/// the most urgent `(deadline, priority, seq)` key — deadline-less
/// requests sort last, higher priority wins a deadline tie, and arrival
/// order breaks exact ties (so with no deadlines anywhere, EDF *is*
/// [`Fifo`]).  Only queue heads compete
/// ([`QueueSet::pop_front_min_by`]), which preserves the per-model FIFO
/// contract: a late tight-deadline request of model M still waits behind
/// M's own earlier requests, but jumps every *other* model's backlog.
pub struct Edf;

impl SchedPolicy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn next_batch(
        &mut self,
        queues: &mut QueueSet,
        hint: &BatchHint,
    ) -> Vec<Pending> {
        let lanes = hint.lanes.max(1);
        let mut batch = Vec::new();
        while batch.len() < hint.max_batch {
            let Some(p) = queues.pop_front_min_by(|p| {
                (
                    p.deadline.is_none(),
                    p.deadline,
                    std::cmp::Reverse(p.priority),
                    p.seq,
                )
            }) else {
                break;
            };
            let key = p.key.clone();
            batch.push(p);
            // Lane-pack top-up (same rule as [`Fifo`]): the most urgent
            // model keeps the lanes it opened — its next requests are at
            // most as urgent as its head was, so no other head is wronged.
            let mut run = 1;
            while run % lanes != 0 && batch.len() < hint.max_batch {
                match queues.pop(&key) {
                    Some(q) => {
                        batch.push(q);
                        run += 1;
                    }
                    None => break,
                }
            }
        }
        batch
    }
}

/// Deficit round-robin across the non-empty model queues.
///
/// Each `next_batch` round walks the active queues in sorted key order,
/// resuming *after* the last queue served in the previous batch (the
/// rotation cursor), and credits each visited queue one quantum —
/// `max_batch / active_queues`, at least 1.  A queue spends its deficit
/// one request at a time while it has any; unspent deficit carries to the
/// next round, and a queue that empties forfeits its credit (standard
/// DRR, so an idle tenant cannot hoard service).  Requests cost 1 each —
/// inference jobs are near-uniform per model, and the per-model histogram
/// (DESIGN.md §14) is where actual cost skew becomes visible.
#[derive(Default)]
pub struct DeficitRoundRobin {
    /// Carried-over service credit per key.
    deficit: HashMap<String, usize>,
    /// Last key served — the next batch starts after it (fair rotation
    /// across batches, not just within one).
    cursor: Option<String>,
}

impl DeficitRoundRobin {
    pub fn new() -> DeficitRoundRobin {
        DeficitRoundRobin::default()
    }
}

impl SchedPolicy for DeficitRoundRobin {
    fn name(&self) -> &'static str {
        "drr"
    }

    fn next_batch(
        &mut self,
        queues: &mut QueueSet,
        hint: &BatchHint,
    ) -> Vec<Pending> {
        let mut batch = Vec::new();
        'rounds: while batch.len() < hint.max_batch {
            let active = queues.active_keys();
            if active.is_empty() {
                break;
            }
            // Round the per-tenant quantum up to a lane multiple so each
            // visit's run arrives at the engine as whole lane packs
            // (DESIGN.md §19); at `lanes: 1` this is classic DRR.
            let lanes = hint.lanes.max(1);
            let base = (hint.max_batch / active.len()).max(1);
            let quantum = ((base + lanes - 1) / lanes) * lanes;
            // Rotate: start at the first active key after the cursor.
            let start = match &self.cursor {
                Some(c) => active.iter().position(|k| k > c).unwrap_or(0),
                None => 0,
            };
            for i in 0..active.len() {
                let key = &active[(start + i) % active.len()];
                let d = self.deficit.entry(key.clone()).or_insert(0);
                *d += quantum;
                let mut full = false;
                while *d > 0 {
                    match queues.pop(key) {
                        Some(p) => {
                            *d -= 1;
                            batch.push(p);
                        }
                        None => break,
                    }
                    if batch.len() >= hint.max_batch {
                        full = true;
                        break;
                    }
                }
                if queues.len_of(key) == 0 {
                    // An emptied queue forfeits unspent credit — even when
                    // its last pop is what filled the batch (otherwise an
                    // idle tenant returns with hoarded deficit).
                    self.deficit.remove(key);
                }
                self.cursor = Some(key.clone());
                if full {
                    break 'rounds;
                }
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn push(qs: &mut QueueSet, key: &str) {
        push_dl(qs, key, None, 0);
    }

    fn push_dl(
        qs: &mut QueueSet,
        key: &str,
        deadline: Option<Instant>,
        priority: u8,
    ) {
        qs.admit(
            key.to_string(),
            Vec::new(),
            mpsc::channel().0,
            Instant::now(),
            deadline,
            priority,
        )
        .unwrap();
    }

    fn filled(reqs: &[(&str, usize)]) -> QueueSet {
        let mut qs = QueueSet::new(1 << 20);
        for &(key, n) in reqs {
            for _ in 0..n {
                push(&mut qs, key);
            }
        }
        qs
    }

    fn keys(batch: &[Pending]) -> Vec<&str> {
        batch.iter().map(|p| p.key.as_str()).collect()
    }

    #[test]
    fn policy_kind_parses_and_displays() {
        assert_eq!(PolicyKind::parse("fifo").unwrap(), PolicyKind::Fifo);
        assert_eq!(PolicyKind::parse("drr").unwrap(), PolicyKind::Drr);
        assert_eq!(PolicyKind::parse("edf").unwrap(), PolicyKind::Edf);
        assert!(PolicyKind::parse("lifo").is_err());
        for k in [PolicyKind::Fifo, PolicyKind::Drr, PolicyKind::Edf] {
            assert_eq!(PolicyKind::parse(&k.to_string()).unwrap(), k);
            assert_eq!(k.build().name(), k.to_string());
        }
    }

    #[test]
    fn fifo_serves_global_arrival_order_across_queues() {
        // Arrivals: a, b, a, c, b — FIFO must replay exactly that.
        let mut qs = QueueSet::new(16);
        for key in ["a", "b", "a", "c", "b"] {
            push(&mut qs, key);
        }
        let hint = BatchHint { max_batch: 3, parallelism: 8, lanes: 1 };
        let b1 = Fifo.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b1), ["a", "b", "a"]);
        assert_eq!(b1.iter().map(|p| p.seq).collect::<Vec<_>>(), [0, 1, 2]);
        let b2 = Fifo.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b2), ["c", "b"]);
        assert!(Fifo.next_batch(&mut qs, &hint).is_empty());
    }

    #[test]
    fn drr_splits_each_batch_across_backlogged_tenants() {
        // 10:1 backlog skew; max_batch 8 over 2 active queues -> quantum 4.
        let mut qs = filled(&[("chatty", 40), ("quiet", 4)]);
        let hint = BatchHint { max_batch: 8, parallelism: 8, lanes: 1 };
        let mut drr = DeficitRoundRobin::new();
        let b1 = drr.next_batch(&mut qs, &hint);
        assert_eq!(
            keys(&b1).iter().filter(|&&k| k == "quiet").count(),
            4,
            "quiet tenant gets its full quantum in the first batch"
        );
        assert_eq!(b1.len(), 8);
        // Quiet's 4 remaining requests were already served; the rest of the
        // backlog is chatty-only, and DRR degrades to plain draining.
        let b2 = drr.next_batch(&mut qs, &hint);
        assert!(keys(&b2).iter().all(|k| *k == "chatty"));
        assert_eq!(b2.len(), 8);
    }

    #[test]
    fn drr_preserves_per_model_fifo_order() {
        let mut qs = filled(&[("a", 6), ("b", 6)]);
        let hint = BatchHint { max_batch: 4, parallelism: 4, lanes: 1 };
        let mut drr = DeficitRoundRobin::new();
        let mut seen: std::collections::HashMap<&str, Vec<u64>> =
            Default::default();
        loop {
            let batch = drr.next_batch(&mut qs, &hint);
            if batch.is_empty() {
                break;
            }
            assert!(batch.len() <= 4);
            for p in &batch {
                seen.entry(if p.key == "a" { "a" } else { "b" })
                    .or_default()
                    .push(p.seq);
            }
        }
        for (k, seqs) in seen {
            let mut sorted = seqs.clone();
            sorted.sort_unstable();
            assert_eq!(seqs, sorted, "model {k} requests were reordered");
        }
    }

    #[test]
    fn drr_rotation_does_not_favor_the_first_key() {
        // max_batch 3 over 3 queues -> quantum 1; rotation must cycle so
        // each queue drains at the same rate across batches.
        let mut qs = filled(&[("a", 3), ("b", 3), ("c", 3)]);
        let hint = BatchHint { max_batch: 3, parallelism: 4, lanes: 1 };
        let mut drr = DeficitRoundRobin::new();
        for _ in 0..3 {
            let batch = drr.next_batch(&mut qs, &hint);
            let mut ks = keys(&batch);
            ks.sort_unstable();
            assert_eq!(ks, ["a", "b", "c"], "each batch serves each tenant");
        }
        assert!(qs.is_empty());
    }

    /// Regression: when the pop that *fills the batch* is also the pop
    /// that *empties a queue*, that queue's unspent credit must still be
    /// forfeited — otherwise an idle tenant returns with hoarded deficit
    /// and takes more than its round-robin share.
    #[test]
    fn drr_forfeits_credit_when_the_filling_pop_empties_a_queue() {
        let hint = BatchHint { max_batch: 4, parallelism: 4, lanes: 1 };
        let mut drr = DeficitRoundRobin::new();
        // Batch 1 trace (quantum 1 over {a,b,c}, then 2 over {a,b}): a's
        // second request is the pop that both fills the batch and empties
        // a, leaving a with 1 unspent credit unless it is forfeited.
        let mut qs = filled(&[("a", 2), ("b", 2), ("c", 1)]);
        let b1 = drr.next_batch(&mut qs, &hint);
        assert_eq!(b1.len(), 4);
        assert_eq!(qs.len_of("a"), 0, "a emptied by the filling pop");
        // Batch 2: only b's leftover — moves the cursor past a.
        let b2 = drr.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b2), ["b"]);
        // a returns from idle; the rotation now visits a first.  With
        // hoarded credit a would take 3 of the 4 slots; its fair share
        // is exactly the quantum (2).
        for _ in 0..3 {
            push(&mut qs, "a");
        }
        for _ in 0..3 {
            push(&mut qs, "b");
        }
        let b3 = drr.next_batch(&mut qs, &hint);
        assert_eq!(b3.len(), 4);
        let a_share = keys(&b3).iter().filter(|&&k| k == "a").count();
        assert_eq!(a_share, 2, "returning tenant must not hoard deficit");
    }

    #[test]
    fn edf_serves_tight_deadlines_ahead_of_a_loose_backlog() {
        let t0 = Instant::now();
        let dl = |ms: u64| Some(t0 + std::time::Duration::from_millis(ms));
        let mut qs = QueueSet::new(64);
        // A patient backlog of 6 "big" requests (2 s deadlines), then 2
        // urgent "small" ones (20 ms) arriving last.
        for _ in 0..6 {
            push_dl(&mut qs, "big@v4", dl(2000), 0);
        }
        push_dl(&mut qs, "small@v4", dl(20), 0);
        push_dl(&mut qs, "small@v4", dl(20), 0);
        let hint = BatchHint { max_batch: 4, parallelism: 4, lanes: 1 };
        let b1 = Edf.next_batch(&mut qs, &hint);
        assert_eq!(
            keys(&b1),
            ["small@v4", "small@v4", "big@v4", "big@v4"],
            "urgent requests jump the patient backlog"
        );
        // FIFO on the same arrival order would have served big first.
        let b2 = Edf.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b2), ["big@v4"; 4]);
    }

    #[test]
    fn edf_orders_by_deadline_then_priority_then_seq() {
        let t0 = Instant::now();
        let dl = |ms: u64| Some(t0 + std::time::Duration::from_millis(ms));
        let mut qs = QueueSet::new(64);
        push_dl(&mut qs, "none@v0", None, 255); // no deadline: last, even at max priority
        push_dl(&mut qs, "lo@v0", dl(50), 1); // same deadline, lower priority
        push_dl(&mut qs, "hi@v0", dl(50), 9); // same deadline, higher priority
        push_dl(&mut qs, "early@v0", dl(10), 0); // earliest deadline wins outright
        let hint = BatchHint { max_batch: 8, parallelism: 8, lanes: 1 };
        let b = Edf.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b), ["early@v0", "hi@v0", "lo@v0", "none@v0"]);
    }

    #[test]
    fn edf_without_deadlines_is_fifo() {
        let mut qs = filled(&[("b", 2), ("a", 2)]);
        let hint = BatchHint { max_batch: 8, parallelism: 8, lanes: 1 };
        let b = Edf.next_batch(&mut qs, &hint);
        assert_eq!(
            b.iter().map(|p| p.seq).collect::<Vec<_>>(),
            [0, 1, 2, 3],
            "deadline-less EDF degrades to global arrival order"
        );
    }

    #[test]
    fn policies_always_progress_on_nonempty_queues() {
        for kind in [PolicyKind::Fifo, PolicyKind::Drr, PolicyKind::Edf] {
            let mut qs = filled(&[("only", 5)]);
            let mut p = kind.build();
            let hint = BatchHint { max_batch: 2, parallelism: 1, lanes: 1 };
            let mut served = 0;
            while !qs.is_empty() {
                let b = p.next_batch(&mut qs, &hint);
                assert!(!b.is_empty(), "{kind}: empty batch on non-empty queues");
                assert!(b.len() <= 2);
                served += b.len();
            }
            assert_eq!(served, 5, "{kind}");
        }
    }

    #[test]
    fn fifo_tops_up_same_model_runs_to_lane_multiples() {
        // Arrivals interleave a, b, a, b; a lanes-2 backend wants
        // same-model pairs, so FIFO pulls each model's own next request
        // forward instead of handing the packer a fully mixed batch.
        let mut qs = QueueSet::new(16);
        for key in ["a", "b", "a", "b"] {
            push(&mut qs, key);
        }
        let hint = BatchHint { max_batch: 4, parallelism: 8, lanes: 2 };
        let b = Fifo.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b), ["a", "a", "b", "b"]);
        // Per-model FIFO held: a's seqs in order, then b's in order.
        assert_eq!(b.iter().map(|p| p.seq).collect::<Vec<_>>(), [0, 2, 1, 3]);
    }

    #[test]
    fn lane_top_up_never_stalls_on_a_dry_queue() {
        // One request per model at lanes 4: runs stay short (the
        // preference yields), and the batch still forms.
        let mut qs = filled(&[("a", 1), ("b", 1)]);
        let hint = BatchHint { max_batch: 8, parallelism: 8, lanes: 4 };
        let b = Fifo.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b), ["a", "b"]);
        assert!(qs.is_empty());
    }

    #[test]
    fn edf_tops_up_the_urgent_model_to_lane_width() {
        let t0 = Instant::now();
        let dl = |ms: u64| Some(t0 + std::time::Duration::from_millis(ms));
        let mut qs = QueueSet::new(64);
        for _ in 0..4 {
            push_dl(&mut qs, "big@v4", dl(2000), 0);
        }
        push_dl(&mut qs, "small@v4", dl(20), 0);
        // small's second request is *looser* than every big deadline —
        // plain EDF would serve it last, but the lane top-up rides it
        // along with small's urgent head to complete the pack.
        push_dl(&mut qs, "small@v4", dl(5000), 0);
        let hint = BatchHint { max_batch: 4, parallelism: 8, lanes: 2 };
        let b = Edf.next_batch(&mut qs, &hint);
        assert_eq!(keys(&b), ["small@v4", "small@v4", "big@v4", "big@v4"]);
    }

    #[test]
    fn drr_quantum_rounds_up_to_lane_multiples() {
        // max_batch 6 over 2 tenants -> base quantum 3; at lanes 4 each
        // visit serves a whole pack of 4 instead of stranding a lane.
        let mut qs = filled(&[("a", 8), ("b", 8)]);
        let hint = BatchHint { max_batch: 6, parallelism: 8, lanes: 4 };
        let mut drr = DeficitRoundRobin::new();
        let b1 = drr.next_batch(&mut qs, &hint);
        assert_eq!(b1.len(), 6);
        assert_eq!(
            keys(&b1).iter().filter(|&&k| k == "a").count(),
            4,
            "first tenant's run is a whole lane pack"
        );
    }

    #[test]
    fn lane_width_beyond_max_batch_still_respects_the_cap() {
        for kind in [PolicyKind::Fifo, PolicyKind::Drr, PolicyKind::Edf] {
            let mut qs = filled(&[("only", 5)]);
            let mut p = kind.build();
            let hint = BatchHint { max_batch: 2, parallelism: 1, lanes: 8 };
            let mut served = 0;
            while !qs.is_empty() {
                let b = p.next_batch(&mut qs, &hint);
                assert!(!b.is_empty() && b.len() <= 2, "{kind}");
                served += b.len();
            }
            assert_eq!(served, 5, "{kind}");
        }
    }

    #[test]
    fn batch_hint_target_fill_clamps() {
        let h = BatchHint { max_batch: 64, parallelism: 8, lanes: 1 };
        assert_eq!(h.target_fill(), 8);
        let h = BatchHint { max_batch: 4, parallelism: 8, lanes: 1 };
        assert_eq!(h.target_fill(), 4);
        let h = BatchHint { max_batch: 4, parallelism: 0, lanes: 1 };
        assert_eq!(h.target_fill(), 1);
    }
}
