//! Bounded per-model request queues with admission control — the buffer
//! stage of the serving scheduler (DESIGN.md §14).
//!
//! Every admitted request lands in the FIFO queue of its `(model,
//! variant)` key.  Queues are **bounded** (`--queue-cap`): a tenant that
//! submits faster than the backend drains is rejected at admission with a
//! structured error on its [`super::Ticket`] — the scheduler never grows
//! an unbounded backlog and never lets one tenant's flood consume the
//! dispatcher's memory.  [`Pending::seq`] is the *global* arrival order,
//! so a policy that wants strict FIFO across tenants ([`super::policy::Fifo`])
//! can reconstruct it exactly.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use super::ReplyTx;

/// One admitted, not-yet-dispatched inference request.
pub struct Pending {
    /// Registry key (`"<model>@<variant>"`, see [`super::model_key`]).
    pub key: String,
    /// Global arrival sequence number — total order across every queue.
    pub seq: u64,
    /// Packed int8 input image.
    pub input: Vec<u8>,
    /// Absolute completion deadline, when the request carries one
    /// (`"deadline_ms"` on the wire).  Stored as data, not read from a
    /// clock: policies may *compare* deadlines ([`super::policy::Edf`])
    /// and stay pure functions of the queue state.
    pub deadline: Option<Instant>,
    /// Scheduling priority (`"priority"` on the wire, 0–255; higher is
    /// more urgent).  Tie-breaks equal deadlines under EDF.
    pub priority: u8,
    /// Where the reply (or a structured error) goes.
    pub(crate) reply: ReplyTx,
    /// Client submission time — the latency clock starts here (it covers
    /// channel wait + queueing + execution, the number a caller sees).
    pub(crate) submitted: Instant,
}

/// The set of bounded per-model queues the scheduler drains.
///
/// Keys iterate in sorted order everywhere ([`BTreeMap`]), so every
/// policy decision over "the active queues" is deterministic for a given
/// arrival sequence.
pub struct QueueSet {
    /// Per-queue capacity (admission bound).
    cap: usize,
    queues: BTreeMap<String, VecDeque<Pending>>,
    next_seq: u64,
    total: usize,
}

impl QueueSet {
    /// A queue set whose per-model queues hold at most `cap` requests.
    pub fn new(cap: usize) -> QueueSet {
        QueueSet {
            cap: cap.max(1),
            queues: BTreeMap::new(),
            next_seq: 0,
            total: 0,
        }
    }

    /// Admission control: enqueue a request onto `key`'s queue, or reject
    /// it when that queue is at capacity.  Rejection hands the reply
    /// sender back with the structured error message the caller forwards
    /// to the ticket — admission pressure is an *answer*, never a panic
    /// and never a dropped request.
    pub(crate) fn admit(
        &mut self,
        key: String,
        input: Vec<u8>,
        reply: ReplyTx,
        submitted: Instant,
        deadline: Option<Instant>,
        priority: u8,
    ) -> Result<(), (ReplyTx, String)> {
        let q = self.queues.entry(key.clone()).or_default();
        if q.len() >= self.cap {
            return Err((
                reply,
                format!(
                    "{key}: admission rejected — queue full \
                     ({} pending, cap {})",
                    q.len(),
                    self.cap
                ),
            ));
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        q.push_back(Pending {
            key,
            seq,
            input,
            deadline,
            priority,
            reply,
            submitted,
        });
        self.total += 1;
        Ok(())
    }

    /// Requests queued across every model.
    pub fn total(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Queued requests for one key.
    pub fn len_of(&self, key: &str) -> usize {
        self.queues.get(key).map_or(0, VecDeque::len)
    }

    /// Sorted keys of the currently non-empty queues.
    pub fn active_keys(&self) -> Vec<String> {
        self.queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Pop the oldest queued request of `key` (its per-model FIFO head).
    pub fn pop(&mut self, key: &str) -> Option<Pending> {
        let p = self.queues.get_mut(key)?.pop_front()?;
        self.total -= 1;
        Some(p)
    }

    /// Pop the globally-oldest request (lowest [`Pending::seq`]; one
    /// scan, no key clone) — what strict cross-tenant FIFO
    /// ([`super::policy::Fifo`]) serves next.
    pub fn pop_oldest(&mut self) -> Option<Pending> {
        self.pop_front_min_by(|p| p.seq)
    }

    /// Pop the queue-head request minimizing `key_fn` — the generalized
    /// head-of-line scan behind [`Self::pop_oldest`] and the EDF policy
    /// ([`super::policy::Edf`]).  Only queue *fronts* compete, so
    /// per-model FIFO order (the policy contract) is preserved whatever
    /// the key function says.
    pub fn pop_front_min_by<K: Ord>(
        &mut self,
        key_fn: impl Fn(&Pending) -> K,
    ) -> Option<Pending> {
        let (_, q) = self
            .queues
            .iter_mut()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| key_fn(q.front().expect("non-empty queue")))?;
        let p = q.pop_front()?;
        self.total -= 1;
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink() -> ReplyTx {
        std::sync::mpsc::channel().0
    }

    fn push(qs: &mut QueueSet, key: &str, input: Vec<u8>) -> Result<(), String> {
        qs.admit(key.to_string(), input, sink(), Instant::now(), None, 0)
            .map_err(|(_, msg)| msg)
    }

    #[test]
    fn admission_bounds_each_queue_independently() {
        let mut qs = QueueSet::new(2);
        assert!(push(&mut qs, "a@v0", vec![1]).is_ok());
        assert!(push(&mut qs, "a@v0", vec![2]).is_ok());
        let msg = push(&mut qs, "a@v0", vec![3]).unwrap_err();
        assert!(msg.contains("queue full"), "{msg}");
        assert!(msg.contains("a@v0"), "{msg}");
        // A different model's queue is unaffected by a's pressure.
        assert!(push(&mut qs, "b@v0", vec![4]).is_ok());
        assert_eq!(qs.total(), 3);
        assert_eq!(qs.len_of("a@v0"), 2);
        // Draining reopens admission.
        assert!(qs.pop("a@v0").is_some());
        assert!(push(&mut qs, "a@v0", vec![5]).is_ok());
    }

    #[test]
    fn seq_is_global_arrival_order_and_pop_oldest_tracks_it() {
        let mut qs = QueueSet::new(8);
        push(&mut qs, "b@v0", vec![]).unwrap();
        push(&mut qs, "a@v0", vec![]).unwrap();
        push(&mut qs, "b@v0", vec![]).unwrap();
        let p = qs.pop_oldest().unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("b@v0", 0));
        let p = qs.pop_oldest().unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("a@v0", 1));
        let p = qs.pop_oldest().unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("b@v0", 2));
        assert!(qs.is_empty());
        assert!(qs.pop_oldest().is_none());
    }

    #[test]
    fn pop_front_min_by_competes_queue_heads_only() {
        let mut qs = QueueSet::new(8);
        let t0 = Instant::now();
        // a: deadlines [late, early] — the early one is *behind* the late
        // one in a's FIFO, so it must not jump the head.
        let mut admit = |key: &str, dl: Option<Instant>| {
            qs.admit(key.to_string(), vec![], sink(), t0, dl, 0).unwrap()
        };
        admit("a@v0", Some(t0 + std::time::Duration::from_millis(500)));
        admit("a@v0", Some(t0 + std::time::Duration::from_millis(1)));
        admit("b@v0", Some(t0 + std::time::Duration::from_millis(100)));
        let key = |p: &Pending| (p.deadline.is_none(), p.deadline, p.seq);
        let p = qs.pop_front_min_by(key).unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("b@v0", 2), "b's head is earliest");
        let p = qs.pop_front_min_by(key).unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("a@v0", 0), "a stays FIFO");
        let p = qs.pop_front_min_by(key).unwrap();
        assert_eq!((p.key.as_str(), p.seq), ("a@v0", 1));
        assert!(qs.pop_front_min_by(key).is_none());
    }

    #[test]
    fn active_keys_sorted_and_skip_drained_queues() {
        let mut qs = QueueSet::new(8);
        push(&mut qs, "z@v4", vec![]).unwrap();
        push(&mut qs, "a@v0", vec![]).unwrap();
        push(&mut qs, "m@v1", vec![]).unwrap();
        assert_eq!(qs.active_keys(), ["a@v0", "m@v1", "z@v4"]);
        qs.pop("m@v1").unwrap();
        assert_eq!(qs.active_keys(), ["a@v0", "z@v4"]);
    }
}
